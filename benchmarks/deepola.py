"""Deep OLA benchmarks (DESIGN.md §13): fused joins + nested aggregates.

Two claims get numbers here:

  * **fused join wall** — a Q3-class two-table join (lineitem ⋈ orders,
    grouped by the probed market segment) on the fused single-dispatch
    kernel (probe tables as Pallas operands) vs the legacy per-member
    kernel batcher (``fused=None`` — the oversized-probe fallback) vs the
    segment-sum scan path.  The ``fused_single_dispatch`` audit check is
    run with ``raise_on_failure=True`` before timing; the fused result is
    asserted bitwise-identical to the scan path (the legacy batcher
    re-associates its per-round-slice sums, so it is held to allclose) —
    same answer, fewer dispatches.
  * **nested time-to-ε** — GROUP BY + HAVING over *estimated* aggregates
    (the Deep OLA query shape): wall time for the full refinement plus
    how many rounds the monotone envelope needs to tighten under a 10%
    relative width, reported alongside the flat join's convergence so
    the cost of nesting is visible.

Output: CSV to stdout + benchmarks/out/BENCH_deepola.json (schema rows
in benchmarks/README.md; seeded baseline in benchmarks/baselines/).
"""
from __future__ import annotations

import sys

import jax
import jax.numpy as jnp
import numpy as np

try:
    from benchmarks import bench_io
except ImportError:  # direct script invocation: benchmarks/ is sys.path[0]
    import bench_io

from repro.analysis import audit as AU
from repro.core import engine, randomize
from repro.core import estimators as E
from repro.core import gla as G
from repro.core.spec import QuerySpec
from repro.data import tpch
from repro.kernels import fused_agg as FK

ROWS = 2_000_000
SMOKE_ROWS = 400_000
PARTS = 4
CHUNK = 1024
ROUNDS = 16
EPS = 0.10


def _shards(cols, rows):
    parts = randomize.randomize_global(
        {k: jnp.asarray(v) for k, v in cols.items()}, jax.random.key(31),
        PARTS)
    n_chunks = -(-rows // PARTS // CHUNK)
    return randomize.pack_partitions(
        parts, chunk_len=CHUNK,
        min_chunks=-(-n_chunks // ROUNDS) * ROUNDS)


def _rounds_to_eps(lower, upper, estimate):
    """First round whose monotone-envelope relative width is under EPS
    (-1 when the run never got there)."""
    lo, hi = map(np.asarray, E.monotone_envelope(
        jnp.asarray(lower), jnp.asarray(upper)))
    mid = np.abs(np.asarray(estimate, np.float64))
    w = (hi.astype(np.float64) - lo.astype(np.float64)) \
        / np.maximum(mid, 1e-12)
    ok = np.flatnonzero(w <= EPS)
    return int(ok[0]) + 1 if ok.size else -1


def run(rows=ROWS, repeats=3, out=sys.stdout):
    cols, q3, _ = tpch.q3_scenario(rows)
    shards = _shards(cols, rows)
    legacy = q3.with_(fused=None)

    # pre-timing certificates: the fused join really is one dispatch per
    # round-slice with the probe tables riding in-kernel
    report = AU.audit_plan(q3, shards, rounds=ROUNDS, emit="kernel",
                           checks=("fused_single_dispatch",),
                           raise_on_failure=True)
    probe_bytes = report.result("fused_single_dispatch").data["probe_bytes"]
    assert probe_bytes > 0, "join probes must ride as kernel operands"

    def run_scan():
        res = engine.run_query(QuerySpec(q3, rounds=ROUNDS, emit="chunk"),
                               shards)
        jax.block_until_ready(res.final)
        return res

    def run_legacy():
        res = engine.run_query(QuerySpec(legacy, rounds=ROUNDS,
                                         emit="kernel"), shards)
        jax.block_until_ready(res.final)
        return res

    def run_fused():
        res = engine.run_query(QuerySpec(q3, rounds=ROUNDS, emit="kernel"),
                               shards)
        jax.block_until_ready(res.final)
        return res

    scan_us, legacy_us, fused_us = bench_io.time_interleaved(
        [run_scan, run_legacy, run_fused], repeats)

    ref = run_scan()
    for a, b in zip(jax.tree.leaves(run_fused().final),
                    jax.tree.leaves(ref.final)):
        assert np.asarray(a).tobytes() == np.asarray(b).tobytes(), (
            "fused join final differs from the scan path")
    np.testing.assert_allclose(             # legacy batcher re-associates
        np.asarray(run_legacy().final), np.asarray(ref.final), rtol=1e-5)

    flat_eps = _rounds_to_eps(
        ref.estimates.lower[..., 0], ref.estimates.upper[..., 0],
        ref.estimates.estimate[..., 0])

    # nested GROUP BY + HAVING over the same join: full-refinement wall +
    # envelope rounds-to-ε for the Deep OLA shape
    hv = G.make_having_gla(q3, 1.0)

    def run_nested():
        res = engine.run_query(QuerySpec(hv, rounds=ROUNDS), shards)
        jax.block_until_ready(res.estimates.estimate)
        return res

    nested_us, = bench_io.time_interleaved([run_nested], repeats)
    nres = run_nested()
    nested_eps = _rounds_to_eps(nres.estimates.lower, nres.estimates.upper,
                                nres.estimates.estimate)
    assert np.isfinite(np.asarray(nres.estimates.estimate)).all()

    bench_rows = [
        ("scan_join_q3", scan_us, {
            "rows": rows, "rounds": ROUNDS,
            "rounds_to_eps10": flat_eps}),
        ("legacy_kernel_join_q3", legacy_us, {
            "rows": rows, "rounds": ROUNDS,
            "allclose_vs_scan": True}),
        ("fused_join_q3", fused_us, {
            "rows": rows, "rounds": ROUNDS,
            "speedup_vs_scan": scan_us / fused_us,
            "speedup_vs_legacy": legacy_us / fused_us,
            "probe_bytes": int(probe_bytes),
            "bitwise_vs_scan": True}),
        ("nested_having_q3", nested_us, {
            "rows": rows, "rounds": ROUNDS,
            "overhead_vs_flat_scan": nested_us / scan_us,
            "rounds_to_eps10": nested_eps}),
    ]
    print("name,us_per_call,derived", file=out)
    rows_out = []
    for name, us, derived in bench_rows:
        print(f"{name},{us:.0f},"
              + ";".join(f"{k}={v}" for k, v in derived.items()), file=out)
        rows_out.append({"name": name, "us_per_call": us, "derived": derived})

    path = bench_io.emit("deepola", rows_out)
    print(f"# wrote {path}", file=out)


if __name__ == "__main__":
    run(rows=int(sys.argv[1]) if len(sys.argv) > 1 else ROWS)
