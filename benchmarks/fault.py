"""Fault tolerance: what losing shards mid-scan costs (DESIGN.md §9).

The runtime fault path (``repro.core.session.FaultPolicy``) renormalizes
the ``single``-estimator merge over the surviving partitions, so a query
that loses shards still finishes with finite variance-floored bounds —
over less data.  This benchmark measures the two prices of survival on a
P=8 session that loses {0, 1, 2, 4} partitions at the mid-scan round:

    us_per_call       — wall time of the full degraded run (median)
    bound_width       — confidence-interval width (upper - lower) at the
                        failure round: by scan end a no-failure run
                        collapses the interval to zero (the variance
                        floor's |D| - |S| term vanishes), so mid-scan is
                        where the rows compare
    width_inflation   — bound_width / the no-failure run's width
    recovery_step_us  — wall time of the failure-absorbing round itself
                        (the step that drops to the alive-mask program)

The no-failure row (lost=0) is the baseline the inflation ratios divide
by; width inflation should grow roughly like 1/sqrt(alive/P) while wall
time stays flat — failure handling is a reweighting, not a re-scan.

Output: CSV to stdout + benchmarks/out/BENCH_fault.json.
"""
from __future__ import annotations

import sys
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import gla, randomize
from repro.core import session as S
from repro.core.spec import QuerySpec
from repro.data import tpch

ROWS = 500_000
SMOKE_ROWS = 100_000
PARTS = 8
ROUNDS = 8
CHUNK = 1024
FAIL_ROUND = ROUNDS // 2
LOST = (0, 1, 2, 4)


def _shards(rows):
    cols = tpch.generate_lineitem(rows, seed=13)
    parts = randomize.randomize_global(
        {k: jnp.asarray(v) for k, v in cols.items()}, jax.random.key(13),
        PARTS)
    n_chunks = -(-rows // PARTS // CHUNK)
    return randomize.pack_partitions(
        parts, chunk_len=CHUNK, min_chunks=-(-n_chunks // ROUNDS) * ROUNDS)


def _q6(rows):
    def func(c):
        return c["quantity"]

    def cond(c):
        sd = c["shipdate"]
        return ((sd >= 0) & (sd < 1460)).astype(jnp.float32)

    return gla.make_sum_gla(func, cond, d_total=float(rows),
                            estimator="single")


def _drive_timed(g, shards, fail_at):
    """One full chaos run; returns (total_us, fail_round_step_us, width)."""
    sess = S.Session(
        QuerySpec(g, rounds=ROUNDS,
                  fault=S.FaultPolicy("single", fail_at=fail_at)),
        shards)
    step_us = 0.0
    t0 = time.perf_counter()
    while not sess.done:
        r = sess.steps_taken
        t1 = time.perf_counter()
        prog = sess.step()
        jax.block_until_ready(jax.tree.leaves(prog.estimates))
        if r == FAIL_ROUND:
            step_us = (time.perf_counter() - t1) * 1e6
    res = sess.result()
    jax.block_until_ready(res.final)
    total_us = (time.perf_counter() - t0) * 1e6
    est = res.estimates
    width = float(np.max(np.asarray(est.upper)[FAIL_ROUND]
                         - np.asarray(est.lower)[FAIL_ROUND]))
    return total_us, step_us, width


def run(rows=ROWS, repeats=3, out=sys.stdout):
    shards = _shards(rows)
    g = _q6(rows)
    bench_rows = []
    base_width = None
    print("name,us_per_call,derived", file=out)
    for lost in LOST:
        fail_at = {p: FAIL_ROUND for p in range(lost)}
        _drive_timed(g, shards, fail_at)  # warm (compile both programs)
        totals, steps, width = [], [], None
        for _ in range(repeats):
            total_us, step_us, width = _drive_timed(g, shards, fail_at)
            totals.append(total_us)
            steps.append(step_us)
        total_us = float(np.median(totals))
        step_us = float(np.median(steps))
        if lost == 0:
            base_width = width
        inflation = width / base_width if base_width else float("inf")
        derived = {
            "lost": lost, "alive": PARTS - lost, "fail_round": FAIL_ROUND,
            "bound_width": width, "width_inflation": inflation,
            "recovery_step_us": step_us,
        }
        print(f"fault_lost{lost}_of{PARTS},{total_us:.0f},"
              f"width={width:.4g};inflation={inflation:.3f};"
              f"recovery_us={step_us:.0f}", file=out)
        bench_rows.append({"name": f"fault_lost{lost}_of{PARTS}",
                           "us_per_call": total_us, "derived": derived})

    try:
        from benchmarks import bench_io
    except ImportError:  # direct script invocation: benchmarks/ is sys.path[0]
        import bench_io
    path = bench_io.emit("fault", bench_rows)
    print(f"# wrote {path}", file=out)


if __name__ == "__main__":
    run(rows=int(sys.argv[1]) if len(sys.argv) > 1 else ROWS)
