"""Diff benchmarks/out/BENCH_*.json against the committed baselines.

    python benchmarks/compare_baseline.py [out_dir [baseline_dir]]

CI runs this after ``python -m benchmarks.run --smoke`` +
``check_schema.py``: the schema validator checks each file in isolation;
this gate checks the *trajectory* — the benchmark surface may only grow,
never silently shrink or drift:

  * every baseline file must be produced by the current smoke run;
  * every baseline row (by ``name``) must still be present;
  * every key a baseline row carries (including ``derived`` sub-keys) must
    still be present — dropping a reported metric is schema drift and
    fails;
  * ``us_per_call`` must stay under a *sanity ceiling*:
    max(CEIL_FLOOR_US, CEIL_FACTOR x baseline).  CI runners are noisy, so
    the ceiling is deliberately generous — it catches hangs and
    asymptotic blowups, not percent-level regressions (those are read off
    the uploaded artifacts).

New files and new rows pass with a note: they seed the next baseline
(refresh with ``cp benchmarks/out/BENCH_*.json benchmarks/baselines/``).
"""
from __future__ import annotations

import json
import sys
from pathlib import Path

CEIL_FACTOR = 50.0
CEIL_FLOOR_US = 10_000_000.0  # 10 s: below this, never fail on time alone


def _rows_by_name(payload: dict) -> dict:
    return {r["name"]: r for r in payload.get("rows", [])}


def compare_file(base: dict, new: dict, fname: str) -> list:
    errs = []
    base_rows, new_rows = _rows_by_name(base), _rows_by_name(new)
    for name, brow in base_rows.items():
        nrow = new_rows.get(name)
        if nrow is None:
            errs.append(f"{fname}: baseline row {name!r} disappeared")
            continue
        missing = set(brow) - set(nrow)
        if missing:
            errs.append(f"{fname}: row {name!r} dropped keys "
                        f"{sorted(missing)} (schema drift)")
        if isinstance(brow.get("derived"), dict) \
                and isinstance(nrow.get("derived"), dict):
            dmissing = set(brow["derived"]) - set(nrow["derived"])
            if dmissing:
                errs.append(f"{fname}: row {name!r} dropped derived keys "
                            f"{sorted(dmissing)} (schema drift)")
        if "us_per_call" in brow and "us_per_call" in nrow:
            ceil = max(CEIL_FLOOR_US, CEIL_FACTOR * float(brow["us_per_call"]))
            if float(nrow["us_per_call"]) > ceil:
                errs.append(
                    f"{fname}: row {name!r} us_per_call "
                    f"{nrow['us_per_call']:.0f} exceeds the sanity ceiling "
                    f"{ceil:.0f} (baseline {brow['us_per_call']:.0f})")
    extra = set(new_rows) - set(base_rows)
    if extra:
        print(f"note {fname}: {len(extra)} new row(s) not in the baseline "
              "(will seed the next refresh)")
    return errs


def main(argv=None) -> int:
    argv = sys.argv[1:] if argv is None else argv
    here = Path(__file__).parent
    out_dir = Path(argv[0]) if argv else here / "out"
    base_dir = Path(argv[1]) if len(argv) > 1 else here / "baselines"
    base_files = sorted(base_dir.glob("BENCH_*.json"))
    if not base_files:
        print(f"FAIL: no baselines under {base_dir}")
        return 1
    failed = False
    for bpath in base_files:
        npath = out_dir / bpath.name
        if not npath.exists():
            print(f"FAIL {bpath.name}: not produced by this run")
            failed = True
            continue
        try:
            base = json.loads(bpath.read_text())
            new = json.loads(npath.read_text())
        except (OSError, json.JSONDecodeError) as e:
            print(f"FAIL {bpath.name}: unreadable ({e})")
            failed = True
            continue
        errs = compare_file(base, new, bpath.name)
        if errs:
            failed = True
            for e in errs:
                print(f"FAIL {e}")
        else:
            print(f"OK   {bpath.name}: {len(base.get('rows', []))} baseline "
                  "rows present, ceilings respected")
    new_only = {p.name for p in out_dir.glob("BENCH_*.json")} \
        - {p.name for p in base_files}
    for name in sorted(new_only):
        print(f"note {name}: no baseline yet (seed it from this run)")
    return 1 if failed else 0


if __name__ == "__main__":
    sys.exit(main())
