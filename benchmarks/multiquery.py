"""Multi-query shared scan: one pass serving N queries vs N solo passes.

PF-OLA's framing (§3–§4) is a *workload* of concurrent estimations riding
one execution.  This benchmark runs N ∈ {1, 2, 4, 8} mixed TPC-H queries
(Q6 windows, Q1 small/large-domain group-by, join group-by) two ways:

  * ``shared``  — ``engine.run_queries``: all N stacked into a GLABundle,
    ONE scan of the shards feeds every query (emit="round").
  * ``n_pass``  — N solo ``engine.run_query`` calls, each paying its own
    full pass (today's baseline for a second concurrent query).

Reported per N: warm wall time (interleaved min-of-repeats) for both, the
speedup, and the HLO scan-loop structure from
``repro/analysis/hlo_cost.py::count_ops``: the shared program must contain
exactly as many ``while`` ops as the single-query program — the round loop
and the chunk loop, i.e. ONE chunk pass regardless of N — while the n-pass
baseline grows linearly.  ``single_chunk_pass_hlo_verified`` records that
assertion (the acceptance gate for N=4).

A second section batches a kernel-capable bundle through
``emit="kernel"``: the fused program issues ONE ``ops.group_agg`` Pallas
dispatch per (partition, round-slice) for the whole bundle, vs one per
member solo (``kernel_dispatches`` in the derived fields).

Output: CSV (name,us_per_call,derived) to stdout + benchmarks/out/
BENCH_multiquery.json (schema in benchmarks/README.md).
"""
from __future__ import annotations

import sys
import time

import jax
import numpy as np

try:
    from benchmarks import bench_io
except ImportError:  # direct script invocation: benchmarks/ is sys.path[0]
    import bench_io

from repro.analysis import audit
from repro.analysis import hlo_cost as HC
from repro.core import engine, gla, randomize
from repro.core.spec import QuerySpec
from repro.data import tpch

ROWS = 150_000
SMOKE_ROWS = 24_000
PARTS = 4
CHUNK = 512
ROUNDS = 4
NS = (1, 2, 4, 8)


def _shards(cols, rows):
    import jax.numpy as jnp

    parts = randomize.randomize_global(
        {k: jnp.asarray(v) for k, v in cols.items()}, jax.random.key(11),
        PARTS)
    n_chunks = -(-rows // PARTS // CHUNK)
    return randomize.pack_partitions(
        parts, chunk_len=CHUNK, min_chunks=-(-n_chunks // ROUNDS) * ROUNDS)


def _query_pool(rows):
    """Eight distinct queries cycling the paper's families."""
    supp, valid = tpch.supplier_nation_table(tpch.Q1_LARGE_SUPPLIERS)
    d = float(rows)
    return [
        gla.make_sum_gla(tpch.q6_func, tpch.q6_cond(tpch.Q6_LOW_WINDOW),
                         d_total=d),
        gla.make_groupby_gla(tpch.q1_func, tpch.q1_cond, tpch.q1_group_small,
                             num_groups=4, d_total=d, num_aggs=4),
        gla.make_sum_gla(tpch.q6_func, tpch.q6_cond(tpch.Q6_HIGH_WINDOW),
                         d_total=d),
        gla.make_groupby_gla(tpch.q1_func, tpch.q1_cond, tpch.q1_group_large,
                             num_groups=tpch.Q1_LARGE_SUPPLIERS,
                             bucket_bits=tpch.Q1_LARGE_BUCKET_BITS,
                             d_total=d, num_aggs=4),
        gla.make_join_groupby_gla(
            tpch.q1_func, tpch.q6_cond(tpch.Q6_LOW_WINDOW),
            lambda c: c["suppkey"], supp, valid,
            num_groups=tpch.NUM_NATIONS, d_total=d, num_aggs=4),
        gla.make_sum_gla(tpch.q6_func, tpch.q6_cond((900, 1265)), d_total=d),
        gla.make_sum_gla(tpch.q6_func, tpch.q6_cond(tpch.Q6_LOW_WINDOW),
                         d_total=d, estimator="multiple"),
        gla.make_sum_gla(tpch.q6_func, tpch.q6_cond((1600, 1965)), d_total=d),
    ]


def _finals(results):
    """Pull every query's final out so nothing is DCE'd."""
    return [r.final for r in (results if isinstance(results, list)
                              else [results])]


def _time_interleaved(fns, shards, repeats):
    """fns: dict name -> compiled callable; min-of-repeats seconds per
    name, via the shared bench_io interleaved timer."""
    names = list(fns)
    us = bench_io.time_interleaved(
        [lambda k=k: jax.block_until_ready(fns[k](shards)) for k in names],
        repeats, warmup=False)  # callers time pre-compiled executables
    return {k: t / 1e6 for k, t in zip(names, us)}


def run(out=sys.stdout, rows=ROWS, repeats=5):
    bench_rows = []

    def report(name, us, derived):
        bench_rows.append({"name": name, "us_per_call": us,
                           "derived": derived})
        dstr = ";".join(f"{k}={v}" for k, v in derived.items())
        print(f"{name},{us:.0f},{dstr}", file=out)

    cols = tpch.generate_lineitem(
        rows, seed=31, num_suppliers=tpch.Q1_LARGE_SUPPLIERS)
    shards = _shards(cols, rows)
    P, C, L = shards["_mask"].shape
    pool = _query_pool(rows)
    scen = {"rows": rows, "partitions": P, "chunks": C, "chunk_len": L,
            "rounds": ROUNDS}

    print("name,us_per_call,derived", file=out)

    # -- shared scan vs N passes over the round-emission scan path --------
    # The chunk-stream loop is the while op with trip count C/R (the
    # round loop wraps it with trip R); the shared audit catalog
    # (repro/analysis/audit.py) counts and certifies it.  ONE chunk pass
    # == exactly one trip-C/R loop.
    per = C // ROUNDS
    assert per != ROUNDS, (
        "pick sizes where chunks-per-round != rounds, or the round loop "
        "is indistinguishable from the chunk loop by trip count")

    solo_compiled = [
        jax.jit(lambda sh, s=QuerySpec(g, rounds=ROUNDS, emit="round"):
                _finals(engine.run_query(s, sh))).lower(shards).compile()
        for g in pool
    ]
    for n in NS:
        glas = pool[:n]
        shared_spec = QuerySpec(glas, rounds=ROUNDS, emit="round")
        shared = jax.jit(lambda sh, s=shared_spec: _finals(
            engine.run_queries(s, sh))).lower(shards).compile()

        def n_pass(sh, n=n):
            outs = []
            for c in solo_compiled[:n]:
                outs.append(c(sh))
            return outs

        best = _time_interleaved(
            {"shared": shared, "n_pass": n_pass}, shards, repeats)

        # THE multi-query invariant: the shared program loops over the
        # chunk stream once — N queries, one data pass (catalog check
        # one_chunk_pass, the acceptance gate for N=4).
        res = audit.check_one_chunk_pass(
            shared.as_text(), chunk_trip=per, where=f"shared N={n}")
        if res.failed:
            raise AssertionError(str(res))
        shared_passes = res.data["chunk_loops"]
        n_pass_passes = sum(audit.chunk_loop_count(c.as_text(), per)
                            for c in solo_compiled[:n])
        assert n_pass_passes == n, (n, n_pass_passes)

        # bitwise check: the shared pass returns exactly the solo results
        sh_finals = shared(shards)
        for i, c in enumerate(solo_compiled[:n]):
            for a, b in zip(jax.tree.leaves(sh_finals[i]),
                            jax.tree.leaves(c(shards))):
                assert np.asarray(a).tobytes() == np.asarray(b).tobytes(), \
                    f"query {i} diverged"

        report(f"multiquery_shared_scan_N{n}", best["shared"] * 1e6,
               {**scen, "queries": n,
                "n_pass_us": round(best["n_pass"] * 1e6),
                "one_pass_vs_n_pass_wall":
                    f"{best['n_pass'] / best['shared']:.2f}x",
                "hlo_chunk_scan_loops_shared": int(shared_passes),
                "hlo_chunk_scan_loops_n_pass": int(n_pass_passes),
                "single_chunk_pass_hlo_verified": shared_passes == 1,
                "finals_bitwise_identical_to_solo": True})

    # -- batched kernel dispatch: one group_agg launch serves the bundle --
    kernel_pool = [pool[3], pool[0], pool[4]]  # Q1-large, Q6, join
    kernel_spec = QuerySpec(kernel_pool, rounds=ROUNDS, emit="kernel")
    fused = jax.jit(lambda sh: _finals(engine.run_queries(
        kernel_spec, sh))).lower(shards).compile()
    # catalog check fused_single_dispatch: the whole bundle — join
    # included, its probe tables riding as kernel operands (DESIGN.md
    # §13) — runs the FUSED program, whose in-kernel segment_sums
    # scatter-expand into extra while loops under interpret mode; an
    # optimized-HLO while census cannot isolate the Pallas grid loops, so
    # certify one-dispatch-per-(partition, round-slice)-for-ALL-members
    # at trace time and report the while count as a lowering diagnostic.
    audit.audit_plan(gla.GLABundle(kernel_pool), shards, rounds=ROUNDS,
                     emit="kernel", checks=("fused_single_dispatch",),
                     raise_on_failure=True)
    fused_whiles = int(HC.count_ops(fused.as_text(), "while",
                                    trip_scaled=False))
    jax.block_until_ready(fused(shards))
    t0 = time.perf_counter()
    jax.block_until_ready(fused(shards))
    dt = time.perf_counter() - t0
    report("multiquery_kernel_bundle", dt * 1e6,
           {**scen, "queries": len(kernel_pool),
            "kernel_dispatches": P * ROUNDS,
            "kernel_dispatches_solo_total": len(kernel_pool) * P * ROUNDS,
            "hlo_while_loops": int(fused_whiles),
            "dispatch_counts_hlo_verified": False,
            "dispatch_counts_trace_verified": True,
            "note": "interpret mode on CPU; dispatch structure is the "
                    "platform-independent mechanism (DESIGN.md §6)"})

    path = bench_io.emit("multiquery", bench_rows)
    print(f"# wrote {path}", file=out)


if __name__ == "__main__":
    run(rows=int(sys.argv[1]) if len(sys.argv) > 1 else ROWS)
