"""Early termination: time-to-ε and fraction of the scan saved.

The paper's headline user feature is stopping "as soon as the estimate is
accurate enough, typically early in the execution".  This benchmark
measures what that is worth on the incremental session driver
(repro/core/session.py, DESIGN.md §7): for each query family it runs the
fused full scan and an early-terminating session side by side and reports

    time-to-ε      — wall time until the stopping rule fires (us)
    rounds_taken   — round-slices executed, of rounds_total
    frac_scan_saved — 1 - rounds_taken / rounds_total
    speedup        — full-scan wall / time-to-ε

Families where the rule never fires (the classic low-selectivity Q6: the
CI only collapses near the full scan) fall through to the complete scan —
frac_scan_saved 0 — which is itself the point: early termination is a
property of the query's convergence, not a discount applied blindly.

Output: CSV to stdout + benchmarks/out/BENCH_early_stop.json.
"""
from __future__ import annotations

import sys
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import engine, gla, randomize
from repro.core import session as S
from repro.core.spec import QuerySpec
from repro.data import tpch

ROWS = 500_000
PARTS = 4
ROUNDS = 32
CHUNK = 1024


def _shards(rows):
    cols = tpch.generate_lineitem(rows, seed=9)
    parts = randomize.randomize_global(
        {k: jnp.asarray(v) for k, v in cols.items()}, jax.random.key(9),
        PARTS)
    n_chunks = -(-rows // PARTS // CHUNK)
    return randomize.pack_partitions(
        parts, chunk_len=CHUNK, min_chunks=-(-n_chunks // ROUNDS) * ROUNDS)


def _wide_q6(d_total):
    def func(c):
        return c["quantity"]

    def cond(c):
        sd = c["shipdate"]
        return ((sd >= 0) & (sd < 1460)).astype(jnp.float32)

    return gla.make_sum_gla(func, cond, d_total=d_total)


def _families(rows):
    d = float(rows)
    return {
        # converges mid-scan: the early-termination win case
        "q6_wide_sum": (_wide_q6(d), 0.01, "chunk"),
        # classic Q6 low selectivity: 1% is only reached near the full
        # scan — the fall-through case
        "q6_low_sel": (gla.make_sum_gla(
            tpch.q6_func, tpch.q6_cond(tpch.Q6_LOW_WINDOW), d_total=d),
            0.01, "chunk"),
        # group-by: every group's CI must meet the rule
        "q1_groupby_small": (gla.make_groupby_gla(
            tpch.q1_func, tpch.q1_cond, tpch.q1_group_small, num_groups=4,
            d_total=d, num_aggs=4), 0.05, "round"),
    }


def _timed(fn, repeats):
    fn()  # warm (compile)
    ts = []
    for _ in range(repeats):
        t0 = time.perf_counter()
        fn()
        ts.append(time.perf_counter() - t0)
    return float(np.median(ts)) * 1e6


def run(rows=ROWS, repeats=3, out=sys.stdout):
    shards = _shards(rows)
    bench_rows = []
    print("name,us_per_call,derived", file=out)
    for name, (g, eps, emit) in _families(rows).items():
        def run_full(g=g, emit=emit):
            res = engine.run_query(
                QuerySpec(g, rounds=ROUNDS, emit=emit), shards)
            jax.block_until_ready(res.final)

        def run_session(g=g, emit=emit, eps=eps):
            sess = S.Session(QuerySpec(g, rounds=ROUNDS, emit=emit,
                                       stop=S.rel_width(eps)), shards)
            res = sess.run()
            jax.block_until_ready(res.final)
            return sess

        full_us = _timed(run_full, repeats)
        sess_us = _timed(run_session, repeats)
        sess = run_session()  # one more for the counters
        taken, total = sess.steps_taken, sess.rounds_total
        saved = 1.0 - taken / total
        speedup = full_us / sess_us if sess_us else float("inf")
        derived = {
            "eps": eps, "rounds_taken": taken, "rounds_total": total,
            "frac_scan_saved": saved, "full_scan_us": full_us,
            "speedup_vs_full": speedup, "converged": bool(sess.converged),
        }
        print(f"early_stop_{name},{sess_us:.0f},"
              f"rounds={taken}/{total};saved={saved:.3f};"
              f"speedup={speedup:.2f}", file=out)
        bench_rows.append({"name": f"early_stop_{name}",
                           "us_per_call": sess_us, "derived": derived})

    try:
        from benchmarks import bench_io
    except ImportError:  # direct script invocation: benchmarks/ is sys.path[0]
        import bench_io
    path = bench_io.emit("early_stop", bench_rows)
    print(f"# wrote {path}", file=out)


if __name__ == "__main__":
    run(rows=int(sys.argv[1]) if len(sys.argv) > 1 else ROWS)
