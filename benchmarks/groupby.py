"""Large-domain group-by: segment_sum scan vs per-round-slice Pallas dispatch.

The paper's headline scenario (§4.4, §5.3) is accurate on-line bounds for
TPC-H Q1 group-by with up to 1M groups.  This benchmark runs the scaled
large-domain Q1 (``repro/data/tpch.py::q1_large_scenario``: >=100k raw
suppkeys folded into 2**13 hash buckets) through both engine group-by
implementations:

  * ``emit="round"``  — the scan path: one ``jax.ops.segment_sum`` per
    state field per chunk (XLA's CPU scatter expander turns each into a
    per-item update loop; on TPU it is a sorted-segment / one-hot lowering).
  * ``emit="kernel"`` — the Pallas path: ONE fused
    selection→bucket→aggregate dispatch per round-slice of each shard
    (``repro/kernels/fused_agg.py``, DESIGN.md §12; the GLA publishes a
    ``FusedSpec``, so the engine prefers the fused kernel over the legacy
    ``ops.group_agg`` one-hot batcher).

Reported per variant: warm wall time (interleaved min-of-repeats, so load
drift cannot masquerade as speedup) and the dispatch structure extracted
from the optimized HLO by ``repro/analysis/hlo_cost.py::count_ops``:

  * ``hlo_while_loops``          — on the kernel path: interpret-mode
    Pallas grid loops plus the in-kernel segment_sums' scatter expansions
    (reported, not asserted — the one-dispatch-per-round-slice claim is
    certified at trace time by the ``fused_single_dispatch`` catalog
    check instead, DESIGN.md §12).
  * ``scatter_item_updates``     — trip-scaled ``dynamic-update-slice``
    count: the per-item scatter traffic of the expanded segment_sums.
  * ``hlo_flops``                — loop-aware HLO flops (the kernel path's
    cost is the dense one-hot MXU contraction).

Finals of the two paths are compared bitwise (the kernel accumulates
chunk-by-chunk in the scan's association order).

Wall-time caveat: on this CPU the kernel runs in Pallas *interpret* mode,
which materializes the [block, G] one-hot densely — so segment_sum wins
wall time here.  The dispatch counts and the flop/byte terms are the
platform-independent mechanism: on TPU the one-hot contraction is the MXU
lowering segment_sum itself resolves to, minus the per-chunk dispatch and
state-emission overhead (DESIGN.md §3).

Output: CSV (name,us_per_call,derived) to stdout + benchmarks/out/
BENCH_groupby.json (schema in benchmarks/README.md).
"""
from __future__ import annotations

import sys
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.analysis import audit
from repro.analysis import hlo_cost as HC
from repro.core import engine, randomize
from repro.core.spec import QuerySpec
from repro.data import tpch

ROWS = 200_000
PARTS = 8
# 512-row chunks keep the chunk count comfortably above ROUNDS at the 50k
# quick scale (see _shards for the >= 2-chunks-per-round-slice floor).
CHUNK = 512
ROUNDS = 8


def _shards(cols, rows):
    parts = randomize.randomize_global(
        {k: jnp.asarray(v) for k, v in cols.items()}, jax.random.key(17),
        PARTS)
    n_chunks = -(-rows // PARTS // CHUNK)
    # >= 2 chunks per round-slice at any row count: a 1-step Pallas grid is
    # unrolled in interpret mode and the HLO dispatch count would read 0
    return randomize.pack_partitions(
        parts, chunk_len=CHUNK,
        min_chunks=max(-(-n_chunks // ROUNDS), 2) * ROUNDS)


def run(out=sys.stdout, rows=ROWS):
    bench_rows = []

    def report(name, us, derived):
        bench_rows.append({"name": name, "us_per_call": us,
                           "derived": derived})
        dstr = ";".join(f"{k}={v}" for k, v in derived.items())
        print(f"{name},{us:.0f},{dstr}", file=out)

    cols, g = tpch.q1_large_scenario(rows, seed=29)
    shards = _shards(cols, rows)
    P, C, L = shards["_mask"].shape

    print("name,us_per_call,derived", file=out)

    # compile once per variant (AOT): the same executable serves the warm
    # runs, the timing loop, and the HLO dispatch counts
    specs = {emit: QuerySpec(g, rounds=ROUNDS, emit=emit)
             for emit in ("round", "kernel")}
    compiled = {
        emit: jax.jit(lambda sh, s=spec: engine.run_query(
            s, sh)).lower(shards).compile()
        for emit, spec in specs.items()
    }
    finals = {}
    for emit, fn in compiled.items():  # warm + capture finals
        finals[emit] = np.asarray(jax.block_until_ready(fn(shards).final))
    ts = {emit: [] for emit in compiled}
    for _ in range(5):  # interleaved round-robin, min-of-repeats
        for emit, fn in compiled.items():
            t0 = time.perf_counter()
            jax.block_until_ready(fn(shards).final)
            ts[emit].append(time.perf_counter() - t0)
    best = {emit: min(v) for emit, v in ts.items()}

    bitwise = finals["kernel"].tobytes() == finals["round"].tobytes()
    assert np.allclose(finals["kernel"], finals["round"], rtol=1e-5)

    counts = {
        emit: {
            "hlo_while_loops": int(HC.count_ops(h, "while",
                                                trip_scaled=False)),
            "scatter_item_updates": int(HC.count_ops(h,
                                                     "dynamic-update-slice")),
            "hlo_flops": HC.analyze(h)["flops"],
        }
        for emit, h in ((e, fn.as_text()) for e, fn in compiled.items())
    }
    # The loop/scatter structure below is the CPU emitter's lowering
    # (Pallas grid -> while loop, segment_sum -> scatter-expanded updates);
    # TPU and GPU lower both differently (custom-calls / native scatter),
    # so report without asserting there.
    # catalog check fused_single_dispatch: the kernel path is the FUSED
    # program (DESIGN.md §12), whose in-kernel segment_sums scatter-expand
    # into extra while loops under interpret mode — an optimized-HLO while
    # census cannot isolate the Pallas grid loops (the same gap that makes
    # the legacy single_kernel_dispatch check skip on fused plans).
    # Certify the dispatch structure the way the catalog does instead:
    # trace-time pallas_call accounting, exactly ONE fused dispatch per
    # (partition, round-slice); the HLO while/scatter counts above are
    # reported as backend-lowering diagnostics, not asserted.
    audit.audit_plan(g, shards, rounds=ROUNDS, emit="kernel",
                     checks=("fused_single_dispatch",),
                     raise_on_failure=True)

    scen = {"rows": rows, "partitions": P, "chunks": C, "chunk_len": L,
            "rounds": ROUNDS, "raw_groups": tpch.Q1_LARGE_SUPPLIERS,
            "buckets": 1 << tpch.Q1_LARGE_BUCKET_BITS}
    report("groupby_segment_sum_round", best["round"] * 1e6,
           {**scen, **counts["round"],
            "note": "3 segment_sums per chunk, scatter-expanded to "
                    "per-item updates on this backend"})
    report("groupby_kernel_dispatch", best["kernel"] * 1e6,
           {**scen, **counts["kernel"],
            "kernel_dispatches": P * ROUNDS,
            "dispatches_per_round_slice": 1,
            "dispatch_counts_hlo_verified": False,
            "dispatch_counts_trace_verified": True,
            "kernel_vs_segment_sum_wall":
                f"{best['round'] / best['kernel']:.2f}x",
            "finals_bitwise_identical": bool(bitwise)})

    try:
        from benchmarks import bench_io
    except ImportError:  # direct script invocation: benchmarks/ is sys.path[0]
        import bench_io
    path = bench_io.emit("groupby", bench_rows)
    print(f"# wrote {path}", file=out)


if __name__ == "__main__":
    run(rows=int(sys.argv[1]) if len(sys.argv) > 1 else ROWS)
