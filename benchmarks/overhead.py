"""Paper Table 2: execution time with estimation off / single / multiple /
synchronized — the zero-overhead claim.

Three measurements:
  1. wall time of the jitted engine on this CPU (vmapped partitions),
     median of repeats, for: no-estimation, single, multiple — the paper's
     Table 2 columns.  The claim reproduced: interactive == non-interactive.
  2. the roofline view: estimation adds arithmetic but no data movement, so
     on memory-bound platforms (the paper's disks, TPU HBM) the overhead is
     zero — we print both HLO terms to make that checkable.
  3. the sharded path (repro/dist/shard_engine.py) on an 8-fake-device
     mesh: no-snapshot baseline vs. async snapshot merging vs. the
     synchronized per-chunk barrier.  Async snapshots reuse states the scan
     already materializes (≈free); the sync barrier pays one coordination
     collective per chunk (the *mechanism* of Wu et al.'s 4× slowdown) —
     so sync-barrier overhead exceeds async-snapshot overhead.  The
     ``overhead_sync_vs_async`` row records the comparison and a warning
     line is printed if timer noise ever inverts it.

Output: CSV (name,us_per_call,derived) to stdout + benchmarks/out/
BENCH_overhead.json (schema in benchmarks/README.md).
"""
from __future__ import annotations

import subprocess
import sys
import textwrap
import time
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import engine, gla, randomize
from repro.core.spec import QuerySpec
from repro.data import tpch

ROWS = 8_000_000
PARTS = 8
CHUNK = 4096
SRC = Path(__file__).resolve().parents[1] / "src"

# sharded-subprocess scale (8 fake devices on one CPU).  128-row chunks
# give ~196 chunks/partition, enough per-chunk barriers for the sync
# coordination cost to rise above timer noise.
SH_ROWS, SH_PARTS, SH_CHUNK, SH_ROUNDS = 200_000, 8, 128, 4


def _shards(rows=ROWS):
    cols = tpch.generate_lineitem(rows, seed=13)
    parts = randomize.randomize_global(
        {k: jnp.asarray(v) for k, v in cols.items()}, jax.random.key(1),
        PARTS)
    # small (smoke) row counts need shorter chunks to keep >= 2 rounds
    chunk = CHUNK if rows >= PARTS * CHUNK * 2 else 256
    return randomize.pack_partitions(parts, chunk_len=chunk)


def _time(fn, repeats=7):
    fn()  # compile + warm
    ts = []
    for _ in range(repeats):
        t0 = time.perf_counter()
        fn()
        ts.append(time.perf_counter() - t0)
    return float(np.median(ts))


def run(out=sys.stdout, rows=ROWS, sh_repeats=25):
    bench_rows = []

    def report(name, us, derived):
        bench_rows.append({"name": name, "us_per_call": us,
                           "derived": derived})
        dstr = ";".join(f"{k}={v}" for k, v in derived.items())
        print(f"{name},{us:.0f},{dstr}", file=out)

    shards = _shards(rows)
    C = shards["_mask"].shape[1]
    rounds = 8
    while C % rounds:
        rounds -= 1
    variants = {
        "no_estimation": dict(estimator="none", snapshots=False),
        "single_estimator": dict(estimator="single", snapshots=True),
        "multiple_estimators": dict(estimator="multiple", snapshots=True),
    }
    times = {}
    for name, v in variants.items():
        g = gla.make_sum_gla(tpch.q6_func, tpch.q6_cond(tpch.Q6_LOW_WINDOW),
                             d_total=float(rows), estimator=v["estimator"])

        def call(g=g, v=v):
            r = engine.run_query(
                QuerySpec(g, rounds=rounds, emit="round",
                          snapshots=v["snapshots"]), shards)
            jax.block_until_ready(r.final)

        times[name] = _time(call)
    base = times["no_estimation"]
    print("name,us_per_call,derived", file=out)
    for name, t in times.items():
        report(f"overhead_{name}", t * 1e6,
               {"overhead_vs_noest": f"{t / base - 1:+.3%}"})

    # Roofline view of the overhead: estimation adds arithmetic (sumSq /
    # matched accumulators — XLA DCEs them when snapshots are off) but no
    # data movement.  On this single CPU core the scan is ALU-bound, so the
    # extra ops show up as the wall-time delta above; on the paper's
    # disk-bound system and on TPU (HBM-bound: the loop's arithmetic
    # intensity is ≪ 1 flop/byte) the memory term is the runtime and the
    # overhead is zero.  We print both terms to make that checkable.
    from repro.analysis import hlo_cost as HC

    def _terms(g, snapshots):
        spec = QuerySpec(g, rounds=rounds, emit="round", snapshots=snapshots)

        def fn(sh):
            r = engine.run_query(spec, sh)
            # keep the estimation outputs live so nothing is DCE'd away
            return r.final if r.estimates is None else (r.final, r.estimates)
        c = jax.jit(fn).lower(shards).compile()
        a = HC.analyze(c.as_text())
        return a["flops"], a["bytes"]

    g_off = gla.make_sum_gla(tpch.q6_func, tpch.q6_cond(tpch.Q6_LOW_WINDOW),
                             d_total=float(rows), estimator="none")
    g_on = gla.make_sum_gla(tpch.q6_func, tpch.q6_cond(tpch.Q6_LOW_WINDOW),
                            d_total=float(rows), estimator="single")
    f0, b0 = _terms(g_off, False)
    f1, b1 = _terms(g_on, True)
    report("overhead_roofline_flops", f1,
           {"delta_vs_noest": f"{f1 / f0 - 1:+.2%}"})
    report("overhead_roofline_bytes", b1,
           {"delta_vs_noest": f"{b1 / b0 - 1:+.2%}",
            "note": "memory-bound-platform overhead = bytes delta"})

    # Sharded path (repro/dist/shard_engine.py): snapshot-off baseline vs
    # async snapshot merge (per-round emission — the paper's zero-overhead
    # implementation under a uniform schedule) vs the synchronized per-chunk
    # barrier (which inherently needs prefix states + one coordination
    # collective per chunk).  Runs on a fake-device mesh in a subprocess
    # (XLA_FLAGS must not leak into this process).  The three variants are
    # timed interleaved round-robin and reported as min-of-repeats so
    # machine-load drift cannot masquerade as overhead.  In-process psum
    # latency is tiny compared to a network round-trip, so the measured sync
    # overhead is a *lower bound* on the real barrier cost; the per-chunk
    # collective count is the mechanism.
    code = textwrap.dedent("""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=%d"
        import sys, time; sys.path.insert(0, %r)
        import numpy as np, jax, jax.numpy as jnp
        from repro.core import engine, gla, randomize
        from repro.data import tpch
        rows, parts, chunk, rounds = %d, %d, %d, %d
        cols = tpch.generate_lineitem(rows, seed=13)
        ps = randomize.randomize_global(
            {k: jnp.asarray(v) for k, v in cols.items()}, jax.random.key(1), parts)
        shards = randomize.pack_partitions(ps, chunk_len=chunk)
        mesh = jax.make_mesh((parts,), ("data",))
        g = gla.make_sum_gla(tpch.q6_func, tpch.q6_cond(tpch.Q6_LOW_WINDOW),
                             d_total=float(rows))
        variants = {
            "noest": dict(mode="async", snapshots=False, emit="round"),
            "async": dict(mode="async", snapshots=True, emit="round"),
            "sync":  dict(mode="sync",  snapshots=True, emit="chunk"),
        }
        def call(kw):
            r = engine.run_query(g, shards, rounds=rounds, mesh=mesh, **kw)
            jax.block_until_ready(r.final if r.snapshots is None else r.snapshots)
        for kw in variants.values():
            call(kw)  # compile + warm
        ts = {k: [] for k in variants}
        for _ in range(%d):
            for k, kw in variants.items():
                t0 = time.perf_counter(); call(kw)
                ts[k].append(time.perf_counter() - t0)
        best = {k: min(v) for k, v in ts.items()}
        print(f"SHARDED {best['noest']:.6f} {best['async']:.6f} {best['sync']:.6f}")
    """ % (SH_PARTS, str(SRC), SH_ROWS, SH_PARTS, SH_CHUNK, SH_ROUNDS,
           sh_repeats))
    r = subprocess.run([sys.executable, "-c", code], capture_output=True,
                       text=True, timeout=900)
    parsed = False
    for line in r.stdout.splitlines():
        if line.startswith("SHARDED"):
            _, t0, ta, ts_ = line.split()
            t0, ta, ts_ = float(t0), float(ta), float(ts_)
            chunks = -(-(SH_ROWS // SH_PARTS) // SH_CHUNK)  # ceil = scan trip count
            async_ovh = ta / t0 - 1
            sync_ovh = ts_ / t0 - 1
            report("overhead_sharded_noest_baseline", t0 * 1e6,
                   {"devices": SH_PARTS})
            report("overhead_async_snapshots_sharded", ta * 1e6,
                   {"overhead_vs_noest": f"{async_ovh:+.3%}",
                    "coordination_collectives_per_partition": 0})
            report("overhead_synchronized_sharded", ts_ * 1e6,
                   {"overhead_vs_noest": f"{sync_ovh:+.3%}",
                    "coordination_collectives_per_partition": chunks,
                    "note": "in-process psum is latency-free; on a network "
                            "each is a blocking round-trip"})
            report("overhead_sync_vs_async",
                   (ts_ - ta) * 1e6,
                   {"sync_barrier_gt_async_snapshot": sync_ovh > async_ovh,
                    "sync_over_async_wall": f"{ts_ / ta:.2f}x"})
            if sync_ovh <= async_ovh:
                print("# WARNING: sync-barrier overhead did not exceed "
                      "async-snapshot overhead on this run (timer noise?); "
                      "the per-chunk collective count above is the "
                      "load-independent mechanism", file=out)
            parsed = True
    if not parsed:
        print(f"# sharded section failed: {r.stderr[-500:]}", file=out)

    try:
        from benchmarks import bench_io
    except ImportError:  # direct script invocation: benchmarks/ is sys.path[0]
        import bench_io
    path = bench_io.emit("overhead", bench_rows)
    print(f"# wrote {path}", file=out)


if __name__ == "__main__":
    run()
