"""Paper Table 2: execution time with estimation off / single / multiple /
synchronized — the zero-overhead claim.

Two measurements:
  1. wall time of the jitted engine on this CPU (vmapped partitions),
     median of repeats, for: no-estimation, single, multiple — the paper's
     Table 2 columns.  The claim reproduced: interactive == non-interactive.
  2. the synchronized estimator's cost, measured in a subprocess on an
     8-fake-device mesh where its per-chunk barrier is a real collective —
     plus the HLO collective count blowup (the *mechanism* of Wu et al.'s
     4× slowdown).

Output CSV: name,us_per_call,derived
"""
from __future__ import annotations

import subprocess
import sys
import textwrap
import time
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import engine, gla, randomize
from repro.data import tpch

ROWS = 8_000_000
PARTS = 8
CHUNK = 4096
SRC = Path(__file__).resolve().parents[1] / "src"


def _shards():
    cols = tpch.generate_lineitem(ROWS, seed=13)
    parts = randomize.randomize_global(
        {k: jnp.asarray(v) for k, v in cols.items()}, jax.random.key(1),
        PARTS)
    return randomize.pack_partitions(parts, chunk_len=CHUNK)


def _time(fn, repeats=7):
    fn()  # compile + warm
    ts = []
    for _ in range(repeats):
        t0 = time.perf_counter()
        fn()
        ts.append(time.perf_counter() - t0)
    return float(np.median(ts))


def run(out=sys.stdout):
    shards = _shards()
    C = shards["_mask"].shape[1]
    rounds = 8
    while C % rounds:
        rounds -= 1
    variants = {
        "no_estimation": dict(estimator="none", snapshots=False),
        "single_estimator": dict(estimator="single", snapshots=True),
        "multiple_estimators": dict(estimator="multiple", snapshots=True),
    }
    times = {}
    for name, v in variants.items():
        g = gla.make_sum_gla(tpch.q6_func, tpch.q6_cond(tpch.Q6_LOW_WINDOW),
                             d_total=float(ROWS), estimator=v["estimator"])

        def call(g=g, v=v):
            r = engine.run_query(g, shards, rounds=rounds, emit="round",
                                 snapshots=v["snapshots"])
            jax.block_until_ready(r.final)

        times[name] = _time(call)
    base = times["no_estimation"]
    print("name,us_per_call,derived", file=out)
    for name, t in times.items():
        print(f"overhead_{name},{t * 1e6:.0f},"
              f"overhead_vs_noest={t / base - 1:+.3%}", file=out)

    # Roofline view of the overhead: estimation adds arithmetic (sumSq /
    # matched accumulators — XLA DCEs them when snapshots are off) but no
    # data movement.  On this single CPU core the scan is ALU-bound, so the
    # extra ops show up as the wall-time delta above; on the paper's
    # disk-bound system and on TPU (HBM-bound: the loop's arithmetic
    # intensity is ≪ 1 flop/byte) the memory term is the runtime and the
    # overhead is zero.  We print both terms to make that checkable.
    from repro.analysis import hlo_cost as HC

    def _terms(g, snapshots):
        def fn(sh):
            r = engine.run_query(g, sh, rounds=rounds, emit="round",
                                 snapshots=snapshots)
            # keep the estimation outputs live so nothing is DCE'd away
            return r.final if r.estimates is None else (r.final, r.estimates)
        c = jax.jit(fn).lower(shards).compile()
        a = HC.analyze(c.as_text())
        return a["flops"], a["bytes"]

    g_off = gla.make_sum_gla(tpch.q6_func, tpch.q6_cond(tpch.Q6_LOW_WINDOW),
                             d_total=float(ROWS), estimator="none")
    g_on = gla.make_sum_gla(tpch.q6_func, tpch.q6_cond(tpch.Q6_LOW_WINDOW),
                            d_total=float(ROWS), estimator="single")
    f0, b0 = _terms(g_off, False)
    f1, b1 = _terms(g_on, True)
    print(f"overhead_roofline_flops,{f1:.3e},delta_vs_noest={f1 / f0 - 1:+.2%}",
          file=out)
    print(f"overhead_roofline_bytes,{b1:.3e},delta_vs_noest={b1 / b0 - 1:+.2%}"
          f";memory-bound-platform overhead = bytes delta", file=out)

    # synchronized estimator: per-chunk barrier on a (fake-device) mesh.
    # In-process psum has near-zero latency, so wall time cannot show the
    # barrier cost; the *mechanism* of Wu et al.'s slowdown is the per-chunk
    # collective, which we count in the compiled HLO (one coordination
    # collective per chunk vs per round).
    code = textwrap.dedent("""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
        import sys, time, re; sys.path.insert(0, %r)
        import numpy as np, jax, jax.numpy as jnp
        from repro.core import engine, gla, randomize
        from repro.data import tpch
        rows, parts, chunk = 500_000, 8, 1024
        cols = tpch.generate_lineitem(rows, seed=13)
        ps = randomize.randomize_global(
            {k: jnp.asarray(v) for k, v in cols.items()}, jax.random.key(1), parts)
        shards = randomize.pack_partitions(ps, chunk_len=chunk)
        mesh = jax.make_mesh((8,), ("data",))
        g = gla.make_sum_gla(tpch.q6_func, tpch.q6_cond(tpch.Q6_LOW_WINDOW),
                             d_total=float(rows))
        from repro.analysis import hlo_cost as HC
        def run_mode(mode):
            def call():
                r = engine.run_query(g, shards, rounds=4, mode=mode, mesh=mesh)
                jax.block_until_ready(r.snapshots)
            call()
            ts = []
            for _ in range(3):
                t0 = time.perf_counter(); call(); ts.append(time.perf_counter()-t0)
            return float(np.median(ts))
        ta, ts_ = run_mode("async"), run_mode("sync")
        print(f"SYNC {ta:.6f} {ts_:.6f}")
    """ % str(SRC))
    r = subprocess.run([sys.executable, "-c", code], capture_output=True,
                       text=True, timeout=900)
    for line in r.stdout.splitlines():
        if line.startswith("SYNC"):
            _, ta, ts_ = line.split()
            ta, ts_ = float(ta), float(ts_)
            chunks = ROWS and 500_000 // 8 // 1024 + 1
            print(f"overhead_async_sharded,{ta * 1e6:.0f},"
                  f"coordination_collectives_per_partition=0", file=out)
            print(f"overhead_synchronized_sharded,{ts_ * 1e6:.0f},"
                  f"coordination_collectives_per_partition={chunks}"
                  f";wall_ratio={ts_ / ta:.2f}x(in-process psum is latency-free;"
                  f" on a network each is a blocking round-trip)", file=out)


if __name__ == "__main__":
    run()
