"""Benchmark driver — one section per paper table/figure.

    python -m benchmarks.run              # everything (CSV to stdout)
    python -m benchmarks.run quick        # skip the heavier sweeps
    python -m benchmarks.run --smoke      # CI-sized: small rows, few repeats

Sections:
  * kernels      — jitted hot-loop throughput (chunk/group aggregation)
  * overhead     — paper Table 2 (estimation overhead incl. synchronized)
  * groupby      — paper §5.3 large-domain Q1: segment_sum scan vs the
                   per-round-slice Pallas group_agg dispatch
  * multiquery   — shared scan: N concurrent queries over ONE pass vs N
                   solo passes (DESIGN.md §6)
  * early_stop   — time-to-ε and fraction of the scan saved by the
                   incremental session driver (DESIGN.md §7)
  * fault        — mid-scan shard loss: bound-width inflation vs shards
                   lost + the cost of the failure-absorbing round
                   (DESIGN.md §9)
  * streaming    — out-of-core chunk sources vs in-memory: steady-state
                   throughput + the O(slice) transfer certificate
                   (DESIGN.md §8)
  * deepola      — fused two-table joins (probe tables in-kernel) vs the
                   legacy kernel batcher vs the scan path, plus nested
                   GROUP BY + HAVING time-to-ε (DESIGN.md §13)
  * convergence  — paper Figs. 1–3 (relative CI width curves)
  * roofline     — §Roofline table from the dry-run artifacts (if present)

Every section prints CSV to stdout and writes a machine-readable
``benchmarks/out/BENCH_<name>.json`` (``benchmarks/check_schema.py``
validates them; CI runs ``--smoke`` + the validator on every push).
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np


def _bench(fn, repeats=5):
    fn()
    ts = []
    for _ in range(repeats):
        t0 = time.perf_counter()
        fn()
        ts.append(time.perf_counter() - t0)
    return float(np.median(ts)) * 1e6


def kernels_section(n=1 << 20):
    """Throughput of the aggregation hot loops (pure-jnp reference path on
    CPU; the Pallas kernels target TPU and are validated in tests)."""
    from repro.kernels import ref
    print("name,us_per_call,derived")
    rng = np.random.default_rng(0)
    vals = jnp.asarray(rng.normal(size=n), jnp.float32)
    w = jnp.asarray(rng.integers(0, 2, n), jnp.float32)
    m = jnp.ones(n, jnp.float32)
    f = jax.jit(ref.chunk_agg_ref)
    us = _bench(lambda: jax.block_until_ready(f(vals, w, m)))
    print(f"kernel_chunk_agg_1M,{us:.0f},GBps={n * 12 / us / 1e3:.2f}")
    gids = jnp.asarray(rng.integers(0, 1000, n), jnp.int32)
    va = jnp.asarray(rng.normal(size=(n, 4)), jnp.float32)
    g = jax.jit(lambda v, w_, i: ref.group_agg_ref(v, w_, i, 1000))
    us = _bench(lambda: jax.block_until_ready(g(va, w, gids)))
    print(f"kernel_group_agg_1Mx4_1000g,{us:.0f},GBps={n * 20 / us / 1e3:.2f}")


def main(argv=None):
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("mode", nargs="?", choices=["quick"],
                    help="legacy positional: skip the heavier sweeps")
    ap.add_argument("--smoke", action="store_true",
                    help="CI-sized run: small row counts, few repeats — "
                         "exercises every section and emits every "
                         "BENCH_*.json in minutes")
    args = ap.parse_args(argv)
    smoke = args.smoke
    quick = smoke or args.mode == "quick"

    print("# === kernels ===")
    kernels_section(n=1 << 16 if smoke else 1 << 20)

    print("# === overhead (paper Table 2) ===")
    from benchmarks import overhead
    if smoke:
        overhead.run(rows=200_000, sh_repeats=5)
    else:
        overhead.run()

    print("# === groupby (paper §5.3 large-domain Q1) ===")
    from benchmarks import groupby
    groupby.run(rows=50_000 if quick else groupby.ROWS)

    print("# === multiquery (shared scan, DESIGN.md §6) ===")
    from benchmarks import multiquery
    if smoke:
        multiquery.run(rows=multiquery.SMOKE_ROWS, repeats=2)
    else:
        multiquery.run()

    print("# === early_stop (time-to-eps, DESIGN.md §7) ===")
    from benchmarks import early_stop
    if smoke:
        early_stop.run(rows=100_000, repeats=2)
    else:
        early_stop.run()

    print("# === fault (mid-scan shard loss, DESIGN.md §9) ===")
    from benchmarks import fault
    if smoke:
        fault.run(rows=fault.SMOKE_ROWS, repeats=2)
    else:
        fault.run()

    print("# === streaming (out-of-core chunk sources, DESIGN.md §8) ===")
    from benchmarks import streaming
    if smoke:
        streaming.run(rows=streaming.SMOKE_ROWS, repeats=2)
    else:
        streaming.run()

    print("# === fused (fused kernel + encoded sources, DESIGN.md §12) ===")
    from benchmarks import fused
    if smoke:
        fused.run(rows=fused.SMOKE_ROWS, repeats=2)
    else:
        fused.run()

    print("# === deepola (fused joins + nested aggregates, DESIGN.md §13) ===")
    from benchmarks import deepola
    if smoke:
        deepola.run(rows=deepola.SMOKE_ROWS, repeats=2)
    else:
        deepola.run()

    print("# === serve (shared-scan OLA service, DESIGN.md §11) ===")
    from benchmarks import serve
    serve.run(rows=serve.SMOKE_ROWS if smoke else serve.ROWS)

    print("# === convergence (paper Figs 1-3) ===")
    from benchmarks import convergence
    tasks = ["agg_low", "agg_high"] if quick else None
    convergence.run(tasks=tasks, rows=100_000 if smoke else convergence.ROWS)

    print("# === roofline (dry-run artifacts) ===")
    try:
        from benchmarks import roofline
        rows = roofline.analyze("single")
        if not rows:
            print("no dry-run artifacts; run: python -m repro.launch.dryrun --all")
        print("name,us_per_call,derived")
        for r in rows:
            if r["status"] != "OK":
                continue
            dom_s = max(r["compute_s"], r["memory_s"], r["collective_s"])
            print(f"roofline_{r['cell']},{dom_s * 1e6:.0f},"
                  f"bottleneck={r['bottleneck']};"
                  f"fraction={r['roofline_fraction']:.3f}")
    except Exception as e:  # artifacts absent in fresh checkouts
        print(f"roofline skipped: {e}")


if __name__ == "__main__":
    main()
