"""Serving benchmark: shared scan vs one-scan-per-query (DESIGN.md §11).

A seeded Poisson stream of slot queries hits the OLA service; every
query rides ONE shared cyclic scan and detaches when its rel-width stop
rule fires (or after a full pass).  The contender gives each query its
own fresh Session over the same data with the same stop rule, served
sequentially from the same arrival times — the one-scan-per-query
pricing the service exists to beat.

Reported per workload size N:

  * sustained queries/sec (N / makespan) for both disciplines;
  * p50/p99 time-to-ε (arrival -> converged/full-pass) for both;
  * the recompile-discipline numbers from the audit catalog
    (``bounded_compiles_under_churn``): jit cache misses under the
    arrival/departure churn vs the capacity-doubling budget.

    PYTHONPATH=src python -m benchmarks.serve [rows]
"""
from __future__ import annotations

import asyncio
import sys
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.analysis import audit
from repro.core import randomize
from repro.core import session as S
from repro.core.gla import SlotFamily, SlotQuery
from repro.core.spec import QuerySpec
from repro.data import tpch
from repro.serving import service as SV

ROWS = 400_000
SMOKE_ROWS = 60_000
PARTS = 8
CHUNK = 512
ROUNDS = 8
EPS = 0.05
QPS = 25.0
NS = (4, 8)
SEED = 0


def _shards(rows):
    cols = tpch.generate_lineitem(rows, seed=SEED)
    parts = randomize.randomize_global(
        {k: jnp.asarray(v) for k, v in cols.items()}, jax.random.key(SEED),
        PARTS)
    return randomize.pack_partitions(parts, chunk_len=CHUNK)


def _family():
    return SlotFamily(
        exprs={"q6": tpch.q6_func, "qty": lambda c: c["quantity"]},
        pred_cols=("shipdate", "discount"),
        groups={"rfls": (tpch.q1_group_small, 4)})


def _workload(n, rng):
    """Seeded Poisson arrivals + query mix (scalar ranges and one group
    member in four, mirroring an interactive dashboard's spread)."""
    arrivals = np.cumsum(rng.exponential(1.0 / QPS, size=n))
    queries = []
    for i in range(n):
        year = float(int(rng.integers(0, 6)) * 365)
        queries.append(SlotQuery(
            expr="qty" if i % 3 == 2 else "q6",
            ranges={"shipdate": (year, year + 730.0),
                    "discount": (0.0, 1.0)},
            group="rfls" if i % 4 == 3 else None))
    return arrivals, queries


async def _drive_shared(family, shards, arrivals, queries):
    """Submit the stream to one OLAService; per-query time-to-ε."""
    t_eps = [0.0] * len(queries)

    async def one(i, svc):
        await asyncio.sleep(float(arrivals[i]))
        t_sub = time.perf_counter()
        h = await svc.submit(
            QuerySpec(queries[i], stop=S.rel_width(EPS)), shards)
        await h.result()
        t_eps[i] = time.perf_counter() - t_sub

    async with SV.OLAService(family, rounds=ROUNDS, grace_s=0.05) as svc:
        t0 = time.perf_counter()
        await asyncio.gather(*(one(i, svc) for i in range(len(queries))))
        # makespan from the FIRST arrival, matching the solo contender
        makespan = time.perf_counter() - t0 - float(arrivals[0])
        scan = svc.scan_for(shards)
        steps = scan.steps_done if scan else 0
    return t_eps, makespan, steps


def _drive_solo(family, shards, arrivals, queries, d_total):
    """One fresh scan per query, served sequentially from the same
    arrival times (a single-executor queue, like re-running the batch
    engine per request)."""
    t_eps = []
    clock = 0.0
    for i, q in enumerate(queries):
        sess = S.Session(
            QuerySpec(family.solo_gla(q, d_total=d_total), rounds=ROUNDS,
                      emit="chunk", stop=S.rel_width(EPS)),
            shards)
        t0 = time.perf_counter()
        res = sess.run()
        jax.block_until_ready(res.final)
        dur = time.perf_counter() - t0
        start = max(float(arrivals[i]), clock)
        clock = start + dur
        t_eps.append(clock - float(arrivals[i]))
    makespan = clock - float(arrivals[0])
    return t_eps, makespan


def run(rows=ROWS, ns=NS, out=sys.stdout):
    shards = _shards(rows)
    family = _family()
    d_total = float(np.asarray(shards["_mask"].sum()))
    rng = np.random.default_rng(SEED)

    # recompile discipline under churn, certified from the audit catalog
    churn = audit.audit_service(family, shards, rounds=4).result(
        "bounded_compiles_under_churn")
    assert not churn.failed, str(churn)
    cache_delta = churn.data.get("cache_miss_delta")
    budget = churn.data.get("budget")

    # warm both disciplines so the timed runs compare steady-state serving
    warm_arr, warm_q = _workload(2, rng)
    asyncio.run(_drive_shared(family, shards, warm_arr * 0.0, warm_q))
    _drive_solo(family, shards, warm_arr * 0.0, warm_q, d_total)

    bench_rows = []
    print("name,us_per_call,derived", file=out)
    for n in ns:
        arrivals, queries = _workload(n, rng)
        shared_eps, shared_mk, steps = asyncio.run(
            _drive_shared(family, shards, arrivals, queries))
        solo_eps, solo_mk = _drive_solo(family, shards, arrivals, queries,
                                        d_total)
        p50s, p99s = np.percentile(shared_eps, [50, 99])
        p50o, p99o = np.percentile(solo_eps, [50, 99])
        derived = {
            "queries": n, "qps_offered": QPS, "eps": EPS,
            "qps_shared": n / shared_mk, "qps_one_scan_per_query": n / solo_mk,
            "p50_time_to_eps_shared_us": p50s * 1e6,
            "p99_time_to_eps_shared_us": p99s * 1e6,
            "p50_time_to_eps_one_scan_us": p50o * 1e6,
            "p99_time_to_eps_one_scan_us": p99o * 1e6,
            "shared_scan_steps": steps,
            "makespan_speedup_vs_one_scan": solo_mk / shared_mk,
            "audit_cache_miss_delta": cache_delta,
            "audit_compile_budget": budget,
        }
        print(f"serve_poisson_N{n},{shared_mk * 1e6:.0f},"
              f"qps={n / shared_mk:.1f};speedup={solo_mk / shared_mk:.2f};"
              f"p99_shared={p99s * 1e3:.0f}ms;p99_solo={p99o * 1e3:.0f}ms",
              file=out)
        bench_rows.append({"name": f"serve_poisson_N{n}",
                           "us_per_call": shared_mk * 1e6,
                           "derived": derived})
        if n >= 4:
            # the acceptance gate: shared scan sustains the stream at
            # least as well as one-scan-per-query for N >= 4
            assert solo_mk / shared_mk > 1.0, (
                f"shared scan lost to one-scan-per-query at N={n}: "
                f"{shared_mk:.3f}s vs {solo_mk:.3f}s")

    try:
        from benchmarks import bench_io
    except ImportError:  # direct script invocation
        import bench_io
    path = bench_io.emit("serve", bench_rows)
    print(f"# wrote {path}", file=out)


if __name__ == "__main__":
    run(rows=int(sys.argv[1]) if len(sys.argv) > 1 else ROWS)
