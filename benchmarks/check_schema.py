"""Validate every benchmarks/out/BENCH_*.json against the schema in
benchmarks/README.md.

    python benchmarks/check_schema.py [out_dir]

Exit status 0 when every file conforms, 1 otherwise (CI gates on it after
``python -m benchmarks.run --smoke``).  The schema is deliberately small:

    { "bench": "<name>",            # matches the BENCH_<name>.json filename
      "rows": [ {"name": ...,       # stable row id, non-empty str, unique
                 "us_per_call": ...,  # optional: finite number (timing rows)
                 "derived": {...}},   # optional: dict of derived quantities
                ... ] }

Row keys beyond those are benchmark-specific and pass through unchecked.

Beyond per-file conformance, the validator fails when any benchmark in
:data:`EXPECTED_BENCHES` is missing its ``BENCH_<name>.json`` — a section
that silently emits nothing (crashed mid-run, or its ``bench_io.emit``
call was dropped) must not pass CI.
"""
from __future__ import annotations

import json
import math
import sys
from pathlib import Path

# every section of ``python -m benchmarks.run --smoke`` that emits a
# BENCH_*.json; grow this set when a new section lands (kernels prints
# CSV only; roofline depends on optional dry-run artifacts)
EXPECTED_BENCHES = frozenset({
    "overhead", "groupby", "multiquery", "early_stop", "fault",
    "streaming", "fused", "deepola", "convergence", "serve",
})


def check_payload(payload, expected_bench: str) -> list:
    """Return a list of violation strings (empty == conforming)."""
    errs = []
    if not isinstance(payload, dict):
        return [f"top level must be an object, got {type(payload).__name__}"]
    bench = payload.get("bench")
    if not isinstance(bench, str) or not bench:
        errs.append("'bench' must be a non-empty string")
    elif bench != expected_bench:
        errs.append(f"'bench' is {bench!r} but the filename says "
                    f"{expected_bench!r}")
    rows = payload.get("rows")
    if not isinstance(rows, list) or not rows:
        errs.append("'rows' must be a non-empty list")
        return errs
    seen = set()
    for i, row in enumerate(rows):
        where = f"rows[{i}]"
        if not isinstance(row, dict):
            errs.append(f"{where}: must be an object")
            continue
        name = row.get("name")
        if not isinstance(name, str) or not name:
            errs.append(f"{where}: 'name' must be a non-empty string")
        elif name in seen:
            errs.append(f"{where}: duplicate row name {name!r}")
        else:
            seen.add(name)
        if "us_per_call" in row:
            us = row["us_per_call"]
            if (not isinstance(us, (int, float)) or isinstance(us, bool)
                    or not math.isfinite(us)):
                errs.append(f"{where}: 'us_per_call' must be a finite "
                            f"number, got {us!r}")
        if "derived" in row and not isinstance(row["derived"], dict):
            errs.append(f"{where}: 'derived' must be an object")
    return errs


def main(argv=None) -> int:
    argv = sys.argv[1:] if argv is None else argv
    out_dir = Path(argv[0]) if argv else Path(__file__).parent / "out"
    files = sorted(out_dir.glob("BENCH_*.json"))
    if not files:
        print(f"FAIL: no BENCH_*.json found under {out_dir}")
        return 1
    failed = False
    present = {p.stem[len("BENCH_"):] for p in files}
    missing = sorted(EXPECTED_BENCHES - present)
    if missing:
        failed = True
        for name in missing:
            print(f"FAIL BENCH_{name}.json: expected after --smoke but "
                  f"missing from {out_dir} — the section emitted nothing")
    for path in files:
        expected = path.stem[len("BENCH_"):]
        try:
            payload = json.loads(path.read_text())
        except (OSError, json.JSONDecodeError) as e:
            print(f"FAIL {path.name}: unreadable JSON ({e})")
            failed = True
            continue
        errs = check_payload(payload, expected)
        if errs:
            failed = True
            print(f"FAIL {path.name}:")
            for e in errs:
                print(f"  - {e}")
        else:
            print(f"OK   {path.name}: {len(payload['rows'])} rows")
    return 1 if failed else 0


if __name__ == "__main__":
    sys.exit(main())
