"""Out-of-core streaming scan vs the in-memory path (DESIGN.md §8).

The paper's headline scale is an 8 TB TPC-H instance — far beyond any
node's memory.  The `repro.data.source` layer decouples the scan from
data residency: this benchmark measures what that costs and certifies
what it buys, at a ``rows`` setting whose full materialization exceeds
the per-round slice budget by >= 8x (``rounds`` slices per scan, one on
device at a time).  Two query families, against two comparators each:

    q1_groupby  — 4-aggregate group-by (compute-dense).  The headline
                  row: per-round compute dominates, the double-buffered
                  prefetch hides the host read, and steady-state
                  streaming throughput must be >= 0.8x the in-memory
                  *incremental* session (same execution discipline,
                  residency the only difference).
    q6_sum      — trivial selective SUM (bandwidth-bound worst case).
                  Compute per byte is too small to hide a memcpy behind
                  on small hosts; the row documents the fall-through,
                  exactly like q6_low_sel in benchmarks/early_stop.py.

The fused whole-scan ratio is reported alongside as context — a fused
program amortizes per-round dispatch that any incremental session pays,
resident or not.  Every streamed run is asserted bitwise-equal to the
resident run, and the O(slice) transfer certificate is *asserted*: the
incremental step program's ENTRY parameter bytes
(``repro.analysis.hlo_cost.entry_param_bytes``) are one round-slice plus
the small carry/weights — never the dataset.  Timing is interleaved
min-of-repeats (same idiom as benchmarks/overhead.py).

Output: CSV to stdout + benchmarks/out/BENCH_streaming.json.  The
parquet rows appear only when the optional ``pyarrow`` is installed and
are not part of the committed baseline.
"""
from __future__ import annotations

import sys
import tempfile

import jax
import jax.numpy as jnp
import numpy as np

try:
    from benchmarks import bench_io
except ImportError:  # direct script invocation: benchmarks/ is sys.path[0]
    import bench_io

from repro.core import engine, gla, randomize
from repro.core import session as S
from repro.core.spec import QuerySpec
from repro.data import source as DS
from repro.data import tpch

ROWS = 2_000_000
SMOKE_ROWS = 400_000
PARTS = 4
CHUNK = 1024
ROUNDS = 16  # dataset = 16x the on-device slice budget


def _shards(rows):
    cols = tpch.generate_lineitem(rows, seed=13)
    parts = randomize.randomize_global(
        {k: jnp.asarray(v) for k, v in cols.items()}, jax.random.key(13),
        PARTS)
    n_chunks = -(-rows // PARTS // CHUNK)
    return randomize.pack_partitions(
        parts, chunk_len=CHUNK,
        min_chunks=-(-n_chunks // ROUNDS) * ROUNDS), parts


def _wide_q6(d_total):
    def func(c):
        return c["quantity"]

    def cond(c):
        sd = c["shipdate"]
        return ((sd >= 0) & (sd < 1460)).astype(jnp.float32)

    return gla.make_sum_gla(func, cond, d_total=d_total)


def _families(rows):
    d = float(rows)
    return {
        "q1_groupby": (gla.make_groupby_gla(
            tpch.q1_func, tpch.q1_cond, tpch.q1_group_small, num_groups=4,
            d_total=d, num_aggs=4), "round"),
        "q6_sum": (_wide_q6(d), "chunk"),
    }


def _bytes_of(spec, width):
    return sum(spec.P * width * spec.L * np.dtype(c.dtype).itemsize
               for c in spec.columns)


def run(rows=ROWS, repeats=3, out=sys.stdout):
    shards, parts = _shards(rows)
    spec = DS.InMemorySource(shards).spec
    per = spec.C // ROUNDS
    slice_bytes = _bytes_of(spec, per)
    dataset_bytes = _bytes_of(spec, spec.C)
    assert dataset_bytes >= 8 * slice_bytes, (
        f"streaming benchmark must run out-of-core by >= 8x: dataset "
        f"{dataset_bytes}B vs slice budget {slice_bytes}B")

    bench_rows = []
    print("name,us_per_call,derived", file=out)

    with tempfile.TemporaryDirectory(prefix="bench_streaming_") as td:
        # long-lived source objects, like a real deployment: chunk-spec,
        # mask sums, mmap handles and read-ahead blocks are set up once
        # and reused every scan
        sources = [("npy", DS.NpyMmapSource(
            DS.NpyMmapSource.save(shards, td + "/npy")))]
        try:
            import pyarrow  # noqa: F401

            pq_dir = DS.ParquetSource.save(parts, td + "/pq",
                                           row_group_len=per * CHUNK)
            sources.append(("parquet", DS.ParquetSource(
                pq_dir, chunk_len=CHUNK, min_chunks=spec.C)))
        except ImportError:
            print("# pyarrow absent: parquet rows skipped", file=out)

        for fam, (q, emit) in _families(rows).items():
            def run_fused(data, q=q, emit=emit):
                res = engine.run_query(
                    QuerySpec(q, rounds=ROUNDS, emit=emit), data)
                jax.block_until_ready(res.final)
                return res

            def run_inc(data, q=q, emit=emit):
                # streaming sources take this path inside run_query too;
                # spelled out here so the resident comparator runs the
                # SAME incremental discipline
                sess = S.Session(QuerySpec(q, rounds=ROUNDS, emit=emit),
                                 data)
                while not sess.done:
                    sess.step()
                jax.block_until_ready(sess.result().final)

            # the O(slice) certificate (catalog check o_slice_footprint):
            # step operands are one round-slice (+ small carry/weights),
            # never the resident dataset — floor/ceiling/out-of-core
            # bounds live in repro/analysis/audit.py
            report = engine.audit_plan(
                q, sources[0][1], rounds=ROUNDS, emit=emit,
                checks=("o_slice_footprint",), raise_on_failure=True)
            step_param_bytes = (
                report.result("o_slice_footprint").data["entry_param_bytes"])

            timings = bench_io.time_interleaved(
                [lambda: run_fused(shards), lambda: run_inc(shards),
                 *(lambda s=s: run_fused(s) for _, s in sources)], repeats)
            fused_us, inc_us, stream_us_list = (timings[0], timings[1],
                                                timings[2:])

            bench_rows.append({
                "name": f"inmem_incremental_{fam}", "us_per_call": inc_us,
                "derived": {"rows": rows, "rounds": ROUNDS,
                            "inmem_fused_us": fused_us,
                            "dataset_bytes": dataset_bytes},
            })
            print(f"inmem_incremental_{fam},{inc_us:.0f},"
                  f"fused_us={fused_us:.0f}", file=out)

            ref = run_fused(shards)
            for (name, src), stream_us in zip(sources, stream_us_list):
                res = run_fused(src)
                for a, b in zip(jax.tree.leaves(res.final),
                                jax.tree.leaves(ref.final)):
                    assert (np.asarray(a).tobytes()
                            == np.asarray(b).tobytes()), (
                        f"{name} streamed {fam} final differs from "
                        "in-memory")
                ratio = inc_us / stream_us if stream_us else float("inf")
                derived = {
                    "rows": rows, "rounds": ROUNDS,
                    "inmem_incremental_us": inc_us,
                    "inmem_fused_us": fused_us,
                    "throughput_vs_inmem": ratio,
                    "throughput_vs_fused": (fused_us / stream_us
                                            if stream_us else float("inf")),
                    "meets_0p8x": bool(ratio >= 0.8),
                    "rows_per_s": rows / (stream_us / 1e6),
                    "slice_bytes": slice_bytes,
                    "dataset_bytes": dataset_bytes,
                    "dataset_over_slice": dataset_bytes / slice_bytes,
                    "step_param_bytes": step_param_bytes,
                    "bitwise_vs_inmem": True,
                }
                print(f"streaming_{name}_{fam},{stream_us:.0f},"
                      f"x_inmem={ratio:.2f};"
                      f"slice_x={dataset_bytes / slice_bytes:.0f};"
                      f"step_B={step_param_bytes:.0f}", file=out)
                bench_rows.append({"name": f"streaming_{name}_{fam}",
                                   "us_per_call": stream_us,
                                   "derived": derived})

    path = bench_io.emit("streaming", bench_rows)
    print(f"# wrote {path}", file=out)


if __name__ == "__main__":
    run(rows=int(sys.argv[1]) if len(sys.argv) > 1 else ROWS)
