"""Fused selection→bucket→aggregate kernel throughput (DESIGN.md §12).

The raw-speed certificate for the fused Pallas path: q6-class selective
scans and the q1 group-by, fused-kernel dispatch vs the segment-sum scan
path, on plain and encoded sources.  Wins are reported as
**fraction-of-roofline**, not just speedups: each row derives

    achieved_gbps     = bytes the scan must move / wall time
    roofline_fraction = achieved_gbps / HBM_BW   (repro.launch.mesh, 819
                        GB/s — the TPU HBM figure the roofline benchmark
                        uses; on a CPU host the fraction is honest about
                        how far interpret mode sits from the roof)

and encoded sources score their *physical* bytes — the stream the
dictionary / bit-packed columns actually move — so the decode-in-kernel
bandwidth win shows up as the SAME aggregate answer from fewer bytes.
That byte shrinkage is not read off trustingly: the audit catalog's
``bytes_moved`` check is run with ``raise_on_failure=True`` before
timing, and every fused/encoded result is asserted bitwise-identical to
the plain scan-path result.

Output: CSV to stdout + benchmarks/out/BENCH_fused.json (schema rows in
benchmarks/README.md; seeded baseline in benchmarks/baselines/).
"""
from __future__ import annotations

import sys

import jax
import jax.numpy as jnp
import numpy as np

try:
    from benchmarks import bench_io
except ImportError:  # direct script invocation: benchmarks/ is sys.path[0]
    import bench_io

from repro.analysis import audit as AU
from repro.core import engine, gla, randomize
from repro.core import session as S
from repro.core.spec import QuerySpec
from repro.data import encodings as ENC
from repro.data import source as DS
from repro.data import tpch
from repro.launch.mesh import HBM_BW

ROWS = 2_000_000
SMOKE_ROWS = 400_000
PARTS = 4
CHUNK = 1024
ROUNDS = 16


def _shards(rows):
    cols = tpch.generate_lineitem(rows, seed=29)
    parts = randomize.randomize_global(
        {k: jnp.asarray(v) for k, v in cols.items()}, jax.random.key(29),
        PARTS)
    n_chunks = -(-rows // PARTS // CHUNK)
    return randomize.pack_partitions(
        parts, chunk_len=CHUNK,
        min_chunks=-(-n_chunks // ROUNDS) * ROUNDS)


def _wide_q6(d_total):
    """q6-class selective SUM over a dense (~80%) shipdate window."""
    def func(c):
        return c["quantity"]

    def cond(c):
        sd = c["shipdate"]
        return ((sd >= 0) & (sd < 1460)).astype(jnp.float32)

    return gla.make_sum_gla(func, cond, d_total=d_total)


def _families(rows):
    d = float(rows)
    return {
        "q6_sum": _wide_q6(d),
        "q1_groupby": gla.make_groupby_gla(
            tpch.q1_func, tpch.q1_cond, tpch.q1_group_small, num_groups=4,
            d_total=d, num_aggs=4),
    }


def _tree_bytes(tree) -> int:
    return sum(int(np.prod(v.shape)) * np.dtype(v.dtype).itemsize
               for v in jax.tree.leaves(tree))


def _roofline(bytes_moved: int, us: float) -> dict:
    gbps = bytes_moved / (us / 1e6) / 1e9
    return {"bytes_moved": bytes_moved,
            "achieved_gbps": gbps,
            "roofline_fraction": gbps / (HBM_BW / 1e9)}


def run(rows=ROWS, repeats=3, out=sys.stdout):
    shards = _shards(rows)
    np_shards = {k: np.asarray(v) for k, v in shards.items()}
    spec = DS.InMemorySource(shards).spec
    logical_bytes = _tree_bytes(spec.slice_like(spec.C))

    esrc = DS.EncodedSource.from_shards(np_shards, {
        "discount": ENC.dict_encoding_for(np_shards["discount"]),
        "shipdate": ENC.BitPackedEncoding(bits=16),
        "rfls": ENC.BitPackedEncoding(bits=2)})
    physical_bytes = _tree_bytes(
        {k: v for k, v in zip(sorted(np_shards),
                              jax.tree.leaves(esrc.step_slice_like(spec.C)))})

    bench_rows = []
    print("name,us_per_call,derived", file=out)

    for fam, q in _families(rows).items():
        # pre-timing certificates: the fused plan really is one dispatch,
        # and the encoded stream really is smaller (audit catalog)
        AU.audit_plan(q, shards, rounds=ROUNDS, emit="kernel",
                      checks=("fused_single_dispatch",),
                      raise_on_failure=True)
        enc_report = AU.audit_plan(
            q, esrc, rounds=ROUNDS, emit="kernel",
            checks=("fused_single_dispatch", "bytes_moved"),
            raise_on_failure=True)
        byte_ratio = enc_report.result("bytes_moved").data["ratio"]

        def run_scan(q=q):
            res = engine.run_query(QuerySpec(q, rounds=ROUNDS, emit="chunk"),
                                   shards)
            jax.block_until_ready(res.final)
            return res

        def run_fused(q=q):
            res = engine.run_query(QuerySpec(q, rounds=ROUNDS,
                                             emit="kernel"), shards)
            jax.block_until_ready(res.final)
            return res

        def run_encoded(q=q):
            sess = S.Session(QuerySpec(q, rounds=ROUNDS, emit="kernel"),
                             esrc)
            while not sess.done:
                sess.step()
            res = sess.result()
            jax.block_until_ready(res.final)
            return res

        scan_us, fused_us, enc_us = bench_io.time_interleaved(
            [run_scan, run_fused, run_encoded], repeats)

        # the whole point of bitwise finals: speed claims are apples to
        # apples — same answer, fewer seconds / fewer bytes
        ref = run_scan()
        for label, res in (("fused", run_fused()),
                           ("encoded", run_encoded())):
            for a, b in zip(jax.tree.leaves(res.final),
                            jax.tree.leaves(ref.final)):
                assert np.asarray(a).tobytes() == np.asarray(b).tobytes(), (
                    f"{label} {fam} final differs from the scan path")

        rows_out = [
            ("scan_" + fam, scan_us, {
                "rows": rows, "rounds": ROUNDS,
                **_roofline(logical_bytes, scan_us)}),
            ("fused_" + fam, fused_us, {
                "rows": rows, "rounds": ROUNDS,
                "speedup_vs_scan": scan_us / fused_us,
                "bitwise_vs_scan": True,
                **_roofline(logical_bytes, fused_us)}),
            ("encoded_fused_" + fam, enc_us, {
                "rows": rows, "rounds": ROUNDS,
                "byte_ratio_vs_logical": byte_ratio,
                "logical_bytes": logical_bytes,
                "bitwise_vs_scan": True,
                **_roofline(physical_bytes, enc_us)}),
        ]
        for name, us, derived in rows_out:
            frac = derived["roofline_fraction"]
            print(f"{name},{us:.0f},roofline_frac={frac:.4f}", file=out)
            bench_rows.append({"name": name, "us_per_call": us,
                               "derived": derived})

    path = bench_io.emit("fused", bench_rows)
    print(f"# wrote {path}", file=out)


if __name__ == "__main__":
    run(rows=int(sys.argv[1]) if len(sys.argv) > 1 else ROWS)
