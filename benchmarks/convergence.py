"""Paper Figures 1–3: relative confidence-bound width vs. scan progress,
for 1/2/4/8 partitions, single vs. multiple estimators, across the three
aggregation tasks (Q6 agg low/high selectivity, Q1 group-by small/large,
join group-by).

The paper plots width vs. *time* at fixed per-node data (scale-up); on one
CPU we plot width vs. scanned fraction with partitions processing in
parallel rounds — the shape of the curves and the parallelism effect
(more partitions ⇒ more result tuples found per round at the same
per-partition progress) reproduce Figs. 1–3.  Output: CSV rows

    task,estimator,partitions,round,frac_scanned,rel_width
"""
from __future__ import annotations

import sys

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import engine, gla, randomize
from repro.core.spec import QuerySpec
from repro.data import tpch

ROWS = 1_000_000
ROUNDS = 10
CHUNK = 1024


def _shards(parts, rows=ROWS, seed=7):
    cols = tpch.generate_lineitem(rows, seed=seed)
    parts_ = randomize.randomize_global(
        {k: jnp.asarray(v) for k, v in cols.items()}, jax.random.key(seed),
        parts)
    # pad the chunk count to a multiple of ROUNDS so every configuration
    # yields the same number of snapshot rounds
    n_chunks = -(-rows // parts // CHUNK)
    return randomize.pack_partitions(
        parts_, chunk_len=CHUNK, min_chunks=-(-n_chunks // ROUNDS) * ROUNDS)


def _tasks(rows=ROWS):
    supp, valid = tpch.supplier_nation_table()
    d = float(rows)
    return {
        "agg_low": dict(maker=lambda est: gla.make_sum_gla(
            tpch.q6_func, tpch.q6_cond(tpch.Q6_LOW_WINDOW),
            d_total=d, estimator=est)),
        "agg_high": dict(maker=lambda est: gla.make_sum_gla(
            tpch.q6_func, tpch.q6_cond(tpch.Q6_HIGH_WINDOW),
            d_total=d, estimator=est)),
        "groupby_small": dict(maker=lambda est: gla.make_groupby_gla(
            tpch.q1_func, tpch.q1_cond, tpch.q1_group_small, num_groups=4,
            d_total=d, estimator=est, num_aggs=4), group=2),
        "groupby_large": dict(maker=lambda est: gla.make_groupby_gla(
            tpch.q1_func, tpch.q1_cond, tpch.q1_group_large, num_groups=1000,
            d_total=d, estimator=est, num_aggs=4), group=123),
        "join_groupby": dict(maker=lambda est: gla.make_join_groupby_gla(
            tpch.q1_func, tpch.q6_cond(tpch.Q6_LOW_WINDOW),
            lambda c: c["suppkey"], supp, valid, num_groups=tpch.NUM_NATIONS,
            d_total=d, estimator=est, num_aggs=4), group=7),
    }


def rel_width(est, task_info):
    lo = np.asarray(est.lower, np.float64)
    hi = np.asarray(est.upper, np.float64)
    mid = np.asarray(est.estimate, np.float64)
    if lo.ndim == 3:                      # [R, G, A] group-by: pick group, agg 3
        g = task_info.get("group", 0)
        lo, hi, mid = lo[:, g, -1], hi[:, g, -1], mid[:, g, -1]
    elif lo.ndim == 2:
        lo, hi, mid = lo[:, 0], hi[:, 0], mid[:, 0]
    return (hi - lo) / np.maximum(np.abs(mid), 1e-12)


def run(tasks=None, out=sys.stdout, rows=ROWS):
    infos = _tasks(rows)
    names = tasks or list(infos.keys())
    bench_rows = []
    print("task,estimator,partitions,round,frac_scanned,rel_width", file=out)
    for task in names:
        info = infos[task]
        for parts in (1, 2, 4, 8):
            shards = _shards(parts, rows)
            C = shards["_mask"].shape[1]
            rounds = ROUNDS
            while C % rounds:
                rounds -= 1
            for est_kind in ("single", "multiple"):
                g = info["maker"](est_kind)
                res = engine.run_query(
                    QuerySpec(g, rounds=rounds, emit="round"), shards)
                w = rel_width(res.estimates, info)
                scanned = np.asarray(res.snapshots.scanned if hasattr(
                    res.snapshots, "scanned") else res.snapshots.base.scanned)
                for r in range(rounds):
                    frac = float(scanned[r]) / rows
                    print(f"{task},{est_kind},{parts},{r},"
                          f"{frac:.4f},{w[r]:.6f}", file=out)
                    bench_rows.append({
                        "name": f"convergence_{task}_{est_kind}_p{parts}_r{r}",
                        "task": task, "estimator": est_kind,
                        "partitions": parts, "round": r,
                        "frac_scanned": frac, "rel_width": float(w[r]),
                    })
    try:
        from benchmarks import bench_io
    except ImportError:  # direct script invocation: benchmarks/ is sys.path[0]
        import bench_io
    path = bench_io.emit("convergence", bench_rows)
    print(f"# wrote {path}", file=out)


if __name__ == "__main__":
    run(tasks=sys.argv[1:] or None)
