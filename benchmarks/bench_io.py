"""Shared benchmark output: every benchmark writes a machine-readable
``BENCH_<name>.json`` next to its human-readable CSV/stdout report.

Schema (see benchmarks/README.md):

    {
      "bench": "<name>",                # which benchmark produced this
      "rows": [ {...}, ... ]            # one dict per reported measurement
    }

Row keys are benchmark-specific but every row carries a ``name``; timing
rows also carry ``us_per_call`` (float, microseconds, median of repeats)
and ``derived`` (dict of derived quantities, e.g. overhead ratios).
"""
from __future__ import annotations

import json
import time
from pathlib import Path

OUT_DIR = Path(__file__).resolve().parent / "out"


def time_interleaved(fns, repeats: int, *, warmup: bool = True):
    """Interleaved min-of-repeats over zero-arg callables, in microseconds.

    The shared timing idiom of the benchmark tree: every contender runs
    once per repeat in round-robin order, so cache/allocator drift hits
    all of them equally, and the min discards external jitter.  Callables
    must block on their own results (``jax.block_until_ready``).
    """
    fns = list(fns)
    if warmup:
        for fn in fns:  # compile + page caches
            fn()
    ts = [[] for _ in fns]
    for _ in range(repeats):
        for i, fn in enumerate(fns):
            t0 = time.perf_counter()
            fn()
            ts[i].append(time.perf_counter() - t0)
    return [min(t) * 1e6 for t in ts]


def emit(bench: str, rows: list, extra: dict | None = None) -> Path:
    """Write BENCH_<bench>.json under benchmarks/out/ and return the path."""
    OUT_DIR.mkdir(parents=True, exist_ok=True)
    payload = {"bench": bench, "rows": rows}
    if extra:
        payload.update(extra)
    path = OUT_DIR / f"BENCH_{bench}.json"
    path.write_text(json.dumps(payload, indent=1))
    return path
