"""Shared benchmark output: every benchmark writes a machine-readable
``BENCH_<name>.json`` next to its human-readable CSV/stdout report.

Schema (see benchmarks/README.md):

    {
      "bench": "<name>",                # which benchmark produced this
      "rows": [ {...}, ... ]            # one dict per reported measurement
    }

Row keys are benchmark-specific but every row carries a ``name``; timing
rows also carry ``us_per_call`` (float, microseconds, median of repeats)
and ``derived`` (dict of derived quantities, e.g. overhead ratios).
"""
from __future__ import annotations

import json
from pathlib import Path

OUT_DIR = Path(__file__).resolve().parent / "out"


def emit(bench: str, rows: list, extra: dict | None = None) -> Path:
    """Write BENCH_<bench>.json under benchmarks/out/ and return the path."""
    OUT_DIR.mkdir(parents=True, exist_ok=True)
    payload = {"bench": bench, "rows": rows}
    if extra:
        payload.update(extra)
    path = OUT_DIR / f"BENCH_{bench}.json"
    path.write_text(json.dumps(payload, indent=1))
    return path
