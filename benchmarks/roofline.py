"""§Roofline: three-term roofline per (arch × shape × mesh) from the
dry-run's compiled artifacts (experiments/dryrun/*.json).

    compute term    = HLO_FLOPs / (chips × 197 TFLOP/s bf16)
    memory term     = HLO_bytes / (chips × 819 GB/s HBM)
    collective term = collective_bytes / (chips × 50 GB/s ICI)

HLO_FLOPs/bytes are loop-aware per-device numbers (repro/analysis/hlo_cost)
multiplied by chip count to match the spec's global convention — the two
normalizations cancel, so each term is per-device work / per-device rate.

MODEL_FLOPS = 6·N_active·D (train) or 2·N_active·D (inference), N_active
counted from the param spec tree with MoE experts scaled to top-k and the
embedding gather excluded.  ratio = MODEL_FLOPS/HLO_FLOPs exposes
remat/attention/dispatch overhead (>1 would mean the compiled graph does
LESS than the model math — a bug; ≪1 means waste or heavy attention).

roofline_fraction = (MODEL_FLOPS/chips/peak) / dominant_term — the fraction
of the dominant-resource time that is irreducible model math; this is the
score §Perf iterates on.
"""
from __future__ import annotations

import json
import sys
from pathlib import Path

import numpy as np

from repro.configs import get_config
from repro.launch.mesh import HBM_BW, ICI_BW, PEAK_FLOPS_BF16
from repro.launch.shapes import SHAPES

DRYRUN_DIR = Path(__file__).resolve().parents[1] / "experiments" / "dryrun"


def n_active_params(cfg) -> float:
    """Matmul-visible active params from the spec tree (embedding gather
    excluded; MoE expert leaves scaled from E to top-k)."""
    import jax.numpy as jnp
    from repro.models import transformer as T
    from repro.models.spec import is_spec
    import jax

    specs = T.param_specs(cfg, dtype=jnp.bfloat16)
    total = 0.0
    for path, leaf in jax.tree_util.tree_flatten_with_path(
            specs, is_leaf=is_spec)[0]:
        keys = [getattr(k, "key", str(k)) for k in path]
        if keys[-1] == "embed" and not cfg.tie_embeddings:
            continue                      # gather, not matmul
        if keys[-1] == "pos_embed" or keys[-1] == "pos":
            continue
        n = float(np.prod(leaf.shape))
        if "experts" in leaf.logical:
            e_dim = leaf.shape[leaf.logical.index("experts")]
            n = n / e_dim * max(cfg.experts_per_token, 1)
        total += n
    return total


def model_flops(cfg, shape_name: str) -> float:
    info = SHAPES[shape_name]
    n = n_active_params(cfg)
    if info["kind"] == "train":
        tokens = info["batch"] * info["seq"]
        return 6.0 * n * tokens
    if info["kind"] == "prefill":
        tokens = info["batch"] * info["seq"]
        return 2.0 * n * tokens
    return 2.0 * n * info["batch"]        # decode: one token per request


def load_cells(mesh_kind: str, dir_path=None):
    rows = []
    base = Path(dir_path) if dir_path else DRYRUN_DIR
    for f in sorted(base.glob(f"*.{mesh_kind}.json")):
        d = json.loads(f.read_text())
        rows.append(d)
    return rows


def analyze(mesh_kind: str = "single", dir_path=None):
    out = []
    for d in load_cells(mesh_kind, dir_path):
        arch, shape, _ = d["cell"].split(".")
        if d["status"] != "OK":
            out.append({"cell": d["cell"], "status": d["status"],
                        "reason": d.get("reason", "")})
            continue
        cfg = get_config(arch)
        chips = d["chips"]
        flops_dev = d["flops_per_device"]
        bytes_dev = d["bytes_per_device"]
        coll_dev = sum(d["collective_bytes_per_device"].values())
        t_c = flops_dev / PEAK_FLOPS_BF16
        t_m = bytes_dev / HBM_BW
        t_n = coll_dev / ICI_BW
        dom = max(("compute", t_c), ("memory", t_m), ("collective", t_n),
                  key=lambda kv: kv[1])
        mf = model_flops(cfg, shape)
        ratio = mf / (flops_dev * chips) if flops_dev else 0.0
        frac = (mf / chips / PEAK_FLOPS_BF16) / dom[1] if dom[1] > 0 else 0.0
        out.append({
            "cell": d["cell"], "status": "OK",
            "compute_s": t_c, "memory_s": t_m, "collective_s": t_n,
            "bottleneck": dom[0],
            "model_flops": mf, "hlo_flops_global": flops_dev * chips,
            "model_over_hlo": ratio,
            "roofline_fraction": frac,
            "peak_gb": d["memory"]["peak_estimate"] / 1e9,
        })
    return out


def main():
    mesh_kind = sys.argv[1] if len(sys.argv) > 1 else "single"
    dir_path = sys.argv[2] if len(sys.argv) > 2 else None
    rows = analyze(mesh_kind, dir_path)
    hdr = (f"{'cell':52s} {'compute_s':>10s} {'memory_s':>10s} "
           f"{'collect_s':>10s} {'bottleneck':>10s} {'MF/HLO':>7s} "
           f"{'roofl%':>7s} {'peakGB':>7s}")
    print(hdr)
    for r in rows:
        if r["status"] != "OK":
            print(f"{r['cell']:52s} {r['status']}: {r.get('reason','')[:60]}")
            continue
        print(f"{r['cell']:52s} {r['compute_s']:10.4f} {r['memory_s']:10.4f} "
              f"{r['collective_s']:10.4f} {r['bottleneck']:>10s} "
              f"{r['model_over_hlo']:7.3f} {100*r['roofline_fraction']:6.1f}% "
              f"{r['peak_gb']:7.1f}")
    if not rows:
        # no dry-run artifacts: nothing to report — do not emit an empty
        # BENCH file (benchmarks/check_schema.py requires non-empty rows)
        print("# no dry-run artifacts; BENCH_roofline.json not written")
        return
    try:
        from benchmarks import bench_io
    except ImportError:  # direct script invocation: benchmarks/ is sys.path[0]
        import bench_io
    named = [{"name": f"roofline_{r['cell']}", **r} for r in rows]
    path = bench_io.emit("roofline", named, extra={"mesh": mesh_kind})
    print(f"# wrote {path}")


if __name__ == "__main__":
    main()
