"""Framework-contract linter (repro/analysis/contracts.py): each rule
fires on crafted violations, stays quiet on the idioms the repo uses, and
the real tree lints clean (the same gate CI's contracts job enforces)."""
from pathlib import Path

import pytest

from repro.analysis import contracts

REPO = Path(__file__).resolve().parent.parent


def _lint_snippet(tmp_path, relpath, src):
    p = tmp_path / relpath
    p.parent.mkdir(parents=True, exist_ok=True)
    p.write_text(src)
    return contracts.lint_file(p, tmp_path)


def _codes(violations):
    return sorted(v.code for v in violations)


# ---------------------------------------------------------------------------
# C001/C002 — GLA construction + subclass pairing
# ---------------------------------------------------------------------------

def test_c001_groups_without_cols(tmp_path):
    vs = _lint_snippet(tmp_path, "q.py", (
        "from repro.core.gla import GLA\n"
        "bad = GLA(name='x', kernel_num_groups=8)\n"
        "good = GLA(name='y', kernel_num_groups=8, kernel_cols=('a',))\n"))
    assert _codes(vs) == ["C001"]
    assert vs[0].line == 2


def test_c002_half_pairs(tmp_path):
    vs = _lint_snippet(tmp_path, "g.py", (
        "from repro.core.gla import GLA\n"
        "class HalfKernel(GLA):\n"
        "    kernel_cols = ('a',)\n"
        "class HalfCkpt(GLA):\n"
        "    def serialize(self):\n"
        "        return b''\n"
        "class Full(GLA):\n"
        "    def serialize(self):\n"
        "        return b''\n"
        "    def deserialize(self, b):\n"
        "        return self\n"
        "class Unrelated:\n"
        "    kernel_cols = ('a',)\n"))
    assert _codes(vs) == ["C002", "C002"]


# ---------------------------------------------------------------------------
# C003/C004 — jit-region host calls; registry scoping
# ---------------------------------------------------------------------------

_HOSTY = (
    "import time\n"
    "import numpy as np\n"
    "def traced(x):\n"
    "    t = time.perf_counter()\n"
    "    y = np.asarray(x)\n"
    "    z = float(x)\n"
    "    r = np.random.normal()\n"
    "    return x.item() + x.tolist()[0] + t + y + z + r\n")


def test_c003_c004_fire_inside_scan_py(tmp_path):
    vs = _lint_snippet(tmp_path, "core/scan.py", _HOSTY)
    codes = _codes(vs)
    assert codes.count("C003") == 4  # asarray, float, .item, .tolist
    assert codes.count("C004") == 2  # perf_counter, np.random


def test_host_calls_outside_jit_regions_are_fine(tmp_path):
    # same source under engine.py's "decorated" policy: no jit decorator,
    # no violations — Session.step() legitimately reads the wall clock
    assert _lint_snippet(tmp_path, "core/engine.py", _HOSTY) == []
    # and in an unregistered file nothing applies at all
    assert _lint_snippet(tmp_path, "data/loader.py", _HOSTY) == []


def test_decorated_policy_catches_jitted_fn(tmp_path):
    vs = _lint_snippet(tmp_path, "dist/shard_engine.py", (
        "import functools\n"
        "import jax\n"
        "import numpy as np\n"
        "@functools.partial(jax.jit, static_argnames=('k',))\n"
        "def step(x, *, k):\n"
        "    return float(x)\n"
        "@jax.jit\n"
        "def step2(x):\n"
        "    def inner(y):\n"
        "        return np.asarray(y)\n"
        "    return inner(x)\n"
        "def host_helper(x):\n"
        "    return np.asarray(x)\n"))
    assert _codes(vs) == ["C003", "C003"]


# ---------------------------------------------------------------------------
# C005/C006 — estimator clamp discipline
# ---------------------------------------------------------------------------

def test_c005_unclamped_vs_clamped_division(tmp_path):
    vs = _lint_snippet(tmp_path, "core/estimators.py", (
        "import jax.numpy as jnp\n"
        "def variance_estimate(s, sq, n, d):\n"
        "    safe = jnp.maximum(n, 2.0)\n"
        "    den = safe * safe * (safe - 1.0)\n"
        "    est = d / den\n"                      # clamped product: OK
        "    frac = s / 2.0\n"                     # nonzero constant: OK
        "    bad = s / n\n"                        # raw denominator: C005
        "    return jnp.where(n >= 2.0, est + frac + bad, jnp.inf)\n"))
    assert _codes(vs) == ["C005"]
    assert "bad" not in vs[0].message or "unclamped" in vs[0].message


def test_c006_variance_guards_must_survive(tmp_path):
    vs = _lint_snippet(tmp_path, "core/estimators.py", (
        "def variance_estimate(s, sq, n, d):\n"
        "    return d / 2.0\n"))
    assert _codes(vs) == ["C006", "C006"]  # lost maximum AND where


# ---------------------------------------------------------------------------
# C007 — envelope manifest
# ---------------------------------------------------------------------------

_META_KEYS = sorted(contracts.ENVELOPE_HISTORY[max(contracts.ENVELOPE_HISTORY)])


def _session_src(version, keys):
    entries = ", ".join(f"'{k}': 0" for k in keys)
    return (f"_CKPT_VERSION = {version}\n"
            "class Session:\n"
            "    def _meta(self):\n"
            f"        return {{{entries}}}\n")


def test_c007_clean_manifest(tmp_path):
    assert _lint_snippet(
        tmp_path, "core/session.py",
        _session_src(max(contracts.ENVELOPE_HISTORY), _META_KEYS)) == []


def test_c007_drifted_key_set(tmp_path):
    vs = _lint_snippet(
        tmp_path, "core/session.py",
        _session_src(max(contracts.ENVELOPE_HISTORY),
                     [*_META_KEYS, "surprise"]))
    assert _codes(vs) == ["C007"]
    assert "surprise" in vs[0].message


def test_c007_stale_version(tmp_path):
    vs = _lint_snippet(
        tmp_path, "core/session.py",
        _session_src(max(contracts.ENVELOPE_HISTORY) - 1, _META_KEYS))
    assert _codes(vs) == ["C007"]
    assert "bump" in vs[0].message


# ---------------------------------------------------------------------------
# C008 — suppression policy
# ---------------------------------------------------------------------------

def test_c008_unallowlisted_suppression(tmp_path):
    vs = _lint_snippet(tmp_path, "q.py", (
        "from repro.core.gla import GLA\n"
        "q = GLA(name='x', kernel_num_groups=8)  # contracts: allow(C001)\n"))
    assert _codes(vs) == ["C008"]
    assert "ALLOWLIST" in vs[0].message


def test_c008_stale_suppression(tmp_path):
    vs = _lint_snippet(tmp_path, "q.py", (
        "x = 1  # contracts: allow(C001)\n"))
    assert _codes(vs) == ["C008"]
    assert "stale" in vs[0].message


def test_mismatched_suppression_keeps_violation(tmp_path):
    # suppressing the WRONG code does not silence the real violation
    vs = _lint_snippet(tmp_path, "q.py", (
        "from repro.core.gla import GLA\n"
        "q = GLA(name='x', kernel_num_groups=8)  # contracts: allow(C003)\n"))
    assert set(_codes(vs)) == {"C001", "C008"}


# ---------------------------------------------------------------------------
# the real tree is clean — the same gate the CI contracts job enforces
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("tree", ["src", "tests", "benchmarks", "examples"])
def test_repo_lints_clean(tree):
    if not (REPO / tree).exists():
        pytest.skip(f"{tree}/ absent")
    violations = []
    for f in contracts.iter_py_files([tree], REPO):
        violations.extend(contracts.lint_file(f, REPO))
    assert not violations, "\n".join(str(v) for v in violations)


def test_cli_exit_codes(tmp_path, capsys):
    (tmp_path / "ok.py").write_text("x = 1\n")
    assert contracts.main([str(tmp_path / "ok.py")]) == 0
    bad = tmp_path / "bad.py"
    bad.write_text("from repro.core.gla import GLA\n"
                   "q = GLA(name='x', kernel_num_groups=8)\n")
    assert contracts.main([str(bad)]) == 1
    out = capsys.readouterr().out
    assert "C001" in out and "FAIL" in out
