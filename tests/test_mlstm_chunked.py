"""Chunkwise-parallel mLSTM vs the sequential oracle (§Perf x2).

The chunkwise form must be *exactly* the sequential recurrence,
reassociated — including the stabilizer trajectory (the chunk row-max is
the closed form of the sequential max-plus recurrence) and the xLSTM
max(|n·q|, 1) denominator in stabilized scale.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax import lax

from repro.models.mlstm_chunked import mlstm_chunkwise
from repro.models.recurrent import _mlstm_cell_step


def _sequential(q, k, v, li, lf):
    B, S, H, dh = q.shape

    def step(carry, xs):
        C, n, m = carry
        qt, kt, vt, it, ft = xs
        C, n, m, h = _mlstm_cell_step(C, n, m, qt, kt, vt, it, ft)
        return (C, n, m), h

    z = jnp.zeros
    xs = jax.tree.map(lambda a: jnp.moveaxis(a, 1, 0), (q, k, v, li, lf))
    (C, n, m), hs = lax.scan(
        step, (z((B, H, dh, dh)), z((B, H, dh)), z((B, H))), xs)
    return jnp.moveaxis(hs, 0, 1), (C, n, m)


def _inputs(B, S, H, dh, seed=0, gate_scale=1.0):
    rng = np.random.default_rng(seed)
    q = jnp.asarray(rng.normal(size=(B, S, H, dh)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(B, S, H, dh)), jnp.float32) / np.sqrt(dh)
    v = jnp.asarray(rng.normal(size=(B, S, H, dh)), jnp.float32)
    li = jnp.asarray(rng.normal(size=(B, S, H)) * gate_scale, jnp.float32)
    lf = jnp.asarray(
        jax.nn.log_sigmoid(jnp.asarray(rng.normal(size=(B, S, H)) + 1.0)),
        jnp.float32)
    return q, k, v, li, lf


@pytest.mark.parametrize("chunk", [8, 32, 64])
@pytest.mark.parametrize("shape", [(2, 64, 2, 8), (1, 96, 3, 16)])
def test_chunkwise_matches_sequential(chunk, shape):
    B, S, H, dh = shape
    q, k, v, li, lf = _inputs(B, S, H, dh, seed=chunk + S)
    h_seq, (Cs, ns, ms) = _sequential(q, k, v, li, lf)
    h_ch, (Cc, nc, mc) = mlstm_chunkwise(q, k, v, li, lf, chunk=chunk)
    np.testing.assert_allclose(np.asarray(h_ch), np.asarray(h_seq),
                               rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(np.asarray(Cc), np.asarray(Cs), rtol=1e-4,
                               atol=1e-4)
    np.testing.assert_allclose(np.asarray(mc), np.asarray(ms), rtol=1e-5,
                               atol=1e-5)


def test_chunkwise_extreme_gates_stable():
    """Large input-gate preactivations stress the stabilizers."""
    q, k, v, li, lf = _inputs(1, 64, 2, 8, seed=9, gate_scale=8.0)
    h_seq, _ = _sequential(q, k, v, li, lf)
    h_ch, _ = mlstm_chunkwise(q, k, v, li, lf, chunk=16)
    assert np.all(np.isfinite(np.asarray(h_ch)))
    np.testing.assert_allclose(np.asarray(h_ch), np.asarray(h_seq),
                               rtol=5e-4, atol=5e-4)


def test_chunkwise_gradients_match():
    q, k, v, li, lf = _inputs(1, 32, 2, 8, seed=3)

    def loss_seq(q):
        h, _ = _sequential(q, k, v, li, lf)
        return jnp.sum(h * h)

    def loss_ch(q):
        h, _ = mlstm_chunkwise(q, k, v, li, lf, chunk=8)
        return jnp.sum(h * h)

    g1 = jax.grad(loss_seq)(q)
    g2 = jax.grad(loss_ch)(q)
    np.testing.assert_allclose(np.asarray(g2), np.asarray(g1), rtol=2e-3,
                               atol=2e-3)
