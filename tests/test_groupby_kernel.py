"""Large-domain group-by kernel dispatch (DESIGN.md §3): hash bucketing,
emit="kernel" vs the segment_sum round path (bitwise), rounds validation,
and the sync-mode incompatibility errors."""
import subprocess
import sys
import textwrap
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import engine, gla, randomize
from repro.data import tpch
from repro.dist import shard_engine

SRC = Path(__file__).resolve().parents[1] / "src"

ROWS = 12_000
PARTS = 4
SUPPLIERS = 2_000
BUCKET_BITS = 11  # 2000 <= 2**11: the bucket hash is injective here


@pytest.fixture(scope="module")
def cols():
    return tpch.generate_lineitem(ROWS, seed=23, num_suppliers=SUPPLIERS)


@pytest.fixture(scope="module")
def shards(cols):
    parts = randomize.randomize_global(
        {k: jnp.asarray(v) for k, v in cols.items()}, jax.random.key(5),
        PARTS)
    return randomize.pack_partitions(parts, chunk_len=256)


@pytest.fixture(scope="module")
def gq():
    return gla.make_groupby_gla(
        tpch.q1_func, tpch.q1_cond, tpch.q1_group_large,
        num_groups=SUPPLIERS, bucket_bits=BUCKET_BITS, d_total=float(ROWS),
        num_aggs=4)


def test_hash_bucket_bijective():
    """Odd multiplier => g -> hash_bucket(g) is a permutation of [0, 2**b)."""
    b = 10
    h = np.asarray(gla.hash_bucket(jnp.arange(1 << b), b))
    assert sorted(h.tolist()) == list(range(1 << b))


def test_groupby_kernel_publishes_contract(gq):
    assert gq.kernel_cols is not None
    assert gq.kernel_num_groups == 1 << BUCKET_BITS
    # non-f32 states cannot take the kernel path
    g64 = gla.make_groupby_gla(
        tpch.q1_func, tpch.q1_cond, tpch.q1_group_large, num_groups=100,
        d_total=float(ROWS), dtype=jnp.float64)
    assert g64.kernel_cols is None and g64.kernel_num_groups is None


def test_kernel_matches_round_bitwise_vmapped(shards, gq):
    """One group_agg dispatch per round-slice reproduces the segment_sum
    scan exactly: finals AND merged round states are bitwise identical
    (the kernel accumulates chunk-by-chunk in the scan's association
    order)."""
    rk = engine.run_query(gq, shards, rounds=4, emit="kernel")
    rr = engine.run_query(gq, shards, rounds=4, emit="round")
    assert np.asarray(rk.final).tobytes() == np.asarray(rr.final).tobytes()
    for a, b in zip(jax.tree.leaves(rk.snapshots),
                    jax.tree.leaves(rr.snapshots)):
        assert np.asarray(a).tobytes() == np.asarray(b).tobytes()
    np.testing.assert_allclose(np.asarray(rk.estimates.estimate),
                               np.asarray(rr.estimates.estimate), rtol=1e-6)


def test_kernel_final_matches_exact_debucketed(cols, shards, gq):
    """End-to-end: bucketed kernel final, de-bucketed back to raw suppkeys,
    equals the host-numpy exact answer (injective bucket hash here)."""
    res = engine.run_query(gq, shards, rounds=4, emit="kernel")
    exact = tpch.exact_answer(cols, tpch.q1_func, tpch.q1_cond,
                              tpch.q1_group_large, SUPPLIERS)
    deb = np.asarray(gla.debucket(res.final, np.arange(SUPPLIERS),
                                  BUCKET_BITS))
    np.testing.assert_allclose(deb, exact, rtol=2e-3, atol=1e-2)
    # injectivity also means every bucket outside the image stays empty
    occupied = np.asarray(gla.hash_bucket(jnp.arange(SUPPLIERS), BUCKET_BITS))
    empty = np.setdiff1d(np.arange(1 << BUCKET_BITS), occupied)
    assert np.all(np.asarray(res.final)[empty] == 0.0)


def test_join_groupby_inherits_kernel_dispatch(shards):
    """The join GLA composes the group-by kernel contract (the hash-probe
    gather lives inside the kernel_cols projection)."""
    supp, valid = tpch.supplier_nation_table(SUPPLIERS)
    gj = gla.make_join_groupby_gla(
        tpch.q1_func, tpch.q6_cond(tpch.Q6_LOW_WINDOW),
        lambda c: c["suppkey"], supp, valid, num_groups=tpch.NUM_NATIONS,
        d_total=float(ROWS), num_aggs=4)
    assert gj.kernel_cols is not None
    assert gj.kernel_num_groups == tpch.NUM_NATIONS
    rk = engine.run_query(gj, shards, rounds=4, emit="kernel")
    rr = engine.run_query(gj, shards, rounds=4, emit="round")
    assert np.asarray(rk.final).tobytes() == np.asarray(rr.final).tobytes()


def test_groupby_multiple_passes_estimator_merge(shards):
    """groupby-multiple declares estimator_merge explicitly (like
    sum-multiple) instead of leaning on the __post_init__ fallback, and the
    stratified estimator runs end-to-end on the bucketed table."""
    gm = gla.make_groupby_gla(
        tpch.q1_func, tpch.q1_cond, tpch.q1_group_large,
        num_groups=SUPPLIERS, bucket_bits=BUCKET_BITS, d_total=float(ROWS),
        estimator="multiple", num_aggs=4)
    assert gm.estimator_merge is gm.merge  # explicit, not fallback-derived
    res = engine.run_query(gm, shards, rounds=4, emit="round")
    lo = np.asarray(res.estimates.lower, np.float64)
    hi = np.asarray(res.estimates.upper, np.float64)
    assert np.all(np.isfinite(lo)) and np.all(np.isfinite(hi))
    # full scan: bounds collapse onto the exact per-bucket answer
    assert np.max(np.abs(hi[-1] - lo[-1])) < 1e-2


def test_rounds_degrade_with_warning(shards, gq):
    """C % rounds != 0 under the default uniform schedule degrades to the
    largest divisor with a warning instead of tripping the scan assert."""
    C = shards["_mask"].shape[1]
    assert C == 12
    for emit in ("round", "kernel"):
        with pytest.warns(UserWarning, match="degrading"):
            res = engine.run_query(gq, shards, rounds=8, emit=emit)
        assert np.asarray(res.snapshots.scanned).shape[0] == 6
    # an explicit incompatible schedule is a hard error, not a silent fix
    bad = engine.uniform_schedule(PARTS, C, 7)
    with pytest.raises(ValueError, match="C % rounds"):
        engine.run_query(gq, shards, schedule=bad, emit="round")
    # ... and so is a divisible but non-uniform one: round-emission paths
    # snapshot at uniform boundaries and would silently ignore it
    skew = engine.straggler_schedule(PARTS, C, 6, speeds=[1, 1, 2, 4])
    for emit in ("round", "kernel"):
        with pytest.raises(ValueError, match="non-uniform"):
            engine.run_query(gq, shards, schedule=skew, emit=emit)


def test_kernel_snapshots_off_single_dispatch(shards, gq):
    """Non-interactive mode collapses to one whole-shard dispatch; the
    final is still bitwise-identical to the interactive run's."""
    on = engine.run_query(gq, shards, rounds=4, emit="kernel")
    off = engine.run_query(gq, shards, rounds=4, emit="kernel",
                           snapshots=False)
    assert off.snapshots is None and off.estimates is None
    assert np.asarray(off.final).tobytes() == np.asarray(on.final).tobytes()


def test_sync_mode_rejects_kernel_paths(shards, gq):
    """No silent downgrade: every sync×kernel combination that cannot run
    the kernel dispatch raises instead of quietly scanning."""
    with pytest.raises(NotImplementedError, match="sync"):
        engine.run_query(gq, shards, rounds=4, mode="sync", emit="kernel")

    # sharded: sync_cost_model=True used to silently run the plain scan
    mesh = jax.make_mesh((1,), ("data",))
    one = jax.tree.map(lambda x: x[:1], shards)
    q6 = gla.make_sum_gla(tpch.q6_func, tpch.q6_cond(tpch.Q6_LOW_WINDOW),
                          d_total=float(ROWS))
    with pytest.raises(ValueError, match="sync_cost_model"):
        engine.run_query(q6, one, rounds=4, mode="sync", emit="kernel",
                         mesh=mesh)
    # group-by kernel has no prefix states for the pmin truncation at all
    sched = jnp.asarray(engine.uniform_schedule(1, 12, 4))
    with pytest.raises(ValueError, match="round states"):
        shard_engine.run_sharded(
            gq, one, sched, jnp.ones((1,), bool), mesh=mesh,
            axis_name="data", mode="sync", emit="kernel", lanes=1,
            snapshots=True, confidence=0.95, sync_cost_model=False)
    # ... and neither does emit="round" once the cost-model scan (which
    # builds its own prefixes) is turned off
    with pytest.raises(ValueError, match="round states"):
        shard_engine.run_sharded(
            gq, one, sched, jnp.ones((1,), bool), mesh=mesh,
            axis_name="data", mode="sync", emit="round", lanes=1,
            snapshots=True, confidence=0.95, sync_cost_model=False)

    # the error's advice is actionable through the public API: the scalar
    # kernel runs under sync once the cost-model collective is waived
    res = engine.run_query(q6, one, rounds=4, mode="sync", emit="kernel",
                           mesh=mesh, sync_cost_model=False)
    ref = engine.run_query(q6, one, rounds=4, mode="sync", emit="chunk")
    np.testing.assert_allclose(float(res.final), float(ref.final), rtol=1e-5)


@pytest.mark.slow
def test_sharded_kernel_matches_vmapped_subprocess():
    """Group-by kernel dispatch under shard_map on 4 fake devices: finals
    bitwise-identical to both the vmapped kernel path and the segment_sum
    round path (in a subprocess so XLA_FLAGS stays local)."""
    code = textwrap.dedent("""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
        import sys; sys.path.insert(0, %r)
        import numpy as np, jax, jax.numpy as jnp
        from repro.core import engine, gla, randomize
        from repro.data import tpch
        rows, parts = 12_000, 4
        cols = tpch.generate_lineitem(rows, seed=23, num_suppliers=2000)
        ps = randomize.randomize_global(
            {k: jnp.asarray(v) for k, v in cols.items()}, jax.random.key(5),
            parts)
        shards = randomize.pack_partitions(ps, chunk_len=256)
        mesh = jax.make_mesh((parts,), ("data",))
        g = gla.make_groupby_gla(
            tpch.q1_func, tpch.q1_cond, tpch.q1_group_large,
            num_groups=2000, bucket_bits=11, d_total=float(rows), num_aggs=4)
        rv = engine.run_query(g, shards, rounds=4, emit="kernel")
        rr = engine.run_query(g, shards, rounds=4, emit="round")
        rs = engine.run_query(g, shards, rounds=4, emit="kernel", mesh=mesh)
        for a, b in ((rs, rv), (rs, rr)):
            assert np.asarray(a.final).tobytes() == np.asarray(b.final).tobytes()
            for x, y in zip(jax.tree.leaves(a.snapshots),
                            jax.tree.leaves(b.snapshots)):
                assert np.asarray(x).tobytes() == np.asarray(y).tobytes()
        print("OK")
    """ % str(SRC))
    r = subprocess.run([sys.executable, "-c", code], capture_output=True,
                       text=True, timeout=600)
    assert r.returncode == 0, r.stderr[-3000:]
    assert "OK" in r.stdout
