"""Engine semantics: zero-overhead snapshots, emit-path consistency,
straggler schedules, sync truncation, lane merge-order independence."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import engine, gla, randomize
from repro.data import tpch

ROWS = 30_000


@pytest.fixture(scope="module")
def shards():
    cols = tpch.generate_lineitem(ROWS, seed=11)
    parts = randomize.randomize_global(
        {k: jnp.asarray(v) for k, v in cols.items()}, jax.random.key(2), 4)
    return randomize.pack_partitions(parts, chunk_len=256)


@pytest.fixture(scope="module")
def q6(shards):
    return gla.make_sum_gla(tpch.q6_func, tpch.q6_cond(tpch.Q6_LOW_WINDOW),
                            d_total=float(ROWS))


def test_emit_paths_agree(shards, q6):
    """chunk-prefix, round, and masked-round paths give identical snapshots
    under a uniform schedule."""
    C = shards["_mask"].shape[1]
    rounds = 4
    while C % rounds:
        rounds -= 1
    a = engine.run_query(q6, shards, rounds=rounds, emit="chunk")
    b = engine.run_query(q6, shards, rounds=rounds, emit="round")
    c = engine.run_query(q6, shards, rounds=rounds, emit="round_masked")
    for x, y in ((a, b), (a, c)):
        np.testing.assert_allclose(np.asarray(x.estimates.estimate),
                                   np.asarray(y.estimates.estimate),
                                   rtol=2e-4)
    np.testing.assert_allclose(float(a.final), float(b.final), rtol=1e-5)


def test_snapshots_do_not_change_final(shards, q6):
    """Interactive mode returns the same final answer as non-interactive —
    the zero-overhead design invariant (timing measured in benchmarks)."""
    on = engine.run_query(q6, shards, rounds=7, snapshots=True)
    off = engine.run_query(q6, shards, rounds=7, snapshots=False)
    np.testing.assert_allclose(float(on.final), float(off.final), rtol=1e-6)
    assert off.estimates is None and on.estimates is not None


def test_straggler_async_final_exact(shards, q6):
    sched = engine.straggler_schedule(4, shards["_mask"].shape[1], 6,
                                      speeds=[1, 1, 2, 4], seed=7)
    res = engine.run_query(q6, shards, schedule=sched, mode="async")
    uni = engine.run_query(q6, shards, rounds=6)
    np.testing.assert_allclose(float(res.final), float(uni.final), rtol=1e-6)
    # async snapshots differ across schedules, but the last one is complete
    np.testing.assert_allclose(np.asarray(res.estimates.estimate)[-1],
                               float(uni.final), rtol=2e-4)


def test_sync_truncates_to_min_progress(shards, q6):
    sched = engine.straggler_schedule(4, shards["_mask"].shape[1], 6,
                                      speeds=[1, 1, 2, 4], seed=7)
    res = engine.run_query(q6, shards, schedule=sched, mode="sync")
    # scanned counts at each snapshot must equal P * min-progress * chunk
    mins = np.min(sched[:, 1:], axis=0)
    scanned = np.asarray(res.snapshots.scanned)
    L = shards["_mask"].shape[2]
    # partitions have ragged tails; allow the padded-chunk tolerance
    expected = 4 * mins * L
    assert np.all(scanned <= expected + 1e-6)
    assert np.all(scanned >= expected * 0.95 - L)


def test_lanes_merge_order_independent(shards, q6):
    """DataPath work-unit analogue: more lanes, same result."""
    r1 = engine.run_query(q6, shards, rounds=4, lanes=1)
    r4 = engine.run_query(q6, shards, rounds=4, lanes=4)
    np.testing.assert_allclose(float(r1.final), float(r4.final), rtol=1e-5)
    np.testing.assert_allclose(np.asarray(r1.estimates.estimate),
                               np.asarray(r4.estimates.estimate), rtol=1e-4)


def test_groupby_large_state_round_path(shards):
    gq = gla.make_groupby_gla(tpch.q1_func, tpch.q1_cond, tpch.q1_group_large,
                              num_groups=1000, d_total=float(ROWS), num_aggs=4)
    C = shards["_mask"].shape[1]
    rounds = 4
    while C % rounds:
        rounds -= 1
    res = engine.run_query(gq, shards, rounds=rounds, emit="round")
    cols = tpch.generate_lineitem(ROWS, seed=11)
    exact = tpch.exact_answer(cols, tpch.q1_func, tpch.q1_cond,
                              tpch.q1_group_large, 1000)
    np.testing.assert_allclose(np.asarray(res.final), exact, rtol=2e-3,
                               atol=1e-2)
