"""Multi-query shared-scan engine (DESIGN.md §6): N concurrent OLA
estimations over a single pass.

The acceptance contract: ``engine.run_queries`` over [Q1, Q6, Q1-large]
returns finals and per-round bounds bitwise-identical to solo ``run_query``
calls, on both the vmapped and shard_map engines, and the bundled
``emit="kernel"`` path issues exactly one ``ops.group_agg`` dispatch per
(partition, round-slice) for the WHOLE bundle."""
import subprocess
import sys
import textwrap
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.analysis import hlo_cost as HC
from repro.core import engine, gla, randomize
from repro.data import tpch

SRC = Path(__file__).resolve().parents[1] / "src"

ROWS = 12_000
PARTS = 4
SUPPLIERS = 2_000
BUCKET_BITS = 11
ROUNDS = 4


@pytest.fixture(scope="module")
def shards():
    cols = tpch.generate_lineitem(ROWS, seed=23, num_suppliers=SUPPLIERS)
    parts = randomize.randomize_global(
        {k: jnp.asarray(v) for k, v in cols.items()}, jax.random.key(5),
        PARTS)
    return randomize.pack_partitions(parts, chunk_len=256)


def _q6(estimator="single"):
    return gla.make_sum_gla(tpch.q6_func, tpch.q6_cond(tpch.Q6_LOW_WINDOW),
                            d_total=float(ROWS), estimator=estimator)


def _q1_small(estimator="single"):
    return gla.make_groupby_gla(
        tpch.q1_func, tpch.q1_cond, tpch.q1_group_small, num_groups=4,
        d_total=float(ROWS), estimator=estimator, num_aggs=4)


def _q1_large(estimator="single"):
    return gla.make_groupby_gla(
        tpch.q1_func, tpch.q1_cond, tpch.q1_group_large,
        num_groups=SUPPLIERS, bucket_bits=BUCKET_BITS, d_total=float(ROWS),
        estimator=estimator, num_aggs=4)


@pytest.fixture(scope="module")
def workload():
    return [_q1_small(), _q6(), _q1_large()]


def _assert_bitwise(a, b):
    for x, y in zip(jax.tree.leaves(a), jax.tree.leaves(b)):
        assert np.asarray(x).tobytes() == np.asarray(y).tobytes()


# ---------------------------------------------------------------------------
# the bundle combinator itself
# ---------------------------------------------------------------------------

def test_bundle_is_a_gla(workload):
    b = gla.GLABundle(workload)
    assert b.members == tuple(workload)
    assert b.merge_is_additive
    assert b.kernel_cols is None  # members publish theirs; the bundle batches
    assert "sum-single" in b.name
    with pytest.raises(ValueError, match="at least one"):
        gla.GLABundle([])
    with pytest.raises(ValueError, match="must not themselves"):
        gla.GLABundle([b, _q6()])


def test_bundle_memoized_for_jit_cache(workload):
    """Re-bundling the same members returns the SAME object: the engines'
    jit caches key on the GLA statically, so a repeated run_queries
    workload must not recompile per call."""
    assert gla.GLABundle(workload) is gla.GLABundle(workload)
    assert gla.GLABundle(workload) is not gla.GLABundle(workload[:2])


def test_bundle_estimate_tuple_matches_members(workload):
    """Per-query emission views: the bundle's estimate is a tuple with one
    Estimate per member, None for estimation-free members."""
    b = gla.GLABundle([_q6(), _q6("none")])
    state = b.init()
    ests = b.estimate(state, 0.95, {"d_total": 1.0})
    assert len(ests) == 2
    assert ests[0] is not None and ests[1] is None


# ---------------------------------------------------------------------------
# bitwise equivalence with solo runs — the shared scan must be free
# ---------------------------------------------------------------------------

def test_run_queries_bitwise_identical_vmapped(shards, workload):
    """[Q1, Q6, Q1-large] through one shared scan == three solo scans,
    bitwise: finals, merged snapshot states, and the per-round bounds."""
    multi = engine.run_queries(workload, shards, rounds=ROUNDS, emit="round")
    assert len(multi) == len(workload)
    for g, res in zip(workload, multi):
        solo = engine.run_query(g, shards, rounds=ROUNDS, emit="round")
        _assert_bitwise(res.final, solo.final)
        _assert_bitwise(res.snapshots, solo.snapshots)
        _assert_bitwise(
            (res.estimates.estimate, res.estimates.lower,
             res.estimates.upper),
            (solo.estimates.estimate, solo.estimates.lower,
             solo.estimates.upper))
        assert float(res.d_total) == float(solo.d_total)


def test_run_queries_chunk_emit_matches_round(shards):
    """Small-state bundles can use prefix emission; snapshots at uniform
    round boundaries equal the round path bitwise."""
    glas = [_q6(), _q1_small()]
    a = engine.run_queries(glas, shards, rounds=ROUNDS, emit="chunk")
    b = engine.run_queries(glas, shards, rounds=ROUNDS, emit="round")
    for x, y in zip(a, b):
        _assert_bitwise(x.final, y.final)
        _assert_bitwise(x.snapshots, y.snapshots)


def test_run_queries_mixed_estimators(shards):
    """single + multiple + estimation-free members coexist in one pass;
    the stratified member's EstimatorTerminate sees the same d_local."""
    glas = [_q6("single"), _q6("multiple"), _q6("none")]
    multi = engine.run_queries(glas, shards, rounds=ROUNDS, emit="round")
    for g, res in zip(glas, multi):
        solo = engine.run_query(g, shards, rounds=ROUNDS, emit="round")
        _assert_bitwise(res.final, solo.final)
        if g.estimate is None:
            assert res.estimates is None
        else:
            _assert_bitwise(res.estimates.estimate, solo.estimates.estimate)
    # the estimation-free member yields None estimates in the bundle view
    assert multi[2].estimates is None


def test_run_queries_snapshots_off(shards, workload):
    multi = engine.run_queries(workload, shards, rounds=ROUNDS, emit="round",
                               snapshots=False)
    for g, res in zip(workload, multi):
        solo = engine.run_query(g, shards, rounds=ROUNDS, emit="round",
                                snapshots=False)
        _assert_bitwise(res.final, solo.final)
        assert res.snapshots is None and res.estimates is None


# ---------------------------------------------------------------------------
# batched kernel dispatch
# ---------------------------------------------------------------------------

def test_run_queries_kernel_batched_bitwise(shards, workload):
    """emit='kernel' batches all members into one group_agg dispatch per
    round-slice.  Group-by members stay bitwise-identical to their solo
    kernel dispatch (disjoint blocks, exact-zero cross-member partials);
    the scalar member folds through the one-hot contraction and is
    interchangeable with the scan path (same caveat as the solo scalar
    kernel)."""
    multi = engine.run_queries(workload, shards, rounds=ROUNDS, emit="kernel")
    for g, res in zip(workload, multi):
        if g.kernel_num_groups is not None:
            solo = engine.run_query(g, shards, rounds=ROUNDS, emit="kernel")
            _assert_bitwise(res.final, solo.final)
            _assert_bitwise(res.snapshots, solo.snapshots)
        else:
            solo = engine.run_query(g, shards, rounds=ROUNDS, emit="round")
            np.testing.assert_allclose(np.asarray(res.final),
                                       np.asarray(solo.final), rtol=1e-5)
            np.testing.assert_allclose(
                np.asarray(res.estimates.estimate),
                np.asarray(solo.estimates.estimate), rtol=1e-4)


def test_kernel_bundle_one_dispatch_per_round_slice(shards, workload):
    """One dispatch per (partition, round-slice) for the WHOLE bundle.

    The all-FusedSpec workload takes the fused path — its in-kernel
    segment_sum lowers to scatter loops under interpret mode, so the
    dispatch count comes from trace-time ``pallas_call`` accounting, not
    a while-op census.  Join members now fuse too (probe tables ride as
    kernel operands, DESIGN.md §13), so the legacy one-hot batcher is
    exercised by stripping the join's fused contract (``fused=None`` —
    the oversized-probe fallback path), where the HLO invariant still
    holds: exactly P×R while ops, every one a Pallas grid loop."""
    if jax.default_backend() != "cpu":
        pytest.skip("interpret-mode lowering check is CPU-specific")
    from repro.kernels import fused_agg as FK
    jax.clear_caches()  # earlier tests traced this program; a jit cache
    # hit would skip pallas_call construction and the count would read 0
    with FK.count_dispatches() as box:
        jax.eval_shape(lambda sh: engine.run_queries(
            workload, sh, rounds=ROUNDS, emit="kernel"), shards)
    assert box[0] == PARTS * ROUNDS, box[0]

    supp = jnp.arange(SUPPLIERS, dtype=jnp.int32) % tpch.NUM_NATIONS
    valid = jnp.ones((SUPPLIERS,), jnp.float32)
    legacy = [*workload, gla.make_join_groupby_gla(
        tpch.q1_func, tpch.q6_cond(tpch.Q6_LOW_WINDOW),
        lambda c: c["suppkey"], supp, valid,
        num_groups=tpch.NUM_NATIONS, d_total=float(ROWS),
        num_aggs=4).with_(fused=None)]
    fn = jax.jit(lambda sh: engine.run_queries(
        legacy, sh, rounds=ROUNDS, emit="kernel")).lower(shards).compile()
    n_while = HC.count_ops(fn.as_text(), "while", trip_scaled=False)
    assert n_while == PARTS * ROUNDS, n_while


def test_kernel_bundle_rejects_scan_only_members(shards):
    g64 = gla.make_groupby_gla(
        tpch.q1_func, tpch.q1_cond, tpch.q1_group_large, num_groups=100,
        d_total=float(ROWS), dtype=jnp.float64)
    assert g64.kernel_cols is None
    with pytest.raises(ValueError, match="do not publish kernel_cols"):
        engine.run_queries([_q6(), g64], shards, rounds=ROUNDS, emit="kernel")


def test_kernel_bundle_rounds_validation(shards, workload):
    """Bundles inherit the round-emission discipline: indivisible explicit
    schedules are rejected, default rounds degrade with a warning."""
    C = shards["_mask"].shape[1]
    bad = engine.uniform_schedule(PARTS, C, 7)
    with pytest.raises(ValueError, match="C % rounds"):
        engine.run_queries(workload, shards, schedule=bad, emit="kernel")
    with pytest.warns(UserWarning, match="degrading"):
        res = engine.run_queries(workload, shards, rounds=8, emit="kernel")
    assert np.asarray(res[0].snapshots.scanned).shape[0] == 6


# ---------------------------------------------------------------------------
# sharded engine
# ---------------------------------------------------------------------------

@pytest.mark.slow
def test_run_queries_sharded_matches_solo_subprocess():
    """Shared scan under shard_map on 4 fake devices: per-query finals,
    snapshots and bounds bitwise-identical to solo sharded AND solo vmapped
    runs; the bundled kernel path agrees with its vmapped twin."""
    code = textwrap.dedent("""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
        import sys; sys.path.insert(0, %r)
        import numpy as np, jax, jax.numpy as jnp
        from repro.core import engine, gla, randomize
        from repro.data import tpch
        rows, parts = 12_000, 4
        cols = tpch.generate_lineitem(rows, seed=23, num_suppliers=2000)
        ps = randomize.randomize_global(
            {k: jnp.asarray(v) for k, v in cols.items()}, jax.random.key(5),
            parts)
        shards = randomize.pack_partitions(ps, chunk_len=256)
        mesh = jax.make_mesh((parts,), ("data",))
        glas = [
            gla.make_groupby_gla(
                tpch.q1_func, tpch.q1_cond, tpch.q1_group_small,
                num_groups=4, d_total=float(rows), num_aggs=4),
            gla.make_sum_gla(tpch.q6_func, tpch.q6_cond(tpch.Q6_LOW_WINDOW),
                             d_total=float(rows)),
            gla.make_groupby_gla(
                tpch.q1_func, tpch.q1_cond, tpch.q1_group_large,
                num_groups=2000, bucket_bits=11, d_total=float(rows),
                num_aggs=4),
        ]
        def leaves_equal(a, b):
            return all(np.asarray(x).tobytes() == np.asarray(y).tobytes()
                       for x, y in zip(jax.tree.leaves(a), jax.tree.leaves(b)))
        multi = engine.run_queries(glas, shards, rounds=4, emit="round",
                                   mesh=mesh)
        for g, res in zip(glas, multi):
            ss = engine.run_query(g, shards, rounds=4, emit="round",
                                  mesh=mesh)
            sv = engine.run_query(g, shards, rounds=4, emit="round")
            for solo in (ss, sv):
                assert leaves_equal(res.final, solo.final)
                assert leaves_equal(res.snapshots, solo.snapshots)
                assert leaves_equal(
                    (res.estimates.estimate, res.estimates.lower,
                     res.estimates.upper),
                    (solo.estimates.estimate, solo.estimates.lower,
                     solo.estimates.upper))
        mk = engine.run_queries(glas, shards, rounds=4, emit="kernel",
                                mesh=mesh)
        mv = engine.run_queries(glas, shards, rounds=4, emit="kernel")
        for a, b in zip(mk, mv):
            assert leaves_equal(a.final, b.final)
            assert leaves_equal(a.snapshots, b.snapshots)
        print("OK")
    """ % str(SRC))
    r = subprocess.run([sys.executable, "-c", code], capture_output=True,
                       text=True, timeout=600)
    assert r.returncode == 0, r.stderr[-3000:]
    assert "OK" in r.stdout


def test_sharded_sync_rejects_bundle_kernel(shards, workload):
    from repro.dist import shard_engine
    mesh = jax.make_mesh((1,), ("data",))
    one = jax.tree.map(lambda x: x[:1], shards)
    sched = jnp.asarray(
        engine.uniform_schedule(1, shards["_mask"].shape[1], ROUNDS))
    with pytest.raises(ValueError, match="round states"):
        shard_engine.run_sharded(
            gla.GLABundle(workload), one, sched, jnp.ones((1,), bool),
            mesh=mesh, axis_name="data", mode="sync", emit="kernel",
            lanes=1, snapshots=True, confidence=0.95, sync_cost_model=False)
