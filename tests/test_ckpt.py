"""Serialize/Deserialize (paper Table 1 transfer extension) + restart."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import ckpt
from repro.configs import get_config
from repro.core import engine, gla, randomize
from repro.data import tpch
from repro.training import train_step as TS


def test_gla_state_roundtrip_bit_exact():
    rows = 5_000
    cols = tpch.generate_lineitem(rows, seed=31)
    parts = randomize.randomize_global(
        {k: jnp.asarray(v) for k, v in cols.items()}, jax.random.key(0), 2)
    shards = randomize.pack_partitions(parts, chunk_len=128)
    g = gla.make_sum_gla(tpch.q6_func, tpch.q6_cond(tpch.Q6_LOW_WINDOW),
                         d_total=float(rows))
    res = engine.run_query(g, shards, rounds=4)
    state = jax.tree.map(lambda x: x[1], res.snapshots)  # mid-query snapshot
    buf = ckpt.serialize_state(state)
    back = ckpt.deserialize_state(buf, like=state)
    for a, b in zip(jax.tree.leaves(state), jax.tree.leaves(back)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_resume_from_checkpoint_equals_uninterrupted():
    """Merge(checkpointed prefix, resumed suffix) == single full run."""
    rows = 6_000
    cols = tpch.generate_lineitem(rows, seed=32)
    parts = randomize.randomize_global(
        {k: jnp.asarray(v) for k, v in cols.items()}, jax.random.key(1), 2)
    shards = randomize.pack_partitions(parts, chunk_len=128)
    g = gla.make_sum_gla(tpch.q6_func, tpch.q6_cond(tpch.Q6_LOW_WINDOW),
                         d_total=float(rows))
    full = engine.run_query(g, shards, rounds=2)

    C = shards["_mask"].shape[1]
    half = C // 2
    first = {k: v[:, :half] for k, v in shards.items()}
    second = {k: v[:, half:] for k, v in shards.items()}
    r1 = engine.run_query(g, first, rounds=1)
    state1 = jax.tree.map(lambda x: x[-1], r1.snapshots)
    buf = ckpt.serialize_state(state1)            # "crash" here
    restored = ckpt.deserialize_state(buf, like=state1)
    r2 = engine.run_query(g, second, rounds=1)
    state2 = jax.tree.map(lambda x: x[-1], r2.snapshots)
    merged = g.merge(restored, state2)
    np.testing.assert_allclose(float(g.terminate(merged)), float(full.final),
                               rtol=1e-5)


def test_zlib_fallback_roundtrip_bit_exact(monkeypatch):
    """The zstandard-less path (exercised for real by the CI no-zstd
    lane): serialize/deserialize and the session envelope must round-trip
    bit-exactly through the stdlib zlib fallback."""
    state = {"a": jnp.arange(7, dtype=jnp.float32),
             "b": (jnp.ones((3, 2), jnp.int32), jnp.float32(0.5))}
    monkeypatch.setattr(ckpt, "zstandard", None)
    buf = ckpt.serialize_state(state)
    assert buf[:4] != b"\x28\xb5\x2f\xfd"  # not a zstd frame
    back = ckpt.deserialize_state(buf, like=state)
    for a, b in zip(jax.tree.leaves(state), jax.tree.leaves(back)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_cross_codec_read(tmp_path, monkeypatch):
    """The codec is identified by the stream's own magic: a zlib-written
    envelope must load regardless of whether zstandard is installed."""
    meta = {"version": 1, "note": "cross-codec"}
    state = {"x": jnp.arange(5, dtype=jnp.float32)}
    monkeypatch.setattr(ckpt, "zstandard", None)
    path = tmp_path / "zlib.ckpt"
    ckpt.save_envelope(path, meta, ckpt.serialize_state(state))
    monkeypatch.undo()  # whatever codec the environment really has
    got_meta, blob = ckpt.load_envelope(path)
    assert got_meta == meta
    back = ckpt.deserialize_state(blob, like=state)
    np.testing.assert_array_equal(np.asarray(back["x"]),
                                  np.asarray(state["x"]))


def test_train_state_roundtrip(tmp_path):
    cfg = get_config("smollm_135m").smoke()
    params, opt = TS.init_train_state(cfg, jax.random.key(0),
                                      dtype=jnp.float32)
    path = tmp_path / "ck" / "state.ckpt"
    ckpt.save_train_state(path, params, opt, step=7, data_cursor=1234)
    p2, o2, step, cursor = ckpt.load_train_state(path, params, opt)
    assert step == 7 and cursor == 1234
    for a, b in zip(jax.tree.leaves(params), jax.tree.leaves(p2)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
