"""Elastic checkpoints: pause on a P-way layout, resume on P' (DESIGN.md §9).

The v3 envelope records per-partition carries + cursors; ``Session.resume
(partitions=P')`` merges (P'|P) or splits (P|P') the carries with the
round-robin chunk interleave from ``data.source.repartition`` and
re-derives the schedule, so the resumed scan continues over exactly the
not-yet-scanned suffix.  Finals match the uninterrupted run — bitwise for
count-like monoids (integer-valued f32 sums are associativity-proof),
allclose otherwise (merge-association order changes).

Also here: the named-ValueError validation contract of ``resume`` (every
plan mismatch is reported by field, before any device work) and the v2→v3
envelope compatibility rule.
"""
import subprocess
import sys
import textwrap
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.checkpoint import ckpt
from repro.core import gla, randomize
from repro.core import scan as SC
from repro.core import session as S
from repro.data import source as DSRC
from repro.data import tpch

SRC = Path(__file__).resolve().parents[1] / "src"
ROWS = 8192
PARTS = 4
ROUNDS = 4  # C=8 chunks/partition at chunk_len=256 -> 2 chunks per round


@pytest.fixture(scope="module")
def shards():
    cols = tpch.generate_lineitem(ROWS, seed=21)
    parts = randomize.randomize_global(
        {k: jnp.asarray(v) for k, v in cols.items()}, jax.random.key(4),
        PARTS)
    return randomize.pack_partitions(parts, chunk_len=256)


def _q6():
    return gla.make_sum_gla(tpch.q6_func, tpch.q6_cond(tpch.Q6_LOW_WINDOW),
                            d_total=float(ROWS))


def _count():
    """COUNT(*) — an integer-valued monoid whose f32 partial sums are
    exact, so any merge association yields bitwise-equal finals."""
    def one(c):
        return jnp.ones_like(c["quantity"])

    return gla.make_sum_gla(one, one, d_total=float(ROWS))


def _drive(sess):
    while not sess.done:
        sess.step()
    return sess.result()


def _tobytes(tree):
    return [np.asarray(x).tobytes() for x in jax.tree.leaves(tree)]


def _ref_final(g, shards):
    return np.asarray(_drive(S.Session(g, shards, rounds=ROUNDS)).final)


# ---------------------------------------------------------------------------
# the repartitioned source view
# ---------------------------------------------------------------------------

def test_repartition_view_data_roundtrip(shards):
    src = DSRC.as_source(shards)
    for pnew in (2, 8):
        view = DSRC.repartition(shards, pnew)
        assert view.spec.P == pnew
        assert view.spec.P * view.spec.C == src.spec.P * src.spec.C
        # same bag of chunks: per-chunk tuple counts are a permutation
        assert (np.sort(view.mask_chunk_sums(), axis=None).tolist()
                == np.sort(src.mask_chunk_sums(), axis=None).tolist())
        # and mapping back is the identity on the data itself
        back = DSRC.RepartitionedSource(view, src.spec.P)
        a = back.slice_cols(0, src.spec.C)
        b = src.slice_cols(0, src.spec.C)
        for k in b:
            np.testing.assert_array_equal(np.asarray(a[k]), np.asarray(b[k]))


def test_repartition_preserves_scanned_prefix(shards):
    """The round-robin interleave keeps a scanned chunk-prefix a prefix:
    old chunks [0, c) hold exactly the rows of new chunks [0, c*k) under a
    split (and [0, c/k) under a merge) — the invariant that lets a cursor
    transfer across layouts by pure arithmetic."""
    src = DSRC.as_source(shards)
    half = src.spec.C // 2
    olds = src.slice_cols(0, half)
    split = DSRC.repartition(shards, 8).slice_cols(0, half // 2)
    merged = DSRC.repartition(shards, 2).slice_cols(0, half * 2)
    for k in olds:
        want = np.sort(np.asarray(olds[k]), axis=None)
        for got in (split[k], merged[k]):
            np.testing.assert_array_equal(
                np.sort(np.asarray(got), axis=None), want)


def test_repartition_validates():
    src = DSRC.as_source({"_mask": jnp.ones((4, 6, 8), jnp.float32)})
    with pytest.raises(ValueError, match="divide"):
        DSRC.repartition(src, 3)
    with pytest.raises(ValueError, match="chunk count"):
        DSRC.RepartitionedSource(src, 16)  # split factor 4 but C=6: 4 !| 6
    assert DSRC.repartition(src, 4) is src


# ---------------------------------------------------------------------------
# elastic resume, vmapped engine
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("pnew", [2, 1, 8])
def test_resume_on_new_partition_count(shards, pnew, tmp_path):
    g = _q6()
    ref = _ref_final(g, shards)
    sess = S.Session(g, shards, rounds=ROUNDS)
    sess.step()
    sess.step()
    ck = tmp_path / "elastic.ckpt"
    sess.pause(ck)
    back = S.Session.resume(ck, g, shards, partitions=pnew)
    assert back._P == pnew and back.steps_taken == 2
    np.testing.assert_allclose(np.asarray(_drive(back).final), ref,
                               rtol=1e-6)


@pytest.mark.parametrize("pnew", [2, 8])
def test_resume_count_monoid_bitwise(shards, pnew, tmp_path):
    g = _count()
    ref = _ref_final(g, shards)
    sess = S.Session(g, shards, rounds=ROUNDS)
    sess.step()
    ck = tmp_path / "count.ckpt"
    sess.pause(ck)
    final = np.asarray(_drive(S.Session.resume(ck, g, shards,
                                               partitions=pnew)).final)
    assert final.tobytes() == ref.tobytes()


def test_resume_roundtrip_p_pprime_p(shards, tmp_path):
    """4 -> P' -> 4: pause the elastically-resumed session again and come
    back to the original layout; the final still matches."""
    g = _q6()
    ref = _ref_final(g, shards)
    for pnew in (2, 8):
        sess = S.Session(g, shards, rounds=ROUNDS)
        sess.step()
        a = tmp_path / f"a{pnew}.ckpt"
        sess.pause(a)
        mid = S.Session.resume(a, g, shards, partitions=pnew)
        mid.step()
        b = tmp_path / f"b{pnew}.ckpt"
        mid.pause(b)
        back = S.Session.resume(b, g, shards, partitions=PARTS)
        assert back._P == PARTS and back.steps_taken == 2
        np.testing.assert_allclose(np.asarray(_drive(back).final), ref,
                                   rtol=1e-6)


def test_resume_elastic_kernel_group(shards, tmp_path):
    """The carry algebra holds for kernel running-sum carries too."""
    g = gla.make_groupby_gla(
        tpch.q1_func, tpch.q1_cond, tpch.q1_group_small, num_groups=4,
        d_total=float(ROWS), num_aggs=4)
    ref = np.asarray(
        _drive(S.Session(g, shards, rounds=ROUNDS, emit="kernel")).final)
    sess = S.Session(g, shards, rounds=ROUNDS, emit="kernel")
    sess.step()
    ck = tmp_path / "kern.ckpt"
    sess.pause(ck)
    back = S.Session.resume(ck, g, shards, partitions=2)
    np.testing.assert_allclose(np.asarray(_drive(back).final), ref,
                               rtol=1e-5)


def test_resume_with_fault_record(shards, tmp_path):
    """A v3 checkpoint carries the failure record and estimator family:
    resuming restores the FaultPolicy without the caller re-supplying it,
    and the finished run matches the uninterrupted chaos run."""
    g = _q6()
    ref = _drive(S.Session(g, shards, rounds=ROUNDS,
                           fault=S.FaultPolicy("single", fail_at={2: 1})))
    sess = S.Session(g, shards, rounds=ROUNDS,
                     fault=S.FaultPolicy("single", fail_at={2: 1}))
    sess.step()
    sess.step()
    ck = tmp_path / "fault.ckpt"
    sess.pause(ck)
    back = S.Session.resume(ck, g, shards)
    assert back._policy is not None and back._policy.estimator == "single"
    assert back._fail_at == {2: 1}
    res = _drive(back)
    assert _tobytes(res.final) == _tobytes(ref.final)
    assert _tobytes(res.estimates) == _tobytes(ref.estimates)


# ---------------------------------------------------------------------------
# validation: every mismatch is a named ValueError before device work
# ---------------------------------------------------------------------------

@pytest.fixture()
def paused(shards, tmp_path):
    sess = S.Session(_q6(), shards, rounds=ROUNDS)
    sess.step()
    ck = tmp_path / "v.ckpt"
    sess.pause(ck)
    return ck


def test_resume_names_mismatched_field(paused, shards):
    g = _q6()
    # estimator family changes the gla name -> named before any state work
    gm = gla.make_sum_gla(tpch.q6_func, tpch.q6_cond(tpch.Q6_LOW_WINDOW),
                          d_total=float(ROWS), estimator="multiple")
    with pytest.raises(ValueError, match="checkpoint mismatch: gla"):
        S.Session.resume(paused, gm, shards)
    # partition-count mismatch of the supplied data: named P error, not a
    # shape error from deserialize_state / normalize_plan (3-way data is
    # not repartition-compatible with the 4-way checkpoint)
    other = jax.tree.map(lambda x: x[:3], shards)
    with pytest.raises(ValueError, match="checkpoint mismatch: P"):
        S.Session.resume(paused, g, other)
    # 2-way data IS repartition-compatible with P=4 — the wrap is
    # attempted, and the surviving disagreement (C) is the one named
    half = jax.tree.map(lambda x: x[:2], shards)
    with pytest.raises(ValueError, match="checkpoint mismatch: C"):
        S.Session.resume(paused, g, half)
    wider = jax.tree.map(lambda x: jnp.concatenate([x, x], axis=2), shards)
    with pytest.raises(ValueError, match="checkpoint mismatch: L"):
        S.Session.resume(paused, g, wider)


def test_resume_rounds_consistency_checked(paused, shards):
    meta, blob = ckpt.load_envelope(paused)
    meta["rounds"] = 7  # no longer agrees with the stored schedule
    ckpt.save_envelope(paused, meta, blob)
    with pytest.raises(ValueError, match="rounds 7"):
        S.Session.resume(paused, _q6(), shards)


def test_resume_fault_family_mismatch(shards, tmp_path):
    sess = S.Session(_q6(), shards, rounds=ROUNDS,
                     fault=S.FaultPolicy("single"))
    sess.step()
    ck = tmp_path / "fam.ckpt"
    sess.pause(ck)
    with pytest.raises(ValueError, match="fault estimator family"):
        S.Session.resume(ck, _q6(), shards,
                         fault=S.FaultPolicy("synchronized"))


def test_elastic_resume_rejections(paused, shards, tmp_path):
    g = _q6()
    with pytest.raises(ValueError, match="repartition 4 -> 3"):
        S.Session.resume(paused, g, shards, partitions=3)
    # a checkpoint with recorded failures cannot be re-laid-out: the dead
    # partition's carry is lost and cannot be merged into a new layout
    sess = S.Session(g, shards, rounds=ROUNDS,
                     fault=S.FaultPolicy("single", fail_at={1: 0}))
    sess.step()
    ck = tmp_path / "dead.ckpt"
    sess.pause(ck)
    with pytest.raises(ValueError, match="all-alive"):
        S.Session.resume(ck, g, shards, partitions=2)


def test_v3_envelope_format(shards, tmp_path):
    sess = S.Session(_q6(), shards, rounds=ROUNDS,
                     fault=S.FaultPolicy("single", fail_at={2: 3}))
    sess.step()
    ck = tmp_path / "v3.ckpt"
    sess.pause(ck)
    meta, _ = ckpt.load_envelope(ck)
    assert meta["version"] == 3
    # cursors: chunk index each partition has consumed up to (1 round of a
    # C=8 / 4-round uniform schedule = 2 chunks)
    assert meta["cursors"] == [2] * PARTS
    assert meta["fail_at"] == [[2, 3]]
    assert meta["fault_estimator"] == "single"


def test_v2_envelope_still_readable(paused, shards):
    """Compatibility rule: v3 readers accept v2 envelopes (the v2 fields
    are a subset); unknown/newer versions are rejected by number."""
    meta, blob = ckpt.load_envelope(paused)
    for key in ("cursors", "fail_at", "fault_estimator"):
        del meta[key]
    meta["version"] = 2
    ckpt.save_envelope(paused, meta, blob)
    back = S.Session.resume(paused, _q6(), shards)
    assert back.steps_taken == 1 and back._policy is None
    meta["version"] = 4
    ckpt.save_envelope(paused, meta, blob)
    with pytest.raises(ValueError, match="unsupported session checkpoint"):
        S.Session.resume(paused, _q6(), shards)


# ---------------------------------------------------------------------------
# carry algebra properties
# ---------------------------------------------------------------------------

@settings(max_examples=20)
@given(st.integers(min_value=0, max_value=3),
       st.lists(st.floats(min_value=-1e6, max_value=1e6),
                min_size=8, max_size=8))
def test_carry_split_merge_roundtrip_identity(kpow, vals):
    """P -> P*k -> P is the identity on the carry pytree: a split places
    each parent carry whole on one child (zeros elsewhere), and the merge
    re-adds exactly x + 0.  That sum is bit-exact for every float except
    -0.0 (IEEE canonicalizes -0.0 + 0.0 to +0.0), so equality is exact up
    to the sign of zeros — arithmetically indistinguishable for
    aggregation."""
    k = 2 ** kpow
    x = {"a": jnp.asarray(np.asarray(vals, np.float32)),
         "b": jnp.asarray(np.asarray(vals, np.float32).reshape(8, 1)
                          * np.arange(3.0, dtype=np.float32))}
    rt = SC.merge_carries(SC.split_carries(x, k), k)
    for got, want in zip(jax.tree.leaves(rt), jax.tree.leaves(x)):
        got, want = np.asarray(got), np.asarray(want)
        assert np.array_equal(got, want)  # -0.0 == 0.0: sign-blind
        nz = want != 0.0
        assert got[nz].tobytes() == want[nz].tobytes()  # bitwise elsewhere


@settings(max_examples=20)
@given(st.lists(st.floats(min_value=-1e6, max_value=1e6),
                min_size=8, max_size=8))
def test_carry_merge_then_split_preserves_observable(vals):
    """P -> P/k -> P cannot restore per-partition placement (carries do
    not unsum), but additive merges cannot observe placement: merging the
    re-split carry reproduces the merged carry bitwise."""
    x = {"a": jnp.asarray(np.asarray(vals, np.float32))}
    down = SC.merge_carries(x, 2)
    again = SC.merge_carries(SC.split_carries(down, 2), 2)
    for got, want in zip(jax.tree.leaves(again), jax.tree.leaves(down)):
        assert np.asarray(got).tobytes() == np.asarray(want).tobytes()


# ---------------------------------------------------------------------------
# mesh elasticity: 8-way mesh checkpoint resumed on a 4-way mesh
# ---------------------------------------------------------------------------

needs8 = pytest.mark.skipif(jax.device_count() < 8,
                            reason="needs 8 devices (fake-device CI lane)")


@needs8
def test_mesh_checkpoint_resumes_on_smaller_mesh(shards, tmp_path):
    """ISSUE acceptance: a checkpoint written on an 8-way mesh resumes on
    a 4-way mesh with finals equal to the uninterrupted 8-way run —
    bitwise for the count monoid, allclose for the float sum."""
    from jax.sharding import Mesh

    cols = tpch.generate_lineitem(ROWS, seed=21)
    parts = randomize.randomize_global(
        {k: jnp.asarray(v) for k, v in cols.items()}, jax.random.key(4), 8)
    shards8 = randomize.pack_partitions(parts, chunk_len=256)
    mesh8 = Mesh(np.array(jax.devices()[:8]), ("data",))
    mesh4 = Mesh(np.array(jax.devices()[:4]), ("data",))
    for g, exact in ((_count(), True), (_q6(), False)):
        ref = np.asarray(
            _drive(S.Session(g, shards8, rounds=ROUNDS, mesh=mesh8)).final)
        sess = S.Session(g, shards8, rounds=ROUNDS, mesh=mesh8)
        sess.step()
        sess.step()
        ck = tmp_path / f"mesh-{g.name}-{exact}.ckpt"
        sess.pause(ck)
        back = S.Session.resume(ck, g, shards8, partitions=4, mesh=mesh4)
        final = np.asarray(_drive(back).final)
        if exact:
            assert final.tobytes() == ref.tobytes()
        else:
            np.testing.assert_allclose(final, ref, rtol=1e-5)


@pytest.mark.slow
def test_elastic_8_to_4_to_8_subprocess():
    """Full fleet-resize cycle on fake devices: scan on an 8-way mesh,
    shrink to 4, grow back to 8, finals equal the uninterrupted run."""
    code = textwrap.dedent("""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
        import sys; sys.path.insert(0, %r)
        import numpy as np, jax, jax.numpy as jnp
        from jax.sharding import Mesh
        from repro.core import gla, randomize
        from repro.core import session as S
        from repro.data import tpch
        rows = 8192
        cols = tpch.generate_lineitem(rows, seed=21)
        parts = randomize.randomize_global(
            {k: jnp.asarray(v) for k, v in cols.items()},
            jax.random.key(4), 8)
        shards = randomize.pack_partitions(parts, chunk_len=256)
        mesh8 = Mesh(np.array(jax.devices()[:8]), ("data",))
        mesh4 = Mesh(np.array(jax.devices()[:4]), ("data",))
        g = gla.make_sum_gla(tpch.q6_func, tpch.q6_cond(tpch.Q6_LOW_WINDOW),
                             d_total=float(rows))
        def drive(s):
            while not s.done:
                s.step()
            return s.result()
        ref = drive(S.Session(g, shards, rounds=4, mesh=mesh8))
        sess = S.Session(g, shards, rounds=4, mesh=mesh8)
        sess.step()
        sess.pause("/tmp/elastic-a.ckpt")
        mid = S.Session.resume("/tmp/elastic-a.ckpt", g, shards,
                               partitions=4, mesh=mesh4)
        mid.step()
        mid.pause("/tmp/elastic-b.ckpt")
        back = S.Session.resume("/tmp/elastic-b.ckpt", g, shards,
                                partitions=8, mesh=mesh8)
        assert back.steps_taken == 2
        res = drive(back)
        np.testing.assert_allclose(np.asarray(res.final),
                                   np.asarray(ref.final), rtol=1e-5)
        print("OK")
    """ % str(SRC))
    r = subprocess.run([sys.executable, "-c", code], capture_output=True,
                       text=True, timeout=600)
    assert r.returncode == 0, r.stderr[-3000:]
    assert "OK" in r.stdout
