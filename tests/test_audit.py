"""Invariant auditor (repro/analysis/audit.py): pure checks on crafted
HLO, report mechanics, and audit_plan end-to-end on both engines."""
import jax
import numpy as np
import pytest

from repro.analysis import audit
from repro.core import engine
from repro.core.session import Session

ROWS = 8_000
ROUNDS = 4


@pytest.fixture(scope="module")
def shards():
    return audit._smoke_data(ROWS, 2, 128, ROUNDS)


@pytest.fixture(scope="module")
def plans():
    return {name: (q, emit) for name, q, emit in audit._smoke_plans(ROWS)}


# ---------------------------------------------------------------------------
# pure checks over crafted HLO text
# ---------------------------------------------------------------------------

_LOOPY = """HloModule m

%cond (p: f32[4]) -> pred[] {
  %p = f32[4]{0} parameter(0)
  ROOT %lt = pred[] constant(true)
}

%body (q: f32[4]) -> f32[4] {
  %q = f32[4]{0} parameter(0)
  ROOT %add = f32[4]{0} add(%q, %q)
}

ENTRY %main (arg: f32[4]) -> f32[4] {
  %arg = f32[4]{0} parameter(0)
  %w1 = f32[4]{0} while(%arg), condition=%cond, body=%body, backend_config={"known_trip_count":{"n":"12"}}
  ROOT %w2 = f32[4]{0} while(%w1), condition=%cond, body=%body, backend_config={"known_trip_count":{"n":"7"}}
}
"""


def test_chunk_loop_count_discriminates_by_trip():
    assert audit.chunk_loop_count(_LOOPY, 12) == 1
    assert audit.chunk_loop_count(_LOOPY, 7) == 1
    assert audit.chunk_loop_count(_LOOPY, 99) == 0


def test_check_one_chunk_pass_pass_and_fail():
    ok = audit.check_one_chunk_pass(_LOOPY, chunk_trip=12)
    assert ok.passed and ok.data["chunk_loops"] == 1
    bad = audit.check_one_chunk_pass(_LOOPY, chunk_trip=99)
    assert bad.failed
    assert bad.data["trips"] == [12, 7]


_SMALL_ENTRY = """HloModule m

ENTRY %main (a: f32[64,16], b: f32[64,16]) -> f32[64,16] {
  %a = f32[64,16]{1,0} parameter(0)
  %b = f32[64,16]{1,0} parameter(1)
  ROOT %add = f32[64,16]{1,0} add(%a, %b)
}
"""
_SMALL_BYTES = 2 * 64 * 16 * 4


def test_check_slice_footprint_bounds():
    ok = audit.check_slice_footprint(
        _SMALL_ENTRY, slice_bytes=_SMALL_BYTES, floor_bytes=64 * 16 * 4)
    assert ok.passed and ok.data["entry_param_bytes"] == _SMALL_BYTES
    # floor: params below one live column means the parser degraded
    assert audit.check_slice_footprint(
        _SMALL_ENTRY, slice_bytes=_SMALL_BYTES,
        floor_bytes=10 * _SMALL_BYTES).failed
    # ceiling: O(slice) violated when slice budget is tiny
    tiny = audit.check_slice_footprint(
        _SMALL_ENTRY, slice_bytes=_SMALL_BYTES,
        floor_bytes=4, dataset_bytes=_SMALL_BYTES * 8)
    assert tiny.failed  # got == dataset/8 boundary: not out-of-core


def test_check_kernel_dispatch_counts_and_skips():
    res = audit.check_kernel_dispatch(_LOOPY, dispatches=2, backend="cpu")
    assert res.passed and res.data["while_ops"] == 2
    assert audit.check_kernel_dispatch(
        _LOOPY, dispatches=3, backend="cpu").failed
    assert audit.check_kernel_dispatch(
        _LOOPY, dispatches=2, backend="tpu").skipped


_PSUM_STEP = """HloModule m

ENTRY %main (a: f32[8]) -> f32[8] {
  %a = f32[8]{0} parameter(0)
  %ar1 = f32[8]{0} all-reduce(%a), replica_groups={}, to_apply=%sum
  ROOT %ar2 = f32[8]{0} all-reduce(%ar1), replica_groups={}, to_apply=%sum
}

%sum (x: f32[], y: f32[]) -> f32[] {
  %x = f32[] parameter(0)
  %y = f32[] parameter(1)
  ROOT %s = f32[] add(%x, %y)
}
"""

_PSUM_IN_LOOP = """HloModule m

%cond (p: f32[8]) -> pred[] {
  %p = f32[8]{0} parameter(0)
  ROOT %lt = pred[] constant(true)
}

%body (q: f32[8]) -> f32[8] {
  %q = f32[8]{0} parameter(0)
  ROOT %ar = f32[8]{0} all-reduce(%q), replica_groups={}, to_apply=%sum
}

%sum (x: f32[], y: f32[]) -> f32[] {
  %x = f32[] parameter(0)
  %y = f32[] parameter(1)
  ROOT %s = f32[] add(%x, %y)
}

ENTRY %main (a: f32[8]) -> f32[8] {
  %a = f32[8]{0} parameter(0)
  ROOT %w = f32[8]{0} while(%a), condition=%cond, body=%body, backend_config={"known_trip_count":{"n":"16"}}
}
"""


def test_check_collectives_flat_vs_loop():
    ok = audit.check_collectives(_PSUM_STEP, max_reductions=4)
    assert ok.passed and ok.data["all_reduce_ops"] == 2
    # more all-reduces than merged-state leaves: duplicated merges
    assert audit.check_collectives(_PSUM_STEP, max_reductions=1).failed
    # an all-reduce inside the chunk loop is O(C) barrier traffic — the
    # trip-scaled count diverges from the flat count and must fail even
    # though the flat count (1) looks fine
    loop = audit.check_collectives(_PSUM_IN_LOOP, max_reductions=4)
    assert loop.failed
    assert "loop" in loop.detail


def test_check_dtype_discipline():
    ok = audit.check_dtype_discipline(
        {"states": {"s": jax.ShapeDtypeStruct((4,), np.float32)}})
    assert ok.passed
    bad = audit.check_dtype_discipline(
        {"states": {"s": jax.ShapeDtypeStruct((4,), np.float16)}})
    assert bad.failed and "states" in bad.detail
    # integer leaves (group ids, counts) are not a downcast
    assert audit.check_dtype_discipline(
        {"views": {"g": jax.ShapeDtypeStruct((4,), np.int8)}}).passed


# ---------------------------------------------------------------------------
# report mechanics
# ---------------------------------------------------------------------------

def test_report_mechanics():
    good = audit.CheckResult("a", "pass", "fine")
    bad = audit.CheckResult("b", "fail", "broken")
    skip = audit.CheckResult("c", "skip", "n/a")
    rep = audit.AuditReport(plan={"gla": "g"}, results=(good, skip))
    assert rep.ok and rep.failures == ()
    rep.raise_for_failures()  # no failures: no raise
    assert rep.result("a").passed
    with pytest.raises(KeyError):
        rep.result("zzz")
    rep2 = audit.AuditReport(plan={"gla": "g"}, results=(good, bad))
    assert not rep2.ok
    with pytest.raises(audit.AuditError, match="broken"):
        rep2.raise_for_failures()
    assert "FAIL" in rep2.summary() and "broken" in rep2.summary()


# ---------------------------------------------------------------------------
# audit_plan end-to-end (vmapped; the sharded lane runs in CI multidevice)
# ---------------------------------------------------------------------------

def test_audit_plan_certifies_scan_plan(shards, plans):
    q6, emit = plans["q6"]
    rep = engine.audit_plan(q6, shards, rounds=ROUNDS, emit=emit)
    assert rep.ok, rep.summary()
    assert rep.result("one_chunk_pass").passed
    assert rep.result("o_slice_footprint").passed
    assert rep.result("single_kernel_dispatch").skipped  # not a kernel plan
    assert rep.result("one_collective_per_round").skipped  # no mesh
    assert rep.result("dtype_discipline").passed


def test_audit_plan_certifies_kernel_bundle(shards, plans):
    bundle, emit = plans["bundle"]
    rep = engine.audit_plan(bundle, shards, rounds=ROUNDS, emit=emit)
    assert rep.ok, rep.summary()
    # every member publishes FusedSpec, so the plan takes the fused path:
    # fused_single_dispatch certifies it and the legacy while-census skips
    assert rep.result("fused_single_dispatch").passed
    assert rep.result("single_kernel_dispatch").skipped
    assert rep.result("one_chunk_pass").skipped  # kernel plans do not scan


def test_audit_plan_unknown_check_raises(shards, plans):
    q6, emit = plans["q6"]
    with pytest.raises(ValueError, match="unknown audit check"):
        engine.audit_plan(q6, shards, rounds=ROUNDS, emit=emit,
                          checks=("one_chunk_pass", "nope"))


def test_audit_plan_no_recompile_dynamic(shards, plans):
    q6, emit = plans["q6"]
    rep = engine.audit_plan(q6, shards, rounds=ROUNDS, emit=emit,
                            checks=("no_recompile_across_rounds",))
    res = rep.result("no_recompile_across_rounds")
    assert not res.failed, res.detail
    if res.passed:
        assert res.data["cache_miss_delta"] <= res.data["budget"]


def test_session_audit_kwarg(shards, plans):
    q6, emit = plans["q6"]
    sess = Session(q6, shards, rounds=ROUNDS, emit=emit, audit=True)
    assert sess.audit_report is not None and sess.audit_report.ok
    # the session still runs normally after the audit
    while not sess.done:
        sess.step()
    assert np.isfinite(float(sess.result().final))
    sub = Session(q6, shards, rounds=ROUNDS, emit=emit,
                  audit=("one_chunk_pass", "dtype_discipline"))
    assert [r.name for r in sub.audit_report.results] == [
        "one_chunk_pass", "dtype_discipline"]
    off = Session(q6, shards, rounds=ROUNDS, emit=emit)
    assert off.audit_report is None


@pytest.mark.skipif(jax.device_count() < 2,
                    reason="sharded audit needs >1 device")
def test_audit_plan_sharded_collectives(plans):
    from repro.launch.mesh import make_test_mesh

    mesh = make_test_mesh(jax.device_count())
    sh = audit._smoke_data(ROWS, int(mesh.devices.size), 128, ROUNDS)
    q6, emit = plans["q6"]
    rep = engine.audit_plan(q6, sh, rounds=ROUNDS, emit=emit, mesh=mesh)
    assert rep.ok, rep.summary()
    coll = rep.result("one_collective_per_round")
    assert coll.passed
    assert coll.data["all_reduce_ops"] >= 1
