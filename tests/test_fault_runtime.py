"""Chaos lane: §4.6 failure semantics enforced on LIVE sessions.

tests/test_fault.py covers the fused post-processing path
(``dist.fault.run_with_failures``); this file kills partitions while a
session is actually running — injected through ``FaultPolicy.fail_at`` or
detected from a dying streaming source (``fault.FailingSource``) — and
checks the runtime enforces exactly what ``dist/fault.py`` documents:
``single`` survives with finite variance-floored bounds, ``multiple`` is
poisoned to (-inf, +inf) from the failure round, ``synchronized`` freezes
at the last pre-failure round, and no NaN ever reaches a QueryResult.

The kill-at-round matrix sweeps {scan, group-kernel, bundle} x estimator
x {first, mid, last} fail rounds on the vmapped engine, plus sharded
variants on an 8-device mesh.  The property section pins the estimator
invariants the chaos assertions rely on (hypothesis, or the fixed-seed
shim from conftest.py).
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.core import estimators as E
from repro.core import gla, randomize
from repro.core import session as S
from repro.core.uda import Estimate
from repro.data import tpch
from repro.dist import fault

ROWS = 8192
PARTS = 4
ROUNDS = 4  # C=8 chunks/partition at chunk_len=256 -> 2 chunks per round
FAIL_ROUNDS = (0, 2, 3)  # first, mid, last


def _sum(estimator, window=(0, 1460)):
    def func(c):
        return c["quantity"]

    def cond(c):
        sd = c["shipdate"]
        return ((sd >= window[0]) & (sd < window[1])).astype(jnp.float32)

    return gla.make_sum_gla(func, cond, d_total=float(ROWS),
                            estimator=estimator)


def _group(estimator):
    return gla.make_groupby_gla(
        tpch.q1_func, tpch.q1_cond, tpch.q1_group_small, num_groups=4,
        d_total=float(ROWS), num_aggs=4, estimator=estimator)


def _bundle(estimator):
    return gla.GLABundle([_sum(estimator), _sum(estimator, window=(0, 400))])


# built once at module scope: the session step jits statically on the GLA
# object, so every (path, estimator) cell compiles exactly once across the
# whole kill-at-round matrix.  The "multiple" model publishes no kernel
# contract (MultState), so the kernel paths cover {single, synchronized} —
# exactly the families whose state is SumState-shaped.
_GLAS = {("scan", e): _sum(e)
         for e in ("single", "multiple", "synchronized")}
_GLAS.update({("kernel_group", e): _group(e)
              for e in ("single", "synchronized")})
_GLAS.update({("kernel_bundle", e): _bundle(e)
              for e in ("single", "synchronized")})
CASES = sorted(_GLAS)


@pytest.fixture(scope="module")
def shards():
    cols = tpch.generate_lineitem(ROWS, seed=21)
    parts = randomize.randomize_global(
        {k: jnp.asarray(v) for k, v in cols.items()}, jax.random.key(4),
        PARTS)
    return randomize.pack_partitions(parts, chunk_len=256)


def _drive(sess):
    while not sess.done:
        sess.step()
    return sess.result()


@pytest.fixture(scope="module")
def baselines(shards):
    """No-failure incremental runs, one per matrix cell.  Pre-failure
    rounds of a chaos run must match these bitwise: before the first
    failure the session executes the identical all-alive program."""
    out = {}
    for (path, est), g in _GLAS.items():
        emit = "chunk" if path == "scan" else "kernel"
        out[(path, est)] = _drive(S.Session(g, shards, rounds=ROUNDS,
                                            emit=emit))
    return out


def _members(est):
    if isinstance(est, Estimate):
        return (est,)
    return tuple(e for e in est if e is not None)


def _rows(est):
    return (np.asarray(est.estimate, np.float64),
            np.asarray(est.lower, np.float64),
            np.asarray(est.upper, np.float64))


def _assert_no_nan(res):
    for part in (res.final, res.snapshots, res.estimates):
        for leaf in jax.tree.leaves(part):
            assert not np.any(np.isnan(np.asarray(leaf)))


def _check_single(em, eb, fr):
    x, lo, hi = _rows(em)
    xb, lob, hib = _rows(eb)
    # survives: finite variance-floored bounds at every round
    assert np.all(np.isfinite(lo)) and np.all(np.isfinite(hi))
    assert np.all(np.isfinite(x))
    # pre-failure rounds ran the identical all-alive program
    np.testing.assert_array_equal(lo[:fr], lob[:fr])
    np.testing.assert_array_equal(hi[:fr], hib[:fr])
    # the variance floor: |S| is capped below |D|, so the final round's
    # interval is strictly wider than the uninterrupted run's
    assert np.max(hi[-1] - lo[-1]) > np.max(hib[-1] - lob[-1])


def _check_multiple(em, eb, fr):
    x, lo, hi = _rows(em)
    _, lob, hib = _rows(eb)
    # poisoned from the failure round on, untouched before it
    assert np.all(np.isneginf(lo[fr:])) and np.all(np.isposinf(hi[fr:]))
    np.testing.assert_array_equal(lo[:fr], lob[:fr])
    np.testing.assert_array_equal(hi[:fr], hib[:fr])
    assert np.all(np.isfinite(x[:fr] if fr else x))


def _check_sync(em, eb, fr):
    x, lo, hi = _rows(em)
    xb, lob, hib = _rows(eb)
    if fr == 0:
        # nothing preceded the failure: no snapshot ever clears the
        # barrier, bounds are infinite from the start
        assert np.all(np.isneginf(lo)) and np.all(np.isposinf(hi))
        return
    np.testing.assert_array_equal(x[:fr], xb[:fr])
    np.testing.assert_array_equal(lo[:fr], lob[:fr])
    np.testing.assert_array_equal(hi[:fr], hib[:fr])
    for r in range(fr, x.shape[0]):  # frozen at the last pre-failure round
        np.testing.assert_array_equal(x[r], x[fr - 1])
        np.testing.assert_array_equal(lo[r], lo[fr - 1])
        np.testing.assert_array_equal(hi[r], hi[fr - 1])


_CHECKS = {"single": _check_single, "multiple": _check_multiple,
           "synchronized": _check_sync}


@pytest.mark.parametrize("fail_round", FAIL_ROUNDS)
@pytest.mark.parametrize("path,estimator", CASES)
def test_kill_at_round(shards, baselines, path, estimator, fail_round):
    emit = "chunk" if path == "scan" else "kernel"
    sess = S.Session(
        _GLAS[(path, estimator)], shards, rounds=ROUNDS, emit=emit,
        fault=S.FaultPolicy(estimator, fail_at={2: fail_round}))
    res = _drive(sess)
    _assert_no_nan(res)
    base = baselines[(path, estimator)]
    got = _members(res.estimates)
    want = _members(base.estimates)
    assert len(got) == len(want) > 0
    for em, eb in zip(got, want):
        _CHECKS[estimator](em, eb, fail_round)


def test_final_covers_surviving_data_only(shards):
    """The partial final equals the fused engine's: the dead partition's
    data (including what it scanned before dying) is excluded."""
    g = _GLAS[("scan", "single")]
    sess = S.Session(g, shards, rounds=ROUNDS,
                     fault=S.FaultPolicy("single", fail_at={2: 2}))
    res = _drive(sess)
    ref = fault.run_with_failures(g, shards, rounds=ROUNDS,
                                  fail_at={2: 2}, estimator="single")
    np.testing.assert_allclose(np.asarray(res.final), np.asarray(ref.final),
                               rtol=1e-6)


def test_fused_policy_matches_run_with_failures(shards):
    """run() with no stopping rule executes the fused program; an attached
    FaultPolicy ships the same [R, P] schedule run_with_failures builds and
    post-processes identically."""
    for est in ("single", "multiple", "synchronized"):
        g = _GLAS[("scan", est)]
        sess = S.Session(g, shards, rounds=ROUNDS,
                         fault=S.FaultPolicy(est, fail_at={1: 2}))
        a = sess.run()
        b = fault.run_with_failures(g, shards, rounds=ROUNDS,
                                    fail_at={1: 2}, estimator=est)
        np.testing.assert_array_equal(np.asarray(a.estimates.lower),
                                      np.asarray(b.estimates.lower))
        np.testing.assert_array_equal(np.asarray(a.estimates.upper),
                                      np.asarray(b.estimates.upper))
        np.testing.assert_allclose(np.asarray(a.final),
                                   np.asarray(b.final), rtol=1e-6)


# ---------------------------------------------------------------------------
# detection: the streaming path loses a partition for real
# ---------------------------------------------------------------------------

def test_streaming_loss_detected_and_survived(shards):
    """A FailingSource raises PartitionLostError from the prefetcher's
    worker thread mid-scan; the session records the failure round, retries
    against the survivors, and finishes with finite single-model bounds
    and the same final as an injected failure at that round."""
    g = _GLAS[("scan", "single")]
    src = fault.FailingSource(shards, fail_chunk={2: 4})  # dies in round 2
    sess = S.Session(g, src, rounds=ROUNDS, fault=S.FaultPolicy("single"))
    res = _drive(sess)
    assert sess._fail_at == {2: 2}
    _assert_no_nan(res)
    lo = np.asarray(res.estimates.lower)
    hi = np.asarray(res.estimates.upper)
    assert np.all(np.isfinite(lo)) and np.all(np.isfinite(hi))
    inj = _drive(S.Session(g, shards, rounds=ROUNDS,
                           fault=S.FaultPolicy("single", fail_at={2: 2})))
    np.testing.assert_allclose(np.asarray(res.final),
                               np.asarray(inj.final), rtol=1e-6)


def test_streaming_loss_without_policy_is_fatal(shards):
    src = fault.FailingSource(shards, fail_chunk={1: 0})
    sess = S.Session(_GLAS[("scan", "single")], src, rounds=ROUNDS)
    with pytest.raises(fault.PartitionLostError, match=r"\[1\]"):
        sess.step()


def test_policy_api_validation(shards):
    g = _GLAS[("scan", "single")]
    with pytest.raises(ValueError, match="unknown estimator model"):
        S.FaultPolicy("stratified")
    with pytest.raises(ValueError, match=">= 0"):
        S.FaultPolicy("single", fail_at={0: -1})
    with pytest.raises(ValueError, match="P=4"):
        S.Session(g, shards, rounds=ROUNDS,
                  fault=S.FaultPolicy("single", fail_at={7: 1}))
    with pytest.raises(ValueError, match="not both"):
        S.Session(g, shards, rounds=ROUNDS, alive=np.ones(PARTS, bool),
                  fault=S.FaultPolicy("single"))
    with pytest.raises(ValueError, match="P="):
        fault.FailingSource(shards, fail_chunk={9: 0})


# ---------------------------------------------------------------------------
# sharded engine: same semantics when partitions are devices
# ---------------------------------------------------------------------------

needs8 = pytest.mark.skipif(jax.device_count() < 8,
                            reason="needs 8 devices (fake-device CI lane)")


@pytest.fixture(scope="module")
def shards8():
    cols = tpch.generate_lineitem(ROWS, seed=21)
    parts = randomize.randomize_global(
        {k: jnp.asarray(v) for k, v in cols.items()}, jax.random.key(4), 8)
    return randomize.pack_partitions(parts, chunk_len=256)


@needs8
def test_sharded_kill_mid_scan_single(shards8):
    """ISSUE acceptance: killing a shard mid-scan on the 8-device lane
    under `single` yields finite variance-floored bounds and a final over
    surviving data — no crash, no NaN — matching the vmapped engine."""
    mesh = jax.make_mesh((8,), ("data",))
    g = _GLAS[("scan", "single")]
    sh = S.Session(g, shards8, rounds=ROUNDS, mesh=mesh,
                   fault=S.FaultPolicy("single", fail_at={3: 2}))
    res = _drive(sh)
    _assert_no_nan(res)
    lo = np.asarray(res.estimates.lower)
    hi = np.asarray(res.estimates.upper)
    assert np.all(np.isfinite(lo)) and np.all(np.isfinite(hi))
    assert np.all(hi[-1] > lo[-1])
    vm = _drive(S.Session(g, shards8, rounds=ROUNDS,
                          fault=S.FaultPolicy("single", fail_at={3: 2})))
    np.testing.assert_allclose(np.asarray(res.final), np.asarray(vm.final),
                               rtol=1e-5)


@needs8
def test_sharded_kill_poisons_multiple(shards8):
    mesh = jax.make_mesh((8,), ("data",))
    g = _GLAS[("scan", "multiple")]
    sess = S.Session(g, shards8, rounds=ROUNDS, mesh=mesh,
                     fault=S.FaultPolicy("multiple", fail_at={5: 2}))
    res = _drive(sess)
    _assert_no_nan(res)
    lo = np.asarray(res.estimates.lower)
    hi = np.asarray(res.estimates.upper)
    assert np.all(np.isneginf(lo[2:])) and np.all(np.isposinf(hi[2:]))
    assert np.all(np.isfinite(lo[:2]))


# ---------------------------------------------------------------------------
# property tests: the estimator invariants the chaos assertions rely on
# ---------------------------------------------------------------------------

@settings(max_examples=20)
@given(st.floats(min_value=0.1, max_value=10.0),
       st.floats(min_value=0.5, max_value=10.0))
def test_half_width_nonincreasing_in_scanned(mu, sigma):
    """Single-model Eq. (4): for fixed population moments the variance
    estimate (hence the CI half-width) is non-increasing in |S| — more
    scanned tuples can only tighten the interval."""
    d = 4096.0
    s = np.arange(2.0, d, 57.0)
    var = np.asarray(E.variance_estimate(
        jnp.asarray(s * mu, jnp.float32),
        jnp.asarray(s * (sigma ** 2 + mu ** 2), jnp.float32),
        jnp.asarray(s, jnp.float32), jnp.asarray(d, jnp.float32)),
        np.float64)
    assert np.all(np.isfinite(var)) and np.all(var >= 0.0)
    # f32 slack: the s*sumsq - sum^2 cancellation leaves ~1e-4 relative
    assert np.all(np.diff(var) <= var[:-1] * 1e-3 + 1e-6)


@settings(max_examples=20)
@given(st.floats(min_value=0.0, max_value=1e6),
       st.integers(min_value=0, max_value=1))
def test_variance_clamp_small_sample_never_nan(val, s):
    """|S| <= 1 leaves the sample variance undefined: the clamp must emit
    +inf (undefined can never certify convergence), never NaN — and the
    bounds built from it stay NaN-free (finite - inf = -inf)."""
    sf = jnp.asarray(float(s), jnp.float32)
    sum_ = jnp.asarray(val * s, jnp.float32)
    var = E.variance_estimate(sum_, jnp.asarray(val ** 2 * s, jnp.float32),
                              sf, jnp.asarray(100.0, jnp.float32))
    assert np.isposinf(np.asarray(var))
    est = E.horvitz_estimate(sum_, sf, jnp.asarray(100.0, jnp.float32))
    lo, hi = E.normal_bounds(est, var, 0.95)
    assert not np.isnan(np.asarray(est))
    assert np.isneginf(np.asarray(lo)) and np.isposinf(np.asarray(hi))


_TINY_P, _TINY_L = 4, 8
_TINY_GLA = gla.make_sum_gla(
    lambda c: c["v"], lambda c: jnp.ones_like(c["v"]),
    d_total=float(_TINY_P * _TINY_L), estimator="single")


@settings(max_examples=5)
@given(st.lists(st.floats(min_value=-100.0, max_value=100.0),
                min_size=_TINY_P * _TINY_L, max_size=_TINY_P * _TINY_L))
def test_alive_mask_renormalization_unbiased(vals):
    """Kill partition p at round 0 and run the REAL policy path to a full
    scan of the survivors: averaging the estimate over every choice of p
    equals the exact total (the alive-mask-weighted Horvitz-Thompson
    estimator is unbiased under partition-uniform sampling)."""
    v = np.asarray(vals, np.float32).reshape(_TINY_P, 1, _TINY_L)
    shards = {"v": jnp.asarray(v),
              "_mask": jnp.ones((_TINY_P, 1, _TINY_L), jnp.float32)}
    total = float(np.sum(np.asarray(v, np.float64)))
    ests = []
    for p in range(_TINY_P):
        sess = S.Session(_TINY_GLA, shards, rounds=1,
                         fault=S.FaultPolicy("single", fail_at={p: 0}))
        sess.step()
        ests.append(float(np.asarray(sess.result().estimates.estimate)[-1]))
    np.testing.assert_allclose(np.mean(ests), total, rtol=1e-4, atol=1.0)
