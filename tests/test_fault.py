"""Node-failure semantics — paper §4.6 made executable."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import engine, gla, randomize
from repro.data import tpch
from repro.dist import fault

ROWS = 20_000


@pytest.fixture(scope="module")
def shards():
    cols = tpch.generate_lineitem(ROWS, seed=21)
    parts = randomize.randomize_global(
        {k: jnp.asarray(v) for k, v in cols.items()}, jax.random.key(4), 4)
    return randomize.pack_partitions(parts, chunk_len=256)


def _exact():
    cols = tpch.generate_lineitem(ROWS, seed=21)
    return tpch.exact_answer(cols, tpch.q6_func,
                             tpch.q6_cond(tpch.Q6_LOW_WINDOW))[0]


def test_single_estimator_survives_failure(shards):
    g = gla.make_sum_gla(tpch.q6_func, tpch.q6_cond(tpch.Q6_LOW_WINDOW),
                         d_total=float(ROWS), estimator="single")
    res = fault.run_with_failures(g, shards, dead_partitions=[2],
                                  estimator="single")
    est = res.estimates
    exact = _exact()
    lo, hi = np.asarray(est.lower)[-1], np.asarray(est.upper)[-1]
    # bounds remain finite and cover the truth
    assert np.isfinite(lo) and np.isfinite(hi)
    assert lo <= exact <= hi
    # but they no longer collapse to zero width (variance floor > 0)
    assert (hi - lo) > 0.0
    floor = fault.variance_floor(g, shards, [2])
    assert floor > 0.0


def test_multiple_estimators_fail_catastrophically(shards):
    g = gla.make_sum_gla(tpch.q6_func, tpch.q6_cond(tpch.Q6_LOW_WINDOW),
                         d_total=float(ROWS), estimator="multiple")
    res = fault.run_with_failures(g, shards, dead_partitions=[1],
                                  estimator="multiple")
    est = res.estimates
    assert np.all(np.isneginf(np.asarray(est.lower)))
    assert np.all(np.isposinf(np.asarray(est.upper)))


def test_no_failure_matches_baseline(shards):
    g = gla.make_sum_gla(tpch.q6_func, tpch.q6_cond(tpch.Q6_LOW_WINDOW),
                         d_total=float(ROWS))
    a = fault.run_with_failures(g, shards, dead_partitions=[],
                                estimator="single")
    b = engine.run_query(g, shards, rounds=8)
    np.testing.assert_allclose(float(a.final), float(b.final), rtol=1e-6)
