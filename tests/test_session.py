"""Early-termination sessions: incremental round driver vs the fused
program (bitwise), stopping-rule semantics, pause/resume, both engines."""
import subprocess
import sys
import textwrap
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import engine, gla, randomize
from repro.core import session as S
from repro.data import tpch

SRC = Path(__file__).resolve().parents[1] / "src"
ROWS = 60_000
PARTS = 4
ROUNDS = 16


def _tobytes(tree):
    return [np.asarray(x).tobytes() for x in jax.tree.leaves(tree)]


@pytest.fixture(scope="module")
def shards():
    cols = tpch.generate_lineitem(ROWS, seed=11)
    parts = randomize.randomize_global(
        {k: jnp.asarray(v) for k, v in cols.items()}, jax.random.key(2),
        PARTS)
    n_chunks = -(-ROWS // PARTS // 256)
    return randomize.pack_partitions(
        parts, chunk_len=256, min_chunks=-(-n_chunks // ROUNDS) * ROUNDS)


def _wide_q6(d_total=ROWS * 1.0, window=(0, 1460)):
    """Q6-style selective SUM that reaches 1% relative error mid-scan."""
    def func(c):
        return c["quantity"]

    def cond(c):
        sd = c["shipdate"]
        return ((sd >= window[0]) & (sd < window[1])).astype(jnp.float32)

    return gla.make_sum_gla(func, cond, d_total=d_total)


def _rel_widths(res) -> np.ndarray:
    lo = np.asarray(res.estimates.lower, np.float64)
    hi = np.asarray(res.estimates.upper, np.float64)
    mid = np.asarray(res.estimates.estimate, np.float64)
    return (hi - lo) / 2.0 / np.abs(mid)


# ---------------------------------------------------------------------------
# incremental discipline == fused program, bitwise
# ---------------------------------------------------------------------------

def test_incremental_matches_fused_bitwise(shards):
    """Manually stepped session == classic run_query: final, snapshots and
    estimates byte-for-byte (same per-round-slice primitives, same
    association order)."""
    q = _wide_q6()
    fused = engine.run_query(q, shards, rounds=ROUNDS, emit="chunk")
    sess = S.Session(q, shards, rounds=ROUNDS, emit="chunk")
    while not sess.done:
        sess.step()
    inc = sess.result()
    assert _tobytes(inc.final) == _tobytes(fused.final)
    assert _tobytes(inc.snapshots) == _tobytes(fused.snapshots)
    assert _tobytes(inc.estimates) == _tobytes(fused.estimates)


def test_incremental_matches_fused_kernel_group(shards):
    """Group-by kernel dispatch: per-round-slice deltas folded incrementally
    are bitwise-identical to the fused per-round-slice loop."""
    gq = gla.make_groupby_gla(
        tpch.q1_func, tpch.q1_cond, tpch.q1_group_small, num_groups=4,
        d_total=float(ROWS), num_aggs=4)
    fused = engine.run_query(gq, shards, rounds=ROUNDS, emit="kernel")
    sess = S.Session(gq, shards, rounds=ROUNDS, emit="kernel",
                     stop=S.abs_width(-1.0))
    inc = sess.run()
    assert sess.steps_taken == ROUNDS
    assert _tobytes(inc.final) == _tobytes(fused.final)
    assert _tobytes(inc.snapshots) == _tobytes(fused.snapshots)


def test_incremental_kernel_scalar_bitwise(shards):
    """Scalar-kernel path: the fused carry-in kernel (DESIGN.md §12) made
    this bitwise — incremental steps accumulate per-chunk contributions in
    the exact association the whole-shard prefix kernel uses, so the old
    interchangeable-not-bitwise carve-out is gone."""
    q = _wide_q6()
    fused = engine.run_query(q, shards, rounds=ROUNDS, emit="kernel")
    sess = S.Session(q, shards, rounds=ROUNDS, emit="kernel",
                     stop=S.abs_width(-1.0))
    assert sess._path == "kernel_fused"
    inc = sess.run()
    assert _tobytes(inc.final) == _tobytes(fused.final)
    assert _tobytes(inc.estimates) == _tobytes(fused.estimates)


# ---------------------------------------------------------------------------
# stopping rules
# ---------------------------------------------------------------------------

def test_q6_style_early_stop_pays_fewer_round_slices(shards):
    """The acceptance property: a Q6-style query with a 1%-relative-error
    stopping rule terminates after strictly fewer round-slices than the
    full scan, at exactly the first round whose CI meets the rule — while
    run_query without a rule stays bitwise-identical to the session-driven
    full scan."""
    q = _wide_q6()
    full = engine.run_query(q, shards, rounds=ROUNDS, emit="chunk")
    w = _rel_widths(full)
    k_expect = int(np.argmax(w <= 0.01)) + 1
    assert 1 < k_expect < ROUNDS, f"tune the fixture: crossing at {k_expect}"

    sess = S.Session(q, shards, rounds=ROUNDS, emit="chunk",
                     stop=S.rel_width(0.01))
    res = sess.run()
    assert sess.converged
    assert sess.steps_taken == k_expect
    assert sess.steps_taken < ROUNDS
    assert np.asarray(res.estimates.estimate).shape[0] == k_expect
    # the early rounds it did execute are the fused program's rounds, bitwise
    assert _tobytes(res.snapshots) == _tobytes(
        jax.tree.map(lambda x: x[:k_expect], full.snapshots))
    # run_query without a stop rule is untouched by the session refactor
    again = engine.run_query(q, shards, rounds=ROUNDS, emit="chunk")
    assert _tobytes(again.final) == _tobytes(full.final)


def test_eps_hit_exactly_at_round_boundary(shards):
    """eps equal to a round's achieved width stops exactly at that round
    (estimates are deterministic, so the comparison is exact)."""
    q = _wide_q6()
    w = _rel_widths(engine.run_query(q, shards, rounds=ROUNDS, emit="chunk"))
    k = ROUNDS // 3  # 0-based round index; widths are decreasing here
    assert np.all(w[:k] > w[k])
    sess = S.Session(q, shards, rounds=ROUNDS, emit="chunk",
                     stop=S.rel_width(float(w[k])))
    sess.run()
    assert sess.converged and sess.steps_taken == k + 1


def test_never_hit_falls_through_to_full_scan(shards):
    """An unsatisfiable rule runs every round; the result is the full-scan
    answer, bitwise vs run_query."""
    q = _wide_q6()
    sess = S.Session(q, shards, rounds=ROUNDS, emit="chunk",
                     stop=S.abs_width(-1.0))
    res = sess.run()
    assert sess.steps_taken == ROUNDS and not sess.converged
    full = engine.run_query(q, shards, rounds=ROUNDS, emit="chunk")
    assert _tobytes(res.final) == _tobytes(full.final)
    assert _tobytes(res.estimates) == _tobytes(full.estimates)


def test_rounds_one_schedule(shards):
    """rounds=1: a single step IS the full scan, with and without a rule."""
    q = _wide_q6()
    full = engine.run_query(q, shards, rounds=1, emit="chunk")
    sess = S.Session(q, shards, rounds=1, emit="chunk",
                     stop=S.rel_width(1e9))
    res = sess.run()
    assert sess.steps_taken == 1
    assert _tobytes(res.final) == _tobytes(full.final)
    sess2 = S.Session(q, shards, rounds=1, emit="chunk")
    sess2.step()
    assert sess2.done
    assert _tobytes(sess2.result().final) == _tobytes(full.final)


def test_infinite_variance_rounds_never_stop_prematurely():
    """|S| <= 1 clamps the variance to +inf (estimators.variance_estimate);
    an infinite half-width must not satisfy any width rule, no matter how
    loose — the stop fires at the first round with a defined variance."""
    vals = np.array([3.0, 1.0, 4.0, 1.0, 5.0, 9.0], np.float32)
    shards1 = {
        "_mask": jnp.ones((1, 6, 1), jnp.float32),
        "v": jnp.asarray(vals).reshape(1, 6, 1),
    }
    q = gla.make_sum_gla(lambda c: c["v"],
                         lambda c: jnp.ones_like(c["v"]), d_total=6.0)
    for rule in (S.rel_width(1e12), S.abs_width(1e12)):
        sess = S.Session(q, shards1, rounds=6, emit="chunk", stop=rule)
        prog = sess.step()
        half = float(np.asarray(prog.estimates.upper)
                     - np.asarray(prog.estimates.lower)) / 2.0
        assert np.isinf(half)  # one scanned tuple: undefined variance
        assert not sess.converged
        sess.run()
        assert sess.steps_taken == 2  # round 2: |S| = 2, variance defined


def test_budget_rules(shards):
    q = _wide_q6()
    sess = S.Session(q, shards, rounds=ROUNDS, emit="chunk",
                     stop=S.budget(max_rounds=3))
    sess.run()
    assert sess.steps_taken == 3
    # tuple budget: half the dataset -> stops once scanned >= it
    sess2 = S.Session(q, shards, rounds=ROUNDS, emit="chunk",
                      stop=S.budget(max_tuples=ROWS / 2))
    sess2.run()
    assert sess2.steps_taken < ROUNDS
    prog_scanned = float(np.asarray(
        sess2.result().snapshots.scanned)[-1])
    assert prog_scanned >= ROWS / 2
    # seconds budget: 0 fires after the first round (never before one)
    sess3 = S.Session(q, shards, rounds=ROUNDS, emit="chunk",
                      stop=S.budget(max_seconds=0.0))
    sess3.run()
    assert sess3.steps_taken == 1
    # any_of combinator: whichever fires first
    sess4 = S.Session(q, shards, rounds=ROUNDS, emit="chunk",
                      stop=S.any_of(S.rel_width(1e-30),
                                    S.budget(max_rounds=2)))
    sess4.run()
    assert sess4.steps_taken == 2


def test_bundle_all_queries_converged(shards):
    """GLABundle sessions stop only when EVERY member's estimator meets the
    rule — the all-queries-converged semantics of run_queries(stop=...)."""
    fast, slow = _wide_q6(), _wide_q6(window=(0, 400))
    eps = 0.02
    ks = []
    for q in (fast, slow):
        w = _rel_widths(engine.run_query(q, shards, rounds=ROUNDS,
                                         emit="round"))
        ks.append(int(np.argmax(w <= eps)) + 1)
    assert ks[0] < ks[1] < ROUNDS, f"tune the fixture: crossings {ks}"
    res = engine.run_queries([fast, slow], shards, rounds=ROUNDS,
                             emit="round", stop=S.rel_width(eps))
    assert np.asarray(res[0].estimates.estimate).shape[0] == max(ks)
    # each member's executed rounds are its solo rounds, bitwise
    solo = engine.run_query(slow, shards, rounds=ROUNDS, emit="round")
    assert _tobytes(res[1].snapshots) == _tobytes(
        jax.tree.map(lambda x: x[:max(ks)], solo.snapshots))


# ---------------------------------------------------------------------------
# pause / resume
# ---------------------------------------------------------------------------

def test_pause_resume_mid_scan_bitwise(shards, tmp_path):
    """Pause at a round boundary, resume (fresh Session object, state
    restored through the checkpoint file), drive on: final and snapshots
    bitwise-identical to an uninterrupted run."""
    q = _wide_q6()
    full = engine.run_query(q, shards, rounds=ROUNDS, emit="chunk")
    sess = S.Session(q, shards, rounds=ROUNDS, emit="chunk")
    for _ in range(ROUNDS // 2):
        sess.step()
    ck = tmp_path / "mid.ckpt"
    sess.pause(ck)
    res_sess = S.Session.resume(ck, q, shards)
    assert res_sess.steps_taken == ROUNDS // 2
    while not res_sess.done:
        res_sess.step()
    res = res_sess.result()
    assert _tobytes(res.final) == _tobytes(full.final)
    assert _tobytes(res.snapshots) == _tobytes(full.snapshots)
    assert _tobytes(res.estimates) == _tobytes(full.estimates)


def test_pause_resume_kernel_group_bitwise(shards, tmp_path):
    """Same equivalence on the group-by kernel dispatch path (running-sum
    carry restored bit-exactly, including the first-delta discipline)."""
    gq = gla.make_groupby_gla(
        tpch.q1_func, tpch.q1_cond, tpch.q1_group_small, num_groups=4,
        d_total=float(ROWS), num_aggs=4)
    fused = engine.run_query(gq, shards, rounds=ROUNDS, emit="kernel")
    sess = S.Session(gq, shards, rounds=ROUNDS, emit="kernel")
    sess.step()  # pause after the FIRST delta: carry = delta, not zero+delta
    ck = tmp_path / "kern.ckpt"
    sess.pause(ck)
    back = S.Session.resume(ck, gq, shards)
    while not back.done:
        back.step()
    assert _tobytes(back.result().final) == _tobytes(fused.final)


def test_pause_resume_roundtrips_schedule_and_alive(shards, tmp_path):
    """The checkpoint carries the round schedule and alive mask: a resumed
    session must replay the SAME boundaries and liveness weights, not
    freshly defaulted ones (regression: the cursor applied to a default
    uniform schedule silently skips/repeats chunks)."""
    q = _wide_q6()
    C = shards["_mask"].shape[1]
    # partition-uniform but non-equal round widths: steppable, != default
    bounds = np.array([0, C // 8, C // 2, C], np.int32)
    sched = np.broadcast_to(bounds, (PARTS, 4)).copy()
    ref = S.Session(q, shards, schedule=sched, emit="chunk")
    while not ref.done:
        ref.step()
    sess = S.Session(q, shards, schedule=sched, emit="chunk")
    sess.step()
    ck = tmp_path / "sched.ckpt"
    sess.pause(ck)
    back = S.Session.resume(ck, q, shards)
    while not back.done:
        back.step()
    assert _tobytes(back.result().final) == _tobytes(ref.result().final)
    assert _tobytes(back.result().snapshots) == _tobytes(
        ref.result().snapshots)
    # static alive mask: the dead partition must stay dead after resume
    alive = np.array([True, True, True, False])
    ref_a = S.Session(q, shards, rounds=4, emit="chunk", alive=alive)
    while not ref_a.done:
        ref_a.step()
    half = S.Session(q, shards, rounds=4, emit="chunk", alive=alive)
    half.step()
    ck2 = tmp_path / "alive.ckpt"
    half.pause(ck2)
    back_a = S.Session.resume(ck2, q, shards)
    while not back_a.done:
        back_a.step()
    assert _tobytes(back_a.result().final) == _tobytes(ref_a.result().final)


def test_resume_validates_fingerprint(shards, tmp_path):
    q = _wide_q6()
    sess = S.Session(q, shards, rounds=ROUNDS, emit="chunk")
    sess.step()
    ck = tmp_path / "fp.ckpt"
    sess.pause(ck)
    other = _wide_q6().with_(name="imposter")
    with pytest.raises(ValueError, match="checkpoint mismatch"):
        S.Session.resume(ck, other, shards)
    small = {k: v[:, :ROUNDS] for k, v in shards.items()}
    with pytest.raises(ValueError, match="checkpoint mismatch"):
        S.Session.resume(ck, q, small)


# ---------------------------------------------------------------------------
# contract errors
# ---------------------------------------------------------------------------

def test_stop_rules_need_incremental_configs(shards):
    q = _wide_q6()
    with pytest.raises(ValueError, match="incrementally-steppable"):
        S.Session(q, shards, rounds=4, mode="sync", stop=S.rel_width(0.1))
    sched = engine.straggler_schedule(PARTS, shards["_mask"].shape[1], 4,
                                     speeds=[1, 1, 2, 4], seed=3)
    with pytest.raises(ValueError, match="incrementally-steppable"):
        S.Session(q, shards, schedule=sched, stop=S.rel_width(0.1))
    # without a rule those configs still run — on the fused program
    sess = S.Session(q, shards, rounds=4, mode="sync")
    with pytest.raises(ValueError, match="cannot step"):
        sess.step()
    res = sess.run()
    full = engine.run_query(q, shards, rounds=4, mode="sync")
    assert _tobytes(res.final) == _tobytes(full.final)


def test_step_and_result_lifecycle(shards):
    q = _wide_q6()
    sess = S.Session(q, shards, rounds=2, emit="chunk")
    with pytest.raises(RuntimeError, match="no rounds executed"):
        sess.result()
    sess.step()
    sess.step()
    with pytest.raises(RuntimeError, match="done"):
        sess.step()
    sess.result()
    # a fused run cannot be paused (there is no incremental carry)
    done = S.Session(q, shards, rounds=2, emit="chunk")
    done.run()
    with pytest.raises(RuntimeError, match="fused"):
        done.pause("/tmp/never-written.ckpt")
    # emit='kernel' is single-lane on BOTH disciplines, rejected up front
    with pytest.raises(ValueError, match="single-lane"):
        S.Session(q, shards, rounds=2, emit="kernel", lanes=2)


def test_pause_after_incremental_run(shards, tmp_path):
    """The README sequence: run() with a rule, read the result, THEN
    pause — an incrementally-run session stays checkpointable, and the
    resumed session is immediately done with the same result."""
    q = _wide_q6()
    sess = S.Session(q, shards, rounds=ROUNDS, emit="chunk",
                     stop=S.rel_width(0.01))
    res = sess.run()
    assert sess.converged
    ck = tmp_path / "after-run.ckpt"
    sess.pause(ck)
    back = S.Session.resume(ck, q, shards)
    assert back.done and back.converged
    assert back.steps_taken == sess.steps_taken
    assert _tobytes(back.result().final) == _tobytes(res.final)
    assert _tobytes(back.result().snapshots) == _tobytes(res.snapshots)


# ---------------------------------------------------------------------------
# sharded engine (fake devices)
# ---------------------------------------------------------------------------

@pytest.mark.skipif(jax.device_count() < 8,
                    reason="needs 8 devices (CI multi-device job sets "
                           "XLA_FLAGS=--xla_force_host_platform_device_count=8)")
def test_session_sharded_inprocess(tmp_path):
    """Multi-device CI job: incremental sharded session == fused sharded
    program bitwise; early stop pays fewer round-slices; pause/resume."""
    rows = 40_000
    cols = tpch.generate_lineitem(rows, seed=4)
    parts = randomize.randomize_global(
        {k: jnp.asarray(v) for k, v in cols.items()}, jax.random.key(1), 8)
    n_chunks = -(-rows // 8 // 128)
    shards8 = randomize.pack_partitions(
        parts, chunk_len=128, min_chunks=-(-n_chunks // 8) * 8)
    mesh = jax.make_mesh((8,), ("data",))
    q = _wide_q6(d_total=float(rows))
    fused = engine.run_query(q, shards8, rounds=8, emit="chunk", mesh=mesh)
    sess = S.Session(q, shards8, rounds=8, emit="chunk", mesh=mesh,
                     stop=S.abs_width(-1.0))
    res = sess.run()
    assert sess.steps_taken == 8
    assert _tobytes(res.final) == _tobytes(fused.final)
    assert _tobytes(res.snapshots) == _tobytes(fused.snapshots)
    early = S.Session(q, shards8, rounds=8, emit="chunk", mesh=mesh,
                      stop=S.rel_width(0.02))
    early.run()
    assert early.converged and early.steps_taken < 8
    half = S.Session(q, shards8, rounds=8, emit="chunk", mesh=mesh)
    for _ in range(4):
        half.step()
    ck = tmp_path / "shard.ckpt"
    half.pause(ck)
    back = S.Session.resume(ck, q, shards8, mesh=mesh)
    while not back.done:
        back.step()
    assert _tobytes(back.result().final) == _tobytes(fused.final)


@pytest.mark.slow
def test_session_sharded_matches_vmapped_subprocess():
    """Single-device environments: same assertions in a subprocess with 8
    fake devices (XLA_FLAGS must precede the jax import)."""
    code = textwrap.dedent("""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
        import sys; sys.path.insert(0, %r)
        import numpy as np, jax, jax.numpy as jnp
        from repro.core import engine, gla, randomize, session as S
        from repro.data import tpch
        rows = 40_000
        cols = tpch.generate_lineitem(rows, seed=4)
        parts = randomize.randomize_global(
            {k: jnp.asarray(v) for k, v in cols.items()}, jax.random.key(1), 8)
        n_chunks = -(-rows // 8 // 128)
        shards = randomize.pack_partitions(
            parts, chunk_len=128, min_chunks=-(-n_chunks // 8) * 8)
        mesh = jax.make_mesh((8,), ("data",))
        def func(c): return c["quantity"]
        def cond(c):
            return ((c["shipdate"] >= 0) & (c["shipdate"] < 1460)).astype(jnp.float32)
        q = gla.make_sum_gla(func, cond, d_total=float(rows))
        fused_v = engine.run_query(q, shards, rounds=8, emit="chunk")
        fused_s = engine.run_query(q, shards, rounds=8, emit="chunk", mesh=mesh)
        sess = S.Session(q, shards, rounds=8, emit="chunk", mesh=mesh,
                         stop=S.abs_width(-1.0))
        res = sess.run()
        assert sess.steps_taken == 8
        for a, b in zip(jax.tree.leaves(res.final), jax.tree.leaves(fused_s.final)):
            assert np.asarray(a).tobytes() == np.asarray(b).tobytes()
        for a, b in zip(jax.tree.leaves(res.snapshots),
                        jax.tree.leaves(fused_s.snapshots)):
            assert np.asarray(a).tobytes() == np.asarray(b).tobytes()
        # incremental sharded == vmapped too (one scan core)
        for a, b in zip(jax.tree.leaves(res.final), jax.tree.leaves(fused_v.final)):
            assert np.asarray(a).tobytes() == np.asarray(b).tobytes()
        early = S.Session(q, shards, rounds=8, emit="chunk", mesh=mesh,
                          stop=S.rel_width(0.02))
        early.run()
        assert early.converged and early.steps_taken < 8, early.steps_taken
        print("OK")
    """ % str(SRC))
    r = subprocess.run([sys.executable, "-c", code], capture_output=True,
                       text=True, timeout=600)
    assert r.returncode == 0, r.stderr[-3000:]
    assert "OK" in r.stdout
