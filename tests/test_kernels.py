"""Pallas kernels vs pure-jnp oracles: shape/dtype sweeps (interpret=True on
CPU — kernels target TPU)."""
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.kernels import ops, ref


@pytest.mark.parametrize("n", [128, 640, 4096, 5000])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("block_rows", [8, 256])
def test_chunk_agg_sweep(n, dtype, block_rows):
    rng = np.random.default_rng(n + block_rows)
    vals = jnp.asarray(rng.normal(size=n), dtype)
    w = jnp.asarray(rng.integers(0, 2, n), jnp.float32)
    m = jnp.asarray(rng.integers(0, 2, n), jnp.float32)
    out = ops.chunk_agg(vals, w, m, block_rows=block_rows, interpret=True)
    exp = ref.chunk_agg_ref(vals, w * m, m)
    tol = 2e-2 if dtype == jnp.bfloat16 else 1e-5
    np.testing.assert_allclose(np.asarray(out), np.asarray(exp), rtol=tol,
                               atol=tol * 10)


def test_chunk_agg_weight_mask_contract():
    """Engine contract: weight already includes the mask."""
    rng = np.random.default_rng(0)
    n = 512
    vals = jnp.asarray(rng.normal(size=n), jnp.float32)
    w = jnp.asarray(rng.integers(0, 2, n), jnp.float32)
    m = jnp.ones(n, jnp.float32)
    out = ops.chunk_agg(vals, w, m, interpret=True)
    assert float(out[2]) == n           # scanned
    assert float(out[3]) == float(w.sum())  # matched


@pytest.mark.parametrize("n", [256, 2048, 3333])
def test_q6_fused_kernel(n):
    rng = np.random.default_rng(n)
    sd = jnp.asarray(rng.integers(0, 2526, n), jnp.float32)
    dc = jnp.asarray(rng.integers(0, 11, n) / 100.0, jnp.float32)
    qt = jnp.asarray(rng.integers(1, 51, n), jnp.float32)
    ep = jnp.asarray(rng.uniform(1, 100, n), jnp.float32)
    m = jnp.asarray(rng.integers(0, 2, n), jnp.float32)
    params = jnp.asarray([420, 785, 0.02, 0.03, 1.0], jnp.float32)
    out = ops.q6_agg(params, sd, dc, qt, ep, m, interpret=True)
    exp = ref.q6_agg_ref(sd, dc, qt, ep, m, params)
    np.testing.assert_allclose(np.asarray(out), np.asarray(exp), rtol=1e-5,
                               atol=1e-4)


@pytest.mark.parametrize("g", [4, 25, 100, 1000])
@pytest.mark.parametrize("a", [1, 4])
@pytest.mark.parametrize("n", [512, 2100])
def test_group_agg_sweep(g, a, n):
    """Kernel vs oracle at unpadded G/A (the wrapper pads G→128k, A→8k)."""
    rng = np.random.default_rng(g * a + n)
    vals = jnp.asarray(rng.normal(size=(n, a)), jnp.float32)
    w = jnp.asarray(rng.integers(0, 2, n), jnp.float32)
    gids = jnp.asarray(rng.integers(0, g, n), jnp.int32)
    s, sq, mt = ops.group_agg(vals, w, gids, num_groups=g, interpret=True)
    assert s.shape == (g, a) and sq.shape == (g, a) and mt.shape == (g,)
    es, esq, emt = ref.group_agg_ref(vals, w, gids, g)
    np.testing.assert_allclose(np.asarray(s), np.asarray(es), rtol=1e-4,
                               atol=1e-4)
    np.testing.assert_allclose(np.asarray(sq), np.asarray(esq), rtol=1e-4,
                               atol=1e-4)
    np.testing.assert_allclose(np.asarray(mt), np.asarray(emt), rtol=1e-5)


def test_group_agg_mxu_padding():
    """ops.group_agg MXU alignment: the kernel sees G padded to a multiple
    of 128 and A padded to a multiple of 8 even when A == 1 (the group_agg.py
    one-hot-matmul contract), and padding never leaks into the results."""
    from unittest import mock

    from repro.kernels import group_agg as _gk

    rng = np.random.default_rng(3)
    n, g = 640, 100
    vals = jnp.asarray(rng.normal(size=n), jnp.float32)  # A == 1
    w = jnp.asarray(rng.integers(0, 2, n), jnp.float32)
    gids = jnp.asarray(rng.integers(0, g, n), jnp.int32)
    seen = {}
    orig = _gk.group_agg_kernel

    def spy(v, wt, gd, *, num_groups, **kw):
        seen["G"], seen["A"] = num_groups, v.shape[1]
        return orig(v, wt, gd, num_groups=num_groups, **kw)

    with mock.patch.object(_gk, "group_agg_kernel", side_effect=spy):
        s, _, mt = ops.group_agg(vals, w, gids, num_groups=g, interpret=True)
    assert seen["G"] % 128 == 0 and seen["G"] >= g
    assert seen["A"] % 8 == 0
    es, _, emt = ref.group_agg_ref(vals[:, None], w, gids, g)
    np.testing.assert_allclose(np.asarray(s), np.asarray(es), rtol=1e-4,
                               atol=1e-4)
    np.testing.assert_allclose(np.asarray(mt), np.asarray(emt), rtol=1e-5)


@settings(max_examples=10, deadline=None)
@given(st.integers(100, 1500), st.integers(2, 30))
def test_group_agg_property(n, g):
    rng = np.random.default_rng(n * g)
    vals = jnp.asarray(rng.normal(size=n), jnp.float32)
    w = jnp.asarray(rng.uniform(0, 1, n), jnp.float32)
    gids = jnp.asarray(rng.integers(0, g, n), jnp.int32)
    s, _, mt = ops.group_agg(vals, w, gids, num_groups=g, interpret=True)
    # group sums add up to the ungrouped aggregate
    tot = ops.chunk_agg(vals, w, jnp.ones(n, jnp.float32), interpret=True)
    np.testing.assert_allclose(float(jnp.sum(s[:, 0])), float(tot[0]),
                               rtol=1e-4)
    np.testing.assert_allclose(float(jnp.sum(mt)), float(tot[3]), rtol=1e-5)
