"""Per-architecture smoke tests: reduced same-family configs, one
forward/train step + decode on CPU, asserting shapes and finiteness.
The FULL configs are exercised only via the dry-run (no allocation)."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config, list_archs
from repro.models import spec, transformer as T
from repro.serving import serve_step as SS
from repro.training import train_step as TS

ARCHS = list_archs()


def _batch(cfg, key, B=2, S=32):
    batch = {"tokens": jax.random.randint(key, (B, S), 0, cfg.vocab_size)}
    if cfg.frontend == "vision_stub":
        batch["patches"] = jax.random.normal(
            key, (B, cfg.vis_tokens, cfg.d_model), jnp.float32)
    if cfg.is_encoder_decoder:
        batch["frames"] = jax.random.normal(
            key, (B, cfg.encoder_seq, cfg.d_model), jnp.float32)
    return batch


@pytest.mark.parametrize("arch", ARCHS)
def test_forward_and_train_step(arch):
    cfg = get_config(arch).smoke()
    key = jax.random.key(0)
    params, opt = TS.init_train_state(cfg, key, dtype=jnp.float32)
    batch = _batch(cfg, key)
    step = jax.jit(TS.make_train_step(cfg, lr=1e-3))
    p1, o1, m1 = step(params, opt, batch)
    assert np.isfinite(float(m1["loss"]))
    assert np.isfinite(float(m1["grad_norm"]))
    # output shapes preserved
    for a, b in zip(jax.tree.leaves(params), jax.tree.leaves(p1)):
        assert a.shape == b.shape and a.dtype == b.dtype
    # second step still finite (optimizer state advanced)
    _, _, m2 = step(p1, o1, batch)
    assert np.isfinite(float(m2["loss"]))


@pytest.mark.parametrize("arch", ARCHS)
def test_prefill_decode_consistency(arch):
    """Greedy decode from a prefilled cache matches the full forward at the
    last position (f32 caches to exclude quantization noise)."""
    cfg = get_config(arch).smoke()
    if cfg.num_experts:
        cfg = dataclasses.replace(cfg, expert_capacity_factor=8.0)
    key = jax.random.key(1)
    params = spec.init_params(T.param_specs(cfg, dtype=jnp.float32), key)
    B, S = 2, 16
    batch = _batch(cfg, key, B, S)
    x, _, _ = T.forward(params, cfg, batch)
    ref = np.asarray(T.unembed(params, cfg, x[:, -1]))

    total = S + (cfg.vis_tokens if cfg.frontend == "vision_stub" else 0)
    logits, cache = SS.make_prefill(cfg, cache_len=total + 4)(params, batch)
    # prefill's last-position logits == forward's last-position logits
    np.testing.assert_allclose(np.asarray(logits), ref, rtol=0.06, atol=0.05)
    # one more decode step runs and stays finite
    pos0 = x.shape[1]
    l2, cache = SS.make_decode(cfg)(params, cache,
                                    jnp.argmax(logits, -1).astype(jnp.int32),
                                    jnp.asarray(pos0, jnp.int32))
    assert np.all(np.isfinite(np.asarray(l2)))


@pytest.mark.parametrize("arch", ["deepseek_7b", "recurrentgemma_9b",
                                  "xlstm_125m", "whisper_base"])
def test_incremental_decode_matches_forward(arch):
    """Token-by-token decode from scratch reproduces the full forward."""
    cfg = get_config(arch).smoke()
    if cfg.kv_cache_dtype != "bf16":   # int8 KV noise is by design; this
        cfg = dataclasses.replace(cfg, kv_cache_dtype="bf16")  # tests logic
    key = jax.random.key(2)
    params = spec.init_params(T.param_specs(cfg, dtype=jnp.float32), key)
    B, S = 2, 12
    batch = _batch(cfg, key, B, S)
    x, _, _ = T.forward(params, cfg, batch)
    ref = np.asarray(T.unembed(params, cfg, x[:, -1]))

    cache = T.init_cache(cfg, B, S)
    cache = jax.tree.map(
        lambda a: a.astype(jnp.float32) if a.dtype == jnp.bfloat16 else a,
        cache)
    if cfg.is_encoder_decoder:
        enc = T._encoder_forward(params, cfg, batch["frames"])

        def fill(c, p):
            c = dict(c)
            c["xk"] = jnp.einsum("bsd,dke->bske", enc, p["xk"]).astype(
                c["xk"].dtype)
            c["xv"] = jnp.einsum("bsd,dke->bske", enc, p["xv"]).astype(
                c["xv"].dtype)
            return c

        pat, n_groups, _ = T._layer_layout(cfg)
        for i in range(len(pat)):
            cache["layers"][f"b{i}"] = jax.vmap(fill)(
                cache["layers"][f"b{i}"], params["layers"][f"b{i}"])
    toks = batch["tokens"]
    for t in range(S):
        logits, cache = T.decode_step(params, cfg, toks[:, t], cache,
                                      jnp.asarray(t, jnp.int32))
    rel = np.max(np.abs(np.asarray(logits) - ref)) / (np.max(np.abs(ref)) + 1e-9)
    assert rel < 2e-3, rel


def test_vocab_padding_and_long_context_flags():
    cfgs = {a: get_config(a) for a in ARCHS}
    assert cfgs["whisper_base"].vocab_padded % 256 == 0
    assert cfgs["internvl2_1b"].vocab_padded >= cfgs["internvl2_1b"].vocab_size
    longs = {a for a, c in cfgs.items() if c.supports_long_context}
    assert longs == {"llama4_maverick_400b_a17b", "recurrentgemma_9b",
                     "xlstm_125m"}
