"""Deep OLA (DESIGN.md §13): the fused join path, nested-estimator
variance discipline, sketch monoids, and serving HAVING slots.

The claims under test:

  * a two-table Q3-class join runs on the fused single-dispatch kernel
    (probe tables as kernel operands, inside the VMEM budget) and is
    bitwise-identical to the scan path — the PR-10 acceptance criterion;
  * the bounded host-batch float64 oracle extends to join queries and is
    invariant to its batch size;
  * nested estimates poison (±inf), never NaN, when a group with |S| <= 1
    passes HAVING; and the post-hoc monotone envelope never widens even
    when the predicate flips groups across rounds (hypothesis property);
  * sketch GLAs (HLL / DKW quantile / count-min) estimate within their
    stated error model and declare the right merge-additivity;
  * a HAVING slot in the serving layer stays bitwise-identical to a
    fresh solo Session over the rounds it witnessed.
"""
import functools

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.analysis import audit
from repro.core import engine
from repro.core import estimators as E
from repro.core import gla as G
from repro.core import randomize
from repro.core import session as SN
from repro.core import sketch as SK
from repro.core.spec import QuerySpec
from repro.core.uda import Estimate
from repro.data import tpch
from repro.kernels import fused_agg as FK
from repro.serving import service as SV

ROWS = 12_000
PARTS = 4
D = float(ROWS)


def _pack(cols, *, key=5, chunk=256):
    parts = randomize.randomize_global(
        {k: jnp.asarray(v) for k, v in cols.items()}, jax.random.key(key),
        PARTS)
    return randomize.pack_partitions(parts, chunk_len=chunk)


@functools.lru_cache(maxsize=None)
def _q3():
    cols, q3, (segment, valid) = tpch.q3_scenario(ROWS)
    return _pack(cols), q3, (segment, valid), cols


def _bits(a, b):
    return np.asarray(a).tobytes() == np.asarray(b).tobytes()


def leaves_equal(a, b):
    la, lb = jax.tree.leaves(a), jax.tree.leaves(b)
    return len(la) == len(lb) and all(_bits(x, y) for x, y in zip(la, lb))


# ---------------------------------------------------------------------------
# fused join path: single dispatch, bitwise vs scan, both engines
# ---------------------------------------------------------------------------

def test_fused_join_bitwise_vs_scan():
    shards, q3, _, _ = _q3()
    assert FK.fused_available(q3)
    a = engine.run_query(QuerySpec(q3, rounds=4, emit="chunk"), shards)
    b = engine.run_query(QuerySpec(q3, rounds=4, emit="kernel"), shards)
    assert leaves_equal(a.final, b.final)
    assert leaves_equal(a.snapshots, b.snapshots)
    assert leaves_equal(
        (a.estimates.estimate, a.estimates.lower, a.estimates.upper),
        (b.estimates.estimate, b.estimates.lower, b.estimates.upper))


def test_fused_join_is_single_dispatch_with_probe_operands():
    shards, q3, _, _ = _q3()
    report = audit.audit_plan(q3, shards, rounds=4, emit="kernel",
                              checks=("fused_single_dispatch",))
    res = report.result("fused_single_dispatch")
    assert not res.failed, str(res)
    assert 0 < res.data["probe_bytes"] <= res.data["probe_budget_bytes"]


def test_q10_four_agg_join_bitwise_vs_scan():
    cols, q10, _ = tpch.q10_scenario(ROWS)
    shards = _pack(cols)
    assert FK.fused_available(q10)
    a = engine.run_query(QuerySpec(q10, rounds=4, emit="chunk"), shards)
    b = engine.run_query(QuerySpec(q10, rounds=4, emit="kernel"), shards)
    assert np.asarray(a.final).shape == (tpch.NUM_SEGMENTS, 4)
    assert leaves_equal(a.final, b.final)
    assert leaves_equal(a.snapshots, b.snapshots)


def test_oversized_probe_tables_fall_back_to_legacy():
    """A probe set past the VMEM budget keeps the contract but fails
    fused_available — the engine degrades, it must not try to fuse."""
    _, q3, _, _ = _q3()
    rows = FK.PROBE_VMEM_BUDGET_BYTES // 4 + 1
    big = G.make_join_groupby_gla(
        tpch.q6_func, tpch.q1_cond, lambda c: c["orderkey"],
        np.zeros(rows, np.int32), np.ones(rows, np.float32),
        num_groups=tpch.NUM_SEGMENTS, d_total=D)
    assert FK.probe_bytes(big) > FK.PROBE_VMEM_BUDGET_BYTES
    assert not FK.fused_available(big)
    assert FK.fused_available(q3)


def test_session_selects_fused_kernel_path_for_join():
    shards, q3, _, _ = _q3()
    sess = SN.Session(QuerySpec(q3, rounds=4, emit="kernel"), shards)
    assert sess._path == "kernel_fused"


needs4 = pytest.mark.skipif(jax.device_count() < 4,
                            reason="needs 4 devices (fake-device lane)")


@needs4
def test_fused_join_bitwise_sharded():
    """The sharded engine replicates the probe tables per device and
    takes the same fused path — bitwise with its own scan path AND the
    vmapped run."""
    shards, q3, _, _ = _q3()
    mesh = jax.make_mesh((4,), ("data",))
    a = engine.run_query(QuerySpec(q3, rounds=4, emit="chunk"), shards,
                         mesh=mesh)
    b = engine.run_query(QuerySpec(q3, rounds=4, emit="kernel"), shards,
                         mesh=mesh)
    v = engine.run_query(QuerySpec(q3, rounds=4, emit="kernel"), shards)
    assert leaves_equal(a.final, b.final)
    assert leaves_equal(a.snapshots, b.snapshots)
    assert leaves_equal(b.final, v.final)
    assert leaves_equal(b.snapshots, v.snapshots)


# ---------------------------------------------------------------------------
# join oracle: bounded host batches, float64, batch-size invariant
# ---------------------------------------------------------------------------

def test_join_oracle_matches_full_scan():
    shards, q3, (segment, valid), cols = _q3()
    res = engine.run_query(QuerySpec(q3, rounds=4), shards)
    exact = tpch.exact_answer(
        cols, tpch.q6_func, tpch.q1_cond,
        num_groups=tpch.NUM_SEGMENTS,
        join_key=lambda c: c["orderkey"],
        dim_group=segment, dim_valid=valid)
    np.testing.assert_allclose(np.asarray(res.final).squeeze(),
                               np.asarray(exact).squeeze(), rtol=1e-3)


def test_join_oracle_batch_size_invariant():
    _, _, (segment, valid), cols = _q3()
    kw = dict(num_groups=tpch.NUM_SEGMENTS,
              join_key=lambda c: c["orderkey"],
              dim_group=segment, dim_valid=valid)
    a = tpch.exact_answer(cols, tpch.q6_func, tpch.q1_cond, **kw)
    b = tpch.exact_answer(cols, tpch.q6_func, tpch.q1_cond,
                          batch_rows=977, **kw)
    np.testing.assert_allclose(a, b, rtol=1e-12)


def test_join_oracle_requires_dim_arrays():
    _, _, _, cols = _q3()
    with pytest.raises(ValueError, match="dim_group and dim_valid"):
        tpch.exact_answer(cols, tpch.q6_func, tpch.q1_cond,
                          join_key=lambda c: c["orderkey"])


# ---------------------------------------------------------------------------
# nested-estimator variance discipline (satellite: edge cases)
# ---------------------------------------------------------------------------

def test_inf_inner_variance_poisons_outer_bound_not_nan():
    """A passing group with |S| <= 1 (+inf inner variance) must drive the
    outer bound to ±inf — the point estimate stays finite, nothing NaNs."""
    inner = Estimate(
        estimate=jnp.asarray([1.0, 2.0]),
        lower=jnp.asarray([-jnp.inf, 1.5]),
        upper=jnp.asarray([jnp.inf, 2.5]),
        info={"var": jnp.asarray([jnp.inf, 0.25])})
    out = E.nested_group_estimate(inner, lambda v: v >= 0.0, 0.95)
    assert float(out.estimate) == 3.0
    assert np.isposinf(float(out.upper))
    assert np.isneginf(float(out.lower))
    assert not np.isnan(np.asarray(
        (out.estimate, out.lower, out.upper))).any()


def test_inf_variance_group_filtered_out_keeps_finite_bounds():
    """The same +inf group EXCLUDED by HAVING must not leak into the
    outer variance (jnp.where masking, never 0 * inf)."""
    inner = Estimate(
        estimate=jnp.asarray([1.0, 2.0]),
        lower=jnp.asarray([-jnp.inf, 1.5]),
        upper=jnp.asarray([jnp.inf, 2.5]),
        info={"var": jnp.asarray([jnp.inf, 0.25])})
    out = E.nested_group_estimate(inner, lambda v: v >= 1.5, 0.95)
    assert float(out.estimate) == 2.0
    assert np.isfinite(np.asarray(
        (out.estimate, out.lower, out.upper))).all()


def test_single_sample_group_poisons_end_to_end():
    """Through the real constructors: one accumulated row in a passing
    group ⇒ ±inf outer bounds, no NaN anywhere in the estimate."""
    g = G.make_groupby_gla(
        lambda c: c["x"], lambda c: jnp.ones_like(c["_mask"]),
        lambda c: c["g"], num_groups=4, d_total=100.0)
    hv = G.make_having_gla(g, 0.0)
    chunk = {"x": jnp.asarray([3.0, 5.0, 7.0]),
             "g": jnp.asarray([0, 0, 1], jnp.int32),
             "_mask": jnp.asarray([1.0, 0.0, 0.0], jnp.float32)}
    state = hv.accumulate(hv.init(), chunk)   # |S| = 1 live row total
    est = hv.estimate(state, 0.95)
    assert np.isfinite(float(est.estimate))
    assert np.isneginf(float(est.lower)) and np.isposinf(float(est.upper))
    assert not np.isnan(np.asarray(jax.tree.leaves(
        (est.estimate, est.lower, est.upper)))).any()


def test_empty_state_estimate_has_no_nan():
    g = G.make_groupby_gla(
        lambda c: c["x"], lambda c: jnp.ones_like(c["_mask"]),
        lambda c: c["g"], num_groups=4, d_total=100.0)
    hv = G.make_having_gla(g, 0.0)
    est = hv.estimate(hv.init(), 0.95)
    assert not np.isnan(np.asarray(jax.tree.leaves(
        (est.estimate, est.lower, est.upper)))).any()


@settings(max_examples=50, deadline=None)
@given(st.lists(st.floats(-1e6, 1e6), min_size=1, max_size=24),
       st.lists(st.floats(0.0, 1e6), min_size=1, max_size=24))
def test_monotone_envelope_never_widens(mids, halves):
    """However HAVING flips bounce the raw per-round CIs around — any
    sequence of intervals — the envelope only tightens and stays valid
    (lo <= hi), including across envelope crossings."""
    n = min(len(mids), len(halves))
    mid = np.asarray(mids[:n], np.float32)
    half = np.asarray(halves[:n], np.float32)
    lo, hi = E.monotone_envelope(mid - half, mid + half)
    lo, hi = np.asarray(lo), np.asarray(hi)
    assert (np.diff(lo) >= 0).all()      # lower bound never drops
    assert (np.diff(hi) <= 0).all()      # upper bound never rises
    assert (lo <= hi).all()


def test_monotone_envelope_with_inf_rounds():
    """±inf rounds (poisoned early bounds) pass through: the envelope
    keeps the tightest finite bounds seen so far."""
    lo = np.asarray([-np.inf, 1.0, -np.inf, 2.0], np.float32)
    hi = np.asarray([np.inf, 9.0, np.inf, 8.0], np.float32)
    elo, ehi = map(np.asarray, E.monotone_envelope(lo, hi))
    np.testing.assert_array_equal(elo, [-np.inf, 1.0, 1.0, 2.0])
    np.testing.assert_array_equal(ehi, [np.inf, 9.0, 9.0, 8.0])


def test_having_flip_rounds_still_give_monotone_envelope():
    """End to end: a threshold near a group's estimate flips membership
    across rounds; raw bounds may jump, the envelope must not widen."""
    shards, q3, _, _ = _q3()
    hv = G.make_having_gla(q3, 1200.0)
    res = engine.run_query(QuerySpec(hv, rounds=6), shards)
    lo = np.asarray(res.estimates.lower)
    hi = np.asarray(res.estimates.upper)
    elo, ehi = map(np.asarray, E.monotone_envelope(lo, hi))
    assert (np.diff(elo) >= -1e-6).all() and (np.diff(ehi) <= 1e-6).all()
    assert (elo <= ehi + 1e-6).all()
    assert not np.isnan(np.concatenate([lo, hi])).any()


# ---------------------------------------------------------------------------
# sketch GLAs
# ---------------------------------------------------------------------------

def _sketch_shards(rows=ROWS):
    rng = np.random.default_rng(3)
    cols = {"k": (np.arange(rows, dtype=np.int32) % 3000),
            "v": rng.random(rows).astype(np.float32),
            "h": (np.arange(rows, dtype=np.int32) % 100)}
    return _pack(cols, key=11)


def test_sketch_additivity_flags():
    """HLL is a max monoid — vmapped engine only; the histogram and CMS
    sketches are additive and may cross the psum merge."""
    hll = SK.make_count_distinct_gla(lambda c: c["k"], d_total=D)
    qtl = SK.make_quantile_gla(lambda c: c["v"], lo=0.0, hi=1.0, d_total=D)
    cms = SK.make_heavy_hitters_gla(lambda c: c["h"], np.arange(3),
                                    d_total=D)
    assert not hll.merge_is_additive
    assert qtl.merge_is_additive and cms.merge_is_additive


def test_hll_count_distinct_within_error_model():
    shards = _sketch_shards()
    hll = SK.make_count_distinct_gla(lambda c: c["k"], d_total=D)
    res = engine.run_query(QuerySpec(hll, rounds=4), shards)
    est = float(res.final)
    rel = abs(est - 3000.0) / 3000.0
    assert rel < 0.1, f"HLL off by {rel:.1%}"
    e = res.estimates
    assert float(np.asarray(e.lower)[-1]) <= est <= \
        float(np.asarray(e.upper)[-1])


def test_quantile_dkw_band_contains_truth():
    shards = _sketch_shards()
    qtl = SK.make_quantile_gla(lambda c: c["v"], lo=0.0, hi=1.0,
                               d_total=D, q=0.5)
    res = engine.run_query(QuerySpec(qtl, rounds=4), shards)
    est = float(res.final)
    assert abs(est - 0.5) < 0.05
    lo = float(np.asarray(res.estimates.lower)[-1])
    hi = float(np.asarray(res.estimates.upper)[-1])
    assert lo <= 0.5 <= hi


def test_heavy_hitters_cms_bounds():
    shards = _sketch_shards()
    cms = SK.make_heavy_hitters_gla(lambda c: c["h"], np.arange(3),
                                    d_total=D)
    res = engine.run_query(QuerySpec(cms, rounds=4), shards)
    est = np.asarray(res.final)                       # full-scan counts
    true = np.asarray([np.sum(np.arange(ROWS) % 100 == c)
                       for c in range(3)], np.float32)
    assert (est >= true - 1e-3).all()                 # CMS never undercounts
    lo = np.asarray(res.estimates.lower)[-1]
    hi = np.asarray(res.estimates.upper)[-1]
    assert (lo <= true).all() and (true <= hi).all()


# ---------------------------------------------------------------------------
# serving: HAVING slots bitwise vs solo sessions
# ---------------------------------------------------------------------------

SROWS = 8192
SCHUNK = 128


@functools.lru_cache(maxsize=None)
def _spacked(parts=PARTS):
    cols = tpch.generate_lineitem(SROWS, seed=1)
    data = {k: jnp.asarray(v) for k, v in cols.items()}
    shards = randomize.randomize_global(data, jax.random.key(9), parts)
    return randomize.pack_partitions(shards, chunk_len=SCHUNK)


@functools.lru_cache(maxsize=None)
def _sfamily():
    return G.SlotFamily(
        exprs={"q6": tpch.q6_func},
        pred_cols=("shipdate",),
        groups={"rfls": (tpch.q1_group_small, 4)})


Q_HAVING = G.SlotQuery("q6", {"shipdate": (100.0, 2000.0)}, group="rfls",
                       having=10.0)
Q_GROUP = G.SlotQuery("q6", {"shipdate": (100.0, 2000.0)}, group="rfls")


def _solo_estimates(fam, packed, rec, d_total, mesh=None):
    view = SV.witnessed_view(packed, rec.witnessed)
    solo = SN.Session(
        QuerySpec(fam.solo_gla(rec.query, d_total=d_total),
                  rounds=len(rec.witnessed), emit="chunk"),
        view, mesh=mesh)
    prog = None
    for _ in range(len(rec.witnessed)):
        prog = solo.step()
    return prog.estimates


def test_having_slot_bitwise_vmapped():
    fam, packed = _sfamily(), _spacked()
    scan = SV.SharedScan(fam, packed, rounds=8)
    rh = scan.attach(Q_HAVING)
    rg = scan.attach(Q_GROUP)
    for _ in range(4):
        scan.step()
    d_total = float(np.asarray(scan._d_total))
    for rec in (rh, rg):
        se = _solo_estimates(fam, packed, rec, d_total)
        assert _bits(rec.estimate.estimate, se.estimate)
        assert _bits(rec.estimate.lower, se.lower)
        assert _bits(rec.estimate.upper, se.upper)
    # having collapses the group bank to a scalar nested estimate
    assert np.asarray(rh.estimate.estimate).shape == ()
    assert np.asarray(rg.estimate.estimate).squeeze().shape == (4,)


def test_having_slot_detach_reattach_resets_threshold():
    fam, packed = _sfamily(), _spacked()
    scan = SV.SharedScan(fam, packed, rounds=8)
    r1 = scan.attach(Q_HAVING)
    scan.step()
    scan.detach(r1)
    r2 = scan.attach(G.SlotQuery("q6", {"shipdate": (100.0, 2000.0)},
                                 group="rfls", having=500.0))
    scan.step()
    d_total = float(np.asarray(scan._d_total))
    se = _solo_estimates(fam, packed, r2, d_total)
    assert _bits(r2.estimate.estimate, se.estimate)
    assert _bits(r2.estimate.lower, se.lower)
    assert _bits(r2.estimate.upper, se.upper)


@needs4
def test_having_slot_bitwise_sharded():
    fam = _sfamily()
    packed = _spacked(parts=4)
    mesh = jax.make_mesh((4,), ("data",))
    scan = SV.SharedScan(fam, packed, rounds=4, mesh=mesh)
    rec = scan.attach(Q_HAVING)
    for _ in range(3):
        scan.step()
    d_total = float(np.asarray(scan._d_total))
    se = _solo_estimates(fam, packed, rec, d_total, mesh=mesh)
    assert _bits(rec.estimate.estimate, se.estimate)
    assert _bits(rec.estimate.lower, se.lower)
    assert _bits(rec.estimate.upper, se.upper)
