"""Loop-aware HLO cost analysis: exactness on scan vs unroll, collective
detection, dynamic-slice traffic."""
import jax
import jax.numpy as jnp
from jax import lax

from repro.analysis import hlo_cost


def _flops(fn, *args):
    c = jax.jit(fn).lower(*args).compile()
    return hlo_cost.analyze(c.as_text()), c


def test_scan_equals_unroll():
    def body(x, w):
        return jnp.tanh(x @ w), None

    def scanned(x, ws):
        return lax.scan(body, x, ws)[0]

    def unrolled(x, ws):
        for i in range(10):
            x, _ = body(x, ws[i])
        return x

    X = jax.ShapeDtypeStruct((256, 256), jnp.float32)
    W = jax.ShapeDtypeStruct((10, 256, 256), jnp.float32)
    a, _ = _flops(scanned, X, W)
    b, _ = _flops(unrolled, X, W)
    assert abs(a["flops"] - b["flops"]) / b["flops"] < 0.01
    # 10 × 2·256³ matmul flops dominate
    assert a["flops"] >= 10 * 2 * 256**3


def test_nested_scan_trip_products():
    def inner(c, x):
        return c + jnp.sum(x @ x), None

    def outer(c, xs):
        c2, _ = lax.scan(inner, c, xs)
        return c2, None

    def fn(xs):
        out, _ = lax.scan(outer, jnp.float32(0), xs)
        return out

    XS = jax.ShapeDtypeStruct((5, 7, 64, 64), jnp.float32)
    a, _ = _flops(fn, XS)
    expect = 5 * 7 * 2 * 64**3
    assert abs(a["flops"] - expect) / expect < 0.05


def test_dot_general_contracting_dims():
    def fn(a, b):
        return jnp.einsum("bij,bjk->bik", a, b)

    A = jax.ShapeDtypeStruct((4, 32, 48), jnp.float32)
    B = jax.ShapeDtypeStruct((4, 48, 16), jnp.float32)
    a, _ = _flops(fn, A, B)
    expect = 2 * 4 * 32 * 16 * 48
    assert abs(a["flops"] - expect) / expect < 0.01


def test_bytes_order_of_magnitude():
    def fn(x):
        return x * 2.0 + 1.0

    X = jax.ShapeDtypeStruct((1 << 20,), jnp.float32)
    a, c = _flops(fn, X)
    ca = c.cost_analysis()
    if isinstance(ca, list):  # older jax returns [props] per computation
        ca = ca[0]
    xla_bytes = ca.get("bytes accessed", 0.0)
    assert 0.3 * xla_bytes <= a["bytes"] <= 4 * xla_bytes + 1e4
