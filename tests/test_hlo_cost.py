"""Loop-aware HLO cost analysis: exactness on scan vs unroll, collective
detection, dynamic-slice traffic."""
import jax
import jax.numpy as jnp
from jax import lax

from repro.analysis import hlo_cost


def _flops(fn, *args):
    c = jax.jit(fn).lower(*args).compile()
    return hlo_cost.analyze(c.as_text()), c


def test_scan_equals_unroll():
    def body(x, w):
        return jnp.tanh(x @ w), None

    def scanned(x, ws):
        return lax.scan(body, x, ws)[0]

    def unrolled(x, ws):
        for i in range(10):
            x, _ = body(x, ws[i])
        return x

    X = jax.ShapeDtypeStruct((256, 256), jnp.float32)
    W = jax.ShapeDtypeStruct((10, 256, 256), jnp.float32)
    a, _ = _flops(scanned, X, W)
    b, _ = _flops(unrolled, X, W)
    assert abs(a["flops"] - b["flops"]) / b["flops"] < 0.01
    # 10 × 2·256³ matmul flops dominate
    assert a["flops"] >= 10 * 2 * 256**3


def test_nested_scan_trip_products():
    def inner(c, x):
        return c + jnp.sum(x @ x), None

    def outer(c, xs):
        c2, _ = lax.scan(inner, c, xs)
        return c2, None

    def fn(xs):
        out, _ = lax.scan(outer, jnp.float32(0), xs)
        return out

    XS = jax.ShapeDtypeStruct((5, 7, 64, 64), jnp.float32)
    a, _ = _flops(fn, XS)
    expect = 5 * 7 * 2 * 64**3
    assert abs(a["flops"] - expect) / expect < 0.05


def test_dot_general_contracting_dims():
    def fn(a, b):
        return jnp.einsum("bij,bjk->bik", a, b)

    A = jax.ShapeDtypeStruct((4, 32, 48), jnp.float32)
    B = jax.ShapeDtypeStruct((4, 48, 16), jnp.float32)
    a, _ = _flops(fn, A, B)
    expect = 2 * 4 * 32 * 16 * 48
    assert abs(a["flops"] - expect) / expect < 0.01


def test_bytes_order_of_magnitude():
    def fn(x):
        return x * 2.0 + 1.0

    X = jax.ShapeDtypeStruct((1 << 20,), jnp.float32)
    a, c = _flops(fn, X)
    ca = c.cost_analysis()
    if isinstance(ca, list):  # older jax returns [props] per computation
        ca = ca[0]
    xla_bytes = ca.get("bytes accessed", 0.0)
    assert 0.3 * xla_bytes <= a["bytes"] <= 4 * xla_bytes + 1e4


# ---------------------------------------------------------------------------
# adversarial HLO text: the parsers must degrade predictably, not crash
# (repro/analysis/audit.py builds its invariant catalog on these)
# ---------------------------------------------------------------------------

import pytest  # noqa: E402


def _hlo(body):
    return "HloModule adversarial\n\n" + body


_WHILE_NO_TRIP = _hlo("""\
%cond.1 (p.1: f32[4]) -> pred[] {
  %p.1 = f32[4]{0} parameter(0)
  ROOT %lt = pred[] constant(true)
}

%body.1 (p.2: f32[4]) -> f32[4] {
  %p.2 = f32[4]{0} parameter(0)
  ROOT %add.1 = f32[4]{0} add(%p.2, %p.2)
}

ENTRY %main.1 (arg.1: f32[4]) -> f32[4] {
  %arg.1 = f32[4]{0} parameter(0)
  ROOT %w.1 = f32[4]{0} while(%arg.1), condition=%cond.1, body=%body.1
}
""")


def test_while_missing_known_trip_count_reports_one():
    # no backend_config known_trip_count: the loop must still be seen,
    # with the documented conservative trip of 1 — not dropped, not a crash
    assert hlo_cost.while_trip_counts(_WHILE_NO_TRIP) == [1]
    assert hlo_cost.count_ops(_WHILE_NO_TRIP, "while", trip_scaled=True) == 1
    # body ops are reachable and counted once (trip 1)
    assert hlo_cost.count_ops(_WHILE_NO_TRIP, "add") == 1


_TUPLE_ROOT = _hlo("""\
ENTRY %main.2 (arg.1: f32[8,4], arg.2: s32[]) -> (f32[8,4], s32[]) {
  %arg.1 = f32[8,4]{1,0} parameter(0)
  %arg.2 = s32[] parameter(1)
  %neg.1 = f32[8,4]{1,0} negate(%arg.1)
  ROOT %t.1 = (f32[8,4]{1,0}, s32[]) tuple(%neg.1, %arg.2)
}
""")


def test_tuple_shaped_root_parses():
    comps = hlo_cost.split_computations(_TUPLE_ROOT)
    root = comps["main.2"][-1]
    assert root.opcode == "tuple"
    # tuple type bytes = sum of element bytes (8*4 f32 + one s32)
    assert hlo_cost.entry_param_bytes(_TUPLE_ROOT) == 8 * 4 * 4 + 4
    # analyze() walks it without raising and reports zero flops
    assert hlo_cost.analyze(_TUPLE_ROOT)["flops"] == 0


_ZERO_DIM = _hlo("""\
ENTRY %main.3 (arg.1: f32[0,16], arg.2: f32[]) -> f32[] {
  %arg.1 = f32[0,16]{1,0} parameter(0)
  %arg.2 = f32[] parameter(1)
  %c.1 = f32[] constant(0)
  %r.1 = f32[] reduce(%arg.1, %c.1), dimensions={0,1}, to_apply=%sum.3
  ROOT %add.1 = f32[] add(%r.1, %arg.2)
}

%sum.3 (a.1: f32[], b.1: f32[]) -> f32[] {
  %a.1 = f32[] parameter(0)
  %b.1 = f32[] parameter(1)
  ROOT %s.1 = f32[] add(%a.1, %b.1)
}
""")


def test_zero_dim_shapes():
    # a [0, 16] operand holds zero elements and zero bytes; scalars
    # (dims "") hold exactly one element, not zero
    assert hlo_cost.entry_param_bytes(_ZERO_DIM) == 0 * 16 * 4 + 4
    res = hlo_cost.analyze(_ZERO_DIM)
    assert res["flops"] >= 0  # no division-by-zero / negative cost


_TRUNCATED = _hlo("""\
%body.4 (p.1: f32[4]) -> f32[4] {
  %p.1 = f32[4]{0} parameter(0)
  ROOT %add.1 = f32[4]{0} add(%p.1, %p.1)
""")  # computation never closed, no ENTRY at all


def test_truncated_computation_raises_value_error():
    with pytest.raises(ValueError, match="no ENTRY"):
        hlo_cost.analyze(_TRUNCATED)
    with pytest.raises(ValueError, match="no ENTRY"):
        hlo_cost.entry_param_bytes(_TRUNCATED)
    with pytest.raises(ValueError, match="no ENTRY"):
        hlo_cost.while_trip_counts(_TRUNCATED)
    # the computation splitter itself tolerates the truncation: it keeps
    # the instructions it saw (the downstream ENTRY check is the gate)
    comps = hlo_cost.split_computations(_TRUNCATED)
    assert [i.opcode for i in comps["body.4"]] == ["parameter", "add"]


def test_entry_reference_to_missing_computation():
    # an ENTRY whose while body was truncated away: traversal must treat
    # the missing computation as empty, not KeyError
    hlo = _hlo("""\
ENTRY %main.5 (arg.1: f32[4]) -> f32[4] {
  %arg.1 = f32[4]{0} parameter(0)
  ROOT %w.1 = f32[4]{0} while(%arg.1), condition=%gone.1, body=%gone.2, backend_config={"known_trip_count":{"n":"9"}}
}
""")
    assert hlo_cost.while_trip_counts(hlo) == [9]
    assert hlo_cost.count_ops(hlo, "add") == 0
