"""Composable plan trees (DESIGN.md §13): PlanNode lowering onto the
flat GLA constructors, the QuerySpec integration, and the C010 contract.

The load-bearing property is *bitwise identity*: a one-node tree over a
classic flat plan must lower to the byte-identical constructor call, so
flat-plan finals/snapshots/bounds survive the refactor unchanged on both
engines.  Lowering-rule violations (two Joins, a SumAgg root over a
Join, group= conflicts) must fail loudly at plan-build time, not deep in
a trace."""
import subprocess
import sys
import textwrap
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np
import pytest

import repro
from repro.analysis import contracts
from repro.core import engine, gla, randomize
from repro.core.spec import (CountDistinct, Filter, GroupAgg, Having,
                             HeavyHitters, Join, PlanNode, Quantile,
                             QuerySpec, Scan, SumAgg, lower_plan)
from repro.data import tpch

SRC = str(Path(__file__).resolve().parents[1] / "src")
ROWS = 12_000
PARTS = 4
D = float(ROWS)


@pytest.fixture(scope="module")
def shards():
    cols = tpch.generate_lineitem(ROWS, seed=23)
    cols["orderkey"] = tpch.generate_orders_fk(ROWS, seed=7)
    parts = randomize.randomize_global(
        {k: jnp.asarray(v) for k, v in cols.items()}, jax.random.key(5), PARTS)
    return randomize.pack_partitions(parts, chunk_len=256)


def leaves_equal(a, b):
    la, lb = jax.tree.leaves(a), jax.tree.leaves(b)
    return len(la) == len(lb) and all(
        np.asarray(x).tobytes() == np.asarray(y).tobytes()
        for x, y in zip(la, lb))


def assert_same_run(flat, tree, shards, emit):
    """Flat GLA vs lowered tree: finals, snapshots and bounds bitwise."""
    a = engine.run_query(QuerySpec(flat, rounds=4, emit=emit), shards)
    b = engine.run_query(QuerySpec(tree, rounds=4, emit=emit), shards)
    assert leaves_equal(a.final, b.final)
    assert leaves_equal(a.snapshots, b.snapshots)
    assert leaves_equal(
        (a.estimates.estimate, a.estimates.lower, a.estimates.upper),
        (b.estimates.estimate, b.estimates.lower, b.estimates.upper))


# ---------------------------------------------------------------------------
# flat plans through one-node trees: bitwise-identical
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("emit", ["chunk", "kernel"])
def test_flat_sum_lowers_bitwise(shards, emit):
    """SumAgg(Filter(Scan)) with the SAME cond closure the flat spelling
    uses lowers to the byte-identical make_sum_gla call."""
    cond = tpch.q6_cond(tpch.Q6_LOW_WINDOW)
    flat = gla.make_sum_gla(tpch.q6_func, cond, d_total=D)
    tree = SumAgg(Filter(Scan(D), cond), tpch.q6_func)
    assert_same_run(flat, tree, shards, emit)


@pytest.mark.parametrize("emit", ["chunk", "kernel"])
def test_flat_groupby_lowers_bitwise(shards, emit):
    flat = gla.make_groupby_gla(
        tpch.q1_func, tpch.q1_cond, tpch.q1_group_small, num_groups=4,
        d_total=D, num_aggs=4)
    tree = GroupAgg(Filter(Scan(D), tpch.q1_cond), tpch.q1_func,
                    num_groups=4, group=tpch.q1_group_small, num_aggs=4)
    assert_same_run(flat, tree, shards, emit)


@pytest.mark.parametrize("emit", ["chunk", "kernel"])
def test_join_tree_lowers_bitwise(shards, emit):
    """GroupAgg over a Join stage lowers to make_join_groupby_gla with
    the verbatim probe arrays — same closures, bitwise-identical run."""
    segment, valid = tpch.orders_table(max(1, ROWS // 4), seed=14)

    def okey(c):
        return c["orderkey"]

    flat = gla.make_join_groupby_gla(
        tpch.q6_func, tpch.q1_cond, okey, segment, valid,
        num_groups=tpch.NUM_SEGMENTS, d_total=D)
    tree = GroupAgg(
        Join(Filter(Scan(D), tpch.q1_cond), okey, segment, valid),
        tpch.q6_func, num_groups=tpch.NUM_SEGMENTS)
    assert_same_run(flat, tree, shards, emit)


needs4 = pytest.mark.skipif(jax.device_count() < 4,
                            reason="needs 4 devices (fake-device lane)")


@needs4
def test_flat_vs_tree_bitwise_sharded(shards):
    """The sharded engine sees the same lowered GLA: one-node trees stay
    bitwise-identical to their flat spelling under shard_map + psum."""
    mesh = jax.make_mesh((4,), ("data",))
    cond = tpch.q6_cond(tpch.Q6_LOW_WINDOW)
    flat = gla.make_sum_gla(tpch.q6_func, cond, d_total=D)
    tree = SumAgg(Filter(Scan(D), cond), tpch.q6_func)
    a = engine.run_query(QuerySpec(flat, rounds=4), shards, mesh=mesh)
    b = engine.run_query(QuerySpec(tree, rounds=4), shards, mesh=mesh)
    assert leaves_equal(a.final, b.final)
    assert leaves_equal(a.snapshots, b.snapshots)
    assert leaves_equal(
        (a.estimates.estimate, a.estimates.lower, a.estimates.upper),
        (b.estimates.estimate, b.estimates.lower, b.estimates.upper))


def test_multi_filter_conjunction(shards):
    """Stacked Filter stages conjoin multiplicatively — same result as a
    single combined predicate (allclose: the combined closure differs)."""
    lo, hi = tpch.Q6_LOW_WINDOW

    def c_lo(c):
        return (c["shipdate"] >= lo).astype(jnp.float32)

    def c_hi(c):
        return (c["shipdate"] < hi).astype(jnp.float32)

    def c_both(c):
        return c_lo(c) * c_hi(c)

    tree = SumAgg(Filter(Filter(Scan(D), c_lo), c_hi), tpch.q6_func)
    flat = gla.make_sum_gla(tpch.q6_func, c_both, d_total=D)
    a = engine.run_query(QuerySpec(flat, rounds=4), shards)
    b = engine.run_query(QuerySpec(tree, rounds=4), shards)
    np.testing.assert_allclose(np.asarray(a.final), np.asarray(b.final),
                               rtol=1e-6)


# ---------------------------------------------------------------------------
# QuerySpec integration
# ---------------------------------------------------------------------------

def test_queryspec_lowers_tree_and_keeps_provenance():
    tree = SumAgg(Filter(Scan(D), tpch.q1_cond), tpch.q6_func)
    qs = QuerySpec(tree, rounds=4)
    assert qs.plan is tree
    assert qs.gla.estimate is not None          # a lowered, runnable GLA
    assert not isinstance(qs.gla, PlanNode)


def test_queryspec_lowers_sequences_mixing_trees_and_glas():
    tree = SumAgg(Filter(Scan(D), tpch.q1_cond), tpch.q6_func)
    flat = gla.make_sum_gla(tpch.q6_func, tpch.q1_cond, d_total=D)
    qs = QuerySpec([tree, flat], rounds=4)
    assert qs.is_multi and len(qs.gla) == 2
    assert qs.gla[1] is flat                    # GLAs pass through untouched
    assert qs.plan == [tree, flat]


def test_plan_node_lower_method_matches_lower_plan(shards):
    tree = GroupAgg(Filter(Scan(D), tpch.q1_cond), tpch.q1_func,
                    num_groups=4, group=tpch.q1_group_small, num_aggs=4)
    g = tree.lower()
    a = engine.run_query(QuerySpec(g, rounds=4), shards)
    b = engine.run_query(QuerySpec(tree, rounds=4), shards)
    assert leaves_equal(a.final, b.final)


def test_having_tree_lowers_to_composed_gla(shards):
    tree = Having(
        GroupAgg(Filter(Scan(D), tpch.q1_cond), tpch.q6_func,
                 num_groups=4, group=tpch.q1_group_small),
        threshold=10.0)
    g = lower_plan(tree)
    assert g.name.startswith("having[")
    res = engine.run_query(QuerySpec(g, rounds=4), shards)
    est = res.estimates
    assert np.isfinite(np.asarray(est.estimate)).all()
    # the nested estimate is scalar (sum over passing groups)
    assert np.asarray(est.estimate).shape[-1:] in ((), (4,))


# ---------------------------------------------------------------------------
# lowering-rule violations fail at plan-build time
# ---------------------------------------------------------------------------

def _ctrue(c):
    return jnp.ones_like(c["_mask"])


def _jtree(child=None):
    seg = np.zeros(8, np.int32)
    val = np.ones(8, np.float32)
    return Join(child or Scan(D), _ctrue, seg, val)


def test_two_join_stages_rejected():
    with pytest.raises(ValueError, match="one Join stage"):
        lower_plan(GroupAgg(_jtree(_jtree()), tpch.q6_func, num_groups=8))


def test_sum_root_over_join_rejected():
    with pytest.raises(ValueError, match="GroupAgg root"):
        lower_plan(SumAgg(_jtree(), tpch.q6_func))


def test_groupagg_plain_scan_needs_group():
    with pytest.raises(ValueError, match="needs group="):
        lower_plan(GroupAgg(Scan(D), tpch.q1_func, num_groups=4))


def test_groupagg_over_join_rejects_group_kwarg():
    with pytest.raises(ValueError, match="drop group="):
        lower_plan(GroupAgg(_jtree(), tpch.q6_func, num_groups=8,
                            group=tpch.q1_group_small))


def test_sketch_roots_reject_join_stages():
    for root in (CountDistinct(_jtree(), _ctrue),
                 Quantile(_jtree(), _ctrue, lo=0.0, hi=1.0),
                 HeavyHitters(_jtree(), _ctrue, np.arange(4))):
        with pytest.raises(ValueError, match="plain filtered scans"):
            lower_plan(root)


def test_nested_estimator_roots_rejected():
    inner = SumAgg(Scan(D), tpch.q6_func)
    with pytest.raises(ValueError, match="below another root"):
        lower_plan(SumAgg(inner, tpch.q6_func))


def test_non_root_lowering_rejected():
    with pytest.raises(ValueError, match="not an estimator root"):
        lower_plan(Filter(Scan(D), _ctrue))
    with pytest.raises(TypeError, match="PlanNode"):
        lower_plan("not a plan")


# ---------------------------------------------------------------------------
# C010: every PlanNode subclass declares monoid + estimator
# ---------------------------------------------------------------------------

def test_c010_requires_monoid_and_estimator(tmp_path):
    bad = tmp_path / "plan_nodes.py"
    bad.write_text(textwrap.dedent("""
        class PlanNode:
            monoid = "none"
            estimator = "none"

        class MySketch(PlanNode):
            monoid = "max"
            # estimator missing

        class Indirect(MySketch):
            pass
    """))
    viols = contracts.lint_file(bad, tmp_path)
    codes = sorted({v.code for v in viols})
    assert codes == ["C010"]
    names = {v.message.split()[2] for v in viols}
    assert names == {"MySketch", "Indirect"}


def test_c010_accepts_declared_nodes(tmp_path):
    ok = tmp_path / "plan_nodes.py"
    ok.write_text(textwrap.dedent("""
        class PlanNode:
            monoid = "none"
            estimator = "none"

        class Good(PlanNode):
            monoid = "sum"
            estimator = "horvitz"
    """))
    assert not [v for v in contracts.lint_file(ok, tmp_path)
                if v.code == "C010"]


def test_c010_clean_on_real_spec_module():
    spec_path = Path(SRC) / "repro" / "core" / "spec.py"
    assert not [v for v in contracts.lint_file(spec_path, Path(SRC).parent)
                if v.code == "C010"]


# ---------------------------------------------------------------------------
# facade: import repro stays jax-free; the new names resolve
# ---------------------------------------------------------------------------

def test_import_repro_stays_jax_free():
    """The lazy-exports facade must not drag in jax (the contracts CI job
    runs on a bare interpreter); plan-tree exports must still resolve."""
    code = textwrap.dedent("""
        import sys
        sys.path.insert(0, %r)
        import repro
        assert "jax" not in sys.modules, "import repro pulled in jax"
        assert "PlanNode" in repro.__all__ and "compose" in repro.__all__
        # spec.py is jax-free too: building a tree must not import jax
        tree = repro.SumAgg(repro.Filter(repro.Scan(8.0), None), None)
        assert "jax" not in sys.modules, "plan-tree build pulled in jax"
        print("OK")
    """ % SRC)
    out = subprocess.run([sys.executable, "-c", code],
                         capture_output=True, text=True)
    assert out.returncode == 0, out.stderr
    assert "OK" in out.stdout


def test_facade_exports_resolve():
    for name in ("PlanNode", "Scan", "Filter", "Join", "SumAgg", "GroupAgg",
                 "Having", "CountDistinct", "Quantile", "HeavyHitters",
                 "lower_plan", "compose", "make_having_gla",
                 "monotone_envelope", "make_count_distinct_gla",
                 "make_quantile_gla", "make_heavy_hitters_gla"):
        assert getattr(repro, name) is not None
