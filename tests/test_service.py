"""Serving layer (DESIGN.md §11): shared-scan slot queries.

The load-bearing claims, each proven here:

  * a late joiner's estimates are bitwise identical to a fresh solo
    Session over exactly the chunk ranges it witnessed (both engines,
    scalar and group-bank members) — unbiased bounds at any attach round;
  * detach-then-reattach reuses the freed slot with zero new compiles
    (slot generations + in-jit ``jnp.where`` carry reset);
  * compile count under arrival/departure churn is bounded by capacity
    doublings, asserted from the audit catalog
    (``bounded_compiles_under_churn``);
  * the asyncio service converges queries via their stop rules, parks an
    idle scan after the grace period, and un-parks it on the next submit
    without losing the cursor.
"""
import asyncio
import functools

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.analysis import audit
from repro.core import gla as G
from repro.core import randomize
from repro.core import session as SN
from repro.core.spec import QuerySpec
from repro.data import tpch
from repro.serving import service as SV

ROWS = 8192
PARTS = 4
CHUNK = 128


@functools.lru_cache(maxsize=None)
def _packed(parts=PARTS):
    cols = tpch.generate_lineitem(ROWS, seed=1)
    data = {k: jnp.asarray(v) for k, v in cols.items()}
    shards = randomize.randomize_global(data, jax.random.key(9), parts)
    return randomize.pack_partitions(shards, chunk_len=CHUNK)


@functools.lru_cache(maxsize=None)
def _family():
    return G.SlotFamily(
        exprs={"q6": tpch.q6_func, "qty": lambda c: c["quantity"]},
        pred_cols=("shipdate", "discount"),
        groups={"rfls": (tpch.q1_group_small, 4)})


Q_SCALAR = G.SlotQuery("q6", {"shipdate": (420.0, 785.0)})
Q_LATE = G.SlotQuery("qty", {"discount": (0.02, 0.08)})
Q_GROUP = G.SlotQuery("q6", {"shipdate": (100.0, 2000.0)}, group="rfls")


def _bits(a, b):
    return np.asarray(a).tobytes() == np.asarray(b).tobytes()


def _solo_estimates(fam, packed, rec, d_total, mesh=None):
    """A fresh Session over exactly the chunk ranges ``rec`` witnessed —
    the reference a slot's estimates must match bitwise."""
    view = SV.witnessed_view(packed, rec.witnessed)
    solo = SN.Session(
        QuerySpec(fam.solo_gla(rec.query, d_total=d_total),
                  rounds=len(rec.witnessed), emit="chunk"),
        view, mesh=mesh)
    prog = None
    for _ in range(len(rec.witnessed)):
        prog = solo.step()
    return prog.estimates


def test_degrade_rounds():
    assert SV._degrade_rounds(16, 8) == 8
    assert SV._degrade_rounds(12, 8) == 6
    assert SV._degrade_rounds(7, 8) == 7
    assert SV._degrade_rounds(7, 4) == 1


def test_late_join_bitwise_vmapped():
    fam, packed = _family(), _packed()
    scan = SV.SharedScan(fam, packed, rounds=8)
    r1 = scan.attach(Q_SCALAR)
    for _ in range(3):
        scan.step()                      # r1 witnesses rounds 0..2
    r2 = scan.attach(Q_LATE)             # joins at cursor 3
    for _ in range(4):
        scan.step()
    assert [lo for lo, _ in r2.witnessed] == [
        c * scan.width for c in (3, 4, 5, 6)]
    d_total = float(np.asarray(scan._d_total))
    se = _solo_estimates(fam, packed, r2, d_total)
    assert _bits(r2.estimate.estimate, se.estimate)
    assert _bits(r2.estimate.lower, se.lower)
    assert _bits(r2.estimate.upper, se.upper)
    # the early joiner completes its full pass one step later
    scan.step()
    assert r1.done and not r1.converged
    assert len(r1.witnessed) == scan.rounds
    assert r1.scanned == d_total


def test_late_join_group_member_bitwise_vmapped():
    fam, packed = _family(), _packed()
    scan = SV.SharedScan(fam, packed, rounds=8)
    scan.attach(Q_SCALAR)
    scan.step()
    rg = scan.attach(Q_GROUP)            # group bank opens mid-scan
    for _ in range(3):
        scan.step()
    d_total = float(np.asarray(scan._d_total))
    se = _solo_estimates(fam, packed, rg, d_total)
    assert _bits(rg.estimate.estimate, se.estimate)
    assert _bits(rg.estimate.lower, se.lower)
    assert _bits(rg.estimate.upper, se.upper)


def test_detach_reattach_reuses_slot_without_recompile():
    fam, packed = _family(), _packed()
    scan = SV.SharedScan(fam, packed, rounds=8)
    recs = [scan.attach(G.SlotQuery("qty", {"discount": (0.0, 0.02 + i / 100)}))
            for i in range(3)]
    scan.step()
    k0 = scan.banks["scalar"].K
    c0 = SV.serve_step_cache_sizes()["vmapped"]
    victim = recs[1]
    scan.detach(victim)
    renew = scan.attach(Q_LATE)
    assert renew.slot == victim.slot          # freed slot reclaimed...
    assert renew.generation == victim.generation + 1   # ...new generation
    scan.step()
    c1 = SV.serve_step_cache_sizes()["vmapped"]
    assert scan.banks["scalar"].K == k0       # no capacity change
    if c0 is not None:                        # membership churn at fixed K
        assert c1 - c0 == 0                   # compiles nothing new
    # the reclaimed carry restarted from zero: bitwise vs a solo Session
    # over the one round the new tenant witnessed
    d_total = float(np.asarray(scan._d_total))
    se = _solo_estimates(fam, packed, renew, d_total)
    assert _bits(renew.estimate.estimate, se.estimate)


def test_churn_bounded_compiles_certified_by_audit():
    """The acceptance gate: compile count under arrival/departure churn
    is bounded by capacity doublings — asserted from the audit catalog,
    not ad-hoc counters."""
    report = audit.audit_service(_family(), _packed(), rounds=4)
    churn = report.result("bounded_compiles_under_churn")
    assert not churn.failed, str(churn)
    if churn.data.get("skipped"):
        pytest.skip("jit cache introspection unavailable")
    assert churn.data["cache_miss_delta"] <= churn.data["budget"]
    assert churn.data["doublings"] >= 1
    assert churn.data["arrivals"] > churn.data["budget"]


@settings(max_examples=8, deadline=None)
@given(st.integers(min_value=0, max_value=6),
       st.integers(min_value=1, max_value=8),
       st.floats(min_value=0.0, max_value=1200.0))
def test_witnessed_coverage_never_below_reported_scanned(join, steps, lo):
    """Property: whatever round a query joins at and however long it
    runs, the tuples inside its witnessed chunk ranges are never fewer
    than the scan reported as scanned — the estimator's scale-up
    ``d_total / scanned`` never overstates coverage."""
    fam, packed = _family(), _packed()
    scan = SV.SharedScan(fam, packed, rounds=8)
    warm = scan.attach(Q_SCALAR)          # keeps the scan advancing
    for _ in range(join):
        scan.step()
        if warm.done:
            scan.detach(warm)
            warm = scan.attach(Q_SCALAR)
    rec = scan.attach(G.SlotQuery("qty", {"shipdate": (lo, lo + 365.0)}))
    for _ in range(steps):
        scan.step()
    ms = scan._ms
    covered = sum(float(ms[:, a:b].sum()) for a, b in rec.witnessed)
    assert len(rec.witnessed) == steps
    assert covered >= rec.scanned
    assert covered == pytest.approx(rec.scanned)
    assert rec.scanned <= steps * float(np.asarray(scan._d_total))


def test_service_converge_park_unpark():
    fam, packed = _family(), _packed()

    async def main():
        async with SV.OLAService(fam, rounds=8, grace_s=0.1) as svc:
            h1 = await svc.submit(
                QuerySpec(Q_SCALAR, stop=SN.rel_width(0.9)), packed)
            h2 = await svc.submit(Q_LATE, packed)
            o1 = await h1.result()
            o2 = await h2.result()
            # generous stop rule -> early convergence detaches q1 while
            # q2 rides the same scan to a full pass
            assert o1.converged and o1.rounds_witnessed < o2.rounds_witnessed
            assert not o2.converged
            assert o2.rounds_witnessed == svc.scan_for(packed).rounds
            steps_before = svc.scan_for(packed).steps_done
            await asyncio.sleep(0.4)
            assert svc.is_parked(packed)  # grace elapsed, drive task gone
            h3 = await svc.submit(Q_SCALAR, packed)   # un-park
            o3 = await h3.result()
            assert o3.rounds_witnessed > 0
            # same scan object kept its cursor across the park
            assert svc.scan_for(packed).steps_done > steps_before

    asyncio.run(main())


def test_service_rejects_bad_submissions():
    fam, packed = _family(), _packed()

    async def main():
        async with SV.OLAService(fam, rounds=8) as svc:
            with pytest.raises(TypeError):
                await svc.submit(tpch.q6_func, packed)
            with pytest.raises(TypeError):
                # QuerySpec around a non-slot GLA
                await svc.submit(
                    QuerySpec(G.make_sum_gla(
                        tpch.q6_func, tpch.q6_cond(tpch.Q6_LOW_WINDOW),
                        d_total=float(ROWS))),
                    packed)
            with pytest.raises(ValueError):
                # confidence is a compile-time static of the shared step
                await svc.submit(QuerySpec(Q_SCALAR, confidence=0.5), packed)

    asyncio.run(main())


needs8 = pytest.mark.skipif(jax.device_count() < 8,
                            reason="needs 8 devices (fake-device lane)")


@needs8
def test_late_join_bitwise_sharded():
    fam = _family()
    packed = _packed(parts=8)
    mesh = jax.make_mesh((8,), ("data",))
    scan = SV.SharedScan(fam, packed, rounds=4, mesh=mesh)
    scan.attach(Q_SCALAR)
    scan.step()
    r2 = scan.attach(Q_LATE)
    rg = scan.attach(Q_GROUP)
    scan.step()
    scan.step()
    d_total = float(np.asarray(scan._d_total))
    for rec in (r2, rg):
        se = _solo_estimates(fam, packed, rec, d_total, mesh=mesh)
        assert _bits(rec.estimate.estimate, se.estimate)
        assert _bits(rec.estimate.lower, se.lower)
        assert _bits(rec.estimate.upper, se.upper)


@needs8
def test_churn_bounded_compiles_sharded():
    mesh = jax.make_mesh((8,), ("data",))
    report = audit.audit_service(_family(), _packed(parts=8), rounds=4,
                                 mesh=mesh)
    churn = report.result("bounded_compiles_under_churn")
    assert not churn.failed, str(churn)
