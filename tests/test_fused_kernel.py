"""Fused selection→bucket→aggregate kernel (kernels/fused_agg.py,
DESIGN.md §12): bitwise equivalence against the segment-sum scan path
across {scalar, group, bundle} × {plain, dict, bit-packed} on both
engines, the dense-predicate regression that broke the legacy "bitwise"
claim, MXU padding discipline, and single-dispatch accounting."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import engine, gla, randomize
from repro.core import scan as SC
from repro.core import session as S
from repro.core.spec import QuerySpec
from repro.data import encodings as ENC
from repro.data import tpch
from repro.data.source import EncodedSource
from repro.kernels import fused_agg as FK

ROWS = 12_000
PARTS = 4
ROUNDS = 4

needs8 = pytest.mark.skipif(jax.device_count() < 8,
                            reason="needs 8 (fake) devices: run under "
                            "XLA_FLAGS=--xla_force_host_platform_device_"
                            "count=8")


def _tb(tree):
    return [np.asarray(x).tobytes() for x in jax.tree.leaves(tree)]


@pytest.fixture(scope="module")
def raw():
    return tpch.generate_lineitem(ROWS, seed=17)


@pytest.fixture(scope="module")
def shards(raw):
    parts = randomize.randomize_global(
        {k: jnp.asarray(v) for k, v in raw.items()}, jax.random.key(4),
        PARTS)
    n_chunks = -(-ROWS // PARTS // 256)
    return randomize.pack_partitions(
        parts, chunk_len=256, min_chunks=-(-n_chunks // ROUNDS) * ROUNDS)


def _dense_cond(c):
    # >80% selectivity: the trap that made the legacy group-kernel
    # "bitwise" tests vacuous (they only ever saw sparse q1 predicates)
    return (c["shipdate"] < 1460).astype(jnp.float32)


def _glas():
    d = float(ROWS)
    scalar = gla.make_sum_gla(tpch.q6_func, _dense_cond, d_total=d)
    scalar4 = gla.make_sum_gla(tpch.q1_func, _dense_cond, d_total=d,
                               num_aggs=4)
    group = gla.make_groupby_gla(tpch.q1_func, _dense_cond,
                                 tpch.q1_group_small, num_groups=4,
                                 d_total=d, num_aggs=4)
    bundle = gla.GLABundle([scalar, group, scalar4])
    return {"scalar": scalar, "scalar4": scalar4, "group": group,
            "bundle": bundle}


def _encodings(raw):
    return ENC.normalize_encodings(
        {"discount": ENC.dict_encoding_for(np.asarray(raw["discount"])),
         "shipdate": ENC.BitPackedEncoding(bits=16),
         "rfls": ENC.BitPackedEncoding(bits=2)})


def _flat_cols(shards, ragged=True):
    """One partition's [C, L] column dict with a ragged final chunk."""
    cols = {k: v[0] for k, v in shards.items()}
    if ragged:
        mask = np.asarray(cols["_mask"]).copy()
        mask[-1, -37:] = 0.0
        cols = dict(cols, _mask=jnp.asarray(mask))
    return cols


def _encode_cols(cols, encs):
    enc = dict(encs)
    out = dict(cols)
    for name, e in enc.items():
        out[name] = jnp.asarray(
            ENC.encode_array(np.asarray(cols[name]), e))
    return out


def _fold_scan(g, cols, rounds=ROUNDS):
    st = SC.stack_init(g, 1)
    views = []
    C = cols["_mask"].shape[0]
    per = C // rounds
    for r in range(rounds):
        st, v = SC.scan_round_step(
            g, st, {k: x[r * per:(r + 1) * per] for k, x in cols.items()}, 1)
        views.append(v)
    return st, views


# ---------------------------------------------------------------------------
# kernel-level bitwise sweep (ragged tails + dense predicate throughout)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("name", ["scalar", "scalar4", "group", "bundle"])
@pytest.mark.parametrize("encoding", ["plain", "encoded"])
def test_fused_round_step_bitwise_vs_scan(shards, raw, name, encoding):
    """Carry-in fused steps == scan fold, every round boundary, every
    member, bit for bit — including in-kernel dict + bit-packed decode."""
    g = _glas()[name]
    cols = _flat_cols(shards)
    encs = _encodings(raw) if encoding == "encoded" else ()
    feed = _encode_cols(cols, encs) if encs else cols

    ref_st, ref_views = _fold_scan(g, cols)
    st = g.init()
    C = cols["_mask"].shape[0]
    per = C // ROUNDS
    for r in range(ROUNDS):
        st = SC.fused_round_step(
            g, st, {k: x[r * per:(r + 1) * per] for k, x in feed.items()},
            encs)
        assert _tb(st) == _tb(ref_views[r]), (name, encoding, r)
    assert _tb(st) == _tb(ref_st)


@pytest.mark.parametrize("name", ["scalar", "scalar4"])
def test_fused_prefix_states_bitwise(shards, raw, name):
    """The one-dispatch scalar prefix family == scan_prefix: final AND all
    C+1 per-chunk running states (what round snapshots index)."""
    g = _glas()[name]
    cols = _flat_cols(shards)
    sf, sp = SC.scan_prefix(g, cols, 1)
    ff, fp = SC.fused_prefix_states(g, cols)
    assert _tb(sf) == _tb(ff)
    assert _tb(sp) == _tb(fp)
    encs = _encodings(raw)
    ff_e, fp_e = SC.fused_prefix_states(g, _encode_cols(cols, encs), encs)
    assert _tb(sf) == _tb(ff_e)
    assert _tb(sp) == _tb(fp_e)


def test_fused_prefix_rejects_group_and_bundle(shards):
    cols = _flat_cols(shards)
    for g in (_glas()["group"], _glas()["bundle"]):
        with pytest.raises(ValueError, match="solo scalar"):
            SC.fused_prefix_states(g, cols)


# ---------------------------------------------------------------------------
# engine + session: vmapped
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("name", ["scalar", "group", "bundle"])
def test_engine_fused_kernel_bitwise_vs_chunk(shards, name):
    """emit='kernel' (now the fused path) == emit='chunk' (segment-sum
    scan): finals byte-for-byte on the vmapped engine — the scalar path's
    old interchangeable-not-bitwise carve-out is gone."""
    g = _glas()[name]
    a = engine.run_query(QuerySpec(g, rounds=ROUNDS, emit="chunk"), shards)
    b = engine.run_query(QuerySpec(g, rounds=ROUNDS, emit="kernel"), shards)
    assert _tb(a.final) == _tb(b.final)
    assert _tb(a.snapshots) == _tb(b.snapshots)


@pytest.mark.parametrize("name", ["scalar", "group", "bundle"])
def test_session_fused_encoded_bitwise(shards, raw, name):
    """Incrementally stepped sessions over an EncodedSource (decode
    in-kernel) == the plain resident fused program, byte for byte."""
    g = _glas()[name]
    ref = engine.run_query(QuerySpec(g, rounds=ROUNDS, emit="kernel"),
                           shards)
    esrc = EncodedSource.from_shards(
        {k: np.asarray(v) for k, v in shards.items()},
        dict(_encodings(raw)))
    sess = S.Session(QuerySpec(g, rounds=ROUNDS, emit="kernel"), esrc)
    assert sess._path == "kernel_fused"
    while not sess.done:
        sess.step()
    inc = sess.result()
    assert _tb(inc.final) == _tb(ref.final)
    assert _tb(inc.snapshots) == _tb(ref.snapshots)


def test_scalar_session_kernel_bitwise(shards):
    """The formerly non-bitwise scalar kernel session: fused carry-in steps
    now reproduce the fused program exactly (replaces the old
    'interchangeable' contract)."""
    g = _glas()["scalar"]
    ref = engine.run_query(QuerySpec(g, rounds=ROUNDS, emit="kernel"),
                           shards)
    sess = S.Session(QuerySpec(g, rounds=ROUNDS, emit="kernel"), shards)
    assert sess._path == "kernel_fused"
    inc = sess.run()
    assert _tb(inc.final) == _tb(ref.final)
    assert _tb(inc.estimates) == _tb(ref.estimates)


# ---------------------------------------------------------------------------
# engine + session: sharded (8 fake devices — CI tier1-multidevice lane)
# ---------------------------------------------------------------------------

@needs8
@pytest.mark.parametrize("name", ["scalar", "group", "bundle"])
def test_sharded_fused_kernel_bitwise(name, raw):
    g = _glas()[name]
    parts = randomize.randomize_global(
        {k: jnp.asarray(v) for k, v in raw.items()}, jax.random.key(4), 8)
    n_chunks = -(-ROWS // 8 // 128)
    shards8 = randomize.pack_partitions(
        parts, chunk_len=128, min_chunks=-(-n_chunks // ROUNDS) * ROUNDS)
    mesh = jax.make_mesh((8,), ("data",))
    a = engine.run_query(QuerySpec(g, rounds=ROUNDS, emit="chunk"),
                         shards8, mesh=mesh)
    b = engine.run_query(QuerySpec(g, rounds=ROUNDS, emit="kernel"),
                         shards8, mesh=mesh)
    assert _tb(a.final) == _tb(b.final)
    assert _tb(a.snapshots) == _tb(b.snapshots)
    esrc = EncodedSource.from_shards(
        {k: np.asarray(v) for k, v in shards8.items()},
        dict(_encodings(raw)))
    sess = S.Session(QuerySpec(g, rounds=ROUNDS, emit="kernel"), esrc,
                     mesh=mesh)
    assert sess._path == "kernel_fused"
    while not sess.done:
        sess.step()
    inc = sess.result()
    assert _tb(inc.final) == _tb(b.final)
    assert _tb(inc.snapshots) == _tb(b.snapshots)


# ---------------------------------------------------------------------------
# padding discipline + dispatch accounting
# ---------------------------------------------------------------------------

def test_fused_mxu_padding_spy(shards):
    """The kernel's accumulator layout pads G→×128 and A→×8 (MXU tiling,
    docs/KERNELS.md), reductions run over UNPADDED [L, A] values, and
    padding never leaks into results."""
    from unittest import mock

    g = _glas()["bundle"]
    cols = _flat_cols(shards)
    seen = []
    orig = FK._chunk_contrib

    def spy(fs, meta_row, chunk, msk, L):
        seen.append(meta_row)
        out = orig(fs, meta_row, chunk, msk, L)
        # contributions arrive already padded to the accumulator layout
        assert all(d.shape[1] % 8 == 0 or d.shape[1] == 1 for d in out)
        return out

    ref, _ = _fold_scan(g, cols)
    with mock.patch.object(FK, "_chunk_contrib", side_effect=spy):
        st = SC.fused_round_step(g, g.init(), cols)
    for kind, A, A_pad, G, G_pad in seen:
        assert A_pad % 8 == 0 and A_pad >= A
        if kind == "group":
            assert G_pad % 128 == 0 and G_pad >= G
    assert {m[0] for m in seen} == {"scalar", "group"}
    assert _tb(st) == _tb(ref)  # padding leaked nowhere


@pytest.mark.parametrize("name", ["group", "bundle"])
def test_fused_mxu_one_hot_parity(shards, name):
    """use_mxu=True (one-hot matmul bucket accumulation) vs the default
    gather lowering, under interpret mode: the matmul re-associates the
    per-bucket sums, so the contract is allclose — not bitwise — against
    both the default kernel and the scan fold."""
    g = _glas()[name]
    cols = _flat_cols(shards)
    ref, _ = _fold_scan(g, cols)
    base = SC.fused_round_step(g, g.init(), cols)
    try:
        mxu = SC.fused_round_step(g, g.init(), cols, use_mxu=True)
    except Exception as e:  # pragma: no cover - backend-dependent pads
        pytest.skip(f"one-hot pad shapes infeasible in interpret mode: {e}")
    for got, want in ((mxu, base), (mxu, ref)):
        for x, y in zip(jax.tree.leaves(got), jax.tree.leaves(want)):
            np.testing.assert_allclose(np.asarray(x), np.asarray(y),
                                       rtol=1e-5, atol=1e-3)


def test_fused_single_dispatch_accounting(shards, raw):
    """One pallas_call per round-slice for a whole bundle — counted
    structurally under eval_shape, plain and encoded alike."""
    g = _glas()["bundle"]
    cols = _flat_cols(shards)
    encs = _encodings(raw)
    feed = _encode_cols(cols, encs)
    with FK.count_dispatches() as box:
        jax.eval_shape(lambda s, c: SC.fused_round_step(g, s, c, encs),
                       g.init(), feed)
    assert box[0] == 1
    with FK.count_dispatches() as box:
        jax.eval_shape(lambda c: SC.fused_prefix_states(_glas()["scalar"], c),
                       cols)
    assert box[0] == 1


def test_fused_available_gates():
    d = 100.0
    fused_ok = gla.make_sum_gla(lambda c: c["x"], lambda c: c["x"] * 0 + 1,
                                d_total=d)
    assert SC.fused_available(fused_ok)
    multiple = gla.make_sum_gla(tpch.q1_func, tpch.q1_cond, d_total=d,
                                num_aggs=4, estimator="multiple")
    assert not SC.fused_available(multiple)
    from repro.data.source import ColumnSpec
    trailing = (ColumnSpec(name="x", dtype="float32", trailing=(3,)),)
    assert not SC.fused_available(fused_ok, trailing)
