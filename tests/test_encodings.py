"""Column encodings (repro/data/encodings.py): exact round-trips for the
dictionary and bit-packed formats (hypothesis properties), EncodedSource's
logical-spec/fingerprint contract, and the physical-stream byte math the
audit's bytes_moved check certifies (DESIGN.md §12)."""
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.data import encodings as ENC
from repro.data import tpch
from repro.data.source import EncodedSource, InMemorySource

ROWS = 4_096


def _shards(rows=ROWS, parts=2, seed=9):
    import jax
    import jax.numpy as jnp

    from repro.core import randomize

    cols = tpch.generate_lineitem(rows, seed=seed)
    parts_d = randomize.randomize_global(
        {k: jnp.asarray(v) for k, v in cols.items()}, jax.random.key(seed),
        parts)
    packed = randomize.pack_partitions(parts_d, chunk_len=128)
    return {k: np.asarray(v) for k, v in packed.items()}


# ---------------------------------------------------------------------------
# round-trip properties
# ---------------------------------------------------------------------------

@settings(max_examples=25, deadline=None)
@given(st.lists(st.floats(-50.0, 50.0), min_size=1, max_size=8),
       st.integers(1, 64))
def test_dict_roundtrip_property(values, reps):
    """encode(decode) is the identity for any float vocabulary that fits
    the code dtype — the decode is a table gather, bit-exact."""
    vocab = np.asarray(sorted(set(np.float32(v) for v in values)),
                       np.float32)
    arr = np.tile(vocab, reps).astype(np.float32)
    enc = ENC.dict_encoding_for(arr)
    codes = ENC.encode_array(arr, enc)
    assert codes.dtype == np.dtype(enc.code_dtype)
    dec = np.asarray(ENC.decode_block(codes, enc))
    assert dec.tobytes() == arr.tobytes()


@settings(max_examples=25, deadline=None)
@given(st.integers(0, 4), st.integers(1, 16))
def test_bitpack_roundtrip_property(bits_idx, blocks):
    """shift-and-mask decode inverts the little-endian pack for every
    supported width, at any multiple-of-lanes length."""
    bits = [1, 2, 4, 8, 16][bits_idx]
    enc = ENC.BitPackedEncoding(bits=bits)
    rng = np.random.default_rng(bits * 1000 + blocks)
    arr = rng.integers(0, 1 << bits, enc.lanes * blocks).astype(np.int32)
    packed = ENC.encode_array(arr, enc)
    assert packed.dtype == np.int32 and packed.size == arr.size // enc.lanes
    dec = np.asarray(ENC.decode_block(packed, enc))
    assert dec.tobytes() == arr.tobytes()


def test_encode_array_validates():
    with pytest.raises(ValueError):
        ENC.encode_array(np.asarray([0.5], np.float32),
                         ENC.DictEncoding(values=(0.25,),
                                          code_dtype="int8",
                                          logical_dtype="float32"))
    with pytest.raises(ValueError):  # out of bit range
        ENC.encode_array(np.asarray([4] * 16, np.int32),
                         ENC.BitPackedEncoding(bits=2))
    with pytest.raises(ValueError):  # length not a multiple of lanes
        ENC.encode_array(np.asarray([1, 0, 1], np.int32),
                         ENC.BitPackedEncoding(bits=2))


# ---------------------------------------------------------------------------
# EncodedSource: logical spec, fingerprint, physical stream
# ---------------------------------------------------------------------------

def _encodings_for(shards):
    return {"discount": ENC.dict_encoding_for(shards["discount"]),
            "shipdate": ENC.BitPackedEncoding(bits=16),
            "rfls": ENC.BitPackedEncoding(bits=2)}


def test_encoded_source_logical_spec_and_fingerprint():
    """The encoded source presents the PLAIN logical schema and hashes the
    decoded stream: fingerprints match the in-memory source exactly, so
    checkpoints resume across plain<->encoded swaps (DESIGN.md §12)."""
    shards = _shards()
    esrc = EncodedSource.from_shards(shards, _encodings_for(shards))
    plain = InMemorySource(shards)
    assert esrc.spec == plain.spec
    assert esrc.fingerprint() == plain.fingerprint()
    assert not esrc.resident


def test_encoded_source_streams_fewer_bytes():
    """step_slice_like (the physical stream) must be measurably smaller
    than spec.slice_like (the logical columns) — what bytes_moved pins."""
    import jax

    shards = _shards()
    esrc = EncodedSource.from_shards(shards, _encodings_for(shards))

    def nbytes(tree):
        return sum(int(np.prod(v.shape)) * np.dtype(v.dtype).itemsize
                   for v in jax.tree.leaves(tree))

    phys, logical = nbytes(esrc.step_slice_like(4)), nbytes(
        esrc.spec.slice_like(4))
    assert phys < 0.95 * logical
    # decoded slices equal the plain slices bit-for-bit
    sl = ENC.decode_cols(esrc.slice_cols(0, 4), esrc.encodings)
    for k, v in plainslice(shards, 4).items():
        assert np.asarray(sl[k]).tobytes() == v.tobytes(), k


def plainslice(shards, hi):
    return {k: v[:, :hi] for k, v in shards.items()}


def test_encoded_source_save_load_roundtrip(tmp_path):
    shards = _shards()
    encs = _encodings_for(shards)
    EncodedSource.save(shards, tmp_path / "enc", encs)
    src = EncodedSource(tmp_path / "enc")
    ref = EncodedSource.from_shards(shards, encs)
    assert src.spec == ref.spec
    assert src.fingerprint() == ref.fingerprint()
