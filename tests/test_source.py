"""Streaming chunk sources (DESIGN.md §8): out-of-core scans must be
bitwise-identical to the in-memory path on both engines, device/host
footprint O(slice), fingerprints must reject same-shape impostors, and
ragged tails must pad via _mask without changing finals."""
import subprocess
import sys
import textwrap
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import engine, gla, randomize
from repro.core import session as S
from repro.data import source as DS
from repro.data import tpch

SRC = Path(__file__).resolve().parents[1] / "src"
ROWS = 40_000          # NOT divisible by PARTS * CHUNK: real ragged tails
PARTS = 4
CHUNK = 256
ROUNDS = 8

try:
    import pyarrow  # noqa: F401

    HAVE_PYARROW = True
except ImportError:  # optional dependency — ParquetSource tests skip
    HAVE_PYARROW = False


def _tobytes(tree):
    return [np.asarray(x).tobytes() for x in jax.tree.leaves(tree)]


def _make_parts(rows=ROWS, seed=11):
    cols = tpch.generate_lineitem(rows, seed=seed)
    return cols, randomize.randomize_global(
        {k: jnp.asarray(v) for k, v in cols.items()}, jax.random.key(2),
        PARTS)


@pytest.fixture(scope="module")
def parts():
    return _make_parts()[1]


@pytest.fixture(scope="module")
def shards(parts):
    n_chunks = -(-ROWS // PARTS // CHUNK)
    return randomize.pack_partitions(
        parts, chunk_len=CHUNK, min_chunks=-(-n_chunks // ROUNDS) * ROUNDS)


@pytest.fixture(scope="module")
def npy_dir(shards, tmp_path_factory):
    d = tmp_path_factory.mktemp("npy_cols")
    return DS.NpyMmapSource.save(shards, d)


def _wide_q6(d_total=ROWS * 1.0):
    def func(c):
        return c["quantity"]

    def cond(c):
        sd = c["shipdate"]
        return ((sd >= 0) & (sd < 1460)).astype(jnp.float32)

    return gla.make_sum_gla(func, cond, d_total=d_total)


def _q1_small(d_total=ROWS * 1.0):
    return gla.make_groupby_gla(
        tpch.q1_func, tpch.q1_cond, tpch.q1_group_small, num_groups=4,
        d_total=d_total, num_aggs=4)


# ---------------------------------------------------------------------------
# the source contract
# ---------------------------------------------------------------------------

def test_as_source_wraps_dict_passthrough(shards):
    src = DS.as_source(shards)
    assert isinstance(src, DS.InMemorySource) and src.resident
    assert DS.as_source(src) is src
    with pytest.raises(TypeError):
        DS.as_source([1, 2, 3])
    P, C, L = shards["_mask"].shape
    assert (src.spec.P, src.spec.C, src.spec.L) == (P, C, L)


def test_npy_source_reconstructs_slices_and_mask_sums(shards, npy_dir):
    src = DS.NpyMmapSource(npy_dir)
    mem = DS.InMemorySource(shards)
    assert src.spec == mem.spec
    C = src.spec.C
    for lo, hi in [(0, 1), (1, 3), (C - 2, C)]:
        a, b = src.slice_cols(lo, hi), mem.slice_cols(lo, hi)
        assert sorted(a) == sorted(b)
        for k in a:
            np.testing.assert_array_equal(np.asarray(a[k]), np.asarray(b[k]))
    # per-chunk tuple counts: exact integers, identical to the device sum
    np.testing.assert_array_equal(
        src.mask_chunk_sums(),
        np.asarray(jnp.sum(shards["_mask"], axis=2), np.float64))


def test_fingerprint_is_storage_independent_and_content_sensitive(
        shards, npy_dir):
    src = DS.NpyMmapSource(npy_dir)
    assert src.fingerprint() == DS.InMemorySource(shards).fingerprint()
    # same shapes, different content -> different fingerprint
    _, parts_o = _make_parts(seed=99)
    shards_o = randomize.pack_partitions(
        parts_o, chunk_len=CHUNK, min_chunks=shards["_mask"].shape[1])
    assert shards_o["_mask"].shape == shards["_mask"].shape
    assert (DS.InMemorySource(shards_o).fingerprint()
            != src.fingerprint())


# ---------------------------------------------------------------------------
# bitwise equivalence with the in-memory path (vmapped engine)
# ---------------------------------------------------------------------------

def test_npy_streaming_matches_inmemory_bitwise(shards, npy_dir):
    """The acceptance property: an out-of-core scan over mmap'd .npy
    columns produces finals, snapshots AND per-round bounds byte-for-byte
    equal to the classic fused in-memory program."""
    q = _wide_q6()
    fused = engine.run_query(q, shards, rounds=ROUNDS, emit="chunk")
    stream = engine.run_query(q, DS.NpyMmapSource(npy_dir), rounds=ROUNDS,
                              emit="chunk")
    assert _tobytes(stream.final) == _tobytes(fused.final)
    assert _tobytes(stream.snapshots) == _tobytes(fused.snapshots)
    assert _tobytes(stream.estimates) == _tobytes(fused.estimates)


def test_npy_streaming_kernel_group_bitwise(shards, npy_dir):
    gq = _q1_small()
    fused = engine.run_query(gq, shards, rounds=ROUNDS, emit="kernel")
    stream = engine.run_query(gq, DS.NpyMmapSource(npy_dir), rounds=ROUNDS,
                              emit="kernel")
    assert _tobytes(stream.final) == _tobytes(fused.final)
    assert _tobytes(stream.snapshots) == _tobytes(fused.snapshots)


def test_streaming_multiquery_bundle_matches_solo(shards, npy_dir):
    """run_queries over a source: every member bitwise vs its solo run."""
    qs = [_wide_q6(), _q1_small()]
    solo = [engine.run_query(g, shards, rounds=ROUNDS, emit="round")
            for g in qs]
    multi = engine.run_queries(qs, DS.NpyMmapSource(npy_dir), rounds=ROUNDS,
                               emit="round")
    for s, m in zip(solo, multi):
        assert _tobytes(s.final) == _tobytes(m.final)
        assert _tobytes(s.snapshots) == _tobytes(m.snapshots)


@pytest.mark.skipif(not HAVE_PYARROW, reason="pyarrow not installed "
                    "(optional ParquetSource dependency)")
def test_parquet_source_matches_inmemory_bitwise(parts, shards, tmp_path):
    """Parquet partitions of live rows reconstruct exactly the
    pack_partitions layout — runs come out bitwise-identical."""
    d = DS.ParquetSource.save(parts, tmp_path / "pq",
                              row_group_len=3 * CHUNK)  # non-aligned groups
    src = DS.ParquetSource(d, chunk_len=CHUNK,
                           min_chunks=shards["_mask"].shape[1])
    assert src.spec == DS.InMemorySource(shards).spec
    assert src.fingerprint() == DS.InMemorySource(shards).fingerprint()
    q = _wide_q6()
    fused = engine.run_query(q, shards, rounds=ROUNDS, emit="chunk")
    stream = engine.run_query(q, src, rounds=ROUNDS, emit="chunk")
    assert _tobytes(stream.final) == _tobytes(fused.final)
    assert _tobytes(stream.snapshots) == _tobytes(fused.snapshots)
    assert _tobytes(stream.estimates) == _tobytes(fused.estimates)


# ---------------------------------------------------------------------------
# streaming discipline: contracts, prefetch, accounting
# ---------------------------------------------------------------------------

def test_streaming_requires_incremental_config(npy_dir):
    src = DS.NpyMmapSource(npy_dir)
    q = _wide_q6()
    with pytest.raises(ValueError, match="incrementally-steppable"):
        S.Session(q, src, rounds=4, mode="sync")
    sched = engine.straggler_schedule(PARTS, src.spec.C, 4,
                                      speeds=[1, 1, 2, 4], seed=3)
    with pytest.raises(ValueError, match="incrementally-steppable"):
        S.Session(q, src, schedule=sched)


def test_streaming_run_without_stop_is_incremental(npy_dir, tmp_path):
    """No stopping rule + streaming source: run() drives the incremental
    discipline (there is nothing resident for a fused program), stays
    pausable, and completes every round."""
    src = DS.NpyMmapSource(npy_dir)
    q = _wide_q6()
    sess = S.Session(q, src, rounds=ROUNDS, emit="chunk")
    res = sess.run()
    assert sess.steps_taken == ROUNDS
    assert not sess._fused
    assert np.asarray(res.estimates.estimate).shape[0] == ROUNDS


def test_streaming_prefetch_reads_round_slices_only(shards, npy_dir):
    """Each step consumes exactly one prefetched round-slice; the source
    is never asked for more than one slice ahead (double buffering), so
    host reads and device residency stay O(slice)."""
    calls = []

    class Spy(DS.NpyMmapSource):
        def slice_cols(self, lo, hi):
            calls.append((lo, hi))
            return super().slice_cols(lo, hi)

    src = Spy(npy_dir)
    q = _wide_q6()
    sess = S.Session(q, src, rounds=ROUNDS, emit="chunk")
    sess.step()
    # first step fetches slice 0 and schedules slice 1 — nothing further
    sched_calls = [c for c in calls if c[1] - c[0] < src.spec.C]
    assert len(sched_calls) <= 2
    C, per = src.spec.C, src.spec.C // ROUNDS
    assert sched_calls[0] == (0, per)
    sess.run()
    sched_calls = [c for c in calls if c[1] - c[0] < src.spec.C]
    assert sched_calls == [(r * per, (r + 1) * per) for r in range(ROUNDS)]


def test_streaming_snapshots_off_matches_fused_contract(shards, npy_dir,
                                                        tmp_path):
    """snapshots=False on the incremental/streaming path: no per-round
    history is retained — result carries None snapshots/estimates like
    the fused program — and the final stays bitwise vs the resident
    snapshots=False run, including across pause/resume."""
    q = _wide_q6()
    fused = engine.run_query(q, shards, rounds=ROUNDS, emit="chunk",
                             snapshots=False)
    assert fused.snapshots is None and fused.estimates is None
    stream = engine.run_query(q, DS.NpyMmapSource(npy_dir), rounds=ROUNDS,
                              emit="chunk", snapshots=False)
    assert stream.snapshots is None and stream.estimates is None
    assert _tobytes(stream.final) == _tobytes(fused.final)
    # stop rules still see per-round estimates (transient, not retained)
    sess = S.Session(q, DS.NpyMmapSource(npy_dir), rounds=ROUNDS,
                     emit="chunk", snapshots=False, stop=S.rel_width(0.01))
    prog = sess.step()
    assert prog.estimates is not None
    ck = tmp_path / "nosnap.ckpt"
    sess.pause(ck)
    back = S.Session.resume(ck, q, DS.NpyMmapSource(npy_dir))
    while not back.done:
        back.step()
    res = back.result()
    assert res.snapshots is None and res.estimates is None
    assert _tobytes(res.final) == _tobytes(fused.final)


def test_streaming_scanned_accounting_matches_inmemory(shards, npy_dir):
    """budget(max_tuples) sees the same scanned counts with and without
    residency — the per-slice mask sums come from the source."""
    q = _wide_q6()
    stop = S.budget(max_tuples=ROWS / 2)
    mem = S.Session(q, shards, rounds=ROUNDS, emit="chunk", stop=stop)
    mem.run()
    stream = S.Session(q, DS.NpyMmapSource(npy_dir), rounds=ROUNDS,
                       emit="chunk", stop=stop)
    stream.run()
    assert mem.steps_taken == stream.steps_taken
    p_mem = S.Session(q, shards, rounds=ROUNDS, emit="chunk")
    p_str = S.Session(q, DS.NpyMmapSource(npy_dir), rounds=ROUNDS,
                      emit="chunk")
    assert p_mem.step().scanned == p_str.step().scanned > 0


# ---------------------------------------------------------------------------
# checkpoint fingerprint (satellite bugfix: same-shape impostors)
# ---------------------------------------------------------------------------

def test_resume_rejects_same_shape_different_data(shards, tmp_path):
    q = _wide_q6()
    sess = S.Session(q, shards, rounds=ROUNDS, emit="chunk")
    sess.step()
    ck = tmp_path / "fp.ckpt"
    sess.pause(ck)
    _, parts_o = _make_parts(seed=99)
    shards_o = randomize.pack_partitions(
        parts_o, chunk_len=CHUNK, min_chunks=shards["_mask"].shape[1])
    assert shards_o["_mask"].shape == shards["_mask"].shape
    with pytest.raises(ValueError, match="fingerprint"):
        S.Session.resume(ck, q, shards_o)


def test_resume_across_source_backends_bitwise(shards, npy_dir, tmp_path):
    """Pause over the mmap source, resume over the in-memory copy of the
    SAME data (and vice versa): fingerprints match, finals bitwise."""
    q = _wide_q6()
    full = engine.run_query(q, shards, rounds=ROUNDS, emit="chunk")
    sess = S.Session(q, DS.NpyMmapSource(npy_dir), rounds=ROUNDS,
                     emit="chunk")
    for _ in range(ROUNDS // 2):
        sess.step()
    ck = tmp_path / "xsrc.ckpt"
    sess.pause(ck)
    back = S.Session.resume(ck, q, shards)       # npy -> in-memory
    while not back.done:
        back.step()
    assert _tobytes(back.result().final) == _tobytes(full.final)
    assert _tobytes(back.result().snapshots) == _tobytes(full.snapshots)
    sess2 = S.Session(q, shards, rounds=ROUNDS, emit="chunk")
    sess2.step()
    ck2 = tmp_path / "xsrc2.ckpt"
    sess2.pause(ck2)
    back2 = S.Session.resume(ck2, q, DS.NpyMmapSource(npy_dir))
    while not back2.done:                         # in-memory -> npy
        back2.step()
    assert _tobytes(back2.result().final) == _tobytes(full.final)


# ---------------------------------------------------------------------------
# ragged tails (satellite: rows not divisible by P x chunk)
# ---------------------------------------------------------------------------

def _ragged_fixture():
    rows = PARTS * 16 * CHUNK - 777   # ragged tail in the last chunks
    parts = _make_parts(rows=rows, seed=7)[1]
    exact = randomize.pack_partitions(parts, chunk_len=CHUNK,
                                      min_chunks=16)
    padded = randomize.pack_partitions(parts, chunk_len=CHUNK,
                                       min_chunks=16 + ROUNDS)
    q = _wide_q6(d_total=float(rows))
    return rows, exact, padded, q


def test_ragged_tail_padding_never_changes_finals(tmp_path):
    """_mask-padded slots contribute exact zeros: the same live rows give
    bitwise-equal finals whether the tail is padded minimally or with
    whole extra masked chunks, resident or streamed, and across a
    pause/resume boundary."""
    _, exact, padded, q = _ragged_fixture()
    res_exact = engine.run_query(q, exact, rounds=ROUNDS, emit="chunk")
    res_pad = engine.run_query(q, padded, rounds=ROUNDS, emit="chunk")
    assert _tobytes(res_pad.final) == _tobytes(res_exact.final)
    # streamed ragged scan == resident ragged scan, snapshots included
    d = DS.NpyMmapSource.save(exact, tmp_path / "ragged_npy")
    stream = engine.run_query(q, DS.NpyMmapSource(d), rounds=ROUNDS,
                              emit="chunk")
    assert _tobytes(stream.final) == _tobytes(res_exact.final)
    assert _tobytes(stream.snapshots) == _tobytes(res_exact.snapshots)
    # across a pause/resume boundary (the tail rounds replay the padding)
    sess = S.Session(q, DS.NpyMmapSource(d), rounds=ROUNDS, emit="chunk")
    for _ in range(ROUNDS - 2):
        sess.step()
    ck = tmp_path / "ragged.ckpt"
    sess.pause(ck)
    back = S.Session.resume(ck, q, DS.NpyMmapSource(d))
    while not back.done:
        back.step()
    assert _tobytes(back.result().final) == _tobytes(res_exact.final)
    # and the padded layout agrees with the float64 oracle
    oracle = tpch.exact_answer(
        DS.InMemorySource(exact), lambda c: c["quantity"],
        lambda c: ((c["shipdate"] >= 0)
                   & (c["shipdate"] < 1460)).astype(jnp.float32))
    np.testing.assert_allclose(float(np.asarray(res_exact.final).ravel()[0]),
                               oracle[0], rtol=1e-6)


@pytest.mark.skipif(not HAVE_PYARROW, reason="pyarrow not installed "
                    "(optional ParquetSource dependency)")
def test_ragged_tail_parquet_bitwise(tmp_path):
    rows = PARTS * 16 * CHUNK - 777
    _, parts = _make_parts(rows=rows, seed=7)
    exact = randomize.pack_partitions(parts, chunk_len=CHUNK, min_chunks=16)
    q = _wide_q6(d_total=float(rows))
    res_exact = engine.run_query(q, exact, rounds=ROUNDS, emit="chunk")
    d = DS.ParquetSource.save(parts, tmp_path / "ragged_pq")
    src = DS.ParquetSource(d, chunk_len=CHUNK, min_chunks=16)
    stream = engine.run_query(q, src, rounds=ROUNDS, emit="chunk")
    assert _tobytes(stream.final) == _tobytes(res_exact.final)
    assert _tobytes(stream.snapshots) == _tobytes(res_exact.snapshots)


# ---------------------------------------------------------------------------
# streaming exact_answer (satellite: the float64 oracle out-of-core)
# ---------------------------------------------------------------------------

def test_exact_answer_streams_and_matches_flat(npy_dir):
    cols = tpch.generate_lineitem(ROWS, seed=11)
    flat = tpch.exact_answer(cols, tpch.q6_func,
                             tpch.q6_cond(tpch.Q6_LOW_WINDOW))
    # tiny batches force many accumulation steps
    batched = tpch.exact_answer(cols, tpch.q6_func,
                                tpch.q6_cond(tpch.Q6_LOW_WINDOW),
                                batch_rows=1111)
    np.testing.assert_allclose(batched, flat, rtol=1e-12)
    # over the source API: padded rows are masked out of the reference
    src = DS.NpyMmapSource(npy_dir)
    streamed = tpch.exact_answer(src, tpch.q6_func,
                                 tpch.q6_cond(tpch.Q6_LOW_WINDOW))
    np.testing.assert_allclose(streamed, flat, rtol=1e-9)
    # group-by reference over a source
    g_flat = tpch.exact_answer(cols, tpch.q1_func, tpch.q1_cond,
                               tpch.q1_group_small, 4)
    g_stream = tpch.exact_answer(src, tpch.q1_func, tpch.q1_cond,
                                 tpch.q1_group_small, 4)
    np.testing.assert_allclose(g_stream, g_flat, rtol=1e-9)


# ---------------------------------------------------------------------------
# sharded engine
# ---------------------------------------------------------------------------

@pytest.mark.skipif(jax.device_count() < 8,
                    reason="needs 8 devices (CI multi-device job sets "
                           "XLA_FLAGS=--xla_force_host_platform_device_count=8)")
def test_sharded_streaming_matches_inmemory_inprocess(tmp_path):
    """Streaming session on a real mesh: slices land per-device via
    shard_engine.device_put_slice; results bitwise vs the fused sharded
    in-memory run, including after pause/resume."""
    rows = 8 * 16 * 128 - 555
    cols = tpch.generate_lineitem(rows, seed=4)
    parts = randomize.randomize_global(
        {k: jnp.asarray(v) for k, v in cols.items()}, jax.random.key(1), 8)
    shards8 = randomize.pack_partitions(parts, chunk_len=128, min_chunks=16)
    d = DS.NpyMmapSource.save(shards8, tmp_path / "npy8")
    mesh = jax.make_mesh((8,), ("data",))
    q = _wide_q6(d_total=float(rows))
    fused = engine.run_query(q, shards8, rounds=8, emit="chunk", mesh=mesh)
    stream = engine.run_query(q, DS.NpyMmapSource(d), rounds=8,
                              emit="chunk", mesh=mesh)
    assert _tobytes(stream.final) == _tobytes(fused.final)
    assert _tobytes(stream.snapshots) == _tobytes(fused.snapshots)
    assert _tobytes(stream.estimates) == _tobytes(fused.estimates)
    half = S.Session(q, DS.NpyMmapSource(d), rounds=8, emit="chunk",
                     mesh=mesh)
    for _ in range(4):
        half.step()
    ck = tmp_path / "shard-stream.ckpt"
    half.pause(ck)
    back = S.Session.resume(ck, q, DS.NpyMmapSource(d), mesh=mesh)
    while not back.done:
        back.step()
    assert _tobytes(back.result().final) == _tobytes(fused.final)


@pytest.mark.slow
def test_sharded_streaming_subprocess(tmp_path):
    """Single-device environments: the same sharded-streaming assertions
    in a subprocess with 8 fake devices (ragged rows, mmap source)."""
    code = textwrap.dedent("""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
        import sys; sys.path.insert(0, %r)
        import numpy as np, jax, jax.numpy as jnp
        from repro.core import engine, gla, randomize, session as S
        from repro.data import tpch, source as DS
        rows = 8 * 16 * 128 - 555
        cols = tpch.generate_lineitem(rows, seed=4)
        parts = randomize.randomize_global(
            {k: jnp.asarray(v) for k, v in cols.items()}, jax.random.key(1), 8)
        shards = randomize.pack_partitions(parts, chunk_len=128, min_chunks=16)
        d = DS.NpyMmapSource.save(shards, %r)
        mesh = jax.make_mesh((8,), ("data",))
        def func(c): return c["quantity"]
        def cond(c):
            return ((c["shipdate"] >= 0) & (c["shipdate"] < 1460)).astype(jnp.float32)
        q = gla.make_sum_gla(func, cond, d_total=float(rows))
        fused = engine.run_query(q, shards, rounds=8, emit="chunk", mesh=mesh)
        stream = engine.run_query(q, DS.NpyMmapSource(d), rounds=8,
                                  emit="chunk", mesh=mesh)
        for a, b in zip(jax.tree.leaves(stream.final),
                        jax.tree.leaves(fused.final)):
            assert np.asarray(a).tobytes() == np.asarray(b).tobytes()
        for a, b in zip(jax.tree.leaves(stream.snapshots),
                        jax.tree.leaves(fused.snapshots)):
            assert np.asarray(a).tobytes() == np.asarray(b).tobytes()
        print("OK")
    """ % (str(SRC), str(tmp_path / "npy8")))
    r = subprocess.run([sys.executable, "-c", code], capture_output=True,
                       text=True, timeout=600)
    assert r.returncode == 0, r.stderr[-3000:]
    assert "OK" in r.stdout
