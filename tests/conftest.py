import sys
from pathlib import Path

# allow running without PYTHONPATH=src (never touches jax device config)
SRC = Path(__file__).resolve().parents[1] / "src"
if str(SRC) not in sys.path:
    sys.path.insert(0, str(SRC))


def pytest_configure(config):
    config.addinivalue_line(
        "markers", "slow: multi-minute subprocess tests (fake-device meshes)")


# ---------------------------------------------------------------------------
# hypothesis fallback shim: when hypothesis is not installed, provide a
# fixed-seed stand-in so the property tests still collect and run.  Real
# hypothesis (shrinking, example database) is strictly better — install it
# via requirements-optional.txt; this shim only keeps the tier-1 suite
# dependency-light.
# ---------------------------------------------------------------------------
try:
    import hypothesis  # noqa: F401
except ImportError:
    import functools
    import random
    import types

    class _Strategy:
        def __init__(self, draw):
            self.draw = draw

    def _floats(min_value=-1e9, max_value=1e9):
        return _Strategy(lambda rnd: rnd.uniform(min_value, max_value))

    def _integers(min_value=0, max_value=1 << 30):
        return _Strategy(lambda rnd: rnd.randint(min_value, max_value))

    def _lists(elements, min_size=0, max_size=10):
        return _Strategy(
            lambda rnd: [elements.draw(rnd)
                         for _ in range(rnd.randint(min_size, max_size))])

    def _given(*strategies):
        def deco(f):
            @functools.wraps(f)
            def wrapper(*args, **kwargs):
                # @settings is applied above @given, i.e. onto this wrapper
                n = getattr(wrapper, "_shim_max_examples", 10)
                for i in range(n):
                    rnd = random.Random(0x5EED + i)
                    drawn = [s.draw(rnd) for s in strategies]
                    f(*args, *drawn, **kwargs)
            # pytest must see the no-arg signature, not follow __wrapped__
            # back to the original and mistake its params for fixtures
            del wrapper.__wrapped__
            return wrapper
        return deco

    def _settings(max_examples=10, **_ignored):
        def deco(f):
            f._shim_max_examples = max_examples
            return f
        return deco

    _st = types.ModuleType("hypothesis.strategies")
    _st.floats, _st.integers, _st.lists = _floats, _integers, _lists
    _hyp = types.ModuleType("hypothesis")
    _hyp.given, _hyp.settings, _hyp.strategies = _given, _settings, _st
    _hyp.__is_shim__ = True
    sys.modules["hypothesis"] = _hyp
    sys.modules["hypothesis.strategies"] = _st
