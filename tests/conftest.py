import sys
from pathlib import Path

# allow running without PYTHONPATH=src (never touches jax device config)
SRC = Path(__file__).resolve().parents[1] / "src"
if str(SRC) not in sys.path:
    sys.path.insert(0, str(SRC))
