"""Statistical correctness of the paper's estimators (Lemmas 1–2, §4.3).

Property tests (hypothesis) + Monte-Carlo checks:
  * full-scan exactness: estimate == exact result, variance == 0
  * unbiasedness of the sampling estimator over random prefixes
  * CI coverage ≈ the nominal confidence level
  * merge associativity/commutativity (the GLA contract)
  * the corrected Alg. 1 (count = scanned items) — the paper erratum
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import engine, estimators as E, gla, randomize
from repro.data import tpch

jax.config.update("jax_platform_name", "cpu")


def _shards(rows=40_000, parts=4, chunk=256, seed=3):
    cols = tpch.generate_lineitem(rows, seed=seed)
    parts_ = randomize.randomize_global(
        {k: jnp.asarray(v) for k, v in cols.items()}, jax.random.key(seed),
        parts)
    return cols, randomize.pack_partitions(parts_, chunk_len=chunk)


def test_full_scan_exact_and_zero_width():
    rows = 40_000
    cols, shards = _shards(rows)
    g = gla.make_sum_gla(tpch.q6_func, tpch.q6_cond(tpch.Q6_LOW_WINDOW),
                         d_total=float(rows))
    res = engine.run_query(g, shards, rounds=8)
    exact = tpch.exact_answer(cols, tpch.q6_func,
                              tpch.q6_cond(tpch.Q6_LOW_WINDOW))[0]
    est = res.estimates
    # last round = full scan: collapse on the exact answer (paper §4.3.1)
    np.testing.assert_allclose(float(res.final), exact, rtol=2e-4)
    np.testing.assert_allclose(np.asarray(est.estimate)[-1], exact, rtol=2e-4)
    assert float(np.asarray(est.upper)[-1] - np.asarray(est.lower)[-1]) < 1e-3
    # widths shrink monotonically in expectation; check endpoints
    widths = np.asarray(est.upper) - np.asarray(est.lower)
    assert widths[0] > widths[-1]


def test_unbiasedness_monte_carlo():
    """E[X] over random data orders ≈ exact aggregate (Lemma 1).

    Uses the Q1 predicate (~3.6% selectivity) so the exact answer is
    non-zero at this scale; the Q6 needle-in-haystack case is covered by
    the convergence benchmark at 1M rows.
    """
    rows, prefix = 4_000, 800
    cols = tpch.generate_lineitem(rows, seed=1)
    chunk = {k: jnp.asarray(v) for k, v in cols.items()}
    chunk["_mask"] = jnp.ones(rows, jnp.float32)
    func = np.asarray(tpch.q6_func(chunk), np.float64)
    condv = np.asarray(tpch.q1_cond(chunk), np.float64)
    g = func * condv
    exact = g.sum()
    rng = np.random.default_rng(0)
    ests = []
    for _ in range(300):
        perm = rng.permutation(rows)[:prefix]
        ests.append(rows / prefix * g[perm].sum())
    err = abs(np.mean(ests) - exact) / abs(exact)
    # MC standard error of the mean
    se = np.std(ests) / np.sqrt(len(ests)) / abs(exact)
    assert err < 4 * se + 0.01


def test_ci_coverage():
    """95% CI covers the truth ~95% of the time (normal-approx tolerance)."""
    rows, prefix = 5_000, 1_000
    rng = np.random.default_rng(42)
    vals = rng.lognormal(0.0, 1.0, rows)
    exact = vals.sum()
    hits = 0
    trials = 200
    for t in range(trials):
        perm = rng.permutation(rows)[:prefix]
        s, sq = vals[perm].sum(), (vals[perm] ** 2).sum()
        est = E.horvitz_estimate(jnp.asarray(s), jnp.asarray(float(prefix)),
                                 float(rows))
        var = E.variance_estimate(jnp.asarray(s), jnp.asarray(sq),
                                  jnp.asarray(float(prefix)), float(rows))
        lo, hi = E.normal_bounds(est, var, 0.95)
        hits += float(lo) <= exact <= float(hi)
    assert 0.88 <= hits / trials <= 1.0


@settings(max_examples=25, deadline=None)
@given(st.lists(st.floats(-100, 100), min_size=3, max_size=3),
       st.lists(st.floats(-100, 100), min_size=3, max_size=3),
       st.lists(st.floats(-100, 100), min_size=3, max_size=3))
def test_merge_associative_commutative(a, b, c):
    def mk(v):
        return E.SumState(jnp.float32(v[0]), jnp.float32(abs(v[1])),
                          jnp.float32(abs(v[2])), jnp.float32(1.0))

    s1, s2, s3 = mk(a), mk(b), mk(c)
    m = E.sum_state_merge
    left = m(m(s1, s2), s3)
    right = m(s1, m(s2, s3))
    for x, y in zip(jax.tree.leaves(left), jax.tree.leaves(right)):
        np.testing.assert_allclose(np.asarray(x), np.asarray(y), rtol=1e-5)
    ab, ba = m(s1, s2), m(s2, s1)
    for x, y in zip(jax.tree.leaves(ab), jax.tree.leaves(ba)):
        np.testing.assert_allclose(np.asarray(x), np.asarray(y), rtol=1e-6)


def test_erratum_count_scanned_not_matched():
    """count must track scanned items (|S|), not predicate matches.

    With the paper-as-printed in-branch count, the variance factor
    (|D|-count) would not vanish at full scan for selective predicates.
    """
    rows = 10_000
    cols, shards = _shards(rows, seed=9)
    g = gla.make_sum_gla(tpch.q6_func, tpch.q6_cond(tpch.Q6_HIGH_WINDOW),
                         d_total=float(rows))
    res = engine.run_query(g, shards, rounds=4)
    st_ = res.snapshots
    scanned = float(np.asarray(st_.scanned)[-1])
    matched = float(np.asarray(st_.matched)[-1])
    assert scanned == pytest.approx(rows)
    assert matched < scanned  # selective predicate
    width = float(np.asarray(res.estimates.upper)[-1]
                  - np.asarray(res.estimates.lower)[-1])
    assert width < 1e-3


def test_variance_estimate_degenerate_sample_sizes():
    """|S| in {0, 1} leaves the variance undefined: the estimator must
    clamp to +inf (infinite-width bounds), never NaN (Eq. 4 divides by
    |S|^2(|S|-1) and multiplies by (|D|-|S|))."""
    d_total = 1000.0
    for s in (0.0, 1.0):
        var = E.variance_estimate(jnp.float32(0.0), jnp.float32(0.0),
                                  jnp.float32(s), d_total)
        assert np.isposinf(float(var)), (s, float(var))
        lo, hi = E.normal_bounds(jnp.float32(0.0), var, 0.95)
        assert not np.isnan(float(lo)) and not np.isnan(float(hi))
    # |S| = 2 is the smallest defined sample: finite and non-negative
    var2 = E.variance_estimate(jnp.float32(3.0), jnp.float32(5.0),
                               jnp.float32(2.0), d_total)
    assert np.isfinite(float(var2)) and float(var2) >= 0.0


def test_variance_estimate_fp_negative_clamps_to_zero():
    """A constant sample makes |S|*sumsq - sum^2 cancel to ~0; float error
    can drive it slightly negative.  The estimator clamps at 0 — bounds
    collapse instead of going NaN through sqrt(negative)."""
    s = 3.0
    c = 0.1  # 0.1 is inexact in binary: s*sumsq - sum^2 != 0 exactly
    var = E.variance_estimate(jnp.float32(s * c), jnp.float32(s * c * c),
                              jnp.float32(s), 10.0)
    assert float(var) >= 0.0
    lo, hi = E.normal_bounds(jnp.float32(s * c), var, 0.95)
    assert not np.isnan(float(lo)) and not np.isnan(float(hi))


def test_mult_estimate_zero_scanned_tuples():
    """Stratified estimator before any tuple arrives: estimate 0 with
    infinite (not NaN) bounds, per-partition EstimatorTerminate included."""
    st = E.mult_estimator_terminate(E.mult_state_zero(), d_local=250.0)
    assert float(st.est) == 0.0
    assert np.isposinf(float(st.estvar))
    est = E.mult_estimate(st, 0.95)
    assert not np.isnan(float(est.estimate))
    assert np.isneginf(float(est.lower)) and np.isposinf(float(est.upper))
    # an empty stratum (d_local == 0) must not generate NaN either
    st0 = E.mult_estimator_terminate(E.mult_state_zero(), d_local=0.0)
    e0 = E.mult_estimate(st0, 0.95)
    assert not np.isnan(float(e0.estimate))


def test_single_round_schedule_end_to_end():
    """rounds=1 (one snapshot at full scan) is a legal schedule on every
    emission path and for both estimator models: bounds collapse on the
    exact answer, never NaN."""
    rows = 8_000
    cols, shards = _shards(rows, seed=21)
    exact = tpch.exact_answer(cols, tpch.q6_func,
                              tpch.q6_cond(tpch.Q6_LOW_WINDOW))[0]
    for estimator in ("single", "multiple"):
        g = gla.make_sum_gla(tpch.q6_func, tpch.q6_cond(tpch.Q6_LOW_WINDOW),
                             d_total=float(rows), estimator=estimator)
        for emit in ("chunk", "round"):
            res = engine.run_query(g, shards, rounds=1, emit=emit)
            est = np.asarray(res.estimates.estimate)
            assert est.shape[0] == 1
            assert not np.any(np.isnan(est))
            np.testing.assert_allclose(est[-1], exact, rtol=2e-4)
            width = float(np.asarray(res.estimates.upper)[-1]
                          - np.asarray(res.estimates.lower)[-1])
            assert width < 1e-3


def test_multiple_estimator_empty_partition_no_nan():
    """A partition with zero live tuples (all-padding shard) contributes
    est=0 and var=inf to the stratified sum: bounds blow up to infinite
    width — honest, and never NaN."""
    rows = 6_000
    _, shards = _shards(rows, parts=4, seed=13)
    # kill partition 3: zero mask = no live tuples, d_local = 0
    shards = dict(shards)
    shards["_mask"] = shards["_mask"].at[3].set(0.0)
    g = gla.make_sum_gla(tpch.q6_func, tpch.q6_cond(tpch.Q6_LOW_WINDOW),
                         d_total=float(rows), estimator="multiple")
    res = engine.run_query(g, shards, rounds=3, emit="round")
    est = np.asarray(res.estimates.estimate)
    lo = np.asarray(res.estimates.lower)
    hi = np.asarray(res.estimates.upper)
    assert not np.any(np.isnan(est))
    assert not np.any(np.isnan(lo)) and not np.any(np.isnan(hi))
    assert np.all(np.isposinf(hi - lo))  # dead stratum: unbounded interval


def test_single_vs_multiple_equal_at_uniform_progress():
    """With equal partition sizes and uniform progress the two models agree
    (paper Fig. 1 single-node observation generalized)."""
    rows = 20_000
    _, shards = _shards(rows, seed=5)
    g1 = gla.make_sum_gla(tpch.q6_func, tpch.q6_cond(tpch.Q6_LOW_WINDOW),
                          d_total=float(rows), estimator="single")
    g2 = gla.make_sum_gla(tpch.q6_func, tpch.q6_cond(tpch.Q6_LOW_WINDOW),
                          d_total=float(rows), estimator="multiple")
    r1 = engine.run_query(g1, shards, rounds=5)
    r2 = engine.run_query(g2, shards, rounds=5)
    np.testing.assert_allclose(np.asarray(r1.estimates.estimate),
                               np.asarray(r2.estimates.estimate), rtol=1e-4)
