"""QuerySpec (DESIGN.md §11): one plan object behind every entry point.

The redesign's contract: ``QuerySpec`` is the canonical plan spelling,
the old loose kwargs keep working through ``coerce_spec`` with exactly
one ``DeprecationWarning``, and the two spellings produce bitwise
identical results.  Rule C009 keeps framework code (src/benchmarks/
examples) off the deprecated spelling; its kwarg list must stay in sync
with the one duplicated into the stdlib-only linter.
"""
import functools
import textwrap
import warnings

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.analysis import contracts
from repro.core import engine, gla, randomize
from repro.core import session as SN
from repro.core import spec as QS
from repro.data import tpch

ROWS = 8192


@functools.lru_cache(maxsize=None)
def _shards():
    cols = tpch.generate_lineitem(ROWS, seed=2)
    parts = randomize.randomize_global(
        {k: jnp.asarray(v) for k, v in cols.items()}, jax.random.key(4), 4)
    return randomize.pack_partitions(parts, chunk_len=128)


def _q6():
    return gla.make_sum_gla(tpch.q6_func, tpch.q6_cond(tpch.Q6_LOW_WINDOW),
                            d_total=float(ROWS), estimator="single")


def _bits(a, b):
    return np.asarray(a).tobytes() == np.asarray(b).tobytes()


def test_spec_and_legacy_kwargs_bitwise_identical():
    g = _q6()
    res_spec = engine.run_query(QS.QuerySpec(g, rounds=4, emit="round"),
                                _shards())
    with pytest.warns(DeprecationWarning, match="loose plan kwargs"):
        res_legacy = engine.run_query(g, _shards(), rounds=4, emit="round")
    assert _bits(res_spec.final, res_legacy.final)
    for a, b in zip(jax.tree.leaves(res_spec.estimates),
                    jax.tree.leaves(res_legacy.estimates)):
        assert _bits(a, b)


def test_bare_gla_stays_warning_free():
    with warnings.catch_warnings():
        warnings.simplefilter("error", DeprecationWarning)
        engine.run_query(_q6(), _shards())


def test_spec_plus_loose_kwargs_is_typeerror():
    with pytest.raises(TypeError, match="not as loose kwargs too"):
        engine.run_query(QS.QuerySpec(_q6()), _shards(), rounds=4)


def test_unknown_kwarg_is_typeerror():
    with pytest.raises(TypeError, match="unexpected keyword"):
        engine.run_query(_q6(), _shards(), roundz=4)


def test_legacy_mode_maps_to_sync():
    spec = QS.coerce_spec(None, {}, caller="t")
    assert spec.mode == "async" and spec.sync is False
    with pytest.warns(DeprecationWarning):
        spec = QS.coerce_spec(_q6(), {"mode": "sync"}, caller="t")
    assert spec.sync is True and spec.mode == "sync"
    with pytest.warns(DeprecationWarning), pytest.raises(ValueError):
        QS.coerce_spec(_q6(), {"mode": "turbo"}, caller="t")


def test_fault_and_estimator_merge_exclusive():
    with pytest.raises(ValueError, match="not both"):
        QS.QuerySpec(_q6(), fault=SN.FaultPolicy("single"),
                     estimator_merge="single")


def test_run_queries_spec_path_matches_legacy():
    glas = [_q6(),
            gla.make_sum_gla(lambda c: c["quantity"],
                             tpch.q6_cond(tpch.Q6_LOW_WINDOW),
                             d_total=float(ROWS))]
    res_spec = engine.run_queries(
        QS.QuerySpec(glas, rounds=4, emit="round"), _shards())
    with pytest.warns(DeprecationWarning):
        res_legacy = engine.run_queries(glas, _shards(), rounds=4,
                                        emit="round")
    for a, b in zip(res_spec, res_legacy):
        assert _bits(a.final, b.final)


def test_session_spec_path_matches_legacy():
    g = _q6()
    s1 = SN.Session(QS.QuerySpec(g, rounds=4, emit="chunk"), _shards())
    r1 = s1.run()
    with pytest.warns(DeprecationWarning):
        s2 = SN.Session(g, _shards(), rounds=4, emit="chunk")
    r2 = s2.run()
    assert _bits(r1.final, r2.final)


def test_deprecated_kwargs_in_sync_with_linter():
    """spec.py owns the list; contracts.py duplicates it literally (the
    linter must import nothing) — this is the tripwire that keeps the
    copies identical."""
    assert frozenset(QS.DEPRECATED_PLAN_KWARGS) == \
        contracts.DEPRECATED_PLAN_KWARGS


def test_c009_flags_framework_code_not_tests(tmp_path):
    bad = textwrap.dedent("""\
        from repro.core import engine
        def f(g, shards):
            return engine.run_query(g, shards, rounds=4, emit="round")
    """)
    good = textwrap.dedent("""\
        import repro
        def f(g, shards):
            return repro.run_query(repro.QuerySpec(g, rounds=4), shards)
    """)
    for sub, src, expect in (("src", bad, True), ("src", good, False),
                             ("tests", bad, False)):
        d = tmp_path / sub
        d.mkdir(exist_ok=True)
        p = d / "mod.py"
        p.write_text(src)
        codes = [v.code for v in contracts.lint_file(p, tmp_path)]
        assert ("C009" in codes) is expect, (sub, src, codes)
