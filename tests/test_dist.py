"""repro.dist subsystem: per-shard kernel dispatch, failure-injection
schedules, and the fault model's estimator-level accounting (DESIGN.md §4)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import engine, gla, randomize
from repro.data import tpch
from repro.dist import fault

ROWS = 12_000
PARTS = 4


@pytest.fixture(scope="module")
def shards():
    cols = tpch.generate_lineitem(ROWS, seed=23)
    parts = randomize.randomize_global(
        {k: jnp.asarray(v) for k, v in cols.items()}, jax.random.key(5), PARTS)
    return randomize.pack_partitions(parts, chunk_len=256)


@pytest.fixture(scope="module")
def q6(shards):
    return gla.make_sum_gla(tpch.q6_func, tpch.q6_cond(tpch.Q6_LOW_WINDOW),
                            d_total=float(ROWS))


def test_kernel_emit_matches_scan(shards, q6):
    """emit='kernel' (one fused Pallas dispatch per shard) produces the same
    snapshots and final as the lax.scan prefix path."""
    assert q6.kernel_cols is not None
    a = engine.run_query(q6, shards, rounds=4, emit="chunk")
    b = engine.run_query(q6, shards, rounds=4, emit="kernel")
    np.testing.assert_allclose(float(a.final), float(b.final), rtol=1e-5)
    for x, y in zip(jax.tree.leaves(a.snapshots), jax.tree.leaves(b.snapshots)):
        np.testing.assert_allclose(np.asarray(x), np.asarray(y), rtol=1e-4,
                                   atol=1e-4)
    np.testing.assert_allclose(np.asarray(a.estimates.estimate),
                               np.asarray(b.estimates.estimate), rtol=1e-4)


def test_kernel_emit_requires_kernel_contract(shards):
    """emit='kernel' needs kernel_cols OR a FusedSpec.  A>1 scalar sums
    publish no legacy kernel projection but DO fuse (DESIGN.md §12), so
    they now run; only GLAs with neither contract are rejected."""
    g = gla.make_sum_gla(tpch.q1_func, tpch.q1_cond, d_total=float(ROWS),
                         num_aggs=4)  # A>1: fused-only
    assert g.kernel_cols is None and g.fused is not None
    a = engine.run_query(g, shards, rounds=4, emit="chunk")
    b = engine.run_query(g, shards, rounds=4, emit="kernel")
    np.testing.assert_allclose(np.asarray(a.final), np.asarray(b.final),
                               rtol=1e-5)
    # "multiple"-estimator states are not plain running sums: no kernel
    # projection and no fused contract — emit='kernel' must still raise
    m = gla.make_sum_gla(tpch.q1_func, tpch.q1_cond, d_total=float(ROWS),
                         num_aggs=4, estimator="multiple")
    assert m.kernel_cols is None and m.fused is None
    with pytest.raises(ValueError, match="kernel_cols"):
        engine.run_query(m, shards, rounds=4, emit="kernel")


def test_failure_schedule_layout():
    sched = fault.failure_schedule(4, 6, {1: 0, 3: 4})
    assert sched.shape == (6, 4)
    assert not sched[:, 1].any()          # dead from the start
    assert sched[:4, 3].all() and not sched[4:, 3].any()
    assert sched[:, 0].all() and sched[:, 2].all()
    assert fault.first_failure_round(sched) == 0
    assert fault.first_failure_round(fault.failure_schedule(4, 6, {3: 4})) == 4
    assert fault.first_failure_round(np.ones(4, bool)) is None


def test_midquery_failure_drops_partition_from_merge(shards, q6):
    """After partition p dies at round r, merged snapshots count only the
    survivors; before r, they include p."""
    rounds, p, r = 6, 1, 3
    res = fault.run_with_failures(q6, shards, fail_at={p: r}, rounds=rounds)
    base = engine.run_query(q6, shards, rounds=rounds)
    scanned = np.asarray(res.snapshots.scanned)
    scanned_base = np.asarray(base.snapshots.scanned)
    np.testing.assert_allclose(scanned[:r], scanned_base[:r], rtol=1e-6)
    assert np.all(scanned[r:] < scanned_base[r:])
    # final merges with the last round's liveness: survivors only
    static = engine.run_query(q6, shards, rounds=rounds,
                              alive=fault.alive_mask(PARTS, [p]))
    np.testing.assert_allclose(float(res.final), float(static.final), rtol=1e-6)


def test_variance_floor_zero_without_failure(shards, q6):
    assert fault.variance_floor(q6, shards, []) == pytest.approx(0.0, abs=1e-6)
    assert fault.variance_floor(q6, shards, [0]) > 0.0


def test_synchronized_stalls_at_failure_round(shards):
    g = gla.make_sum_gla(tpch.q6_func, tpch.q6_cond(tpch.Q6_LOW_WINDOW),
                         d_total=float(ROWS), estimator="synchronized")
    rounds, r = 6, 2
    res = fault.run_with_failures(g, shards, fail_at={2: r}, rounds=rounds,
                                  estimator="synchronized")
    est = np.asarray(res.estimates.estimate)
    lo = np.asarray(res.estimates.lower)
    # frozen at the last pre-failure snapshot from round r on
    for arr in (est, lo):
        assert np.all(arr[r:] == arr[r - 1])
    # dead from the start: the barrier never clears, bounds are infinite
    res0 = fault.run_with_failures(g, shards, dead_partitions=[2],
                                   rounds=rounds, estimator="synchronized")
    assert np.all(np.isneginf(np.asarray(res0.estimates.lower)))
    assert np.all(np.isposinf(np.asarray(res0.estimates.upper)))


def test_non_additive_merge_fold_path(shards):
    """A non-additive GLA (max) runs through the fold-merge path when every
    partition is alive, and refuses alive masks (they need additivity)."""
    from repro.core.uda import GLA
    g_max = GLA(
        init=lambda: {"mx": jnp.full((), -jnp.inf)},
        accumulate=lambda s, c: {"mx": jnp.maximum(
            s["mx"],
            jnp.max(jnp.where(c["_mask"] > 0, c["extendedprice"], -jnp.inf)))},
        merge=lambda a, b: {"mx": jnp.maximum(a["mx"], b["mx"])},
        terminate=lambda s: s["mx"],
        merge_is_additive=False, name="max")
    res = engine.run_query(g_max, shards, rounds=2, snapshots=False)
    exact = float(jnp.max(jnp.where(shards["_mask"] > 0,
                                    shards["extendedprice"], -jnp.inf)))
    assert float(res.final) == exact
    with pytest.raises(NotImplementedError, match="merge_is_additive"):
        engine.run_query(g_max, shards, rounds=2, snapshots=False,
                         alive=fault.alive_mask(PARTS, [1]))


def test_multiple_midquery_poisons_only_after_failure(shards):
    g = gla.make_sum_gla(tpch.q6_func, tpch.q6_cond(tpch.Q6_LOW_WINDOW),
                         d_total=float(ROWS), estimator="multiple")
    rounds, r = 6, 3
    res = fault.run_with_failures(g, shards, fail_at={0: r}, rounds=rounds,
                                  estimator="multiple")
    lo = np.asarray(res.estimates.lower)
    hi = np.asarray(res.estimates.upper)
    assert np.all(np.isfinite(lo[:r])) and np.all(np.isfinite(hi[:r]))
    assert np.all(np.isneginf(lo[r:])) and np.all(np.isposinf(hi[r:]))
