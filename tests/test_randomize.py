"""Distributed randomization (paper §4.2): dtype preservation through the
shuffle, including partitions that receive no rows (empty buckets)."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.core import randomize


def _tiny_parts():
    """Two tiny partitions with int32 + float32 columns."""
    return [
        {"shipdate": jnp.arange(3, dtype=jnp.int32),
         "extendedprice": jnp.asarray([1.5, 2.5, 3.5], jnp.float32)},
        {"shipdate": jnp.arange(4, dtype=jnp.int32),
         "extendedprice": jnp.asarray([4.5, 5.5, 6.5, 7.5], jnp.float32)},
    ]


def test_empty_bucket_preserves_dtype():
    """A partition that receives no rows in the shuffle must keep the source
    dtypes — the old np.zeros((0,)) fallback promoted int32 to float64."""
    # key(2) routes every row away from one partition at this tiny size
    # (deterministic; asserted below so a jax PRNG change cannot silently
    # turn this into a non-regression test)
    out = randomize.randomize_distributed(_tiny_parts(), jax.random.key(2))
    sizes = [o["shipdate"].shape[0] for o in out]
    assert 0 in sizes, f"shuffle no longer produces an empty bucket: {sizes}"
    for o in out:
        assert o["shipdate"].dtype == jnp.int32
        assert o["extendedprice"].dtype == jnp.float32
    assert sum(sizes) == 7  # nothing lost


def test_zero_row_source_partition_preserves_dtype():
    parts = [
        {"shipdate": jnp.zeros((0,), jnp.int32)},
        {"shipdate": jnp.arange(4, dtype=jnp.int32)},
    ]
    out = randomize.randomize_distributed(parts, jax.random.key(0))
    for o in out:
        assert o["shipdate"].dtype == jnp.int32
    assert sum(o["shipdate"].shape[0] for o in out) == 4


def test_empty_bucket_packs_into_engine_layout():
    """pack_partitions keeps the int32 columns int32 even when one partition
    is empty, so group ids stay integral downstream."""
    out = randomize.randomize_distributed(_tiny_parts(), jax.random.key(2))
    shards = randomize.pack_partitions(out, chunk_len=4)
    assert shards["shipdate"].dtype == jnp.int32
    assert shards["extendedprice"].dtype == jnp.float32
    # empty partition contributes only masked padding
    dead = int(np.argmin(np.asarray(shards["_mask"]).sum(axis=(1, 2))))
    assert np.asarray(shards["_mask"])[dead].sum() == 0
