"""End-to-end system behaviour: the paper's full workflow on one box.

Pipeline under test: generate → globally randomize → chunk → run all three
estimation models on all three query families → verify convergence
semantics, exactness at full scan, and the interactive/non-interactive
equivalence.  Plus the PF-OLA↔LM bridge (online eval with early stop).
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import engine, gla, metrics, randomize
from repro.data import tpch

ROWS = 50_000
PARTS = 4


@pytest.fixture(scope="module")
def data():
    cols = tpch.generate_lineitem(ROWS, seed=77)
    parts = randomize.randomize_global(
        {k: jnp.asarray(v) for k, v in cols.items()}, jax.random.key(9),
        PARTS)
    return cols, randomize.pack_partitions(parts, chunk_len=512)


@pytest.mark.parametrize("estimator", ["single", "multiple"])
def test_all_query_families_converge(data, estimator):
    cols, shards = data
    supp, valid = tpch.supplier_nation_table()
    queries = {
        "agg": gla.make_sum_gla(
            tpch.q6_func, tpch.q6_cond(tpch.Q6_LOW_WINDOW),
            d_total=float(ROWS), estimator=estimator),
        "groupby": gla.make_groupby_gla(
            tpch.q1_func, tpch.q1_cond, tpch.q1_group_small, num_groups=4,
            d_total=float(ROWS), estimator=estimator, num_aggs=4),
        "join": gla.make_join_groupby_gla(
            tpch.q1_func, tpch.q6_cond(tpch.Q6_LOW_WINDOW),
            lambda c: c["suppkey"], supp, valid,
            num_groups=tpch.NUM_NATIONS, d_total=float(ROWS),
            estimator=estimator, num_aggs=4),
    }
    for name, g in queries.items():
        res = engine.run_query(g, shards, rounds=6, emit="chunk")
        est = res.estimates
        lo = np.asarray(est.lower, np.float64)
        hi = np.asarray(est.upper, np.float64)
        width = hi - lo
        # widths collapse at full scan, for every group/aggregate
        assert np.all(np.abs(width[-1]) < 1e-2), name
        # bounds bracket the final (exact) estimate for most cells/rounds.
        # Needle-in-haystack groups (join: some nations have 0-2 result
        # tuples at this scale) legitimately report [0,0] before their first
        # match — the paper's high-selectivity TTU effect — so the coverage
        # threshold is deliberately loose here; calibrated coverage is
        # asserted statistically in test_estimators.test_ci_coverage.
        final = np.asarray(est.estimate, np.float64)[-1]
        inside = (lo <= final + 1e-6) & (final - 1e-6 <= hi)
        assert inside.mean() > 0.7, name


def test_join_final_matches_exact(data):
    cols, shards = data
    supp, valid = tpch.supplier_nation_table()
    g = gla.make_join_groupby_gla(
        tpch.q1_func, tpch.q6_cond(tpch.Q6_LOW_WINDOW),
        lambda c: c["suppkey"], supp, valid, num_groups=tpch.NUM_NATIONS,
        d_total=float(ROWS), num_aggs=4)
    res = engine.run_query(g, shards, rounds=4)

    def jfunc(chunk):
        return tpch.q1_func(chunk)

    def jcond(chunk):
        base = tpch.q6_cond(tpch.Q6_LOW_WINDOW)(chunk)
        return base * jnp.asarray(valid)[chunk["suppkey"].astype(jnp.int32)]

    def jgroup(chunk):
        return jnp.asarray(supp)[chunk["suppkey"].astype(jnp.int32)]

    exact = tpch.exact_answer(cols, jfunc, jcond, jgroup, tpch.NUM_NATIONS)
    np.testing.assert_allclose(np.asarray(res.final), exact, rtol=5e-3,
                               atol=1e-2)


def test_online_eval_bridge_early_stop():
    """Loss-GLA over a toy scoring function: bounds are valid and tighten."""
    n = 8_192
    rng = np.random.default_rng(3)
    scores = rng.normal(3.0, 0.3, n).astype(np.float32)
    cols = {"score": jnp.asarray(scores)}
    parts = randomize.randomize_global(cols, jax.random.key(0), 4)
    shards = randomize.pack_partitions(parts, chunk_len=128)
    g = metrics.make_loss_gla(lambda c: c["score"], d_total=float(n))
    res = engine.run_query(g, shards, rounds=8)
    mean, lo, hi = metrics.mean_with_bounds(res.estimates)
    true_mean = scores.mean()
    assert abs(mean[-1] - true_mean) < 1e-3
    # early rounds bracket the truth and tighten monotonically-ish
    assert lo[0] <= true_mean <= hi[0]
    assert (hi[-1] - lo[-1]) < (hi[0] - lo[0])
