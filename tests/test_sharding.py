"""Sharding rule table + sharded-engine equivalence (subprocess with fake
devices so the main test process keeps 1 CPU device)."""
import subprocess
import sys
import textwrap
from pathlib import Path

import pytest

from repro.models.spec import ParamSpec

SRC = Path(__file__).resolve().parents[1] / "src"


class FakeMesh:
    axis_names = ("data", "model")

    class _Dev:
        shape = (16, 16)
        size = 256

    devices = _Dev()


def _ps(shape, logical, **kw):
    from repro.dist import sharding as SH
    return tuple(SH.spec_pspec(ParamSpec(shape, logical), FakeMesh(), **kw))


def test_divisible_dims_shard():
    assert _ps((5120, 25600), ("embed", "mlp")) == (None, "model")
    assert _ps((202240, 5120), ("vocab", "embed")) == ("model", None)
    assert _ps((5120, 64, 128), ("embed", "heads", None)) == (None, "model", None)


def test_indivisible_falls_back():
    # smollm: 9 heads don't divide 16 -> try embed (576/16=36 ✓)
    assert _ps((576, 9, 64), ("embed", "heads", None)) == ("model", None, None)
    # nothing divisible -> fully replicated
    assert _ps((7, 9), ("heads", "kv")) == (None, None)


def test_expert_priority_over_mlp():
    # llama4: 128 experts shard; grok: 8 experts fall through to mlp
    assert _ps((128, 5120, 8192), ("experts", "embed", "mlp")) == (
        "model", None, None)
    assert _ps((8, 6144, 32768), ("experts", "embed", "mlp")) == (
        None, None, "model")


def test_opt_data_axis_zero_style():
    ps = _ps((5120, 25600), ("embed", "mlp"), opt_data_axis="data")
    assert ps == ("data", "model")


def test_layers_axis_never_sharded():
    ps = _ps((16, 5120, 25600), ("layers", "embed", "mlp"),
             opt_data_axis="data")
    assert ps[0] is None


@pytest.mark.slow
def test_sharded_engine_matches_vmapped_subprocess():
    """Runs the engine under shard_map on 8 fake devices and compares with
    the vmapped path — in a subprocess so XLA_FLAGS stays local."""
    code = textwrap.dedent("""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
        import sys; sys.path.insert(0, %r)
        import numpy as np, jax, jax.numpy as jnp
        from repro.core import engine, gla, randomize
        from repro.data import tpch
        rows = 60_000
        cols = tpch.generate_lineitem(rows)
        parts = randomize.randomize_global(
            {k: jnp.asarray(v) for k, v in cols.items()}, jax.random.key(0), 8)
        shards = randomize.pack_partitions(parts, chunk_len=256)
        mesh = jax.make_mesh((8,), ("data",))
        g = gla.make_sum_gla(tpch.q6_func, tpch.q6_cond(tpch.Q6_LOW_WINDOW),
                             d_total=float(rows))
        rv = engine.run_query(g, shards, rounds=8)
        rs = engine.run_query(g, shards, rounds=8, mesh=mesh)
        np.testing.assert_allclose(np.asarray(rv.estimates.estimate),
                                   np.asarray(rs.estimates.estimate), rtol=2e-5)
        # both paths run the same scan core: final GLA states (and the
        # merged snapshot states) are bitwise identical, not just close
        assert np.asarray(rv.final).tobytes() == np.asarray(rs.final).tobytes()
        for a, b in zip(jax.tree.leaves(rv.snapshots),
                        jax.tree.leaves(rs.snapshots)):
            assert np.asarray(a).tobytes() == np.asarray(b).tobytes()
        # the kernel path sums in a different order (tiled lane partials +
        # cumsum), so it is interchangeable, not bitwise-identical
        rk = engine.run_query(g, shards, rounds=8, mesh=mesh, emit="kernel")
        np.testing.assert_allclose(float(rk.final), float(rv.final), rtol=1e-5)
        sched = engine.straggler_schedule(8, shards["_mask"].shape[1], 6,
                                          speeds=[1,1,1,1,2,2,3,4])
        sv = engine.run_query(g, shards, schedule=sched, mode="sync")
        ss = engine.run_query(g, shards, schedule=sched, mode="sync", mesh=mesh)
        np.testing.assert_allclose(np.asarray(sv.estimates.estimate),
                                   np.asarray(ss.estimates.estimate), rtol=2e-5)
        print("OK")
    """ % str(SRC))
    r = subprocess.run([sys.executable, "-c", code], capture_output=True,
                       text=True, timeout=600)
    assert r.returncode == 0, r.stderr[-3000:]
    assert "OK" in r.stdout
