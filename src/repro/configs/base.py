"""ArchConfig — the framework's model configuration schema.

Every assigned architecture is a ``src/repro/configs/<id>.py`` exporting
``CONFIG``; reduced smoke variants come from :meth:`ArchConfig.smoke`.
"""
from __future__ import annotations

import dataclasses
import importlib
from typing import Optional, Tuple


@dataclasses.dataclass(frozen=True)
class ArchConfig:
    name: str
    family: str                  # dense | moe | audio | vlm | hybrid | ssm
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    d_ff: int
    vocab_size: int              # raw; padded to %256 at build time
    head_dim: Optional[int] = None

    # --- attention/block options ------------------------------------------
    qk_norm: bool = False
    mlp_act: str = "silu"        # silu | relu2 | gelu
    mlp_gated: bool = True
    norm: str = "rms"            # rms | ln
    pos: str = "rope"            # rope | learned | none
    rope_theta: float = 10000.0
    attn_chunk: Optional[int] = None   # local/chunked attention window
    tie_embeddings: bool = False
    logit_softcap: Optional[float] = None

    # --- layer pattern -----------------------------------------------------
    # cycled over layers; entries: attn | attn_chunked | rglru | mlstm | slstm
    block_pattern: Tuple[str, ...] = ("attn",)

    # --- MoE ----------------------------------------------------------------
    num_experts: int = 0
    experts_per_token: int = 0
    expert_capacity_factor: float = 1.25

    # --- recurrent forms -------------------------------------------------------
    mlstm_form: str = "chunkwise"        # chunkwise (TPU matmul form) | sequential

    # --- recurrent widths ----------------------------------------------------
    lru_width: Optional[int] = None      # rglru state width (default d_model)
    local_window: int = 2048             # rglru local-attention window

    # --- encoder-decoder / frontends -----------------------------------------
    is_encoder_decoder: bool = False
    encoder_layers: int = 0
    encoder_seq: int = 1500              # whisper frame count
    frontend: Optional[str] = None       # audio_stub | vision_stub
    vis_tokens: int = 256                # vlm patch-embedding prefix length

    # --- serving ---------------------------------------------------------------
    kv_cache_dtype: str = "bf16"         # bf16 | int8 (quantized KV cache)

    # --- training -------------------------------------------------------------
    fsdp: bool = False                   # shard params/grads over `data` too
    optimizer: str = "adamw"             # adamw | adafactor
    remat: str = "full"                  # full | dots | none
    train_microbatches: int = 1          # grad-accumulation chunks per step
    moe_groups: int = 16                 # MoE dispatch groups (≈ data shards)

    # ------------------------------------------------------------------------
    @property
    def head_dim_(self) -> int:
        return self.head_dim or self.d_model // self.num_heads

    @property
    def vocab_padded(self) -> int:
        return -(-self.vocab_size // 256) * 256

    @property
    def supports_long_context(self) -> bool:
        """True iff 500K-token decode is tractable: either every temporal
        mixer has bounded state (SSM/hybrid), or most layers are
        chunked-local with only a minority of global-attention layers whose
        S-sharded KV cache fits (Llama-4 iRoPE layout)."""
        if all(b != "attn" for b in self.block_pattern):
            return True
        return self.attn_chunk is not None

    def layer_types(self) -> Tuple[str, ...]:
        p = self.block_pattern
        return tuple(p[i % len(p)] for i in range(self.num_layers))

    def smoke(self) -> "ArchConfig":
        """Reduced same-family config for CPU smoke tests."""
        pat_len = len(self.block_pattern)
        n_layers = max(pat_len, 2)
        return dataclasses.replace(
            self,
            num_layers=n_layers,
            d_model=64,
            num_heads=4,
            num_kv_heads=min(self.num_kv_heads, 2) or 1,
            d_ff=128 if self.d_ff else 0,
            head_dim=16,
            vocab_size=512,
            num_experts=min(self.num_experts, 4),
            lru_width=64 if self.lru_width or "rglru" in self.block_pattern else None,
            local_window=32,
            encoder_layers=min(self.encoder_layers, 2),
            encoder_seq=24,
            vis_tokens=8,
            attn_chunk=32 if self.attn_chunk else None,
            remat="none",
            train_microbatches=1,
            moe_groups=2,
        )


ASSIGNED = [
    "llama4_maverick_400b_a17b",
    "grok_1_314b",
    "deepseek_7b",
    "nemotron_4_15b",
    "smollm_135m",
    "qwen3_32b",
    "whisper_base",
    "internvl2_1b",
    "recurrentgemma_9b",
    "xlstm_125m",
]

_ALIAS = {n.replace("_", "-"): n for n in ASSIGNED}


def list_archs():
    return list(ASSIGNED)


def get_config(arch: str) -> ArchConfig:
    mod_name = _ALIAS.get(arch, arch).replace("-", "_")
    mod = importlib.import_module(f"repro.configs.{mod_name}")
    return mod.CONFIG
