"""Architecture configs.  ``get_config(arch_id)`` resolves any assigned arch."""
from repro.configs.base import ArchConfig, get_config, list_archs  # noqa: F401
