"""deepseek-7b [dense] — arXiv:2401.02954, hf:deepseek-ai/deepseek-llm-7b-base.

30L d_model=4096 32H (MHA: kv=32) d_ff=11008 vocab=102400 — llama architecture.
"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="deepseek-7b",
    family="dense",
    num_layers=30,
    d_model=4096,
    num_heads=32,
    num_kv_heads=32,
    d_ff=11008,
    vocab_size=102400,
    kv_cache_dtype="int8",   # MHA 32-kv-head cache: bf16 does not fit 256x16GB at decode_32k
    train_microbatches=4,
)
