"""smollm-135m [dense] — hf:HuggingFaceTB/SmolLM-135M.

30L d_model=576 9H (GQA kv=3) d_ff=1536 vocab=49152 — small llama arch,
tied embeddings.  9 heads do not divide the 16-way model axis: attention
stays replicated on 'model' while the MLP shards (DESIGN.md §5).
"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="smollm-135m",
    family="dense",
    num_layers=30,
    d_model=576,
    num_heads=9,
    num_kv_heads=3,
    d_ff=1536,
    vocab_size=49152,
    tie_embeddings=True,
)
