"""llama4-maverick-400b-a17b [moe] — hf:meta-llama/Llama-4-Scout-17B-16E (unverified).

48L d_model=5120 40H (GQA kv=8) d_ff=8192 vocab=202048, MoE 128 experts top-1.
Early fusion multimodal in the real model; assigned spec is the LM backbone.
Llama-4 uses chunked local attention (8192) on 3 of every 4 layers and global
attention (NoPE) on the 4th — that is what makes long_500k decode runnable
with bounded KV (DESIGN.md §5).
"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="llama4-maverick-400b-a17b",
    family="moe",
    num_layers=48,
    d_model=5120,
    num_heads=40,
    num_kv_heads=8,
    d_ff=8192,
    vocab_size=202048,
    num_experts=128,
    experts_per_token=1,
    attn_chunk=8192,
    block_pattern=("attn_chunked", "attn_chunked", "attn_chunked", "attn"),
    optimizer="adafactor",
    fsdp=True,   # factored stats: 400B AdamW does not fit 256x16GB
    qk_norm=True,
    train_microbatches=16,
)
