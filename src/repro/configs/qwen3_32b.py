"""qwen3-32b [dense] — hf:Qwen/Qwen3-32B (config family verified via Qwen3-8B).

64L d_model=5120 64H (GQA kv=8) d_ff=25600 vocab=151936, qk-norm, head_dim=128.
"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="qwen3-32b",
    family="dense",
    num_layers=64,
    d_model=5120,
    num_heads=64,
    num_kv_heads=8,
    d_ff=25600,
    vocab_size=151936,
    head_dim=128,
    qk_norm=True,
    rope_theta=1_000_000.0,
    train_microbatches=8,
)
