"""recurrentgemma-9b [hybrid] — arXiv:2402.19427 / Griffin (unverified).

Assigned spec: 38L d_model=4096 16H (GQA kv=1) d_ff=12288, RG-LRU + local
attention 1:2 (pattern rec,rec,attn; 38 = 12x3 + 2 rec tail).  Local window
2048, MQA (kv=1) for the attention blocks, GeGLU MLP.
long_500k runs: RG-LRU state is O(1), local-attn KV bounded by the window.
"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="recurrentgemma-9b",
    family="hybrid",
    num_layers=38,
    d_model=4096,
    num_heads=16,
    num_kv_heads=1,
    d_ff=12288,
    vocab_size=256000,
    mlp_act="gelu",
    mlp_gated=True,
    block_pattern=("rglru", "rglru", "attn_chunked"),
    attn_chunk=2048,
    local_window=2048,
    lru_width=4096,
    train_microbatches=4,
)
