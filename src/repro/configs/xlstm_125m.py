"""xlstm-125m [ssm] — arXiv:2405.04517 (unverified).

12L d_model=768 4H d_ff=0 vocab=50304 — sLSTM + mLSTM blocks; the blocks
carry their own up-projections (mLSTM pre-up x2, sLSTM post-up 4/3 gated),
hence d_ff=0 in the assigned spec.  Pattern (m,m,m,s) x3 ≈ the paper's
mLSTM-heavy ratios.  long_500k runs: both cell states are O(1) per token.
"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="xlstm-125m",
    family="ssm",
    num_layers=12,
    d_model=768,
    num_heads=4,
    num_kv_heads=4,
    d_ff=0,
    vocab_size=50304,
    block_pattern=("mlstm", "mlstm", "mlstm", "slstm"),
)
