"""internvl2-1b [vlm] — arXiv:2404.16821, hf:OpenGVLab/InternVL2-1B.

LM backbone = Qwen2-0.5B: 24L d_model=896 14H (GQA kv=2) d_ff=4864
vocab=151655.  The InternViT-300M vision tower is a STUB per the assignment:
input_specs() provides 256 precomputed patch embeddings per image, prepended
to the text sequence.
"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="internvl2-1b",
    family="vlm",
    num_layers=24,
    d_model=896,
    num_heads=14,
    num_kv_heads=2,
    d_ff=4864,
    vocab_size=151655,
    frontend="vision_stub",
    vis_tokens=256,
)
