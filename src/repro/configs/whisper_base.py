"""whisper-base [audio] — arXiv:2212.04356 (unverified).

Enc-dec, 6L+6L d_model=512 8H (MHA) d_ff=2048 vocab=51865.  GELU MLP
(ungated), LayerNorm, learned positions, no rope.  The conv audio frontend
is a STUB per the assignment: input_specs() provides precomputed frame
embeddings [B, 1500, 512].  decode_32k exercises the backbone's 32K-KV
decoder path (the real model caps decoder positions at 448 — deviation
recorded in DESIGN.md §5).
"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="whisper-base",
    family="audio",
    num_layers=6,              # decoder layers
    d_model=512,
    num_heads=8,
    num_kv_heads=8,
    d_ff=2048,
    vocab_size=51865,
    mlp_act="gelu",
    mlp_gated=False,
    norm="ln",
    pos="learned",
    is_encoder_decoder=True,
    encoder_layers=6,
    encoder_seq=1500,
    frontend="audio_stub",
)
