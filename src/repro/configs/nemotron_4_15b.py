"""nemotron-4-15b [dense] — arXiv:2402.16819 (unverified).

32L d_model=6144 48H (GQA kv=8) d_ff=24576 vocab=256000.
Squared-ReLU MLP (ungated), LayerNorm, no embedding tying.
"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="nemotron-4-15b",
    family="dense",
    num_layers=32,
    d_model=6144,
    num_heads=48,
    num_kv_heads=8,
    d_ff=24576,
    vocab_size=256000,
    mlp_act="relu2",
    mlp_gated=False,
    norm="ln",
    train_microbatches=4,
)
