"""PF-OLA reproduction — the stable public surface.

Import query construction, execution, serving and stopping rules from
here rather than deep module paths::

    import repro

    q = repro.make_sum_gla(func, cond, d_total=float(n))
    spec = repro.QuerySpec(q, rounds=8, stop=repro.rel_width(0.01))
    result = repro.run_query(spec, shards)

Deep paths (``repro.core.engine`` etc.) keep working — this facade adds
names, it does not move them.  Attributes resolve lazily (PEP 562) so
that importing :mod:`repro` stays side-effect free and jax-free: the
contracts CI job runs ``python -m repro.analysis.contracts`` on a bare
interpreter, and ``import repro`` must not drag in an accelerator
runtime it doesn't need.
"""
from __future__ import annotations

from typing import TYPE_CHECKING

# name -> (module, attribute) table behind __getattr__
_EXPORTS = {
    # query construction
    "GLA": ("repro.core.uda", "GLA"),
    "Estimate": ("repro.core.uda", "Estimate"),
    "GLABundle": ("repro.core.gla", "GLABundle"),
    "make_sum_gla": ("repro.core.gla", "make_sum_gla"),
    "make_groupby_gla": ("repro.core.gla", "make_groupby_gla"),
    "make_join_groupby_gla": ("repro.core.gla", "make_join_groupby_gla"),
    # Deep OLA composition (DESIGN.md §13)
    "compose": ("repro.core.gla", "compose"),
    "make_having_gla": ("repro.core.gla", "make_having_gla"),
    "monotone_envelope": ("repro.core.estimators", "monotone_envelope"),
    # sketch GLAs
    "make_count_distinct_gla": ("repro.core.sketch",
                                "make_count_distinct_gla"),
    "make_quantile_gla": ("repro.core.sketch", "make_quantile_gla"),
    "make_heavy_hitters_gla": ("repro.core.sketch",
                               "make_heavy_hitters_gla"),
    # plan trees (lowered by QuerySpec; DESIGN.md §13)
    "PlanNode": ("repro.core.spec", "PlanNode"),
    "Scan": ("repro.core.spec", "Scan"),
    "Filter": ("repro.core.spec", "Filter"),
    "Join": ("repro.core.spec", "Join"),
    "SumAgg": ("repro.core.spec", "SumAgg"),
    "GroupAgg": ("repro.core.spec", "GroupAgg"),
    "Having": ("repro.core.spec", "Having"),
    "CountDistinct": ("repro.core.spec", "CountDistinct"),
    "Quantile": ("repro.core.spec", "Quantile"),
    "HeavyHitters": ("repro.core.spec", "HeavyHitters"),
    "lower_plan": ("repro.core.spec", "lower_plan"),
    # plans and execution
    "QuerySpec": ("repro.core.spec", "QuerySpec"),
    "run_query": ("repro.core.engine", "run_query"),
    "run_queries": ("repro.core.engine", "run_queries"),
    "QueryResult": ("repro.core.engine", "QueryResult"),
    "Session": ("repro.core.session", "Session"),
    "resume": ("repro.core.session", "resume"),
    "RoundProgress": ("repro.core.session", "RoundProgress"),
    "FaultPolicy": ("repro.core.session", "FaultPolicy"),
    # stopping rules
    "rel_width": ("repro.core.session", "rel_width"),
    "abs_width": ("repro.core.session", "abs_width"),
    "budget": ("repro.core.session", "budget"),
    "any_of": ("repro.core.session", "any_of"),
    "all_of": ("repro.core.session", "all_of"),
    # data sources
    "as_source": ("repro.data.source", "as_source"),
    "ChunkSource": ("repro.data.source", "ChunkSource"),
    # serving (DESIGN.md §11)
    "OLAService": ("repro.serving.service", "OLAService"),
    "SharedScan": ("repro.serving.service", "SharedScan"),
    "SlotFamily": ("repro.core.gla", "SlotFamily"),
    "SlotQuery": ("repro.core.gla", "SlotQuery"),
}

__all__ = sorted(_EXPORTS)


def __getattr__(name: str):
    try:
        mod_name, attr = _EXPORTS[name]
    except KeyError:
        raise AttributeError(
            f"module 'repro' has no attribute {name!r}") from None
    import importlib

    value = getattr(importlib.import_module(mod_name), attr)
    globals()[name] = value   # cache: next access skips __getattr__
    return value


def __dir__():
    return sorted(set(globals()) | set(__all__))


if TYPE_CHECKING:  # static-analysis view of the lazy table
    from repro.core.engine import QueryResult, run_queries, run_query
    from repro.core.estimators import monotone_envelope
    from repro.core.gla import (GLABundle, SlotFamily, SlotQuery, compose,
                                make_groupby_gla, make_having_gla,
                                make_join_groupby_gla, make_sum_gla)
    from repro.core.session import (FaultPolicy, RoundProgress, Session,
                                    abs_width, all_of, any_of, budget,
                                    rel_width, resume)
    from repro.core.sketch import (make_count_distinct_gla,
                                   make_heavy_hitters_gla,
                                   make_quantile_gla)
    from repro.core.spec import (CountDistinct, Filter, GroupAgg, Having,
                                 HeavyHitters, Join, PlanNode, Quantile,
                                 QuerySpec, Scan, SumAgg, lower_plan)
    from repro.core.uda import GLA, Estimate
    from repro.data.source import ChunkSource, as_source
    from repro.serving.service import OLAService, SharedScan
