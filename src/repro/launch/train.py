"""Training driver: end-to-end LM training with checkpoint/restart and
PF-OLA online metrics.

    PYTHONPATH=src python -m repro.launch.train --arch smollm_135m \
        --steps 300 --smoke --batch 8 --seq 64 --ckpt-every 100

On hardware this runs the full config under the production mesh (the same
train_step the dry-run lowers); with --smoke it trains the reduced
same-family config on CPU — the end-to-end example driver.
"""
from __future__ import annotations

import argparse
import time
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import ckpt
from repro.configs import get_config
from repro.data.tokens import token_batches
from repro.training import train_step as TS


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="smollm_135m")
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--lr", type=float, default=3e-3)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--ckpt-every", type=int, default=100)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_ckpt")
    ap.add_argument("--resume", action="store_true")
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if args.smoke:
        cfg = cfg.smoke()
    key = jax.random.key(0)
    params, opt = TS.init_train_state(
        cfg, key, dtype=jnp.float32 if args.smoke else jnp.bfloat16)
    start, cursor = 0, 0
    path = Path(args.ckpt_dir) / f"{args.arch}.ckpt"
    if args.resume and path.exists():
        params, opt, start, cursor = ckpt.load_train_state(path, params, opt)
        print(f"resumed from step {start}")

    step_fn = jax.jit(TS.make_train_step(cfg, lr=args.lr))
    batches = token_batches(cfg, args.batch, args.seq, start=cursor)
    # loss as a running PF-OLA state: anytime mean + CI over the run
    s = sq = n = 0.0
    t0 = time.time()
    for step in range(start, args.steps):
        batch, cursor = next(batches)
        params, opt, m = step_fn(params, opt, batch)
        loss = float(m["loss"])
        s, sq, n = s + loss, sq + loss * loss, n + 1
        if (step + 1) % 10 == 0:
            mean = s / n
            var = max(sq / n - mean * mean, 0.0) / max(n - 1, 1)
            half = 1.96 * np.sqrt(var)
            print(f"step {step + 1:4d} loss {loss:.4f} "
                  f"run-mean {mean:.4f} ±{half:.4f} "
                  f"({(time.time() - t0) / (step - start + 1):.2f}s/step)")
        if (step + 1) % args.ckpt_every == 0:
            ckpt.save_train_state(path, params, opt, step + 1, cursor)
            print(f"checkpointed at step {step + 1}")
    ckpt.save_train_state(path, params, opt, args.steps, cursor)
    print("done")


if __name__ == "__main__":
    main()
