import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
"""§Perf hillclimbing tool: compile ONE cell (optionally with config
overrides) and report the three roofline terms, peak memory, and the top
bytes/collective contributors — the hypothesis→change→measure loop's
measurement step.

    PYTHONPATH=src python -m repro.launch.hillclimb qwen3_32b train_4k \
        --set train_microbatches=4 --label mb4
"""
import argparse
import dataclasses
import json
import time
from pathlib import Path

import jax
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.analysis import hlo_cost as HC
from repro.launch import mesh as M
from repro.launch.mesh import HBM_BW, ICI_BW, PEAK_FLOPS_BF16

OUT = Path(__file__).resolve().parents[3] / "experiments" / "hillclimb"


def coerce(v: str):
    for cast in (int, float):
        try:
            return cast(v)
        except ValueError:
            pass
    if v in ("True", "False"):
        return v == "True"
    return v


def run(arch: str, shape: str, overrides: dict, label: str,
        mesh_kind: str = "single"):
    import repro.configs.base as CB
    from repro.launch import dryrun as DR

    cfg0 = CB.get_config(arch)
    cfg = dataclasses.replace(cfg0, **overrides) if overrides else cfg0

    # monkeypatch get_config so build_cell sees the overridden config
    def _patched_get_config(a):
        return cfg

    DR.get_config = _patched_get_config

    mesh = M.make_production_mesh(multi_pod=(mesh_kind == "multi"))
    t0 = time.time()
    fn, args, shardings, donate, _ = DR.build_cell(arch, shape, mesh)
    shardings = jax.tree.map(lambda ps: NamedSharding(mesh, ps), shardings,
                             is_leaf=lambda x: isinstance(x, P))
    jax.set_mesh(mesh)
    with mesh:
        compiled = jax.jit(fn, in_shardings=shardings,
                           donate_argnums=donate).lower(*args).compile()
    dt = time.time() - t0
    mem = compiled.memory_analysis()
    hc = HC.HloCost(compiled.as_text())
    tot = hc.total()
    coll = sum(tot.collective_bytes.values())
    t_c = tot.flops / PEAK_FLOPS_BF16
    t_m = tot.bytes / HBM_BW
    t_n = coll / ICI_BW
    peak = (mem.argument_size_in_bytes + mem.output_size_in_bytes
            + mem.temp_size_in_bytes - mem.alias_size_in_bytes)
    rec = {
        "cell": f"{arch}.{shape}.{mesh_kind}", "label": label,
        "overrides": overrides,
        "compute_s": t_c, "memory_s": t_m, "collective_s": t_n,
        "dominant": max(("compute", t_c), ("memory", t_m),
                        ("collective", t_n), key=lambda kv: kv[1])[0],
        "flops_per_device": tot.flops, "bytes_per_device": tot.bytes,
        "collective_bytes": tot.collective_bytes,
        "peak_gb": peak / 1e9, "temp_gb": mem.temp_size_in_bytes / 1e9,
        "compile_s": round(dt, 1),
    }
    print(json.dumps(rec, indent=1))
    print("--- top bytes contributors (trip-scaled) ---")
    for k, v in hc.bytes_breakdown(12):
        print(f"  {v:.3e}  {k}")
    OUT.mkdir(parents=True, exist_ok=True)
    (OUT / f"{arch}.{shape}.{label}.json").write_text(json.dumps(rec, indent=1))
    return rec


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("arch")
    ap.add_argument("shape")
    ap.add_argument("--set", action="append", default=[],
                    help="cfg override key=value")
    ap.add_argument("--label", default="exp")
    ap.add_argument("--mesh", default="single")
    a = ap.parse_args()
    ov = {}
    for kv in getattr(a, "set"):
        k, v = kv.split("=", 1)
        ov[k] = coerce(v)
    run(a.arch, a.shape, ov, a.label, a.mesh)
