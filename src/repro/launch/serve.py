"""OLA service entry point: concurrent anytime queries on one shared scan.

    PYTHONPATH=src python -m repro.launch.serve --rows 200000 --queries 6 \
        --qps 20 --eps 0.05

Boots an :class:`repro.serving.service.OLAService` over a synthetic
TPC-H lineitem instance, submits a seeded Poisson stream of slot
queries (scalar Q6-style range aggregates plus group-by members), and
prints each query's anytime outcome as it converges or completes a full
pass.  All queries ride ONE cyclic scan (DESIGN.md §11); arrivals and
departures reuse the warm jitted step via the padded-slot bundle.

The LLM prefill/decode demo that used to live here is
``examples/llm_serve_demo.py`` — run it directly.
"""
from __future__ import annotations

import argparse
import asyncio
import time


async def _run(args):
    import jax
    import jax.numpy as jnp
    import numpy as np

    import repro
    from repro.core import randomize
    from repro.data import tpch

    cols = tpch.generate_lineitem(args.rows, seed=args.seed)
    parts = randomize.randomize_global(
        {k: jnp.asarray(v) for k, v in cols.items()},
        jax.random.key(args.seed), args.parts)
    shards = randomize.pack_partitions(parts, chunk_len=args.chunk)

    family = repro.SlotFamily(
        exprs={"q6": tpch.q6_func, "qty": lambda c: c["quantity"]},
        pred_cols=("shipdate", "discount"),
        groups={"rfls": (tpch.q1_group_small, 4)})

    rng = np.random.default_rng(args.seed)
    # seeded Poisson stream: exponential inter-arrival gaps
    arrivals = np.cumsum(rng.exponential(1.0 / args.qps, size=args.queries))
    service = repro.OLAService(family, rounds=args.rounds,
                               grace_s=args.grace)
    t0 = time.perf_counter()

    async def one(i):
        await asyncio.sleep(float(arrivals[i]))
        year = int(rng.integers(0, 6)) * 365
        q = repro.SlotQuery(
            expr="qty" if i % 3 == 2 else "q6",
            ranges={"shipdate": (float(year), float(year + 730)),
                    "discount": (0.0, 1.0)},
            group="rfls" if i % 4 == 3 else None)
        spec = repro.QuerySpec(q, stop=repro.rel_width(args.eps))
        h = await service.submit(spec, shards)
        out = await h.result()
        est = np.asarray(out.estimate.estimate)
        head = float(est.reshape(-1)[0])
        print(f"  q{i:02d} expr={q.expr:3s} group={q.group or '-':4s} "
              f"t={time.perf_counter() - t0:6.2f}s "
              f"rounds={out.rounds_witnessed} "
              f"converged={str(out.converged):5s} est[0]={head:14.2f}")
        return out

    async with service:
        outs = await asyncio.gather(*(one(i) for i in range(args.queries)))
    scan = service.scan_for(shards)
    n_conv = sum(o.converged for o in outs)
    print(f"served {args.queries} queries ({n_conv} early-converged) on "
          f"{scan.steps_done if scan else 0} shared scan step(s); "
          f"compile budget {scan.compile_budget() if scan else 0}")


def main(argv=None):
    ap = argparse.ArgumentParser(
        description="Serve concurrent OLA queries over one shared scan")
    ap.add_argument("--rows", type=int, default=200_000)
    ap.add_argument("--parts", type=int, default=8)
    ap.add_argument("--chunk", type=int, default=1024)
    ap.add_argument("--rounds", type=int, default=8)
    ap.add_argument("--queries", type=int, default=6)
    ap.add_argument("--qps", type=float, default=20.0,
                    help="Poisson arrival rate (queries/second)")
    ap.add_argument("--eps", type=float, default=0.05,
                    help="per-query relative-width stop threshold")
    ap.add_argument("--grace", type=float, default=0.25,
                    help="idle seconds before the shared scan parks")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)
    asyncio.run(_run(args))


if __name__ == "__main__":
    main()
