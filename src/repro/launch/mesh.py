"""Production meshes.

``make_production_mesh`` is a FUNCTION (not a module-level constant) so that
importing this module never touches jax device state; the dry-run sets
XLA_FLAGS before any jax import (repro/launch/dryrun.py) and only then
builds the mesh.

Topology: TPU v5e pod slices.  Single pod: 16×16 = 256 chips as
(data=16, model=16).  Multi-pod: 2 pods × 256 = 512 chips as
(pod=2, data=16, model=16) — gradient/GLA reductions cross pods over DCI on
the `pod` axis; model parallelism never leaves a pod.
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_test_mesh(devices: int = 8, axes=("data",)):
    """Small mesh over however many (possibly fake) devices exist."""
    n = len(jax.devices())
    use = min(devices, n)
    shape = (use,) if len(axes) == 1 else (use // 2, 2)
    return jax.make_mesh(shape, axes)


# v5e per-chip hardware constants (roofline denominators)
PEAK_FLOPS_BF16 = 197e12      # FLOP/s
HBM_BW = 819e9                # B/s
ICI_BW = 50e9                 # B/s per link (aggregate approximation)
HBM_PER_CHIP = 16 * 1024**3   # bytes
