import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
"""Multi-pod dry-run: lower + compile every (arch × shape × mesh) cell.

This is how the distribution config is proven coherent without hardware:

    with mesh:
        lowered = jax.jit(step, in_shardings=...).lower(*abstract_inputs)
        compiled = lowered.compile()
        compiled.memory_analysis()   # proves it fits
        compiled.cost_analysis()     # FLOPs/bytes for the roofline

The two XLA_FLAGS lines above MUST stay the first statements in this module
(before any jax import — jax locks the device count at first init).  Do not
set the flag anywhere global: smoke tests and benchmarks see 1 CPU device.

Usage:
    python -m repro.launch.dryrun --arch qwen3-32b --shape train_4k --mesh single
    python -m repro.launch.dryrun --all            # every cell, subprocesses
    python -m repro.launch.dryrun --all --mesh multi

Per-cell JSON results land in experiments/dryrun/, consumed by
benchmarks/roofline.py and EXPERIMENTS.md.
"""
import argparse
import json
import re
import subprocess
import sys
import time
from pathlib import Path

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs import get_config, list_archs
from repro.dist import sharding as SH
from repro.launch import mesh as M
from repro.launch.shapes import SHAPES, batch_specs, cell_runnable, decode_specs

OUT_DIR = Path(__file__).resolve().parents[3] / "experiments" / "dryrun"

_COLLECTIVE_RE = re.compile(
    r"=\s*([a-z0-9]+)\[([0-9,]*)\][^ ]*\s+"
    r"(all-reduce|all-gather|reduce-scatter|all-to-all|collective-permute)\b")

_DTYPE_BYTES = {
    "f32": 4, "bf16": 2, "f16": 2, "s32": 4, "u32": 4, "s8": 1, "u8": 1,
    "pred": 1, "f64": 8, "s64": 8, "u64": 8, "s16": 2, "u16": 2, "f8e4m3": 1,
    "f8e5m2": 1,
}


def parse_collective_bytes(hlo_text: str):
    """Per-device bytes by collective kind, from post-SPMD optimized HLO."""
    out = {}
    for m in _COLLECTIVE_RE.finditer(hlo_text):
        dtype, dims, kind = m.groups()
        nbytes = _DTYPE_BYTES.get(dtype, 4)
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        out[kind] = out.get(kind, 0) + n * nbytes
    return out


def _opt_abstract_and_pspecs(cfg, params_abs, spec_tree, mesh):
    """Optimizer-state abstract values + ZeRO pspecs (DESIGN.md §5)."""
    from repro.training import optimizer as O
    opt_abs = jax.eval_shape(lambda p: O.opt_init(p, cfg.optimizer), params_abs)
    param_ps = SH.param_pspecs(spec_tree, mesh, opt_data_axis="data")

    def generic(x):
        assign = [None] * len(x.shape)
        for axis in ("model", "data"):
            size = SH.mesh_axis_size(mesh, axis)
            if size <= 1:
                continue
            cands = [(d, i) for i, d in enumerate(x.shape)
                     if assign[i] is None and d % size == 0 and d >= size]
            if cands:
                assign[max(cands)[1]] = axis
        return P(*assign)

    if cfg.optimizer == "adamw":
        opt_ps = type(opt_abs)(P(), param_ps, param_ps, param_ps)
    else:
        vr_ps = jax.tree.map(generic, opt_abs.vr)
        vc_ps = jax.tree.map(generic, opt_abs.vc)
        opt_ps = type(opt_abs)(P(), vr_ps, vc_ps)
    return opt_abs, opt_ps


def build_cell(arch: str, shape_name: str, mesh):
    """Returns (fn, abstract_args, in_shardings) for one cell."""
    from repro.models import spec as S
    from repro.models import transformer as T
    from repro.serving import serve_step as SS
    from repro.training import train_step as TS

    cfg = get_config(arch)
    kind = SHAPES[shape_name]["kind"]
    spec_tree = T.param_specs(cfg, dtype=jnp.bfloat16)
    params_abs = S.abstract_params(spec_tree)
    params_sh = SH.param_pspecs(
        spec_tree, mesh, opt_data_axis="data" if cfg.fsdp else None)
    daxes = SH.batch_axes(mesh)
    dsize = 1
    for a in daxes:
        dsize *= SH.mesh_axis_size(mesh, a)
    dspec = daxes if len(daxes) > 1 else daxes[0]

    def bspec(shape):
        ps = [None] * len(shape)
        if shape[0] % dsize == 0 and shape[0] >= dsize:
            ps[0] = dspec
        return P(*ps)

    if kind == "train":
        batch_abs = batch_specs(cfg, shape_name)
        batch_sh = {k: bspec(v.shape) for k, v in batch_abs.items()}
        opt_abs, opt_ps = _opt_abstract_and_pspecs(cfg, params_abs, spec_tree,
                                                   mesh)
        fn = TS.make_train_step(cfg, dp_size=dsize,
                                batch_axes=daxes if daxes else None)
        args = (params_abs, opt_abs, batch_abs)
        shardings = (params_sh, opt_ps, batch_sh)
        return fn, args, shardings, (0, 1), cfg   # donate params + opt state

    if kind == "prefill":
        batch_abs = batch_specs(cfg, shape_name)
        batch_sh = {k: bspec(v.shape) for k, v in batch_abs.items()}
        fn = SS.make_prefill(cfg, cache_len=SHAPES[shape_name]["seq"])
        return fn, (params_abs, batch_abs), (params_sh, batch_sh), (), cfg

    # decode
    cache_abs, token_abs, pos_abs = decode_specs(cfg, shape_name)
    info = SHAPES[shape_name]
    cache_sh = SH.cache_pspecs(cache_abs, mesh, batch=info["batch"],
                               seq_len=info["seq"])
    token_sh = bspec(token_abs.shape)
    fn = SS.make_decode(cfg)
    args = (params_abs, cache_abs, token_abs, pos_abs)
    shardings = (params_sh, cache_sh, token_sh, P())
    return fn, args, shardings, (1,), cfg         # donate the cache


def run_cell(arch: str, shape_name: str, mesh_kind: str, verbose=True):
    cfg = get_config(arch)
    ok, reason = cell_runnable(cfg, shape_name)
    cell_id = f"{arch}.{shape_name}.{mesh_kind}"
    if not ok:
        return {"cell": cell_id, "status": "SKIP", "reason": reason}

    mesh = M.make_production_mesh(multi_pod=(mesh_kind == "multi"))
    t0 = time.time()
    fn, args, shardings, donate, cfg = build_cell(arch, shape_name, mesh)
    shardings = jax.tree.map(
        lambda ps: NamedSharding(mesh, ps), shardings,
        is_leaf=lambda x: isinstance(x, P))
    jax.set_mesh(mesh)  # ambient mesh: model code reads it for constraints
    with mesh:
        jfn = jax.jit(fn, in_shardings=shardings, donate_argnums=donate)
        lowered = jfn.lower(*args)
        t_lower = time.time() - t0
        compiled = lowered.compile()
        t_compile = time.time() - t0 - t_lower

    mem = compiled.memory_analysis()
    cost = compiled.cost_analysis()
    hlo = compiled.as_text()
    coll = parse_collective_bytes(hlo)
    # loop-aware accounting (XLA's cost_analysis counts while bodies once;
    # see repro/analysis/hlo_cost.py)
    from repro.analysis import hlo_cost as HC
    aware = HC.analyze(hlo)
    n_chips = mesh.devices.size
    result = {
        "cell": cell_id,
        "status": "OK",
        "chips": n_chips,
        "flops_per_device": float(aware["flops"]),
        "bytes_per_device": float(aware["bytes"]),
        "collective_bytes_per_device": aware["collective_bytes"],
        "xla_flops_per_device_loopsonce": float(cost.get("flops", 0.0)),
        "xla_bytes_per_device_loopsonce": float(
            cost.get("bytes accessed", 0.0)),
        "collective_bytes_unscaled": coll,
        "memory": {
            "argument_bytes": int(mem.argument_size_in_bytes),
            "output_bytes": int(mem.output_size_in_bytes),
            "temp_bytes": int(mem.temp_size_in_bytes),
            "alias_bytes": int(mem.alias_size_in_bytes),
            "peak_estimate": int(mem.argument_size_in_bytes
                                 + mem.output_size_in_bytes
                                 + mem.temp_size_in_bytes
                                 - mem.alias_size_in_bytes),
        },
        "lower_s": round(t_lower, 1),
        "compile_s": round(t_compile, 1),
    }
    if verbose:
        print(json.dumps(result, indent=1))
        print(f"[memory_analysis] {mem}")
    return result


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch")
    ap.add_argument("--shape")
    ap.add_argument("--mesh", default="single", choices=["single", "multi"])
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--force", action="store_true")
    args = ap.parse_args()
    OUT_DIR.mkdir(parents=True, exist_ok=True)

    if not args.all:
        res = run_cell(args.arch, args.shape, args.mesh)
        out = OUT_DIR / f"{res['cell']}.json"
        out.write_text(json.dumps(res, indent=1))
        print(f"wrote {out}")
        sys.exit(0 if res["status"] in ("OK", "SKIP") else 1)

    # --all: one subprocess per cell (isolates compile memory, resumable)
    failures = []
    for arch in list_archs():
        for shape in SHAPES:
            cell = f"{arch}.{shape}.{args.mesh}"
            out = OUT_DIR / f"{cell}.json"
            if out.exists() and not args.force:
                print(f"skip (cached): {cell}")
                continue
            print(f"=== {cell} ===", flush=True)
            r = subprocess.run(
                [sys.executable, "-m", "repro.launch.dryrun",
                 "--arch", arch, "--shape", shape, "--mesh", args.mesh],
                cwd=str(Path(__file__).resolve().parents[2]),
                env={**os.environ, "PYTHONPATH": str(
                    Path(__file__).resolve().parents[2])},
            )
            if r.returncode != 0:
                failures.append(cell)
                out.write_text(json.dumps(
                    {"cell": cell, "status": "FAIL"}, indent=1))
    print(f"done; failures: {failures}")
    sys.exit(1 if failures else 0)


if __name__ == "__main__":
    main()
