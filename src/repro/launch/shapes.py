"""Assigned input-shape cells and abstract input specs (no allocation).

Shape table (assignment):
    train_4k      seq 4,096   global_batch 256   lowers train_step
    prefill_32k   seq 32,768  global_batch 32    lowers prefill_step
    decode_32k    seq 32,768  global_batch 128   lowers decode_step
    long_500k     seq 524,288 global_batch 1     lowers decode_step;
                  runs only for sub-quadratic archs (DESIGN.md §5)

``input_specs`` returns ShapeDtypeStruct stand-ins for every model input —
weak-type-correct, shardable, never allocated.
"""
from __future__ import annotations

from typing import Dict

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig

SHAPES: Dict[str, dict] = {
    "train_4k": dict(kind="train", seq=4096, batch=256),
    "prefill_32k": dict(kind="prefill", seq=32768, batch=32),
    "decode_32k": dict(kind="decode", seq=32768, batch=128),
    "long_500k": dict(kind="decode", seq=524288, batch=1),
}


def cell_runnable(cfg: ArchConfig, shape_name: str):
    """(runs?, reason-if-skipped)."""
    if shape_name == "long_500k" and not cfg.supports_long_context:
        return False, ("full quadratic attention; 500K-token decode needs "
                       "sub-quadratic attention (skip noted in DESIGN.md §5)")
    return True, ""


def batch_specs(cfg: ArchConfig, shape_name: str) -> Dict[str, jax.ShapeDtypeStruct]:
    """Abstract batch for train/prefill kinds."""
    info = SHAPES[shape_name]
    B, S = info["batch"], info["seq"]
    d = cfg.d_model
    out = {}
    s_txt = S
    if cfg.frontend == "vision_stub":
        s_txt = S - cfg.vis_tokens
        out["patches"] = jax.ShapeDtypeStruct((B, cfg.vis_tokens, d), jnp.bfloat16)
    if cfg.is_encoder_decoder:
        out["frames"] = jax.ShapeDtypeStruct((B, cfg.encoder_seq, d), jnp.bfloat16)
    out["tokens"] = jax.ShapeDtypeStruct((B, s_txt), jnp.int32)
    return out


def decode_specs(cfg: ArchConfig, shape_name: str):
    """(cache_abstract, token, pos) for decode kinds."""
    from repro.models import transformer as T
    info = SHAPES[shape_name]
    B, S = info["batch"], info["seq"]
    cache = jax.eval_shape(lambda: T.init_cache(cfg, B, S))
    token = jax.ShapeDtypeStruct((B,), jnp.int32)
    pos = jax.ShapeDtypeStruct((), jnp.int32)
    return cache, token, pos
