"""Serving: prefill + decode steps with batched requests.

Three jittable entry points per architecture:

  prefill_step(params, batch)              -> (last_logits, cache)
  decode_step(params, cache, token, pos)   -> (logits, cache)
  serve_decode = greedy wrapper used by examples/serve driver

The decode KV cache layout and sharding are described in
repro/dist/sharding.py (batch over data axes; cache sequence over `model` —
flash-decoding).  Recurrent archs (rglru/mlstm/slstm) carry O(1) states, so
``long_500k`` decoding holds no 500K-slot cache for them — that is exactly
why those cells run (DESIGN.md §5).
"""
from __future__ import annotations

from typing import Dict

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.models import transformer as T


def make_prefill(cfg: ArchConfig, cache_len: int):
    def prefill_step(params, batch: Dict[str, jnp.ndarray]):
        x, _, cache = T.forward(params, cfg, batch, cache_len=cache_len)
        logits = T.unembed(params, cfg, x[:, -1]).astype(jnp.float32)
        return logits, cache

    return prefill_step


def make_decode(cfg: ArchConfig):
    def decode_step(params, cache, token, pos):
        return T.decode_step(params, cfg, token, cache, pos)

    return decode_step


def greedy_generate(cfg: ArchConfig, params, batch, *, steps: int,
                    cache_len: int):
    """Greedy generation driver (host loop; each step jittable)."""
    prefill = jax.jit(make_prefill(cfg, cache_len))
    decode = jax.jit(make_decode(cfg))
    logits, cache = prefill(params, batch)
    pos0 = batch["tokens"].shape[1] + (
        cfg.vis_tokens if cfg.frontend == "vision_stub" and "patches" in batch
        else 0)
    tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)
    out = [tok]
    for i in range(steps - 1):
        logits, cache = decode(params, cache, tok,
                               jnp.asarray(pos0 + i, jnp.int32))
        tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        out.append(tok)
    return jnp.stack(out, axis=1)
