"""Concurrent OLA serving — one shared scan, many queries (DESIGN.md §11).

The paper's interactive promise is many users watching estimates tighten
at once, but the batch engines price each query (or pre-declared
``GLABundle``) at one full scan.  Following OLA-RAW's shared-cursor
design (PAPERS.md, arXiv 1702.00358), this module serves dynamically
arriving queries from ONE in-flight cyclic scan per dataset:

  * :class:`SharedScan` — the synchronous core.  It advances one
    round-slice per :meth:`SharedScan.step` over a fixed uniform
    schedule, cycling ``cursor mod R``; queries attach at any round into
    a **padded slot bundle** and detach on convergence without stopping
    the scan.  A late joiner's carry starts at zero on its attach round,
    so its estimates are built from *witnessed* rounds only — the
    Horvitz–Thompson scale-up ``d_total / scanned`` keeps bounds
    unbiased no matter when the query joined
    (``tests/test_service.py`` proves bitwise identity with a fresh
    solo Session over the witnessed chunk ranges).
  * :class:`OLAService` — the asyncio front end.  ``await
    service.submit(spec, data)`` returns a :class:`QueryHandle`;
    the service owns one SharedScan per (source fingerprint, engine),
    drives it on an executor thread, applies attach/detach between
    steps, and **parks** an idle scan after a grace period (the drive
    task exits; the scan object — cursor position and warm jit caches —
    stays for the next arrival).

Recompile discipline (the hard part): bundle membership changes on
every arrival/departure, but the jitted step's shapes must not.  Slots
live in power-of-two capacity banks; per-slot query parameters
(:class:`repro.core.gla.SlotParams`) are **dynamic** jit inputs, and an
inactive slot carries the empty predicate range (weight exactly 0).
The step functions' static arguments are only (family, bank,
confidence) — so the jit cache grows by exactly one entry per
capacity doubling per bank per engine, never per query
(``analysis/audit.py`` ``bounded_compiles_under_churn``).  Slot
generations let a detached query's state be reclaimed: attach marks the
slot ``fresh`` and the step resets its carry to the init state via
``jnp.where`` *inside* the jit region (no shape change, and no
``0 * x`` masking — that would turn negative carries into ``-0.0`` and
break bitwise identity with a fresh query's ``+0.0`` init).
"""
from __future__ import annotations

import asyncio
import dataclasses
import functools
import time
from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import engine as EN
from repro.core import scan as SC
from repro.core.gla import SlotFamily, SlotParams, SlotQuery
from repro.core.session import RoundProgress
from repro.core.spec import QuerySpec
from repro.data import source as DSRC


# ---------------------------------------------------------------------------
# jitted per-round steps — the serving twins of session._step_vmapped /
# shard_engine.session_step_sharded.  Statics are (family, bank,
# confidence[, mesh]) only: per-slot query parameters are dynamic inputs,
# so the cache grows ONLY when a bank's slot capacity (the K in the
# params/states shapes) doubles.
# ---------------------------------------------------------------------------

def _reset_fresh(params: SlotParams, states: tuple) -> tuple:
    """Zero the carries of freshly (re)claimed slots — inside the jit
    region, shape-stable, and via ``jnp.where`` so reclaimed state is
    bitwise the init state (multiplicative masking would leave -0.0)."""
    def one(k, st):
        return jax.tree.map(
            lambda x: jnp.where(params.fresh[k], jnp.zeros((), x.dtype), x),
            st)

    return tuple(one(k, st) for k, st in enumerate(states))


@functools.partial(
    jax.jit, static_argnames=("family", "bank", "confidence"))
def serve_step_vmapped(family: SlotFamily, bank: str, params: SlotParams,
                       states, slice_shards: dict, w_r: jnp.ndarray,
                       d_local: jnp.ndarray, d_total: jnp.ndarray, *,
                       confidence: float):
    """Advance one bank of the shared scan one round-slice (vmapped).

    Mirrors ``session._step_vmapped``'s scan branch over the bank's
    K-slot bundle: per-partition ``scan_round_step``, estimator
    terminate, the same weighted round merge, then per-slot Estimates.
    Returns (new states tuple, tuple of K Estimates).
    """
    states = _reset_fresh(params, states)
    gla = family.bind(bank, params, d_total)
    new_states, views = jax.vmap(
        lambda st, c: SC.scan_round_step(gla, st, c, 1)
    )(states, slice_shards)
    term = jax.vmap(
        lambda s, dl: gla.estimator_terminate(s, {"d_local": dl})
    )(views, d_local)
    merged = EN._merge_rounds(
        gla, jax.tree.map(lambda x: x[:, None], term), w_r[:, None],
        gla.estimator_merge, True)
    merged = jax.tree.map(lambda x: x[0], merged)
    est = gla.estimate(merged, confidence, {"d_total": d_total})
    return new_states, est


@functools.partial(
    jax.jit,
    static_argnames=("family", "bank", "mesh", "axis_name", "confidence"))
def serve_step_sharded(family: SlotFamily, bank: str, params: SlotParams,
                       states, slice_shards: dict, w_r: jnp.ndarray,
                       d_local: jnp.ndarray, d_total: jnp.ndarray, *, mesh,
                       axis_name: str, confidence: float):
    """The shard_map twin: partitions on ``axis_name``, slot parameters
    replicated, the bank GLA bound *inside* the worker, one psum merge
    per step — the same discipline as ``session_step_sharded``."""
    from jax import lax
    from jax.sharding import PartitionSpec as PS

    from repro.dist.shard_engine import _shard_map

    states = _reset_fresh(params, states)

    def worker(pp, dt, st, cols, w_p, dl):
        st = jax.tree.map(lambda x: x[0], st)
        cols = jax.tree.map(lambda x: x[0], cols)
        gla = family.bind(bank, pp, dt)
        new_st, view = SC.scan_round_step(gla, st, cols, 1)
        term = gla.estimator_terminate(view, {"d_local": dl[0]})
        merged = lax.psum(
            jax.tree.map(lambda x: x * w_p[0].astype(x.dtype), term),
            axis_name)
        return jax.tree.map(lambda x: x[None], new_st), merged

    pspec = PS(axis_name)
    fn = _shard_map(worker, mesh, (PS(), PS(), pspec, pspec, pspec, pspec),
                    (pspec, PS()))
    new_states, merged = fn(params, d_total, states, slice_shards, w_r,
                            d_local)
    gla = family.bind(bank, params, d_total)
    est = gla.estimate(merged, confidence, {"d_total": d_total})
    return new_states, est


def serve_step_cache_sizes() -> Dict[str, Optional[int]]:
    """Current jit-cache entry counts of the serving steps — what the
    audit's churn check reads before/after a workload."""
    out = {}
    for name, fn in (("vmapped", serve_step_vmapped),
                     ("sharded", serve_step_sharded)):
        size = getattr(fn, "_cache_size", None)
        out[name] = size() if callable(size) else None
    return out


# ---------------------------------------------------------------------------
# the shared scan (synchronous core)
# ---------------------------------------------------------------------------

def _degrade_rounds(C: int, rounds: int) -> int:
    """Largest r <= rounds with C % r == 0 — one slice width for the
    whole cyclic scan, so each (bank, capacity) pair is ONE compile."""
    for r in range(min(int(rounds), C), 0, -1):
        if C % r == 0:
            return r
    return 1


@dataclasses.dataclass
class SlotRecord:
    """One attached query's slot, progress, and outcome."""

    query: SlotQuery
    bank: str
    slot: int
    generation: int
    stop: Optional[Any] = None
    witnessed: List[Tuple[int, int]] = dataclasses.field(default_factory=list)
    scanned: float = 0.0
    estimate: Any = None                  # latest per-round Estimate
    elapsed_s: float = 0.0
    done: bool = False
    converged: bool = False               # stop rule fired (vs full pass)
    detached: bool = False


class _Bank:
    """One capacity bank: host-side slot parameters + device carries.

    ``K`` is a power of two; parameter rows of detached slots hold the
    empty range (predicate weight exactly 0).  ``generation[k]``
    increments on every attach, so a stale handle can never read a
    reclaimed slot's results.
    """

    def __init__(self, name: str, family: SlotFamily, P: int, *,
                 mesh=None, axis_name: str = "data"):
        self.name = name
        self.family = family
        self.P = P
        self.mesh = mesh
        self.axis_name = axis_name
        self.K = 1
        n_pred = len(family.pred_cols)
        self.expr = np.zeros(1, np.int32)
        self.lo = np.full((1, n_pred), np.inf, np.float32)
        self.hi = np.full((1, n_pred), -np.inf, np.float32)
        self.fresh = np.zeros(1, bool)
        self.hv = np.full(1, np.inf, np.float32)
        self.generation = np.zeros(1, np.int64)
        self.slots: List[Optional[SlotRecord]] = [None]
        self.states = (self._zero_state(),)
        self.stepped_ks: set = set()      # capacities actually executed

    def _zero_state(self):
        z = self.family.zero_slot_state(self.name)
        z = jax.tree.map(
            lambda x: jnp.broadcast_to(x, (self.P, *x.shape)), z)
        if self.mesh is None:
            return z
        # commit fresh carries to the SAME sharding the sharded step
        # outputs (partitions on the mesh axis) — otherwise the step
        # after a capacity growth sees a different input-sharding cache
        # key than steady state and recompiles once per capacity
        from jax.sharding import NamedSharding
        from jax.sharding import PartitionSpec as PS
        sh = NamedSharding(self.mesh, PS(self.axis_name))
        return jax.tree.map(lambda x: jax.device_put(x, sh), z)

    @property
    def active(self) -> int:
        return sum(1 for s in self.slots if s is not None)

    @property
    def doublings(self) -> int:
        return int(self.K).bit_length() - 1

    def _grow(self) -> None:
        n_pred = len(self.family.pred_cols)
        K = self.K
        self.expr = np.concatenate([self.expr, np.zeros(K, np.int32)])
        self.lo = np.concatenate(
            [self.lo, np.full((K, n_pred), np.inf, np.float32)])
        self.hi = np.concatenate(
            [self.hi, np.full((K, n_pred), -np.inf, np.float32)])
        self.fresh = np.concatenate([self.fresh, np.zeros(K, bool)])
        self.hv = np.concatenate([self.hv, np.full(K, np.inf, np.float32)])
        self.generation = np.concatenate(
            [self.generation, np.zeros(K, np.int64)])
        self.slots.extend([None] * K)
        self.states = self.states + tuple(self._zero_state()
                                          for _ in range(K))
        self.K = 2 * K

    def attach(self, q: SlotQuery, stop) -> SlotRecord:
        try:
            k = self.slots.index(None)
        except ValueError:
            self._grow()
            k = self.slots.index(None)
        expr_idx, lo, hi = self.family.slot_row(q)
        self.expr[k] = expr_idx
        self.lo[k], self.hi[k] = lo, hi
        self.hv[k] = np.inf if q.having is None else q.having
        self.fresh[k] = True
        self.generation[k] += 1
        rec = SlotRecord(query=q, bank=self.name, slot=k,
                         generation=int(self.generation[k]), stop=stop)
        self.slots[k] = rec
        return rec

    def detach(self, rec: SlotRecord) -> None:
        k = rec.slot
        if rec.detached or self.slots[k] is not rec:
            return                        # stale ticket: slot was reclaimed
        rec.detached = True
        self.slots[k] = None
        e, lo, hi = self.family.inactive_row()
        self.expr[k] = e
        self.lo[k], self.hi[k] = lo, hi
        self.hv[k] = np.inf
        # state is NOT cleared here — the next attach marks the slot
        # fresh and the jitted step reclaims the carry in-region

    def params(self) -> SlotParams:
        # hv rides along only for having banks — classic banks keep the
        # 4-field params their jitted steps were traced with
        hv = (jnp.asarray(self.hv) if self.name.endswith(":having")
              else None)
        return SlotParams(expr=jnp.asarray(self.expr),
                          lo=jnp.asarray(self.lo), hi=jnp.asarray(self.hi),
                          fresh=jnp.asarray(self.fresh), hv=hv)


class SharedScan:
    """One cyclic scan over one dataset, serving many slot queries.

    The scan advances one round-slice per :meth:`step`, cycling
    ``cursor mod R`` over a uniform schedule (``rounds`` degrades to the
    largest divisor of the chunk count, so every slice has the one width
    the jitted steps compiled for).  Queries :meth:`attach` at any round
    — their carry starts fresh on the next step — and complete after
    witnessing all R rounds (one full pass) or when their stopping rule
    fires; :meth:`detach` frees the slot without disturbing the cursor
    or any other query.

    Synchronous and single-threaded by contract: :class:`OLAService`
    serializes attach/detach against in-flight steps.
    """

    def __init__(self, family: SlotFamily, data, *, rounds: int = 8,
                 confidence: float = 0.95, mesh=None,
                 axis_name: str = "data"):
        self.family = family
        self.source = DSRC.as_source(data)
        self.confidence = float(confidence)
        self.mesh = mesh
        self.axis_name = axis_name
        spec = self.source.spec
        self.P, self.C = spec.P, spec.C
        self.rounds = _degrade_rounds(self.C, rounds)
        self.width = self.C // self.rounds
        ms = self.source.mask_chunk_sums()
        self._ms = ms
        self._d_local = jnp.asarray(ms.sum(axis=1), jnp.float32)
        self._d_total = jnp.asarray(ms.sum(), jnp.float32)
        self._w_r = jnp.ones((self.P,), jnp.float32)
        self.banks: Dict[str, _Bank] = {}
        self.cursor = 0
        self.steps_done = 0

    # -- membership ---------------------------------------------------------

    @property
    def active_slots(self) -> int:
        return sum(b.active for b in self.banks.values())

    def attach(self, q: SlotQuery, stop=None) -> SlotRecord:
        name = self.family.bank_of(q)
        bank = self.banks.get(name)
        if bank is None:
            bank = self.banks[name] = _Bank(name, self.family, self.P,
                                            mesh=self.mesh,
                                            axis_name=self.axis_name)
        return bank.attach(q, stop)

    def detach(self, rec: SlotRecord) -> None:
        bank = self.banks.get(rec.bank)
        if bank is not None:
            bank.detach(rec)

    def compile_budget(self) -> int:
        """Jit-cache entries this scan's workload is allowed to have
        created: one per (bank, capacity) pair actually stepped — i.e.
        1 + #doublings per stepped bank — never one per arrival."""
        return sum(len(b.stepped_ks) for b in self.banks.values())

    # -- the drive ----------------------------------------------------------

    def _slice(self, lo: int, hi: int):
        if self.source.resident:
            shards = self.source.shards  # type: ignore[attr-defined]
            return {k: v[:, lo:hi] for k, v in shards.items()}
        cols = self.source.slice_cols(lo, hi)
        if self.mesh is None:
            return jax.device_put(cols)
        from repro.dist import shard_engine
        return shard_engine.device_put_slice(cols, mesh=self.mesh,
                                             axis_name=self.axis_name)

    def step(self) -> List[Tuple[SlotRecord, RoundProgress]]:
        """Advance every bank with live queries one round-slice; return
        the (record, progress) of each slot that witnessed the round.
        Completed slots come back with ``done`` set — the caller (the
        service) detaches them."""
        t0 = time.perf_counter()
        r = self.cursor % self.rounds
        lo, hi = r * self.width, (r + 1) * self.width
        live = {n: b for n, b in self.banks.items() if b.active}
        if not live:
            return []
        slice_shards = self._slice(lo, hi)
        range_count = float(self._ms[:, lo:hi].sum())
        out: List[Tuple[SlotRecord, RoundProgress]] = []
        for name, bank in live.items():
            params = bank.params()
            if self.mesh is None:
                new_states, est = serve_step_vmapped(
                    self.family, name, params, bank.states, slice_shards,
                    self._w_r, self._d_local, self._d_total,
                    confidence=self.confidence)
            else:
                new_states, est = serve_step_sharded(
                    self.family, name, params, bank.states, slice_shards,
                    self._w_r, self._d_local, self._d_total, mesh=self.mesh,
                    axis_name=self.axis_name, confidence=self.confidence)
            bank.states = new_states
            bank.fresh[:] = False
            bank.stepped_ks.add(bank.K)
            dt = time.perf_counter() - t0
            for k, rec in enumerate(bank.slots):
                if rec is None:
                    continue
                rec.witnessed.append((lo, hi))
                rec.scanned += range_count
                rec.estimate = est[k]
                rec.elapsed_s += dt
                prog = RoundProgress(
                    round=len(rec.witnessed), rounds_total=self.rounds,
                    estimates=est[k], scanned=rec.scanned,
                    d_total=float(self._d_total), elapsed_s=rec.elapsed_s)
                if rec.stop is not None and rec.stop(prog):
                    rec.converged = True
                if rec.converged or len(rec.witnessed) >= self.rounds:
                    rec.done = True
                out.append((rec, prog))
        self.cursor += 1
        self.steps_done += 1
        return out


def witnessed_view(data, ranges) -> dict:
    """The chunk ranges a slot witnessed, concatenated in witness order,
    as a fresh [P, C', L] shards dict — the dataset a solo Session must
    scan to reproduce the slot's estimates bitwise (tests, DESIGN.md
    §11).  ``data`` is a shards dict or ChunkSource."""
    src = DSRC.as_source(data)
    parts = [src.slice_cols(lo, hi) for lo, hi in ranges]
    return {k: np.concatenate([np.asarray(p[k]) for p in parts], axis=1)
            for k in parts[0]}


# ---------------------------------------------------------------------------
# the asyncio service
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class QueryOutcome:
    """What :meth:`QueryHandle.result` resolves to."""

    estimate: Any                 # final witnessed-rounds Estimate (host)
    rounds_witnessed: int
    scanned: float
    d_total: float
    converged: bool               # stop rule fired (False = full pass)
    elapsed_s: float


class QueryHandle:
    """An in-flight serving query: progress stream + awaitable result."""

    def __init__(self, query: SlotQuery, stop):
        self.query = query
        self._stop = stop
        self.progress: List[RoundProgress] = []
        self._done = asyncio.Event()
        self._outcome: Optional[QueryOutcome] = None
        self._record: Optional[SlotRecord] = None
        self._cancelled = False

    @property
    def done(self) -> bool:
        return self._done.is_set()

    async def result(self) -> QueryOutcome:
        await self._done.wait()
        assert self._outcome is not None
        return self._outcome

    def _finish(self, rec: SlotRecord, d_total: float) -> None:
        est = (jax.device_get(rec.estimate)
               if rec.estimate is not None else None)
        self._outcome = QueryOutcome(
            estimate=est, rounds_witnessed=len(rec.witnessed),
            scanned=rec.scanned, d_total=d_total,
            converged=rec.converged, elapsed_s=rec.elapsed_s)
        self._done.set()


class OLAService:
    """Asyncio OLA serving over shared scans (DESIGN.md §11).

    One service owns one :class:`repro.core.gla.SlotFamily` and one
    in-flight :class:`SharedScan` per (source fingerprint, engine).
    ``submit`` attaches a query to the matching scan — starting or
    un-parking it as needed — and returns a :class:`QueryHandle` whose
    ``result()`` resolves when the query converges (stop rule) or
    completes a full pass.  Convergence detaches the slot; the scan
    keeps running for the remaining queries and parks ``grace_s``
    seconds after the last one leaves (the drive task exits; the scan's
    cursor and the jitted steps' warm caches survive for the next
    arrival).

    All scan mutation happens on the event-loop thread between executor
    steps, so SharedScan itself needs no locking.
    """

    def __init__(self, family: SlotFamily, *, rounds: int = 8,
                 confidence: float = 0.95, grace_s: float = 0.25,
                 mesh=None, axis_name: str = "data"):
        self.family = family
        self.rounds = rounds
        self.confidence = confidence
        self.grace_s = grace_s
        self.mesh = mesh
        self.axis_name = axis_name
        self._runners: Dict[tuple, "_Runner"] = {}
        self._closed = False

    # -- public surface -----------------------------------------------------

    async def submit(self, spec, data) -> QueryHandle:
        """Attach one slot query.  ``spec`` is a
        :class:`repro.core.spec.QuerySpec` whose ``gla`` is a
        :class:`repro.core.gla.SlotQuery` (its ``stop`` rule is
        honored; ``rounds`` is scan-wide, set on the service), or a
        bare ``SlotQuery``."""
        if self._closed:
            raise RuntimeError("service is closed")
        if isinstance(spec, QuerySpec):
            query, stop = spec.gla, spec.stop
            if spec.confidence != self.confidence:
                raise ValueError(
                    f"per-query confidence {spec.confidence} != service "
                    f"confidence {self.confidence}: confidence is a "
                    "compile-time static of the shared step — set it on "
                    "OLAService(...)")
        elif isinstance(spec, SlotQuery):
            query, stop = spec, None
        else:
            raise TypeError(
                "submit() takes a SlotQuery or a QuerySpec wrapping one, "
                f"got {type(spec).__name__}")
        if not isinstance(query, SlotQuery):
            raise TypeError(
                f"QuerySpec.gla must be a SlotQuery here, got "
                f"{type(query).__name__}")
        src = DSRC.as_source(data)
        key = (src.fingerprint(),
               "vmapped" if self.mesh is None else "sharded")
        runner = self._runners.get(key)
        if runner is None:
            scan = SharedScan(self.family, src, rounds=self.rounds,
                              confidence=self.confidence, mesh=self.mesh,
                              axis_name=self.axis_name)
            runner = self._runners[key] = _Runner(scan)
        handle = QueryHandle(query, stop)
        runner.pending.append(("attach", handle))
        runner.wake.set()
        if runner.task is None or runner.task.done():
            runner.task = asyncio.get_running_loop().create_task(
                self._drive(runner))
        return handle

    def cancel(self, handle: QueryHandle) -> None:
        """Detach a query before it converges; its handle resolves with
        whatever it had witnessed so far."""
        handle._cancelled = True
        for runner in self._runners.values():
            if handle in runner.handles.values() or any(
                    h is handle for _, h in runner.pending):
                runner.pending.append(("detach", handle))
                runner.wake.set()
                return

    def scan_for(self, data) -> Optional[SharedScan]:
        """The shared scan serving ``data`` on this service's engine, if
        one exists (parked or running) — introspection for tests/audit."""
        key = (DSRC.as_source(data).fingerprint(),
               "vmapped" if self.mesh is None else "sharded")
        runner = self._runners.get(key)
        return runner.scan if runner is not None else None

    def is_parked(self, data) -> bool:
        key = (DSRC.as_source(data).fingerprint(),
               "vmapped" if self.mesh is None else "sharded")
        runner = self._runners.get(key)
        return runner is not None and (runner.task is None
                                       or runner.task.done())

    async def close(self) -> None:
        self._closed = True
        tasks = [r.task for r in self._runners.values()
                 if r.task is not None and not r.task.done()]
        for t in tasks:
            t.cancel()
        for t in tasks:
            try:
                await t
            except asyncio.CancelledError:
                pass

    async def __aenter__(self) -> "OLAService":
        return self

    async def __aexit__(self, *exc) -> None:
        await self.close()

    # -- the drive loop -----------------------------------------------------

    def _apply_pending(self, runner: "_Runner") -> None:
        pending, runner.pending = runner.pending, []
        d_total = float(runner.scan._d_total)
        for op, handle in pending:
            if op == "attach":
                if handle._cancelled:
                    handle._finish(SlotRecord(handle.query, "", -1, 0),
                                   d_total)
                    continue
                rec = runner.scan.attach(handle.query, handle._stop)
                handle._record = rec
                runner.handles[id(rec)] = handle
            else:  # detach
                rec = handle._record
                if rec is not None and not rec.detached:
                    runner.scan.detach(rec)
                    runner.handles.pop(id(rec), None)
                    handle._finish(rec, d_total)

    async def _drive(self, runner: "_Runner") -> None:
        loop = asyncio.get_running_loop()
        while True:
            self._apply_pending(runner)
            if runner.scan.active_slots == 0:
                runner.wake.clear()
                if runner.pending:
                    continue
                try:
                    await asyncio.wait_for(runner.wake.wait(), self.grace_s)
                except asyncio.TimeoutError:
                    return                # park: scan object stays warm
                continue
            progressed = await loop.run_in_executor(None, runner.scan.step)
            for rec, prog in progressed:
                handle = runner.handles.get(id(rec))
                if handle is None:
                    continue
                handle.progress.append(prog)
                if rec.done:
                    runner.scan.detach(rec)
                    runner.handles.pop(id(rec), None)
                    handle._finish(rec, float(runner.scan._d_total))
            # yield so submit()/cancel() callbacks enqueue between steps
            await asyncio.sleep(0)


class _Runner:
    """One shared scan's drive state: the scan, its (possibly parked)
    task, queued attach/detach ops, and the record->handle map."""

    def __init__(self, scan: SharedScan):
        self.scan = scan
        self.task: Optional[asyncio.Task] = None
        self.pending: List[Tuple[str, QueryHandle]] = []
        self.wake = asyncio.Event()
        self.handles: Dict[int, QueryHandle] = {}
