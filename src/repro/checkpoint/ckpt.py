"""Serialize / Deserialize — the paper's UDA transfer extension, used for
(1) shipping GLA states between processes, (2) checkpoint/restart of both
aggregation queries and training state.

Format: msgpack envelope (treedef repr + leaf dtype/shape table) with
compressed little-endian leaf bytes.  Compression is zstd when the
``zstandard`` package is available, else zlib — the codec is identified by
the stream's own magic/format tag (zstd frame magic 0x28B52FFD vs. the zlib
header), so either side can read what the other wrote.  Restart is exact:
deserialized states are bit-identical, so a resumed query continues from
the same sample prefix (tests/test_ckpt.py).

For training, `save_train_state`/`load_train_state` snapshot
(params, opt_state, step, data-pipeline cursor) — the cursor makes the
sampling prefix reproducible after restart, which on-line estimation
requires (the sample so far must stay a without-replacement prefix).
"""
from __future__ import annotations

import zlib
from pathlib import Path
from typing import Any

import jax
import jax.numpy as jnp
import msgpack
import numpy as np

try:
    import zstandard
except ImportError:  # optional dependency — fall back to stdlib zlib
    zstandard = None

_ZSTD_MAGIC = b"\x28\xb5\x2f\xfd"


def _compress(raw: bytes) -> bytes:
    if zstandard is not None:
        return zstandard.ZstdCompressor(level=3).compress(raw)
    return zlib.compress(raw, 6)


def _decompress(buf: bytes) -> bytes:
    if buf[:4] == _ZSTD_MAGIC:
        if zstandard is None:
            raise RuntimeError(
                "checkpoint is zstd-compressed but zstandard is not "
                "installed (pip install zstandard)")
        return zstandard.ZstdDecompressor().decompress(buf)
    return zlib.decompress(buf)


def serialize_state(state: Any) -> bytes:
    leaves, treedef = jax.tree.flatten(state)
    arrs = [np.asarray(leaf) for leaf in leaves]
    payload = {
        "treedef": str(treedef),
        "leaves": [
            {"dtype": a.dtype.str, "shape": list(a.shape),
             "data": a.tobytes()} for a in arrs
        ],
    }
    raw = msgpack.packb(payload, use_bin_type=True)
    return _compress(raw)


def deserialize_state(buf: bytes, like: Any) -> Any:
    raw = _decompress(buf)
    payload = msgpack.unpackb(raw, raw=False)
    _, treedef = jax.tree.flatten(like)
    leaves = [
        jnp.asarray(np.frombuffer(rec["data"], dtype=np.dtype(rec["dtype"]))
                    .reshape(rec["shape"]))
        for rec in payload["leaves"]
    ]
    return jax.tree.unflatten(treedef, leaves)


def save_envelope(path: str | Path, meta: dict, blob: bytes) -> None:
    """Atomically write a (metadata header, opaque state blob) pair.

    ``meta`` is a plain msgpack-able dict readable without knowing the
    blob's pytree structure — the session layer (repro/core/session.py)
    stores its configuration fingerprint and scan cursor here so
    :func:`load_envelope` can rebuild the deserialization skeleton before
    touching the blob.  The blob is whatever :func:`serialize_state`
    produced (already compressed); pass ``b""`` for state-less envelopes.
    """
    raw = msgpack.packb({"meta": meta, "blob": blob}, use_bin_type=True)
    Path(path).parent.mkdir(parents=True, exist_ok=True)
    tmp = Path(str(path) + ".tmp")
    tmp.write_bytes(raw)
    tmp.replace(path)


def load_envelope(path: str | Path) -> tuple:
    """Read a :func:`save_envelope` file; returns ``(meta, blob)``."""
    d = msgpack.unpackb(Path(path).read_bytes(), raw=False)
    return d["meta"], d["blob"]


def require_version(meta: dict, supported, *, what: str = "checkpoint"):
    """Validate an envelope's ``version`` against the supported set.

    Returns the version so the caller can feature-gate on it: readers
    accept *older* formats whose fields are a subset of the current one
    (the session layer's v3 reader accepts v2 envelopes — DESIGN.md §9
    records the compatibility rule) but never newer or unknown ones.
    """
    version = meta.get("version")
    if version not in tuple(supported):
        raise ValueError(
            f"unsupported {what} version: {version!r} "
            f"(supported: {sorted(supported)})")
    return version


def save(path: str | Path, state: Any) -> None:
    Path(path).parent.mkdir(parents=True, exist_ok=True)
    tmp = Path(str(path) + ".tmp")
    tmp.write_bytes(serialize_state(state))
    tmp.replace(path)          # atomic publish — crash-safe restart point


def load(path: str | Path, like: Any) -> Any:
    return deserialize_state(Path(path).read_bytes(), like)


def save_train_state(path, params, opt_state, step: int, data_cursor: int):
    save(path, {
        "params": params,
        "opt": opt_state,
        "meta": {"step": jnp.asarray(step), "cursor": jnp.asarray(data_cursor)},
    })


def load_train_state(path, params_like, opt_like):
    like = {"params": params_like, "opt": opt_like,
            "meta": {"step": jnp.asarray(0), "cursor": jnp.asarray(0)}}
    st = load(path, like)
    return (st["params"], st["opt"], int(st["meta"]["step"]),
            int(st["meta"]["cursor"]))
