"""Concrete GLAs — paper Algorithms 1–4.

Constructors return :class:`repro.core.uda.GLA` bundles for the three
aggregation problems of paper §4, each in the three estimation models:

  * :func:`make_sum_gla`          — §4.3  single-table SUM/COUNT (Algs. 1, 2)
  * :func:`make_groupby_gla`      — §4.4  group-by aggregation (Alg. 3)
  * :func:`make_join_groupby_gla` — §4.5  join group-by with replicated
                                    dimension table (Alg. 4)

Queries are expressed as ``func(chunk) -> [n] or [n, A]`` (A simultaneous
aggregates, like TPC-H Q1's four SUMs) and ``cond(chunk) -> [n] in {0,1}``.
Group-by adds ``group(chunk) -> [n] int ids in [0, num_groups)``.

TPU adaptation (DESIGN.md §3): the per-group scatter is a
``jax.ops.segment_sum`` here (lowers to one-hot matmul / sorted segment ops on
TPU); the Pallas hot-path kernel in ``repro/kernels`` implements the identical
contraction with explicit VMEM tiling and is allclose-checked against these
reference semantics.  Group-by GLAs publish the ``(vals, weight, gids)``
kernel projection so ``engine.run_query(emit="kernel")`` reaches that kernel
directly (one dispatch per round-slice); large raw-id domains fold through
:func:`hash_bucket` into a 2**bucket_bits dense bucket table.
"""
from __future__ import annotations

from functools import lru_cache
from typing import Callable, Optional, Sequence

import jax
import jax.numpy as jnp

from repro.core import estimators as E
from repro.core.uda import GLA, Chunk, Estimate


def _as_2d(vals: jnp.ndarray) -> jnp.ndarray:
    """[n] -> [n, 1]; [n, A] stays."""
    return vals[:, None] if vals.ndim == 1 else vals


# ---------------------------------------------------------------------------
# Multi-query bundles (paper §3: "any number of concurrent estimation
# models" driven alongside one execution).  A bundle is itself a GLA whose
# state is the tuple of member states, so every engine scan path runs N
# queries over a single pass of the chunk stream.  Each member sees the
# exact same chunks in the exact same order as it would alone, so finals
# and snapshot states are bitwise-identical to solo runs
# (tests/test_multiquery.py).
# ---------------------------------------------------------------------------


def GLABundle(glas: Sequence[GLA], *, name: Optional[str] = None) -> GLA:
    """Stack heterogeneous GLAs into one fused GLA over a shared scan.

    The fused state is ``tuple(member states)``; accumulate/merge/terminate
    and the estimator extensions apply member-wise over the same chunk.
    ``estimate`` returns a tuple with one :class:`Estimate` per member
    (``None`` for members without an estimation model), preserving
    per-query round-emission views.  ``merge_is_additive`` holds iff it
    holds for every member — the engines' psum/tensordot merges then apply
    leaf-wise across the whole tuple.

    The bundle publishes no ``kernel_cols`` of its own; the engines'
    ``emit="kernel"`` path instead batches every member's kernel projection
    into one ``ops.group_agg`` dispatch per round-slice
    (``repro.core.scan.bundle_kernel_rounds_states``) when all members
    publish one.  Use :func:`repro.core.engine.run_queries` to execute a
    bundle and get per-query results back.

    Bundling the same member GLAs again returns the *same* bundle object
    (memoized): the engines' jit caches key on the GLA statically, so a
    repeated interactive workload must not pay an XLA recompile per
    ``run_queries`` call just because the combinator rebuilt its closures.
    """
    members = tuple(glas)
    if not members:
        raise ValueError("GLABundle needs at least one member GLA")
    if any(m.members for m in members):
        raise ValueError("GLABundle members must not themselves be bundles")
    return _bundle_cached(members, name)


@lru_cache(maxsize=256)
def _bundle_cached(members: tuple, name: Optional[str]) -> GLA:
    def init():
        return tuple(m.init() for m in members)

    def accumulate(state, chunk):
        return tuple(
            m.accumulate(s, chunk) for m, s in zip(members, state))

    def merge(a, b):
        return tuple(m.merge(x, y) for m, x, y in zip(members, a, b))

    def terminate(state):
        return tuple(m.terminate(s) for m, s in zip(members, state))

    def estimator_terminate(state, ctx=None):
        return tuple(
            m.estimator_terminate(s, ctx) for m, s in zip(members, state))

    def estimator_merge(a, b):
        return tuple(
            m.estimator_merge(x, y) for m, x, y in zip(members, a, b))

    def estimate(state, confidence, ctx=None):
        return tuple(
            m.estimate(s, confidence, ctx) if m.estimate is not None else None
            for m, s in zip(members, state))

    any_estimate = any(m.estimate is not None for m in members)
    return GLA(
        init=init, accumulate=accumulate, merge=merge, terminate=terminate,
        estimator_terminate=estimator_terminate,
        estimator_merge=estimator_merge,
        estimate=estimate if any_estimate else None,
        merge_is_additive=all(m.merge_is_additive for m in members),
        members=members,
        name=name or "bundle[" + "+".join(m.name for m in members) + "]",
    )


# ---------------------------------------------------------------------------
# Hash-bucketed group tables (paper §4.4 large-domain group-by, e.g. the
# 1M-group Q1).  The dense [G, A] composite state cannot scale with the raw
# id domain, so raw ids are folded into 2**bucket_bits buckets by a
# multiplicative hash.  The multiplier is odd, hence invertible mod 2**b:
# g -> (g * MULT) mod 2**b is a *bijection* on [0, 2**b), so any raw domain
# with num_groups <= 2**bucket_bits maps injectively and de-bucketing is
# exact (tests/test_groupby_kernel.py::
# test_kernel_final_matches_exact_debucketed).
# Larger domains fold ~num_groups / 2**b raw ids per bucket — the bucket
# then estimates the folded groups' combined aggregate.
# ---------------------------------------------------------------------------

_BUCKET_MULT = 2654435761  # 2**32 / golden ratio (Knuth), odd


def hash_bucket(gids: jnp.ndarray, bucket_bits: int) -> jnp.ndarray:
    """Raw group ids -> int32 bucket ids in [0, 2**bucket_bits)."""
    h = jnp.asarray(gids).astype(jnp.uint32) * jnp.uint32(_BUCKET_MULT)
    return (h & jnp.uint32((1 << bucket_bits) - 1)).astype(jnp.int32)


def debucket(bucket_vals: jnp.ndarray, raw_ids, bucket_bits: int):
    """Gather per-raw-id rows from a bucketed group table [2**b, ...].

    Exact whenever the active raw-id set maps injectively into buckets —
    guaranteed for num_groups <= 2**bucket_bits by the hash bijectivity.
    """
    idx = hash_bucket(jnp.asarray(raw_ids), bucket_bits)
    return jnp.take(bucket_vals, idx, axis=0)


# ---------------------------------------------------------------------------
# Paper Alg. 1 / Alg. 2 — GLASum, single / multiple / synchronized
# ---------------------------------------------------------------------------

def make_sum_gla(
    func: Callable[[Chunk], jnp.ndarray],
    cond: Callable[[Chunk], jnp.ndarray],
    *,
    d_total: float,
    estimator: str = "single",
    dtype=jnp.float32,
    num_aggs: int = 1,
) -> GLA:
    """SUM(func(d)) WHERE cond(d) — paper query (1).

    ``estimator``: "single" (Alg. 1), "multiple" (Alg. 2), "synchronized"
    (Wu et al.; same state as single — the barrier lives in the engine), or
    "none" (plain aggregate, the no-estimation overhead baseline).
    """
    A = num_aggs

    def zero_sum():
        z = jnp.zeros((A,), dtype)
        s = jnp.zeros((), dtype)
        return E.SumState(sum=z, sumsq=z, scanned=s, matched=s)

    def acc_sum(state: E.SumState, chunk: Chunk) -> E.SumState:
        vals = _as_2d(func(chunk)).astype(dtype)              # [n, A]
        w = (cond(chunk) * chunk["_mask"]).astype(dtype)      # [n]
        m = chunk["_mask"].astype(dtype)
        return E.SumState(
            sum=state.sum + vals.T @ w,
            sumsq=state.sumsq + (vals * vals).T @ w,
            scanned=state.scanned + jnp.sum(m),
            matched=state.matched + jnp.sum(w),
        )

    def merge(a, b):
        return jax.tree.map(jnp.add, a, b)

    def terminate(state):
        s = state.sum if A > 1 else state.sum[0]
        return s

    if estimator in ("single", "synchronized", "none"):

        def estimate(state: E.SumState, confidence, ctx=None) -> Estimate:
            est = E.horvitz_estimate(state.sum, state.scanned, d_total)
            var = E.variance_estimate(state.sum, state.sumsq, state.scanned, d_total)
            lo, hi = E.normal_bounds(est, var, confidence)
            sq = (lambda x: x) if A > 1 else (lambda x: x[0])
            return Estimate(sq(est), sq(lo), sq(hi),
                            info={"var": sq(var), "frac": state.scanned / d_total})

        # Per-shard fused-kernel dispatch (engine emit="kernel"): the Pallas
        # kernel reproduces acc_sum's state from (func, cond) projections —
        # only for the plain f32 single-aggregate SumState layout.
        if A == 1 and dtype == jnp.float32:
            def kernel_cols(chunk):
                return func(chunk), cond(chunk)
        else:
            kernel_cols = None

        return GLA(
            init=zero_sum, accumulate=acc_sum, merge=merge, terminate=terminate,
            estimate=None if estimator == "none" else estimate,
            merge_is_additive=True, kernel_cols=kernel_cols,
            name=f"sum-{estimator}",
        )

    if estimator == "multiple":

        def zero_mult():
            z = jnp.zeros((A,), dtype)
            return E.MultState(base=zero_sum(), est=z, estvar=z)

        def acc_mult(state: E.MultState, chunk: Chunk) -> E.MultState:
            return E.MultState(acc_sum(state.base, chunk), state.est, state.estvar)

        def merge_mult(a: E.MultState, b: E.MultState) -> E.MultState:
            # Merging *local* (pre-EstimatorTerminate) states: base adds,
            # est/estvar are not yet meaningful — keep additive for engine
            # uniformity (they are zero until estimator_terminate).
            return jax.tree.map(jnp.add, a, b)

        def est_term(state: E.MultState, ctx) -> E.MultState:
            """Alg. 2 EstimatorTerminate — needs |D_i| from the engine ctx."""
            b = state.base
            d_local = ctx["d_local"]
            est = E.horvitz_estimate(b.sum, b.scanned, d_local)
            var = E.variance_estimate(b.sum, b.sumsq, b.scanned, d_local)
            return E.MultState(b, est, var)

        def estimate(state: E.MultState, confidence, ctx=None) -> Estimate:
            lo, hi = E.normal_bounds(state.est, state.estvar, confidence)
            sq = (lambda x: x) if A > 1 else (lambda x: x[0])
            return Estimate(sq(state.est), sq(lo), sq(hi),
                            info={"var": sq(state.estvar)})

        return GLA(
            init=zero_mult, accumulate=acc_mult, merge=merge_mult,
            terminate=lambda s: terminate(s.base),
            estimator_terminate=est_term, estimator_merge=merge_mult,
            estimate=estimate, merge_is_additive=True, name="sum-multiple",
        )

    raise ValueError(f"unknown estimator model: {estimator!r}")


# ---------------------------------------------------------------------------
# Paper Alg. 3 — GLAGroupBy (composite GLA: a GLASum per group)
# ---------------------------------------------------------------------------

def make_groupby_gla(
    func: Callable[[Chunk], jnp.ndarray],
    cond: Callable[[Chunk], jnp.ndarray],
    group: Callable[[Chunk], jnp.ndarray],
    *,
    num_groups: int,
    d_total: float,
    estimator: str = "single",
    dtype=jnp.float32,
    num_aggs: int = 1,
    bucket_bits: Optional[int] = None,
) -> GLA:
    """GROUP BY gAtts SUM(func(d)) WHERE cond(d) — paper query (5).

    State is the dense composite of per-group GLASum states ("GLA
    composition", paper §4.4): sums/sumsqs/matched are [G, A]/[G]; ``scanned``
    is global (each group's predicate is cond ∧ group==g over the same scan).
    The per-item scatter is a segment_sum → one-hot MXU contraction on TPU.

    ``bucket_bits`` enables the large-domain hash-bucketed group table
    (paper's 1M-group Q1): raw ids from ``group`` are folded through
    :func:`hash_bucket` and the dense state covers the 2**bucket_bits
    buckets instead of the raw domain.  Recover per-raw-id rows with
    :func:`debucket` (exact for num_groups <= 2**bucket_bits).

    Under the single/synchronized/none estimation models, float32 states
    publish the group-by ``kernel_cols`` contract
    ``chunk -> (vals, weight, gids)`` plus ``kernel_num_groups``, so
    ``engine.run_query(emit="kernel")`` dispatches the Pallas one-hot MXU
    kernel (``repro/kernels/group_agg.py``) once per round-slice
    (DESIGN.md §3).  The "multiple" estimator keeps its MultState wrapper
    on the scan paths only.
    """
    A = num_aggs
    if bucket_bits is not None:
        raw_group = group

        def group(chunk):  # noqa: F811 — bucketed view of the raw ids
            return hash_bucket(raw_group(chunk), bucket_bits)

        G = 1 << bucket_bits
    else:
        G = num_groups

    def zero():
        return E.SumState(
            sum=jnp.zeros((G, A), dtype), sumsq=jnp.zeros((G, A), dtype),
            scanned=jnp.zeros((), dtype), matched=jnp.zeros((G,), dtype),
        )

    def acc(state: E.SumState, chunk: Chunk) -> E.SumState:
        vals = _as_2d(func(chunk)).astype(dtype)             # [n, A]
        w = (cond(chunk) * chunk["_mask"]).astype(dtype)     # [n]
        gids = group(chunk).astype(jnp.int32)                # [n]
        vw = vals * w[:, None]
        return E.SumState(
            sum=state.sum + jax.ops.segment_sum(vw, gids, num_segments=G),
            sumsq=state.sumsq + jax.ops.segment_sum(vals * vw, gids, num_segments=G),
            scanned=state.scanned + jnp.sum(chunk["_mask"].astype(dtype)),
            matched=state.matched + jax.ops.segment_sum(w, gids, num_segments=G),
        )

    def merge(a, b):
        return jax.tree.map(jnp.add, a, b)

    suffix = f"-b{bucket_bits}" if bucket_bits is not None else ""

    if estimator in ("single", "synchronized", "none"):

        def estimate(state: E.SumState, confidence, ctx=None) -> Estimate:
            est = E.horvitz_estimate(state.sum, state.scanned, d_total)   # [G, A]
            var = E.variance_estimate(state.sum, state.sumsq, state.scanned, d_total)
            lo, hi = E.normal_bounds(est, var, confidence)
            return Estimate(est, lo, hi, info={"var": var, "matched": state.matched})

        # Group-by fused-kernel dispatch (engine emit="kernel"): ops.group_agg
        # reproduces acc's state from the (func, cond, group) projections —
        # one one-hot MXU dispatch per round-slice (scan.kernel_rounds_states).
        if dtype == jnp.float32:
            def kernel_cols(chunk):
                return func(chunk), cond(chunk), group(chunk)
            kernel_G = G
        else:
            kernel_cols = None
            kernel_G = None

        return GLA(
            init=zero, accumulate=acc, merge=merge,
            terminate=lambda s: s.sum,
            estimate=None if estimator == "none" else estimate,
            merge_is_additive=True, kernel_cols=kernel_cols,
            kernel_num_groups=kernel_G, name=f"groupby-{estimator}{suffix}",
        )

    if estimator == "multiple":

        def zero_mult():
            z = jnp.zeros((G, A), dtype)
            return E.MultState(base=zero(), est=z, estvar=z)

        def acc_mult(state, chunk):
            return E.MultState(acc(state.base, chunk), state.est, state.estvar)

        def est_term(state: E.MultState, ctx) -> E.MultState:
            b = state.base
            d_local = ctx["d_local"]
            est = E.horvitz_estimate(b.sum, b.scanned, d_local)
            var = E.variance_estimate(b.sum, b.sumsq, b.scanned, d_local)
            return E.MultState(b, est, var)

        def estimate(state: E.MultState, confidence, ctx=None) -> Estimate:
            lo, hi = E.normal_bounds(state.est, state.estvar, confidence)
            return Estimate(state.est, lo, hi, info={"var": state.estvar})

        return GLA(
            init=zero_mult, accumulate=acc_mult, merge=merge,
            terminate=lambda s: s.base.sum,
            estimator_terminate=est_term, estimator_merge=merge,
            estimate=estimate, merge_is_additive=True,
            name=f"groupby-multiple{suffix}",
        )

    raise ValueError(f"unknown estimator model: {estimator!r}")


# ---------------------------------------------------------------------------
# Paper Alg. 4 — GLAJoin (replicated in-memory dimension table)
# ---------------------------------------------------------------------------

def make_join_groupby_gla(
    func: Callable[[Chunk], jnp.ndarray],
    cond: Callable[[Chunk], jnp.ndarray],
    join_key: Callable[[Chunk], jnp.ndarray],
    dim_group: jnp.ndarray,
    dim_valid: jnp.ndarray,
    *,
    num_groups: int,
    d_total: float,
    estimator: str = "single",
    dtype=jnp.float32,
    num_aggs: int = 1,
) -> GLA:
    """Join group-by — paper query (6), M replicated and hashed in memory.

    ``dim_group[k]`` is the group id the dimension row with key ``k`` maps to
    (e.g. supplier -> nation), ``dim_valid[k]`` its cond_M(M.sAtts) predicate.
    Per the paper, H is built by the user application during Init (query
    setup) and shipped with the query — here it is a replicated closure
    constant.  Accumulate = hash-probe (gather) + GLAGroupBy accumulate.
    """
    dim_group = jnp.asarray(dim_group, jnp.int32)
    dim_valid = jnp.asarray(dim_valid)

    def joined_group(chunk: Chunk) -> jnp.ndarray:
        keys = join_key(chunk).astype(jnp.int32)
        return dim_group[keys]

    def joined_cond(chunk: Chunk) -> jnp.ndarray:
        keys = join_key(chunk).astype(jnp.int32)
        return cond(chunk) * dim_valid[keys].astype(cond(chunk).dtype)

    inner = make_groupby_gla(
        func, joined_cond, joined_group,
        num_groups=num_groups, d_total=d_total, estimator=estimator,
        dtype=dtype, num_aggs=num_aggs,
    )
    return inner.with_(name=f"join-{estimator}")
