"""Concrete GLAs — paper Algorithms 1–4.

Constructors return :class:`repro.core.uda.GLA` bundles for the three
aggregation problems of paper §4, each in the three estimation models:

  * :func:`make_sum_gla`          — §4.3  single-table SUM/COUNT (Algs. 1, 2)
  * :func:`make_groupby_gla`      — §4.4  group-by aggregation (Alg. 3)
  * :func:`make_join_groupby_gla` — §4.5  join group-by with replicated
                                    dimension table (Alg. 4)

Queries are expressed as ``func(chunk) -> [n] or [n, A]`` (A simultaneous
aggregates, like TPC-H Q1's four SUMs) and ``cond(chunk) -> [n] in {0,1}``.
Group-by adds ``group(chunk) -> [n] int ids in [0, num_groups)``.

TPU adaptation (DESIGN.md §3): the per-group scatter is a
``jax.ops.segment_sum`` here (lowers to one-hot matmul / sorted segment ops on
TPU); the Pallas hot-path kernel in ``repro/kernels`` implements the identical
contraction with explicit VMEM tiling and is allclose-checked against these
reference semantics.  Group-by GLAs publish the ``(vals, weight, gids)``
kernel projection so ``engine.run_query(emit="kernel")`` reaches that kernel
directly (one dispatch per round-slice); large raw-id domains fold through
:func:`hash_bucket` into a 2**bucket_bits dense bucket table.
"""
from __future__ import annotations

from functools import lru_cache
from typing import Callable, Mapping, NamedTuple, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import estimators as E
from repro.core.uda import GLA, Chunk, Estimate, FusedSpec, ProbeTable


def _as_2d(vals: jnp.ndarray) -> jnp.ndarray:
    """[n] -> [n, 1]; [n, A] stays."""
    return vals[:, None] if vals.ndim == 1 else vals


# ---------------------------------------------------------------------------
# Multi-query bundles (paper §3: "any number of concurrent estimation
# models" driven alongside one execution).  A bundle is itself a GLA whose
# state is the tuple of member states, so every engine scan path runs N
# queries over a single pass of the chunk stream.  Each member sees the
# exact same chunks in the exact same order as it would alone, so finals
# and snapshot states are bitwise-identical to solo runs
# (tests/test_multiquery.py).
# ---------------------------------------------------------------------------


def GLABundle(glas: Sequence[GLA], *, name: Optional[str] = None) -> GLA:
    """Stack heterogeneous GLAs into one fused GLA over a shared scan.

    The fused state is ``tuple(member states)``; accumulate/merge/terminate
    and the estimator extensions apply member-wise over the same chunk.
    ``estimate`` returns a tuple with one :class:`Estimate` per member
    (``None`` for members without an estimation model), preserving
    per-query round-emission views.  ``merge_is_additive`` holds iff it
    holds for every member — the engines' psum/tensordot merges then apply
    leaf-wise across the whole tuple.

    The bundle publishes no ``kernel_cols`` of its own; the engines'
    ``emit="kernel"`` path instead batches every member's kernel projection
    into one ``ops.group_agg`` dispatch per round-slice
    (``repro.core.scan.bundle_kernel_rounds_states``) when all members
    publish one.  Use :func:`repro.core.engine.run_queries` to execute a
    bundle and get per-query results back.

    Bundling the same member GLAs again returns the *same* bundle object
    (memoized): the engines' jit caches key on the GLA statically, so a
    repeated interactive workload must not pay an XLA recompile per
    ``run_queries`` call just because the combinator rebuilt its closures.
    """
    members = tuple(glas)
    if not members:
        raise ValueError("GLABundle needs at least one member GLA")
    if any(m.members for m in members):
        raise ValueError("GLABundle members must not themselves be bundles")
    return _bundle_cached(members, name)


@lru_cache(maxsize=256)
def _bundle_cached(members: tuple, name: Optional[str]) -> GLA:
    return _combine_members(members, name)


def _combine_members(members: tuple, name: Optional[str]) -> GLA:
    """The tuple-of-states combinator behind :func:`GLABundle`.

    Exposed separately (uncached) for the serving slot families, whose
    members close over *traced* per-slot parameters: those closures are
    rebuilt on every trace by design and must never enter the bundle
    memo — the jit cache of the serving step keys on the family object
    instead (repro/serving/service.py).
    """
    def init():
        return tuple(m.init() for m in members)

    def accumulate(state, chunk):
        return tuple(
            m.accumulate(s, chunk) for m, s in zip(members, state))

    def merge(a, b):
        return tuple(m.merge(x, y) for m, x, y in zip(members, a, b))

    def terminate(state):
        return tuple(m.terminate(s) for m, s in zip(members, state))

    def estimator_terminate(state, ctx=None):
        return tuple(
            m.estimator_terminate(s, ctx) for m, s in zip(members, state))

    def estimator_merge(a, b):
        return tuple(
            m.estimator_merge(x, y) for m, x, y in zip(members, a, b))

    def estimate(state, confidence, ctx=None):
        return tuple(
            m.estimate(s, confidence, ctx) if m.estimate is not None else None
            for m, s in zip(members, state))

    any_estimate = any(m.estimate is not None for m in members)
    return GLA(
        init=init, accumulate=accumulate, merge=merge, terminate=terminate,
        estimator_terminate=estimator_terminate,
        estimator_merge=estimator_merge,
        estimate=estimate if any_estimate else None,
        merge_is_additive=all(m.merge_is_additive for m in members),
        members=members,
        name=name or "bundle[" + "+".join(m.name for m in members) + "]",
    )


# ---------------------------------------------------------------------------
# Hash-bucketed group tables (paper §4.4 large-domain group-by, e.g. the
# 1M-group Q1).  The dense [G, A] composite state cannot scale with the raw
# id domain, so raw ids are folded into 2**bucket_bits buckets by a
# multiplicative hash.  The multiplier is odd, hence invertible mod 2**b:
# g -> (g * MULT) mod 2**b is a *bijection* on [0, 2**b), so any raw domain
# with num_groups <= 2**bucket_bits maps injectively and de-bucketing is
# exact (tests/test_groupby_kernel.py::
# test_kernel_final_matches_exact_debucketed).
# Larger domains fold ~num_groups / 2**b raw ids per bucket — the bucket
# then estimates the folded groups' combined aggregate.
# ---------------------------------------------------------------------------

_BUCKET_MULT = 2654435761  # 2**32 / golden ratio (Knuth), odd


def hash_bucket(gids: jnp.ndarray, bucket_bits: int) -> jnp.ndarray:
    """Raw group ids -> int32 bucket ids in [0, 2**bucket_bits)."""
    h = jnp.asarray(gids).astype(jnp.uint32) * jnp.uint32(_BUCKET_MULT)
    return (h & jnp.uint32((1 << bucket_bits) - 1)).astype(jnp.int32)


def debucket(bucket_vals: jnp.ndarray, raw_ids, bucket_bits: int):
    """Gather per-raw-id rows from a bucketed group table [2**b, ...].

    Exact whenever the active raw-id set maps injectively into buckets —
    guaranteed for num_groups <= 2**bucket_bits by the hash bijectivity.
    """
    idx = hash_bucket(jnp.asarray(raw_ids), bucket_bits)
    return jnp.take(bucket_vals, idx, axis=0)


# ---------------------------------------------------------------------------
# Paper Alg. 1 / Alg. 2 — GLASum, single / multiple / synchronized
# ---------------------------------------------------------------------------

def make_sum_gla(
    func: Callable[[Chunk], jnp.ndarray],
    cond: Callable[[Chunk], jnp.ndarray],
    *,
    d_total: float,
    estimator: str = "single",
    dtype=jnp.float32,
    num_aggs: int = 1,
) -> GLA:
    """SUM(func(d)) WHERE cond(d) — paper query (1).

    ``estimator``: "single" (Alg. 1), "multiple" (Alg. 2), "synchronized"
    (Wu et al.; same state as single — the barrier lives in the engine), or
    "none" (plain aggregate, the no-estimation overhead baseline).
    """
    A = num_aggs

    def zero_sum():
        z = jnp.zeros((A,), dtype)
        s = jnp.zeros((), dtype)
        return E.SumState(sum=z, sumsq=z, scanned=s, matched=s)

    def acc_sum(state: E.SumState, chunk: Chunk) -> E.SumState:
        vals = _as_2d(func(chunk)).astype(dtype)              # [n, A]
        w = (cond(chunk) * chunk["_mask"]).astype(dtype)      # [n]
        m = chunk["_mask"].astype(dtype)
        # multiply-then-reduce, NOT vals.T @ w: XLA:CPU fuses a matvec into
        # the surrounding scan carry (GEMM accumulator), changing the
        # reduction order between contexts.  The elementwise product + axis
        # reduction is context-stable, so the fused Pallas kernel
        # (kernels/fused_agg.py) reproduces these states bitwise —
        # the scalar-kernel path is exact, not just statistically
        # interchangeable (DESIGN.md §12, docs/KERNELS.md).
        return E.SumState(
            sum=state.sum + (vals * w[:, None]).sum(axis=0),
            sumsq=state.sumsq + ((vals * vals) * w[:, None]).sum(axis=0),
            scanned=state.scanned + jnp.sum(m),
            matched=state.matched + jnp.sum(w),
        )

    def merge(a, b):
        return jax.tree.map(jnp.add, a, b)

    def terminate(state):
        s = state.sum if A > 1 else state.sum[0]
        return s

    if estimator in ("single", "synchronized", "none"):

        def estimate(state: E.SumState, confidence, ctx=None) -> Estimate:
            est = E.horvitz_estimate(state.sum, state.scanned, d_total)
            var = E.variance_estimate(state.sum, state.sumsq, state.scanned, d_total)
            lo, hi = E.normal_bounds(est, var, confidence)
            sq = (lambda x: x) if A > 1 else (lambda x: x[0])
            return Estimate(sq(est), sq(lo), sq(hi),
                            info={"var": sq(var), "frac": state.scanned / d_total})

        # Per-shard fused-kernel dispatch (engine emit="kernel"): the Pallas
        # kernel reproduces acc_sum's state from (func, cond) projections —
        # only for the plain f32 single-aggregate SumState layout.
        if A == 1 and dtype == jnp.float32:
            def kernel_cols(chunk):
                return func(chunk), cond(chunk)
        else:
            kernel_cols = None

        # Fused in-kernel contract: any f32 SumState qualifies (A > 1 too —
        # the fused kernel pads A to a multiple of 8 itself).
        fused = (FusedSpec(func=func, cond=cond, group=None, num_aggs=A)
                 if dtype == jnp.float32 else None)

        return GLA(
            init=zero_sum, accumulate=acc_sum, merge=merge, terminate=terminate,
            estimate=None if estimator == "none" else estimate,
            merge_is_additive=True, kernel_cols=kernel_cols, fused=fused,
            name=f"sum-{estimator}",
        )

    if estimator == "multiple":

        def zero_mult():
            z = jnp.zeros((A,), dtype)
            return E.MultState(base=zero_sum(), est=z, estvar=z)

        def acc_mult(state: E.MultState, chunk: Chunk) -> E.MultState:
            return E.MultState(acc_sum(state.base, chunk), state.est, state.estvar)

        def merge_mult(a: E.MultState, b: E.MultState) -> E.MultState:
            # Merging *local* (pre-EstimatorTerminate) states: base adds,
            # est/estvar are not yet meaningful — keep additive for engine
            # uniformity (they are zero until estimator_terminate).
            return jax.tree.map(jnp.add, a, b)

        def est_term(state: E.MultState, ctx) -> E.MultState:
            """Alg. 2 EstimatorTerminate — needs |D_i| from the engine ctx."""
            b = state.base
            d_local = ctx["d_local"]
            est = E.horvitz_estimate(b.sum, b.scanned, d_local)
            var = E.variance_estimate(b.sum, b.sumsq, b.scanned, d_local)
            return E.MultState(b, est, var)

        def estimate(state: E.MultState, confidence, ctx=None) -> Estimate:
            lo, hi = E.normal_bounds(state.est, state.estvar, confidence)
            sq = (lambda x: x) if A > 1 else (lambda x: x[0])
            return Estimate(sq(state.est), sq(lo), sq(hi),
                            info={"var": sq(state.estvar)})

        return GLA(
            init=zero_mult, accumulate=acc_mult, merge=merge_mult,
            terminate=lambda s: terminate(s.base),
            estimator_terminate=est_term, estimator_merge=merge_mult,
            estimate=estimate, merge_is_additive=True, name="sum-multiple",
        )

    raise ValueError(f"unknown estimator model: {estimator!r}")


# ---------------------------------------------------------------------------
# Paper Alg. 3 — GLAGroupBy (composite GLA: a GLASum per group)
# ---------------------------------------------------------------------------

def make_groupby_gla(
    func: Callable[[Chunk], jnp.ndarray],
    cond: Callable[[Chunk], jnp.ndarray],
    group: Callable[[Chunk], jnp.ndarray],
    *,
    num_groups: int,
    d_total: float,
    estimator: str = "single",
    dtype=jnp.float32,
    num_aggs: int = 1,
    bucket_bits: Optional[int] = None,
) -> GLA:
    """GROUP BY gAtts SUM(func(d)) WHERE cond(d) — paper query (5).

    State is the dense composite of per-group GLASum states ("GLA
    composition", paper §4.4): sums/sumsqs/matched are [G, A]/[G]; ``scanned``
    is global (each group's predicate is cond ∧ group==g over the same scan).
    The per-item scatter is a segment_sum → one-hot MXU contraction on TPU.

    ``bucket_bits`` enables the large-domain hash-bucketed group table
    (paper's 1M-group Q1): raw ids from ``group`` are folded through
    :func:`hash_bucket` and the dense state covers the 2**bucket_bits
    buckets instead of the raw domain.  Recover per-raw-id rows with
    :func:`debucket` (exact for num_groups <= 2**bucket_bits).

    Under the single/synchronized/none estimation models, float32 states
    publish the group-by ``kernel_cols`` contract
    ``chunk -> (vals, weight, gids)`` plus ``kernel_num_groups``, so
    ``engine.run_query(emit="kernel")`` dispatches the Pallas one-hot MXU
    kernel (``repro/kernels/group_agg.py``) once per round-slice
    (DESIGN.md §3).  The "multiple" estimator keeps its MultState wrapper
    on the scan paths only.
    """
    A = num_aggs
    if bucket_bits is not None:
        raw_group = group

        def group(chunk):  # noqa: F811 — bucketed view of the raw ids
            return hash_bucket(raw_group(chunk), bucket_bits)

        G = 1 << bucket_bits
    else:
        G = num_groups

    def zero():
        return E.SumState(
            sum=jnp.zeros((G, A), dtype), sumsq=jnp.zeros((G, A), dtype),
            scanned=jnp.zeros((), dtype), matched=jnp.zeros((G,), dtype),
        )

    def acc(state: E.SumState, chunk: Chunk) -> E.SumState:
        vals = _as_2d(func(chunk)).astype(dtype)             # [n, A]
        w = (cond(chunk) * chunk["_mask"]).astype(dtype)     # [n]
        gids = group(chunk).astype(jnp.int32)                # [n]
        vw = vals * w[:, None]
        return E.SumState(
            sum=state.sum + jax.ops.segment_sum(vw, gids, num_segments=G),
            sumsq=state.sumsq + jax.ops.segment_sum(vals * vw, gids, num_segments=G),
            scanned=state.scanned + jnp.sum(chunk["_mask"].astype(dtype)),
            matched=state.matched + jax.ops.segment_sum(w, gids, num_segments=G),
        )

    def merge(a, b):
        return jax.tree.map(jnp.add, a, b)

    suffix = f"-b{bucket_bits}" if bucket_bits is not None else ""

    if estimator in ("single", "synchronized", "none"):

        def estimate(state: E.SumState, confidence, ctx=None) -> Estimate:
            est = E.horvitz_estimate(state.sum, state.scanned, d_total)   # [G, A]
            var = E.variance_estimate(state.sum, state.sumsq, state.scanned, d_total)
            lo, hi = E.normal_bounds(est, var, confidence)
            return Estimate(est, lo, hi, info={"var": var, "matched": state.matched})

        # Group-by fused-kernel dispatch (engine emit="kernel"): ops.group_agg
        # reproduces acc's state from the (func, cond, group) projections —
        # one one-hot MXU dispatch per round-slice (scan.kernel_rounds_states).
        if dtype == jnp.float32:
            def kernel_cols(chunk):
                return func(chunk), cond(chunk), group(chunk)
            kernel_G = G
            # ``group`` here is already the bucketed view when bucket_bits
            # is set, so the kernel hash-buckets in-register too.
            fused = FusedSpec(func=func, cond=cond, group=group, num_aggs=A,
                              num_groups=G)
        else:
            kernel_cols = None
            kernel_G = None
            fused = None

        return GLA(
            init=zero, accumulate=acc, merge=merge,
            terminate=lambda s: s.sum,
            estimate=None if estimator == "none" else estimate,
            merge_is_additive=True, kernel_cols=kernel_cols,
            kernel_num_groups=kernel_G, fused=fused,
            name=f"groupby-{estimator}{suffix}",
        )

    if estimator == "multiple":

        def zero_mult():
            z = jnp.zeros((G, A), dtype)
            return E.MultState(base=zero(), est=z, estvar=z)

        def acc_mult(state, chunk):
            return E.MultState(acc(state.base, chunk), state.est, state.estvar)

        def est_term(state: E.MultState, ctx) -> E.MultState:
            b = state.base
            d_local = ctx["d_local"]
            est = E.horvitz_estimate(b.sum, b.scanned, d_local)
            var = E.variance_estimate(b.sum, b.sumsq, b.scanned, d_local)
            return E.MultState(b, est, var)

        def estimate(state: E.MultState, confidence, ctx=None) -> Estimate:
            lo, hi = E.normal_bounds(state.est, state.estvar, confidence)
            return Estimate(state.est, lo, hi, info={"var": state.estvar})

        return GLA(
            init=zero_mult, accumulate=acc_mult, merge=merge,
            terminate=lambda s: s.base.sum,
            estimator_terminate=est_term, estimator_merge=merge,
            estimate=estimate, merge_is_additive=True,
            name=f"groupby-multiple{suffix}",
        )

    raise ValueError(f"unknown estimator model: {estimator!r}")


# ---------------------------------------------------------------------------
# Paper Alg. 4 — GLAJoin (replicated in-memory dimension table)
# ---------------------------------------------------------------------------

def make_join_groupby_gla(
    func: Callable[[Chunk], jnp.ndarray],
    cond: Callable[[Chunk], jnp.ndarray],
    join_key: Callable[[Chunk], jnp.ndarray],
    dim_group: jnp.ndarray,
    dim_valid: jnp.ndarray,
    *,
    num_groups: int,
    d_total: float,
    estimator: str = "single",
    dtype=jnp.float32,
    num_aggs: int = 1,
    bucket_bits: Optional[int] = None,
    d_dim: Optional[float] = None,
    s_dim: Optional[float] = None,
) -> GLA:
    """Join group-by — paper query (6), M replicated and hashed in memory.

    ``dim_group[k]`` is the group id the dimension row with key ``k`` maps to
    (e.g. supplier -> nation), ``dim_valid[k]`` its cond_M(M.sAtts) predicate.
    Per the paper, H is built by the user application during Init (query
    setup) and shipped with the query — here it is a replicated closure
    constant.  Accumulate = hash-probe (gather) + GLAGroupBy accumulate.

    Fused path: the probe arrays additionally ride as
    ``FusedSpec.probe_tables`` (:class:`repro.core.uda.ProbeTable`) — extra
    ``pallas_call`` operands the kernel injects into the in-kernel chunk
    dict — so Q3/Q10-class two-table queries run the one-dispatch fused
    kernel with the gather *inside* the VMEM residency, bitwise-identical
    to this scan path (the kernel closures repeat the gather expression
    trees below verbatim against the same arrays).  Oversized dimension
    tables fail the kernel's VMEM probe budget and fall back to the legacy
    ``kernel_cols`` path automatically (``fused_agg.fused_available``).

    §3.3 multiplicative join estimator: pass ``d_dim`` (dimension-table
    cardinality) and ``s_dim`` (rows of it sampled so far) to scale the
    estimate by the dimension-side inverse sampling fraction
    (``estimators.join_scale``).  With the replicated table fully resident
    — the default, ``d_dim=None`` — the factor is exactly 1 and the
    estimate is the unchanged single-table Horvitz–Thompson formula.
    """
    dim_group = jnp.asarray(dim_group, jnp.int32)
    dim_valid = jnp.asarray(dim_valid)

    def joined_group(chunk: Chunk) -> jnp.ndarray:
        keys = join_key(chunk).astype(jnp.int32)
        return dim_group[keys]

    def joined_cond(chunk: Chunk) -> jnp.ndarray:
        keys = join_key(chunk).astype(jnp.int32)
        return cond(chunk) * dim_valid[keys].astype(cond(chunk).dtype)

    inner = make_groupby_gla(
        func, joined_cond, joined_group,
        num_groups=num_groups, d_total=d_total, estimator=estimator,
        dtype=dtype, num_aggs=num_aggs, bucket_bits=bucket_bits,
    )

    fused = None
    if inner.fused is not None:
        pt_group = ProbeTable("dim_group", dim_group)
        pt_valid = ProbeTable("dim_valid", dim_valid)

        def fused_group(chunk: Chunk) -> jnp.ndarray:
            keys = join_key(chunk).astype(jnp.int32)
            gids = chunk[pt_group.key][keys]
            if bucket_bits is not None:
                gids = hash_bucket(gids, bucket_bits)
            return gids

        def fused_cond(chunk: Chunk) -> jnp.ndarray:
            keys = join_key(chunk).astype(jnp.int32)
            return cond(chunk) * chunk[pt_valid.key][keys].astype(
                cond(chunk).dtype)

        fused = inner.fused._replace(
            cond=fused_cond, group=fused_group,
            probe_tables=(pt_group, pt_valid))

    est_fn = inner.estimate
    if est_fn is not None and d_dim is not None:
        sd = float(d_dim if s_dim is None else s_dim)
        scale = jnp.asarray(
            float(d_dim), dtype) / jnp.maximum(jnp.asarray(sd, dtype), 1.0)
        inner_estimate = est_fn

        def est_fn(state, confidence, ctx=None):  # noqa: F811
            e = inner_estimate(state, confidence, ctx)
            var = e.info["var"] * (scale * scale)
            est = e.estimate * scale
            lo, hi = E.normal_bounds(est, var, confidence)
            return Estimate(est, lo, hi,
                            info={**e.info, "var": var, "dim_scale": scale})

    return inner.with_(name=f"join-{estimator}", fused=fused,
                       estimate=est_fn)


# ---------------------------------------------------------------------------
# Deep OLA composition — an outer estimator consuming inner OLA estimates
# (PAPERS.md 2303.04103; DESIGN.md §13)
# ---------------------------------------------------------------------------

def compose(inner: GLA, outer_estimate: Callable[[Estimate, float], Estimate],
            *, name: Optional[str] = None) -> GLA:
    """Nest an outer estimator over the inner GLA's *estimate*.

    Execution scaffolding — init/accumulate/merge/terminate, the estimator
    extensions, kernel contracts, additivity — is the inner GLA's
    **verbatim**: a composed plan rides every engine path, fused kernel,
    session, checkpoint envelope, and fault policy exactly as the inner
    plan does, with bitwise-identical states.  Only ``estimate`` differs:
    the inner estimate is computed first, then
    ``outer_estimate(inner_est, confidence)`` maps it to the outer
    :class:`Estimate` — the Deep OLA pattern where each refinement round
    re-derives the whole nested answer from the current inner bounds,
    variance propagated through the nesting
    (``estimators.nested_group_estimate``).
    """
    if inner.estimate is None:
        raise ValueError(
            f"compose() needs an inner GLA with an estimation model, "
            f"got {inner.name!r}")
    if inner.members:
        raise ValueError("compose() nests a single GLA, not a bundle — "
                         "bundle the composed GLAs instead")
    inner_estimate = inner.estimate

    def estimate(state, confidence, ctx=None) -> Estimate:
        return outer_estimate(inner_estimate(state, confidence, ctx),
                              confidence)

    return inner.with_(estimate=estimate,
                       name=name or f"compose[{inner.name}]")


def make_having_gla(inner: GLA, threshold, *, mode: str = ">=",
                    agg: int = 0, name: Optional[str] = None) -> GLA:
    """GROUP BY + HAVING over *estimated* aggregates (Deep OLA query shape).

    Sums the inner group-by's per-group estimates over the groups whose
    inner point estimate (aggregate column ``agg``) passes
    ``estimate <mode> threshold``, with the outer variance propagated as
    the sum of passing groups' inner variances — a group at |S| <= 1
    (+inf inner variance) that passes HAVING poisons the outer bound to
    ±inf, never NaN (estimators.nested_group_estimate).  ``threshold``
    may be a traced value (the serving layer passes per-slot thresholds
    as dynamic jit inputs).  Per-round bounds can widen transiently when
    the predicate flips a group; apply ``estimators.monotone_envelope``
    post-hoc for a monotone UI envelope.
    """
    cmps = {">=": lambda v, t: v >= t, ">": lambda v, t: v > t,
            "<=": lambda v, t: v <= t, "<": lambda v, t: v < t}
    if mode not in cmps:
        raise ValueError(f"unknown HAVING mode {mode!r}")
    cmp = cmps[mode]

    def having(est_g):
        v = est_g[:, agg] if est_g.ndim == 2 else est_g
        return cmp(v, threshold)

    def outer(inner_est: Estimate, confidence) -> Estimate:
        return E.nested_group_estimate(inner_est, having, confidence)

    return compose(inner, outer,
                   name=name or f"having[{inner.name}{mode}{threshold!r}]")


# ---------------------------------------------------------------------------
# Padded-slot query families — the serving layer's dynamic bundle
# (repro/serving/service.py, DESIGN.md §11).
#
# A GLABundle fixes its membership at trace time: every attach/detach of a
# query would build a new bundle object, and the engines' jit caches key on
# the GLA statically — a recompile per arrival.  A SlotFamily instead fixes
# the *query family* statically (a basis of value expressions, a set of
# range-predicate columns, optional group keys) and makes the per-slot
# query parameters DYNAMIC jit inputs (:class:`SlotParams`): which basis
# expression a slot aggregates, its half-open predicate ranges, and whether
# the slot was freshly (re)claimed this round.  The serving step then
# compiles once per (family, bank, slot capacity) and serves any
# arrival/departure pattern from the same executable; capacity grows in
# powers of two, so compile count under churn is bounded by capacity
# doublings, never per-arrival (audit: ``bounded_compiles_under_churn``).
#
# Bitwise discipline: each slot's program is built from the SAME
# constructors as a solo query (``make_sum_gla`` / ``make_groupby_gla``)
# with value selection by row-gather from the stacked basis and predicate
# weights from identical half-open comparisons, combined by the SAME
# tuple combinator as :func:`GLABundle` — so a slot's states, estimates
# and bounds are bitwise-identical to a fresh solo Session over the rounds
# the slot witnessed (tests/test_service.py).  Slot reclaim resets state
# via ``jnp.where(fresh, zeros, state)`` — never by multiplying with a
# 0/1 mask, which would turn negative carries into -0.0 and break bitwise
# identity with a fresh +0.0 init.
# ---------------------------------------------------------------------------

_INACTIVE_LO = np.float32(np.inf)    # empty half-open range: weight exactly 0
_INACTIVE_HI = np.float32(-np.inf)


class SlotQuery(NamedTuple):
    """One query expressible in a :class:`SlotFamily`.

    ``SUM(exprs[expr](d)) WHERE AND_j lo_j <= pred_col_j(d) < hi_j
    [GROUP BY group [HAVING est >= having]]`` — ``ranges`` maps predicate
    column -> (lo, hi) half-open; columns not named are unconstrained.
    ``group`` names one of the family's group keys (None = scalar
    aggregate).  ``having`` (requires ``group``) nests the Deep OLA
    HAVING estimator over the group estimates: the slot reports the SUM
    over groups whose estimated aggregate is >= the threshold
    (``gla.make_having_gla``); the threshold is a *dynamic* slot
    parameter, so arrivals with different thresholds share one compiled
    step.
    """

    expr: str
    ranges: Mapping[str, Tuple[float, float]] = {}
    group: Optional[str] = None
    having: Optional[float] = None


class SlotParams(NamedTuple):
    """Dynamic per-slot parameters of one bank — jit INPUTS, never
    statics.  Leaves are [K] / [K, n_pred] with K the bank's power-of-two
    slot capacity; inactive slots carry the empty range (lo=+inf,
    hi=-inf), so their predicate weight is exactly 0 on every tuple.
    ``hv`` is the per-slot HAVING threshold (having banks only; +inf on
    inactive slots, so no group passes and the nested estimate is an
    exact 0 ± 0)."""

    expr: jnp.ndarray   # int32 [K] — row into the family's expression basis
    lo: jnp.ndarray     # float32 [K, n_pred]
    hi: jnp.ndarray     # float32 [K, n_pred]
    fresh: jnp.ndarray  # bool [K] — reclaim: reset the slot's carry first
    hv: Optional[jnp.ndarray] = None  # float32 [K] — HAVING thresholds


def _range_cond(pred_cols: Tuple[str, ...], lo, hi):
    """Predicate closure over (possibly traced) per-column bounds.

    Shared verbatim between a slot's in-bundle program (traced bounds)
    and its solo comparison GLA (host float32 bounds), so the 0/1 weights
    are bitwise-identical.  Unconstrained columns carry (-inf, +inf) and
    compare all-True for finite data either way.
    """
    def cond(chunk):
        w = None
        for j, col in enumerate(pred_cols):
            c = (chunk[col] >= lo[j]) & (chunk[col] < hi[j])
            w = c if w is None else w & c
        return w.astype(jnp.float32)

    return cond


class SlotFamily:
    """A parametric family of slot queries over a fixed expression basis.

    Args:
      exprs: ordered mapping name -> (chunk -> [n] float32) value
        expressions — the basis a slot selects from by index.
      pred_cols: the columns range predicates may constrain.
      groups: optional mapping name -> (group_fn, num_groups) for group-by
        slots; each group key gets its own bank (its own dense [G, A]
        states and its own jitted step).

    Instances hash by identity — the serving layer builds ONE family per
    service and uses it as the static jit key of its per-round step; two
    equal-looking families are different compile keys on purpose.
    """

    def __init__(self, exprs: Mapping[str, Callable[[Chunk], jnp.ndarray]],
                 pred_cols: Sequence[str],
                 groups: Optional[Mapping[str, Tuple[Callable, int]]] = None):
        self.expr_names: Tuple[str, ...] = tuple(exprs)
        self._expr_fns = tuple(exprs[n] for n in self.expr_names)
        if not self._expr_fns:
            raise ValueError("SlotFamily needs at least one basis expression")
        self.pred_cols: Tuple[str, ...] = tuple(pred_cols)
        self.groups = dict(groups or {})

    # -- host-side parameter rows -------------------------------------------

    def bank_of(self, q: SlotQuery) -> str:
        """The bank a query lands in: its group key, "scalar", or — for
        nested HAVING queries — ``"<group>:having"`` (tree-shaped members
        need their own compiled step: same states, different estimate)."""
        if q.group is not None and q.group not in self.groups:
            raise KeyError(f"unknown group key {q.group!r}; family has "
                           f"{sorted(self.groups)}")
        if q.having is not None:
            if q.group is None:
                raise ValueError(
                    "SlotQuery.having needs a group key — HAVING nests "
                    "over per-group estimates")
            return f"{q.group}:having"
        return q.group if q.group is not None else "scalar"

    def slot_row(self, q: SlotQuery):
        """Host (expr_idx, lo[n_pred], hi[n_pred]) float32 row for ``q``."""
        if q.expr not in self.expr_names:
            raise KeyError(f"unknown expression {q.expr!r}; family basis is "
                           f"{list(self.expr_names)}")
        unknown = sorted(set(q.ranges) - set(self.pred_cols))
        if unknown:
            raise KeyError(f"query constrains {unknown}, not in the "
                           f"family's pred_cols {list(self.pred_cols)}")
        lo = np.full(len(self.pred_cols), -np.inf, np.float32)
        hi = np.full(len(self.pred_cols), np.inf, np.float32)
        for j, col in enumerate(self.pred_cols):
            if col in q.ranges:
                lo[j], hi[j] = (np.float32(q.ranges[col][0]),
                                np.float32(q.ranges[col][1]))
        return self.expr_names.index(q.expr), lo, hi

    def inactive_row(self):
        """(expr_idx, lo, hi) of a parked slot: the empty range."""
        n = len(self.pred_cols)
        return (0, np.full(n, _INACTIVE_LO, np.float32),
                np.full(n, _INACTIVE_HI, np.float32))

    # -- per-slot GLA programs ----------------------------------------------

    def _select_func(self, expr_idx):
        """Value expression by (possibly traced) basis index: the stacked
        basis is computed once per chunk (CSE'd across slots) and the
        slot's row gathered — the gathered row is bitwise the expression's
        own output, so it matches the solo GLA's direct call."""
        fns = self._expr_fns
        if len(fns) == 1:
            return fns[0]

        def func(chunk):
            return jnp.stack([f(chunk) for f in fns])[expr_idx]

        return func

    def _member_gla(self, bank: str, func, cond, d_total, hv=None) -> GLA:
        if bank == "scalar":
            return make_sum_gla(func, cond, d_total=d_total)
        base, _, nested = bank.partition(":")
        gfn, G = self.groups[base]
        inner = make_groupby_gla(func, cond, gfn, num_groups=G,
                                 d_total=d_total)
        if nested != "having":
            return inner
        # tree-shaped member: the slot's state IS the group-by state; only
        # the estimate nests (gla.compose), so carries, reclaim, and the
        # psum merge are the group bank's unchanged.  The (possibly
        # traced) threshold stays out of the static name.
        return make_having_gla(inner, hv, name=f"having[{base}]")

    def solo_gla(self, q: SlotQuery, *, d_total: float) -> GLA:
        """The stand-alone GLA of one slot query — what a fresh Session
        would run.  Built from the same constructors, the same predicate
        closure and the same d_total as the in-bundle slot program, so it
        is the bitwise reference for late-join tests."""
        expr_idx, lo, hi = self.slot_row(q)
        cond = _range_cond(self.pred_cols, lo, hi)
        hv = None if q.having is None else jnp.float32(q.having)
        return self._member_gla(self.bank_of(q), self._expr_fns[expr_idx],
                                cond, d_total, hv)

    def bind(self, bank: str, params: SlotParams, d_total) -> GLA:
        """The K-slot bundle GLA of one bank, closed over (traced) params.

        Called INSIDE the serving step's jit region: the returned GLA's
        member closures capture the traced per-slot parameters, so the
        step function — whose statics are only (family, bank, K) — serves
        every arrival/departure pattern from one executable.  Never
        memoized (see :func:`_combine_members`).
        """
        K = int(params.expr.shape[0])
        members = []
        for k in range(K):
            func = self._select_func(params.expr[k])
            cond = _range_cond(self.pred_cols, params.lo[k], params.hi[k])
            hv = None if params.hv is None else params.hv[k]
            members.append(self._member_gla(bank, func, cond, d_total, hv))
        return _combine_members(tuple(members), f"slots-{bank}x{K}")

    def zero_slot_state(self, bank: str):
        """One slot's init state (the reclaim target of a fresh slot)."""
        if bank == "scalar":
            z = jnp.zeros((1,), jnp.float32)
            s = jnp.zeros((), jnp.float32)
            return E.SumState(sum=z, sumsq=z, scanned=s, matched=s)
        _, G = self.groups[bank.partition(":")[0]]
        return E.SumState(
            sum=jnp.zeros((G, 1), jnp.float32),
            sumsq=jnp.zeros((G, 1), jnp.float32),
            scanned=jnp.zeros((), jnp.float32),
            matched=jnp.zeros((G,), jnp.float32))


def next_pow2(n: int) -> int:
    """Smallest power of two >= n (and >= 1) — slot-capacity discipline."""
    return 1 << max(0, int(n - 1).bit_length())
