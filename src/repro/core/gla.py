"""Concrete GLAs — paper Algorithms 1–4.

Constructors return :class:`repro.core.uda.GLA` bundles for the three
aggregation problems of paper §4, each in the three estimation models:

  * :func:`make_sum_gla`          — §4.3  single-table SUM/COUNT (Algs. 1, 2)
  * :func:`make_groupby_gla`      — §4.4  group-by aggregation (Alg. 3)
  * :func:`make_join_groupby_gla` — §4.5  join group-by with replicated
                                    dimension table (Alg. 4)

Queries are expressed as ``func(chunk) -> [n] or [n, A]`` (A simultaneous
aggregates, like TPC-H Q1's four SUMs) and ``cond(chunk) -> [n] in {0,1}``.
Group-by adds ``group(chunk) -> [n] int ids in [0, num_groups)``.

TPU adaptation (DESIGN.md §3): the per-group scatter is a
``jax.ops.segment_sum`` here (lowers to one-hot matmul / sorted segment ops on
TPU); the Pallas hot-path kernel in ``repro/kernels`` implements the identical
contraction with explicit VMEM tiling and is allclose-checked against these
reference semantics.
"""
from __future__ import annotations

from functools import partial
from typing import Callable, Optional

import jax
import jax.numpy as jnp

from repro.core import estimators as E
from repro.core.uda import GLA, Chunk, Estimate


def _as_2d(vals: jnp.ndarray) -> jnp.ndarray:
    """[n] -> [n, 1]; [n, A] stays."""
    return vals[:, None] if vals.ndim == 1 else vals


# ---------------------------------------------------------------------------
# Paper Alg. 1 / Alg. 2 — GLASum, single / multiple / synchronized
# ---------------------------------------------------------------------------

def make_sum_gla(
    func: Callable[[Chunk], jnp.ndarray],
    cond: Callable[[Chunk], jnp.ndarray],
    *,
    d_total: float,
    estimator: str = "single",
    dtype=jnp.float32,
    num_aggs: int = 1,
) -> GLA:
    """SUM(func(d)) WHERE cond(d) — paper query (1).

    ``estimator``: "single" (Alg. 1), "multiple" (Alg. 2), "synchronized"
    (Wu et al.; same state as single — the barrier lives in the engine), or
    "none" (plain aggregate, the no-estimation overhead baseline).
    """
    A = num_aggs

    def zero_sum():
        z = jnp.zeros((A,), dtype)
        s = jnp.zeros((), dtype)
        return E.SumState(sum=z, sumsq=z, scanned=s, matched=s)

    def acc_sum(state: E.SumState, chunk: Chunk) -> E.SumState:
        vals = _as_2d(func(chunk)).astype(dtype)              # [n, A]
        w = (cond(chunk) * chunk["_mask"]).astype(dtype)      # [n]
        m = chunk["_mask"].astype(dtype)
        return E.SumState(
            sum=state.sum + vals.T @ w,
            sumsq=state.sumsq + (vals * vals).T @ w,
            scanned=state.scanned + jnp.sum(m),
            matched=state.matched + jnp.sum(w),
        )

    def merge(a, b):
        return jax.tree.map(jnp.add, a, b)

    def terminate(state):
        s = state.sum if A > 1 else state.sum[0]
        return s

    if estimator in ("single", "synchronized", "none"):

        def estimate(state: E.SumState, confidence, ctx=None) -> Estimate:
            est = E.horvitz_estimate(state.sum, state.scanned, d_total)
            var = E.variance_estimate(state.sum, state.sumsq, state.scanned, d_total)
            lo, hi = E.normal_bounds(est, var, confidence)
            sq = (lambda x: x) if A > 1 else (lambda x: x[0])
            return Estimate(sq(est), sq(lo), sq(hi),
                            info={"var": sq(var), "frac": state.scanned / d_total})

        # Per-shard fused-kernel dispatch (engine emit="kernel"): the Pallas
        # kernel reproduces acc_sum's state from (func, cond) projections —
        # only for the plain f32 single-aggregate SumState layout.
        kernel_cols = None
        if A == 1 and dtype == jnp.float32:
            kernel_cols = lambda chunk: (func(chunk), cond(chunk))

        return GLA(
            init=zero_sum, accumulate=acc_sum, merge=merge, terminate=terminate,
            estimate=None if estimator == "none" else estimate,
            merge_is_additive=True, kernel_cols=kernel_cols,
            name=f"sum-{estimator}",
        )

    if estimator == "multiple":

        def zero_mult():
            z = jnp.zeros((A,), dtype)
            return E.MultState(base=zero_sum(), est=z, estvar=z)

        def acc_mult(state: E.MultState, chunk: Chunk) -> E.MultState:
            return E.MultState(acc_sum(state.base, chunk), state.est, state.estvar)

        def merge_mult(a: E.MultState, b: E.MultState) -> E.MultState:
            # Merging *local* (pre-EstimatorTerminate) states: base adds,
            # est/estvar are not yet meaningful — keep additive for engine
            # uniformity (they are zero until estimator_terminate).
            return jax.tree.map(jnp.add, a, b)

        def est_term(state: E.MultState, ctx) -> E.MultState:
            """Alg. 2 EstimatorTerminate — needs |D_i| from the engine ctx."""
            b = state.base
            d_local = ctx["d_local"]
            est = E.horvitz_estimate(b.sum, b.scanned, d_local)
            var = E.variance_estimate(b.sum, b.sumsq, b.scanned, d_local)
            return E.MultState(b, est, var)

        def estimate(state: E.MultState, confidence, ctx=None) -> Estimate:
            lo, hi = E.normal_bounds(state.est, state.estvar, confidence)
            sq = (lambda x: x) if A > 1 else (lambda x: x[0])
            return Estimate(sq(state.est), sq(lo), sq(hi),
                            info={"var": sq(state.estvar)})

        return GLA(
            init=zero_mult, accumulate=acc_mult, merge=merge_mult,
            terminate=lambda s: terminate(s.base),
            estimator_terminate=est_term, estimator_merge=merge_mult,
            estimate=estimate, merge_is_additive=True, name="sum-multiple",
        )

    raise ValueError(f"unknown estimator model: {estimator!r}")


# ---------------------------------------------------------------------------
# Paper Alg. 3 — GLAGroupBy (composite GLA: a GLASum per group)
# ---------------------------------------------------------------------------

def make_groupby_gla(
    func: Callable[[Chunk], jnp.ndarray],
    cond: Callable[[Chunk], jnp.ndarray],
    group: Callable[[Chunk], jnp.ndarray],
    *,
    num_groups: int,
    d_total: float,
    estimator: str = "single",
    dtype=jnp.float32,
    num_aggs: int = 1,
) -> GLA:
    """GROUP BY gAtts SUM(func(d)) WHERE cond(d) — paper query (5).

    State is the dense composite of per-group GLASum states ("GLA
    composition", paper §4.4): sums/sumsqs/matched are [G, A]/[G]; ``scanned``
    is global (each group's predicate is cond ∧ group==g over the same scan).
    The per-item scatter is a segment_sum → one-hot MXU contraction on TPU.
    """
    G, A = num_groups, num_aggs

    def zero():
        return E.SumState(
            sum=jnp.zeros((G, A), dtype), sumsq=jnp.zeros((G, A), dtype),
            scanned=jnp.zeros((), dtype), matched=jnp.zeros((G,), dtype),
        )

    def acc(state: E.SumState, chunk: Chunk) -> E.SumState:
        vals = _as_2d(func(chunk)).astype(dtype)             # [n, A]
        w = (cond(chunk) * chunk["_mask"]).astype(dtype)     # [n]
        gids = group(chunk).astype(jnp.int32)                # [n]
        vw = vals * w[:, None]
        return E.SumState(
            sum=state.sum + jax.ops.segment_sum(vw, gids, num_segments=G),
            sumsq=state.sumsq + jax.ops.segment_sum(vals * vw, gids, num_segments=G),
            scanned=state.scanned + jnp.sum(chunk["_mask"].astype(dtype)),
            matched=state.matched + jax.ops.segment_sum(w, gids, num_segments=G),
        )

    def merge(a, b):
        return jax.tree.map(jnp.add, a, b)

    if estimator in ("single", "synchronized", "none"):

        def estimate(state: E.SumState, confidence, ctx=None) -> Estimate:
            est = E.horvitz_estimate(state.sum, state.scanned, d_total)   # [G, A]
            var = E.variance_estimate(state.sum, state.sumsq, state.scanned, d_total)
            lo, hi = E.normal_bounds(est, var, confidence)
            return Estimate(est, lo, hi, info={"var": var, "matched": state.matched})

        return GLA(
            init=zero, accumulate=acc, merge=merge,
            terminate=lambda s: s.sum,
            estimate=None if estimator == "none" else estimate,
            merge_is_additive=True, name=f"groupby-{estimator}",
        )

    if estimator == "multiple":

        def zero_mult():
            z = jnp.zeros((G, A), dtype)
            return E.MultState(base=zero(), est=z, estvar=z)

        def acc_mult(state, chunk):
            return E.MultState(acc(state.base, chunk), state.est, state.estvar)

        def est_term(state: E.MultState, ctx) -> E.MultState:
            b = state.base
            d_local = ctx["d_local"]
            est = E.horvitz_estimate(b.sum, b.scanned, d_local)
            var = E.variance_estimate(b.sum, b.sumsq, b.scanned, d_local)
            return E.MultState(b, est, var)

        def estimate(state: E.MultState, confidence, ctx=None) -> Estimate:
            lo, hi = E.normal_bounds(state.est, state.estvar, confidence)
            return Estimate(state.est, lo, hi, info={"var": state.estvar})

        return GLA(
            init=zero_mult, accumulate=acc_mult,
            merge=lambda a, b: jax.tree.map(jnp.add, a, b),
            terminate=lambda s: s.base.sum,
            estimator_terminate=est_term,
            estimate=estimate, merge_is_additive=True, name="groupby-multiple",
        )

    raise ValueError(f"unknown estimator model: {estimator!r}")


# ---------------------------------------------------------------------------
# Paper Alg. 4 — GLAJoin (replicated in-memory dimension table)
# ---------------------------------------------------------------------------

def make_join_groupby_gla(
    func: Callable[[Chunk], jnp.ndarray],
    cond: Callable[[Chunk], jnp.ndarray],
    join_key: Callable[[Chunk], jnp.ndarray],
    dim_group: jnp.ndarray,
    dim_valid: jnp.ndarray,
    *,
    num_groups: int,
    d_total: float,
    estimator: str = "single",
    dtype=jnp.float32,
    num_aggs: int = 1,
) -> GLA:
    """Join group-by — paper query (6), M replicated and hashed in memory.

    ``dim_group[k]`` is the group id the dimension row with key ``k`` maps to
    (e.g. supplier -> nation), ``dim_valid[k]`` its cond_M(M.sAtts) predicate.
    Per the paper, H is built by the user application during Init (query
    setup) and shipped with the query — here it is a replicated closure
    constant.  Accumulate = hash-probe (gather) + GLAGroupBy accumulate.
    """
    dim_group = jnp.asarray(dim_group, jnp.int32)
    dim_valid = jnp.asarray(dim_valid)

    def joined_group(chunk: Chunk) -> jnp.ndarray:
        keys = join_key(chunk).astype(jnp.int32)
        return dim_group[keys]

    def joined_cond(chunk: Chunk) -> jnp.ndarray:
        keys = join_key(chunk).astype(jnp.int32)
        return cond(chunk) * dim_valid[keys].astype(cond(chunk).dtype)

    inner = make_groupby_gla(
        func, joined_cond, joined_group,
        num_groups=num_groups, d_total=d_total, estimator=estimator,
        dtype=dtype, num_aggs=num_aggs,
    )
    return inner.with_(name=f"join-{estimator}")
