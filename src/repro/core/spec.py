"""QuerySpec — the consolidated query-plan surface (DESIGN.md §11).

Historically every entry point (``run_query``, ``run_queries``,
``Session``) grew its own copy of the plan kwargs (rounds, schedule,
stop, emit, mode, lanes, ...), and adding a parameter meant touching all
of them.  :class:`QuerySpec` is the one place a query plan lives: build
it once, hand it to any entry point — including ``OLAService.submit``,
where a loose-kwarg spelling never existed.

The old spellings keep working through :func:`coerce_spec`, the thin
shim every entry point routes through: a bare GLA first argument is
wrapped silently, but passing any of the deprecated loose plan kwargs
emits a ``DeprecationWarning`` (and rule C009 in
``repro/analysis/contracts.py`` keeps framework code off them).

``QuerySpec`` is plan-only by design: *where* the plan runs (``mesh``,
``axis_name``, ``audit``) stays a per-call argument — the same spec can
be submitted to the vmapped engine, a shard_map mesh, or a service scan.
"""
from __future__ import annotations

import dataclasses
import warnings
from typing import Any, Optional

#: Loose plan kwargs accepted (with a DeprecationWarning) by the
#: run_query/run_queries/Session shims.  ``mode`` maps onto
#: ``QuerySpec.sync``; everything else maps onto the field of the same
#: name.  Rule C009 (repro/analysis/contracts.py) forbids framework code
#: from spelling plans this way.
DEPRECATED_PLAN_KWARGS = (
    "rounds", "schedule", "stop", "confidence", "mode", "emit", "lanes",
    "snapshots", "alive", "fault", "sync_cost_model", "estimator_merge",
)


def _is_gla_sequence(gla) -> bool:
    """True when ``gla`` is a plain sequence of queries (run_queries),
    as opposed to a single GLA or a NamedTuple query description."""
    return isinstance(gla, (tuple, list)) and not hasattr(type(gla), "_fields")


@dataclasses.dataclass(frozen=True)
class QuerySpec:
    """One OLA query plan.

    Fields mirror the engine's execution model (DESIGN.md §2, §7):

      gla             the UDA bundle — one GLA, a sequence of GLAs
                      (``run_queries``), or a ``serving`` slot query.
      rounds          snapshot points over the scan.
      schedule        cumulative chunk boundaries [P, R+1]; None = uniform.
      stop            stopping rule (``repro.core.session.rel_width`` et al.).
      emit            state-emission discipline ("chunk" | "round" |
                      "round_masked" | "kernel"); None resolves to "chunk"
                      for a single GLA and "round" for a sequence.
      sync            True = the Wu et al. synchronized estimator barrier
                      (the old ``mode="sync"``).
      lanes           parallel GLA states per partition.
      snapshots       False = non-interactive mode (no per-round states).
      confidence      CI level for estimates.
      alive           static liveness mask [P] or [R, P] (paper §4.6).
      fault           runtime ``FaultPolicy``; exclusive with
                      ``estimator_merge``.
      estimator_merge shorthand for the fault-estimator family
                      ("single" | "multiple" | "synchronized") — resolves
                      to ``FaultPolicy(estimator_merge)`` when ``fault``
                      is not given.
      sync_cost_model sharded sync mode only: pay the per-chunk
                      coordination collective (DESIGN.md §4).
    """

    gla: Any
    rounds: int = 8
    schedule: Optional[Any] = None
    stop: Optional[Any] = None
    emit: Optional[str] = None
    sync: bool = False
    lanes: int = 1
    snapshots: bool = True
    confidence: float = 0.95
    alive: Optional[Any] = None
    fault: Optional[Any] = None
    estimator_merge: Optional[str] = None
    sync_cost_model: bool = True

    def __post_init__(self):
        if self.fault is not None and self.estimator_merge is not None:
            raise ValueError(
                "QuerySpec: pass either fault= (a FaultPolicy) or "
                "estimator_merge= (its shorthand), not both")

    @property
    def mode(self) -> str:
        return "sync" if self.sync else "async"

    @property
    def is_multi(self) -> bool:
        return _is_gla_sequence(self.gla)

    def resolved_emit(self) -> str:
        if self.emit is not None:
            return self.emit
        return "round" if self.is_multi else "chunk"

    def resolved_fault(self):
        """The runtime fault policy: ``fault`` as given, or one built
        from the ``estimator_merge`` shorthand."""
        if self.fault is not None or self.estimator_merge is None:
            return self.fault
        from repro.core.session import FaultPolicy  # session imports spec

        return FaultPolicy(self.estimator_merge)

    def with_(self, **kw) -> "QuerySpec":
        return dataclasses.replace(self, **kw)


def coerce_spec(spec_or_gla, legacy: dict, *, caller: str) -> QuerySpec:
    """The back-compat shim behind every entry point.

    ``spec_or_gla`` is either a ready :class:`QuerySpec` (canonical; any
    loose plan kwarg alongside it is a TypeError) or a bare GLA.  A bare
    GLA with no loose kwargs wraps silently — ``run_query(gla, data)``
    stays warning-free; any deprecated kwarg triggers one
    ``DeprecationWarning`` naming the offending spellings.
    """
    if isinstance(spec_or_gla, QuerySpec):
        if legacy:
            raise TypeError(
                f"{caller}(): pass the plan inside the QuerySpec, not as "
                f"loose kwargs too ({sorted(legacy)})")
        return spec_or_gla
    if not legacy:
        return QuerySpec(gla=spec_or_gla)
    unknown = sorted(set(legacy) - set(DEPRECATED_PLAN_KWARGS))
    if unknown:
        raise TypeError(
            f"{caller}() got unexpected keyword arguments: {unknown}")
    warnings.warn(
        f"{caller}(gla, data, {'/'.join(sorted(legacy))}=...) loose plan "
        f"kwargs are deprecated — pass {caller}(QuerySpec(gla, ...), data) "
        "(repro.QuerySpec)", DeprecationWarning, stacklevel=3)
    kw = dict(legacy)
    mode = kw.pop("mode", None)
    if mode is not None:
        if mode not in ("async", "sync"):
            raise ValueError(f"mode must be 'async' or 'sync', got {mode!r}")
        kw["sync"] = mode == "sync"
    return QuerySpec(gla=spec_or_gla, **kw)
