"""QuerySpec — the consolidated query-plan surface (DESIGN.md §11).

Historically every entry point (``run_query``, ``run_queries``,
``Session``) grew its own copy of the plan kwargs (rounds, schedule,
stop, emit, mode, lanes, ...), and adding a parameter meant touching all
of them.  :class:`QuerySpec` is the one place a query plan lives: build
it once, hand it to any entry point — including ``OLAService.submit``,
where a loose-kwarg spelling never existed.

The old spellings keep working through :func:`coerce_spec`, the thin
shim every entry point routes through: a bare GLA first argument is
wrapped silently, but passing any of the deprecated loose plan kwargs
emits a ``DeprecationWarning`` (and rule C009 in
``repro/analysis/contracts.py`` keeps framework code off them).

``QuerySpec`` is plan-only by design: *where* the plan runs (``mesh``,
``axis_name``, ``audit``) stays a per-call argument — the same spec can
be submitted to the vmapped engine, a shard_map mesh, or a service scan.
"""
from __future__ import annotations

import dataclasses
import warnings
from typing import Any, Optional

#: Loose plan kwargs accepted (with a DeprecationWarning) by the
#: run_query/run_queries/Session shims.  ``mode`` maps onto
#: ``QuerySpec.sync``; everything else maps onto the field of the same
#: name.  Rule C009 (repro/analysis/contracts.py) forbids framework code
#: from spelling plans this way.
DEPRECATED_PLAN_KWARGS = (
    "rounds", "schedule", "stop", "confidence", "mode", "emit", "lanes",
    "snapshots", "alive", "fault", "sync_cost_model", "estimator_merge",
)


def _is_gla_sequence(gla) -> bool:
    """True when ``gla`` is a plain sequence of queries (run_queries),
    as opposed to a single GLA or a NamedTuple query description."""
    return isinstance(gla, (tuple, list)) and not hasattr(type(gla), "_fields")


# ---------------------------------------------------------------------------
# Composable OLA plan trees (DESIGN.md §13).
#
# A PlanNode tree is the declarative face of a query: a Scan leaf, an
# optional chain of Filter/Join stages, and an estimator root (SumAgg /
# GroupAgg / sketch roots, optionally wrapped in Having for Deep OLA
# nesting).  ``QuerySpec`` lowers any PlanNode handed to it through
# :func:`lower_plan` onto the *existing* GLA constructors — a one-node
# tree over a classic flat plan lowers to the byte-identical constructor
# call, so flat-plan finals/snapshots/bounds stay bitwise-identical
# (tests/test_plan_tree.py).
#
# Contract (rule C010, repro/analysis/contracts.py): every PlanNode
# subclass declares its ``monoid`` (how partial states merge: "sum" |
# "max" | "none" for pure stages) and ``estimator`` (which estimator
# family the root pairs with) as class attributes, so a reader — and the
# sharded engine's additivity gate — can see the merge semantics without
# chasing the lowering.
# ---------------------------------------------------------------------------


class PlanNode:
    """Base class of the plan tree.  Subclasses are plain frozen
    dataclasses with ``child`` links; ``lower()`` produces the executable
    GLA.  Identity semantics (``eq=False``): nodes may hold device arrays
    (probe tables) and are never used as cache keys themselves."""

    monoid = "none"
    estimator = "none"

    def lower(self):
        return lower_plan(self)


@dataclasses.dataclass(frozen=True, eq=False)
class Scan(PlanNode):
    """Leaf: the randomized fact-table scan.  ``d_total`` = |D|."""

    monoid = "none"
    estimator = "none"

    d_total: float


@dataclasses.dataclass(frozen=True, eq=False)
class Filter(PlanNode):
    """Selection stage: ``cond(chunk) -> [n] in {0,1}``.  Multiple Filter
    stages combine multiplicatively (conjunction)."""

    monoid = "none"
    estimator = "none"

    child: Any
    cond: Any


@dataclasses.dataclass(frozen=True, eq=False)
class Join(PlanNode):
    """Fact-to-dimension hash probe (paper Alg. 4 / §3.3).

    ``dim_group[k]`` / ``dim_valid[k]`` are the replicated dimension
    arrays indexed by ``join_key(chunk)``; the GroupAgg root above this
    stage groups by the probed attribute.  ``d_dim``/``s_dim`` opt into
    the §3.3 multiplicative join estimator scale for sampled dimension
    tables (resident tables — the default — scale by exactly 1).
    """

    monoid = "none"
    estimator = "multiplicative"

    child: Any
    join_key: Any
    dim_group: Any
    dim_valid: Any
    d_dim: Optional[float] = None
    s_dim: Optional[float] = None


@dataclasses.dataclass(frozen=True, eq=False)
class SumAgg(PlanNode):
    """Estimator root: SUM(func(d)) with the Eq. (2)/(4) sampling
    estimator (``model``: single | multiple | synchronized | none)."""

    monoid = "sum"
    estimator = "horvitz"

    child: Any
    func: Any
    num_aggs: int = 1
    model: str = "single"


@dataclasses.dataclass(frozen=True, eq=False)
class GroupAgg(PlanNode):
    """Estimator root: GROUP BY SUM with per-group sampling estimators.

    ``group`` maps fact chunks to dense ids; leave it None above a Join
    stage (the probed ``dim_group`` provides the grouping).
    """

    monoid = "sum"
    estimator = "horvitz-per-group"

    child: Any
    func: Any
    num_groups: int
    group: Any = None
    num_aggs: int = 1
    model: str = "single"
    bucket_bits: Optional[int] = None


@dataclasses.dataclass(frozen=True, eq=False)
class Having(PlanNode):
    """Deep OLA nesting root: SUM over groups whose *estimated* inner
    aggregate passes ``estimate <mode> threshold``, variance propagated
    (estimators.nested_group_estimate).  ``child`` must lower to a
    group-shaped estimating GLA (a GroupAgg-rooted plan)."""

    monoid = "sum"
    estimator = "nested-normal"

    child: Any
    threshold: Any
    mode: str = ">="
    agg: int = 0


@dataclasses.dataclass(frozen=True, eq=False)
class CountDistinct(PlanNode):
    """Sketch root: COUNT(DISTINCT key(d)) via HLL-style registers.
    Max monoid — NOT additive, vmapped engine only (core/sketch.py)."""

    monoid = "max"
    estimator = "hll-normal"

    child: Any
    key: Any
    log2m: int = 12


@dataclasses.dataclass(frozen=True, eq=False)
class Quantile(PlanNode):
    """Sketch root: the q-quantile of value(d) over [lo, hi) via an
    additive fixed-bin histogram CDF with DKW bands."""

    monoid = "sum"
    estimator = "dkw"

    child: Any
    value: Any
    lo: float
    hi: float
    bins: int = 256
    q: float = 0.5


@dataclasses.dataclass(frozen=True, eq=False)
class HeavyHitters(PlanNode):
    """Sketch root: per-candidate frequencies via an additive count-min
    sketch, Horvitz–Thompson-scaled with the CM overcount bound."""

    monoid = "sum"
    estimator = "cms-ht"

    child: Any
    key: Any
    candidates: Any
    width: int = 1024
    depth: int = 4


def _unstack_stages(node):
    """Walk an estimator root's child chain down to the Scan leaf.

    Returns ``(scan, conds, join)`` — the leaf, the Filter conds in
    scan-to-root order, and the single Join stage (or None).
    """
    conds, join = [], None
    cur = node
    while not isinstance(cur, Scan):
        if isinstance(cur, Filter):
            conds.append(cur.cond)
        elif isinstance(cur, Join):
            if join is not None:
                raise ValueError("plan trees support one Join stage")
            join = cur
        elif isinstance(cur, PlanNode):
            raise ValueError(
                f"{type(cur).__name__} is an estimator root — it cannot "
                f"appear below another root")
        else:
            raise TypeError(f"not a PlanNode: {cur!r}")
        cur = cur.child
    return cur, conds[::-1], join


def _combined_cond(conds, *, optional=False):
    """Conjunction of Filter conds.  A single cond is returned AS-IS so a
    one-Filter tree hands the constructor the very same closure the flat
    spelling would — identical GLA args, bitwise-identical plans."""
    if len(conds) == 1:
        return conds[0]
    if not conds:
        if optional:
            return None

        def cond_true(chunk):
            import jax.numpy as jnp

            return jnp.ones_like(chunk["_mask"])

        return cond_true

    def cond_all(chunk):
        w = conds[0](chunk)
        for c in conds[1:]:
            w = w * c(chunk)
        return w

    return cond_all


def lower_plan(node):
    """Lower a PlanNode tree onto the executable GLA constructors
    (repro.core.gla / repro.core.sketch).

    Lowering rules (DESIGN.md §13): stages collapse into the constructor
    arguments of their estimator root — Filters into ``cond``, a Join
    into the probe arrays of ``make_join_groupby_gla`` — and Having wraps
    the lowered child through ``gla.compose``.  Imports are
    function-local so ``import repro`` (and this module) stays jax-free.
    """
    from repro.core import gla as G

    if not isinstance(node, PlanNode):
        raise TypeError(f"lower_plan() takes a PlanNode, got {node!r}")
    if isinstance(node, Having):
        inner = lower_plan(node.child)
        return G.make_having_gla(
            inner, node.threshold, mode=node.mode, agg=node.agg)
    if isinstance(node, SumAgg):
        scan, conds, join = _unstack_stages(node.child)
        if join is not None:
            raise ValueError(
                "Join plans need a GroupAgg root — the grouping comes "
                "from the probed dimension attribute")
        return G.make_sum_gla(
            node.func, _combined_cond(conds), d_total=scan.d_total,
            estimator=node.model, num_aggs=node.num_aggs)
    if isinstance(node, GroupAgg):
        scan, conds, join = _unstack_stages(node.child)
        cond = _combined_cond(conds)
        if join is None:
            if node.group is None:
                raise ValueError("GroupAgg over a plain scan needs group=")
            return G.make_groupby_gla(
                node.func, cond, node.group, num_groups=node.num_groups,
                d_total=scan.d_total, estimator=node.model,
                num_aggs=node.num_aggs, bucket_bits=node.bucket_bits)
        if node.group is not None:
            raise ValueError(
                "GroupAgg above a Join groups by the probed dim_group — "
                "drop group=")
        return G.make_join_groupby_gla(
            node.func, cond, join.join_key, join.dim_group, join.dim_valid,
            num_groups=node.num_groups, d_total=scan.d_total,
            estimator=node.model, num_aggs=node.num_aggs,
            bucket_bits=node.bucket_bits, d_dim=join.d_dim,
            s_dim=join.s_dim)

    from repro.core import sketch as SK

    if isinstance(node, (CountDistinct, Quantile, HeavyHitters)):
        scan, conds, join = _unstack_stages(node.child)
        if join is not None:
            raise ValueError("sketch roots run over plain filtered scans")
        cond = _combined_cond(conds, optional=True)
        if isinstance(node, CountDistinct):
            return SK.make_count_distinct_gla(
                node.key, d_total=scan.d_total, log2m=node.log2m, cond=cond)
        if isinstance(node, Quantile):
            return SK.make_quantile_gla(
                node.value, lo=node.lo, hi=node.hi, d_total=scan.d_total,
                bins=node.bins, q=node.q, cond=cond)
        return SK.make_heavy_hitters_gla(
            node.key, node.candidates, d_total=scan.d_total,
            width=node.width, depth=node.depth, cond=cond)
    raise ValueError(
        f"{type(node).__name__} is not an estimator root — plans lower "
        f"from their root node")


@dataclasses.dataclass(frozen=True)
class QuerySpec:
    """One OLA query plan.

    Fields mirror the engine's execution model (DESIGN.md §2, §7):

      gla             the UDA bundle — one GLA, a sequence of GLAs
                      (``run_queries``), or a ``serving`` slot query.
      rounds          snapshot points over the scan.
      schedule        cumulative chunk boundaries [P, R+1]; None = uniform.
      stop            stopping rule (``repro.core.session.rel_width`` et al.).
      emit            state-emission discipline ("chunk" | "round" |
                      "round_masked" | "kernel"); None resolves to "chunk"
                      for a single GLA and "round" for a sequence.
      sync            True = the Wu et al. synchronized estimator barrier
                      (the old ``mode="sync"``).
      lanes           parallel GLA states per partition.
      snapshots       False = non-interactive mode (no per-round states).
      confidence      CI level for estimates.
      alive           static liveness mask [P] or [R, P] (paper §4.6).
      fault           runtime ``FaultPolicy``; exclusive with
                      ``estimator_merge``.
      estimator_merge shorthand for the fault-estimator family
                      ("single" | "multiple" | "synchronized") — resolves
                      to ``FaultPolicy(estimator_merge)`` when ``fault``
                      is not given.
      sync_cost_model sharded sync mode only: pay the per-chunk
                      coordination collective (DESIGN.md §4).
      plan            the PlanNode tree ``gla`` was lowered from, when the
                      spec was built from one (read-only provenance; a
                      GLA-built spec leaves it None).

    ``gla`` also accepts a :class:`PlanNode` tree (or a sequence mixing
    trees and GLAs): it is lowered through :func:`lower_plan` at
    construction, the original tree kept in ``plan``.
    """

    gla: Any
    rounds: int = 8
    schedule: Optional[Any] = None
    stop: Optional[Any] = None
    emit: Optional[str] = None
    sync: bool = False
    lanes: int = 1
    snapshots: bool = True
    confidence: float = 0.95
    alive: Optional[Any] = None
    fault: Optional[Any] = None
    estimator_merge: Optional[str] = None
    sync_cost_model: bool = True
    plan: Optional[Any] = None

    def __post_init__(self):
        if self.fault is not None and self.estimator_merge is not None:
            raise ValueError(
                "QuerySpec: pass either fault= (a FaultPolicy) or "
                "estimator_merge= (its shorthand), not both")
        g = self.gla
        if isinstance(g, PlanNode):
            object.__setattr__(self, "plan", g)
            object.__setattr__(self, "gla", lower_plan(g))
        elif _is_gla_sequence(g) and any(
                isinstance(m, PlanNode) for m in g):
            object.__setattr__(self, "plan", g)
            object.__setattr__(self, "gla", type(g)(
                lower_plan(m) if isinstance(m, PlanNode) else m
                for m in g))

    @property
    def mode(self) -> str:
        return "sync" if self.sync else "async"

    @property
    def is_multi(self) -> bool:
        return _is_gla_sequence(self.gla)

    def resolved_emit(self) -> str:
        if self.emit is not None:
            return self.emit
        return "round" if self.is_multi else "chunk"

    def resolved_fault(self):
        """The runtime fault policy: ``fault`` as given, or one built
        from the ``estimator_merge`` shorthand."""
        if self.fault is not None or self.estimator_merge is None:
            return self.fault
        from repro.core.session import FaultPolicy  # session imports spec

        return FaultPolicy(self.estimator_merge)

    def with_(self, **kw) -> "QuerySpec":
        return dataclasses.replace(self, **kw)


def coerce_spec(spec_or_gla, legacy: dict, *, caller: str) -> QuerySpec:
    """The back-compat shim behind every entry point.

    ``spec_or_gla`` is either a ready :class:`QuerySpec` (canonical; any
    loose plan kwarg alongside it is a TypeError) or a bare GLA.  A bare
    GLA with no loose kwargs wraps silently — ``run_query(gla, data)``
    stays warning-free; any deprecated kwarg triggers one
    ``DeprecationWarning`` naming the offending spellings.
    """
    if isinstance(spec_or_gla, QuerySpec):
        if legacy:
            raise TypeError(
                f"{caller}(): pass the plan inside the QuerySpec, not as "
                f"loose kwargs too ({sorted(legacy)})")
        return spec_or_gla
    if not legacy:
        return QuerySpec(gla=spec_or_gla)
    unknown = sorted(set(legacy) - set(DEPRECATED_PLAN_KWARGS))
    if unknown:
        raise TypeError(
            f"{caller}() got unexpected keyword arguments: {unknown}")
    warnings.warn(
        f"{caller}(gla, data, {'/'.join(sorted(legacy))}=...) loose plan "
        f"kwargs are deprecated — pass {caller}(QuerySpec(gla, ...), data) "
        "(repro.QuerySpec)", DeprecationWarning, stacklevel=3)
    kw = dict(legacy)
    mode = kw.pop("mode", None)
    if mode is not None:
        if mode not in ("async", "sync"):
            raise ValueError(f"mode must be 'async' or 'sync', got {mode!r}")
        kw["sync"] = mode == "sync"
    return QuerySpec(gla=spec_or_gla, **kw)
