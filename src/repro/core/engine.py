"""The PF-OLA execution engine — paper §3.2–§3.4, adapted to SPMD JAX.

Execution model (DESIGN.md §2):

  * a *partition* is the unit of data locality (a GLADE worker node).  In the
    vmapped path partitions are a leading array axis (used by tests/benchmarks
    on 1 CPU device); in the sharded path partitions are devices along the
    ``data`` mesh axis under ``jax.shard_map``
    (repro/dist/shard_engine.py, used by the dry-run and real deployments).
    Both paths run the *same* GLA and the same math: the per-partition scans
    live in repro/core/scan.py and are shared verbatim — the paths differ
    only in the merge mechanism (tensordot over the partition axis here,
    ``lax.psum`` there).
  * within a partition, chunks are consumed by ``lax.scan`` — the analogue of
    DataPath work-units pulling chunks.  ``lanes > 1`` keeps several GLA
    states per partition (the paper's "list of GLA states bounded by the
    number of work units") and merges them on demand, which makes the
    associative-decomposability contract *observable* and testable.
  * a *snapshot* (partial-result request, paper §3.4) is the scan carry
    emitted at a round boundary.  The state already exists — emission adds no
    recompute and no extra data pass; this is the zero-overhead property,
    verified by benchmarks/overhead.py (wall time) and HLO cost analysis.
  * *stragglers / asynchrony*: a ``schedule`` gives each partition its own
    cumulative chunk-progress curve.  Async snapshots take each partition at
    its own progress (valid for the single estimator under global
    randomization); ``mode="sync"`` truncates every partition to the global
    minimum progress — the Wu et al. barrier — and, in the sharded path,
    pays a per-chunk collective, reproducing that estimator's overhead
    mechanistically.
  * node failure: ``alive`` masks partitions out of merging — [P] for a
    partition dead throughout, [R, P] for a failure-injection schedule; see
    repro/dist/fault.py for the estimator-level consequences (paper §4.6,
    DESIGN.md §4).
  * *plan trees* (DESIGN.md §13): ``QuerySpec`` lowers ``PlanNode`` trees
    (scan → filter/join → aggregate/sketch → having) to GLAs before they
    reach this engine, so every path here — including the fused kernel,
    whose join probe tables ride as extra Pallas operands — executes
    composed Deep OLA plans with the same machinery as flat ones.  Classic
    flat plans lower to one-node trees with bitwise-identical programs.
"""
from __future__ import annotations

import functools
import warnings
from typing import Any, NamedTuple, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import scan as SC
from repro.core import spec as QS
from repro.core.uda import GLA, Estimate

Pytree = Any


class QueryResult(NamedTuple):
    final: Any                    # gla.terminate(fully merged state)
    snapshots: Optional[Pytree]   # merged per-round states, leaves [R, ...]
    estimates: Optional[Estimate]  # per-round Estimate, leaves [R, ...]
    d_total: jnp.ndarray
    d_local: jnp.ndarray          # [P]


# ---------------------------------------------------------------------------
# schedules
# ---------------------------------------------------------------------------

def uniform_schedule(num_partitions: int, num_chunks: int, rounds: int) -> np.ndarray:
    """Cumulative chunk boundaries [P, R+1]; round r covers [b[r], b[r+1])."""
    b = np.round(np.linspace(0, num_chunks, rounds + 1)).astype(np.int32)
    return np.broadcast_to(b, (num_partitions, rounds + 1)).copy()


def straggler_schedule(
    num_partitions: int, num_chunks: int, rounds: int, speeds, seed: int = 0
) -> np.ndarray:
    """Per-partition progress curves under heterogeneous speeds.

    ``speeds[p]`` is partition p's relative throughput; progress accrues
    proportionally with small multiplicative jitter, capped at num_chunks.
    Every partition eventually finishes (last round = full scan) so the query
    completes — stragglers only delay, as in the paper's asynchronous model.
    """
    rng = np.random.default_rng(seed)
    speeds = np.asarray(speeds, np.float64)
    base = num_chunks / speeds.max()
    sched = np.zeros((num_partitions, rounds + 1), np.int32)
    for p in range(num_partitions):
        jitter = rng.uniform(0.85, 1.15, rounds)
        inc = speeds[p] * base / rounds * jitter
        cum = np.minimum(np.cumsum(inc), num_chunks)
        sched[p, 1:] = np.round(cum).astype(np.int32)
    sched[:, -1] = num_chunks  # completion
    return sched


# ---------------------------------------------------------------------------
# vmapped (partition-simulation) path
# ---------------------------------------------------------------------------

def _merge_over_partitions(gla: GLA, states: Pytree, w: jnp.ndarray, merge,
                           all_alive: bool):
    """Merge states with leading partition axis [P, ...] under weights [P].

    ``all_alive`` is decided on the host before tracing: a non-additive
    merge cannot honor a liveness mask (the weights feed a tensordot), so
    it is only legal when every partition is statically alive.
    """
    P = w.shape[0]
    if gla.merge_is_additive:
        return jax.tree.map(
            lambda x: jnp.tensordot(w.astype(x.dtype), x, axes=(0, 0)), states
        )
    if not all_alive:
        raise NotImplementedError("alive masks need merge_is_additive")
    return SC.fold_merge(merge, states, P)


def _merge_rounds(gla: GLA, states: Pytree, w_pr: jnp.ndarray, merge,
                  all_alive: bool):
    """Merge [P, R, ...] states with per-(partition, round) weights [P, R]."""
    P, R = w_pr.shape
    if gla.merge_is_additive:
        return jax.tree.map(
            lambda x: jnp.einsum(
                "pr,pr...->r...", w_pr.astype(x.dtype), x), states
        )
    if not all_alive:
        raise NotImplementedError("alive masks need merge_is_additive")
    return jax.vmap(lambda s: SC.fold_merge(merge, s, P), in_axes=1)(states)


@functools.partial(
    jax.jit, static_argnames=("gla", "mode", "emit", "lanes", "snapshots",
                              "confidence", "all_alive")
)
def _run_vmapped(gla: GLA, shards: dict, sched: jnp.ndarray, alive: jnp.ndarray,
                 *, mode: str, emit: str, lanes: int, snapshots: bool,
                 confidence: float, all_alive: bool):
    P, C, L = shards["_mask"].shape
    R = sched.shape[1] - 1
    d_local = jnp.sum(shards["_mask"], axis=(1, 2))
    d_total = jnp.sum(d_local)
    w_pr, w_final = SC.round_weights(alive, R)
    # fused dispatch blocks one [1, L] row per column — trailing dims fall
    # back to the legacy kernels (resident shards are always plain/decoded)
    fused_ok = SC.fused_available(gla) and all(
        v.ndim == 3 for v in shards.values())

    if emit == "kernel" and (gla.kernel_num_groups is not None
                             or gla.members):
        # group-by / bundled kernel dispatch: dense [G, A] states follow the
        # round emission discipline (DESIGN.md §3, §6) — no per-chunk
        # prefixes exist.  Bundles batch every member into one group_agg
        # dispatch per round-slice.
        assert lanes == 1, "emit='kernel' runs single-lane"
        if mode == "sync":
            raise NotImplementedError("sync mode requires emit='chunk'")
        # snapshots off: no round states are consumed — one whole-shard
        # dispatch (same chunk-sequential association, R-fold fewer launches)
        if fused_ok:
            # one fused selection→bucket→aggregate dispatch per round-slice,
            # bitwise-identical to the scan path (DESIGN.md §12)
            finals, round_states = SC.fused_rounds_states_batched(
                gla, shards, R if snapshots else 1)
        else:
            kernel_fn = (SC.bundle_kernel_rounds_states_batched if gla.members
                         else SC.kernel_rounds_states_batched)
            finals, round_states = kernel_fn(gla, shards,
                                             R if snapshots else 1)
    elif emit in ("chunk", "kernel"):
        if emit == "chunk":
            finals, prefixes = jax.vmap(
                lambda c: SC.scan_prefix(gla, c, lanes))(shards)
        elif fused_ok:
            # fused per-shard dispatch: running accumulators live in the
            # kernel's output refs, so the prefixes — and hence the scalar
            # finals — are bitwise-identical to the scan path (DESIGN.md §12)
            assert lanes == 1, "emit='kernel' runs single-lane"
            finals, prefixes = SC.fused_prefix_states_batched(gla, shards)
        else:  # legacy per-shard kernel dispatch (DESIGN.md §3)
            assert lanes == 1, "emit='kernel' runs single-lane"
            finals, prefixes = SC.kernel_prefix_states_batched(gla, shards)
        if snapshots:
            if mode == "sync":
                idx = jnp.broadcast_to(jnp.min(sched[:, 1:], axis=0), (P, R))
            else:
                idx = sched[:, 1:]
            round_states = jax.vmap(
                lambda pref, ix: jax.tree.map(lambda x: x[ix], pref)
            )(prefixes, idx)  # [P, R, ...]
        else:
            round_states = None
    elif emit == "round":
        finals, round_states = jax.vmap(
            lambda c: SC.scan_rounds(gla, c, lanes, R)
        )(shards)
        if mode == "sync":
            raise NotImplementedError("sync mode requires emit='chunk'")
    elif emit == "round_masked":
        finals, round_states = jax.vmap(
            lambda c, s: SC.scan_rounds_masked(gla, c, s, lanes)
        )(shards, sched)
    else:
        raise ValueError(f"unknown emit: {emit}")

    # Final result: plain Merge across partitions, then Terminate.
    merged_final = _merge_over_partitions(gla, finals, w_final, gla.merge,
                                          all_alive)
    final = gla.terminate(merged_final)

    if not snapshots or round_states is None:
        return QueryResult(final, None, None, d_total, d_local)

    # EstimatorTerminate per (partition, round) with the partition's |D_i|,
    # then EstimatorMerge across partitions (paper §3.1: intra- then inter-).
    def et(p_states, dl):
        return jax.vmap(lambda s: gla.estimator_terminate(s, {"d_local": dl}))(p_states)

    terminated = jax.vmap(et)(round_states, d_local)          # [P, R, ...]
    merged = _merge_rounds(gla, terminated, w_pr, gla.estimator_merge,
                           all_alive)

    estimates = None
    if gla.estimate is not None:
        estimates = jax.vmap(
            lambda s: gla.estimate(s, confidence, {"d_total": d_total})
        )(merged)

    return QueryResult(final, merged, estimates, d_total, d_local)


# ---------------------------------------------------------------------------
# public API
# ---------------------------------------------------------------------------

def normalize_plan(spec_or_gla, data, rounds: int = 8,
                   schedule: Optional[np.ndarray] = None,
                   emit: Optional[str] = None):
    """Validate emit/kernel contracts and resolve the plan.

    Canonical form: ``normalize_plan(spec, data) -> QuerySpec`` — takes a
    :class:`repro.core.spec.QuerySpec`, resolves its emission discipline
    and round schedule against the data's shape contract, and returns the
    resolved spec (``emit`` a concrete string, ``schedule`` a [P, R+1]
    ndarray, ``rounds`` its R).  This is what ``Session`` and
    ``OLAService`` call, so every entry point enforces identical
    contracts.

    Legacy form: ``normalize_plan(gla, data, rounds, schedule, emit) ->
    (rounds, schedule)`` — the pre-QuerySpec signature, kept for old
    callers.

    ``data`` is a resident [P, C, L] shards dict or a
    ``repro.data.source.ChunkSource`` (only the shape contract is
    consulted — no data is read).  Round-emission paths ("round", and
    group-by/bundle "kernel") emit at uniform round boundaries only:
    ``rounds`` degrades to the largest divisor of C with a warning, and
    an explicit ``schedule`` that is indivisible or non-uniform is a
    ValueError (those paths would silently ignore it otherwise).
    """
    if isinstance(spec_or_gla, QS.QuerySpec):
        qspec = spec_or_gla
        if qspec.is_multi:
            raise TypeError(
                "a QuerySpec holding a sequence of GLAs is a run_queries() "
                "plan — run_queries bundles it before execution")
        emit = qspec.resolved_emit()
        rounds, schedule = _resolve_rounds_schedule(
            qspec.gla, data, qspec.rounds, qspec.schedule, emit)
        return qspec.with_(rounds=rounds, schedule=schedule, emit=emit)
    rounds, schedule = _resolve_rounds_schedule(
        spec_or_gla, data, rounds, schedule,
        "chunk" if emit is None else emit)
    return rounds, schedule


def _resolve_rounds_schedule(gla: GLA, data, rounds: int,
                             schedule: Optional[np.ndarray], emit: str):
    spec = getattr(data, "spec", None)  # duck-typed: core stays data-free
    P, C, L = ((spec.P, spec.C, spec.L) if spec is not None
               else data["_mask"].shape[:3])
    if emit == "kernel":
        if gla.members:
            # one dispatch serves every member: either ALL publish the fused
            # contract (fused_agg path) or ALL publish kernel_cols (legacy
            # group_agg batching) — a mixed bundle has no single-kernel plan
            if any(m.fused is None for m in gla.members):
                missing = [m.name for m in gla.members
                           if m.kernel_cols is None]
                if missing:
                    raise ValueError(
                        f"bundle members {missing} do not publish kernel_cols "
                        "or a fused contract — emit='kernel' batches every "
                        "member into one dispatch and cannot mix in "
                        "scan-only members")
        elif gla.kernel_cols is None and gla.fused is None:
            raise ValueError(
                f"GLA {gla.name!r} publishes neither kernel_cols nor a "
                "fused kernel contract")
    needs_uniform_rounds = emit == "round" or (
        emit == "kernel" and (gla.kernel_num_groups is not None
                              or bool(gla.members)))
    if needs_uniform_rounds:
        if schedule is None:
            if C % rounds:
                best = max(d for d in range(1, rounds + 1) if C % d == 0)
                warnings.warn(
                    f"emit={emit!r} needs C % rounds == 0 (C={C}); degrading "
                    f"rounds {rounds} -> {best}", stacklevel=2)
                rounds = best
        else:
            sched_np = np.asarray(schedule)
            R = sched_np.shape[1] - 1
            if C % R:
                raise ValueError(
                    f"emit={emit!r} needs C % rounds == 0, got C={C} with a "
                    f"{R}-round schedule")
            # These paths emit states at uniform round boundaries only; a
            # schedule they would silently ignore is an error, not a hint.
            if not np.array_equal(sched_np, uniform_schedule(P, C, R)):
                raise ValueError(
                    f"emit={emit!r} emits snapshots at uniform round "
                    "boundaries and cannot honor a non-uniform schedule — "
                    "use emit='round_masked' (large states, any schedule) "
                    "or emit='chunk' (prefix states)")
    if schedule is None:
        schedule = uniform_schedule(P, C, rounds)
    return np.asarray(schedule).shape[1] - 1, np.asarray(schedule)


def _execute_full(gla: GLA, shards: dict, sched: jnp.ndarray,
                  alive_arr: jnp.ndarray, *, mode: str, emit: str, lanes: int,
                  snapshots: bool, confidence: float, all_alive: bool,
                  mesh, axis_name: str, sync_cost_model: bool) -> QueryResult:
    """Dispatch one fused whole-scan program (vmapped or sharded)."""
    if mesh is None:
        return _run_vmapped(
            gla, shards, sched, alive_arr, mode=mode, emit=emit, lanes=lanes,
            snapshots=snapshots, confidence=confidence, all_alive=all_alive,
        )
    from repro.dist import shard_engine  # local import: core must not require dist
    return shard_engine.run_sharded(
        gla, shards, sched, alive_arr, mesh=mesh, axis_name=axis_name,
        mode=mode, emit=emit, lanes=lanes, snapshots=snapshots,
        confidence=confidence, sync_cost_model=sync_cost_model,
    )


def run_query(
    spec,
    data,
    *,
    mesh=None,
    axis_name: str = "data",
    **plan,
) -> QueryResult:
    """Execute a GLA query with on-line estimation.

    A thin wrapper over :class:`repro.core.session.Session` driven to
    completion.  Without a stopping rule this runs the fused whole-scan
    program — byte-for-byte the classic engine path; with ``spec.stop``
    the session advances round by round and terminates as soon as the
    rule fires, so the result may cover fewer than ``spec.rounds``
    snapshot rounds and its ``final`` is the best partial-scan answer at
    the stopping round.

    Args:
      spec: a :class:`repro.core.spec.QuerySpec` (the canonical spelling
        — see its docstring for every plan field), or a bare GLA for the
        default plan.  The old loose plan kwargs (``rounds=``, ``emit=``,
        ``stop=``, ...) still work on a bare GLA but emit a
        ``DeprecationWarning`` (rule C009 keeps framework code off them).
      data: columnar dict, leaves [P, C, L] incl. "_mask", OR any
        ``repro.data.source.ChunkSource`` (DESIGN.md §8).  Streaming
        sources (``NpyMmapSource``/``ParquetSource``) are scanned
        out-of-core on the incremental discipline with O(slice) device
        footprint; finals/snapshots/bounds stay bitwise-identical to the
        resident path on the scan and group/bundle kernel paths.
      mesh: if given, run under shard_map with partitions on ``axis_name``
        (repro/dist/shard_engine.py).  Engine location is a per-call
        choice, never part of the spec.
    """
    from repro.core import session as SN  # local: session imports engine

    qspec = QS.coerce_spec(spec, plan, caller="run_query")
    return SN.Session(qspec, data, mesh=mesh, axis_name=axis_name).run()


def run_queries(
    specs,
    data,
    *,
    mesh=None,
    axis_name: str = "data",
    **plan,
):
    """Execute N concurrent OLA queries over a SINGLE pass of the shards.

    The paper's central claim (§3–§4) is that any number of concurrent
    estimation models ride alongside one execution with virtually no
    overhead.  This is the multi-query hot path that delivers it: the
    queries are stacked into a :func:`repro.core.gla.GLABundle` (one
    tuple-of-states GLA), every scan path feeds all of them from the same
    chunk stream, and the results are unbundled into one
    :class:`QueryResult` per query.  Each query's finals, snapshot states
    and per-round bounds are bitwise-identical to running it alone with
    ``run_query`` (tests/test_multiquery.py) — a second query no longer
    pays a second pass over the data.

    ``specs`` is a :class:`repro.core.spec.QuerySpec` whose ``gla`` is a
    sequence of GLAs, or a bare sequence for the default plan (the old
    loose kwargs also still work on a bare sequence, with a
    ``DeprecationWarning``).  The plan applies to the shared scan — one
    schedule, one mode, one emission discipline for the bundle.  ``emit``
    resolves to ``"round"`` by default because the bundle state is as
    large as its largest member — per-chunk prefix emission (``"chunk"``)
    is only sensible when every member is small.  ``emit="kernel"``
    requires every member to publish ``kernel_cols`` and batches all of
    them into one ``ops.group_agg`` dispatch per round-slice (DESIGN.md
    §6).  ``spec.stop`` applies to the shared scan: with e.g.
    ``session.rel_width`` every member that publishes an estimator must
    converge before the bundle stops — the all-queries-converged rule.

    Returns: list of :class:`QueryResult`, one per input GLA, in order.
    """
    from repro.core.gla import GLABundle  # local: avoid import cycle at load

    qspec = QS.coerce_spec(specs, plan, caller="run_queries")
    if not qspec.is_multi:
        raise TypeError("run_queries() takes a sequence of GLAs — for a "
                        "single query use run_query()")
    glas = list(qspec.gla)
    # Resolve emit while the spec still knows it is multi-query, then
    # swap in the bundle (one tuple-of-states GLA) for execution.
    qspec = qspec.with_(emit=qspec.resolved_emit(), gla=GLABundle(glas))
    res = run_query(qspec, data, mesh=mesh, axis_name=axis_name)
    out = []
    for i in range(len(glas)):
        est = res.estimates[i] if res.estimates is not None else None
        snap = res.snapshots[i] if res.snapshots is not None else None
        out.append(QueryResult(res.final[i], snap, est,
                               res.d_total, res.d_local))
    return out


def audit_plan(gla, data, *, rounds: int = 8, schedule=None,
               emit: str = "chunk", mode: str = "async", lanes: int = 1,
               snapshots: bool = True, confidence: float = 0.95,
               mesh=None, axis_name: str = "data", checks=None,
               raise_on_failure: bool = False):
    """Certify a query plan against the compiled-program invariant catalog.

    Thin re-export of :func:`repro.analysis.audit.audit_plan` so callers
    holding an engine handle can audit without importing ``repro.analysis``
    themselves.  Args mirror :func:`run_query`; returns an
    ``AuditReport``.  No data is scanned by the default (static) checks.
    """
    from repro.analysis import audit as AU  # local: analysis is optional at load

    return AU.audit_plan(
        gla, data, rounds=rounds, schedule=schedule, emit=emit, mode=mode,
        lanes=lanes, snapshots=snapshots, confidence=confidence, mesh=mesh,
        axis_name=axis_name, checks=checks,
        raise_on_failure=raise_on_failure)
