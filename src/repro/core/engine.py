"""The PF-OLA execution engine — paper §3.2–§3.4, adapted to SPMD JAX.

Execution model (DESIGN.md §2):

  * a *partition* is the unit of data locality (a GLADE worker node).  In the
    vmapped path partitions are a leading array axis (used by tests/benchmarks
    on 1 CPU device); in the sharded path partitions are devices along the
    ``data`` mesh axis under ``jax.shard_map`` (used by the dry-run and real
    deployments).  Both paths run the *same* GLA and the same math.
  * within a partition, chunks are consumed by ``lax.scan`` — the analogue of
    DataPath work-units pulling chunks.  ``lanes > 1`` keeps several GLA
    states per partition (the paper's "list of GLA states bounded by the
    number of work units") and merges them on demand, which makes the
    associative-decomposability contract *observable* and testable.
  * a *snapshot* (partial-result request, paper §3.4) is the scan carry
    emitted at a round boundary.  The state already exists — emission adds no
    recompute and no extra data pass; this is the zero-overhead property,
    verified by benchmarks/overhead.py (wall time) and HLO cost analysis.
  * *stragglers / asynchrony*: a ``schedule`` gives each partition its own
    cumulative chunk-progress curve.  Async snapshots take each partition at
    its own progress (valid for the single estimator under global
    randomization); ``mode="sync"`` truncates every partition to the global
    minimum progress — the Wu et al. barrier — and, in the sharded path,
    pays a per-chunk collective, reproducing that estimator's overhead
    mechanistically.
  * node failure: ``alive`` masks partitions out of merging; see
    repro/dist/fault.py for the estimator-level consequences (paper §4.6).
"""
from __future__ import annotations

import functools
from typing import Any, NamedTuple, Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax

from repro.core.uda import GLA, Estimate

Pytree = Any


class QueryResult(NamedTuple):
    final: Any                    # gla.terminate(fully merged state)
    snapshots: Optional[Pytree]   # merged per-round states, leaves [R, ...]
    estimates: Optional[Estimate]  # per-round Estimate, leaves [R, ...]
    d_total: jnp.ndarray
    d_local: jnp.ndarray          # [P]


# ---------------------------------------------------------------------------
# helpers
# ---------------------------------------------------------------------------

def _stack_init(gla: GLA, lanes: int) -> Pytree:
    s = gla.init()
    if lanes == 1:
        return s
    return jax.tree.map(lambda x: jnp.broadcast_to(x, (lanes,) + x.shape), s)


def _fold_merge(merge, states: Pytree, n: int) -> Pytree:
    acc = jax.tree.map(lambda x: x[0], states)
    for i in range(1, n):
        acc = merge(acc, jax.tree.map(lambda x: x[i], states))
    return acc


def _accumulate_chunk(gla: GLA, states: Pytree, chunk: dict, lanes: int):
    """Advance lane states by one chunk; return (states, lane-merged view)."""
    if lanes == 1:
        st = gla.accumulate(states, chunk)
        return st, st
    lc = {k: v.reshape(lanes, -1) for k, v in chunk.items()}
    st = jax.vmap(gla.accumulate)(states, lc)
    return st, _fold_merge(gla.merge, st, lanes)


def uniform_schedule(num_partitions: int, num_chunks: int, rounds: int) -> np.ndarray:
    """Cumulative chunk boundaries [P, R+1]; round r covers [b[r], b[r+1])."""
    b = np.round(np.linspace(0, num_chunks, rounds + 1)).astype(np.int32)
    return np.broadcast_to(b, (num_partitions, rounds + 1)).copy()


def straggler_schedule(
    num_partitions: int, num_chunks: int, rounds: int, speeds, seed: int = 0
) -> np.ndarray:
    """Per-partition progress curves under heterogeneous speeds.

    ``speeds[p]`` is partition p's relative throughput; progress accrues
    proportionally with small multiplicative jitter, capped at num_chunks.
    Every partition eventually finishes (last round = full scan) so the query
    completes — stragglers only delay, as in the paper's asynchronous model.
    """
    rng = np.random.default_rng(seed)
    speeds = np.asarray(speeds, np.float64)
    base = num_chunks / speeds.max()
    sched = np.zeros((num_partitions, rounds + 1), np.int32)
    for p in range(num_partitions):
        jitter = rng.uniform(0.85, 1.15, rounds)
        inc = speeds[p] * base / rounds * jitter
        cum = np.minimum(np.cumsum(inc), num_chunks)
        sched[p, 1:] = np.round(cum).astype(np.int32)
    sched[:, -1] = num_chunks  # completion
    return sched


# ---------------------------------------------------------------------------
# per-partition scans
# ---------------------------------------------------------------------------

def _scan_prefix(gla: GLA, cols: dict, lanes: int):
    """Scan chunks emitting every prefix state (init prepended): [C+1, ...].

    Used when snapshots at *arbitrary* per-partition progress are needed
    (straggler schedules, sync truncation).  State must be small — the
    emission cost is O(C · |state|) HBM traffic, nothing else.
    """
    init = _stack_init(gla, lanes)
    init_view = _fold_merge(gla.merge, init, lanes) if lanes > 1 else init

    def body(st, chunk):
        st, view = _accumulate_chunk(gla, st, chunk, lanes)
        return st, view

    last, prefixes = lax.scan(body, init, cols)
    prefixes = jax.tree.map(
        lambda i, p: jnp.concatenate([i[None], p], axis=0), init_view, prefixes
    )
    final_view = jax.tree.map(lambda p: p[-1], prefixes)
    return final_view, prefixes


def _scan_rounds(gla: GLA, cols: dict, lanes: int, rounds: int):
    """Uniform-schedule fast path: emit state only at round boundaries.

    O(|state|·R) emission — usable for large-state GLAs (1M-group group-by).
    Requires C % rounds == 0.
    """
    C = cols["_mask"].shape[0]
    assert C % rounds == 0, f"uniform rounds path needs C%R==0, got {C}%{rounds}"
    per = C // rounds
    rcols = {k: v.reshape((rounds, per) + v.shape[1:]) for k, v in cols.items()}
    init = _stack_init(gla, lanes)

    def round_body(st, round_cols):
        def chunk_body(s, chunk):
            s, _ = _accumulate_chunk(gla, s, chunk, lanes)
            return s, None
        st, _ = lax.scan(chunk_body, st, round_cols)
        view = _fold_merge(gla.merge, st, lanes) if lanes > 1 else st
        return st, view

    last, views = lax.scan(round_body, init, rcols)
    final_view = _fold_merge(gla.merge, last, lanes) if lanes > 1 else last
    return final_view, views


def _scan_rounds_masked(gla: GLA, cols: dict, sched: jnp.ndarray, lanes: int):
    """Arbitrary-schedule path for large-state GLAs: O(R·C) masked scan.

    Round r re-scans all chunks with liveness mask (lo <= c < hi); correctness
    from the uda mask contract.  Emission is per-round.
    """
    C = cols["_mask"].shape[0]
    R = sched.shape[0] - 1
    init = _stack_init(gla, lanes)

    def round_body(st, r):
        lo, hi = sched[r], sched[r + 1]

        def chunk_body(carry, xs):
            s = carry
            c, chunk = xs
            live = ((c >= lo) & (c < hi)).astype(chunk["_mask"].dtype)
            chunk = dict(chunk)
            chunk["_mask"] = chunk["_mask"] * live
            s, _ = _accumulate_chunk(gla, s, chunk, lanes)
            return s, None

        st, _ = lax.scan(chunk_body, st, (jnp.arange(C), cols))
        view = _fold_merge(gla.merge, st, lanes) if lanes > 1 else st
        return st, view

    last, views = lax.scan(round_body, init, jnp.arange(R))
    final_view = _fold_merge(gla.merge, last, lanes) if lanes > 1 else last
    return final_view, views


# ---------------------------------------------------------------------------
# vmapped (partition-simulation) path
# ---------------------------------------------------------------------------

def _merge_over_partitions(gla: GLA, states: Pytree, alive: jnp.ndarray, merge):
    """Merge states with leading partition axis [P, ...] under an alive mask."""
    P = alive.shape[0]
    if gla.merge_is_additive:
        w = alive.astype(jnp.float32)
        return jax.tree.map(
            lambda x: jnp.tensordot(w.astype(x.dtype), x, axes=(0, 0)), states
        )
    if not bool(jnp.all(alive)):
        raise NotImplementedError("alive masks need merge_is_additive")
    return _fold_merge(merge, states, P)


@functools.partial(
    jax.jit, static_argnames=("gla", "mode", "emit", "lanes", "snapshots", "confidence")
)
def _run_vmapped(gla: GLA, shards: dict, sched: jnp.ndarray, alive: jnp.ndarray,
                 *, mode: str, emit: str, lanes: int, snapshots: bool,
                 confidence: float):
    P, C, L = shards["_mask"].shape
    R = sched.shape[1] - 1
    d_local = jnp.sum(shards["_mask"], axis=(1, 2))
    d_total = jnp.sum(d_local)

    if emit == "chunk":
        finals, prefixes = jax.vmap(lambda c: _scan_prefix(gla, c, lanes))(shards)
        if snapshots:
            if mode == "sync":
                idx = jnp.broadcast_to(jnp.min(sched[:, 1:], axis=0), (P, R))
            else:
                idx = sched[:, 1:]
            round_states = jax.vmap(
                lambda pref, ix: jax.tree.map(lambda x: x[ix], pref)
            )(prefixes, idx)  # [P, R, ...]
        else:
            round_states = None
    elif emit == "round":
        finals, round_states = jax.vmap(
            lambda c: _scan_rounds(gla, c, lanes, R)
        )(shards)
        if mode == "sync":
            raise NotImplementedError("sync mode requires emit='chunk'")
    elif emit == "round_masked":
        finals, round_states = jax.vmap(
            lambda c, s: _scan_rounds_masked(gla, c, s, lanes)
        )(shards, sched)
    else:
        raise ValueError(f"unknown emit: {emit}")

    # Final result: plain Merge across partitions, then Terminate.
    merged_final = _merge_over_partitions(gla, finals, alive, gla.merge)
    final = gla.terminate(merged_final)

    if not snapshots or round_states is None:
        return QueryResult(final, None, None, d_total, d_local)

    # EstimatorTerminate per (partition, round) with the partition's |D_i|,
    # then EstimatorMerge across partitions (paper §3.1: intra- then inter-).
    def et(p_states, dl):
        return jax.vmap(lambda s: gla.estimator_terminate(s, {"d_local": dl}))(p_states)

    terminated = jax.vmap(et)(round_states, d_local)          # [P, R, ...]
    merged = _merge_over_partitions(gla, terminated, alive, gla.estimator_merge)

    estimates = None
    if gla.estimate is not None:
        estimates = jax.vmap(
            lambda s: gla.estimate(s, confidence, {"d_total": d_total})
        )(merged)

    return QueryResult(final, merged, estimates, d_total, d_local)


# ---------------------------------------------------------------------------
# sharded (shard_map over the mesh data axis) path
# ---------------------------------------------------------------------------

def _run_sharded(gla: GLA, shards: dict, sched: jnp.ndarray, alive: jnp.ndarray,
                 *, mesh, axis_name: str, mode: str, emit: str, lanes: int,
                 snapshots: bool, confidence: float, sync_cost_model: bool = True):
    """Same math as _run_vmapped with partitions = devices on ``axis_name``.

    GLA states must be additive (all shipped GLAs are) so the cross-device
    EstimatorMerge is a single psum — the efficient aggregation-tree path.
    In ``mode="sync"`` a per-chunk psum of the progress counter models the
    Wu et al. per-item serialization; its cost is visible in wall time and in
    the HLO collective count (benchmarks/overhead.py).
    """
    assert gla.merge_is_additive, "sharded path requires additive merges"
    P = shards["_mask"].shape[0]
    R = sched.shape[1] - 1

    def worker(cols, sched_p, alive_p):
        cols = jax.tree.map(lambda x: x[0], cols)      # [1, C, L] -> [C, L]
        sched_p = sched_p[0]
        alive_p = alive_p[0].astype(jnp.float32)
        d_local = jnp.sum(cols["_mask"]) * alive_p
        d_total = lax.psum(d_local, axis_name)

        if mode == "sync" and sync_cost_model:
            # Per-chunk progress coordination: the barrier the paper's
            # synchronized competitor needs.  The psum'd counter feeds the
            # next iteration's carry so it cannot be DCE'd.
            def body(carry, chunk):
                st, prog = carry
                st, view = _accumulate_chunk(gla, st, chunk, lanes)
                prog = lax.psum(prog + 1.0, axis_name) / P
                return (st, prog), view
            init = (_stack_init(gla, lanes), jnp.zeros(()))
            (last, _), prefixes = lax.scan(body, init, cols)
            init_view = _stack_init(gla, lanes)
            if lanes > 1:
                init_view = _fold_merge(gla.merge, init_view, lanes)
                last = _fold_merge(gla.merge, last, lanes)
            prefixes = jax.tree.map(
                lambda i, p: jnp.concatenate([i[None], p], 0), init_view, prefixes)
            final_view = last
        elif emit == "chunk":
            final_view, prefixes = _scan_prefix(gla, cols, lanes)
        elif emit == "round":
            final_view, round_states = _scan_rounds(gla, cols, lanes, R)
            prefixes = None
        else:
            raise ValueError(emit)

        if emit == "chunk" or mode == "sync":
            if mode == "sync":
                gmin = lax.pmin(sched_p[1:], axis_name)
                idx = gmin
            else:
                idx = sched_p[1:]
            round_states = jax.tree.map(lambda x: x[idx], prefixes)

        # weight by aliveness, then psum == EstimatorMerge over the tree
        def wz(x):
            return x * alive_p.astype(x.dtype)

        merged_final = lax.psum(jax.tree.map(wz, final_view), axis_name)
        if snapshots:
            term = jax.vmap(
                lambda s: gla.estimator_terminate(s, {"d_local": d_local})
            )(round_states)
            merged_rounds = lax.psum(jax.tree.map(wz, term), axis_name)
        else:
            merged_rounds = None
        return merged_final, merged_rounds, d_total, d_local[None]

    from jax.sharding import PartitionSpec as PS
    pspec = PS(axis_name)
    out_specs = (PS(), PS(), PS(), PS(axis_name))
    fn = jax.shard_map(
        worker, mesh=mesh,
        in_specs=(pspec, pspec, pspec),
        out_specs=out_specs,
        check_vma=False,  # carry starts replicated (gla.init) and becomes
                          # device-varying after the first accumulate
    )
    sched_arr = jnp.asarray(sched)
    merged_final, merged_rounds, d_total, d_local = fn(shards, sched_arr, alive)
    final = gla.terminate(merged_final)
    estimates = None
    if snapshots and gla.estimate is not None:
        estimates = jax.vmap(
            lambda s: gla.estimate(s, confidence, {"d_total": d_total})
        )(merged_rounds)
    return QueryResult(final, merged_rounds, estimates, d_total, d_local)


# ---------------------------------------------------------------------------
# public API
# ---------------------------------------------------------------------------

def run_query(
    gla: GLA,
    shards: dict,
    *,
    rounds: int = 8,
    schedule: Optional[np.ndarray] = None,
    confidence: float = 0.95,
    mode: str = "async",
    emit: str = "chunk",
    lanes: int = 1,
    snapshots: bool = True,
    alive: Optional[np.ndarray] = None,
    mesh=None,
    axis_name: str = "data",
) -> QueryResult:
    """Execute a GLA query with on-line estimation.

    Args:
      gla: the UDA bundle (repro.core.gla constructors or custom).
      shards: columnar dict, leaves [P, C, L], must include "_mask".
      rounds: number of snapshot points (ignored if ``schedule`` given).
      schedule: cumulative chunk boundaries [P, R+1] (engine.*_schedule).
      mode: "async" (paper's estimator) or "sync" (Wu et al. barrier).
      emit: "chunk" (prefix states; small-state GLAs, any schedule),
            "round" (uniform schedule fast path, large states), or
            "round_masked" (any schedule, large states, O(R·C)).
      lanes: parallel GLA states per partition (DataPath work-unit analogue).
      snapshots: False = non-interactive mode (overhead baseline).
      alive: bool [P] — node-failure mask (paper §4.6).
      mesh: if given, run under shard_map with partitions on ``axis_name``.
    """
    P, C, L = shards["_mask"].shape
    if schedule is None:
        schedule = uniform_schedule(P, C, rounds)
    sched = jnp.asarray(schedule, jnp.int32)
    alive_arr = jnp.ones((P,), bool) if alive is None else jnp.asarray(alive, bool)

    if mesh is None:
        return _run_vmapped(
            gla, shards, sched, alive_arr, mode=mode, emit=emit, lanes=lanes,
            snapshots=snapshots, confidence=confidence,
        )
    return _run_sharded(
        gla, shards, sched, alive_arr, mesh=mesh, axis_name=axis_name,
        mode=mode, emit=emit, lanes=lanes, snapshots=snapshots,
        confidence=confidence,
    )
