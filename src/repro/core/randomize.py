"""Data randomization — paper §4.2.

On-line aggregation needs samples; PF-OLA's choice (shared with DBO/CONTROL)
is to store data in random order so a *sequential scan* yields a
without-replacement sample prefix.  The single-estimator model additionally
needs **global** randomization: any prefix of any union of partition scans
must be a uniform sample of the whole dataset.

Two implementations:

  * :func:`randomize_global` — reference: one global permutation, then split
    into partitions.  Used as the statistical oracle in tests.
  * :func:`randomize_distributed` — the paper's two-stage parallel algorithm:
    (1) each partition assigns every local item an independent uniform target
    partition (random hash on a per-item random value — NOT on item content),
    then items are exchanged (the all-to-all "shuffle"); (2) each partition
    sorts its received items by fresh per-item random keys (a local random
    permutation), which "separates items received from the same origin".

Both operate on columnar dicts.  The distributed variant keeps per-partition
cardinalities ragged (as in a real shuffle); :func:`pack_partitions` pads to a
rectangular [P, n_max] layout with a ``_mask`` column, which is what the
engine consumes.
"""
from __future__ import annotations

from typing import Dict, List

import jax
import jax.numpy as jnp
import numpy as np

Columns = Dict[str, jnp.ndarray]


def randomize_global(cols: Columns, key, num_partitions: int) -> List[Columns]:
    """Reference: global permutation, then round-robin split into partitions."""
    n = next(iter(cols.values())).shape[0]
    perm = jax.random.permutation(key, n)
    shuffled = {k: v[perm] for k, v in cols.items()}
    # Contiguous split (equal sizes up to remainder).
    bounds = np.linspace(0, n, num_partitions + 1).astype(int)
    return [
        {k: v[bounds[i]:bounds[i + 1]] for k, v in shuffled.items()}
        for i in range(num_partitions)
    ]


def randomize_distributed(
    parts: List[Columns], key, num_partitions: int | None = None
) -> List[Columns]:
    """Paper §4.2 two-stage algorithm over already-partitioned data.

    Stage 1: for each local item draw an independent uniform target partition
    (the "random hash of a random value"); exchange.  Stage 2: per-partition
    random permutation via sort on fresh random keys.  Runs on host numpy —
    this is the *load-time* path (the paper folds it into data loading).
    """
    num_partitions = num_partitions or len(parts)
    keys = jax.random.split(key, 2 * len(parts) + num_partitions)
    # Stage 1: draw targets and scatter.
    buckets: List[Dict[str, list]] = [
        {k: [] for k in parts[0]} for _ in range(num_partitions)
    ]
    for i, p in enumerate(parts):
        n_i = next(iter(p.values())).shape[0]
        tgt = np.asarray(jax.random.randint(keys[i], (n_i,), 0, num_partitions))
        for k, v in p.items():
            v = np.asarray(v)
            for j in range(num_partitions):
                buckets[j][k].append(v[tgt == j])
    out: List[Columns] = []
    dtypes = {k: np.asarray(v).dtype for k, v in parts[0].items()}
    for j in range(num_partitions):
        # Empty buckets (a partition that received no rows) must keep the
        # source dtype: a bare np.zeros((0,)) would silently promote int32
        # columns (shipdate, rfls, suppkey) to float64 downstream.
        cat = {k: (np.concatenate(vs) if vs
                   else np.zeros((0,), dtypes[k])).astype(dtypes[k],
                                                          copy=False)
               for k, vs in buckets[j].items()}
        n_j = next(iter(cat.values())).shape[0]
        # Stage 2: fresh random keys -> sort = local random permutation.
        # (Reusing origin-node random values is NOT valid — paper §4.2.)
        rk = np.asarray(jax.random.uniform(keys[len(parts) + j], (n_j,)))
        order = np.argsort(rk)
        out.append({k: jnp.asarray(v[order]) for k, v in cat.items()})
    return out


def pack_partitions(
    parts: List[Columns], chunk_len: int, *, min_chunks: int | None = None
) -> Columns:
    """Pad ragged partitions to [P, C, L] chunked columns with a _mask.

    The engine consumes this layout.  ``_mask`` marks live items; padded
    slots never contribute to any GLA state (uda.Chunk contract).
    """
    P = len(parts)
    ns = [next(iter(p.values())).shape[0] for p in parts]
    C = max(-(-n // chunk_len) for n in ns)  # ceil
    if min_chunks is not None:
        C = max(C, min_chunks)
    total = C * chunk_len
    out: Dict[str, np.ndarray] = {}
    names = list(parts[0].keys())
    for k in names:
        buf = np.zeros((P, total), dtype=np.asarray(parts[0][k]).dtype)
        for i, p in enumerate(parts):
            v = np.asarray(p[k])
            buf[i, : v.shape[0]] = v
        out[k] = jnp.asarray(buf.reshape(P, C, chunk_len))
    mask = np.zeros((P, total), dtype=np.float32)
    for i, n in enumerate(ns):
        mask[i, :n] = 1.0
    out["_mask"] = jnp.asarray(mask.reshape(P, C, chunk_len))
    return out
