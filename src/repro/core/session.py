"""Interactive OLA sessions — the paper's headline user feature, §1/§3.4.

PF-OLA's promise is that "the user can stop the computation as soon as the
estimate is accurate enough, typically early in the execution".  The classic
engine entry points (``engine.run_query``/``run_queries``) execute every
round of every chunk inside one fused program and only then hand back
snapshots — confidence bounds can never actually save work.  This module is
the missing code path: an **incremental round driver** that advances the
scan one round-slice at a time and evaluates pluggable **stopping rules**
between rounds, so a query over N rounds that converges at round k pays
only k/N of the scan.

Execution disciplines (DESIGN.md §7):

  * *fused* — no stopping rule attached and the session is driven straight
    to completion: one whole-scan program, byte-for-byte the classic
    ``run_query`` path (``run_query`` itself is now a thin wrapper over a
    fused session).
  * *incremental* — a stopping rule is attached, or the caller advances the
    session manually with :meth:`Session.step`.  Each step jits ONE
    round-slice (``scan.scan_round_step`` / ``scan.fused_round_step`` /
    the legacy ``scan.ROUND_DELTA_FNS`` primitives — the same
    per-round-slice primitives the fused paths fold over all rounds), then
    merges that round's states across partitions and produces the round's
    :class:`Estimate`.  The chunk-sequential accumulation order is
    identical to the fused program, so round-boundary states and finals
    are bitwise-identical across disciplines on every path — scan,
    ``kernel_fused`` (scalar included), and the legacy group/bundle
    kernels (tests/test_session.py, tests/test_fused_kernel.py).

Incremental stepping works on **both** engines — the vmapped path here and
the ``shard_map`` path (``repro.dist.shard_engine.session_step_sharded``)
— and requires ``mode="async"`` with a partition-uniform schedule (the
default): the synchronized barrier and per-partition straggler schedules
are whole-scan semantics and stay on the fused discipline.

Composed Deep OLA plans (DESIGN.md §13) need nothing special here: a
``QuerySpec`` built from a ``PlanNode`` tree arrives already lowered to a
GLA, join GLAs carry their probe tables inside their fused contract (the
``kernel_fused`` path ships them as extra Pallas operands), and nested
estimators (GROUP BY + HAVING, ``gla.compose``) only wrap ``estimate`` —
states, checkpoints and stop rules are the inner plan's verbatim.  Stop
rules over nested plans see the *outer* bounds, which can widen
transiently when the HAVING predicate flips a group; pair them with
``estimators.monotone_envelope`` post-hoc for monotone UI bounds.

Sessions pause and resume across processes: :meth:`Session.pause`
serializes the per-partition round states plus the scan cursor through
``repro.checkpoint.ckpt`` and :meth:`Session.resume` continues from the
exact round boundary — resumed sessions produce bitwise-identical finals
to uninterrupted ones (the carry is restored bit-exactly and the remaining
round-slices replay the same program).  The checkpoint meta carries the
data source's **content fingerprint** (DESIGN.md §8), so resuming against
different data — even same-shape data — raises instead of silently
producing wrong finals.

Data arrives either as a resident ``[P, C, L]`` shards dict (the classic
path, wrapped in a ``repro.data.source.InMemorySource``) or as any other
:class:`repro.data.source.ChunkSource` (``NpyMmapSource``,
``ParquetSource``): streaming sources are scanned **out-of-core** — each
:meth:`Session.step` pulls one round-slice through a double-buffered
host→device prefetcher (`jax.device_put` of slice r+1 overlaps round r's
compute), so peak device footprint is O(slice), not O(dataset), and the
engine scales past accelerator RAM.  Streaming runs the incremental
discipline by definition (there is nothing resident for a fused
whole-scan program to close over), which is exactly why it stays
bitwise-identical to the fused in-memory path on the scan and
group/bundle kernel paths.
"""
from __future__ import annotations

import functools
import time
from concurrent.futures import ThreadPoolExecutor
from typing import Any, Callable, List, Mapping, NamedTuple, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import ckpt
from repro.core import engine as EN
from repro.core import scan as SC
from repro.core import spec as QS
from repro.core.uda import GLA, Estimate
from repro.data import source as DSRC

Pytree = Any

# v3 adds per-partition cursors, the runtime failure record and the fault
# estimator family to the meta (elastic resume + §4.6 runtime semantics,
# DESIGN.md §9); v2 envelopes stay readable — their fields are a subset.
_CKPT_VERSION = 3
_READABLE_VERSIONS = (2, _CKPT_VERSION)


# ---------------------------------------------------------------------------
# stopping rules
# ---------------------------------------------------------------------------

class RoundProgress(NamedTuple):
    """What a stopping rule sees after each round.

    ``estimates`` is the round's :class:`repro.core.uda.Estimate` — a tuple
    with one entry per member (``None`` for members without an estimation
    model) when the session runs a ``GLABundle``, or ``None`` when the GLA
    publishes no estimator at all.
    """

    round: int          # rounds completed so far (1-based)
    rounds_total: int
    estimates: Any
    scanned: float      # tuples scanned so far across all partitions
    d_total: float
    elapsed_s: float    # driver wall time, accumulated across pause/resume


StoppingRule = Callable[[RoundProgress], bool]


def _per_estimate(estimates, pred) -> bool:
    """True when ``pred`` holds for every available member estimate.

    ``None`` (no estimation model anywhere) can never attest convergence.
    For bundles, members without an estimator are skipped — the members
    that do estimate decide (this IS the all-queries-converged rule for
    ``GLABundle`` sessions).
    """
    if estimates is None:
        return False
    # a bundle's estimates are a plain tuple of per-member Estimates;
    # Estimate itself is a NamedTuple, so check for it first
    members = ((estimates,) if isinstance(estimates, Estimate)
               else tuple(estimates))
    present = [e for e in members if e is not None]
    if not present:
        return False
    return all(pred(e) for e in present)


def _half_widths(est) -> np.ndarray:
    lo = np.asarray(est.lower, np.float64)
    hi = np.asarray(est.upper, np.float64)
    return (hi - lo) / 2.0


def rel_width(eps: float, *, min_rounds: int = 1) -> StoppingRule:
    """Stop once every aggregate's CI half-width ≤ ``eps`` · |estimate|.

    The reduction is a max over all aggregates (and groups): every entry
    must converge.  Entries with zero half-width (e.g. empty groups, whose
    variance estimate is exactly 0) count as converged; infinite half-widths
    (the |S| ≤ 1 variance clamp in early rounds) never do — an undefined
    variance cannot trigger a premature stop.
    """
    def converged(e):
        half = _half_widths(e)
        mid = np.abs(np.asarray(e.estimate, np.float64))
        rel = np.where(half == 0.0, 0.0, half / np.maximum(mid, 1e-300))
        return bool(rel.size == 0 or np.max(rel) <= eps)

    def rule(prog: RoundProgress) -> bool:
        if prog.round < min_rounds:
            return False
        return _per_estimate(prog.estimates, converged)

    return rule


def abs_width(limit: float, *, min_rounds: int = 1) -> StoppingRule:
    """Stop once every aggregate's CI half-width ≤ ``limit`` (absolute)."""
    def converged(e):
        half = _half_widths(e)
        return bool(half.size == 0 or np.max(half) <= limit)

    def rule(prog: RoundProgress) -> bool:
        if prog.round < min_rounds:
            return False
        return _per_estimate(prog.estimates, converged)

    return rule


def budget(*, max_seconds: Optional[float] = None,
           max_tuples: Optional[float] = None,
           max_rounds: Optional[int] = None) -> StoppingRule:
    """Stop when any resource budget is exhausted, converged or not.

    ``max_seconds`` counts driver wall time accumulated across
    pause/resume; ``max_tuples`` counts scanned tuples across partitions.
    """
    def rule(prog: RoundProgress) -> bool:
        if max_seconds is not None and prog.elapsed_s >= max_seconds:
            return True
        if max_tuples is not None and prog.scanned >= max_tuples:
            return True
        if max_rounds is not None and prog.round >= max_rounds:
            return True
        return False

    return rule


def any_of(*rules: StoppingRule) -> StoppingRule:
    """Stop when ANY rule fires (e.g. converged OR out of time budget)."""
    return lambda prog: any(r(prog) for r in rules)


def all_of(*rules: StoppingRule) -> StoppingRule:
    """Stop only when EVERY rule fires."""
    return lambda prog: all(r(prog) for r in rules)


# ---------------------------------------------------------------------------
# runtime failure handling (paper §4.6 live, DESIGN.md §9)
# ---------------------------------------------------------------------------

class FaultPolicy:
    """Make mid-scan partition loss survivable instead of fatal.

    Attach to a :class:`Session` (``fault=FaultPolicy(...)``) and failures
    degrade the answer instead of crashing the driver.  They arrive two
    ways:

      * *injected* — ``fail_at`` maps partition -> failure round, the
        ``repro.dist.fault.failure_schedule`` convention: ``fail_at[p] ==
        0`` is dead from the start, and partition p's state (everything it
        accumulated) is excluded from every merge from round ``fail_at[p]``
        on.
      * *detected* — a streaming source read raises
        :class:`repro.data.source.PartitionLostError` (chaos wrapper
        ``repro.dist.fault.FailingSource``, or a real storage/device
        error); the session records the current round as that partition's
        failure round and retries the read against the survivors.

    ``estimator`` names the estimation model the GLA was built with — the
    bound handling after a failure depends on it, not on the state
    (``repro.dist.fault`` module docstring spells out why):

      * ``single`` — survives: the alive-mask-weighted merge IS the
        renormalization (Horvitz–Thompson over the surviving uniform
        sample), and the variance floor — |S| is capped below |D|, so the
        (|D|-|S|) factor in Eq. (4) never vanishes — keeps bounds finite
        and honest.
      * ``multiple`` — poisoned: bounds are (-inf, +inf) from the failure
        round on.
      * ``synchronized`` — frozen: estimates stall at the last pre-failure
        round (infinite bounds if the failure precedes the first round).

    Excluding dead partitions is a weighted merge, so the session requires
    ``gla.merge_is_additive``.
    """

    _ESTIMATORS = ("single", "multiple", "synchronized")

    def __init__(self, estimator: str = "single", *,
                 fail_at: Optional[Mapping[int, int]] = None):
        if estimator not in self._ESTIMATORS:
            raise ValueError(
                f"unknown estimator model {estimator!r}; expected one of "
                f"{self._ESTIMATORS}")
        self.estimator = estimator
        self.fail_at = {int(p): int(r) for p, r in (fail_at or {}).items()}
        for p, r in self.fail_at.items():
            if p < 0 or r < 0:
                raise ValueError(
                    f"fail_at maps partition -> failure round, both >= 0; "
                    f"got {{{p}: {r}}}")


def _map_member_ests(fn, est):
    """Apply ``fn`` to an Estimate, member-wise for a bundle's tuple
    (members without an estimation model pass through as None)."""
    if est is None:
        return None
    if isinstance(est, Estimate):
        return fn(est)
    return tuple(None if e is None else fn(e) for e in est)


# ---------------------------------------------------------------------------
# per-round jitted step (vmapped engine); the sharded twin lives in
# repro/dist/shard_engine.py next to the other shard_map programs.
# ---------------------------------------------------------------------------

@functools.partial(
    jax.jit, static_argnames=("gla", "path", "lanes", "confidence",
                              "all_alive", "first", "encodings")
)
def _step_vmapped(gla: GLA, states, slice_shards: dict, w_r: jnp.ndarray,
                  d_local: jnp.ndarray, d_total: jnp.ndarray, *, path: str,
                  lanes: int, confidence: float, all_alive: bool,
                  first: bool, encodings: tuple = ()):
    """Advance one round-slice on the vmapped engine.

    Returns (new per-partition states, per-partition round views, merged
    round state, round Estimate-or-None).  ``first`` matters only on the
    legacy kernel paths: the running sum starts from the first delta (not
    zero + delta), matching ``scan._fold_running_sum`` bit-for-bit; the
    carry-style ``"kernel_fused"`` path needs no first split.
    ``encodings`` is the source's static (name, Encoding) tuple: the fused
    path decodes inside the kernel, every other path decodes the physical
    slice generically before accumulating (same ``decode_block`` math, so
    results stay bitwise-identical to the plain source).
    """
    if encodings and path != "kernel_fused":
        from repro.data import encodings as ENC  # local: core stays data-free
        slice_shards = ENC.decode_cols(slice_shards, encodings)
    if path == "scan":
        new_states, views = jax.vmap(
            lambda st, c: SC.scan_round_step(gla, st, c, lanes)
        )(states, slice_shards)
    elif path == "kernel_fused":
        P = slice_shards["_mask"].shape[0]
        # carry-style: the per-partition state rides into the kernel; no
        # first/add split.  Unrolled over partitions for the same reason
        # as scan._unroll_partitions: Pallas calls stay out of vmap/scan.
        outs = [
            SC.fused_round_step(
                gla, jax.tree.map(lambda x, p=p: x[p], states),
                jax.tree.map(lambda x, p=p: x[p], slice_shards), encodings)
            for p in range(P)
        ]
        new_states = jax.tree.map(lambda *xs: jnp.stack(xs), *outs)
        views = new_states
    else:
        delta_fn = SC.ROUND_DELTA_FNS[path]
        P = slice_shards["_mask"].shape[0]
        # unrolled over partitions for the same reason as
        # scan._unroll_partitions: Pallas calls stay out of vmap/scan.
        deltas = [delta_fn(gla, jax.tree.map(lambda x, p=p: x[p],
                                             slice_shards))
                  for p in range(P)]
        delta = jax.tree.map(lambda *xs: jnp.stack(xs), *deltas)
        new_states = delta if first else jax.tree.map(jnp.add, states, delta)
        views = new_states

    term = jax.vmap(
        lambda s, dl: gla.estimator_terminate(s, {"d_local": dl})
    )(views, d_local)
    merged = EN._merge_rounds(
        gla, jax.tree.map(lambda x: x[:, None], term), w_r[:, None],
        gla.estimator_merge, all_alive)
    merged = jax.tree.map(lambda x: x[0], merged)
    est = None
    if gla.estimate is not None:
        est = gla.estimate(merged, confidence, {"d_total": d_total})
    return new_states, views, merged, est


@functools.partial(jax.jit, static_argnames=("gla", "all_alive"))
def _final_vmapped(gla: GLA, views, w_final: jnp.ndarray, *, all_alive: bool):
    merged = EN._merge_over_partitions(gla, views, w_final, gla.merge,
                                       all_alive)
    return gla.terminate(merged)


# ---------------------------------------------------------------------------
# host -> device slice prefetch (streaming sources, DESIGN.md §8)
# ---------------------------------------------------------------------------

class _SlicePrefetcher:
    """Double-buffered host→device pipeline for streaming sources.

    One worker thread reads round-slice r+1 from the source and
    ``device_put``s it while the main thread's round-r compute runs;
    :meth:`get` hands over the ready buffer and immediately schedules the
    next fetch.  Depth 1 == double buffering: at most two slices are alive
    on device at once, so steady-state device footprint is O(slice) and
    the scan never stalls on I/O once warmed.
    """

    def __init__(self, source: DSRC.ChunkSource, bounds, put):
        self._source = source
        self._bounds = list(bounds)   # [(lo, hi)] per round
        self._put = put               # host cols dict -> device arrays
        self._ex = ThreadPoolExecutor(max_workers=1)
        self._fut = None
        self._next_r = None

    def _fetch(self, r: int):
        lo, hi = self._bounds[r]
        return self._put(self._source.slice_cols(lo, hi))

    def get(self, r: int):
        """Device buffers for round r; kicks off the fetch of round r+1
        before blocking, so (with the single worker) slice r+1 transfers
        while round r's jitted step runs."""
        if self._fut is not None and self._next_r == r:
            fut = self._fut
        else:
            fut = self._ex.submit(self._fetch, r)
        if r + 1 < len(self._bounds):
            self._fut, self._next_r = self._ex.submit(self._fetch, r + 1), r + 1
        else:
            self._fut = self._next_r = None
        return fut.result()

    def close(self) -> None:
        """Drop the pending buffer and retire the worker thread (sessions
        close the prefetcher when they finish, converge, or pause — a
        long-lived process must not accumulate one idle thread and one
        captured device slice per completed session).  Waits for an
        in-flight fetch: pause() reads the source from the main thread
        right after closing (fingerprint sampling), and e.g. pyarrow file
        handles are not safe to read from two threads at once."""
        self._fut = self._next_r = None
        self._ex.shutdown(wait=True, cancel_futures=True)


# ---------------------------------------------------------------------------
# the session
# ---------------------------------------------------------------------------

class Session:
    """A long-lived OLA query: advance round by round, stop early, pause.

    ``data`` is a resident ``[P, C, L]`` shards dict or any
    :class:`repro.data.source.ChunkSource`; streaming sources
    (``NpyMmapSource``/``ParquetSource``) are scanned out-of-core through
    the double-buffered prefetcher — O(slice) device footprint — and
    always run the incremental discipline (DESIGN.md §8).

    The plan arrives as a :class:`repro.core.spec.QuerySpec` (canonical) or
    a bare GLA; the old loose plan kwargs still work with a
    ``DeprecationWarning``.  Engine location (``mesh``/``axis_name``) and
    ``audit`` stay per-call arguments.  Construction validates exactly like
    :func:`repro.core.engine.run_query` (same emit/kernel contracts, same
    round-degrade policy).  Drive it with

      * :meth:`run` — to convergence (``stop`` rule) or completion.  With no
        stopping rule and no prior :meth:`step`, this executes the fused
        whole-scan program — byte-for-byte the classic engine path.
      * :meth:`step` — one round-slice; returns the :class:`RoundProgress`
        the stopping rule saw.  Requires an incrementally-steppable config:
        ``mode="async"`` with a partition-uniform schedule and no
        failure-injection ``alive`` schedule.
      * :meth:`result` — :class:`engine.QueryResult` over the rounds
        executed so far (early-stopped sessions report the partial-scan
        final, i.e. the best current answer).
      * :meth:`pause` / :meth:`resume` — checkpoint between rounds and
        continue later, bitwise-identically, even in another process.
    """

    def __init__(self, spec, data, *, mesh=None, axis_name: str = "data",
                 audit=None, **plan):
        qspec = QS.coerce_spec(spec, plan, caller="Session")
        source = DSRC.as_source(data)
        qspec = EN.normalize_plan(qspec, source)
        self.spec = qspec  # the resolved plan, for introspection
        gla: GLA = qspec.gla
        rounds, schedule, emit = qspec.rounds, qspec.schedule, qspec.emit
        stop, mode, lanes = qspec.stop, qspec.mode, qspec.lanes
        snapshots, confidence = qspec.snapshots, qspec.confidence
        alive, fault = qspec.alive, qspec.resolved_fault()
        sync_cost_model = qspec.sync_cost_model
        self._gla = gla
        self._source = source
        self._resident = source.resident
        self._shards = source.shards if source.resident else None
        self._sched = np.asarray(schedule, np.int32)
        self._rounds = self._sched.shape[1] - 1
        self._stop = stop
        self._confidence = float(confidence)
        self._mode = mode
        self._emit = emit
        self._lanes = lanes
        self._snapshots = snapshots
        self._mesh = mesh
        self._axis_name = axis_name
        self._sync_cost_model = sync_cost_model
        P, C, L = source.spec.P, source.spec.C, source.spec.L
        self._P, self._C, self._L = P, C, L

        alive_np = None if alive is None else np.asarray(alive)
        self._alive = alive_np
        self._all_alive = alive_np is None or bool(np.all(alive_np))
        alive_arr = (jnp.ones((P,), bool) if alive_np is None
                     else jnp.asarray(alive_np, bool))
        self._alive_arr = alive_arr
        self._w_pr = self._w_final = None  # lazy, with the stats below

        self._policy = fault
        self._fail_at = {} if fault is None else dict(fault.fail_at)
        self._prefail_est = None  # last all-alive round's Estimate
        if fault is not None:
            if alive_np is not None:
                raise ValueError(
                    "pass failures either as a static alive mask or "
                    "through a FaultPolicy, not both")
            if not gla.merge_is_additive:
                raise ValueError(
                    "FaultPolicy needs additive merges: excluding dead "
                    "partitions is a weighted merge, which non-additive "
                    "GLAs cannot honor")
            for p in self._fail_at:
                if p >= P:
                    raise ValueError(
                        f"FaultPolicy.fail_at names partition {p}, but "
                        f"the data has P={P}")

        uniform = bool(np.all(self._sched == self._sched[0]))
        self._incremental_ok = (
            mode == "async" and uniform
            and (alive_np is None or alive_np.ndim == 1))
        if stop is not None and not self._incremental_ok:
            raise ValueError(
                "stopping rules need an incrementally-steppable session: "
                "mode='async' with a partition-uniform schedule and no "
                "[R, P] failure-injection alive mask (sync barriers and "
                "straggler schedules are whole-scan semantics)")
        if not self._resident and not self._incremental_ok:
            raise ValueError(
                "streaming sources scan incrementally and need an "
                "incrementally-steppable config: mode='async' with a "
                "partition-uniform schedule and no [R, P] alive schedule "
                "(whole-scan semantics require resident shards)")

        if emit == "kernel":
            if lanes != 1:
                raise ValueError("emit='kernel' runs single-lane")
            # the fused kernel (DESIGN.md §12) subsumes the legacy kernel
            # paths whenever the GLA publishes a FusedSpec and every column
            # is kernel-shaped; it is carry-style and bitwise-identical to
            # the scan path, scalar GLAs included.
            if SC.fused_available(gla, self._source.spec.columns):
                self._path = "kernel_fused"
            else:
                self._path = ("kernel_bundle" if gla.members
                              else "kernel_group" if gla.kernel_num_groups
                              is not None else "kernel_scalar")
        else:
            self._path = "scan"
        # encoded sources (data/encodings.py) ship physical columns; the
        # fused path decodes them in-kernel, every other path decodes the
        # slice before accumulating (_step_vmapped / session_step_sharded).
        self._encodings = tuple(getattr(self._source, "encodings", ()) or ())

        # d_local/d_total, merge weights and the per-chunk scanned-tuple
        # prefix are only consumed by the incremental discipline; computed
        # lazily on the first step() so a fused-only session (every classic
        # run_query call, possibly itself under jit) pays nothing for them
        # — the fused program derives its own copies internally.
        self._d_local = self._d_total = None
        self._mask_cum: Optional[np.ndarray] = None
        self._prefetch: Optional[_SlicePrefetcher] = None

        self._states: Optional[Pytree] = None
        self._views: Optional[Pytree] = None
        self._merged: List[Pytree] = []
        self._ests: List[Any] = []
        self._steps = 0
        self._elapsed = 0.0
        self._converged = False
        self._fused = False
        self._result: Optional[EN.QueryResult] = None

        # audit=True certifies the plan against the static invariant
        # catalog before the first byte is scanned (audit=("name", ...)
        # selects checks); any failure raises AuditError here, in the
        # constructor, so a bad plan never runs.  The report is kept on
        # ``self.audit_report`` for callers that want the pass details.
        self.audit_report = None
        if audit:
            from repro.analysis import audit as AU
            self.audit_report = AU.audit_plan(
                gla, source, rounds=rounds, schedule=self._sched,
                emit=emit, mode=mode, lanes=lanes, snapshots=snapshots,
                confidence=self._confidence, mesh=mesh,
                axis_name=axis_name,
                checks=None if audit is True else tuple(audit),
                raise_on_failure=True)

    # -- introspection -------------------------------------------------------

    @property
    def steps_taken(self) -> int:
        """Round-slices executed so far (the k in 'pays only k/N')."""
        return self._steps

    @property
    def rounds_total(self) -> int:
        return self._rounds

    @property
    def converged(self) -> bool:
        """True once the stopping rule has fired."""
        return self._converged

    @property
    def done(self) -> bool:
        return (self._converged or self._steps >= self._rounds
                or self._result is not None)

    @property
    def elapsed_s(self) -> float:
        return self._elapsed

    # -- the incremental driver ----------------------------------------------

    def _init_states(self) -> Pytree:
        base = (SC.stack_init(self._gla, self._lanes)
                if self._path == "scan" else self._gla.init())
        return jax.tree.map(
            lambda x: jnp.broadcast_to(x, (self._P, *x.shape)), base)

    def _ensure_stats(self) -> None:
        if self._d_local is None:
            # Per-(partition, chunk) live-tuple counts come from the source
            # (host float64, exact for integer counts) — not from a resident
            # _mask array — so progress accounting and budget(max_tuples)
            # work without whole-dataset residency.  Counts are integers, so
            # the f32 casts match the device-side jnp.sum the fused program
            # computes, bit-for-bit, up to 2**24 tuples per reduction.
            ms = self._source.mask_chunk_sums()
            self._d_local = jnp.asarray(ms.sum(axis=1), jnp.float32)
            self._d_total = jnp.asarray(ms.sum(), jnp.float32)
            self._w_pr, self._w_final = SC.round_weights(
                self._alive_arr, self._rounds)

    def _slice_shards(self, r: int, lo: int, hi: int):
        """Round-r slice as device-consumable arrays.

        Resident sources keep the classic lazy device-array slicing;
        streaming sources go through the double-buffered prefetcher (the
        mesh path places each partition's block on its device via
        ``shard_engine.device_put_slice``)."""
        if self._resident:
            return {k: v[:, lo:hi] for k, v in self._shards.items()}
        if self._prefetch is None:
            if self._mesh is None:
                put = jax.device_put
            else:
                from repro.dist import shard_engine
                put = functools.partial(shard_engine.device_put_slice,
                                        mesh=self._mesh,
                                        axis_name=self._axis_name)
            bounds = [(int(self._sched[0, i]), int(self._sched[0, i + 1]))
                      for i in range(self._rounds)]
            self._prefetch = _SlicePrefetcher(self._source, bounds, put)
        return self._prefetch.get(r)

    def _close_prefetch(self) -> None:
        if self._prefetch is not None:
            self._prefetch.close()
            self._prefetch = None

    # -- runtime failure bookkeeping (FaultPolicy, DESIGN.md §9) -------------

    def _record_failure(self, p: int, r: int) -> None:
        if not 0 <= p < self._P:
            raise ValueError(
                f"source reported lost partition {p}, but the data has "
                f"P={self._P}")
        # first failure round wins: a partition cannot die twice, and a
        # retried read re-reporting the same loss must not move the round
        self._fail_at.setdefault(int(p), int(r))

    def _alive_now(self, r: int) -> np.ndarray:
        """[P] bool — partition p contributes to round r's merge iff it has
        not failed at or before r (``failure_schedule`` convention)."""
        a = np.ones(self._P, bool)
        for p, fr in self._fail_at.items():
            if fr <= r:
                a[p] = False
        return a

    def _first_fail_round(self) -> Optional[int]:
        return min(self._fail_at.values()) if self._fail_at else None

    def _fetch_slice(self, r: int, lo: int, hi: int):
        """Round-r slice, surviving partition loss when a policy is
        attached: a :class:`repro.data.source.PartitionLostError` records
        the newly-dead partitions at this round and the read retries
        against the survivors (the source serves them zeroed from then
        on).  Bounded by P+1 attempts — each retry must name at least one
        new partition, so the loop cannot spin."""
        for _ in range(self._P + 1):
            try:
                return self._slice_shards(r, lo, hi)
            except DSRC.PartitionLostError as e:
                if self._policy is None:
                    raise
                for p in e.partitions:
                    self._record_failure(p, r)
        raise RuntimeError(
            f"source kept losing partitions at round {r} — more loss "
            f"reports than partitions")

    def _apply_policy_est(self, est, r: int):
        """Per-round §4.6 estimator consequences.  ``single`` passes
        through (the alive-weighted merge already renormalized, and the
        variance floor keeps bounds finite); ``multiple`` poisons bounds
        from the failure round on; ``synchronized`` freezes at the last
        pre-failure round (infinite bounds when nothing preceded it)."""
        fr = self._first_fail_round()
        if fr is None or r < fr:
            self._prefail_est = est
            return est
        if self._policy is None or self._policy.estimator == "single":
            return est
        from repro.dist import fault  # local: fault imports engine
        if self._policy.estimator == "multiple":
            return _map_member_ests(fault.poison_bounds, est)
        if self._prefail_est is None:  # failed before the first round
            return _map_member_ests(fault.poison_bounds, est)
        return self._prefail_est

    def step(self) -> RoundProgress:
        """Advance one round-slice; evaluate the stopping rule; return what
        it saw.  Raises on configs that cannot step incrementally."""
        if self._result is not None:
            raise RuntimeError("session already ran to completion")
        if not self._incremental_ok:
            raise ValueError(
                "this session cannot step incrementally (sync mode, "
                "non-uniform schedule, or [R, P] alive schedule) — use "
                "run(), which executes the fused whole-scan program")
        if self.done:
            raise RuntimeError("session is done; call result()")
        t0 = time.perf_counter()
        self._ensure_stats()
        r = self._steps
        lo, hi = int(self._sched[0, r]), int(self._sched[0, r + 1])
        slice_shards = self._fetch_slice(r, lo, hi)
        first = self._path not in ("scan", "kernel_fused") and r == 0
        states = self._states
        if states is None:
            states = self._init_states()
        w_r = self._w_pr[:, r]
        all_alive = self._all_alive
        if self._fail_at:
            alive_now = self._alive_now(r)
            if not alive_now.all():
                # dead partitions drop out of this round's merge; their
                # carry keeps stepping (harmless — weight 0 forever after)
                w_r = w_r * jnp.asarray(alive_now, jnp.float32)
                all_alive = False
        if self._mesh is None:
            new_states, views, merged, est = _step_vmapped(
                self._gla, states, slice_shards, w_r, self._d_local,
                self._d_total, path=self._path, lanes=self._lanes,
                confidence=self._confidence, all_alive=all_alive,
                first=first, encodings=self._encodings)
        else:
            from repro.dist import shard_engine
            new_states, views, merged, est = shard_engine.session_step_sharded(
                self._gla, states, slice_shards, w_r, self._d_local,
                self._d_total, mesh=self._mesh, axis_name=self._axis_name,
                path=self._path, lanes=self._lanes,
                confidence=self._confidence, first=first,
                encodings=self._encodings)
        if self._policy is not None:
            est = self._apply_policy_est(est, r)
        self._states, self._views = new_states, views
        if self._snapshots:
            # snapshots off = non-interactive mode: the round's merged
            # state and estimate still exist transiently (stop rules read
            # ``est`` from RoundProgress) but no per-round history is
            # retained — O(state), not O(rounds x state), matching the
            # fused program's snapshots=False semantics
            self._merged.append(merged)
            self._ests.append(est)
        self._steps += 1
        if self._mask_cum is None:
            # per-slice mask sums folded on the host (source-provided, no
            # whole-dataset residency) — feeds scanned/budget(max_tuples)
            self._mask_cum = np.cumsum(self._source.mask_chunk_sums(), axis=1)
        scanned = float(self._mask_cum[:, hi - 1].sum()) if hi else 0.0
        self._elapsed += time.perf_counter() - t0
        prog = RoundProgress(
            round=self._steps, rounds_total=self._rounds, estimates=est,
            scanned=scanned, d_total=float(self._d_total),
            elapsed_s=self._elapsed)
        if self._stop is not None and self._stop(prog):
            self._converged = True
        if self.done:
            self._close_prefetch()
        return prog

    def run(self) -> EN.QueryResult:
        """Drive to convergence or completion and return the result.

        Resident sources with no stopping rule execute the fused
        whole-scan program; streaming sources always run the incremental
        discipline (one prefetched round-slice on device at a time)."""
        if self._result is not None:
            return self._result
        if self._resident and self._steps == 0 and (
                self._stop is None or not self._incremental_ok):
            t0 = time.perf_counter()
            self._fused = True
            alive_arr, all_alive = self._alive_arr, self._all_alive
            if self._fail_at:
                # injected failures on the fused program: ship the policy
                # as an [R, P] schedule, exactly the dist.fault path
                from repro.dist import fault
                alive_arr = jnp.asarray(fault.failure_schedule(
                    self._P, self._rounds, self._fail_at))
                all_alive = False
            self._result = EN._execute_full(
                self._gla, self._shards, jnp.asarray(self._sched),
                alive_arr, mode=self._mode, emit=self._emit,
                lanes=self._lanes, snapshots=self._snapshots,
                confidence=self._confidence, all_alive=all_alive,
                mesh=self._mesh, axis_name=self._axis_name,
                sync_cost_model=self._sync_cost_model)
            if self._fail_at and self._result.estimates is not None:
                from repro.dist import fault
                fr = self._first_fail_round()
                post = {"multiple": lambda e: fault._poison(e, fr),
                        "synchronized": lambda e: fault._stall(e, fr)}.get(
                            self._policy.estimator)
                if post is not None and fr < self._rounds:
                    self._result = self._result._replace(
                        estimates=_map_member_ests(
                            post, self._result.estimates))
            self._elapsed += time.perf_counter() - t0
            self._steps = self._rounds
            return self._result
        while not self.done:
            self.step()
        return self.result()

    def result(self) -> EN.QueryResult:
        """QueryResult over the rounds executed so far.

        ``final`` is Terminate(Merge of the current per-partition states) —
        the full-scan answer when the session completed.  For an
        early-stopped session it is the raw partial aggregate over the
        scanned prefix (Terminate does not extrapolate); the anytime
        *answer* is the last round's ``estimates`` entry, whose CI is what
        the stopping rule certified.  ``snapshots``/``estimates`` stack the
        executed rounds, leaves ``[steps_taken, ...]``.
        """
        if self._result is not None:
            return self._result
        if self._steps == 0:
            raise RuntimeError("no rounds executed yet — step() or run()")
        w_final, all_alive = self._w_final, self._all_alive
        if self._fail_at:
            alive_now = self._alive_now(self._steps - 1)
            if not alive_now.all():
                # the final is over surviving partitions' data only — a
                # dead partition's carry (data it scanned before dying)
                # is lost with it, per the §4.6 failure model
                w_final = w_final * jnp.asarray(alive_now, jnp.float32)
                all_alive = False
        if self._mesh is None:
            final = _final_vmapped(self._gla, self._views, w_final,
                                   all_alive=all_alive)
        else:
            from repro.dist import shard_engine
            final = shard_engine.session_final_sharded(
                self._gla, self._views, w_final, mesh=self._mesh,
                axis_name=self._axis_name)
        snaps = ests = None
        if self._merged:
            snaps = jax.tree.map(lambda *xs: jnp.stack(xs), *self._merged)
        if self._ests and self._ests[0] is not None:
            ests = jax.tree.map(lambda *xs: jnp.stack(xs), *self._ests)
        res = EN.QueryResult(final, snaps, ests, self._d_total, self._d_local)
        if self.done:
            self._result = res
        return res

    # -- pause / resume ------------------------------------------------------

    def _meta(self) -> dict:
        return {
            "version": _CKPT_VERSION, "gla": self._gla.name,
            "rounds": self._rounds, "steps": self._steps,
            "emit": self._emit, "mode": self._mode, "lanes": self._lanes,
            "snapshots": self._snapshots,
            "confidence": self._confidence, "path": self._path,
            "P": self._P, "C": self._C, "L": self._L,
            # the scan cursor is only meaningful against the exact same
            # round boundaries and liveness weights, so both round-trip
            "schedule": self._sched.tolist(),
            "alive": (None if self._alive is None
                      else np.asarray(self._alive, int).tolist()),
            # v3 (DESIGN.md §9): per-partition scan cursors (chunk index
            # each partition has consumed up to — the elastic resume
            # re-derives these for a new partition count), the runtime
            # failure record as [partition, round] pairs (msgpack maps
            # cannot key on ints), and the fault estimator family
            "cursors": [int(self._sched[p, self._steps])
                        for p in range(self._P)],
            "fail_at": sorted([int(p), int(r)]
                              for p, r in self._fail_at.items()),
            "fault_estimator": (None if self._policy is None
                                else self._policy.estimator),
            "elapsed_s": self._elapsed, "converged": self._converged,
            # content fingerprint (DESIGN.md §8): resume refuses different
            # data, including same-shape impostors
            "source": self._source.spec.meta(),
            "fingerprint": self._source.fingerprint(),
        }

    def _payload_like(self, steps: int) -> dict:
        """Shape/structure skeleton of the checkpoint payload, rebuilt from
        the session config so deserialization never needs live state.  The
        vmapped step's output structure is identical to the sharded one
        (global shapes), so one eval_shape serves both engines.  The slice
        skeleton comes from the source's chunk spec, never from resident
        arrays — deserialization works for streaming sources too."""
        self._ensure_stats()
        per0 = max(1, int(self._sched[0, 1] - self._sched[0, 0]))
        # physical slice shapes: encoded sources ship packed columns
        slice_like = self._source.step_slice_like(per0)
        states_like = jax.eval_shape(self._init_states)
        st, views, merged, est = _step_vmapped.eval_shape(
            self._gla, states_like, slice_like,
            jax.ShapeDtypeStruct((self._P,), jnp.float32),
            jax.ShapeDtypeStruct(self._d_local.shape, self._d_local.dtype),
            jax.ShapeDtypeStruct(self._d_total.shape, self._d_total.dtype),
            path=self._path, lanes=self._lanes,
            confidence=self._confidence, all_alive=self._all_alive,
            first=self._path not in ("scan", "kernel_fused"),
            encodings=self._encodings)
        hist = steps if self._snapshots else 0  # no history retained
        return {"states": st, "views": views,
                "merged": (merged,) * hist, "ests": (est,) * hist}

    def pause(self, path) -> None:
        """Checkpoint the session between rounds (Serialize, paper Table 1).

        Stores the per-partition scan carry, per-round merged states and
        estimates, and the scan cursor.  Resume with :meth:`Session.resume`
        — in this process or another — and drive on: the remaining rounds
        replay the exact program, so finals are bitwise-identical to an
        uninterrupted session.
        """
        if self._fused:
            raise RuntimeError(
                "session ran the fused whole-scan program — there is no "
                "incremental carry to pause; attach a stopping rule or "
                "step() to run incrementally")
        self._close_prefetch()  # paused sessions hold no worker thread
        blob = b""
        if self._steps:
            payload = {"states": self._states, "views": self._views,
                       "merged": tuple(self._merged),
                       "ests": tuple(self._ests)}
            blob = ckpt.serialize_state(payload)
        ckpt.save_envelope(path, self._meta(), blob)

    @classmethod
    def resume(cls, path, gla: GLA, data, *,
               stop: Optional[StoppingRule] = None,
               partitions: Optional[int] = None,
               fault: Optional[FaultPolicy] = None, mesh=None,
               axis_name: str = "data") -> "Session":
        """Rebuild a paused session from ``path`` + the original gla/data.

        The checkpoint stores configuration and state but not code or data:
        the caller supplies the same GLA and the same dataset — as a shards
        dict or any ChunkSource; the **content fingerprint** stored at
        pause time is re-derived from the supplied source and must match,
        so resuming against different data (even same-shape data, which
        would silently produce wrong finals) raises ``ValueError``.  The
        check is best-effort by design — per-chunk tuple counts plus
        strided column samples, not a full-content hash (repro.data.source
        docstring spells out what escapes it).  The fingerprint is
        storage-independent: a session paused over in-memory shards
        resumes over an ``.npy``/parquet copy of the same rows.  ``stop``
        is attached fresh — rules are closures and do not serialize.

        Every plan mismatch (gla name, shape, rounds, estimator family,
        data content) raises a ``ValueError`` naming the field *before any
        device work* — never a shape error from deep inside
        ``deserialize_state``.

        **Elastic resume** (DESIGN.md §9): ``partitions=P'`` continues the
        scan on a different partition count — P'|P merges carries
        (round-robin chunk interleave, ``scan.merge_carries``), P|P'
        splits them (``scan.split_carries``) — so a checkpoint taken on an
        8-way mesh resumes on a 4-way one, or vice versa.  Requires an
        all-alive checkpoint with a partition-uniform schedule; finals
        match the uninterrupted run up to merge-association order
        (bitwise for count-like monoids).

        A v3 checkpoint carries the runtime failure record and estimator
        family; ``fault`` overrides/extends the restored policy (it must
        agree on the estimator family).  ``synchronized`` sessions restore
        the frozen estimate from the snapshot history; with
        ``snapshots=False`` there is no history and post-failure rounds
        degrade to infinite bounds.
        """
        meta, blob = ckpt.load_envelope(path)
        ckpt.require_version(meta, _READABLE_VERSIONS,
                             what="session checkpoint")

        # -- validate the supplied plan against the envelope BEFORE any
        # session construction or device work, naming the field
        src = DSRC.as_source(data)
        if meta["gla"] != gla.name:
            raise ValueError(
                f"checkpoint mismatch: gla was {meta['gla']!r} at pause "
                f"time, got {gla.name!r} now")
        if meta["L"] != src.spec.L:
            raise ValueError(
                f"checkpoint mismatch: L was {meta['L']!r} at pause "
                f"time, got {src.spec.L!r} now")
        if src.spec.P != int(meta["P"]):
            # the dataset may arrive in its original layout while the
            # session was paused on an elastic view of it (or vice versa):
            # re-wrap to the pause-time layout when the counts are
            # repartition-compatible, else name the field
            try:
                src = DSRC.repartition(src, int(meta["P"]))
            except ValueError as err:
                raise ValueError(
                    f"checkpoint mismatch: P was {meta['P']!r} at pause "
                    f"time, got {src.spec.P!r} now ({err})") from None
        if meta["C"] != src.spec.C:
            raise ValueError(
                f"checkpoint mismatch: C was {meta['C']!r} at pause "
                f"time, got {src.spec.C!r} now")
        sched = np.asarray(meta["schedule"], np.int32)
        if (sched.ndim != 2 or sched.shape[0] != meta["P"]
                or meta["rounds"] != sched.shape[1] - 1
                or not 0 <= meta["steps"] <= meta["rounds"]):
            raise ValueError(
                f"checkpoint mismatch: rounds {meta['rounds']!r} / steps "
                f"{meta['steps']!r} do not agree with the stored "
                f"{list(sched.shape)}-shaped schedule")
        # fingerprint on the ORIGINAL layout — it hashes the chunk spec,
        # so it must be checked before any repartitioning view wraps src
        if meta["fingerprint"] != src.fingerprint():
            raise ValueError(
                "checkpoint mismatch: data content fingerprint differs — "
                "the supplied shards/source do not hold the data this "
                "session was paused over (same shapes are not enough; "
                "resuming would silently produce wrong finals)")

        # -- rehydrate the fault record (v2 envelopes: no failures, no
        # policy); a caller-supplied policy must agree on the family
        rec_fail = {int(p): int(r) for p, r in (meta.get("fail_at") or [])}
        rec_est = meta.get("fault_estimator")
        if (fault is not None and rec_est is not None
                and fault.estimator != rec_est):
            raise ValueError(
                f"checkpoint mismatch: fault estimator family was "
                f"{rec_est!r} at pause time, got {fault.estimator!r} now")
        if fault is None and rec_est is not None:
            fault = FaultPolicy(rec_est, fail_at=rec_fail)
        elif fault is not None and rec_fail:
            merged_at = dict(fault.fail_at)
            for p, r in rec_fail.items():
                merged_at[p] = min(r, merged_at.get(p, r))
            fault = FaultPolicy(fault.estimator, fail_at=merged_at)

        alive = (None if meta["alive"] is None
                 else np.asarray(meta["alive"], bool))

        # -- elastic resume: re-derive source view + schedule for P'
        P_old = int(meta["P"])
        factor, split = 1, False
        if partitions is not None and int(partitions) != P_old:
            P_new = int(partitions)
            if alive is not None or rec_fail:
                raise ValueError(
                    "elastic resume requires an all-alive checkpoint: "
                    "dead partitions' carries are lost and cannot be "
                    "merged or split into a new layout")
            bounds = sched[0]
            if not np.all(sched == bounds):
                raise ValueError(
                    "elastic resume requires a partition-uniform schedule")
            src = DSRC.repartition(src, P_new)  # validates divisibility
            if P_new <= P_old:
                factor, split = P_old // P_new, False
                bounds = bounds * factor
            else:
                factor, split = P_new // P_old, True
                if np.any(bounds % factor):
                    raise ValueError(
                        f"cannot split {P_old} -> {P_new} partitions: "
                        f"round boundaries {bounds.tolist()} are not all "
                        f"divisible by {factor}")
                bounds = bounds // factor
            sched = np.broadcast_to(
                bounds, (P_new, bounds.size)).astype(np.int32)

        sess = cls(
            QS.QuerySpec(
                gla, rounds=int(sched.shape[1] - 1), stop=stop,
                schedule=sched, alive=alive, fault=fault,
                confidence=meta["confidence"], sync=meta["mode"] == "sync",
                emit=meta["emit"], lanes=meta["lanes"],
                snapshots=meta["snapshots"]),
            src, mesh=mesh, axis_name=axis_name)
        if meta["steps"]:
            payload = ckpt.deserialize_state(
                blob, like=sess._payload_like(meta["steps"]))
            states, views = payload["states"], payload["views"]
            if factor > 1:
                xform = SC.split_carries if split else SC.merge_carries
                states = xform(states, factor)
                views = xform(views, factor)
            if mesh is not None:
                from repro.dist import shard_engine
                states = shard_engine.device_put_carry(
                    states, mesh=mesh, axis_name=axis_name)
                views = shard_engine.device_put_carry(
                    views, mesh=mesh, axis_name=axis_name)
            sess._states, sess._views = states, views
            # merged/est history is partition-independent (already merged
            # over P) — restored as-is under any elastic relayout
            sess._merged = list(payload["merged"])
            sess._ests = list(payload["ests"])
            if sess._ests:
                sess._prefail_est = sess._ests[-1]
        sess._steps = meta["steps"]
        sess._elapsed = meta["elapsed_s"]
        sess._converged = meta["converged"]
        return sess
