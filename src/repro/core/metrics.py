"""On-line aggregation over model computations — the PF-OLA ↔ LM bridge.

The paper's query (1) is  SUM(func(d)) WHERE cond(d).  Substituting
``func(d) = loss(params, d)`` makes *dataset-level evaluation* an on-line
aggregation problem: stream eval batches through the model, keep the
(sum, sumSq, count) GLA state, and report an anytime estimate of the
full-corpus loss with confidence bounds — stopping early once the bounds
are tight.  ``cond`` becomes a data-selection predicate (domain, length
bucket, ...), and per-group statistics are the paper's query (5).

These constructors return standard GLAs executed by repro.core.engine —
the estimation machinery is identical to the TPC-H path; only ``func``
changed.  That is the paper's expressiveness claim, demonstrated on a
neural workload.
"""
from __future__ import annotations

from typing import Callable, Optional

import jax.numpy as jnp

from repro.core.gla import make_groupby_gla, make_sum_gla
from repro.core.uda import GLA, Chunk


def make_loss_gla(
    loss_per_example: Callable[[Chunk], jnp.ndarray],
    *,
    d_total: float,
    cond: Optional[Callable[[Chunk], jnp.ndarray]] = None,
    estimator: str = "single",
    dtype=jnp.float32,
) -> GLA:
    """GLA whose func is a per-example model loss.

    ``loss_per_example(chunk) -> [n]`` runs the model forward on the chunk's
    examples (the chunk carries token arrays).  The mean loss over the
    predicate-selected subset is SUM/COUNT — both estimated simultaneously
    by stacking two aggregates (func and the constant-1 function), exactly
    the paper's AVERAGE construction (§4.3).
    """
    def func2(chunk):
        lpe = loss_per_example(chunk)
        return jnp.stack([lpe, jnp.ones_like(lpe)], axis=-1)

    c = cond if cond is not None else (
        lambda chunk: jnp.ones_like(chunk["_mask"]))
    return make_sum_gla(func2, c, d_total=d_total, estimator=estimator,
                        dtype=dtype, num_aggs=2).with_(name="loss-gla")


def mean_with_bounds(est) -> tuple:
    """Turn the 2-agg (sum, count) Estimate into a mean ± half-width.

    Ratio-estimator bounds via first-order delta method: the count estimate
    is near-exact relative to the loss spread, so half-width(mean) ≈
    half-width(sum)/count_estimate.  Exact at full scan (variance 0).
    """
    import numpy as np
    est_sum, est_cnt = np.asarray(est.estimate).T
    lo_sum = np.asarray(est.lower).T[0]
    hi_sum = np.asarray(est.upper).T[0]
    cnt = np.maximum(est_cnt, 1.0)
    mean = est_sum / cnt
    half = (hi_sum - lo_sum) / 2.0 / cnt
    return mean, mean - half, mean + half


def make_groupwise_loss_gla(
    loss_per_example: Callable[[Chunk], jnp.ndarray],
    group: Callable[[Chunk], jnp.ndarray],
    *,
    num_groups: int,
    d_total: float,
    estimator: str = "single",
) -> GLA:
    """Per-domain / per-bucket loss statistics with simultaneous bounds —
    paper query (5) with func = loss."""

    def func2(chunk):
        lpe = loss_per_example(chunk)
        return jnp.stack([lpe, jnp.ones_like(lpe)], axis=-1)

    def cond(chunk):
        return jnp.ones_like(chunk["_mask"])

    return make_groupby_gla(func2, cond, group, num_groups=num_groups,
                            d_total=d_total, estimator=estimator,
                            num_aggs=2).with_(name="groupwise-loss-gla")
