"""Extended UDA (User-Defined Aggregate) interface — paper Table 1.

A GLA (Generalized Linear Aggregate) is an associative-decomposable UDA: the
order in which ``accumulate`` and ``merge`` are invoked does not change the
final result.  In JAX a GLA is a bundle of *pure functions* over a pytree
state; the engine (``repro.core.engine``) owns every parallel-execution
detail, exactly as in the paper.

Interface mapping (paper Table 1 → this module):

    Init                -> GLA.init()
    Accumulate(Item d)  -> GLA.accumulate(state, chunk)    [chunk-vectorized]
    Merge(in1,in2,out)  -> GLA.merge(s1, s2) -> s
    Terminate           -> GLA.terminate(state)
    Serialize           -> repro.checkpoint.serialize_state(state)
    Deserialize         -> repro.checkpoint.deserialize_state(buf, like=state)
    EstimatorTerminate  -> GLA.estimator_terminate(state)  [intra-node]
    EstimatorMerge      -> GLA.estimator_merge(s1, s2)     [inter-node]
    Estimate            -> GLA.estimate(state, confidence) -> Estimate

``accumulate`` is vectorized over a *chunk* — a dict of equal-length column
arrays.  Every chunk carries a ``_mask`` column (float/bool, 1 = live item);
masked items MUST NOT contribute to the state.  This is how the engine
implements ragged tails and per-partition straggler schedules without
dynamic shapes.

Whenever a method is missing, it does not change the UDA state (paper §3.1):
``estimator_terminate`` defaults to identity and ``estimator_merge`` defaults
to ``merge``.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, NamedTuple, Optional

Chunk = dict  # column name -> [chunk_len] array; always includes "_mask"
State = Any  # arbitrary pytree


class Estimate(NamedTuple):
    """Result of GLA.estimate — estimator with confidence bounds."""

    estimate: Any
    lower: Any
    upper: Any
    # Auxiliary diagnostics (variance estimate, sample fraction, ...)
    info: Any = None


class ProbeTable:
    """A small device-resident side table threaded into the fused kernel.

    Join probe arrays (dimension-table group ids, validity masks) cannot be
    captured by a Pallas kernel body as closure constants — they must enter
    ``pallas_call`` as explicit operands.  A ProbeTable wraps the array with
    a process-unique ``key``; the fused kernel injects the array into the
    in-kernel column dict under that key (docs/KERNELS.md rule 9), so FusedSpec
    closures gather from ``chunk[pt.key]`` exactly as the scan path gathers
    from the closed-over array — identical expression trees, bitwise results.

    Identity semantics on purpose: the GLA holding this spec is a *static*
    jit argument, so ProbeTable keeps ``object.__hash__`` / ``__eq__``
    (arrays are unhashable; value-hashing would defeat jit caching anyway).
    """

    _ids = 0

    def __init__(self, name: str, values):
        ProbeTable._ids += 1
        self.name = name
        self.values = values
        self.key = f"__probe{ProbeTable._ids}_{name}"

    @property
    def nbytes(self) -> int:
        v = self.values
        return int(v.size) * int(v.dtype.itemsize)

    def __repr__(self):  # pragma: no cover - debugging aid
        v = self.values
        return f"ProbeTable({self.name}, shape={tuple(v.shape)}, {v.dtype})"


class FusedSpec(NamedTuple):
    """Contract for the fused selection→bucket→aggregate Pallas kernel
    (``repro.kernels.fused_agg``, DESIGN.md §12, docs/KERNELS.md).

    Unlike ``kernel_cols`` — which projects (vals, weight[, gids]) *outside*
    the kernel — these closures run over the raw column dict *inside* the
    kernel body, after any in-kernel column decode, so predicate
    evaluation, hash-bucketing and the f32 accumulation share one VMEM
    residency per round-slice:

      func:  chunk -> [n] or [n, num_aggs] values (any float dtype; the
             kernel accumulates in f32)
      cond:  chunk -> [n] 0/1 predicate (bare — the kernel fuses ``_mask``)
      group: chunk -> [n] int32 dense group ids in [0, num_groups), already
             hash-bucketed (``gla.hash_bucket``); None selects the scalar
             SumState contract
      num_aggs:   A (padded to a multiple of 8 inside the kernel)
      num_groups: G (padded to a multiple of 128), or None for scalar
      probe_tables: ProbeTables threaded into the kernel as extra operands;
             closures read them via ``chunk[pt.key]``.  Their combined bytes
             are checked against the kernel's VMEM probe budget by
             ``fused_agg.fused_available`` (oversized joins fall back to the
             legacy ``kernel_cols`` path).
    """

    func: Callable[[Chunk], Any]
    cond: Callable[[Chunk], Any]
    group: Optional[Callable[[Chunk], Any]]
    num_aggs: int
    num_groups: Optional[int] = None
    probe_tables: tuple = ()


def _identity(state: State, ctx: Optional[dict] = None) -> State:
    """Default EstimatorTerminate: the state is its own partial aggregate.

    ``ctx`` carries per-partition execution facts the engine knows and the
    GLA cannot (paper §4.6 "dataset information"): ``d_local`` = |D_i| of the
    partition this state was accumulated on, ``d_total`` = |D|.
    """
    return state


@dataclasses.dataclass(frozen=True)
class GLA:
    """An associative-decomposable UDA with the extended (estimation) interface.

    Attributes:
      init: () -> state.
      accumulate: (state, chunk) -> state.  Chunk-vectorized; must honor
        ``chunk["_mask"]``.
      merge: (s1, s2) -> s.  Must be associative and commutative — this is
        the GLA contract that makes asynchronous tree/ring aggregation legal,
        and it is property-tested in tests/test_estimators.py.
      terminate: (state) -> final result.
      estimator_terminate: intra-node partial-aggregate finalization
        (paper §3.1 third extension).  Identity by default.
      estimator_merge: inter-node partial-aggregate merge.  Defaults to
        ``merge``.
      estimate: (state, confidence) -> Estimate, or None for GLAs with no
        estimation model attached.
      merge_is_additive: True when ``merge`` is elementwise addition over all
        state leaves.  The engine then lowers cross-device merging to a single
        ``psum`` (ring all-reduce) instead of gather+fold — the efficient path
        the paper gets from its aggregation tree.
      kernel_cols: optional column projection enabling the fused-kernel
        dispatch (engine ``emit="kernel"``, DESIGN.md §3).  Only meaningful
        for GLAs whose state is a float32 ``estimators.SumState`` (directly
        or per group) with additive merge.  Two contracts, selected by
        ``kernel_num_groups``:
        * scalar (``kernel_num_groups is None``): ``chunk -> (vals, weight)``.
          The Pallas kernel computes per-chunk (sum, sumsq, scanned, matched)
          partials for a whole shard in one launch and the engine prefix-sums
          them into the same states ``accumulate`` would have produced
          (``scan.kernel_prefix_states``).
        * group-by: ``chunk -> (vals, weight, gids)`` with
          ``kernel_num_groups`` set to the dense group-table size G.  Dense
          [G, A] states make per-chunk prefixes memory-infeasible, so the
          engine dispatches ``kernels.ops.group_agg`` once per *round-slice*
          (``scan.kernel_rounds_states``), composing with the ``emit="round"``
          emission discipline (uniform schedules, C % R == 0).
        In both contracts ``weight`` is the bare predicate — the engine fuses
        ``chunk["_mask"]`` itself.
      kernel_num_groups: dense group-table size for the group-by kernel
        contract; None selects the scalar SumState contract.
      members: non-empty only for bundle GLAs (``repro.core.gla.GLABundle``):
        the member GLAs whose states this GLA stacks into one tuple pytree.
        The engine uses it to (a) recognize bundles when validating
        ``emit="kernel"`` (the bundle itself publishes no ``kernel_cols`` —
        each member does) and (b) unbundle per-query results after the
        shared scan (``engine.run_queries``).
    """

    init: Callable[[], State]
    accumulate: Callable[[State, Chunk], State]
    merge: Callable[[State, State], State]
    terminate: Callable[[State], Any]
    estimator_terminate: Callable[[State, Optional[dict]], State] = _identity
    estimator_merge: Optional[Callable[[State, State], State]] = None
    estimate: Optional[Callable[..., Estimate]] = None
    merge_is_additive: bool = False
    kernel_cols: Optional[Callable[[Chunk], Any]] = None
    kernel_num_groups: Optional[int] = None
    # fused-kernel contract (FusedSpec): published alongside kernel_cols by
    # the gla.py constructors when the state is a f32 SumState (scalar or
    # dense-group).  When set, ``emit="kernel"`` plans run the one-dispatch
    # fused Pallas kernel (kernels/fused_agg.py) instead of the legacy
    # project-then-aggregate kernels, and encoded sources decode in-kernel.
    fused: Optional[FusedSpec] = None
    members: tuple = ()
    name: str = "gla"

    def __post_init__(self):
        if self.estimator_merge is None:
            object.__setattr__(self, "estimator_merge", self.merge)

    # -- convenience ---------------------------------------------------------
    def with_(self, **kw) -> "GLA":
        return dataclasses.replace(self, **kw)


def masked(cond: Any, chunk: Chunk) -> Any:
    """Combine a selection predicate with the chunk liveness mask."""
    return cond * chunk["_mask"]
