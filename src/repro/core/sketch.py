"""Sketch-backed GLAs — COUNT DISTINCT, quantiles, heavy hitters.

The PF-OLA thesis is that the GLA interface abstracts *any*
associative-decomposable aggregate; sketches make that concrete: each
sketch is "just" a new merge monoid behind the same
Init/Accumulate/Merge/Estimate surface, so it composes for free with
bundles, sessions, streaming sources, checkpoints, and (when the monoid
is additive) the sharded mesh engine.

Three monoids (DESIGN.md §13):

  * :func:`make_count_distinct_gla` — HLL-style leading-zero registers.
    Merge is elementwise **max** — associative/commutative/idempotent but
    NOT additive, so this GLA runs on the vmapped engine only
    (``dist.run_sharded`` lowers merges to a single psum and asserts
    ``merge_is_additive``; a max-monoid mesh reduction is future work).
  * :func:`make_quantile_gla` — fixed-bin histogram CDF with
    Dvoretzky–Kiefer–Wolfowitz bands.  Additive: runs everywhere.
  * :func:`make_heavy_hitters_gla` — count-min sketch over a candidate id
    set, Horvitz–Thompson-scaled with the CM overcount bound.  Additive.

Estimation semantics under OLA: each sketch summarizes the rows *scanned
so far*; estimates converge to the exact full-data answer as the scan
completes.  COUNT DISTINCT is a lower-bound-style estimator mid-scan
(distinct values not yet scanned cannot be extrapolated without species
assumptions); its interval covers sketch error, not sampling error — the
info dict says how much of the data backs it.
"""
from __future__ import annotations

from typing import Callable, NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.core import estimators as E
from repro.core.gla import _BUCKET_MULT
from repro.core.uda import GLA, Chunk, Estimate


def _mix32(x: jnp.ndarray) -> jnp.ndarray:
    """32-bit finalizer (xorshift-multiply) over uint32 keys."""
    h = x.astype(jnp.uint32) * jnp.uint32(_BUCKET_MULT)
    h = h ^ (h >> 15)
    h = h * jnp.uint32(0x2C1B3C6D)
    h = h ^ (h >> 12)
    return h


# ---------------------------------------------------------------------------
# COUNT DISTINCT — HLL-style max-merge registers
# ---------------------------------------------------------------------------

class HLLState(NamedTuple):
    registers: jnp.ndarray  # [m] f32 max leading-zero ranks
    scanned: jnp.ndarray    # |S| live rows folded in


def make_count_distinct_gla(
    key: Callable[[Chunk], jnp.ndarray],
    *,
    d_total: float,
    log2m: int = 12,
    cond: Optional[Callable[[Chunk], jnp.ndarray]] = None,
) -> GLA:
    """COUNT(DISTINCT key(d)) [WHERE cond(d)] via 2**log2m HLL registers.

    Registers hold the max rank (leading-zero run + 1) of hashed keys per
    bucket; merge is elementwise max, so duplicate keys — within a chunk,
    across chunks, across partitions — collapse idempotently.  Standard
    error is ~1.04/sqrt(m) relative (Flajolet et al.), reported as a
    normal interval around the bias-corrected estimate with the
    linear-counting small-range correction.
    """
    m = 1 << log2m
    alpha = 0.7213 / (1.0 + 1.079 / m)  # bias correction, m >= 128

    def init():
        return HLLState(registers=jnp.zeros((m,), jnp.float32),
                        scanned=jnp.zeros((), jnp.float32))

    def accumulate(state: HLLState, chunk: Chunk) -> HLLState:
        w = chunk["_mask"]
        if cond is not None:
            w = cond(chunk) * w
        h = _mix32(key(chunk))
        bucket = (h & jnp.uint32(m - 1)).astype(jnp.int32)
        rest = h >> jnp.uint32(log2m)
        rank = jnp.where(
            rest == 0,
            jnp.float32(32 - log2m + 1),
            jax.lax.clz(rest.astype(jnp.int32)).astype(jnp.float32)
            - jnp.float32(log2m) + 1.0)
        rank = rank * w.astype(jnp.float32)  # dead rows rank 0 = no-op
        regs = jnp.maximum(
            state.registers,
            jax.ops.segment_max(rank, bucket, num_segments=m))
        return HLLState(
            registers=regs,
            scanned=state.scanned + jnp.sum(chunk["_mask"].astype(jnp.float32)))

    def merge(a: HLLState, b: HLLState) -> HLLState:
        return HLLState(registers=jnp.maximum(a.registers, b.registers),
                        scanned=a.scanned + b.scanned)

    def terminate(state: HLLState):
        return _hll_point(state.registers)

    def _hll_point(regs):
        raw = alpha * m * m / jnp.sum(jnp.exp2(-regs))
        zeros = jnp.sum((regs == 0).astype(jnp.float32))
        linear = m * jnp.log(m / jnp.maximum(zeros, 1.0))
        return jnp.where((raw <= 2.5 * m) & (zeros > 0), linear, raw)

    def estimate(state: HLLState, confidence, ctx=None) -> Estimate:
        est = _hll_point(state.registers)
        rel = 1.04 / jnp.sqrt(jnp.float32(m))
        half = E.zq(confidence) * rel * est
        frac = state.scanned / jnp.maximum(jnp.float32(d_total), 1.0)
        return Estimate(est, est - half, est + half,
                        info={"rel_err": rel, "frac": frac})

    return GLA(init=init, accumulate=accumulate, merge=merge,
               terminate=terminate, estimate=estimate,
               merge_is_additive=False,  # max monoid: vmapped engine only
               name=f"hll-distinct-m{m}")


# ---------------------------------------------------------------------------
# Quantiles — fixed-bin histogram CDF with DKW bands (additive)
# ---------------------------------------------------------------------------

class HistState(NamedTuple):
    counts: jnp.ndarray   # [bins] f32 in-range predicate-matching rows
    scanned: jnp.ndarray
    matched: jnp.ndarray


def make_quantile_gla(
    value: Callable[[Chunk], jnp.ndarray],
    *,
    lo: float,
    hi: float,
    d_total: float,
    bins: int = 256,
    q: float = 0.5,
    cond: Optional[Callable[[Chunk], jnp.ndarray]] = None,
) -> GLA:
    """q-quantile of value(d) [WHERE cond(d)] over a known range [lo, hi).

    The histogram CDF is an empirical distribution over the sample scanned
    so far; the DKW inequality bounds sup|F_n - F| by
    sqrt(ln(2/(1-conf)) / (2 n)), so the interval is the value-space span
    of the (q ± eps)-quantiles plus one bin of discretization.  Counts are
    additive — this monoid runs on both engines and under psum merges.
    """
    B = int(bins)
    width = (float(hi) - float(lo)) / B
    edges = jnp.float32(lo) + width * jnp.arange(B + 1, dtype=jnp.float32)

    def init():
        z = jnp.zeros((), jnp.float32)
        return HistState(counts=jnp.zeros((B,), jnp.float32),
                         scanned=z, matched=z)

    def accumulate(state: HistState, chunk: Chunk) -> HistState:
        v = value(chunk).astype(jnp.float32)
        w = chunk["_mask"].astype(jnp.float32)
        if cond is not None:
            w = cond(chunk).astype(jnp.float32) * w
        b = jnp.clip(jnp.floor((v - lo) / width), 0, B - 1).astype(jnp.int32)
        return HistState(
            counts=state.counts + jax.ops.segment_sum(w, b, num_segments=B),
            scanned=state.scanned
            + jnp.sum(chunk["_mask"].astype(jnp.float32)),
            matched=state.matched + jnp.sum(w))

    def merge(a, b):
        return jax.tree.map(jnp.add, a, b)

    def _quantile_value(cdf, p):
        # first bin upper edge where the CDF reaches p (conservative)
        idx = jnp.sum((cdf < p).astype(jnp.int32))
        return edges[jnp.clip(idx, 0, B)]

    def terminate(state: HistState):
        cdf = jnp.cumsum(state.counts) / jnp.maximum(state.matched, 1.0)
        return _quantile_value(cdf, q)

    def estimate(state: HistState, confidence, ctx=None) -> Estimate:
        n = state.matched
        cdf = jnp.cumsum(state.counts) / jnp.maximum(n, 1.0)
        conf = jnp.asarray(confidence, jnp.float32)
        eps = jnp.sqrt(
            jnp.log(2.0 / jnp.maximum(1.0 - conf, 1e-9))
            / (2.0 * jnp.maximum(n, 1.0)))
        est = _quantile_value(cdf, q)
        # _quantile_value returns the crossing bin's LOWER edge; the true
        # quantile sits anywhere inside that bin, so both band edges get
        # the one-bin discretization margin (a point mass exactly on a
        # bin boundary otherwise escapes the upper bound)
        lo_v = _quantile_value(cdf, q - eps) - width
        hi_v = _quantile_value(cdf, q + eps) + width
        # n == 0: no order statistics at all — poison to the full range
        lo_v = jnp.where(n > 0, lo_v, -jnp.inf)
        hi_v = jnp.where(n > 0, hi_v, jnp.inf)
        frac = state.scanned / jnp.maximum(jnp.float32(d_total), 1.0)
        return Estimate(est, lo_v, hi_v, info={"eps": eps, "frac": frac})

    return GLA(init=init, accumulate=accumulate, merge=merge,
               terminate=terminate, estimate=estimate,
               merge_is_additive=True, name=f"quantile-q{q}-b{B}")


# ---------------------------------------------------------------------------
# Heavy hitters — count-min sketch over candidate ids (additive)
# ---------------------------------------------------------------------------

class CMSState(NamedTuple):
    table: jnp.ndarray    # [depth, width] f32 hashed counts
    scanned: jnp.ndarray
    matched: jnp.ndarray


# distinct odd multipliers per CMS row (pairwise-independent enough for the
# standard CM overcount guarantee at small depth)
_CMS_MULTS = (2654435761, 2246822519, 3266489917, 668265263, 374761393)


def make_heavy_hitters_gla(
    key: Callable[[Chunk], jnp.ndarray],
    candidates,
    *,
    d_total: float,
    width: int = 1024,
    depth: int = 4,
    cond: Optional[Callable[[Chunk], jnp.ndarray]] = None,
) -> GLA:
    """Per-candidate frequency estimates via a count-min sketch.

    ``candidates`` is the static id array to report (the heavy-hitter
    shortlist).  Each CMS cell overcounts by at most e/width of the total
    mass w.h.p.; the reported interval is the HT-scaled min-row count
    minus that overcount (lower) to the HT-scaled min-row count plus the
    sampling half-width (upper).  Counts are additive — both engines.
    """
    W, D = int(width), int(depth)
    if D > len(_CMS_MULTS):
        raise ValueError(f"depth <= {len(_CMS_MULTS)} supported")
    cand = jnp.asarray(candidates).astype(jnp.uint32)

    def _buckets(k):
        return tuple(
            ((k.astype(jnp.uint32) * jnp.uint32(_CMS_MULTS[d])
              ^ (k.astype(jnp.uint32) >> 16)) & jnp.uint32(W - 1))
            .astype(jnp.int32) for d in range(D))

    def init():
        z = jnp.zeros((), jnp.float32)
        return CMSState(table=jnp.zeros((D, W), jnp.float32),
                        scanned=z, matched=z)

    def accumulate(state: CMSState, chunk: Chunk) -> CMSState:
        w = chunk["_mask"].astype(jnp.float32)
        if cond is not None:
            w = cond(chunk).astype(jnp.float32) * w
        ks = key(chunk)
        rows = [jax.ops.segment_sum(w, b, num_segments=W)
                for b in _buckets(ks)]
        return CMSState(
            table=state.table + jnp.stack(rows),
            scanned=state.scanned
            + jnp.sum(chunk["_mask"].astype(jnp.float32)),
            matched=state.matched + jnp.sum(w))

    def merge(a, b):
        return jax.tree.map(jnp.add, a, b)

    def _counts(table):
        per_row = jnp.stack(
            [table[d][b] for d, b in enumerate(_buckets(cand))])  # [D, C]
        return jnp.min(per_row, axis=0)                           # [C]

    def terminate(state: CMSState):
        return _counts(state.table)

    def estimate(state: CMSState, confidence, ctx=None) -> Estimate:
        sample_counts = _counts(state.table)                      # [C]
        scale = jnp.float32(d_total) / jnp.maximum(state.scanned, 1.0)
        est = sample_counts * scale
        overcount = (jnp.e / W) * state.matched * scale
        # sampling error on a {0,1}-valued count: binomial half-width
        p = sample_counts / jnp.maximum(state.scanned, 1.0)
        var = (jnp.float32(d_total)
               * jnp.maximum(jnp.float32(d_total) - state.scanned, 0.0)
               * p * jnp.maximum(1.0 - p, 0.0)
               / jnp.maximum(state.scanned, 1.0))
        half = E.zq(confidence) * jnp.sqrt(var)
        frac = state.scanned / jnp.maximum(jnp.float32(d_total), 1.0)
        return Estimate(est, est - half - overcount, est + half,
                        info={"overcount": overcount, "frac": frac})

    return GLA(init=init, accumulate=accumulate, merge=merge,
               terminate=terminate, estimate=estimate,
               merge_is_additive=True, name=f"cms-hh-w{W}d{D}")
