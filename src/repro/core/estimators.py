"""Sampling estimators for parallel on-line aggregation — paper §4.

Implements the generic sampling-without-replacement estimator (Eq. 2) with its
unbiased variance estimator (Eq. 4), and the three parallel estimation models
compared in the paper:

  * ``single``       — the paper's contribution (§4.3.2): one estimator over
                       the union of per-partition samples; valid at *unequal*
                       per-partition sample fractions because the data is
                       globally randomized.  No synchronization.
  * ``multiple``     — stratified sampling (§4.3.3, Luo et al. SIGMOD'02):
                       one estimator per partition, summed;
                       EstimatorTerminate/EstimatorMerge required.
  * ``synchronized`` — Wu et al. VLDB'09: the single-estimator formula but
                       only valid when every partition has sampled the same
                       fraction; the engine enforces a per-round barrier and
                       truncates to the minimum progress.

Erratum note (DESIGN.md §1): paper Algorithm 1 increments ``count`` inside
``if cond(d)``; Eq. (2)/(4) require |S| = number of *scanned* items.  We track
``scanned`` (= |S|) for every live item and restrict sum/sumSq to predicate
matches, i.e. we estimate sum over D of func(d)*1[cond(d)].  At full scan the
variance term (|D|-|S|) vanishes and the bounds collapse on the exact answer
— property-tested.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.core.uda import Estimate

# z-quantile via the inverse normal CDF.  jax.scipy.special.ndtri is the
# canonical primitive (scipy is not installed in this environment).
_ndtri = jax.scipy.special.ndtri


class SumState(NamedTuple):
    """State of the generic sampling estimator (corrected paper Alg. 1).

    sum     = sum of func(d) over scanned, predicate-matching items
    sumsq   = sum of func(d)^2 over scanned, predicate-matching items
    scanned = |S|, number of scanned (live) items — predicate-independent
    matched = number of scanned items matching the predicate (diagnostic; also
              the COUNT aggregate when func == 1)
    """

    sum: jnp.ndarray
    sumsq: jnp.ndarray
    scanned: jnp.ndarray
    matched: jnp.ndarray


def sum_state_zero(dtype=jnp.float32) -> SumState:
    z = jnp.zeros((), dtype)
    return SumState(z, z, z, z)


def sum_state_accumulate(state: SumState, vals, live) -> SumState:
    """Fold a chunk of func-values with a liveness*predicate weight.

    ``vals``: func(d) per item (already multiplied by nothing);
    ``live``: 1.0 for scanned items, ``match``: weight in [0,1] — the caller
    passes live = chunk mask, and vals pre-multiplied by the predicate.
    """
    raise NotImplementedError("use sum_accumulate_masked")


def sum_accumulate_masked(state: SumState, func_vals, cond, mask) -> SumState:
    """Accumulate one chunk: func_vals [n], cond [n] in {0,1}, mask [n] in {0,1}."""
    w = (cond * mask).astype(state.sum.dtype)
    m = mask.astype(state.sum.dtype)
    v = func_vals.astype(state.sum.dtype)
    return SumState(
        sum=state.sum + jnp.sum(v * w),
        sumsq=state.sumsq + jnp.sum(v * v * w),
        scanned=state.scanned + jnp.sum(m),
        matched=state.matched + jnp.sum(w),
    )


def sum_state_merge(a: SumState, b: SumState) -> SumState:
    return jax.tree.map(jnp.add, a, b)


def zq(confidence):
    """Two-sided z quantile: P(|Z| <= zq) = confidence."""
    conf = jnp.asarray(confidence, jnp.float32)
    return _ndtri((1.0 + conf) / 2.0)


def horvitz_estimate(sum_, scanned, d_total):
    """Paper Eq. (2): X = |D|/|S| * sum_{s in S, cond} func(s)."""
    safe_s = jnp.maximum(scanned, 1.0)
    return d_total / safe_s * sum_


def variance_estimate(sum_, sumsq, scanned, d_total):
    """Paper Eq. (4) — unbiased estimator of Var(X) from the sample.

    Est = |D|(|D|-|S|) / (|S|^2 (|S|-1)) * (|S| * sumsq - sum^2)
    """
    s = scanned
    safe = jnp.maximum(s, 2.0)  # needs |S| >= 2; engine masks earlier rounds
    num = d_total * jnp.maximum(d_total - s, 0.0)
    den = safe * safe * (safe - 1.0)
    est = num / den * jnp.maximum(s * sumsq - sum_ * sum_, 0.0)
    # With fewer than 2 scanned items the variance is undefined -> +inf width.
    return jnp.where(s >= 2.0, est, jnp.inf)


def normal_bounds(est, var, confidence):
    half = zq(confidence) * jnp.sqrt(var)
    return est - half, est + half


# --------------------------------------------------------------------------
# The three estimation models, expressed over SumState pytrees.
# --------------------------------------------------------------------------

def single_estimate(state: SumState, confidence, *, d_total) -> Estimate:
    """Paper Alg. 1 (GLASum-SingleEstimator), corrected per the erratum note.

    Valid at arbitrary per-partition progress given global randomization.
    The state passed here is the *merged* state across partitions.
    """
    est = horvitz_estimate(state.sum, state.scanned, d_total)
    var = variance_estimate(state.sum, state.sumsq, state.scanned, d_total)
    lo, hi = normal_bounds(est, var, confidence)
    frac = state.scanned / jnp.maximum(d_total, 1.0)
    return Estimate(est, lo, hi, info={"var": var, "frac": frac})


class MultState(NamedTuple):
    """State for the multiple-estimators (stratified) model — paper Alg. 2.

    base fields accumulate locally; (est, estvar) are produced by
    EstimatorTerminate at each node and summed by EstimatorMerge.
    """

    base: SumState
    est: jnp.ndarray
    estvar: jnp.ndarray


def mult_state_zero(dtype=jnp.float32) -> MultState:
    z = jnp.zeros((), dtype)
    return MultState(sum_state_zero(dtype), z, z)


def mult_estimator_terminate(state: MultState, *, d_local) -> MultState:
    """Paper Alg. 2 EstimatorTerminate: local estimator for partition i.

    est_i    = |D_i|/count * sum
    estvar_i = |D_i|(|D_i|-count)/(count^2(count-1)) * (count*sumSq - sum^2)
    """
    b = state.base
    est = horvitz_estimate(b.sum, b.scanned, d_local)
    var = variance_estimate(b.sum, b.sumsq, b.scanned, d_local)
    return MultState(b, est, var)


def mult_estimator_merge(a: MultState, b: MultState) -> MultState:
    """Paper Alg. 2 EstimatorMerge: sum the local estimators and variances."""
    return MultState(
        base=sum_state_merge(a.base, b.base),
        est=a.est + b.est,
        estvar=a.estvar + b.estvar,
    )


def mult_estimate(state: MultState, confidence) -> Estimate:
    lo, hi = normal_bounds(state.est, state.estvar, confidence)
    return Estimate(state.est, lo, hi, info={"var": state.estvar})


def synchronized_estimate(state: SumState, confidence, *, d_total) -> Estimate:
    """Wu et al. synchronized estimator: same formula as `single`, but the
    engine guarantees equal sample fractions by truncating every partition to
    the global minimum progress (the barrier) before merging into ``state``.
    """
    return single_estimate(state, confidence, d_total=d_total)


# --------------------------------------------------------------------------
# Deep OLA: nested estimators, join scaling, monotone envelopes
# (DESIGN.md §13; PAPERS.md 2303.04103 + paper §3.3)
# --------------------------------------------------------------------------

def join_scale(d_fact, s_fact, d_dim, s_dim):
    """§3.3 multiplicative join estimator scale: (|R|/|S_R|)·(|T|/|S_T|).

    With the dimension side fully resident (s_dim == d_dim, our probe-table
    joins) the second factor is exactly 1.0 and the scale degrades to the
    plain Horvitz–Thompson |R|/|S_R| — which is why resident-dim joins keep
    bitwise-identical estimates through the single-table formulas.
    """
    fact = d_fact / jnp.maximum(s_fact, 1.0)
    dim = d_dim / jnp.maximum(s_dim, 1.0)
    return fact * dim


def nested_group_estimate(inner: Estimate, having, confidence) -> Estimate:
    """Deep OLA nested aggregate: SUM over groups whose *estimated* inner
    aggregate passes a HAVING predicate.

    ``inner`` holds per-group arrays (estimate/lower/upper [G] with
    info["var"] [G]); ``having`` maps the inner point estimates [G] to a
    0/1 keep mask [G].  The outer point estimate sums the passing groups'
    inner estimates; its variance is the sum of the passing groups' inner
    variances (independent-strata composition — each group's state is
    accumulated from disjoint sample rows).

    Variance discipline: a group with |S| <= 1 carries +inf inner variance
    (``variance_estimate``).  If such a group passes HAVING, the outer
    variance must go to +inf — *poisoning* the bound, never NaN.  The mask
    is applied with ``jnp.where`` (0 * inf == NaN under IEEE multiply);
    the outer point estimate stays finite, so est ∓ inf·zq yields ±inf
    bounds.
    """
    keep = having(inner.estimate).astype(inner.estimate.dtype)
    var_g = inner.info["var"] if isinstance(inner.info, dict) else inner.info
    if keep.ndim < inner.estimate.ndim:  # [G] mask over [G, A] estimates
        keep = keep[..., None]
    est = jnp.sum(jnp.where(keep > 0, inner.estimate, 0.0), axis=0)
    var = jnp.sum(jnp.where(keep > 0, var_g, 0.0), axis=0)
    if est.ndim and est.shape[-1] == 1:
        est, var = est[..., 0], var[..., 0]
    lo, hi = normal_bounds(est, var, confidence)
    return Estimate(est, lo, hi,
                    info={"var": var, "keep": keep, "inner_var": var_g})


def monotone_envelope(lower, upper):
    """Running intersection of per-round confidence intervals.

    OLA UIs want bounds that only tighten; raw per-round CIs can widen
    transiently when a HAVING predicate flips a group in or out of the
    outer sum.  Each round's CI holds at the stated confidence, so their
    running intersection [cummax(lo), cummin(hi)] is a valid (conservative)
    envelope that is monotonically non-widening by construction.  A round
    whose CI is disjoint from the intersection so far crosses the running
    bounds (cummax(lo) > cummin(hi)) — and since the running bounds only
    drift further apart from there, the envelope FREEZES at the last
    consistent round: lower stays non-decreasing and upper non-increasing
    through the contradiction instead of chasing a drifting midpoint
    (tests/test_deepola.py holds this as a hypothesis property).  Applied
    post-hoc by examples/tests — never inside the shared runtime, where it
    would perturb classic plans' published bounds.
    """
    lo = jax.lax.cummax(jnp.asarray(lower), axis=0)
    hi = jax.lax.cummin(jnp.asarray(upper), axis=0)
    crossed = lo > hi                    # monotone along rounds: a suffix
    idx = jnp.argmax(crossed, axis=0)    # first contradicting round
    prev = jnp.maximum(idx - 1, 0)
    frozen_lo = jnp.take_along_axis(lo, prev[None], axis=0)[0]
    frozen_hi = jnp.take_along_axis(hi, prev[None], axis=0)[0]
    # a round-0 contradiction (lower[0] > upper[0]) has nothing to freeze
    # to — collapse to an empty-width interval at that round's midpoint
    mid0 = 0.5 * (lo[0] + hi[0])
    frozen_lo = jnp.where(idx > 0, frozen_lo, mid0)
    frozen_hi = jnp.where(idx > 0, frozen_hi, mid0)
    return (jnp.where(crossed, frozen_lo, lo),
            jnp.where(crossed, frozen_hi, hi))
