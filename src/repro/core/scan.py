"""Shared scan/merge core — the one implementation both execution paths run.

The engine has two physical execution paths (DESIGN.md §2, §4):

  * vmapped   — partitions are a leading array axis on one device
                (``repro.core.engine._run_vmapped``), and
  * sharded   — partitions are devices along the ``data`` mesh axis under
                ``jax.shard_map`` (``repro.dist.shard_engine.run_sharded``).

Both consume the per-partition scan primitives in this module, so the GLA
math is written exactly once; the paths differ only in how per-partition
states are merged (tensordot over the partition axis vs. ``lax.psum``).

Scan variants (selected by the engine's ``emit`` argument):

  ``scan_prefix``        every prefix state [C+1, ...]; small-state GLAs,
                         arbitrary snapshot schedules.
  ``scan_rounds``        state only at round boundaries; large-state GLAs,
                         uniform schedules (C % R == 0).
  ``scan_rounds_masked`` per-round O(R·C) masked re-scan; large-state GLAs,
                         arbitrary schedules.
  ``kernel_prefix_states`` one fused Pallas dispatch for the whole shard
                         (per-chunk partials + prefix-sum); SumState GLAs
                         that publish ``kernel_cols`` (DESIGN.md §3).
  ``kernel_rounds_states`` one ``ops.group_agg`` Pallas dispatch per
                         round-slice; group-by GLAs that publish
                         ``kernel_cols`` + ``kernel_num_groups`` — dense
                         [G, A] states follow the round emission discipline
                         (DESIGN.md §3).
  ``fused_rounds_states`` / ``fused_prefix_states`` — the fused
                         selection→bucket→aggregate kernel (DESIGN.md §12,
                         kernels/fused_agg.py): predicate, hash-bucketing,
                         in-kernel column decode and f32 accumulation in
                         ONE carry-in dispatch per round-slice, bitwise-
                         identical to the scan paths (scalar included).
                         Preferred by both engines whenever the GLA
                         publishes a ``FusedSpec`` (``gla.fused``); the
                         kernel_* paths above remain for GLAs that only
                         publish the legacy ``kernel_cols`` projection.

The per-round-slice primitives those variants fold over all rounds —
``scan_round_step``, ``kernel_round_delta``, ``bundle_round_deltas``,
``kernel_scalar_round_delta``, ``fused_round_step`` — are also jitted
standalone by the incremental session driver (repro/core/session.py,
DESIGN.md §7), which advances one round at a time so stopping rules can
terminate the scan early.  One implementation, two execution disciplines.

``round_weights`` centralizes partition-liveness accounting: the engine and
the fault model (repro/dist/fault.py) express node failure as an ``alive``
mask of shape [P] (static) or [R, P] (failure-injection schedule), and every
merge weights partition states by it.
"""
from __future__ import annotations

from typing import Any, Tuple

import jax
import jax.numpy as jnp
from jax import lax

from repro.core.uda import GLA

Pytree = Any


# ---------------------------------------------------------------------------
# lane (work-unit) handling
# ---------------------------------------------------------------------------

def stack_init(gla: GLA, lanes: int) -> Pytree:
    """Initial state, broadcast to ``lanes`` parallel GLA states."""
    s = gla.init()
    if lanes == 1:
        return s
    return jax.tree.map(lambda x: jnp.broadcast_to(x, (lanes, *x.shape)), s)


def fold_merge(merge, states: Pytree, n: int) -> Pytree:
    """Left-fold ``merge`` over a leading axis of length ``n``."""
    acc = jax.tree.map(lambda x: x[0], states)
    for i in range(1, n):
        acc = merge(acc, jax.tree.map(lambda x, i=i: x[i], states))
    return acc


def accumulate_chunk(gla: GLA, states: Pytree, chunk: dict, lanes: int):
    """Advance lane states by one chunk; return (states, lane-merged view)."""
    if lanes == 1:
        st = gla.accumulate(states, chunk)
        return st, st
    lc = {k: v.reshape(lanes, -1) for k, v in chunk.items()}
    st = jax.vmap(gla.accumulate)(states, lc)
    return st, fold_merge(gla.merge, st, lanes)


# ---------------------------------------------------------------------------
# per-partition scans
# ---------------------------------------------------------------------------

def scan_prefix(gla: GLA, cols: dict, lanes: int):
    """Scan chunks emitting every prefix state (init prepended): [C+1, ...].

    Used when snapshots at *arbitrary* per-partition progress are needed
    (straggler schedules, sync truncation).  State must be small — the
    emission cost is O(C · |state|) HBM traffic, nothing else.
    """
    init = stack_init(gla, lanes)
    init_view = fold_merge(gla.merge, init, lanes) if lanes > 1 else init

    def body(st, chunk):
        st, view = accumulate_chunk(gla, st, chunk, lanes)
        return st, view

    last, prefixes = lax.scan(body, init, cols)
    prefixes = jax.tree.map(
        lambda i, p: jnp.concatenate([i[None], p], axis=0), init_view, prefixes
    )
    final_view = jax.tree.map(lambda p: p[-1], prefixes)
    return final_view, prefixes


def scan_round_step(gla: GLA, states: Pytree, round_cols: dict, lanes: int):
    """Advance laned per-partition states by ONE round-slice of chunks.

    The per-round-slice primitive both execution disciplines share: the
    monolithic :func:`scan_rounds` folds it over all rounds inside one
    program, and the incremental session driver (repro/core/session.py)
    jits it standalone and advances round by round, evaluating stopping
    rules in between.  Identical chunk-sequential accumulation order either
    way, so round-boundary states are bitwise-identical across disciplines
    (tests/test_session.py).

    Returns (new laned states, lane-merged round-boundary view).
    """
    def chunk_body(s, chunk):
        s, _ = accumulate_chunk(gla, s, chunk, lanes)
        return s, None

    states, _ = lax.scan(chunk_body, states, round_cols)
    view = fold_merge(gla.merge, states, lanes) if lanes > 1 else states
    return states, view


def scan_rounds(gla: GLA, cols: dict, lanes: int, rounds: int):
    """Uniform-schedule fast path: emit state only at round boundaries.

    O(|state|·R) emission — usable for large-state GLAs (1M-group group-by).
    Requires C % rounds == 0.
    """
    C = cols["_mask"].shape[0]
    assert C % rounds == 0, f"uniform rounds path needs C%R==0, got {C}%{rounds}"
    per = C // rounds
    rcols = {k: v.reshape((rounds, per, *v.shape[1:])) for k, v in cols.items()}
    init = stack_init(gla, lanes)

    def round_body(st, round_cols):
        return scan_round_step(gla, st, round_cols, lanes)

    last, views = lax.scan(round_body, init, rcols)
    final_view = fold_merge(gla.merge, last, lanes) if lanes > 1 else last
    return final_view, views


def scan_rounds_masked(gla: GLA, cols: dict, sched: jnp.ndarray, lanes: int):
    """Arbitrary-schedule path for large-state GLAs: O(R·C) masked scan.

    Round r re-scans all chunks with liveness mask (lo <= c < hi); correctness
    from the uda mask contract.  Emission is per-round.
    """
    C = cols["_mask"].shape[0]
    R = sched.shape[0] - 1
    init = stack_init(gla, lanes)

    def round_body(st, r):
        lo, hi = sched[r], sched[r + 1]

        def chunk_body(carry, xs):
            s = carry
            c, chunk = xs
            live = ((c >= lo) & (c < hi)).astype(chunk["_mask"].dtype)
            chunk = dict(chunk)
            chunk["_mask"] = chunk["_mask"] * live
            s, _ = accumulate_chunk(gla, s, chunk, lanes)
            return s, None

        st, _ = lax.scan(chunk_body, st, (jnp.arange(C), cols))
        view = fold_merge(gla.merge, st, lanes) if lanes > 1 else st
        return st, view

    last, views = lax.scan(round_body, init, jnp.arange(R))
    final_view = fold_merge(gla.merge, last, lanes) if lanes > 1 else last
    return final_view, views


# ---------------------------------------------------------------------------
# fused-kernel shard path (per-shard kernel dispatch, DESIGN.md §3)
# ---------------------------------------------------------------------------

def kernel_prefix_states(gla: GLA, cols: dict):
    """One Pallas dispatch for a whole [C, L] shard -> SumState prefixes.

    Valid for GLAs that publish ``kernel_cols`` (additive SumState layout):
    the kernel emits per-chunk (sum, sumsq, scanned, matched) partials in a
    single launch; additivity turns the prefix states into a cumsum, so the
    result is interchangeable with :func:`scan_prefix` at lanes == 1.
    """
    from repro.core import estimators as E
    from repro.kernels import ops

    assert gla.kernel_cols is not None, "GLA does not publish kernel_cols"
    C, L = cols["_mask"].shape
    flat = {k: v.reshape(C * L) for k, v in cols.items()}
    vals, weight = gla.kernel_cols(flat)
    partials = ops.shard_chunk_partials(
        vals.reshape(C, L), weight.reshape(C, L), cols["_mask"]
    )  # [C, 4]
    cum = jnp.concatenate(
        [jnp.zeros((1, 4), partials.dtype), jnp.cumsum(partials, axis=0)], 0
    )  # [C+1, 4]
    prefixes = E.SumState(
        sum=cum[:, 0:1], sumsq=cum[:, 1:2], scanned=cum[:, 2], matched=cum[:, 3]
    )
    final_view = jax.tree.map(lambda p: p[-1], prefixes)
    return final_view, prefixes


def _unroll_partitions(fn, shards: dict):
    """Run a per-shard (final, views) function on every partition, stacked.

    P is small and static, so an unrolled loop keeps the Pallas calls out of
    scan/vmap transforms (interpret mode on CPU stays supported).
    """
    P = shards["_mask"].shape[0]
    outs = [fn(jax.tree.map(lambda x, p=p: x[p], shards)) for p in range(P)]
    finals = jax.tree.map(lambda *xs: jnp.stack(xs), *[o[0] for o in outs])
    views = jax.tree.map(lambda *xs: jnp.stack(xs), *[o[1] for o in outs])
    return finals, views


def _fold_running_sum(deltas):
    """Fold per-round additive deltas into round-boundary states.

    Sequential association order on purpose — it matches the scan paths'
    chunk-by-chunk accumulation, which is what keeps kernel-path states
    bitwise-identical to the scan states.  Returns (final, views stacked
    [R, ...]).
    """
    acc, views = deltas[0], [deltas[0]]
    for d in deltas[1:]:
        acc = jax.tree.map(jnp.add, acc, d)
        views.append(acc)
    return acc, jax.tree.map(lambda *xs: jnp.stack(xs), *views)


def kernel_prefix_states_batched(gla: GLA, shards: dict):
    """Vmapped-path wrapper: one kernel dispatch per partition, stacked."""
    return _unroll_partitions(lambda c: kernel_prefix_states(gla, c), shards)


def kernel_scalar_round_delta(gla: GLA, slice_cols: dict):
    """Scalar-contract SumState delta for ONE round-slice of a shard.

    One ``shard_chunk_partials`` dispatch over the slice; the within-slice
    prefix keeps the chunk-sequential association, so the delta is the
    slice's chunk-ordered total.  Adding deltas round by round re-associates
    float adds against the whole-shard cumsum of
    :func:`kernel_prefix_states`, so this legacy path is interchangeable —
    not bitwise-identical — with the scan path.  Sessions prefer
    :func:`fused_round_step` (carry-in accumulation, bitwise-identical to
    the scan path) whenever the GLA publishes ``gla.fused``; this primitive
    remains for kernel_cols-only GLAs.
    """
    from repro.core import estimators as E
    from repro.kernels import ops

    assert gla.kernel_cols is not None, "GLA does not publish kernel_cols"
    C, L = slice_cols["_mask"].shape
    flat = {k: v.reshape(C * L) for k, v in slice_cols.items()}
    vals, weight = gla.kernel_cols(flat)
    partials = ops.shard_chunk_partials(
        vals.reshape(C, L), weight.reshape(C, L), slice_cols["_mask"]
    )  # [C, 4]
    tot = jnp.cumsum(partials, axis=0)[-1]
    return E.SumState(sum=tot[0:1], sumsq=tot[1:2], scanned=tot[2],
                      matched=tot[3])


def kernel_round_delta(gla: GLA, slice_cols: dict):
    """Group-by SumState delta for ONE round-slice: a single ``group_agg``
    dispatch with ``block_rows`` pinned to the chunk length (chunk-sequential
    association inside the kernel).  The per-round-slice primitive shared by
    the monolithic :func:`kernel_rounds_states` loop and the incremental
    session driver — both fold deltas with the same sequential running sum,
    so round-boundary states are bitwise-identical across disciplines."""
    from repro.core import estimators as E
    from repro.kernels import ops

    assert gla.kernel_cols is not None, "GLA does not publish kernel_cols"
    assert gla.kernel_num_groups is not None, (
        "GLA publishes the scalar kernel contract, not the group-by one")
    per, L = slice_cols["_mask"].shape
    sl = {k: v.reshape(per * L) for k, v in slice_cols.items()}
    vals, weight, gids = gla.kernel_cols(sl)
    w = (weight * sl["_mask"]).astype(jnp.float32)
    sums, sumsqs, matched = ops.group_agg(
        vals, w, gids.astype(jnp.int32), num_groups=gla.kernel_num_groups,
        block_rows=L)
    return E.SumState(
        sum=sums, sumsq=sumsqs,
        scanned=jnp.sum(sl["_mask"].astype(jnp.float32)),
        matched=matched,
    )


def kernel_rounds_states(gla: GLA, cols: dict, rounds: int):
    """One ``ops.group_agg`` dispatch per round-slice -> group SumState views.

    Valid for group-by GLAs publishing the ``(vals, weight, gids)`` kernel
    projection plus ``kernel_num_groups`` (core/gla.make_groupby_gla).  The
    dense [G, A] state makes per-chunk prefix emission memory-infeasible, so
    this path composes with the ``emit="round"`` discipline instead: the
    kernel aggregates each round-slice of the shard in a single launch and
    additivity turns the round-boundary states into a running sum of the
    per-round deltas — interchangeable with :func:`scan_rounds` at lanes==1.

    ``block_rows`` is pinned to the chunk length, so the kernel accumulates
    chunk-by-chunk in the same association order as the scan path; the
    running sum over rounds is folded sequentially for the same reason
    (see tests/test_groupby_kernel.py for the bitwise-equality check).
    """
    C, L = cols["_mask"].shape
    assert C % rounds == 0, (
        f"group-by kernel path needs C % rounds == 0, got {C} % {rounds}")
    per = C // rounds
    deltas = [
        kernel_round_delta(
            gla, {k: v[r * per:(r + 1) * per] for k, v in cols.items()})
        for r in range(rounds)
    ]
    return _fold_running_sum(deltas)


def kernel_rounds_states_batched(gla: GLA, shards: dict, rounds: int):
    """Vmapped-path wrapper for :func:`kernel_rounds_states`: unrolled over
    partitions (same rationale as :func:`_unroll_partitions`)."""
    return _unroll_partitions(
        lambda c: kernel_rounds_states(gla, c, rounds), shards)


# ---------------------------------------------------------------------------
# multi-query bundles: batched kernel dispatch (DESIGN.md §6)
# ---------------------------------------------------------------------------

def _bundle_member_projection(member: GLA, sl: dict):
    """Normalize a member's kernel projection to (vals [n, A], weight, G).

    Scalar-contract members (``kernel_num_groups is None``) are folded in as
    a 1-group table: their ``(vals, weight)`` projection becomes a group-by
    projection with every item in group 0, so a single ``ops.group_agg``
    dispatch serves scalar and group-by members alike.
    """
    assert member.kernel_cols is not None, (
        f"bundle member {member.name!r} does not publish kernel_cols")
    if member.kernel_num_groups is None:
        vals, weight = member.kernel_cols(sl)
        gids = jnp.zeros(vals.shape[0], jnp.int32)
        G = 1
    else:
        vals, weight, gids = member.kernel_cols(sl)
        G = member.kernel_num_groups
    if vals.ndim == 1:
        vals = vals[:, None]
    return vals, weight, gids.astype(jnp.int32), G


def bundle_round_deltas(gla: GLA, slice_cols: dict):
    """Per-member SumState deltas for ONE round-slice of a bundle: every
    member's kernel projection stacked row-wise into a single ``group_agg``
    dispatch (gid offsets into one concatenated group table, vals zero-padded
    to the widest member — see :func:`bundle_kernel_rounds_states` for why
    members stay value-isolated).  The per-round-slice primitive shared by
    the monolithic loop and the incremental session driver.  Returns a tuple
    of one delta per member, matching the bundle's tuple-state layout."""
    from repro.core import estimators as E
    from repro.kernels import ops

    members = gla.members
    assert members, "bundle kernel path needs a GLABundle"
    per, L = slice_cols["_mask"].shape
    sl = {k: v.reshape(per * L) for k, v in slice_cols.items()}
    mask = sl["_mask"].astype(jnp.float32)
    scanned = jnp.sum(mask)
    projs = [_bundle_member_projection(m, sl) for m in members]
    A_max = max(v.shape[1] for v, _, _, _ in projs)
    offs = []
    vals_cat, w_cat, gids_cat = [], [], []
    off = 0
    for vals, weight, gids, G in projs:
        offs.append(off)
        if vals.shape[1] < A_max:
            vals = jnp.concatenate(
                [vals, jnp.zeros((vals.shape[0], A_max - vals.shape[1]),
                                 vals.dtype)], axis=1)
        vals_cat.append(vals)
        w_cat.append((weight * sl["_mask"]).astype(jnp.float32))
        gids_cat.append(gids + jnp.int32(off))
        off += G
    sums, sumsqs, matched = ops.group_agg(
        jnp.concatenate(vals_cat, axis=0),
        jnp.concatenate(w_cat, axis=0),
        jnp.concatenate(gids_cat, axis=0),
        num_groups=off, block_rows=L)
    deltas = []
    for i, (vals, _, _, G) in enumerate(projs):
        o, A = offs[i], vals.shape[1]
        if members[i].kernel_num_groups is None:
            deltas.append(E.SumState(
                sum=sums[o, :1], sumsq=sumsqs[o, :1],
                scanned=scanned, matched=matched[o]))
        else:
            deltas.append(E.SumState(
                sum=sums[o:o + G, :A], sumsq=sumsqs[o:o + G, :A],
                scanned=scanned, matched=matched[o:o + G]))
    return tuple(deltas)


def bundle_kernel_rounds_states(gla: GLA, cols: dict, rounds: int):
    """ONE ``ops.group_agg`` dispatch per round-slice for a whole bundle.

    Every member's kernel projection of the same round-slice is stacked
    row-wise into a single dispatch: member m's group ids are offset into
    the disjoint range [off_m, off_m + G_m) of one concatenated group table,
    and its vals are zero-padded to the widest member's aggregate count.
    Because each member's rows are a multiple of ``block_rows`` (pinned to
    the chunk length L), members occupy disjoint kernel blocks, so member
    m's table rows receive exact-zero partials from every other member's
    blocks — group-by members' states stay bitwise-identical to their solo
    :func:`kernel_rounds_states` dispatch, while scalar members fold through
    the one-hot contraction and are interchangeable-not-bitwise with the
    scan path.  Engines prefer :func:`fused_rounds_states` (bitwise for
    every member, scalar included) whenever all members publish
    ``gla.fused``; this legacy path remains for kernel_cols-only bundles.
    Returns (tuple of member finals, tuple of member [R] views)
    matching the bundle's tuple-state layout.
    """
    members = gla.members
    assert members, "bundle kernel path needs a GLABundle"
    C, L = cols["_mask"].shape
    assert C % rounds == 0, (
        f"bundle kernel path needs C % rounds == 0, got {C} % {rounds}")
    per = C // rounds

    deltas = [[] for _ in members]  # [member][round] -> SumState delta
    for r in range(rounds):
        per_member = bundle_round_deltas(
            gla, {k: v[r * per:(r + 1) * per] for k, v in cols.items()})
        for i, d in enumerate(per_member):
            deltas[i].append(d)

    folded = [_fold_running_sum(member_deltas) for member_deltas in deltas]
    return (tuple(f for f, _ in folded), tuple(v for _, v in folded))


def bundle_kernel_rounds_states_batched(gla: GLA, shards: dict, rounds: int):
    """Vmapped-path wrapper for :func:`bundle_kernel_rounds_states`:
    unrolled over partitions (same rationale as
    :func:`_unroll_partitions`)."""
    return _unroll_partitions(
        lambda c: bundle_kernel_rounds_states(gla, c, rounds), shards)


# ---------------------------------------------------------------------------
# fused selection→bucket→aggregate kernel path (DESIGN.md §12)
# ---------------------------------------------------------------------------
# Thin drivers over repro.kernels.fused_agg: ONE carry-in Pallas dispatch per
# round-slice fusing predicate evaluation, hash-bucket group ids, in-kernel
# column decode (repro.data.encodings) and f32 accumulation.  Because the
# running state enters the kernel as an input ref, round-boundary states keep
# the exact scan-carry association — the fused paths are bitwise-identical to
# the scan paths for scalar, group-by and bundle GLAs alike
# (tests/test_fused_kernel.py, docs/KERNELS.md).

def fused_available(gla: GLA, columns=None) -> bool:
    """True when ``gla`` (and every bundle member) publishes ``gla.fused``
    and every source column is kernel-decodable (no trailing dims)."""
    from repro.kernels import fused_agg

    return fused_agg.fused_available(gla, columns)


def fused_round_step(gla: GLA, state, slice_cols: dict, encodings=(), *,
                     use_mxu: bool = False):
    """Carry-in fused step for ONE round-slice: (state, slice) -> state.

    The per-round-slice primitive behind the ``kernel_fused`` session path.
    Carry-style rather than delta-style (no first/add split): the incoming
    state rides into the kernel as an input ref and every chunk accumulates
    on top, so starting from ``gla.init()`` reproduces the scan-carry
    association exactly from round 0.  ``encodings`` is the source's static
    (name, Encoding) tuple; encoded columns arrive physical and are decoded
    inside the kernel body.  Join GLAs additionally ship their replicated
    probe tables as extra kernel operands (``FusedSpec.probe_tables``);
    ``use_mxu`` selects the one-hot matmul group scatter (TPU MXU lowering
    — re-associates, so allclose rather than bitwise vs the default).
    """
    from repro.kernels import fused_agg

    return fused_agg.fused_round_step(
        gla, state, slice_cols, encodings=encodings, use_mxu=use_mxu)


def fused_rounds_states(gla: GLA, cols: dict, rounds: int, encodings=()):
    """Fused analogue of :func:`kernel_rounds_states` /
    :func:`bundle_kernel_rounds_states`: one fused dispatch per round-slice
    with the carry threaded through, round-boundary views stacked [R, ...].
    Bitwise-identical to the :func:`scan_rounds` views at lanes == 1 for
    scalar, group-by and bundle states alike.  Requires C % rounds == 0.
    """
    C = cols["_mask"].shape[0]
    assert C % rounds == 0, (
        f"fused kernel path needs C % rounds == 0, got {C} % {rounds}")
    per = C // rounds
    st = gla.init()
    views = []
    for r in range(rounds):
        st = fused_round_step(
            gla, st,
            {k: v[r * per:(r + 1) * per] for k, v in cols.items()},
            encodings)
        views.append(st)
    return st, jax.tree.map(lambda *xs: jnp.stack(xs), *views)


def fused_rounds_states_batched(gla: GLA, shards: dict, rounds: int,
                                encodings=()):
    """Vmapped-path wrapper for :func:`fused_rounds_states`: unrolled over
    partitions (same rationale as :func:`_unroll_partitions`)."""
    return _unroll_partitions(
        lambda c: fused_rounds_states(gla, c, rounds, encodings), shards)


def fused_prefix_states(gla: GLA, cols: dict, encodings=()):
    """Fused analogue of :func:`kernel_prefix_states` for solo scalar GLAs:
    ONE fused dispatch per shard emitting the running per-chunk prefix rows
    alongside the final accumulators.  The running state lives in the
    kernel's output refs, so prefixes keep the exact chunk-sequential
    association — bitwise-identical to :func:`scan_prefix` at lanes == 1."""
    from repro.kernels import fused_agg

    return fused_agg.fused_prefix_states(gla, cols, encodings=encodings)


def fused_prefix_states_batched(gla: GLA, shards: dict, encodings=()):
    """Vmapped-path wrapper: one fused prefix dispatch per partition."""
    return _unroll_partitions(
        lambda c: fused_prefix_states(gla, c, encodings), shards)


# The session drivers' path-name -> per-round-slice primitive table, kept
# here next to the primitives so the vmapped and sharded steps cannot
# diverge (repro/core/session.py, repro/dist/shard_engine.py).  These are
# delta-style (first-round states ARE the first deltas); the carry-style
# "kernel_fused" path dispatches :func:`fused_round_step` directly instead.
ROUND_DELTA_FNS = {
    "kernel_scalar": kernel_scalar_round_delta,
    "kernel_group": kernel_round_delta,
    "kernel_bundle": bundle_round_deltas,
}


# ---------------------------------------------------------------------------
# liveness accounting (node failure, DESIGN.md §4)
# ---------------------------------------------------------------------------

def round_weights(alive: jnp.ndarray, rounds: int) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Normalize an alive mask to ([P, R] merge weights, [P] final weights).

    ``alive`` is [P] (partition dead for the whole query) or [R, P]
    (failure-injection schedule: row r gives liveness during round r).  The
    final result merges with the last round's liveness — a partition that
    died mid-query never reports its final state.
    """
    alive = jnp.asarray(alive)
    if alive.ndim == 1:
        w = jnp.broadcast_to(alive[:, None], (alive.shape[0], rounds))
        return w.astype(jnp.float32), alive.astype(jnp.float32)
    w = alive.T.astype(jnp.float32)  # [P, R]
    return w, w[:, -1]


# ---------------------------------------------------------------------------
# elastic carry algebra (resume on a different partition count, DESIGN.md §9)
# ---------------------------------------------------------------------------

def merge_carries(states: Pytree, group: int) -> Pytree:
    """Fold a [P, ...] carry pytree to [P/group, ...] partitions.

    New partition i is the left-fold Merge (additive add, the same
    association order as :func:`fold_merge`) of old partitions
    [i*group, (i+1)*group).  Valid for additive merges only — exactly the
    contract the engines' weighted liveness merges already require.
    ``merge_carries(split_carries(x, k), k)`` is the identity on the carry
    pytree (x + 0 is exact), property-tested in tests/test_elastic.py.
    """
    def m(x):
        assert x.shape[0] % group == 0, (x.shape, group)
        g = x.reshape((x.shape[0] // group, group, *x.shape[1:]))
        acc = g[:, 0]
        for j in range(1, group):
            acc = acc + g[:, j]
        return acc

    return jax.tree.map(m, states)


def split_carries(states: Pytree, group: int) -> Pytree:
    """Expand a [P, ...] carry pytree to [P*group, ...] partitions.

    Child p*group inherits parent p's whole carry; the other children
    start from the additive identity (zeros).  A carry cannot be unsummed
    into the sub-streams that produced it, but to an additive merge *where*
    a carry lives is unobservable — any weighted sum over the children
    equals the parent's contribution exactly, so merged snapshots, finals
    and estimates are preserved.  Inverse of :func:`merge_carries`.
    """
    def s(x):
        z = jnp.zeros_like(x)
        cols = [x, *[z] * (group - 1)]
        return jnp.stack(cols, axis=1).reshape(
            (x.shape[0] * group, *x.shape[1:]))

    return jax.tree.map(s, states)
