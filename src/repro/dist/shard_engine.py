"""Sharded execution: partitions = devices under ``jax.shard_map``.

This is the deployment path of the engine (DESIGN.md §2, §4): the vmapped
path simulates partitions as an array axis on one device; here each
partition is a device along the ``data`` axis of a mesh built by
repro/launch/mesh.py.  Both paths call the *same* per-partition scan core
(repro/core/scan.py) — this module only owns what is genuinely distributed:

  * cross-partition merging.  GLA states must be additive (all shipped GLAs
    are), so Merge/EstimatorMerge lower to a single ``lax.psum`` — the ring
    all-reduce that plays the role of the paper's aggregation tree.
  * asynchronous snapshots.  Each partition contributes the prefix state at
    its *own* scheduled progress; the psum merges unequal-progress states,
    which is exactly what the paper's single estimator makes legal.
  * the synchronized barrier.  ``mode="sync"`` truncates every partition to
    the global minimum progress via ``lax.pmin`` and, with
    ``sync_cost_model=True``, additionally pays one coordination ``psum`` per
    chunk — the per-item serialization that makes the Wu et al. estimator
    slow, visible in wall time and in the HLO collective count
    (benchmarks/overhead.py).
  * node failure.  ``alive`` weights ([P] or [R, P], repro/dist/fault.py)
    zero dead partitions out of every psum.
  * replicated join sides.  Two-table plans (DESIGN.md §13) close their
    probe tables over the worker function — under ``shard_map`` the
    dimension arrays are trace-time constants replicated to every device
    (the paper §5.4 strategy), so the fused kernel's probe operands need
    no mesh annotations and the psum'd states stay bitwise-identical to
    the vmapped engine's.  Non-additive sketch GLAs (HLL max-merge,
    ``merge_is_additive=False``) are rejected by the additivity gate
    below: they run vmapped only.

Equivalence with the vmapped path is asserted in
tests/test_sharding.py::test_sharded_engine_matches_vmapped_subprocess.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax

from repro.core import scan as SC
from repro.core.uda import GLA


def device_put_slice(cols: dict, *, mesh, axis_name: str = "data"):
    """Place one streaming round-slice on the mesh (DESIGN.md §8).

    ``cols`` is a host-side [P, width, L] columnar batch from a
    ``repro.data.source.ChunkSource``; each partition's block lands on its
    own device along ``axis_name``, so the per-host/per-device footprint
    is O(slice / P).  Called from the session prefetcher's worker thread —
    the transfer of slice r+1 overlaps round r's compute, and the fetched
    arrays feed :func:`session_step_sharded` without a re-layout.
    """
    from jax.sharding import NamedSharding, PartitionSpec

    sh = NamedSharding(mesh, PartitionSpec(axis_name))
    return {k: jax.device_put(np.asarray(v), sh) for k, v in cols.items()}


def device_put_carry(states, *, mesh, axis_name: str = "data"):
    """Place a [P, ...] session carry pytree on the mesh (DESIGN.md §9).

    Resumed carries arrive host-backed from the checkpoint — possibly
    merged/split to a new partition count by the elastic carry algebra
    (``repro.core.scan.merge_carries``/``split_carries``) — and placing
    them explicitly along ``axis_name`` keeps the first resumed step free
    of implicit host→device resharding; the sibling of
    :func:`device_put_slice` for carries instead of data slices.
    """
    from jax.sharding import NamedSharding, PartitionSpec

    sh = NamedSharding(mesh, PartitionSpec(axis_name))
    return jax.tree.map(
        lambda x: jax.device_put(jnp.asarray(x), sh), states)


def _shard_map(worker, mesh, in_specs, out_specs):
    """jax-version-tolerant shard_map with replication checking off (the
    scan carry starts replicated from gla.init and becomes device-varying
    after the first accumulate, which the static checker rejects)."""
    fn = getattr(jax, "shard_map", None)
    if fn is not None:
        return fn(worker, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                  check_vma=False)
    from jax.experimental.shard_map import shard_map as xfn
    return xfn(worker, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
               check_rep=False)


@functools.partial(
    jax.jit,
    static_argnames=("gla", "mesh", "axis_name", "mode", "emit", "lanes",
                     "snapshots", "sync_cost_model"),
)
def _run_sharded_jit(gla: GLA, shards: dict, sched: jnp.ndarray,
                     alive2d: jnp.ndarray, *, mesh, axis_name: str, mode: str,
                     emit: str, lanes: int, snapshots: bool,
                     sync_cost_model: bool):
    P = shards["_mask"].shape[0]
    R = sched.shape[1] - 1
    # fused dispatch blocks one [1, L] row per column — trailing dims fall
    # back to the legacy kernels (resident shards are always plain/decoded)
    fused_ok = SC.fused_available(gla) and all(
        v.ndim == 3 for v in shards.values())

    def worker(cols, sched_p, alive_p):
        cols = jax.tree.map(lambda x: x[0], cols)      # [1, C, L] -> [C, L]
        sched_p = sched_p[0]
        alive_r = alive_p[0].astype(jnp.float32)       # [R] liveness per round
        d_local = jnp.sum(cols["_mask"])
        d_total = lax.psum(d_local, axis_name)

        if mode == "sync" and sync_cost_model:
            # Per-chunk progress coordination: the barrier the paper's
            # synchronized competitor needs.  The psum'd counter feeds the
            # next iteration's carry so it cannot be DCE'd.
            def body(carry, chunk):
                st, prog = carry
                st, view = SC.accumulate_chunk(gla, st, chunk, lanes)
                prog = lax.psum(prog + 1.0, axis_name) / P
                return (st, prog), view
            init = (SC.stack_init(gla, lanes), jnp.zeros(()))
            (last, _), prefixes = lax.scan(body, init, cols)
            init_view = SC.stack_init(gla, lanes)
            if lanes > 1:
                init_view = SC.fold_merge(gla.merge, init_view, lanes)
                last = SC.fold_merge(gla.merge, last, lanes)
            prefixes = jax.tree.map(
                lambda i, p: jnp.concatenate([i[None], p], 0), init_view, prefixes)
            final_view = last
        elif emit == "kernel":
            assert lanes == 1, "emit='kernel' runs single-lane"
            if fused_ok and (gla.members or gla.kernel_num_groups is not None):
                # ONE fused selection→bucket→aggregate dispatch per
                # round-slice covers every member, bitwise-identical to the
                # scan path (DESIGN.md §12).
                final_view, round_states = SC.fused_rounds_states(
                    gla, cols, R if snapshots else 1)
                prefixes = None
            elif fused_ok:
                # fused per-shard dispatch with in-kernel running prefixes —
                # bitwise-identical to the scan path, scalar contract too.
                final_view, prefixes = SC.fused_prefix_states(gla, cols)
            elif gla.members:
                # bundled kernel dispatch: ONE group_agg launch per
                # round-slice covers every member (DESIGN.md §6).
                final_view, round_states = SC.bundle_kernel_rounds_states(
                    gla, cols, R if snapshots else 1)
                prefixes = None
            elif gla.kernel_num_groups is not None:
                # group-by kernel dispatch: round emission discipline, no
                # per-chunk prefixes (DESIGN.md §3).  Snapshots off: one
                # whole-shard dispatch, nothing else is consumed.
                final_view, round_states = SC.kernel_rounds_states(
                    gla, cols, R if snapshots else 1)
                prefixes = None
            else:
                final_view, prefixes = SC.kernel_prefix_states(gla, cols)
        elif emit == "chunk":
            final_view, prefixes = SC.scan_prefix(gla, cols, lanes)
        elif emit == "round":
            final_view, round_states = SC.scan_rounds(gla, cols, lanes, R)
            prefixes = None
        else:
            raise ValueError(emit)

        if prefixes is not None:
            if mode == "sync":
                gmin = lax.pmin(sched_p[1:], axis_name)
                idx = gmin
            else:
                idx = sched_p[1:]
            round_states = jax.tree.map(lambda x: x[idx], prefixes)

        # weight by aliveness, then psum == EstimatorMerge over the tree.
        # Final states merge with the last round's liveness — a partition
        # that died mid-query never reports its final state.
        def w_final(x):
            return x * alive_r[-1].astype(x.dtype)

        def w_rounds(x):
            w = alive_r.reshape((R, *(1,) * (x.ndim - 1)))
            return x * w.astype(x.dtype)

        merged_final = lax.psum(jax.tree.map(w_final, final_view), axis_name)
        if snapshots:
            term = jax.vmap(
                lambda s: gla.estimator_terminate(s, {"d_local": d_local})
            )(round_states)
            merged_rounds = lax.psum(jax.tree.map(w_rounds, term), axis_name)
        else:
            merged_rounds = None
        return merged_final, merged_rounds, d_total, d_local[None]

    from jax.sharding import PartitionSpec as PS
    pspec = PS(axis_name)
    out_specs = (PS(), PS(), PS(), PS(axis_name))
    fn = _shard_map(worker, mesh, (pspec, pspec, pspec), out_specs)
    return fn(shards, sched, alive2d)


# ---------------------------------------------------------------------------
# incremental session steps (repro/core/session.py, DESIGN.md §7): the same
# per-round-slice primitives the fused program folds over all rounds, jitted
# standalone with partitions on the mesh axis.  One psum per step merges the
# round's estimator states; the scan carry stays sharded between steps.
# ---------------------------------------------------------------------------

@functools.partial(
    jax.jit, static_argnames=("gla", "mesh", "axis_name", "path", "lanes",
                              "confidence", "first", "encodings"),
)
def session_step_sharded(gla: GLA, states, slice_shards: dict,
                         w_r: jnp.ndarray, d_local: jnp.ndarray,
                         d_total: jnp.ndarray, *, mesh, axis_name: str,
                         path: str, lanes: int, confidence: float,
                         first: bool, encodings: tuple = ()):
    """Advance one round-slice with partitions on ``axis_name``.

    Same contract as ``session._step_vmapped``: returns (new per-partition
    states, per-partition round views, merged round state, round
    Estimate-or-None).  ``first`` starts the legacy kernel paths' running
    sum from the first delta, matching ``scan._fold_running_sum``
    bit-for-bit; the carry-style ``"kernel_fused"`` path needs no first
    split (zero-init carries are exact).  ``encodings`` is the source's
    static (name, Encoding) tuple: the fused path decodes in-kernel, every
    other path decodes generically before accumulating.
    """
    def worker(st, cols, w_p, dl):
        st = jax.tree.map(lambda x: x[0], st)
        cols = jax.tree.map(lambda x: x[0], cols)
        w = w_p[0]
        dl = dl[0]
        if encodings and path != "kernel_fused":
            from repro.data import encodings as ENC  # local: core stays data-free
            cols = ENC.decode_cols(cols, encodings)
        if path == "scan":
            new_st, view = SC.scan_round_step(gla, st, cols, lanes)
        elif path == "kernel_fused":
            new_st = SC.fused_round_step(gla, st, cols, encodings)
            view = new_st
        else:
            delta = SC.ROUND_DELTA_FNS[path](gla, cols)
            new_st = delta if first else jax.tree.map(jnp.add, st, delta)
            view = new_st
        term = gla.estimator_terminate(view, {"d_local": dl})
        merged = lax.psum(
            jax.tree.map(lambda x: x * w.astype(x.dtype), term), axis_name)
        return (jax.tree.map(lambda x: x[None], new_st),
                jax.tree.map(lambda x: x[None], view), merged)

    from jax.sharding import PartitionSpec as PS
    pspec = PS(axis_name)
    fn = _shard_map(worker, mesh, (pspec, pspec, pspec, pspec),
                    (pspec, pspec, PS()))
    new_states, views, merged = fn(states, slice_shards, w_r, d_local)
    est = None
    if gla.estimate is not None:
        est = gla.estimate(merged, confidence, {"d_total": d_total})
    return new_states, views, merged, est


@functools.partial(jax.jit, static_argnames=("gla", "mesh", "axis_name"))
def session_final_sharded(gla: GLA, views, w_final: jnp.ndarray, *, mesh,
                          axis_name: str):
    """Merge the current per-partition round views into the session final —
    the same weighted psum the fused program ends with."""
    def worker(v, w_p):
        v = jax.tree.map(lambda x: x[0], v)
        merged = lax.psum(
            jax.tree.map(lambda x: x * w_p[0].astype(x.dtype), v), axis_name)
        return merged

    from jax.sharding import PartitionSpec as PS
    fn = _shard_map(worker, mesh, (PS(axis_name), PS(axis_name)), PS())
    return gla.terminate(fn(views, w_final))


@functools.partial(jax.jit, static_argnames=("gla", "confidence"))
def _estimates_jit(gla: GLA, merged_rounds, d_total, confidence: float):
    return jax.vmap(
        lambda s: gla.estimate(s, confidence, {"d_total": d_total})
    )(merged_rounds)


def run_sharded(gla: GLA, shards: dict, sched: jnp.ndarray, alive: jnp.ndarray,
                *, mesh, axis_name: str, mode: str, emit: str, lanes: int,
                snapshots: bool, confidence: float, sync_cost_model: bool = True):
    """Same math as engine._run_vmapped with partitions on ``axis_name``."""
    from repro.core.engine import QueryResult

    assert gla.merge_is_additive, "sharded path requires additive merges"
    if emit == "kernel" and mode == "sync":
        # No silent downgrade: with sync_cost_model the per-chunk
        # coordination scan replaces the scan entirely (the kernel dispatch
        # would never run), and the group-by kernel contract has no prefix
        # states for the pmin truncation even without it.
        if sync_cost_model:
            raise ValueError(
                "emit='kernel' is incompatible with mode='sync' + "
                "sync_cost_model=True: the per-chunk coordination scan "
                "bypasses the kernel dispatch — use emit='chunk', or pass "
                "sync_cost_model=False (scalar-SumState GLAs only)")
        if gla.kernel_num_groups is not None or gla.members:
            raise ValueError(
                "group-by/bundled emit='kernel' emits round states only; "
                "mode='sync' needs prefix states for the min-progress "
                "truncation — use emit='chunk' or mode='async'")
    if emit == "round" and mode == "sync" and not sync_cost_model:
        # Same silent-downgrade class: scan_rounds has no prefix states, so
        # the pmin truncation would be skipped and async round states would
        # come back labeled as synchronized estimates.
        raise ValueError(
            "emit='round' emits round states only; mode='sync' needs prefix "
            "states for the min-progress truncation — use emit='chunk'")
    P = shards["_mask"].shape[0]
    R = sched.shape[1] - 1
    # alive arrives [P] or [R, P]; ship it as [P, R] so the partition axis
    # leads and shards like everything else.
    alive2d = jnp.broadcast_to(alive, (R, P)).T if alive.ndim == 1 else alive.T
    merged_final, merged_rounds, d_total, d_local = _run_sharded_jit(
        gla, shards, jnp.asarray(sched), alive2d, mesh=mesh,
        axis_name=axis_name, mode=mode, emit=emit, lanes=lanes,
        snapshots=snapshots, sync_cost_model=sync_cost_model)
    final = gla.terminate(merged_final)
    estimates = None
    if snapshots and gla.estimate is not None:
        estimates = _estimates_jit(gla, merged_rounds, d_total, confidence)
    return QueryResult(final, merged_rounds, estimates, d_total, d_local)
