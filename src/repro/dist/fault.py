"""Node-failure model — paper §4.6 made executable (DESIGN.md §4).

The engine expresses failure as an ``alive`` mask: [P] for partitions dead
throughout the query, [R, P] for an injection schedule (partition p
disappears at round ``fail_at[p]`` and its state — including everything it
had already accumulated — is lost, so it is excluded from every merge from
that round on).  This module owns the masks and, crucially, the
*estimator-level consequences*, which differ per estimation model:

  * ``single``       — survives.  Under global randomization (§4.2) the
    union of surviving partitions' scans is still a uniform
    without-replacement sample of the whole dataset; the estimator stays
    unbiased.  The price is a *variance floor*: |S| can never reach |D|, so
    the (|D|-|S|) factor in Eq. (4) never vanishes and the confidence bounds
    never collapse to zero width (:func:`variance_floor`).
  * ``multiple``     — fails catastrophically.  Stratified sampling treats
    each partition as a stratum; a dead stratum's contribution has no
    surviving sample, its local estimator is gone, and nothing bounds the
    missing term — the honest interval is (-inf, +inf) from the failure
    round on.
  * ``synchronized`` — stalls.  The Wu et al. barrier waits for every
    partition to reach the same progress; a dead partition never arrives, so
    no snapshot after the failure round clears the barrier.  Estimates
    freeze at the last pre-failure snapshot (infinite bounds if the failure
    precedes the first snapshot).

The final (non-estimate) result is always the aggregate over surviving
partitions' data — exact for what was scanned, silent about what was lost;
that is precisely why the estimator-level accounting above matters.

This module covers the *fused* path (:func:`run_with_failures` injects a
whole-scan schedule and post-processes the stacked estimates).  The *live*
counterpart — failures injected or detected mid-scan on a running session —
is ``repro.core.session.FaultPolicy`` (DESIGN.md §9), which consumes the
same schedules and the per-round helpers here; :class:`FailingSource` is
the chaos wrapper that makes a streaming source actually die mid-scan.
"""
from __future__ import annotations

from typing import Mapping, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import engine
from repro.core import estimators as E
from repro.core.spec import QuerySpec
from repro.core.uda import GLA, Estimate
from repro.data import source as DSRC

# canonical home is the import-light data layer (sources raise it from
# worker threads without importing any engine code); re-exported here
# because callers think of it as part of the failure model
PartitionLostError = DSRC.PartitionLostError


def alive_mask(num_partitions: int, dead_partitions: Sequence[int]) -> np.ndarray:
    """[P] bool — False for partitions dead for the whole query."""
    alive = np.ones(num_partitions, bool)
    for p in dead_partitions:
        alive[p] = False
    return alive


def failure_schedule(
    num_partitions: int, rounds: int, fail_at: Mapping[int, int]
) -> np.ndarray:
    """[R, P] bool — partition p is alive during round r iff r < fail_at[p].

    ``fail_at[p] == 0`` means dead from the start; partitions absent from
    ``fail_at`` never fail.  Row r feeds the merge of snapshot r, so a
    partition contributes snapshots strictly before its failure round and is
    excluded (state lost) from then on.
    """
    sched = np.ones((rounds, num_partitions), bool)
    for p, r in fail_at.items():
        sched[r:, p] = False
    return sched


def first_failure_round(alive) -> Optional[int]:
    """Earliest round with a dead partition, or None if all live throughout."""
    alive = np.asarray(alive)
    if alive.ndim == 1:
        return 0 if not alive.all() else None
    dead_rows = np.flatnonzero(~alive.all(axis=1))
    return int(dead_rows[0]) if dead_rows.size else None


def _poison(est: Estimate, fail_round: int) -> Estimate:
    """Bounds -> (-inf, +inf) from ``fail_round`` on (multiple model)."""
    def after(x, v):
        r = jnp.arange(x.shape[0]).reshape((-1, *(1,) * (x.ndim - 1)))
        return jnp.where(r >= fail_round, v, x)

    return Estimate(
        estimate=est.estimate,
        lower=jax.tree.map(lambda x: after(x, -jnp.inf), est.lower),
        upper=jax.tree.map(lambda x: after(x, jnp.inf), est.upper),
        info=est.info,
    )


def _stall(est: Estimate, fail_round: int) -> Estimate:
    """Freeze estimates at the last pre-failure snapshot (synchronized model)."""
    if fail_round == 0:
        return Estimate(
            estimate=est.estimate,
            lower=jax.tree.map(lambda x: jnp.full_like(x, -jnp.inf), est.lower),
            upper=jax.tree.map(lambda x: jnp.full_like(x, jnp.inf), est.upper),
            info=est.info,
        )

    def freeze(x):
        r = jnp.arange(x.shape[0]).reshape((-1, *(1,) * (x.ndim - 1)))
        return jnp.where(r >= fail_round, x[fail_round - 1], x)

    return Estimate(
        estimate=jax.tree.map(freeze, est.estimate),
        lower=jax.tree.map(freeze, est.lower),
        upper=jax.tree.map(freeze, est.upper),
        info=est.info,
    )


def poison_bounds(est: Estimate) -> Estimate:
    """One round's Estimate with bounds forced to (-inf, +inf).

    Per-round sibling of :func:`_poison`/:func:`_stall` (which operate on
    round-stacked estimates): the live session driver applies the §4.6
    consequences round by round as failures happen, and this is both the
    ``multiple`` poison and the ``synchronized`` stall-before-first-round
    for a single round's estimate.  The point estimate is kept — it is the
    honest bounds, not the number, that §4.6 takes away.
    """
    return Estimate(
        estimate=est.estimate,
        lower=jax.tree.map(lambda x: jnp.full_like(x, -jnp.inf), est.lower),
        upper=jax.tree.map(lambda x: jnp.full_like(x, jnp.inf), est.upper),
        info=est.info,
    )


def run_with_failures(
    gla: GLA,
    shards: dict,
    dead_partitions: Sequence[int] = (),
    *,
    estimator: str = "single",
    rounds: int = 8,
    fail_at: Optional[Mapping[int, int]] = None,
    schedule: Optional[np.ndarray] = None,
    mode: str = "async",
    emit: str = "chunk",
    confidence: float = 0.95,
    mesh=None,
    axis_name: str = "data",
) -> engine.QueryResult:
    """Run a query under injected node failures and apply §4.6 semantics.

    ``dead_partitions`` fail before the query starts; ``fail_at`` maps
    partition -> failure round for mid-query failures.  ``estimator`` names
    the estimation model the GLA was built with — the post-processing of the
    bounds (poison / stall / pass-through) depends on it, not on the state.
    """
    P, C, L = shards["_mask"].shape
    if schedule is None:
        schedule = engine.uniform_schedule(P, C, rounds)
    R = schedule.shape[1] - 1
    if fail_at:
        at = {p: 0 for p in dead_partitions}
        at.update(fail_at)
        alive = failure_schedule(P, R, at)
    else:
        alive = alive_mask(P, dead_partitions)

    res = engine.run_query(
        QuerySpec(gla, schedule=schedule, sync=mode == "sync", emit=emit,
                  confidence=confidence, alive=alive),
        shards, mesh=mesh, axis_name=axis_name,
    )

    fr = first_failure_round(alive)
    if fr is None or res.estimates is None:
        return res
    if estimator == "multiple":
        return res._replace(estimates=_poison(res.estimates, fr))
    if estimator == "synchronized":
        return res._replace(estimates=_stall(res.estimates, fr))
    return res  # single: unbiased as-is, variance floor > 0


def variance_floor(
    gla: GLA, shards: dict, dead_partitions: Sequence[int]
) -> float:
    """Residual estimator variance at full scan of the surviving partitions.

    For the single model, failure caps |S| at the survivors' cardinality, so
    Eq. (4) bottoms out at a strictly positive value (0.0 when nothing
    died).  Only meaningful for SumState-shaped states (sum / groupby GLAs
    in the single or synchronized models).
    """
    P = shards["_mask"].shape[0]
    res = engine.run_query(
        QuerySpec(gla, rounds=1, alive=alive_mask(P, dead_partitions)),
        shards)
    full = jax.tree.map(lambda x: x[-1], res.snapshots)
    var = E.variance_estimate(full.sum, full.sumsq, full.scanned, res.d_total)
    return float(np.max(np.asarray(var)))


class FailingSource(DSRC.ChunkSource):
    """Chaos wrapper: partition p's storage dies at chunk ``fail_chunk[p]``.

    The first ``slice_cols`` call whose range touches a partition's fail
    chunk raises :class:`PartitionLostError` naming every newly-dead
    partition — surfacing through the session's streaming prefetcher
    exactly like a real read/device error would (the exception crosses the
    worker thread via the future).  Once a partition's loss has been
    *observed* this way, subsequent reads serve its columns and masks
    zeroed: the data is gone, not stale, and a zeroed mask contributes
    nothing to any additive merge.  Dataset-level stats — mask-chunk sums
    (|D| is a property of the data, not of which replicas survive) and the
    content fingerprint — delegate to the inner source.

    ``resident`` is False even over in-memory data so the wrapper always
    exercises the detection path the real failure would take.
    """

    resident = False

    def __init__(self, inner, fail_chunk: Mapping[int, int]):
        self.inner = DSRC.as_source(inner)
        self.spec = self.inner.spec
        for p in fail_chunk:
            if not 0 <= int(p) < self.spec.P:
                raise ValueError(
                    f"fail_chunk names partition {p}, but the source has "
                    f"P={self.spec.P}")
        self._fail = {int(p): int(c) for p, c in fail_chunk.items()}
        self._dead: set = set()

    def slice_cols(self, lo: int, hi: int) -> dict:
        newly = sorted(p for p, c in self._fail.items()
                       if c < hi and p not in self._dead)
        if newly:
            # record the deaths BEFORE raising: the exception may be
            # consumed on another thread while the next prefetch already
            # runs here, and that read must see the partitions dead
            self._dead.update(newly)
            raise PartitionLostError(newly)
        cols = {k: np.array(v, copy=True)
                for k, v in self.inner.slice_cols(lo, hi).items()}
        for p in self._dead:
            for v in cols.values():
                v[p] = 0
        return cols

    def mask_chunk_sums(self) -> np.ndarray:
        return self.inner.mask_chunk_sums()

    def fingerprint(self) -> str:
        return self.inner.fingerprint()
