"""Sharding rule table: logical parameter axes -> mesh axes (DESIGN.md §5).

Every parameter declares *logical* axis names in its :class:`ParamSpec`
(repro/models/spec.py); this module is the single place where logical names
meet a concrete mesh.  Rules:

  * exactly one dimension shards on ``model``, chosen by priority
    (``MODEL_PRIORITY``: experts > vocab > mlp > heads > kv > state > embed)
    among dimensions divisible by the axis size — indivisible candidates
    fall through to the next name, and if nothing divides, the parameter
    replicates.  This is why smollm's 9 heads fall back to sharding embed
    and grok's 8 experts fall back to tensor-parallel d_ff.
  * with ``opt_data_axis`` set (ZeRO / FSDP), one *additional* dimension
    shards on the data axis — the first remaining logical dimension that
    divides, never ``layers`` (the scanned layer stack must stay intact per
    device).
  * decode caches shard batch over the data axes and the sequence dimension
    over ``model`` (flash-decoding), via :func:`cache_pspecs`.

The table is pure shape arithmetic — it works on a real ``jax.Mesh`` or any
stand-in exposing ``axis_names`` and ``devices.shape`` (tests use a fake),
and never touches device state.
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
from jax.sharding import PartitionSpec as P

from repro.models.spec import ParamSpec, is_spec

# Priority for the single model-parallel dimension.  "layers" is absent by
# design: the scanned layer stack is never sharded.
MODEL_PRIORITY: Tuple[str, ...] = (
    "experts", "vocab", "mlp", "heads", "kv", "state", "embed")


def mesh_axis_size(mesh, axis: str) -> int:
    """Size of a named mesh axis (1 if the mesh does not have it)."""
    names = tuple(mesh.axis_names)
    if axis not in names:
        return 1
    i = names.index(axis)
    if hasattr(mesh, "devices"):  # jax.Mesh or test stand-in
        return int(mesh.devices.shape[i])
    return int(tuple(mesh.axis_sizes)[i])  # AbstractMesh (newer jax)


def ambient_mesh():
    """The mesh activations should be pinned against, or None.

    jax-version tolerant: prefers ``jax.sharding.get_abstract_mesh`` (newer
    jax, set via ``jax.set_mesh``), falls back to the thread-local physical
    mesh installed by ``with mesh:`` blocks, and returns None when neither
    is active so model-side pinning helpers become no-ops.
    """
    fn = getattr(jax.sharding, "get_abstract_mesh", None)
    if fn is not None:
        try:
            m = fn()
            if m is not None and not m.empty:
                return m
        except Exception:
            pass
    try:
        from jax._src import mesh as _mesh_internal
        pm = _mesh_internal.thread_resources.env.physical_mesh
        if pm is not None and not pm.empty:
            return pm
    except Exception:
        pass
    return None


def batch_axes(mesh) -> Tuple[str, ...]:
    """Data-parallel mesh axes, outermost first (pod crosses DCI)."""
    return tuple(a for a in ("pod", "data") if a in tuple(mesh.axis_names))


def spec_pspec(spec: ParamSpec, mesh, *, opt_data_axis: Optional[str] = None,
               model_axis: str = "model") -> P:
    """PartitionSpec for one parameter under the rule table."""
    assign = [None] * len(spec.shape)
    msize = mesh_axis_size(mesh, model_axis)
    if msize > 1:
        for name in MODEL_PRIORITY:
            hit = [
                i for i, lg in enumerate(spec.logical)
                if lg == name and spec.shape[i] % msize == 0
                and spec.shape[i] >= msize
            ]
            if hit:
                assign[hit[0]] = model_axis
                break
    if opt_data_axis is not None:
        dsize = mesh_axis_size(mesh, opt_data_axis)
        if dsize > 1:
            for i, lg in enumerate(spec.logical):
                if (lg is not None and lg != "layers" and assign[i] is None
                        and spec.shape[i] % dsize == 0
                        and spec.shape[i] >= dsize):
                    assign[i] = opt_data_axis
                    break
    return P(*assign)


def param_pspecs(spec_tree, mesh, *, opt_data_axis: Optional[str] = None):
    """PartitionSpec pytree for a ParamSpec tree."""
    return jax.tree.map(
        lambda s: spec_pspec(s, mesh, opt_data_axis=opt_data_axis),
        spec_tree, is_leaf=is_spec,
    )


def cache_pspecs(cache_abs, mesh, *, batch: int, seq_len: int,
                 model_axis: str = "model"):
    """Decode-cache PartitionSpecs: batch over data axes, sequence over
    ``model`` (flash-decoding).  Dimensions are recognized by size — cache
    layouts vary per architecture but batch/seq extents are unambiguous.
    """
    daxes = batch_axes(mesh)
    dsize = 1
    for a in daxes:
        dsize *= mesh_axis_size(mesh, a)
    dspec = daxes if len(daxes) > 1 else (daxes[0] if daxes else None)
    msize = mesh_axis_size(mesh, model_axis)

    def one(x):
        assign = [None] * len(x.shape)
        for i, d in enumerate(x.shape):
            if d == batch and dsize > 1 and d % dsize == 0:
                assign[i] = dspec
                break
        for i, d in enumerate(x.shape):
            if (assign[i] is None and d == seq_len and msize > 1
                    and d % msize == 0):
                assign[i] = model_axis
                break
        return P(*assign)

    return jax.tree.map(one, cache_abs)
