"""Distributed execution: sharding rules, the shard_map engine path, and the
node-failure model (DESIGN.md §4–§5).

Modules:
  * ``shard_engine`` — the ``jax.shard_map`` execution path over the ``data``
    mesh axis; same GLA math as the vmapped path (repro/core/scan.py), with
    async per-partition snapshot merging and the sync-mode per-chunk barrier.
  * ``fault``        — partition liveness masks, failure-injection schedules,
    and the estimator-level consequences of dead partitions (paper §4.6).
  * ``sharding``     — the logical-axis → mesh-axis rule table for model
    parameters, optimizer state (ZeRO), and decode caches.
"""
