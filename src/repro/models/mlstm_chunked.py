"""Chunkwise-parallel mLSTM — the TPU-native training form (§Perf x2).

The sequential cell updates C_t = f_t C_{t-1} + i_t v_t k_tᵀ one step at a
time: every token materializes a [dh, dh] matrix state (for xlstm-125m that
is 147K floats *per token per head* of backward-pass traffic — the 93 GB
peak measured on train_4k).  The recurrence is linear in C, so a chunk of c
steps collapses into matmuls (identical math, reassociated):

  intra-chunk:  P_ts = (q_t·k_s) · exp(F_t − F_s + logi_s − m_t),  s ≤ t
  inter-chunk:  q_t·C_in scaled by exp(F_t + m_in − m_t)
  state update: C_out = e^{F_c+m_in−m_out} C_in + (diag(w) V)ᵀ K-style matmul

where F_t = Σ_{s≤t} logf_s and m_* are the xLSTM log-scale stabilizers.
Everything runs on the MXU at [c, c] / [c, dh] granularity; per-step state
traffic disappears.  The xLSTM max(|n·q|, 1) denominator becomes
max(|den_t|, e^{−m_t}) in stabilized scale.

Validated against the sequential oracle in tests/test_mlstm_chunked.py
(allclose at 1e-4 over shape sweeps).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax import lax

NEG = -1e30


def _chunk_step(carry, xs):
    """One chunk.  carry: (C [B,H,d,d], n [B,H,d], m [B,H]); xs leaves
    [c, B, H, ...] (time-major within the chunk)."""
    C, n, m = carry
    c = xs[0].shape[0]
    # time-major [c,B,H,...] -> [B,H,c,...]
    q, k, v = (jnp.moveaxis(x, 0, 2) for x in (xs[0], xs[1], xs[2]))
    li = jnp.moveaxis(xs[3], 0, 2)            # [B,H,c]
    lf = jnp.moveaxis(xs[4], 0, 2)

    F = jnp.cumsum(lf, axis=-1)               # [B,H,c]  F_t
    a = F + m[..., None]                      # log-scale of C_in at step t
    # pairwise log weights D_ts = F_t - F_s + li_s  (s <= t)
    D = F[..., :, None] - F[..., None, :] + li[..., None, :]
    tri = jnp.tril(jnp.ones((c, c), bool))
    D = jnp.where(tri, D, NEG)
    # row stabilizer == the sequential m_t (max-plus recurrence closed form)
    m_row = jnp.maximum(a, jnp.max(D, axis=-1))          # [B,H,c]
    S = jnp.einsum("bhtd,bhsd->bhts", q, k)              # [B,H,c,c]
    P = S * jnp.exp(D - m_row[..., None])
    inter = jnp.exp(a - m_row)                           # [B,H,c]
    num = (jnp.einsum("bhts,bhsd->bhtd", P, v)
           + inter[..., None] * jnp.einsum("bhde,bhte->bhtd", C, q))
    den = (jnp.sum(P, axis=-1)
           + inter * jnp.einsum("bhd,bhtd->bht", n, q))
    # xLSTM floor max(|n·q|, 1) is defined in the *stabilized* scale, and
    # den here carries exactly the sequential stabilization (m_row == m_t)
    h = num / jnp.maximum(jnp.abs(den), 1.0)[..., None]

    # ---- state to next chunk ----
    Fc = F[..., -1]                                      # [B,H]
    w_log = Fc[..., None] - F + li                       # [B,H,c]
    m_new = jnp.maximum(Fc + m, jnp.max(w_log, axis=-1))
    w = jnp.exp(w_log - m_new[..., None])                # [B,H,c]
    decay = jnp.exp(Fc + m - m_new)                      # [B,H]
    C_new = (decay[..., None, None] * C
             + jnp.einsum("bhtd,bhte->bhde", v * w[..., None], k))
    n_new = decay[..., None] * n + jnp.einsum("bht,bhtd->bhd", w, k)
    return (C_new, n_new, m_new), jnp.moveaxis(h, 2, 0)  # h back to [c,B,H,d]


def mlstm_chunkwise(q, k, v, logi, logf, *, chunk: int = 128,
                    initial=None):
    """q/k/v [B,S,H,dh] (k pre-scaled), logi/logf [B,S,H] -> h [B,S,H,dh].

    Returns (h, (C, n, m) final).  Math == the sequential scan over
    `_mlstm_cell_step` (tests/test_mlstm_chunked.py).
    """
    B, S, H, dh = q.shape
    c = min(chunk, S)
    while S % c:
        c -= 1
    n_chunks = S // c

    def to_chunks(x):                         # [B,S,...] -> [n,c,B,H,...]
        x = jnp.moveaxis(x, 1, 0)             # [S,B,...]
        return x.reshape((n_chunks, c, *x.shape[1:]))

    xs = tuple(to_chunks(x) for x in (q, k, v, logi, logf))
    if initial is None:
        C0 = jnp.zeros((B, H, dh, dh), jnp.float32)
        n0 = jnp.zeros((B, H, dh), jnp.float32)
        m0 = jnp.zeros((B, H), jnp.float32)
        initial = (C0, n0, m0)
    final, hs = lax.scan(jax.checkpoint(_chunk_step), initial, xs)
    h = hs.reshape((S, B, H, dh))
    return jnp.moveaxis(h, 0, 1), final
