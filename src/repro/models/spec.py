"""Parameter specification system — one source of truth for shape, logical
sharding axes, and initialization of every parameter.

A model definition builds a pytree of :class:`ParamSpec`.  From that tree:

  * ``init_params``  — materialize arrays (smoke tests, real training)
  * ``abstract_params`` — ShapeDtypeStructs (dry-run: no allocation)
  * ``param_pspecs`` — PartitionSpecs via the sharding rule table
    (repro/dist/sharding.py), with divisibility fallback to replication.

Logical axis names used across models:
  "embed"   d_model              "mlp"     d_ff
  "heads"   attention heads      "kv"      kv heads
  "head_dim"                     "vocab"   (padded) vocabulary
  "experts" MoE experts          "layers"  scanned layer stack
  "state"   recurrent state width
"""
from __future__ import annotations

import dataclasses
from typing import Any, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass(frozen=True)
class ParamSpec:
    shape: Tuple[int, ...]
    logical: Tuple[Optional[str], ...]  # logical axis per dim (None = no shard)
    init: str = "normal"  # "normal" | "zeros" | "ones" | "embed"
    scale: float = 1.0    # stddev multiplier (fan-in handled per init kind)
    dtype: Any = jnp.bfloat16

    def __post_init__(self):
        assert len(self.shape) == len(self.logical), (self.shape, self.logical)


def is_spec(x) -> bool:
    return isinstance(x, ParamSpec)


def _init_one(spec: ParamSpec, key) -> jnp.ndarray:
    if spec.init == "zeros":
        return jnp.zeros(spec.shape, spec.dtype)
    if spec.init == "ones":
        return jnp.ones(spec.shape, spec.dtype)
    if spec.init == "embed":
        std = spec.scale
    else:
        fan_in = spec.shape[0] if len(spec.shape) >= 2 else max(spec.shape[-1], 1)
        std = spec.scale / np.sqrt(fan_in)
    return (jax.random.normal(key, spec.shape, jnp.float32) * std).astype(spec.dtype)


def _tree_with_keys(tree, key):
    """Deterministic per-leaf key from the tree path."""
    leaves, treedef = jax.tree.flatten(tree, is_leaf=is_spec)
    keys = jax.random.split(key, max(len(leaves), 1))
    return leaves, treedef, keys


def init_params(spec_tree, key):
    leaves, treedef, keys = _tree_with_keys(spec_tree, key)
    out = [_init_one(s, k) for s, k in zip(leaves, keys)]
    return jax.tree.unflatten(treedef, out)


def abstract_params(spec_tree):
    return jax.tree.map(
        lambda s: jax.ShapeDtypeStruct(s.shape, s.dtype), spec_tree, is_leaf=is_spec
    )


def cast_tree(tree, dtype):
    return jax.tree.map(
        lambda x: x.astype(dtype) if jnp.issubdtype(x.dtype, jnp.floating) else x,
        tree,
    )
