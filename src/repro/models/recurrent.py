"""Recurrent temporal-mixing blocks: RG-LRU (Griffin/RecurrentGemma),
mLSTM and sLSTM (xLSTM).

Each block exposes:
  *_specs(cfg)                      — ParamSpec tree
  *_train(p, x, cfg)                — full-sequence forward
  *_decode(p, x1, state, cfg)       — one-token step, carrying state
  *_state(cfg, batch)               — zero state (eval_shape-able)

Train-time parallelization:
  * RG-LRU is a linear diagonal recurrence → `lax.associative_scan` (O(log S)
    depth, fully parallel — the TPU-appropriate form).
  * mLSTM/sLSTM baseline is a sequential `lax.scan` over time.  mLSTM has a
    chunkwise-parallel form (repro/models/mlstm_chunked.py) which is the
    §Perf hillclimb for the xlstm arch; sLSTM is inherently sequential
    (recurrent weights inside the nonlinearity — xLSTM paper §2.2).

Cell states are kept in f32 regardless of activation dtype (stability).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax import lax

from repro.models.layers import apply_norm
from repro.models.spec import ParamSpec


def _norm_spec(d, kind, dtype):
    if kind == "rms":
        return {"scale": ParamSpec((d,), ("embed",), "ones", dtype=dtype)}
    return {"scale": ParamSpec((d,), ("embed",), "ones", dtype=dtype),
            "bias": ParamSpec((d,), ("embed",), "zeros", dtype=dtype)}


def _blocked_scan(step, carry, xs, block: int):
    """Two-level time scan: outer over S/block blocks, inner (rematted) over
    block steps.

    A flat S-step scan stores every per-step carry for the backward pass —
    for mLSTM that is S × [B,H,dh,dh] f32 (the 93 GB/device peak measured on
    xlstm train_4k, §Perf iteration x1).  Blocking stores carries only at
    block boundaries (S/block of them) and recomputes inside the block on
    the backward pass: memory ÷ block, +1 recompute of cheap elementwise
    cell math.
    """
    S = jax.tree.leaves(xs)[0].shape[0]
    b = min(block, S)
    while S % b:
        b -= 1
    n = S // b
    xs_b = jax.tree.map(lambda a: a.reshape((n, b, *a.shape[1:])), xs)

    @jax.checkpoint
    def outer(carry, xb):
        return lax.scan(step, carry, xb)

    carry, ys_b = lax.scan(outer, carry, xs_b)
    ys = jax.tree.map(lambda a: a.reshape((S, *a.shape[2:])), ys_b)
    return carry, ys


def _causal_conv(u, kernel):
    """Depthwise causal conv, u [B,S,w], kernel [taps,w]."""
    taps = kernel.shape[0]
    pad = jnp.pad(u, ((0, 0), (taps - 1, 0), (0, 0)))
    out = jnp.zeros_like(u)
    for t in range(taps):
        out = out + pad[:, t: t + u.shape[1]] * kernel[taps - 1 - t]
    return out


def _conv_step(x1, conv_state, kernel):
    """x1 [B,w]; conv_state [B,taps-1,w] (most recent last).

    Matches _causal_conv: kernel[j] multiplies x[t-j], so the window
    (oldest..newest) contracts against the reversed kernel.
    """
    taps = kernel.shape[0]
    window = jnp.concatenate([conv_state, x1[:, None]], axis=1)  # [B,taps,w]
    out = jnp.einsum("btw,tw->bw", window, kernel[::-1])
    return out, window[:, 1:]


# =========================================================================== RG-LRU

def rglru_specs(cfg, dtype):
    d, w = cfg.d_model, cfg.lru_width or cfg.d_model
    return {
        "ln1": _norm_spec(d, cfg.norm, dtype),
        "w_gate": ParamSpec((d, w), ("embed", "state"), dtype=dtype),
        "w_rec": ParamSpec((d, w), ("embed", "state"), dtype=dtype),
        "conv": ParamSpec((4, w), (None, "state"), scale=0.5, dtype=dtype),
        "ga_w": ParamSpec((w,), ("state",), "zeros", dtype=dtype),
        "ga_b": ParamSpec((w,), ("state",), "zeros", dtype=dtype),
        "gx_w": ParamSpec((w,), ("state",), "zeros", dtype=dtype),
        "gx_b": ParamSpec((w,), ("state",), "zeros", dtype=dtype),
        "lam": ParamSpec((w,), ("state",), "ones", dtype=jnp.float32),
        "w_out": ParamSpec((w, d), ("state", "embed"), dtype=dtype),
    }


_LRU_C = 8.0


def _rglru_gates(p, u):
    """u [.., w] conv output -> (log_a, gated_input) in f32."""
    uf = u.astype(jnp.float32)
    r = jax.nn.sigmoid(
        uf * p["ga_w"].astype(jnp.float32) + p["ga_b"].astype(jnp.float32))
    i = jax.nn.sigmoid(
        uf * p["gx_w"].astype(jnp.float32) + p["gx_b"].astype(jnp.float32))
    log_a = -_LRU_C * r * jax.nn.softplus(p["lam"].astype(jnp.float32))
    a = jnp.exp(log_a)
    b = jnp.sqrt(jnp.maximum(1.0 - a * a, 1e-12)) * (i * uf)
    return a, b


def rglru_train(p, x, cfg):
    h = apply_norm(x, p["ln1"], cfg.norm)
    g = jax.nn.gelu(h @ p["w_gate"])
    u = _causal_conv(h @ p["w_rec"], p["conv"])
    a, b = _rglru_gates(p, u)

    def comb(c1, c2):
        a1, b1 = c1
        a2, b2 = c2
        return a1 * a2, a2 * b1 + b2

    _, hseq = lax.associative_scan(comb, (a, b), axis=1)
    out = (g * hseq.astype(x.dtype)) @ p["w_out"]
    u_in = (apply_norm(x, p["ln1"], cfg.norm) @ p["w_rec"]).astype(jnp.float32)
    state = {"h": hseq[:, -1], "conv": u_in[:, -3:]}
    return x + out, state


def rglru_state(cfg, batch):
    w = cfg.lru_width or cfg.d_model
    return {
        "h": jnp.zeros((batch, w), jnp.float32),
        "conv": jnp.zeros((batch, 3, w), jnp.float32),
    }


def rglru_decode(p, x1, state, cfg):
    """x1 [B, d] one token."""
    h = apply_norm(x1, p["ln1"], cfg.norm)
    g = jax.nn.gelu(h @ p["w_gate"])
    u_in = (h @ p["w_rec"]).astype(jnp.float32)
    u, conv_new = _conv_step(u_in, state["conv"], p["conv"].astype(jnp.float32))
    a, b = _rglru_gates(p, u)
    h_new = a * state["h"] + b
    out = (g * h_new.astype(x1.dtype)) @ p["w_out"]
    return x1 + out, {"h": h_new, "conv": conv_new}


# =========================================================================== mLSTM

def _mlstm_dims(cfg):
    d = cfg.d_model
    di = 2 * d
    H = cfg.num_heads
    dh = di // H
    return d, di, H, dh


def mlstm_specs(cfg, dtype):
    d, di, H, dh = _mlstm_dims(cfg)
    return {
        "ln1": _norm_spec(d, cfg.norm, dtype),
        "w_up": ParamSpec((d, di), ("embed", "state"), dtype=dtype),
        "w_z": ParamSpec((d, di), ("embed", "state"), dtype=dtype),
        "conv": ParamSpec((4, di), (None, "state"), scale=0.5, dtype=dtype),
        "wq": ParamSpec((di, H, dh), ("state", "heads", None), dtype=dtype),
        "wk": ParamSpec((di, H, dh), ("state", "heads", None), dtype=dtype),
        "wv": ParamSpec((di, H, dh), ("state", "heads", None), dtype=dtype),
        "w_if": ParamSpec((di, 2 * H), ("state", None), scale=0.1, dtype=dtype),
        "b_if": ParamSpec((2 * H,), (None,), "zeros", dtype=jnp.float32),
        "w_down": ParamSpec((di, d), ("state", "embed"), dtype=dtype),
    }


def _mlstm_cell_step(C, n, m, q, k, v, logi, logf):
    """One stabilized mLSTM step.  C [B,H,dh,dh]; n [B,H,dh]; m [B,H]."""
    m_new = jnp.maximum(logf + m, logi)
    i_p = jnp.exp(logi - m_new)
    f_p = jnp.exp(logf + m - m_new)
    C_new = f_p[..., None, None] * C + i_p[..., None, None] * (
        v[..., :, None] * k[..., None, :])
    n_new = f_p[..., None] * n + i_p[..., None] * k
    num = jnp.einsum("bhde,bhe->bhd", C_new, q)
    den = jnp.maximum(jnp.abs(jnp.einsum("bhd,bhd->bh", n_new, q)), 1.0)
    h = num / den[..., None]
    return C_new, n_new, m_new, h


def _mlstm_qkv(p, u):
    """u [.., di] conv output -> q,k,v,[logi,logf] in f32."""
    q = jnp.einsum("...i,ihd->...hd", u, p["wq"]).astype(jnp.float32)
    k = jnp.einsum("...i,ihd->...hd", u, p["wk"]).astype(jnp.float32)
    v = jnp.einsum("...i,ihd->...hd", u, p["wv"]).astype(jnp.float32)
    dh = q.shape[-1]
    k = k / jnp.sqrt(jnp.float32(dh))
    gates = (u @ p["w_if"]).astype(jnp.float32) + p["b_if"]
    H = q.shape[-2]
    logi = gates[..., :H]
    logf = jax.nn.log_sigmoid(gates[..., H:])
    return q, k, v, logi, logf


def mlstm_train(p, x, cfg):
    d, di, H, dh = _mlstm_dims(cfg)
    B, S, _ = x.shape
    hin = apply_norm(x, p["ln1"], cfg.norm)
    z = hin @ p["w_z"]
    u = _causal_conv(hin @ p["w_up"], p["conv"])
    q, k, v, logi, logf = _mlstm_qkv(p, u)

    def step(carry, xs):
        C, n, m = carry
        qt, kt, vt, it, ft = xs
        C, n, m, h = _mlstm_cell_step(C, n, m, qt, kt, vt, it, ft)
        return (C, n, m), h

    C0 = jnp.zeros((B, H, dh, dh), jnp.float32)
    n0 = jnp.zeros((B, H, dh), jnp.float32)
    m0 = jnp.zeros((B, H), jnp.float32)
    if cfg.mlstm_form == "chunkwise":
        from repro.models.mlstm_chunked import mlstm_chunkwise
        hseq, (Cf, nf, mf) = mlstm_chunkwise(q, k, v, logi, logf, chunk=128)
        hs = hseq.reshape(B, S, di)
    else:
        xs = jax.tree.map(lambda a: jnp.moveaxis(a, 1, 0),
                          (q, k, v, logi, logf))
        (Cf, nf, mf), hs = _blocked_scan(step, (C0, n0, m0), xs, block=128)
        hs = jnp.moveaxis(hs, 0, 1).reshape(B, S, di)  # [B,S,H,dh]->[B,S,di]
    out = (hs.astype(x.dtype) * jax.nn.silu(z)) @ p["w_down"]
    u_in = (hin @ p["w_up"]).astype(jnp.float32)
    state = {"C": Cf, "n": nf, "m": mf, "conv": u_in[:, -3:]}
    return x + out, state


def mlstm_state(cfg, batch):
    d, di, H, dh = _mlstm_dims(cfg)
    return {
        "C": jnp.zeros((batch, H, dh, dh), jnp.float32),
        "n": jnp.zeros((batch, H, dh), jnp.float32),
        "m": jnp.zeros((batch, H), jnp.float32),
        "conv": jnp.zeros((batch, 3, di), jnp.float32),
    }


def mlstm_decode(p, x1, state, cfg):
    hin = apply_norm(x1, p["ln1"], cfg.norm)
    z = hin @ p["w_z"]
    u_in = (hin @ p["w_up"]).astype(jnp.float32)
    u, conv_new = _conv_step(u_in, state["conv"], p["conv"].astype(jnp.float32))
    q, k, v, logi, logf = _mlstm_qkv(p, u.astype(x1.dtype))
    C, n, m, h = _mlstm_cell_step(state["C"], state["n"], state["m"],
                                  q, k, v, logi, logf)
    di = u.shape[-1]
    hf = h.reshape(x1.shape[0], di)
    out = (hf.astype(x1.dtype) * jax.nn.silu(z)) @ p["w_down"]
    return x1 + out, {"C": C, "n": n, "m": m, "conv": conv_new}


# =========================================================================== sLSTM

def _slstm_dims(cfg):
    d = cfg.d_model
    H = cfg.num_heads
    dh = d // H
    fd = -(-int(d * 8 / 3) // 64) * 64
    return d, H, dh, fd


def slstm_specs(cfg, dtype):
    d, H, dh, fd = _slstm_dims(cfg)
    def gate():
        return ParamSpec((d, H, dh), ("embed", "heads", None), scale=0.5,
                         dtype=dtype)

    def rec():
        return ParamSpec((H, dh, dh), ("heads", None, None), scale=0.5,
                         dtype=dtype)

    def bias():
        return ParamSpec((H, dh), ("heads", None), "zeros",
                         dtype=jnp.float32)
    return {
        "ln1": _norm_spec(d, cfg.norm, dtype),
        "wz": gate(), "wi": gate(), "wf": gate(), "wo": gate(),
        "rz": rec(), "ri": rec(), "rf": rec(), "ro": rec(),
        "bz": bias(), "bi": bias(), "bf": bias(), "bo": bias(),
        "ln2": _norm_spec(d, cfg.norm, dtype),
        "ffn_wi": ParamSpec((d, 2 * fd), ("embed", "mlp"), dtype=dtype),
        "ffn_wo": ParamSpec((fd, d), ("mlp", "embed"), dtype=dtype),
    }


def _slstm_step(p, xt, c, n, m, h):
    """xt [B,d] pre-projected gate inputs; states [B,H,dh] f32."""

    def pre(w, r, b):
        return (jnp.einsum("bd,dhe->bhe", xt, w).astype(jnp.float32)
                + jnp.einsum("bhd,hde->bhe", h, r.astype(jnp.float32)) + b)

    z = jnp.tanh(pre(p["wz"], p["rz"], p["bz"]))
    logi = pre(p["wi"], p["ri"], p["bi"])
    logf = jax.nn.log_sigmoid(pre(p["wf"], p["rf"], p["bf"]))
    o = jax.nn.sigmoid(pre(p["wo"], p["ro"], p["bo"]))
    m_new = jnp.maximum(logf + m, logi)
    i_p = jnp.exp(logi - m_new)
    f_p = jnp.exp(logf + m - m_new)
    c_new = f_p * c + i_p * z
    n_new = jnp.maximum(f_p * n + i_p, 1e-6)
    h_new = o * c_new / n_new
    return c_new, n_new, m_new, h_new


def slstm_train(p, x, cfg):
    d, H, dh, fd = _slstm_dims(cfg)
    B, S, _ = x.shape
    hin = apply_norm(x, p["ln1"], cfg.norm)

    def step(carry, xt):
        c, n, m, h = carry
        c, n, m, h = _slstm_step(p, xt, c, n, m, h)
        return (c, n, m, h), h

    z0 = jnp.zeros((B, H, dh), jnp.float32)
    (cf, nf, mf, hfin), hs = _blocked_scan(step, (z0, z0, z0, z0),
                                           jnp.moveaxis(hin, 1, 0), block=128)
    hs = jnp.moveaxis(hs, 0, 1).reshape(B, S, d).astype(x.dtype)
    x = x + hs
    # gated FFN (xLSTM post-up-projection block)
    hf = apply_norm(x, p["ln2"], cfg.norm)
    u = hf @ p["ffn_wi"]
    a, b = jnp.split(u, 2, axis=-1)
    out = x + (jax.nn.gelu(a) * b) @ p["ffn_wo"]
    state = {"c": cf, "n": nf, "m": mf, "h": hfin}
    return out, state


def slstm_state(cfg, batch):
    d, H, dh, fd = _slstm_dims(cfg)
    z = jnp.zeros((batch, H, dh), jnp.float32)
    return {"c": z, "n": z, "m": z, "h": z}


def slstm_decode(p, x1, state, cfg):
    d, H, dh, fd = _slstm_dims(cfg)
    hin = apply_norm(x1, p["ln1"], cfg.norm)
    c, n, m, h = _slstm_step(p, hin, state["c"], state["n"], state["m"],
                             state["h"])
    x1 = x1 + h.reshape(x1.shape[0], d).astype(x1.dtype)
    hf = apply_norm(x1, p["ln2"], cfg.norm)
    u = hf @ p["ffn_wi"]
    a, b = jnp.split(u, 2, axis=-1)
    out = x1 + (jax.nn.gelu(a) * b) @ p["ffn_wo"]
    return out, {"c": c, "n": n, "m": m, "h": h}
