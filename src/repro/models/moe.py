"""Mixture-of-Experts layer — grouped capacity dispatch, TPU-native.

Scatter-free-ish design (DESIGN.md §3): tokens are processed in *groups*
aligned with the data-parallel shards (the GSPMD MoE pattern).  Within a
group, each (token, slot) pair is ranked inside its chosen expert with a
sort-free cummax trick, dropped beyond capacity, scattered into a dense
[groups, E, C, d] buffer, pushed through the expert matmuls on the MXU, and
gathered back weighted by the router gate.

Sharding: the buffer and expert weights carry logical axis "experts"; the
rule table (repro/dist/sharding.py) puts "experts" on the `model` mesh axis
when E divides it (llama4: 128/16 → EP) and otherwise falls back to sharding
d_ff within the expert (grok: 8 experts → TP-within-expert).  The g axis is
"batch"-logical → `data`, so dispatch scatters stay device-local and the
expert einsum induces the canonical all-to-all.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.layers import mlp_act


def _ranks_within_expert(eids: jnp.ndarray, num_experts: int) -> jnp.ndarray:
    """rank[t] = #previous tokens in this group that chose the same expert.

    eids [T] int32.  argsort-based: stable-sort token indices by expert, then
    positions within equal-expert runs are (iota - run_start).
    """
    T = eids.shape[0]
    order = jnp.argsort(eids, stable=True)                     # [T]
    e_sorted = eids[order]
    iota = jnp.arange(T, dtype=jnp.int32)
    is_start = jnp.concatenate(
        [jnp.ones((1,), bool), e_sorted[1:] != e_sorted[:-1]])
    run_start = jax.lax.cummax(jnp.where(is_start, iota, 0))
    pos_in_run = iota - run_start
    ranks = jnp.zeros((T,), jnp.int32).at[order].set(pos_in_run)
    return ranks


def _pin_expert_weights(p, cfg):
    """Force FSDP-sharded expert weights to gather *before* the expert
    einsums.

    Under FSDP the weights carry a `data` shard on d or f; left alone,
    GSPMD contracts against the sharded dim and all-reduces the expert
    *outputs* ([g,E,cap,f] — 5.5 TB/step measured on grok train_4k, §Perf
    g2) instead of all-gathering the ~0.2 GB weight shard.  Pinning the
    weights to their model-only sharding at use restores the intended
    FSDP schedule: gather weights, compute locally, reduce grads.
    No-op without an ambient mesh.
    """
    from repro.dist import sharding as SH
    mesh = SH.ambient_mesh()
    if mesh is None or "model" not in tuple(mesh.axis_names):
        return p
    from jax.sharding import PartitionSpec as PS
    msize = SH.mesh_axis_size(mesh, "model")
    if cfg.num_experts % msize == 0 and cfg.num_experts >= msize:
        wi_spec, wo_spec = PS("model", None, None), PS("model", None, None)
    elif cfg.d_ff % msize == 0:
        wi_spec, wo_spec = PS(None, None, "model"), PS(None, "model", None)
    else:
        wi_spec = wo_spec = PS(None, None, None)
    out = dict(p)
    out["wi"] = jax.lax.with_sharding_constraint(p["wi"], wi_spec)
    if "wg" in p:
        out["wg"] = jax.lax.with_sharding_constraint(p["wg"], wi_spec)
    out["wo"] = jax.lax.with_sharding_constraint(p["wo"], wo_spec)
    return out


def moe_mlp(p, x, cfg, *, groups: int):
    """x [B, S, d] -> [B, S, d] through top-k routed experts.

    p: router [d, E]; wi/wg [E, d, f]; wo [E, f, d].
    """
    # NOTE (§Perf g2, REFUTED): pinning FSDP'd expert weights to model-only
    # sharding before the einsums (forcing a weight gather) was measured
    # 2.5x WORSE on grok — GSPMD replicated the expert compute 8x instead.
    # The helper is kept for reference; GSPMD's own schedule (output
    # all-reduce over the weight-sharded contraction) wins here.
    B, S, d = x.shape
    E, k = cfg.num_experts, cfg.experts_per_token
    T = B * S
    g = min(groups, T)
    while T % g:
        g -= 1
    Tg = T // g
    cap = max(8, int(-(-Tg * k * cfg.expert_capacity_factor // E)))
    cap = min(cap, Tg)

    xf = x.reshape(g, Tg, d)
    logits = jnp.einsum("gtd,de->gte", xf, p["router"].astype(x.dtype))
    probs = jax.nn.softmax(logits.astype(jnp.float32), axis=-1)
    gate_vals, eidx = jax.lax.top_k(probs, k)                  # [g, Tg, k]
    gate_vals = gate_vals / jnp.sum(gate_vals, axis=-1, keepdims=True)

    # flatten (token, slot) pairs per group
    e_flat = eidx.reshape(g, Tg * k)
    ranks = jax.vmap(lambda e: _ranks_within_expert(e, E))(e_flat)
    keep = (ranks < cap).astype(jnp.float32) * gate_vals.reshape(g, Tg * k)

    # dispatch: dense [g, E, C, d] buffer (device-local scatter: g ~ data)
    tok_idx = jnp.repeat(jnp.arange(Tg, dtype=jnp.int32), k)   # [Tg*k]
    x_pairs = jnp.take(xf, tok_idx, axis=1)                    # [g, Tg*k, d]
    buf = jnp.zeros((g, E, cap, d), x.dtype)
    gi = jnp.broadcast_to(jnp.arange(g, dtype=jnp.int32)[:, None], e_flat.shape)
    buf = buf.at[gi, e_flat, jnp.minimum(ranks, cap - 1)].add(
        x_pairs * (ranks < cap)[..., None].astype(x.dtype))

    # expert MLP on the MXU: [g, E, C, d] x [E, d, f]
    if cfg.mlp_gated:
        h = mlp_act(jnp.einsum("gecd,edf->gecf", buf, p["wi"]), cfg.mlp_act)
        h = h * jnp.einsum("gecd,edf->gecf", buf, p["wg"])
    else:
        h = mlp_act(jnp.einsum("gecd,edf->gecf", buf, p["wi"]), cfg.mlp_act)
    out_buf = jnp.einsum("gecf,efd->gecd", h, p["wo"])         # [g, E, C, d]

    # combine: gather each (token, slot)'s expert output, weight by gate
    out_pairs = out_buf[gi, e_flat, jnp.minimum(ranks, cap - 1)]   # [g, Tg*k, d]
    out_pairs = out_pairs * keep[..., None].astype(out_pairs.dtype)
    out = jnp.sum(out_pairs.reshape(g, Tg, k, d), axis=2)
    # auxiliary load-balance loss ingredients (Switch-style)
    me = jnp.mean(probs, axis=(0, 1))                              # [E]
    ce = jnp.mean(
        jax.nn.one_hot(eidx[..., 0], E, dtype=jnp.float32), axis=(0, 1))
    aux = E * jnp.sum(me * ce)
    return out.reshape(B, S, d), aux
