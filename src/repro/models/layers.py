"""Transformer substrate layers: norms, RoPE, attention, MLP.

Attention is a pure-JAX blockwise ("flash") implementation: an *unrolled*
loop over query blocks, each with a `lax.scan` over exactly the key/value
blocks that query block can see (triangle scheduling).  This keeps peak
activation memory at O(q_block · kv_block) per head instead of O(S²) and —
because the block ranges are static — performs **zero fully-masked-block
FLOPs** for causal/chunked/windowed masks, which keeps the HLO FLOP count
honest for the roofline analysis.

Mask modes:
  causal  — standard autoregressive
  chunk   — attend only within the surrounding `window`-sized chunk
            (Llama-4 style chunked local attention), causal inside
  window  — sliding window of `window` past positions (RG local attention)
  full    — bidirectional (encoder / cross attention)
"""
from __future__ import annotations

import math
from typing import Optional

import jax
import jax.numpy as jnp
from jax import lax

NEG_INF = -1e30


# --------------------------------------------------------------------------- norms

def rms_norm(x, gamma, eps=1e-6):
    x32 = x.astype(jnp.float32)
    var = jnp.mean(x32 * x32, axis=-1, keepdims=True)
    return (x32 * jax.lax.rsqrt(var + eps)).astype(x.dtype) * gamma


def layer_norm(x, gamma, beta, eps=1e-5):
    x32 = x.astype(jnp.float32)
    mu = jnp.mean(x32, axis=-1, keepdims=True)
    var = jnp.var(x32, axis=-1, keepdims=True)
    y = (x32 - mu) * jax.lax.rsqrt(var + eps)
    return y.astype(x.dtype) * gamma + beta


def apply_norm(x, p, kind):
    if kind == "rms":
        return rms_norm(x, p["scale"])
    return layer_norm(x, p["scale"], p["bias"])


# --------------------------------------------------------------------------- rope

def rope_freqs(head_dim: int, theta: float):
    return theta ** (-jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim)


def apply_rope(x, positions, theta: float):
    """x [B,S,H,dh] with positions [S], or [B,H,dh] with scalar position."""
    dh = x.shape[-1]
    freqs = rope_freqs(dh, theta)                          # [dh/2]
    pos = jnp.asarray(positions, jnp.float32)
    ang = pos[..., None] * freqs                           # [S, dh/2] | [dh/2]
    if x.ndim == 4:                                        # [B,S,H,dh]
        ang = ang.reshape((1,) + ang.shape[:-1] + (1, dh // 2))
    cos, sin = jnp.cos(ang), jnp.sin(ang)
    x1, x2 = x[..., : dh // 2], x[..., dh // 2:]
    x1f, x2f = x1.astype(jnp.float32), x2.astype(jnp.float32)
    out = jnp.concatenate([x1f * cos - x2f * sin, x2f * cos + x1f * sin], axis=-1)
    return out.astype(x.dtype)


def softcap(scores, cap: Optional[float]):
    if cap is None:
        return scores
    return cap * jnp.tanh(scores / cap)


# --------------------------------------------------------------------------- flash attention

def _kv_block_range(i, n_kv, qb, kvb, mode, window):
    """Static kv-block range [lo, hi) visible to query block i."""
    if mode == "full":
        return 0, n_kv
    hi = min(n_kv, -(-((i + 1) * qb) // kvb))  # causal upper bound
    if mode == "causal":
        return 0, hi
    if mode == "window":
        lo = max(0, (i * qb - window) // kvb)
        return lo, hi
    if mode == "chunk":
        lo = ((i * qb) // window) * (window // kvb)
        return lo, hi
    raise ValueError(mode)


def flash_attention(q, k, v, *, mode="causal", window=None, cap=None,
                    q_block=1024, kv_block=1024):
    """q [B,Sq,H,dh], k/v [B,Sk,K,dh] -> [B,Sq,H,dh].

    Query positions are aligned with key positions (q_offset=0); the decode
    path (single new token against a cache) is `decode_attention` below.
    """
    B, Sq, H, dh = q.shape
    _, Sk, K, _ = k.shape
    G = H // K

    def pick(S, target):
        b = min(target, S)
        while S % b:
            b -= 1
        return b

    if mode in ("window", "chunk") and window is not None and window >= Sk:
        mode = "causal"      # the window covers the whole sequence
    if mode in ("window", "chunk"):
        assert window is not None
        qb = pick(Sq, min(q_block, window))
        kvb = pick(Sk, min(kv_block, window))
        assert window % kvb == 0, (
            f"window {window} must be a multiple of kv block {kvb}")
    else:
        qb = pick(Sq, q_block)
        kvb = pick(Sk, kv_block)
    n_q, n_kv = Sq // qb, Sk // kvb
    scale = 1.0 / math.sqrt(dh)
    kpos_all = jnp.arange(Sk, dtype=jnp.int32).reshape(n_kv, kvb)

    outs = []
    for i in range(n_q):
        lo, hi = _kv_block_range(i, n_kv, qb, kvb, mode, window)
        qi = q[:, i * qb:(i + 1) * qb].reshape(B, qb, K, G, dh)
        qpos = i * qb + jnp.arange(qb, dtype=jnp.int32)
        k_blocks = k[:, lo * kvb:hi * kvb].reshape(B, hi - lo, kvb, K, dh)
        v_blocks = v[:, lo * kvb:hi * kvb].reshape(B, hi - lo, kvb, K, dh)
        kp_blocks = kpos_all[lo:hi]

        m0 = jnp.full((B, qb, K, G), NEG_INF, jnp.float32)
        l0 = jnp.zeros((B, qb, K, G), jnp.float32)
        o0 = jnp.zeros((B, qb, K, G, dh), jnp.float32)

        def step(carry, xs, qi=qi, qpos=qpos):
            m, den, o = carry
            kj, vj, kp = xs
            s = jnp.einsum("bqkgd,btkd->bqkgt", qi, kj,
                           preferred_element_type=jnp.float32) * scale
            s = softcap(s, cap)
            if mode != "full":
                msk = kp[None, :] <= qpos[:, None]                      # causal
                if mode == "window":
                    msk &= kp[None, :] > (qpos[:, None] - window)
                elif mode == "chunk":
                    msk &= (kp[None, :] // window) == (qpos[:, None] // window)
                s = jnp.where(msk[None, :, None, None, :], s, NEG_INF)
            m_new = jnp.maximum(m, jnp.max(s, axis=-1))
            p = jnp.exp(s - m_new[..., None])
            corr = jnp.exp(m - m_new)
            den_new = den * corr + jnp.sum(p, axis=-1)
            o_new = o * corr[..., None] + jnp.einsum(
                "bqkgt,btkd->bqkgd", p.astype(vj.dtype), vj,
                preferred_element_type=jnp.float32)
            return (m_new, den_new, o_new), None

        # remat the kv-block body: backward recomputes the [qb,kvb]
        # score/probability blocks instead of storing them per step —
        # the flash-attention memory property under reverse-mode
        (m, den, o), _ = lax.scan(jax.checkpoint(step), (m0, l0, o0), (
            jnp.moveaxis(k_blocks, 1, 0), jnp.moveaxis(v_blocks, 1, 0), kp_blocks))
        o = o / jnp.maximum(den, 1e-30)[..., None]
        outs.append(o.reshape(B, qb, H, dh))
    return jnp.concatenate(outs, axis=1).astype(q.dtype)


def decode_attention(q, k_cache, v_cache, valid, *, cap=None):
    """One-token attention against a cache.

    q [B,H,dh]; k/v_cache [B,S,K,dh]; valid [B,S] or [S] bool.
    Flash-decoding across a sequence-sharded cache comes for free under
    GSPMD: the softmax/contraction over the sharded S dim lowers to partial
    reductions + a tiny all-reduce.
    """
    B, H, dh = q.shape
    K = k_cache.shape[2]
    G = H // K
    # cache operands cast to the query compute dtype (bf16 on TPU); f32
    # accumulation via preferred_element_type — no f32 cache copy
    qh = q.reshape(B, K, G, dh)
    s = jnp.einsum("bkgd,btkd->bkgt", qh, k_cache.astype(q.dtype),
                   preferred_element_type=jnp.float32)
    s = s / math.sqrt(dh)
    s = softcap(s, cap)
    if valid.ndim == 1:
        valid = valid[None, :]
    s = jnp.where(valid[:, None, None, :], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bkgt,btkd->bkgd", p.astype(q.dtype),
                   v_cache.astype(q.dtype),
                   preferred_element_type=jnp.float32)
    return o.reshape(B, H, dh).astype(q.dtype)


# --------------------------------------------------------------------------- mlp

def mlp_act(x, kind: str):
    if kind == "silu":
        return jax.nn.silu(x)
    if kind == "gelu":
        return jax.nn.gelu(x)
    if kind == "relu2":
        r = jax.nn.relu(x)
        return r * r
    raise ValueError(kind)


def mlp(p, x, cfg):
    """Gated (SwiGLU-style) or plain MLP."""
    if cfg.mlp_gated:
        h = mlp_act(x @ p["wi"], cfg.mlp_act) * (x @ p["wg"])
    else:
        h = mlp_act(x @ p["wi"], cfg.mlp_act)
    return h @ p["wo"]
