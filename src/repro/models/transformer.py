"""Model assembly: param specs, train forward, prefill, and decode step for
every assigned architecture family (dense / MoE / enc-dec / VLM / hybrid /
SSM), driven entirely by ArchConfig.

Layer-stack structure: the config's ``block_pattern`` is cycled over
``num_layers``.  Full pattern repetitions are *scanned* (params stacked on a
leading "layers" axis — one trace per group keeps compile time flat in
depth); leftover tail layers are applied unscanned.  Each block type owns
its params, its decode-cache layout, and its train/decode apply:

  attn           global causal attention + MLP/MoE
  attn_chunked   chunked/windowed local attention + MLP/MoE (ring cache)
  rglru          RG-LRU temporal mixing + MLP
  mlstm / slstm  xLSTM blocks (self-contained)

Cross-entropy is computed in sequence chunks against the (model-sharded)
unembedding so the full [B,S,V] logits tensor never materializes — with
202K vocabularies that tensor would dominate HBM.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict

import jax
import jax.numpy as jnp
from jax import lax

from repro.configs.base import ArchConfig
from repro.models import recurrent as R
from repro.models.layers import (apply_norm, apply_rope, decode_attention,
                                 flash_attention, mlp)
from repro.models.moe import moe_mlp
from repro.models.spec import ParamSpec

MAX_LEARNED_POS = 32768


# =========================================================================== specs

def _norm_spec(d, kind, dtype):
    if kind == "rms":
        return {"scale": ParamSpec((d,), ("embed",), "ones", dtype=dtype)}
    return {"scale": ParamSpec((d,), ("embed",), "ones", dtype=dtype),
            "bias": ParamSpec((d,), ("embed",), "zeros", dtype=dtype)}


def _mlp_specs(cfg: ArchConfig, dtype):
    d, f = cfg.d_model, cfg.d_ff
    if cfg.num_experts:
        E = cfg.num_experts
        s = {
            "router": ParamSpec((d, E), ("embed", None), dtype=jnp.float32),
            "wi": ParamSpec((E, d, f), ("experts", "embed", "mlp"), dtype=dtype),
            "wo": ParamSpec((E, f, d), ("experts", "mlp", "embed"), dtype=dtype),
        }
        if cfg.mlp_gated:
            s["wg"] = ParamSpec((E, d, f), ("experts", "embed", "mlp"), dtype=dtype)
        return s
    s = {
        "wi": ParamSpec((d, f), ("embed", "mlp"), dtype=dtype),
        "wo": ParamSpec((f, d), ("mlp", "embed"), dtype=dtype),
    }
    if cfg.mlp_gated:
        s["wg"] = ParamSpec((d, f), ("embed", "mlp"), dtype=dtype)
    return s


def _attn_specs(cfg: ArchConfig, dtype, cross: bool = False):
    d, H, K, dh = cfg.d_model, cfg.num_heads, cfg.num_kv_heads, cfg.head_dim_
    s = {
        "ln1": _norm_spec(d, cfg.norm, dtype),
        "wq": ParamSpec((d, H, dh), ("embed", "heads", None), dtype=dtype),
        "wk": ParamSpec((d, K, dh), ("embed", "kv", None), dtype=dtype),
        "wv": ParamSpec((d, K, dh), ("embed", "kv", None), dtype=dtype),
        "wo": ParamSpec((H, dh, d), ("heads", None, "embed"), dtype=dtype),
        "ln2": _norm_spec(d, cfg.norm, dtype),
        "mlp": _mlp_specs(cfg, dtype),
    }
    if cfg.qk_norm:
        s["qn"] = ParamSpec((dh,), (None,), "ones", dtype=dtype)
        s["kn"] = ParamSpec((dh,), (None,), "ones", dtype=dtype)
    if cross:
        s["lnx"] = _norm_spec(d, cfg.norm, dtype)
        s["xq"] = ParamSpec((d, H, dh), ("embed", "heads", None), dtype=dtype)
        s["xk"] = ParamSpec((d, K, dh), ("embed", "kv", None), dtype=dtype)
        s["xv"] = ParamSpec((d, K, dh), ("embed", "kv", None), dtype=dtype)
        s["xo"] = ParamSpec((H, dh, d), ("heads", None, "embed"), dtype=dtype)
    return s


def _block_specs(cfg: ArchConfig, ltype: str, dtype, cross=False):
    if ltype in ("attn", "attn_chunked"):
        return _attn_specs(cfg, dtype, cross=cross)
    if ltype == "rglru":
        s = R.rglru_specs(cfg, dtype)
        s["ln2"] = _norm_spec(cfg.d_model, cfg.norm, dtype)
        s["mlp"] = _mlp_specs(cfg, dtype)
        return s
    if ltype == "mlstm":
        return R.mlstm_specs(cfg, dtype)
    if ltype == "slstm":
        return R.slstm_specs(cfg, dtype)
    raise ValueError(ltype)


def _stack_specs(tree, n: int):
    return jax.tree.map(
        lambda s: dataclasses.replace(
            s, shape=(n,) + s.shape, logical=("layers",) + s.logical),
        tree, is_leaf=lambda x: isinstance(x, ParamSpec))


def _layer_layout(cfg: ArchConfig):
    """(pattern, n_groups, tail_types)."""
    pat = cfg.block_pattern
    n_groups = cfg.num_layers // len(pat)
    tail = cfg.layer_types()[n_groups * len(pat):]
    return pat, n_groups, tail


def param_specs(cfg: ArchConfig, dtype=jnp.bfloat16) -> Dict[str, Any]:
    d, V = cfg.d_model, cfg.vocab_padded
    pat, n_groups, tail = _layer_layout(cfg)
    cross = cfg.is_encoder_decoder
    group = {f"b{i}": _block_specs(cfg, lt, dtype, cross=cross)
             for i, lt in enumerate(pat)}
    specs: Dict[str, Any] = {
        "embed": ParamSpec((V, d), ("vocab", "embed"), "embed", scale=0.02,
                           dtype=dtype),
        "layers": _stack_specs(group, n_groups) if n_groups else {},
        "tail": {f"t{i}": _block_specs(cfg, lt, dtype, cross=cross)
                 for i, lt in enumerate(tail)},
        "ln_f": _norm_spec(d, cfg.norm, dtype),
    }
    if not cfg.tie_embeddings:
        specs["lm_head"] = ParamSpec((d, V), ("embed", "vocab"), scale=1.0,
                                     dtype=dtype)
    if cfg.pos == "learned":
        specs["pos_embed"] = ParamSpec((MAX_LEARNED_POS, d), (None, "embed"),
                                       "embed", scale=0.02, dtype=dtype)
    if cfg.is_encoder_decoder:
        enc_block = _attn_specs(cfg, dtype, cross=False)
        specs["encoder"] = {
            "pos": ParamSpec((cfg.encoder_seq, d), (None, "embed"), "embed",
                             scale=0.02, dtype=dtype),
            "layers": _stack_specs(
                {"b0": enc_block}, cfg.encoder_layers),
            "ln_f": _norm_spec(d, cfg.norm, dtype),
        }
    return specs


# =========================================================================== blocks (train)

def pin_batch_activation(x):
    """Constrain an activation's leading dim to the data axes, rest
    replicated.

    With FSDP the *parameters* carry the `data` axis (e.g. the embedding
    table is [V:model, d:data]); without this pin GSPMD propagates the
    d:data sharding into the activations and silently *replicates the
    batch* — measured on grok train_4k as 16× redundant attention compute
    plus score-sized all-reduces (§Perf iteration g1).  No-op without an
    ambient mesh.
    """
    from repro.dist import sharding as SH
    mesh = SH.ambient_mesh()
    if mesh is None:
        return x
    daxes = SH.batch_axes(mesh)
    if not daxes:
        return x
    dsize = 1
    for a in daxes:
        dsize *= SH.mesh_axis_size(mesh, a)
    if x.shape[0] % dsize or x.shape[0] < dsize:
        return x
    lead = daxes if len(daxes) > 1 else daxes[0]
    from jax.sharding import PartitionSpec as PS
    return lax.with_sharding_constraint(
        x, PS(lead, *([None] * (x.ndim - 1))))


def _pin_replicated_heads(x, cfg):
    """Force partial-sum reduction at q/k/v granularity when heads cannot
    shard on the model axis (e.g. llama4's 40 heads on 16).

    With the head count indivisible, the projection weight falls back to
    d-sharding (row parallel); left alone, GSPMD defers the partial-sum
    all-reduce *into the attention scores* — an 8x (= kv_block/head_dim)
    inflation measured at 41 TB/step on llama4 train_4k (§Perf iteration
    l2).  Constraining q/k/v to model-replicated pins the reduction to the
    [B,S,H,dh] tensor instead.  No-op without an ambient mesh.
    """
    from repro.dist import sharding as SH
    mesh = SH.ambient_mesh()
    if mesh is None or "model" not in tuple(mesh.axis_names):
        return x
    if cfg.num_heads % SH.mesh_axis_size(mesh, "model") == 0:
        return x
    daxes = SH.batch_axes(mesh)
    lead = daxes if len(daxes) > 1 else (daxes[0] if daxes else None)
    from jax.sharding import PartitionSpec as PS
    return lax.with_sharding_constraint(
        x, PS(lead, *([None] * (x.ndim - 1))))


def _qkv(p, h, cfg, prefix=""):
    q = jnp.einsum("bsd,dhe->bshe", h, p[prefix + ("xq" if prefix else "wq")])
    k = jnp.einsum("bsd,dke->bske", h, p[prefix + ("xk" if prefix else "wk")])
    v = jnp.einsum("bsd,dke->bske", h, p[prefix + ("xv" if prefix else "wv")])
    if h.ndim == 3:
        q = _pin_replicated_heads(q, cfg)
        k = _pin_replicated_heads(k, cfg)
        v = _pin_replicated_heads(v, cfg)
    return q, k, v


def _qk_normalize(p, q, k, cfg):
    if not cfg.qk_norm:
        return q, k
    def rn(x, g):
        x32 = x.astype(jnp.float32)
        v = jnp.mean(x32 * x32, axis=-1, keepdims=True)
        return (x32 * lax.rsqrt(v + 1e-6)).astype(x.dtype) * g
    return rn(q, p["qn"]), rn(k, p["kn"])


def _attn_train(p, x, cfg: ArchConfig, ltype, enc_out=None,
                positions=None, cache_len: int = 0):
    h = apply_norm(x, p["ln1"], cfg.norm)
    q, k, v = _qkv(p, h, cfg)
    q, k = _qk_normalize(p, q, k, cfg)
    if cfg.pos == "rope":
        pos = positions if positions is not None else jnp.arange(
            x.shape[1], dtype=jnp.int32)
        q = apply_rope(q, pos, cfg.rope_theta)
        k = apply_rope(k, pos, cfg.rope_theta)
    mode = "causal" if ltype == "attn" else "chunk"
    window = cfg.attn_chunk if ltype == "attn_chunked" else None
    if ltype == "attn_chunked" and cfg.family == "hybrid":
        mode = "window"
        window = cfg.local_window
    o = flash_attention(q, k, v, mode=mode, window=window,
                        cap=cfg.logit_softcap)
    x = x + jnp.einsum("bshe,hed->bsd", o, p["wo"])

    kx = vx = None
    if enc_out is not None:
        hx = apply_norm(x, p["lnx"], cfg.norm)
        qx = jnp.einsum("bsd,dhe->bshe", hx, p["xq"])
        kx = jnp.einsum("bsd,dke->bske", enc_out, p["xk"])
        vx = jnp.einsum("bsd,dke->bske", enc_out, p["xv"])
        ox = flash_attention(qx, kx, vx, mode="full")
        x = x + jnp.einsum("bshe,hed->bsd", ox, p["xo"])

    h2 = apply_norm(x, p["ln2"], cfg.norm)
    if cfg.num_experts:
        out, aux = moe_mlp(p["mlp"], h2, cfg, groups=cfg.moe_groups)
    else:
        out, aux = mlp(p["mlp"], h2, cfg), 0.0

    cache = None
    if cache_len:
        cache = _kv_to_cache(cfg, ltype, k, v, cache_len)
        if kx is not None:
            cache["xk"] = kx.astype(jnp.bfloat16)
            cache["xv"] = vx.astype(jnp.bfloat16)
    return x + out, cache, aux


def _kv_to_cache(cfg, ltype, k, v, cache_len: int):
    """Pack full-sequence K/V [B,S,K,dh] into a decode cache of cache_len."""
    B, S, K, dh = k.shape
    if ltype == "attn_chunked":
        W = cfg.local_window if cfg.family == "hybrid" else cfg.attn_chunk
        W = min(W, cache_len)
        take = min(W, S)
        kw = k[:, -take:]
        vw = v[:, -take:]
        kpos = jnp.arange(S - take, S, dtype=jnp.int32)
        if take < W:
            pad = W - take
            kw = jnp.pad(kw, ((0, 0), (0, pad), (0, 0), (0, 0)))
            vw = jnp.pad(vw, ((0, 0), (0, pad), (0, 0), (0, 0)))
            kpos = jnp.concatenate([kpos, jnp.full((pad,), -1, jnp.int32)])
        # ring layout: slot = pos % W
        slots = jnp.where(kpos >= 0, kpos % W, jnp.arange(W) * 0 + jnp.arange(W))
        kr = jnp.zeros_like(kw).at[:, slots].set(kw)
        vr = jnp.zeros_like(vw).at[:, slots].set(vw)
        pr = jnp.full((W,), -1, jnp.int32).at[slots].set(kpos)
        return {"k": kr.astype(jnp.bfloat16), "v": vr.astype(jnp.bfloat16),
                "kpos": pr}
    assert S <= cache_len, (
        f"prefill length {S} (incl. any frontend prefix) exceeds cache_len "
        f"{cache_len}")
    pad = cache_len - S
    kf = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
    vf = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
    if cfg.kv_cache_dtype == "int8":
        kq, ks = _quant(kf)
        vq, vs = _quant(vf)
        return {"k": kq, "v": vq, "ks": ks, "vs": vs}
    return {"k": kf.astype(jnp.bfloat16), "v": vf.astype(jnp.bfloat16)}


def _block_train(p, x, cfg, ltype, enc_out=None, cache_len: int = 0):
    """returns (x, cache_entry_or_None, aux_loss)."""
    if ltype in ("attn", "attn_chunked"):
        return _attn_train(p, x, cfg, ltype, enc_out, cache_len=cache_len)
    if ltype == "rglru":
        x, st = R.rglru_train(p, x, cfg)
        h2 = apply_norm(x, p["ln2"], cfg.norm)
        return x + mlp(p["mlp"], h2, cfg), (st if cache_len else None), 0.0
    if ltype == "mlstm":
        x, st = R.mlstm_train(p, x, cfg)
        return x, (st if cache_len else None), 0.0
    if ltype == "slstm":
        x, st = R.slstm_train(p, x, cfg)
        return x, (st if cache_len else None), 0.0
    raise ValueError(ltype)


def _remat_wrap(fn, policy: str):
    if policy == "none":
        return fn
    if policy == "dots":
        # save every dot output: backward never recomputes matmuls, hence
        # never replays their TP collectives (trade: saved-activation HBM)
        return jax.checkpoint(fn, policy=jax.checkpoint_policies.dots_saveable)
    return jax.checkpoint(fn)


# =========================================================================== forward (train)

def _embed_tokens(params, cfg, tokens):
    return jnp.take(params["embed"], tokens, axis=0)


def _encoder_forward(params, cfg, frames):
    p = params["encoder"]
    x = frames + p["pos"][None, : frames.shape[1]]

    # encoder attention is bidirectional (mode="full")
    def enc_block(x, gp):
        pp = gp["b0"]
        h = apply_norm(x, pp["ln1"], cfg.norm)
        q, k, v = _qkv(pp, h, cfg)
        o = flash_attention(q, k, v, mode="full")
        x = x + jnp.einsum("bshe,hed->bsd", o, pp["wo"])
        h2 = apply_norm(x, pp["ln2"], cfg.norm)
        return x + mlp(pp["mlp"], h2, cfg), None

    x, _ = lax.scan(_remat_wrap(enc_block, cfg.remat), x, p["layers"])
    return apply_norm(x, p["ln_f"], cfg.norm)


def forward(params, cfg: ArchConfig, batch: Dict[str, jnp.ndarray],
            cache_len: int = 0):
    """Full-sequence forward -> final hidden states [B, S, d] (+ aux, caches).

    batch: tokens [B, S_txt]; optional "frames" [B,Tenc,d] (audio stub),
    "patches" [B,P,d] (vision stub).  With ``cache_len`` > 0 this is the
    *prefill* path: per-layer decode caches (KV packed/quantized to
    ``cache_len`` slots, recurrent final states) are assembled and returned
    in the same structure `init_cache` produces.
    """
    tokens = batch["tokens"]
    x = pin_batch_activation(_embed_tokens(params, cfg, tokens))
    enc_out = None
    if cfg.frontend == "vision_stub" and "patches" in batch:
        x = jnp.concatenate([batch["patches"].astype(x.dtype), x], axis=1)
    if cfg.is_encoder_decoder:
        enc_out = _encoder_forward(params, cfg, batch["frames"])
    if cfg.pos == "learned":
        x = x + params["pos_embed"][None, : x.shape[1]]

    pat, n_groups, tail = _layer_layout(cfg)
    aux0 = jnp.zeros((), jnp.float32)

    def group_fn(carry, gp):
        x, aux = carry
        caches = {}
        for i, lt in enumerate(pat):
            x, c, a = _block_train(gp[f"b{i}"], x, cfg, lt, enc_out,
                                   cache_len=cache_len)
            x = pin_batch_activation(x)
            aux = aux + a
            if cache_len:
                caches[f"b{i}"] = c
        return (x, aux), (caches if cache_len else None)

    carry = (x, aux0)
    ys = None
    if n_groups:
        carry, ys = lax.scan(_remat_wrap(group_fn, cfg.remat), carry,
                             params["layers"])
    x, aux = carry
    tail_caches = {}
    for i, lt in enumerate(tail):
        x, c, a = _block_train(params["tail"][f"t{i}"], x, cfg, lt, enc_out,
                               cache_len=cache_len)
        aux = aux + a
        if cache_len:
            tail_caches[f"t{i}"] = c
    x = apply_norm(x, params["ln_f"], cfg.norm)
    cache = {"layers": ys or {}, "tail": tail_caches} if cache_len else None
    return x, aux, cache


def unembed(params, cfg, x):
    head = params["embed"].T if cfg.tie_embeddings else params["lm_head"]
    return x @ head


def xent_loss(params, cfg: ArchConfig, x, targets, mask, seq_chunk=1024):
    """Chunked softmax cross-entropy: never materializes [B,S,V].

    x [B,S,d]; targets/mask [B,S].
    """
    B, S, d = x.shape
    head = (params["embed"].T if cfg.tie_embeddings else params["lm_head"])
    c = min(seq_chunk, S)
    while S % c:
        c -= 1
    n = S // c

    def chunk_loss(carry, xs):
        xc, tc, mc = xs                       # [B,c,d], [B,c], [B,c]
        logits = (xc @ head).astype(jnp.float32)
        lse = jax.nn.logsumexp(logits, axis=-1)
        gold = jnp.take_along_axis(logits, tc[..., None], axis=-1)[..., 0]
        nll = (lse - gold) * mc
        return carry + jnp.sum(nll), None

    xs = (x.reshape(B, n, c, d).swapaxes(0, 1),
          targets.reshape(B, n, c).swapaxes(0, 1),
          mask.reshape(B, n, c).swapaxes(0, 1))
    total, _ = lax.scan(chunk_loss, jnp.zeros((), jnp.float32), xs)
    return total / jnp.maximum(jnp.sum(mask), 1.0)


# =========================================================================== caches

def _attn_cache(cfg, ltype, batch, seq_len):
    K, dh = cfg.num_kv_heads, cfg.head_dim_
    if ltype == "attn_chunked":
        W = cfg.local_window if cfg.family == "hybrid" else cfg.attn_chunk
        W = min(W, seq_len)
        return {
            "k": jnp.zeros((batch, W, K, dh), jnp.bfloat16),
            "v": jnp.zeros((batch, W, K, dh), jnp.bfloat16),
            "kpos": jnp.full((W,), -1, jnp.int32),
        }
    if cfg.kv_cache_dtype == "int8":
        return {
            "k": jnp.zeros((batch, seq_len, K, dh), jnp.int8),
            "v": jnp.zeros((batch, seq_len, K, dh), jnp.int8),
            "ks": jnp.zeros((batch, seq_len, K), jnp.float32),
            "vs": jnp.zeros((batch, seq_len, K), jnp.float32),
        }
    return {
        "k": jnp.zeros((batch, seq_len, K, dh), jnp.bfloat16),
        "v": jnp.zeros((batch, seq_len, K, dh), jnp.bfloat16),
    }


def _block_cache(cfg, ltype, batch, seq_len):
    if ltype in ("attn", "attn_chunked"):
        c = _attn_cache(cfg, ltype, batch, seq_len)
        if cfg.is_encoder_decoder:
            K, dh = cfg.num_kv_heads, cfg.head_dim_
            c["xk"] = jnp.zeros((batch, cfg.encoder_seq, K, dh), jnp.bfloat16)
            c["xv"] = jnp.zeros((batch, cfg.encoder_seq, K, dh), jnp.bfloat16)
        return c
    if ltype == "rglru":
        return R.rglru_state(cfg, batch)
    if ltype == "mlstm":
        return R.mlstm_state(cfg, batch)
    if ltype == "slstm":
        return R.slstm_state(cfg, batch)
    raise ValueError(ltype)


def init_cache(cfg: ArchConfig, batch: int, seq_len: int):
    pat, n_groups, tail = _layer_layout(cfg)
    group = {f"b{i}": _block_cache(cfg, lt, batch, seq_len)
             for i, lt in enumerate(pat)}
    stacked = jax.tree.map(
        lambda x: jnp.broadcast_to(x, (n_groups, *x.shape)), group
    ) if n_groups else {}
    return {
        "layers": stacked,
        "tail": {f"t{i}": _block_cache(cfg, lt, batch, seq_len)
                 for i, lt in enumerate(tail)},
    }


# =========================================================================== decode

def _quant(x):
    s = jnp.max(jnp.abs(x.astype(jnp.float32)), axis=-1) / 127.0 + 1e-8
    q = jnp.round(x.astype(jnp.float32) / s[..., None]).astype(jnp.int8)
    return q, s


def _attn_decode(p, x1, cache, pos, cfg, ltype):
    """x1 [B, d]; returns (x1_out, cache)."""
    B, d = x1.shape
    h = apply_norm(x1, p["ln1"], cfg.norm)
    q = jnp.einsum("bd,dhe->bhe", h, p["wq"])
    k1 = jnp.einsum("bd,dke->bke", h, p["wk"])
    v1 = jnp.einsum("bd,dke->bke", h, p["wv"])
    if cfg.qk_norm:
        q, k1 = _qk_normalize(p, q, k1, cfg)
    if cfg.pos == "rope":
        q = apply_rope(q, pos, cfg.rope_theta)
        k1 = apply_rope(k1, pos, cfg.rope_theta)

    if ltype == "attn_chunked":
        W = cache["k"].shape[1]
        slot = pos % W
        cache = dict(cache)
        cache["k"] = lax.dynamic_update_index_in_dim(
            cache["k"], k1.astype(cache["k"].dtype), slot, axis=1)
        cache["v"] = lax.dynamic_update_index_in_dim(
            cache["v"], v1.astype(cache["v"].dtype), slot, axis=1)
        cache["kpos"] = lax.dynamic_update_index_in_dim(
            cache["kpos"], pos.astype(jnp.int32), slot, axis=0)
        kp = cache["kpos"]
        if cfg.family == "hybrid":                  # sliding window
            valid = (kp >= 0) & (kp > pos - W) & (kp <= pos)
        else:                                        # llama4 chunk semantics
            Wc = cfg.attn_chunk
            valid = (kp >= 0) & ((kp // Wc) == (pos // Wc)) & (kp <= pos)
        kc, vc = cache["k"], cache["v"]
    elif cfg.kv_cache_dtype == "int8":
        S = cache["k"].shape[1]
        kq, ks = _quant(k1)
        vq, vs = _quant(v1)
        cache = dict(cache)
        cache["k"] = lax.dynamic_update_index_in_dim(cache["k"], kq, pos, axis=1)
        cache["v"] = lax.dynamic_update_index_in_dim(cache["v"], vq, pos, axis=1)
        cache["ks"] = lax.dynamic_update_index_in_dim(cache["ks"], ks, pos, axis=1)
        cache["vs"] = lax.dynamic_update_index_in_dim(cache["vs"], vs, pos, axis=1)
        kc = (cache["k"].astype(jnp.bfloat16)
              * cache["ks"][..., None].astype(jnp.bfloat16))
        vc = (cache["v"].astype(jnp.bfloat16)
              * cache["vs"][..., None].astype(jnp.bfloat16))
        valid = jnp.arange(S, dtype=jnp.int32) <= pos
    else:
        S = cache["k"].shape[1]
        cache = dict(cache)
        cache["k"] = lax.dynamic_update_index_in_dim(
            cache["k"], k1.astype(cache["k"].dtype), pos, axis=1)
        cache["v"] = lax.dynamic_update_index_in_dim(
            cache["v"], v1.astype(cache["v"].dtype), pos, axis=1)
        kc, vc = cache["k"], cache["v"]
        valid = jnp.arange(S, dtype=jnp.int32) <= pos

    o = decode_attention(q, kc, vc, valid, cap=cfg.logit_softcap)
    x1 = x1 + jnp.einsum("bhe,hed->bd", o, p["wo"])

    if cfg.is_encoder_decoder:
        hx = apply_norm(x1, p["lnx"], cfg.norm)
        qx = jnp.einsum("bd,dhe->bhe", hx, p["xq"])
        ox = decode_attention(qx, cache["xk"], cache["xv"],
                              jnp.ones(cache["xk"].shape[1], bool))
        x1 = x1 + jnp.einsum("bhe,hed->bd", ox, p["xo"])

    h2 = apply_norm(x1, p["ln2"], cfg.norm)
    if cfg.num_experts:
        out, _ = moe_mlp(p["mlp"], h2[:, None, :], cfg, groups=cfg.moe_groups)
        out = out[:, 0]
    else:
        out = mlp(p["mlp"], h2, cfg)
    return x1 + out, cache


def _block_decode(p, x1, cache, pos, cfg, ltype):
    if ltype in ("attn", "attn_chunked"):
        return _attn_decode(p, x1, cache, pos, cfg, ltype)
    if ltype == "rglru":
        x1, st = R.rglru_decode(p, x1, cache, cfg)
        h2 = apply_norm(x1, p["ln2"], cfg.norm)
        return x1 + mlp(p["mlp"], h2, cfg), st
    if ltype == "mlstm":
        return R.mlstm_decode(p, x1, cache, cfg)
    if ltype == "slstm":
        return R.slstm_decode(p, x1, cache, cfg)
    raise ValueError(ltype)


def decode_step(params, cfg: ArchConfig, token, cache, pos):
    """One decoding step.  token [B] int32; pos scalar int32."""
    x1 = jnp.take(params["embed"], token, axis=0)
    if cfg.pos == "learned":
        x1 = x1 + params["pos_embed"][pos]
    pat, n_groups, tail = _layer_layout(cfg)

    def group_fn(x1, xs):
        gp, gc = xs
        new_c = {}
        for i, lt in enumerate(pat):
            x1, new_c[f"b{i}"] = _block_decode(gp[f"b{i}"], x1, gc[f"b{i}"],
                                               pos, cfg, lt)
        return x1, new_c

    new_cache = {"layers": {}, "tail": {}}
    if n_groups:
        x1, new_cache["layers"] = lax.scan(
            group_fn, x1, (params["layers"], cache["layers"]))
    for i, lt in enumerate(tail):
        x1, new_cache["tail"][f"t{i}"] = _block_decode(
            params["tail"][f"t{i}"], x1, cache["tail"][f"t{i}"], pos, cfg, lt)
    x1 = apply_norm(x1, params["ln_f"], cfg.norm)
    logits = unembed(params, cfg, x1).astype(jnp.float32)
    return logits, new_cache
