"""Pallas TPU kernel: group-by aggregation via one-hot MXU contraction.

The paper's Alg. 3 hot loop scatters each item's aggregates into a hash
table.  TPUs have no efficient scatter; the TPU-native adaptation
(DESIGN.md §3) turns the scatter into a matmul:

    sums[G, A]  += onehot(gids)[N, G]ᵀ @ (vals·w)[N, A]

which runs on the MXU.  The [G, A] (+ sumsq, matched) accumulators stay
resident in VMEM across grid steps; each grid step streams one [block, ...]
tile of items.  G is the *padded* group-table size (hash-bucketed for large
domains, e.g. the paper's 1M-group Q1 — see repro/core/gla.py).

Tiling: items stream as [block_rows, A] row blocks (unlike chunk_agg's
[R, 128] lane tiles — here the lane dim carries the A aggregates, and the
one-hot is built per block with a broadcasted_iota over G).  The ops.py
wrapper pads G to a multiple of 128 (the one-hot's lane dim) and A to a
multiple of 8 (the [G, A] output sublane pairing), so both matmul operand
shapes are MXU-aligned; ``matched`` keeps its [G, 1] layout (a single
lane-dim column — tolerated, and sliced off by the wrapper anyway).

Bitwise guarantee: driven with ``block_rows`` == chunk length (as
``core/scan.py::kernel_round_delta`` does), accumulation runs chunk by
chunk in the scan's association order and states equal the segment_sum
scan bit-for-bit.  The fused round-slice kernel
(:mod:`repro.kernels.fused_agg`, DESIGN.md §12) extends the same
guarantee to scalars and in-kernel decode; authoring rules in
docs/KERNELS.md.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

LANES = 128


def _group_body(vals_ref, weight_ref, gids_ref, sums_ref, sumsqs_ref,
                matched_ref, *, num_groups: int):
    @pl.when(pl.program_id(0) == 0)
    def _init():
        sums_ref[...] = jnp.zeros_like(sums_ref)
        sumsqs_ref[...] = jnp.zeros_like(sumsqs_ref)
        matched_ref[...] = jnp.zeros_like(matched_ref)

    v = vals_ref[...].astype(jnp.float32)        # [B, A]
    w = weight_ref[...].astype(jnp.float32)      # [B, 1]
    g = gids_ref[...]                            # [B, 1] int32
    B = v.shape[0]
    # one-hot on the fly: [B, G]
    iota = jax.lax.broadcasted_iota(jnp.int32, (B, num_groups), 1)
    onehot = (g == iota).astype(jnp.float32)
    vw = v * w                                    # [B, A]
    sums_ref[...] += jnp.dot(onehot.T, vw, preferred_element_type=jnp.float32)
    sumsqs_ref[...] += jnp.dot(onehot.T, v * vw,
                               preferred_element_type=jnp.float32)
    matched_ref[...] += jnp.dot(onehot.T, w, preferred_element_type=jnp.float32)


def group_agg_kernel(vals, weight, gids, *, num_groups: int,
                     block_rows: int = 512, interpret: bool = False):
    """vals [N, A], weight [N, 1], gids [N, 1] -> (sums, sumsqs [G, A], matched [G, 1]).

    N % block_rows == 0; the ops.py wrapper pads num_groups to a multiple
    of 128 and A to a multiple of 8 before calling (MXU alignment).
    """
    N, A = vals.shape
    assert N % block_rows == 0
    grid = (N // block_rows,)
    vspec = pl.BlockSpec((block_rows, A), lambda i: (i, 0))
    wspec = pl.BlockSpec((block_rows, 1), lambda i: (i, 0))
    out_ga = pl.BlockSpec((num_groups, A), lambda i: (0, 0))
    out_g1 = pl.BlockSpec((num_groups, 1), lambda i: (0, 0))
    import functools
    return pl.pallas_call(
        functools.partial(_group_body, num_groups=num_groups),
        grid=grid,
        in_specs=[vspec, wspec, wspec],
        out_specs=[out_ga, out_ga, out_g1],
        out_shape=[
            jax.ShapeDtypeStruct((num_groups, A), jnp.float32),
            jax.ShapeDtypeStruct((num_groups, A), jnp.float32),
            jax.ShapeDtypeStruct((num_groups, 1), jnp.float32),
        ],
        interpret=interpret,
    )(vals, weight, gids)
