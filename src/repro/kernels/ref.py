"""Pure-jnp oracles for the Pallas kernels.

These are the semantics the kernels must match (assert_allclose in
tests/test_kernels.py across shape/dtype sweeps).  They are also the
fallback implementation on backends without Pallas support.
"""
from __future__ import annotations

import jax.numpy as jnp
import jax


def chunk_agg_ref(vals, weight, mask):
    """Fused selection+aggregation over a flat chunk — paper Alg. 1 hot loop.

    vals   [N] f32/bf16 — func(d) per item
    weight [N] — cond(d)·mask in {0,1}
    mask   [N] — liveness in {0,1}
    returns [4] f32: (sum, sumsq, scanned, matched)
    """
    v = vals.astype(jnp.float32)
    w = weight.astype(jnp.float32)
    m = mask.astype(jnp.float32)
    return jnp.stack(
        [jnp.sum(v * w), jnp.sum(v * v * w), jnp.sum(m), jnp.sum(w)]
    )


def q6_agg_ref(shipdate, discount, quantity, extendedprice, mask, params):
    """Fully fused Q6 predicate+func+aggregate (what the kernel fuses).

    params [6]: (date_lo, date_hi, disc_lo, disc_hi, qty_eq, unused)
    returns [4] f32: (sum, sumsq, scanned, matched)
    """
    date_lo, date_hi, disc_lo, disc_hi, qty_eq = [params[i] for i in range(5)]
    sd = shipdate.astype(jnp.float32)
    cond = (
        (sd >= date_lo) & (sd < date_hi)
        & (discount >= disc_lo) & (discount <= disc_hi)
        & (quantity == qty_eq)
    ).astype(jnp.float32)
    v = (extendedprice * discount).astype(jnp.float32)
    return chunk_agg_ref(v, cond * mask, mask)


def group_agg_ref(vals, weight, gids, num_groups):
    """Group-by aggregation — paper Alg. 3 hot loop.

    vals [N, A], weight [N], gids [N] int32 in [0, G)
    returns (sums [G, A], sumsqs [G, A], matched [G]) in f32
    """
    v = vals.astype(jnp.float32)
    w = weight.astype(jnp.float32)
    vw = v * w[:, None]
    sums = jax.ops.segment_sum(vw, gids, num_segments=num_groups)
    sumsqs = jax.ops.segment_sum(v * vw, gids, num_segments=num_groups)
    matched = jax.ops.segment_sum(w, gids, num_segments=num_groups)
    return sums, sumsqs, matched
