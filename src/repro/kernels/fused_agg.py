"""Fused selection→bucket→aggregate Pallas kernel (DESIGN.md §12).

One VMEM-resident dispatch per round-slice that fuses everything between
the streamed bytes and the GLA state update:

    decode (dict / bit-packed columns)          repro/data/encodings.py
    → predicate evaluation  (FusedSpec.cond × _mask)
    → group-id computation  (FusedSpec.group, already hash-bucketed)
    → f32 accumulation      (mul-reduce scalar / one-hot MXU group)

into the ``estimators.SumState`` layout, *carrying the state in*: the
previous round's (sum, sumsq, matched) enter as constant-index-map input
refs, are copied to the output refs at ``program_id == 0``, and each grid
step (one chunk of length L) accumulates on top.  Because the kernel adds
per-chunk contributions to a running carry in chunk order — the exact
association ``scan.scan_round_step`` uses — finals and snapshots are
**bitwise-identical** to the segment-sum scan path, for the scalar
contract too (the legacy scalar kernel was only statistically
interchangeable; see docs/KERNELS.md for the accumulation-order rules
that make this hold).

Bundles fuse further: all members' accumulations run in the SAME
``pallas_call`` (separate out-ref triples per member), so N concurrent
queries still cost one dispatch and one VMEM residency per round-slice —
the audit catalog's ``fused_single_dispatch`` check pins this down via
:func:`count_dispatches`.

Padding follows the repo's MXU discipline (docs/KERNELS.md): A → multiple
of 8, G → multiple of 128; padded value columns are zero (they reduce to
zero independently per column), padded group rows receive no one-hot hits,
and the unpadded slices are returned — padding never leaks.

Kernels run with ``interpret=True`` off-TPU (ops._interpret_default), and
every result is asserted bitwise against the scan reference in
tests/test_fused_kernel.py across {scalar, group, bundle} × {plain, dict,
bit-packed} × both engines.
"""
from __future__ import annotations

import contextlib

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.data import encodings as ENC
from repro.kernels.ops import _interpret_default


def _pad8(a: int) -> int:
    return -(-a // 8) * 8


def _pad128(g: int) -> int:
    return -(-g // 128) * 128


# Join probe tables ride inside the kernel's VMEM residency for the whole
# grid (constant index map — fetched once, revisited every step), so their
# combined footprint is budgeted against the ~16 MiB/core VMEM the column
# blocks and accumulators also live in.  Oversized joins fall back to the
# legacy kernel_cols path (fused_available returns False).
PROBE_VMEM_BUDGET_BYTES = 4 * 1024 * 1024


# ---------------------------------------------------------------------------
# dispatch accounting (analysis/audit.py: fused_single_dispatch)
# ---------------------------------------------------------------------------

_DISPATCHES = [0]  # pallas_call constructions since import (monotonic)


@contextlib.contextmanager
def count_dispatches():
    """Count fused ``pallas_call`` constructions traced inside the block.

    Yields a one-element list; after the block it holds the count.  Works
    under ``jax.eval_shape``/lowering (no execution needed), which is how
    the audit catalog proves one-dispatch-per-round-slice statically.
    """
    start = _DISPATCHES[0]
    box = [0]
    try:
        yield box
    finally:
        box[0] = _DISPATCHES[0] - start


# ---------------------------------------------------------------------------
# contract helpers
# ---------------------------------------------------------------------------

def fused_members(gla):
    """The per-member ``FusedSpec`` tuple of ``gla`` (itself, or its bundle
    members), or None when any member lacks a fused contract."""
    members = gla.members or (gla,)
    specs = tuple(m.fused for m in members)
    return None if any(s is None for s in specs) else specs


def unique_probes(specs):
    """Unique ProbeTables across member specs, first-seen order (members
    built from one ``with_probe_tables`` join share table objects — shared
    tables enter the kernel once)."""
    seen = {}
    for fs in specs:
        for pt in fs.probe_tables:
            seen.setdefault(pt.key, pt)
    return tuple(seen.values())


def probe_bytes(gla) -> int:
    """Combined unique probe-table bytes of ``gla``'s fused contract (0 when
    none) — the number ``fused_available`` holds under the VMEM budget."""
    specs = fused_members(gla)
    return 0 if specs is None else sum(
        pt.nbytes for pt in unique_probes(specs))


def fused_available(gla, columns=None) -> bool:
    """True when every member publishes a fused contract AND the source's
    column table is fusable (no trailing dims — the kernel blocks one
    [1, L] row per column) AND any join probe tables fit the kernel's VMEM
    probe budget."""
    specs = fused_members(gla)
    if specs is None:
        return False
    if columns is not None and any(c.trailing for c in columns):
        return False
    probes = unique_probes(specs)
    if sum(pt.nbytes for pt in probes) > PROBE_VMEM_BUDGET_BYTES:
        return False
    return True


def _member_meta(specs):
    """Static (kind, A, A_pad, G, G_pad) per member."""
    meta = []
    for fs in specs:
        a_pad = _pad8(fs.num_aggs)
        if fs.group is None:
            meta.append(("scalar", fs.num_aggs, a_pad, None, None))
        else:
            meta.append(("group", fs.num_aggs, a_pad, fs.num_groups,
                         _pad128(fs.num_groups)))
    return meta


def _pad_cols(d, a_pad):
    """Zero-pad a [rows, A] contribution to [rows, A_pad] columns."""
    if d.shape[1] == a_pad:
        return d
    return jnp.concatenate(
        [d, jnp.zeros((d.shape[0], a_pad - d.shape[1]), jnp.float32)], axis=1)


def _pad_rows(d, g_pad):
    """Zero-pad a [G, cols] contribution to [G_pad, cols] rows."""
    if d.shape[0] == g_pad:
        return d
    return jnp.concatenate(
        [d, jnp.zeros((g_pad - d.shape[0], d.shape[1]), jnp.float32)], axis=0)


def _chunk_contrib(fs, meta_row, chunk, msk, L, use_mxu=False):
    """One chunk's (sum, sumsq, matched) contribution, padded.

    The bitwise guarantee rests on IDENTICAL EXPRESSION TREES, not on
    numerically-equivalent ones: the scalar member repeats ``gla.acc_sum``
    verbatim (multiply-then-reduce — context-stable on XLA:CPU, unlike a
    matvec, which fuses into surrounding scan carries), and the group
    member repeats the scan path's ``jax.ops.segment_sum`` scatter —
    a one-hot contraction reduces over L in a different association and
    its low bits drift from the scatter's once L outgrows the CPU
    reduce's vectorization block (~256 at f32).  ``use_mxu`` switches the
    group member to the one-hot MXU contraction for compiled TPU kernels,
    where a scatter does not lower; re-validate bitwise-vs-scan on-device
    before relying on it there (docs/KERNELS.md).

    Reductions run over the UNPADDED [L, A] values / [G, A] segments —
    padding A (or G) first changes the reduce's vectorization, hence its
    association, hence the low bits; only the already-reduced result is
    padded to the accumulator-ref layout.  Returns 2-D arrays shaped like
    the member's accumulator refs.
    """
    kind, A, A_pad, G, G_pad = meta_row
    vals = fs.func(chunk)
    vals = (vals[:, None] if vals.ndim == 1 else vals).astype(jnp.float32)
    w = (fs.cond(chunk) * msk).astype(jnp.float32)
    if kind == "scalar":
        d_s = ((vals * w[:, None]).sum(axis=0))[None]            # [1, A]
        d_q = (((vals * vals) * w[:, None]).sum(axis=0))[None]
        d_m = jnp.sum(w).reshape(1, 1)
        return _pad_cols(d_s, A_pad), _pad_cols(d_q, A_pad), d_m
    gids = fs.group(chunk).astype(jnp.int32)
    vw = vals * w[:, None]
    if use_mxu:
        onehot = (jax.lax.broadcasted_iota(jnp.int32, (L, G_pad), 1)
                  == gids[:, None]).astype(jnp.float32)          # [L, G_pad]
        d_s = jnp.dot(onehot.T, vw, preferred_element_type=jnp.float32)
        d_q = jnp.dot(onehot.T, vals * vw,
                      preferred_element_type=jnp.float32)
        d_m = jnp.dot(onehot.T, w[:, None],
                      preferred_element_type=jnp.float32)
        return _pad_cols(d_s, A_pad), _pad_cols(d_q, A_pad), d_m
    d_s = jax.ops.segment_sum(vw, gids, num_segments=G)
    d_q = jax.ops.segment_sum(vals * vw, gids, num_segments=G)
    d_m = jax.ops.segment_sum(w, gids, num_segments=G)[:, None]
    return (_pad_rows(_pad_cols(d_s, A_pad), G_pad),
            _pad_rows(_pad_cols(d_q, A_pad), G_pad),
            _pad_rows(d_m, G_pad))


def _table_inputs(names, enc_map):
    """Dictionary value tables as extra kernel inputs (Pallas forbids
    captured constants in the body): (table names, arrays, BlockSpecs)."""
    tbl_names = [n for n in names
                 if isinstance(enc_map.get(n), ENC.DictEncoding)]
    args = [enc_map[n].table() for n in tbl_names]
    specs = [pl.BlockSpec(t.shape, lambda i: (0,)) for t in args]
    return tbl_names, args, specs


def _decode_chunk(names, col_refs, enc_map, tables):
    """Rebuild the logical chunk dict from one grid step's column refs,
    decoding encoded columns in-register (exact).  ``tables`` maps dict-
    encoded column names to their value-table values (read off the extra
    table input refs); bit-packed columns shift-and-mask via
    ``encodings.decode_block``."""
    chunk = {}
    for n, r in zip(names, col_refs):
        enc = enc_map.get(n)
        if isinstance(enc, ENC.DictEncoding):
            chunk[n] = jnp.take(tables[n], r[0].astype(jnp.int32), axis=0)
        else:
            chunk[n] = ENC.decode_block(r[0], enc)
    return chunk


def _carry_arrays(specs, meta, states):
    """Pack member SumStates into the padded f32 carry layout."""
    carries = []
    for fs, mrow, st in zip(specs, meta, states):
        kind, A, A_pad, G, G_pad = mrow
        if kind == "scalar":
            s = jnp.zeros((1, A_pad), jnp.float32).at[0, :A].set(st.sum)
            q = jnp.zeros((1, A_pad), jnp.float32).at[0, :A].set(st.sumsq)
            m = jnp.asarray(st.matched, jnp.float32).reshape(1, 1)
        else:
            s = jnp.zeros((G_pad, A_pad), jnp.float32).at[:G, :A].set(st.sum)
            q = jnp.zeros((G_pad, A_pad), jnp.float32).at[:G, :A].set(st.sumsq)
            m = jnp.zeros((G_pad, 1), jnp.float32).at[:G, 0].set(st.matched)
        carries += [s, q, m]
    return carries


def _unpack_states(outs, specs, meta, states, scanned_delta):
    """Slice padding off the kernel outputs back into member SumStates."""
    new_states = []
    for i, (mrow, st) in enumerate(zip(meta, states)):
        kind, A, A_pad, G, G_pad = mrow
        s, q, m = outs[3 * i:3 * i + 3]
        if kind == "scalar":
            new_states.append(st._replace(
                sum=s[0, :A], sumsq=q[0, :A], matched=m[0, 0],
                scanned=st.scanned + scanned_delta))
        else:
            new_states.append(st._replace(
                sum=s[:G, :A], sumsq=q[:G, :A], matched=m[:G, 0],
                scanned=st.scanned + scanned_delta))
    return new_states


# ---------------------------------------------------------------------------
# the fused round-step kernel (carry-in; scalar, group, and bundles)
# ---------------------------------------------------------------------------

def fused_round_step(gla, state, cols, encodings=(), *, interpret=None,
                     use_mxu=False):
    """Advance ``state`` over one round-slice in ONE fused dispatch.

    Contract (docs/KERNELS.md):
      cols:       {name: [C, L]} logical — or [C, L/lanes] physical for
                  columns named in ``encodings`` (decoded in-kernel);
                  must include a plain ``_mask``.
      state:      member SumState (bundle: tuple thereof), any f32 shapes
                  matching the GLA's init().
      returns:    same pytree, advanced over the C chunks in chunk order.

    Join members' ``FusedSpec.probe_tables`` enter as extra whole-array
    operands (constant index map — one VMEM residency for the grid) and are
    injected into the in-kernel chunk dict under their keys before the
    member closures run, so the in-kernel gather repeats the scan path's
    expression tree verbatim.  ``use_mxu=True`` lowers group members via
    the one-hot MXU contraction instead of segment_sum (compiled TPU; only
    statistically interchangeable — see ``_chunk_contrib``).

    Bitwise guarantee: identical to folding ``gla.accumulate`` over the C
    chunks (``scan.scan_round_step``), including from a checkpointed
    mid-scan carry.  ``scanned`` (and nothing else) is accumulated outside
    the kernel — live counts are integer-valued f32, exact under any
    association, and need only ``_mask``.
    """
    interpret = _interpret_default() if interpret is None else interpret
    specs = fused_members(gla)
    if specs is None:
        raise ValueError(
            f"GLA {gla.name!r} does not publish a fused kernel contract")
    probes = unique_probes(specs)
    pbytes = sum(pt.nbytes for pt in probes)
    if pbytes > PROBE_VMEM_BUDGET_BYTES:
        raise ValueError(
            f"GLA {gla.name!r}: probe tables need {pbytes} bytes, over the "
            f"{PROBE_VMEM_BUDGET_BYTES}-byte kernel VMEM budget — route "
            f"this plan through the legacy kernel_cols path")
    is_bundle = bool(gla.members)
    states = tuple(state) if is_bundle else (state,)
    meta = _member_meta(specs)
    enc_map = dict(encodings)
    names = sorted(cols)
    mask = cols["_mask"]
    C, L = int(mask.shape[0]), int(mask.shape[1])

    carries = _carry_arrays(specs, meta, states)
    col_args = [cols[n] for n in names]
    col_specs = [pl.BlockSpec((1, int(cols[n].shape[1])), lambda i: (i, 0))
                 for n in names]
    tbl_names, tbl_args, tbl_specs = _table_inputs(names, enc_map)
    probe_args = [jnp.asarray(pt.values) for pt in probes]
    probe_specs = [pl.BlockSpec(a.shape, lambda i, _nd=a.ndim: (0,) * _nd)
                   for a in probe_args]
    carry_specs = [pl.BlockSpec(c.shape, lambda i: (0, 0)) for c in carries]
    out_shape = [jax.ShapeDtypeStruct(c.shape, c.dtype) for c in carries]
    n_cols, n_tbl, n_probe, n_carry = (
        len(names), len(tbl_names), len(probes), len(carries))
    kw = {"use_mxu": True} if use_mxu else {}

    def body(*refs):
        col_refs = refs[:n_cols]
        tbl_refs = refs[n_cols:n_cols + n_tbl]
        probe_refs = refs[n_cols + n_tbl:n_cols + n_tbl + n_probe]
        rest = refs[n_cols + n_tbl + n_probe:]
        in_refs = rest[:n_carry]
        out_refs = rest[n_carry:]

        @pl.when(pl.program_id(0) == 0)
        def _seed():
            for o, c in zip(out_refs, in_refs):
                o[...] = c[...]

        tables = {n: t[...] for n, t in zip(tbl_names, tbl_refs)}
        chunk = _decode_chunk(names, col_refs, enc_map, tables)
        for pt, r in zip(probes, probe_refs):
            chunk[pt.key] = r[...]
        msk = chunk["_mask"].astype(jnp.float32)
        for k, (fs, mrow) in enumerate(zip(specs, meta)):
            d_s, d_q, d_m = _chunk_contrib(fs, mrow, chunk, msk, L, **kw)
            out_refs[3 * k][...] = out_refs[3 * k][...] + d_s
            out_refs[3 * k + 1][...] = out_refs[3 * k + 1][...] + d_q
            out_refs[3 * k + 2][...] = out_refs[3 * k + 2][...] + d_m

    _DISPATCHES[0] += 1
    outs = pl.pallas_call(
        body, grid=(C,),
        in_specs=[*col_specs, *tbl_specs, *probe_specs, *carry_specs],
        out_specs=[pl.BlockSpec(c.shape, lambda i: (0, 0)) for c in carries],
        out_shape=out_shape, interpret=interpret,
    )(*col_args, *tbl_args, *probe_args, *carries)

    scanned_delta = jnp.sum(mask.astype(jnp.float32))
    new_states = _unpack_states(outs, specs, meta, states, scanned_delta)
    return tuple(new_states) if is_bundle else new_states[0]


# ---------------------------------------------------------------------------
# prefix-states kernel (scalar contract; per-chunk running states)
# ---------------------------------------------------------------------------

def fused_prefix_states(gla, cols, encodings=(), *, interpret=None):
    """Whole-shard scalar scan in ONE dispatch, emitting per-chunk prefixes.

    Contract: scalar (non-group, non-bundle) fused GLAs only.  Returns
    ``(final_state, prefix_states)`` where ``prefix_states`` leaves have a
    leading [C + 1] axis — row 0 is init(), row c+1 the state after chunk
    c — exactly the ``scan.scan_prefix`` layout the engines index round
    boundaries (and the sharded sync barrier's pmin truncation) from.

    The kernel keeps the running (sum, sumsq, matched) in revisited
    constant-index-map refs — sequential chunk-order adds, same
    association as the carry-in round step — and snapshots them into a
    per-chunk output row after each grid step, so the whole prefix family
    costs one dispatch (audit: single_kernel_dispatch counts 1 grid loop).
    Bitwise-identical to folding ``gla.accumulate`` chunk by chunk.
    """
    interpret = _interpret_default() if interpret is None else interpret
    specs = fused_members(gla)
    if specs is None or len(specs) != 1 or specs[0].group is not None:
        raise ValueError(
            f"fused_prefix_states needs a solo scalar fused GLA, got "
            f"{gla.name!r}")
    fs = specs[0]
    (meta_row,) = _member_meta((fs,))
    _, A, A_pad, _, _ = meta_row
    enc_map = dict(encodings)
    names = sorted(cols)
    mask = cols["_mask"]
    C, L = int(mask.shape[0]), int(mask.shape[1])

    probes = unique_probes((fs,))
    col_args = [cols[n] for n in names]
    col_specs = [pl.BlockSpec((1, int(cols[n].shape[1])), lambda i: (i, 0))
                 for n in names]
    tbl_names, tbl_args, tbl_specs = _table_inputs(names, enc_map)
    probe_args = [jnp.asarray(pt.values) for pt in probes]
    probe_specs = [pl.BlockSpec(a.shape, lambda i, _nd=a.ndim: (0,) * _nd)
                   for a in probe_args]
    acc_shapes = [jax.ShapeDtypeStruct((1, A_pad), jnp.float32),
                  jax.ShapeDtypeStruct((1, A_pad), jnp.float32),
                  jax.ShapeDtypeStruct((1, 1), jnp.float32)]
    row_shapes = [jax.ShapeDtypeStruct((C, A_pad), jnp.float32),
                  jax.ShapeDtypeStruct((C, A_pad), jnp.float32),
                  jax.ShapeDtypeStruct((C, 1), jnp.float32)]
    acc_specs = [pl.BlockSpec(s.shape, lambda i: (0, 0)) for s in acc_shapes]
    row_specs = [pl.BlockSpec((1, s.shape[1]), lambda i: (i, 0))
                 for s in row_shapes]
    n_cols, n_tbl, n_probe = len(names), len(tbl_names), len(probes)

    def body(*refs):
        col_refs = refs[:n_cols]
        tbl_refs = refs[n_cols:n_cols + n_tbl]
        probe_refs = refs[n_cols + n_tbl:n_cols + n_tbl + n_probe]
        a_s, a_q, a_m, p_s, p_q, p_m = refs[n_cols + n_tbl + n_probe:]

        @pl.when(pl.program_id(0) == 0)
        def _seed():
            a_s[...] = jnp.zeros_like(a_s)
            a_q[...] = jnp.zeros_like(a_q)
            a_m[...] = jnp.zeros_like(a_m)

        tables = {n: t[...] for n, t in zip(tbl_names, tbl_refs)}
        chunk = _decode_chunk(names, col_refs, enc_map, tables)
        for pt, r in zip(probes, probe_refs):
            chunk[pt.key] = r[...]
        msk = chunk["_mask"].astype(jnp.float32)
        d_s, d_q, d_m = _chunk_contrib(fs, meta_row, chunk, msk, L)
        a_s[...] = a_s[...] + d_s
        a_q[...] = a_q[...] + d_q
        a_m[...] = a_m[...] + d_m
        p_s[...] = a_s[...]
        p_q[...] = a_q[...]
        p_m[...] = a_m[...]

    _DISPATCHES[0] += 1
    outs = pl.pallas_call(
        body, grid=(C,),
        in_specs=[*col_specs, *tbl_specs, *probe_specs],
        out_specs=[*acc_specs, *row_specs],
        out_shape=[*acc_shapes, *row_shapes], interpret=interpret,
    )(*col_args, *tbl_args, *probe_args)
    a_s, a_q, a_m, p_s, p_q, p_m = outs

    # scanned prefixes: integer-valued live counts — cumsum is exact, so
    # it matches the scan fold bit-for-bit (DESIGN.md §12)
    m32 = mask.astype(jnp.float32)
    scanned_chunks = jnp.sum(m32, axis=tuple(range(1, m32.ndim)))     # [C]
    zero = jnp.zeros((1,), jnp.float32)
    scanned_pref = jnp.concatenate([zero, jnp.cumsum(scanned_chunks)])

    init = gla.init()
    final = init._replace(
        sum=a_s[0, :A], sumsq=a_q[0, :A], matched=a_m[0, 0],
        scanned=init.scanned + scanned_pref[-1])
    pad_row = jnp.zeros((1, A_pad), jnp.float32)
    prefixes = init._replace(
        sum=jnp.concatenate([pad_row, p_s])[:, :A],
        sumsq=jnp.concatenate([pad_row, p_q])[:, :A],
        matched=jnp.concatenate([jnp.zeros((1, 1), jnp.float32), p_m])[:, 0],
        scanned=scanned_pref)
    return final, prefixes
