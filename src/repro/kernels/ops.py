"""Jit'd wrappers around the Pallas kernels.

Handle layout (flat -> [R, 128] lane tiles), padding, backend dispatch
(interpret=True on CPU — the kernels target TPU), and reduction of
lane-partial accumulators.  Semantics == repro.kernels.ref oracles.

Padding here follows the MXU discipline of docs/KERNELS.md §3: lane dims
pad to 128, sublane dims to 8, padded rows are value-inert (weight 0,
in-range gid), and outputs are sliced back so padding never escapes this
package.  These wrappers serve the legacy ``kernel_cols`` contract; the
fused ``FusedSpec`` dispatch lives in :mod:`repro.kernels.fused_agg`.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels import chunk_agg as _ck
from repro.kernels import group_agg as _gk
from repro.kernels import ref as _ref

LANES = _ck.LANES


def _interpret_default() -> bool:
    return jax.default_backend() != "tpu"


def _pad_rows(x, multiple, fill=0):
    n = x.shape[0]
    pad = (-n) % multiple
    if pad:
        x = jnp.concatenate([x, jnp.full((pad, *x.shape[1:]), fill, x.dtype)])
    return x


def _to_tiles(x, block_rows):
    """[N] -> [R, 128] with R % block_rows == 0 (zero padded)."""
    x = _pad_rows(x, LANES)
    x = x.reshape(-1, LANES)
    return _pad_rows(x, block_rows)


@functools.partial(jax.jit, static_argnames=("block_rows", "interpret"))
def chunk_agg(vals, weight, mask, *, block_rows: int = 256, interpret=None):
    """Fused aggregate over a flat chunk -> [4] f32 (sum, sumsq, scanned, matched).

    vals/weight/mask: [N] any numeric dtype (cast to f32; zero-padded to
    [R, 128] lane tiles, R a multiple of ``block_rows``).  Lane partials
    are reduced here, so the result is interchangeable — not bitwise —
    with the flat mul-reduce (docs/KERNELS.md §2).
    """
    interpret = _interpret_default() if interpret is None else interpret
    v = _to_tiles(vals.astype(jnp.float32), block_rows)
    w = _to_tiles(weight.astype(jnp.float32), block_rows)
    m = _to_tiles(mask.astype(jnp.float32), block_rows)
    acc = _ck.chunk_agg_kernel(v, w, m, block_rows=block_rows,
                               interpret=interpret)
    return jnp.sum(acc[:4], axis=1)


@functools.partial(jax.jit, static_argnames=("block_rows", "interpret"))
def shard_chunk_partials(vals, weight, mask, *, block_rows: int = 256,
                         interpret=None):
    """Per-chunk partials for a whole shard in one kernel dispatch.

    vals/weight/mask: [C, L] -> [C, 4] f32 (sum, sumsq, scanned, matched)
    per chunk.  Used by the engine's ``emit="kernel"`` path (the snapshot
    prefix states are the cumsum of these rows for additive GLAs).

    Legacy scalar dispatch: per-chunk lane partials make the states
    interchangeable-not-bitwise with the scan path.  GLAs publishing a
    ``FusedSpec`` route through ``fused_agg.fused_prefix_states`` instead,
    which is bitwise (DESIGN.md §12).
    """
    interpret = _interpret_default() if interpret is None else interpret
    C, L = vals.shape

    def tiles(x):
        x = x.astype(jnp.float32)
        pad = (-L) % LANES
        if pad:
            x = jnp.concatenate(
                [x, jnp.zeros((C, pad), jnp.float32)], axis=1)
        return x.reshape(C, -1, LANES)

    v, w, m = tiles(vals), tiles(weight), tiles(mask)
    R = v.shape[1]
    br = min(block_rows, R)
    while R % br:
        br -= 1
    acc = _ck.shard_agg_kernel(v, w, m, block_rows=br, interpret=interpret)
    return jnp.sum(acc[:, :4, :], axis=2)


@functools.partial(jax.jit, static_argnames=("block_rows", "interpret"))
def q6_agg(params, shipdate, discount, quantity, extendedprice, mask,
           *, block_rows: int = 256, interpret=None):
    """Fully fused Q6: params [>=5] f32, flat columns -> [4] f32."""
    interpret = _interpret_default() if interpret is None else interpret
    p = jnp.zeros((1, 8), jnp.float32).at[0, : params.shape[0]].set(params)
    tiles = [
        _to_tiles(c.astype(jnp.float32), block_rows)
        for c in (shipdate, discount, quantity, extendedprice, mask)
    ]
    acc = _ck.q6_agg_kernel(p, *tiles, block_rows=block_rows,
                            interpret=interpret)
    return jnp.sum(acc[:4], axis=1)


@functools.partial(jax.jit,
                   static_argnames=("num_groups", "block_rows", "interpret"))
def group_agg(vals, weight, gids, *, num_groups: int, block_rows: int = 512,
              interpret=None):
    """Group-by aggregate.

    vals [N] or [N, A]; weight [N]; gids [N] int32.
    returns (sums [G, A], sumsqs [G, A], matched [G]) f32 — unpadded G/A.

    MXU alignment (group_agg.py contract): G is padded to a multiple of 128
    (the one-hot ``[B, G]`` lane dim) and A to a multiple of 8 even when
    A == 1 (the ``[G, A]`` output sublane pairing); results are sliced back
    to the unpadded shapes.  Padded group columns receive no items (gids are
    in-range) and padded agg columns are zero-filled, so the padding is
    value-inert.

    Bitwise guarantee: with ``block_rows`` pinned to the chunk length the
    kernel adds per-chunk contributions in the scan's association order,
    so round states and finals equal the segment_sum scan bit-for-bit
    (tests/test_groupby_kernel.py, docs/KERNELS.md §2/§6).
    """
    interpret = _interpret_default() if interpret is None else interpret
    if vals.ndim == 1:
        vals = vals[:, None]
    N, A = vals.shape
    A_pad = -(-A // 8) * 8
    G_pad = -(-num_groups // 128) * 128
    v = jnp.zeros((N, A_pad), jnp.float32).at[:, :A].set(vals.astype(jnp.float32))
    v = _pad_rows(v, block_rows)
    w = _pad_rows(weight.astype(jnp.float32)[:, None], block_rows)
    # padded rows get weight 0 AND an in-range gid so the one-hot is harmless
    g = _pad_rows(gids.astype(jnp.int32)[:, None], block_rows)
    sums, sumsqs, matched = _gk.group_agg_kernel(
        v, w, g, num_groups=G_pad, block_rows=block_rows, interpret=interpret
    )
    return (sums[:num_groups, :A], sumsqs[:num_groups, :A],
            matched[:num_groups, 0])


# re-export oracles for convenience
chunk_agg_ref = _ref.chunk_agg_ref
q6_agg_ref = _ref.q6_agg_ref
group_agg_ref = _ref.group_agg_ref
