"""Pallas TPU kernel: fused predicate + func + streaming aggregation.

The paper's Accumulate hot loop (Alg. 1) reads a chunk and updates
(sum, sumSq, count) under a selection predicate.  On the paper's system this
is disk-bound; on TPU it is HBM-bandwidth-bound (arithmetic intensity < 1
FLOP/byte), so the kernel's job is to touch each column byte exactly once:
stream [block, 128] tiles HBM→VMEM, evaluate the predicate on the VPU, and
keep the 4-scalar state resident in VMEM across grid steps (the classic
revisited-output accumulator pattern).

Three entry points:

  * ``chunk_agg_kernel``  — generic: takes precomputed ``vals``/``weight``.
  * ``q6_agg_kernel``     — fully fused TPC-H Q6: raw columns in, predicate
    and func evaluated in-kernel, so intermediates never hit HBM.  This is
    the kernel the paper's zero-overhead claim leans on: sum/sumSq/count add
    ≤3 VPU ops/item to a memory-bound stream.
  * ``shard_agg_kernel``  — per-shard dispatch (engine ``emit="kernel"``,
    DESIGN.md §3): one launch covers a whole [C, rows, 128] shard on a 2D
    grid (chunk-major) and emits *per-chunk* accumulator tiles [C, 8, 128].
    Additive states make the engine's snapshot prefixes a cumsum of these
    partials, so the sharded engine issues C·P fewer kernel launches while
    producing states interchangeable with the scan path.

Accumulator layout: [8, 128] f32 (one aligned VREG tile); rows 0..3 hold
lane-partials of (sum, sumsq, scanned, matched); the host wrapper reduces
over lanes.  Output block index is constant over the grid so the tile stays
in VMEM; it is zero-initialized at step 0 with ``pl.when``.

These are the legacy ``kernel_cols`` scalar kernels: the lane-partial
layout makes their states interchangeable — not bitwise — with the scan
path.  GLAs that publish a ``FusedSpec`` dispatch
:mod:`repro.kernels.fused_agg` instead, whose scalar accumulation replays
the scan's exact expression tree (DESIGN.md §12, docs/KERNELS.md).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

LANES = 128
ACC_ROWS = 8  # aligned (8, 128) f32 tile


def _acc_update(acc_ref, v, w, m):
    """acc rows: 0=sum(v·w·m) 1=sum(v²·w·m) 2=sum(m) 3=sum(w·m).

    ``w`` is the predicate weight, ``m`` the liveness mask; their product is
    fused here (one extra VPU multiply on a memory-bound stream).
    """
    wm = w * m
    z = jnp.zeros((ACC_ROWS - 4, LANES), jnp.float32)
    upd = jnp.concatenate(
        [
            jnp.sum(v * wm, axis=0, keepdims=True),
            jnp.sum(v * v * wm, axis=0, keepdims=True),
            jnp.sum(m, axis=0, keepdims=True),
            jnp.sum(wm, axis=0, keepdims=True),
            z,
        ],
        axis=0,
    )
    acc_ref[...] += upd


def _chunk_agg_body(vals_ref, weight_ref, mask_ref, acc_ref):
    @pl.when(pl.program_id(0) == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    v = vals_ref[...].astype(jnp.float32)
    w = weight_ref[...].astype(jnp.float32)
    m = mask_ref[...].astype(jnp.float32)
    _acc_update(acc_ref, v, w, m)


def chunk_agg_kernel(vals, weight, mask, *, block_rows: int = 256,
                     interpret: bool = False):
    """vals/weight/mask: [R, 128] (R % block_rows == 0) -> [8, 128] partials."""
    R = vals.shape[0]
    assert vals.shape[1] == LANES and R % block_rows == 0
    grid = (R // block_rows,)
    spec = pl.BlockSpec((block_rows, LANES), lambda i: (i, 0))
    return pl.pallas_call(
        _chunk_agg_body,
        grid=grid,
        in_specs=[spec, spec, spec],
        out_specs=pl.BlockSpec((ACC_ROWS, LANES), lambda i: (0, 0)),
        out_shape=jax.ShapeDtypeStruct((ACC_ROWS, LANES), jnp.float32),
        interpret=interpret,
    )(vals, weight, mask)


def _shard_agg_body(vals_ref, weight_ref, mask_ref, acc_ref):
    @pl.when(pl.program_id(1) == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    v = vals_ref[0].astype(jnp.float32)
    w = weight_ref[0].astype(jnp.float32)
    m = mask_ref[0].astype(jnp.float32)
    wm = w * m
    z = jnp.zeros((ACC_ROWS - 4, LANES), jnp.float32)
    upd = jnp.concatenate(
        [
            jnp.sum(v * wm, axis=0, keepdims=True),
            jnp.sum(v * v * wm, axis=0, keepdims=True),
            jnp.sum(m, axis=0, keepdims=True),
            jnp.sum(wm, axis=0, keepdims=True),
            z,
        ],
        axis=0,
    )
    acc_ref[...] += upd[None]


def shard_agg_kernel(vals, weight, mask, *, block_rows: int = 256,
                     interpret: bool = False):
    """Whole-shard per-chunk aggregation in ONE kernel dispatch.

    vals/weight/mask: [C, R, 128] (R % block_rows == 0) -> [C, 8, 128]
    per-chunk accumulator tiles.  The grid is (C, R // block_rows) with the
    block index innermost, so chunk c's output tile is revisited across its
    blocks and stays resident in VMEM (zero-initialized at block 0).
    """
    C, R, lanes = vals.shape
    assert lanes == LANES and R % block_rows == 0, (vals.shape, block_rows)
    grid = (C, R // block_rows)
    spec = pl.BlockSpec((1, block_rows, LANES), lambda i, j: (i, j, 0))
    return pl.pallas_call(
        _shard_agg_body,
        grid=grid,
        in_specs=[spec, spec, spec],
        out_specs=pl.BlockSpec((1, ACC_ROWS, LANES), lambda i, j: (i, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((C, ACC_ROWS, LANES), jnp.float32),
        interpret=interpret,
    )(vals, weight, mask)


def _q6_body(params_ref, shipdate_ref, discount_ref, quantity_ref,
             extprice_ref, mask_ref, acc_ref):
    @pl.when(pl.program_id(0) == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    p = params_ref[0, :]
    date_lo, date_hi, disc_lo, disc_hi, qty_eq = p[0], p[1], p[2], p[3], p[4]
    sd = shipdate_ref[...].astype(jnp.float32)
    dc = discount_ref[...].astype(jnp.float32)
    qt = quantity_ref[...].astype(jnp.float32)
    ep = extprice_ref[...].astype(jnp.float32)
    m = mask_ref[...].astype(jnp.float32)
    cond = (
        (sd >= date_lo) & (sd < date_hi)
        & (dc >= disc_lo) & (dc <= disc_hi)
        & (qt == qty_eq)
    ).astype(jnp.float32)
    _acc_update(acc_ref, ep * dc, cond * m, m)


def q6_agg_kernel(params, shipdate, discount, quantity, extendedprice, mask,
                  *, block_rows: int = 256, interpret: bool = False):
    """Fully fused Q6.  params [1, 8] f32; columns [R, 128] -> [8, 128]."""
    R = shipdate.shape[0]
    assert R % block_rows == 0
    grid = (R // block_rows,)
    col = pl.BlockSpec((block_rows, LANES), lambda i: (i, 0))
    par = pl.BlockSpec((1, 8), lambda i: (0, 0))
    return pl.pallas_call(
        _q6_body,
        grid=grid,
        in_specs=[par, col, col, col, col, col],
        out_specs=pl.BlockSpec((ACC_ROWS, LANES), lambda i: (0, 0)),
        out_shape=jax.ShapeDtypeStruct((ACC_ROWS, LANES), jnp.float32),
        interpret=interpret,
    )(params, shipdate, discount, quantity, extendedprice, mask)
