"""Synthetic TPC-H lineitem-like data — the paper's evaluation workload.

The paper runs over an 8 TB TPC-H instance (48e9 lineitem rows over 8 nodes).
This container is CPU-only, so the generator reproduces the *distributions*
TPC-H dbgen uses for the columns the paper's queries touch, scaled by
``rows``.  Selectivity regimes match the paper:

  * Q6 low-selectivity  — one-year shipdate window  (~2.8e-4 match rate)
  * Q6 high-selectivity — single-day shipdate       (~7.3e-7 in the paper;
    here a single day out of 2,526 ⇒ needle-in-haystack at our scale)
  * Q1 group-by small   — 4 populated (returnflag, linestatus) groups
  * Q1 group-by large   — group by suppkey (paper: 1M groups; scaled)
  * join group-by       — lineitem ⋈ supplier ⋈ nation (25 nations),
    supplier/nation replicated + pre-joined (paper §5.4 strategy)

Column encodings (all numeric, columnar):
  shipdate  int32  days in [0, 2526)   (1992-01-02 .. 1998-12-01)
  discount  float32 in {0.00 .. 0.10}  (dbgen: uniform 11 values)
  quantity  float32 in {1 .. 50}
  extendedprice float32
  tax       float32 in {0.00 .. 0.08}
  rfls      int32 in [0, 4)   returnflag×linestatus combined group
  suppkey   int32 in [0, num_suppliers)
"""
from __future__ import annotations

from typing import Dict

import jax.numpy as jnp
import numpy as np

DAYS = 2526  # dbgen shipdate span
Q6_LOW_WINDOW = (420, 785)   # ~1 year starting '1993-02-26'
Q6_HIGH_WINDOW = (420, 421)  # the single day '1993-02-26'
Q1_WINDOW = (2434, 2526)     # ['1998-09-01','1998-12-01']
NUM_NATIONS = 25


def generate_lineitem(rows: int, *, num_suppliers: int = 1000, seed: int = 7,
                      dtype=np.float32) -> Dict[str, np.ndarray]:
    rng = np.random.default_rng(seed)
    cols = {
        "shipdate": rng.integers(0, DAYS, rows, dtype=np.int32),
        "discount": (rng.integers(0, 11, rows) / 100.0).astype(dtype),
        "quantity": rng.integers(1, 51, rows).astype(dtype),
        "extendedprice": (rng.uniform(900.0, 105000.0, rows) / 1000.0).astype(dtype),
        "tax": (rng.integers(0, 9, rows) / 100.0).astype(dtype),
        "rfls": rng.integers(0, 4, rows, dtype=np.int32),
        "suppkey": rng.integers(0, num_suppliers, rows, dtype=np.int32),
    }
    return cols


def supplier_nation_table(num_suppliers: int = 1000, seed: int = 11):
    """Replicated dimension side: suppkey -> nationkey, plus validity.

    Mirrors the paper's strategy: supplier ⋈ nation pre-joined in memory,
    hashed on suppkey (paper §5.4).
    """
    rng = np.random.default_rng(seed)
    supp_nation = rng.integers(0, NUM_NATIONS, num_suppliers).astype(np.int32)
    valid = np.ones(num_suppliers, np.float32)
    return supp_nation, valid


# --- query pieces -----------------------------------------------------------

def q6_func(chunk):
    return chunk["extendedprice"] * chunk["discount"]


def q6_cond(window):
    lo, hi = window

    def cond(chunk):
        sd = chunk["shipdate"]
        return (
            (sd >= lo) & (sd < hi)
            & (chunk["discount"] >= 0.02 - 1e-6) & (chunk["discount"] <= 0.03 + 1e-6)
            & (chunk["quantity"] == 1.0)
        ).astype(jnp.float32)

    return cond


def q1_func(chunk):
    """The four Q1 SUM aggregates, stacked [n, 4]."""
    ep, dc, tx = chunk["extendedprice"], chunk["discount"], chunk["tax"]
    return jnp.stack(
        [chunk["quantity"], ep, ep * (1 - dc), ep * (1 - dc) * (1 + tx)], axis=-1
    )


def q1_cond(chunk):
    sd = chunk["shipdate"]
    return ((sd >= Q1_WINDOW[0]) & (sd < Q1_WINDOW[1])).astype(jnp.float32)


def q1_group_small(chunk):
    return chunk["rfls"]


def q1_group_large(chunk):
    return chunk["suppkey"]


# Scaled large-domain Q1 (paper §5.3: 1M groups): suppkey spans >= 100k raw
# ids, folded into 2**bucket_bits hash buckets (repro/core/gla.hash_bucket)
# so the dense composite state stays TPU/VMEM-feasible.
Q1_LARGE_SUPPLIERS = 100_000
Q1_LARGE_BUCKET_BITS = 13


def q1_large_scenario(rows: int, *, num_suppliers: int = Q1_LARGE_SUPPLIERS,
                      bucket_bits: int = Q1_LARGE_BUCKET_BITS, seed: int = 7,
                      estimator: str = "single"):
    """Large-domain Q1 group-by: columns + a hash-bucketed group-by GLA.

    The GLA publishes the group-by kernel projection, so it runs through
    ``engine.run_query(emit="kernel")`` (one ``ops.group_agg`` dispatch per
    round-slice) as well as the segment_sum paths.  Returns ``(cols, gla)``.
    """
    from repro.core import gla as _gla  # local: data must not require core

    cols = generate_lineitem(rows, num_suppliers=num_suppliers, seed=seed)
    g = _gla.make_groupby_gla(
        q1_func, q1_cond, q1_group_large, num_groups=num_suppliers,
        bucket_bits=bucket_bits, d_total=float(rows), estimator=estimator,
        num_aggs=4)
    return cols, g


# --- two-table Q3/Q10-class join scenarios ---------------------------------
#
# lineitem ⋈ orders on orderkey, group by an orders-side attribute with an
# orders-side date predicate — the TPC-H Q3/Q10 family shape.  The orders
# dimension is replicated + pre-joined in memory (paper §5.4), so it rides
# the fused kernel as ProbeTable operands (DESIGN.md §13).

NUM_SEGMENTS = 5        # dbgen c_mktsegment / o_orderpriority-scale domain
Q3_DATE_CUTOFFS = (430, 2100)   # orders-side o_orderdate window

def generate_orders_fk(rows: int, *, num_orders: int | None = None,
                       seed: int = 7) -> np.ndarray:
    """The lineitem-side foreign key l_orderkey, int32 [rows].

    Generated separately so :func:`generate_lineitem` stays byte-stable
    for every existing scenario; callers add it as ``cols["orderkey"]``.
    """
    num_orders = num_orders or max(1, rows // 4)
    rng = np.random.default_rng(seed + 101)
    return rng.integers(0, num_orders, rows, dtype=np.int32)


def orders_table(num_orders: int, seed: int = 13, *,
                 date_window=Q3_DATE_CUTOFFS):
    """Replicated orders dimension: orderkey -> (segment group, validity).

    ``segment`` plays c_mktsegment (Q3) / n_name (Q10); ``valid`` is the
    orders-side date predicate cond_M(M.sAtts), evaluated once at build
    time exactly like supplier ⋈ nation pre-joining (paper §5.4).
    """
    rng = np.random.default_rng(seed)
    segment = rng.integers(0, NUM_SEGMENTS, num_orders).astype(np.int32)
    orderdate = rng.integers(0, DAYS, num_orders).astype(np.int32)
    lo, hi = date_window
    valid = ((orderdate >= lo) & (orderdate < hi)).astype(np.float32)
    return segment, valid


def q3_scenario(rows: int, *, num_orders: int | None = None, seed: int = 7,
                estimator: str = "single"):
    """Q3-class join: SUM(revenue) per order segment, orders date-windowed.

    Returns ``(cols, gla, dim)`` with ``dim = (segment, valid)``; the GLA
    publishes a fused projection whose probe tables are the dim arrays, so
    it runs the one-dispatch fused kernel on both engines.
    """
    from repro.core import gla as _gla  # local: data must not require core

    cols = generate_lineitem(rows, seed=seed)
    cols["orderkey"] = generate_orders_fk(rows, num_orders=num_orders,
                                          seed=seed)
    n_orders = num_orders or max(1, rows // 4)
    segment, valid = orders_table(n_orders, seed=seed + 7)
    g = _gla.make_join_groupby_gla(
        q6_func, q1_cond, lambda c: c["orderkey"], segment, valid,
        num_groups=NUM_SEGMENTS, d_total=float(rows), estimator=estimator)
    return cols, g, (segment, valid)


def q10_scenario(rows: int, *, num_orders: int | None = None, seed: int = 7,
                 estimator: str = "single"):
    """Q10-class join: the four Q1 SUM aggregates per order segment.

    Same two-table shape as Q3 with a wider aggregate block ([G, 4]
    states) — exercises the fused kernel's A-axis padding under join
    probes.  Returns ``(cols, gla, dim)``.
    """
    from repro.core import gla as _gla

    cols = generate_lineitem(rows, seed=seed)
    cols["orderkey"] = generate_orders_fk(rows, num_orders=num_orders,
                                          seed=seed)
    n_orders = num_orders or max(1, rows // 4)
    segment, valid = orders_table(n_orders, seed=seed + 7)
    g = _gla.make_join_groupby_gla(
        q1_func, q1_cond, lambda c: c["orderkey"], segment, valid,
        num_groups=NUM_SEGMENTS, d_total=float(rows), estimator=estimator,
        num_aggs=4)
    return cols, g, (segment, valid)


def _exact_batches(cols, batch_rows: int):
    """Yield bounded row-batch chunk dicts (with ``_mask``) from either a
    flat columnar dict or a ``repro.data.source.ChunkSource``.

    Streaming sources are read one chunk-slice group at a time and
    flattened to rows with their real mask, so the reference never holds
    more than O(batch) rows on host or device — the same out-of-core
    discipline as the engine (DESIGN.md §8).
    """
    from repro.data import source as _source  # local: optional coupling

    if isinstance(cols, _source.ChunkSource):
        from repro.data import encodings as _encodings

        P, C, L = cols.spec.P, cols.spec.C, cols.spec.L
        step = max(1, batch_rows // max(1, P * L))
        for lo in range(0, C, step):
            sl = cols.slice_cols(lo, min(C, lo + step))
            if cols.encodings:  # physical codes/words -> logical values
                sl = _encodings.decode_cols(sl, cols.encodings)
            chunk = {}
            for k, v in sl.items():
                a = np.asarray(v)  # one host materialization per column
                chunk[k] = jnp.asarray(a.reshape((-1, *a.shape[3:])))
            yield chunk
        return
    n = next(iter(cols.values())).shape[0]
    for lo in range(0, n, batch_rows):
        chunk = {k: jnp.asarray(v[lo:lo + batch_rows]) for k, v in cols.items()}
        if "_mask" not in chunk:
            first = next(iter(chunk.values()))
            chunk["_mask"] = jnp.ones(first.shape[:1], jnp.float32)
        yield chunk


def exact_answer(cols, func, cond, group=None,
                 num_groups: int | None = None, *,
                 batch_rows: int = 1 << 18,
                 join_key=None, dim_group=None, dim_valid=None):
    """Ground truth in float64 (the oracle for all correctness tests).

    ``cols`` is a flat columnar dict (host rows) OR any
    ``repro.data.source.ChunkSource``.  The reference is accumulated over
    bounded host batches in float64 rather than materializing the entire
    dataset as one device chunk — which OOMed exactly at the out-of-core
    scales the source layer unlocks.  Padded rows contribute nothing: the
    batch's ``_mask`` folds into the predicate weight.

    Two-table joins (Q3/Q10 class): pass ``join_key`` (chunk -> fact-side
    foreign keys) with the replicated ``dim_group``/``dim_valid`` arrays.
    Each bounded batch gathers its own keys' dimension rows on host —
    only O(batch + |dim|) resident, never the whole fact table — with the
    dimension predicate folded into the weight and the group read through
    the join, mirroring ``gla.make_join_groupby_gla``.
    """
    if join_key is not None and (dim_group is None or dim_valid is None):
        raise ValueError("join oracle needs dim_group and dim_valid")
    dim_group = None if dim_group is None else np.asarray(dim_group)
    dim_valid = None if dim_valid is None else np.asarray(dim_valid, np.float64)
    acc = None
    out = None
    grouped = group is not None or (join_key is not None
                                    and dim_group is not None)
    for chunk in _exact_batches(cols, batch_rows):
        vals = np.asarray(func(chunk), np.float64)
        w = (np.asarray(cond(chunk), np.float64)
             * np.asarray(chunk["_mask"], np.float64))
        if join_key is not None:
            keys = np.asarray(join_key(chunk), np.int64)
            w = w * dim_valid[keys]
            gid = dim_group[keys]
        elif group is not None:
            gid = np.asarray(group(chunk))
        if vals.ndim == 1:
            vals = vals[:, None]
        contrib = vals * w[:, None]
        if not grouped:
            s = contrib.sum(axis=0)
            acc = s if acc is None else acc + s
        else:
            if out is None:
                out = np.zeros((num_groups, vals.shape[1]))
            np.add.at(out, gid, contrib)
    return out if grouped else acc
