"""Synthetic token streams for LM training/serving examples.

Deterministic, cursor-addressable (checkpoint/restart needs to resume the
stream at an exact position), with a Zipf-ish unigram distribution plus
short-range repetition structure so small models have something learnable.
"""
from __future__ import annotations

import jax.numpy as jnp
import numpy as np


def _zipf_probs(vocab: int, alpha: float = 1.1):
    r = np.arange(1, vocab + 1, dtype=np.float64)
    p = r ** (-alpha)
    return p / p.sum()


def token_batches(cfg, batch: int, seq: int, *, start: int = 0, seed: int = 0):
    """Generator of ({"tokens": [B, S]}, next_cursor) with stable cursors."""
    probs = _zipf_probs(cfg.vocab_size)
    cursor = start
    while True:
        rng = np.random.default_rng(seed * 1_000_003 + cursor)
        toks = rng.choice(cfg.vocab_size, size=(batch, seq), p=probs)
        # inject copy structure: second half repeats the first half shifted
        half = seq // 2
        toks[:, half:half * 2] = toks[:, :half]
        batch_dict = {"tokens": jnp.asarray(toks, jnp.int32)}
        if cfg.frontend == "vision_stub":
            frames = rng.normal(size=(batch, cfg.vis_tokens, cfg.d_model))
            batch_dict["patches"] = jnp.asarray(frames, jnp.float32)
        if cfg.is_encoder_decoder:
            fr = rng.normal(size=(batch, cfg.encoder_seq, cfg.d_model))
            batch_dict["frames"] = jnp.asarray(fr, jnp.float32)
        cursor += 1
        yield batch_dict, cursor
