"""Streaming chunk sources — out-of-core scans (DESIGN.md §8).

The paper's headline claim is early estimates over an **8 TB** TPC-H
instance — data far larger than any node's memory — yet the engine's
native input is a resident columnar dict with `[P, C, L]` device arrays,
capping scale at accelerator RAM.  This module decouples the *scan* from
data *residency*: a :class:`ChunkSource` yields round-slices of
`[P, slice, L]` column batches (plus per-chunk ``_mask`` tuple counts for
progress accounting) and the incremental session driver
(`repro/core/session.py`) pulls one slice per round, double-buffered
through a host→device prefetcher, so peak device footprint is O(slice) —
not O(dataset) — while finals, snapshots and per-round bounds stay
bitwise-identical to the in-memory path on both engines
(tests/test_source.py).

Three implementations:

  * :class:`InMemorySource` — wraps today's shard dicts; the compatibility
    default (`as_source` wraps any plain dict in one).  Slicing stays the
    lazy device-array slicing the engine always did.
  * :class:`NpyMmapSource` — memory-mapped columnar ``.npy`` files, one
    `[P, C, L]` array per column (``NpyMmapSource.save`` writes the
    layout).  Reads page in only the requested slice.
  * :class:`ParquetSource` — optional ``pyarrow``; one ``part-*.parquet``
    file of live rows per partition, read via columnar row-group batches
    (predicate-free projection pushdown — only requested columns and the
    covering row groups are materialized).  The padded `[P, C, L]` layout
    it reconstructs is exactly ``randomize.pack_partitions`` of the same
    ragged partitions, so results are bitwise-identical to packing the
    rows in memory.

Two cross-cutting pieces live here too: :class:`RepartitionedSource`, a
P'-way *view* of a P-way source (round-robin chunk interleaving that keeps
scanned prefixes prefixes — the data half of elastic checkpoint resume,
DESIGN.md §9), and :class:`PartitionLostError`, the exception a source
raises when a partition's storage dies mid-scan (the detection half of the
session's ``FaultPolicy``).

Every source also publishes a cheap **content fingerprint** (per-partition
per-chunk ``_mask`` sums + strided column samples, hashed) used by
``Session.pause``/``resume`` to reject resuming against different data —
same-shape-different-content silently produces wrong finals otherwise.
It is a *best-effort sampled check*, not a full-content hash (a full read
at pause time would defeat the out-of-core design): it catches shape or
tuple-count mismatches and any content change at the sampled positions,
but an edit confined to unsampled elements that also preserves per-chunk
live counts passes undetected.  The fingerprint is a function of the
*logical data*, not the storage, so a session paused over in-memory
shards can resume over an ``.npy`` or parquet copy of the same dataset.
"""
from __future__ import annotations

import hashlib
from pathlib import Path
from typing import Dict, List, NamedTuple, Optional, Tuple

import numpy as np

# Bound on host bytes touched per fingerprint/mask-sum pass; keeps the
# strided sample cheap even for multi-GB mmaps.
_SAMPLE_CHUNKS = 8
_SAMPLE_ELEMS = 256


class PartitionLostError(RuntimeError):
    """A partition's storage/device vanished mid-scan (DESIGN.md §9).

    Raised by sources (and surfaced through the session's prefetcher) when
    a slice read touches a partition that no longer exists.  Sessions with
    a ``repro.core.session.FaultPolicy`` attached catch it, record the
    failure round, and retry the read; sources must serve subsequent reads
    with the dead partitions' columns and masks zeroed — the data is gone,
    not stale.  Without a policy the error propagates: losing data is not
    silently survivable by default.
    """

    def __init__(self, partitions):
        self.partitions = tuple(sorted(int(p) for p in partitions))
        super().__init__(
            f"partitions lost mid-scan: {list(self.partitions)}")


class ColumnSpec(NamedTuple):
    name: str
    # np.dtype(...).name, e.g. "float32": unlike .str it round-trips JAX
    # extension dtypes (np.dtype(bfloat16).str is the opaque "<V2", but
    # .name is "bfloat16", which np.dtype() resolves while ml_dtypes is
    # registered — i.e. whenever jax is importable)
    dtype: str
    trailing: Tuple[int, ...] = ()  # dims after [P, C, L] (usually none)
    # logical elements per stored element: 1 for plain columns; the
    # per-word lane count for bit-packed physical columns, so a stored
    # chunk is [L // lanes] elements wide (DESIGN.md §12)
    lanes: int = 1


class ChunkSpec(NamedTuple):
    """Static shape contract of a source: [P, C, L] plus column table."""

    P: int
    C: int
    L: int
    columns: Tuple[ColumnSpec, ...]   # sorted by name; includes "_mask"

    def slice_like(self, width: int):
        """jax.ShapeDtypeStruct skeleton of one [P, width, L] slice —
        what ``Session._payload_like`` feeds eval_shape, so checkpoint
        deserialization never needs live data."""
        import jax

        return {
            c.name: jax.ShapeDtypeStruct(
                (self.P, width, self.L // c.lanes, *c.trailing),
                np.dtype(c.dtype))
            for c in self.columns
        }

    def meta(self) -> dict:
        """msgpack-able form for checkpoint envelopes."""
        return {"P": self.P, "C": self.C, "L": self.L,
                "columns": [[c.name, c.dtype, list(c.trailing)]
                            for c in self.columns]}


class ChunkSource:
    """Base class: a [P, C, L] columnar dataset readable in chunk slices.

    Subclasses set ``spec`` and implement :meth:`slice_cols`.  ``resident``
    is True when the whole dataset already lives on device (the in-memory
    compatibility path) — the engine then keeps its classic fused
    whole-scan programs; streaming sources run the incremental discipline.

    ``spec`` is always the *logical* shape contract — what the query
    closures see after any decode.  ``encodings`` (name-sorted tuple of
    ``(column, repro.data.encodings.Encoding)``) declares which columns
    :meth:`slice_cols` returns in *physical* (encoded) form; consumers
    decode via ``encodings.decode_cols`` or inside the fused kernel
    (DESIGN.md §12).  ``_mask`` is never encoded.
    """

    spec: ChunkSpec
    resident: bool = False
    encodings: tuple = ()

    def slice_cols(self, lo: int, hi: int) -> Dict[str, np.ndarray]:
        """Columns of chunk range [lo, hi): dict of [P, hi-lo, L] arrays
        (host ndarrays for streaming sources), including ``_mask``.
        Columns named in ``encodings`` come back physical (encoded)."""
        raise NotImplementedError

    # -- physical layout (what actually crosses host -> device) -------------

    def physical_columns(self) -> Tuple[ColumnSpec, ...]:
        """Column table of the bytes :meth:`slice_cols` actually returns:
        the logical table with encoded columns swapped to their stored
        dtype and per-element lane count.  Plain sources return
        ``spec.columns`` unchanged."""
        if not self.encodings:
            return self.spec.columns
        enc = dict(self.encodings)
        out = []
        for c in self.spec.columns:
            e = enc.get(c.name)
            if e is None:
                out.append(c)
            else:
                out.append(ColumnSpec(c.name, e.physical_dtype(), c.trailing,
                                      e.lanes))
        return tuple(out)

    def step_slice_like(self, width: int):
        """ShapeDtypeStruct skeleton of one *physical* [P, width, ·] slice —
        the operand shapes of the incremental step program (and of
        ``Session._payload_like``'s eval_shape), honoring per-column
        encodings.  Equal to ``spec.slice_like(width)`` for plain sources."""
        phys = ChunkSpec(self.spec.P, self.spec.C, self.spec.L,
                         self.physical_columns())
        return phys.slice_like(width)

    # -- tuple-count accounting (progress / d_local without residency) ------

    def mask_chunk_sums(self) -> np.ndarray:
        """Per-(partition, chunk) live-tuple counts, float64 [P, C].

        Computed once (streamed in bounded slices for on-disk sources) and
        cached.  Counts are integers, so float64 is exact and the f32
        casts downstream match the device-side ``jnp.sum`` of the resident
        mask bit-for-bit up to 2**24 tuples per reduction.
        """
        if getattr(self, "_mask_sums", None) is None:
            P, C, _ = self.spec.P, self.spec.C, self.spec.L
            out = np.zeros((P, C), np.float64)
            step = max(1, _SAMPLE_CHUNKS * 64)
            for lo in range(0, C, step):
                hi = min(C, lo + step)
                m = np.asarray(self.slice_cols(lo, hi)["_mask"])
                out[:, lo:hi] = m.sum(axis=2, dtype=np.float64)
            self._mask_sums = out
        return self._mask_sums

    # -- content fingerprint (DESIGN.md §8) ---------------------------------

    def fingerprint(self) -> str:
        """Cheap content hash: sha256 over the shape spec, the per-chunk
        ``_mask`` sums, and strided element samples of every column at up
        to ``_SAMPLE_CHUNKS`` evenly-spaced chunks.  Identical data yields
        the identical fingerprint regardless of the storage backend.
        Best-effort by design — O(samples) reads, not a full-content
        hash; see the module docstring for what escapes it."""
        if getattr(self, "_fingerprint", None) is None:
            spec = self.spec
            h = hashlib.sha256()
            h.update(repr(spec).encode())
            h.update(np.ascontiguousarray(self.mask_chunk_sums()).tobytes())
            n_samp = min(spec.C, _SAMPLE_CHUNKS)
            sample_chunks = sorted(
                {int(i) for i in np.linspace(0, spec.C - 1, n_samp)})
            stride = max(1, spec.L // _SAMPLE_ELEMS)
            for c in sample_chunks:
                sl = self._fingerprint_slice(c, c + 1)
                for name in sorted(sl):
                    v = np.asarray(sl[name])[:, 0, ::stride]
                    h.update(name.encode())
                    h.update(np.ascontiguousarray(v).tobytes())
            self._fingerprint = h.hexdigest()
        return self._fingerprint

    def _fingerprint_slice(self, lo: int, hi: int):
        """Fingerprint sampling reads *logical* values: encoded columns are
        decoded first, so an encoded copy of a dataset fingerprints equal
        to the plain original (checkpoints cross the encoding boundary,
        DESIGN.md §12)."""
        sl = self.slice_cols(lo, hi)
        if self.encodings:
            from repro.data import encodings as _enc

            sl = {k: np.asarray(v)
                  for k, v in _enc.decode_cols(sl, self.encodings).items()}
        return sl


def _spec_from_arrays(arrays: Dict[str, np.ndarray]) -> ChunkSpec:
    P, C, L = arrays["_mask"].shape[:3]
    cols = tuple(
        ColumnSpec(k, np.dtype(arrays[k].dtype).name,
                   tuple(arrays[k].shape[3:]))
        for k in sorted(arrays))
    return ChunkSpec(int(P), int(C), int(L), cols)


class InMemorySource(ChunkSource):
    """Wraps a resident [P, C, L] shards dict — the compatibility default.

    ``slice_cols`` is the same lazy device-array slicing the session always
    did, so the in-memory path is byte- and schedule-identical to the
    pre-source engine.
    """

    resident = True

    def __init__(self, shards: Dict[str, "np.ndarray"]):
        if "_mask" not in shards:
            raise ValueError("shards dict must include a '_mask' column")
        self.shards = shards
        self.spec = _spec_from_arrays(shards)

    def slice_cols(self, lo: int, hi: int):
        return {k: v[:, lo:hi] for k, v in self.shards.items()}

    def mask_chunk_sums(self) -> np.ndarray:
        # one device-side reduction; only the [P, C] result crosses to host
        if getattr(self, "_mask_sums", None) is None:
            import jax.numpy as jnp

            self._mask_sums = np.asarray(
                jnp.sum(self.shards["_mask"], axis=2), np.float64)
        return self._mask_sums


class NpyMmapSource(ChunkSource):
    """Memory-mapped columnar ``.npy`` files: ``<dir>/<column>.npy``, each
    a [P, C, L] array, ``_mask.npy`` required.  ``np.load(mmap_mode='r')``
    keeps the OS page cache in charge — a slice read touches only the
    pages of that chunk range."""

    def __init__(self, directory):
        self.directory = Path(directory)
        paths = sorted(self.directory.glob("*.npy"))
        if not paths:
            raise FileNotFoundError(f"no .npy columns under {self.directory}")
        self._cols = {p.stem: np.load(p, mmap_mode="r") for p in paths}
        if "_mask" not in self._cols:
            raise ValueError(f"{self.directory} lacks _mask.npy")
        shape = self._cols["_mask"].shape
        for k, v in self._cols.items():
            if v.shape[:3] != shape[:3]:
                raise ValueError(
                    f"column {k!r} shape {v.shape} does not match _mask "
                    f"{shape}")
        self.spec = _spec_from_arrays(self._cols)

    @staticmethod
    def save(shards: Dict[str, "np.ndarray"], directory) -> Path:
        """Write a resident shards dict as the mmap-able column layout."""
        directory = Path(directory)
        directory.mkdir(parents=True, exist_ok=True)
        for k, v in shards.items():
            np.save(directory / f"{k}.npy", np.asarray(v))
        return directory

    def slice_cols(self, lo: int, hi: int):
        # np.ascontiguousarray materializes ONLY the slice on host; the
        # prefetcher device_puts it, so device footprint stays O(slice).
        return {k: np.ascontiguousarray(v[:, lo:hi])
                for k, v in self._cols.items()}

    def mask_chunk_sums(self) -> np.ndarray:
        # Only the mask column is summed — the generic fallback would
        # materialize every column of every chunk just to read _mask,
        # a full-dataset host read on the backend built to avoid one.
        if getattr(self, "_mask_sums", None) is None:
            mask = self._cols["_mask"]
            C = self.spec.C
            out = np.zeros((self.spec.P, C), np.float64)
            step = max(1, _SAMPLE_CHUNKS * 64)
            for lo in range(0, C, step):
                hi = min(C, lo + step)
                out[:, lo:hi] = mask[:, lo:hi].sum(axis=2,
                                                   dtype=np.float64)
            self._mask_sums = out
        return self._mask_sums


class EncodedSource(ChunkSource):
    """Columnar source storing dictionary-coded / bit-packed *physical*
    columns (repro/data/encodings.py) while presenting the plain *logical*
    ``spec`` — streamed bytes shrink with the data, results do not change
    (the decode is exact, so finals are bitwise-equal to the plain source;
    DESIGN.md §12).

    Two constructions: :meth:`from_shards` encodes a resident [P, C, L]
    dict on the host (in-memory physical arrays), or :meth:`save` +
    ``EncodedSource(directory)`` for the mmap-backed on-disk layout
    (``<dir>/<column>.npy`` physical arrays + ``encodings.json``).

    ``slice_cols`` returns encoded columns *physical* — the incremental
    session threads ``self.encodings`` into the step program, where the
    fused kernel (or the generic ``decode_cols`` fallback) decodes them
    in-register.  The fingerprint decodes before sampling, so it equals
    the plain dataset's fingerprint: a session paused over plain data
    resumes over an encoded copy of it and vice versa.  Always
    ``resident=False``: encoded data runs the incremental discipline.
    """

    def __init__(self, directory):
        import json

        from repro.data import encodings as _enc

        self.directory = Path(directory)
        meta = json.loads((self.directory / "encodings.json").read_text())
        encs = {}
        for name, d in meta.items():
            if d["kind"] == "dict":
                encs[name] = _enc.DictEncoding(
                    values=tuple(d["values"]), code_dtype=d["code_dtype"],
                    logical_dtype=d["logical_dtype"])
            else:
                encs[name] = _enc.BitPackedEncoding(
                    bits=int(d["bits"]), logical_dtype=d["logical_dtype"])
        phys = {p.stem: np.load(p, mmap_mode="r")
                for p in sorted(self.directory.glob("*.npy"))}
        self._init_from(phys, _enc.normalize_encodings(encs))

    def _init_from(self, phys, encodings):
        if "_mask" not in phys:
            raise ValueError("EncodedSource needs a plain '_mask' column")
        self._phys = phys
        self.encodings = encodings
        enc = dict(encodings)
        if "_mask" in enc:
            raise ValueError("'_mask' must never be encoded")
        P, C, L = phys["_mask"].shape[:3]
        cols = []
        for name in sorted(phys):
            e = enc.get(name)
            v = phys[name]
            if e is None:
                cols.append(ColumnSpec(name, np.dtype(v.dtype).name,
                                       tuple(v.shape[3:])))
            else:
                if v.shape[2] * e.lanes != L:
                    raise ValueError(
                        f"column {name!r}: physical chunk length "
                        f"{v.shape[2]} x {e.lanes} lanes != L={L}")
                cols.append(ColumnSpec(name, e.logical_dtype,
                                       tuple(v.shape[3:])))
        self.spec = ChunkSpec(int(P), int(C), int(L), tuple(cols))

    @classmethod
    def from_shards(cls, shards: Dict[str, "np.ndarray"], encodings):
        """Encode a resident [P, C, L] shards dict on the host."""
        from repro.data import encodings as _enc

        encodings = _enc.normalize_encodings(encodings)
        enc = dict(encodings)
        phys = {}
        for name, v in shards.items():
            a = np.asarray(v)
            e = enc.get(name)
            phys[name] = a if e is None else _enc.encode_array(a, e)
        self = cls.__new__(cls)
        self.directory = None
        self._init_from(phys, encodings)
        return self

    @staticmethod
    def save(shards: Dict[str, "np.ndarray"], directory, encodings) -> Path:
        """Write the physical column layout + ``encodings.json``."""
        import json

        from repro.data import encodings as _enc

        encodings = _enc.normalize_encodings(encodings)
        enc = dict(encodings)
        directory = Path(directory)
        directory.mkdir(parents=True, exist_ok=True)
        meta = {}
        for name, v in shards.items():
            a = np.asarray(v)
            e = enc.get(name)
            np.save(directory / f"{name}.npy",
                    a if e is None else _enc.encode_array(a, e))
            if isinstance(e, _enc.DictEncoding):
                meta[name] = {"kind": "dict", "values": list(e.values),
                              "code_dtype": e.code_dtype,
                              "logical_dtype": e.logical_dtype}
            elif e is not None:
                meta[name] = {"kind": "bitpack", "bits": e.bits,
                              "logical_dtype": e.logical_dtype}
        (directory / "encodings.json").write_text(json.dumps(meta, indent=1))
        return directory

    def slice_cols(self, lo: int, hi: int):
        # physical bytes only: encoded columns ship as codes/words and are
        # decoded on device (in the fused kernel when published)
        return {k: np.ascontiguousarray(v[:, lo:hi])
                for k, v in self._phys.items()}

    def mask_chunk_sums(self) -> np.ndarray:
        # mask is stored plain; sum it alone (same streaming discipline as
        # NpyMmapSource — never materialize every column for _mask)
        if getattr(self, "_mask_sums", None) is None:
            mask = self._phys["_mask"]
            C = self.spec.C
            out = np.zeros((self.spec.P, C), np.float64)
            step = max(1, _SAMPLE_CHUNKS * 64)
            for lo in range(0, C, step):
                hi = min(C, lo + step)
                out[:, lo:hi] = mask[:, lo:hi].sum(axis=2, dtype=np.float64)
            self._mask_sums = out
        return self._mask_sums


class ParquetSource(ChunkSource):
    """Columnar parquet partitions: ``<dir>/part-*.parquet``, one file of
    *live* rows per partition (no mask column — liveness is derived from
    row counts, exactly like ``randomize.pack_partitions``).

    Reads go through pyarrow's columnar batches: a slice [lo, hi) maps to
    the row range [lo·L, hi·L) of each partition, satisfied by reading the
    covering row groups with column projection — never the whole file.
    ``read_row_groups`` has a fixed per-call cost, so sequential scans
    read **ahead**: each physical read covers up to ``readahead`` row
    groups and later slices are served from the cached block until they
    run past it.  One block is cached per partition, so the extension
    past the covering groups is additionally clamped to
    ``readahead_bytes / P`` per partition — total host cache stays under
    ``readahead_bytes`` (plus one covering read) no matter how large the
    writer's row groups are, never O(dataset).  Requires the optional
    ``pyarrow`` dependency.
    """

    def __init__(self, directory, *, chunk_len: int,
                 min_chunks: Optional[int] = None,
                 columns: Optional[List[str]] = None,
                 readahead: int = 8, readahead_bytes: int = 64 << 20):
        try:
            import pyarrow.parquet as pq
        except ImportError as e:  # optional dependency
            raise ImportError(
                "ParquetSource needs the optional 'pyarrow' package "
                "(pip install pyarrow)") from e
        self.directory = Path(directory)
        paths = sorted(self.directory.glob("part-*.parquet"))
        if not paths:
            raise FileNotFoundError(
                f"no part-*.parquet files under {self.directory}")
        self._pq = pq
        self._files = [pq.ParquetFile(p, memory_map=True) for p in paths]
        self._rows = [f.metadata.num_rows for f in self._files]
        self._readahead = max(1, int(readahead))
        self._readahead_bytes = int(readahead_bytes)
        self._block: List[Optional[tuple]] = [None] * len(self._files)
        L = int(chunk_len)
        C = max(-(-n // L) for n in self._rows)
        if min_chunks is not None:
            C = max(C, int(min_chunks))
        self.chunk_len = L
        schema = self._files[0].schema_arrow
        names = columns if columns is not None else list(schema.names)
        self._names = sorted(names)
        dtypes = {name: np.dtype(schema.field(name).type.to_pandas_dtype())
                  for name in self._names}
        cols = tuple(ColumnSpec(n, dtypes[n].name) for n in self._names)
        cols += (ColumnSpec("_mask", np.dtype(np.float32).name),)
        self.spec = ChunkSpec(len(self._files), C, L,
                              tuple(sorted(cols)))
        # row-group boundaries per file, for covering-group reads
        self._rg_starts = []
        for f in self._files:
            starts = np.zeros(f.metadata.num_row_groups + 1, np.int64)
            for g in range(f.metadata.num_row_groups):
                starts[g + 1] = starts[g] + f.metadata.row_group(g).num_rows
            self._rg_starts.append(starts)

    @staticmethod
    def save(parts: List[Dict[str, "np.ndarray"]], directory, *,
             row_group_len: int = 1 << 16) -> Path:
        """Write ragged partition dicts (randomize.* output) as
        ``part-*.parquet`` files of live rows.  ``_mask`` columns are
        dropped — parquet stores live rows only."""
        import pyarrow as pa
        import pyarrow.parquet as pq

        directory = Path(directory)
        directory.mkdir(parents=True, exist_ok=True)
        for i, p in enumerate(parts):
            table = pa.table({k: np.asarray(v) for k, v in p.items()
                              if k != "_mask"})
            pq.write_table(table, directory / f"part-{i:05d}.parquet",
                           row_group_size=row_group_len)
        return directory

    def _covering_block(self, part: int, row_lo: int, row_hi: int):
        """Cached (block_lo, block_hi, column ndarrays) covering
        [row_lo, row_hi), reading ``readahead`` row groups past the
        requested range so a sequential scan pays the fixed
        read_row_groups + arrow->numpy cost once per block, not once per
        slice."""
        blk = self._block[part]
        if blk is not None and blk[0] <= row_lo and row_hi <= blk[1]:
            return blk
        f, starts = self._files[part], self._rg_starts[part]
        g_lo = int(np.searchsorted(starts, row_lo, side="right")) - 1
        g_hi = int(np.searchsorted(starts, row_hi, side="left"))
        # extend past the covering groups for read-ahead, clamped both by
        # group count and by the per-partition share of the byte budget —
        # P cached blocks must never sum past readahead_bytes even when
        # the writer used huge row groups
        row_bytes = max(1, sum(np.dtype(c.dtype).itemsize
                               for c in self.spec.columns
                               if c.name in self._names))
        budget_rows = self._readahead_bytes // (len(self._files) * row_bytes)
        while (g_hi < f.metadata.num_row_groups
               and g_hi - g_lo < self._readahead
               and int(starts[g_hi + 1] - starts[g_lo]) <= budget_rows):
            g_hi += 1
        table = f.read_row_groups(list(range(g_lo, g_hi)),
                                  columns=self._names)
        arrs = {n: table.column(n).to_numpy(zero_copy_only=False)
                for n in self._names}
        blk = (int(starts[g_lo]), int(starts[g_hi]), arrs)
        self._block[part] = blk
        return blk

    def _read_rows(self, part: int, row_lo: int, row_hi: int):
        """Live rows [row_lo, row_hi) of one partition as a columnar dict,
        via the covering row groups (columnar-batch read, projected)."""
        row_hi = min(row_hi, self._rows[part])
        if row_lo >= row_hi:
            return {}, 0
        blk_lo, _, arrs = self._covering_block(part, row_lo, row_hi)
        out = {n: v[row_lo - blk_lo:row_hi - blk_lo]
               for n, v in arrs.items()}
        return out, row_hi - row_lo

    def slice_cols(self, lo: int, hi: int):
        P, L = self.spec.P, self.chunk_len
        width = hi - lo
        dtypes = {c.name: np.dtype(c.dtype) for c in self.spec.columns}
        bufs = {n: np.zeros((P, width * L), dtypes[n]) for n in self._names}
        mask = np.zeros((P, width * L), np.float32)
        for p in range(P):
            rows, n = self._read_rows(p, lo * L, hi * L)
            for name, v in rows.items():
                bufs[name][p, :n] = v
            mask[p, :n] = 1.0
        out = {n: b.reshape(P, width, L) for n, b in bufs.items()}
        out["_mask"] = mask.reshape(P, width, L)
        return out

    def mask_chunk_sums(self) -> np.ndarray:
        # Liveness is a pure function of row counts — no I/O needed.
        if getattr(self, "_mask_sums", None) is None:
            P, C, L = self.spec.P, self.spec.C, self.spec.L
            c = np.arange(C, dtype=np.int64)
            n = np.asarray(self._rows, np.int64)[:, None]
            self._mask_sums = np.clip(n - c[None, :] * L, 0, L).astype(
                np.float64)
        return self._mask_sums


class RepartitionedSource(ChunkSource):
    """A P'-way view of a P-way source — elastic resume (DESIGN.md §9).

    Merging (P' < P, P % P' == 0, k = P / P'): new partition i
    round-robin-interleaves the chunk streams of old partitions
    [i·k, (i+1)·k) — new chunk j is old (partition i·k + j mod k, chunk
    j // k) — so C' = k·C.  Splitting (P' > P, P' % P == 0, k = P' / P,
    k | C): new partition p·k + j de-interleaves old partition p's
    stream, taking old chunks j, j+k, j+2k, …, so C' = C / k.

    The round-robin convention is what makes checkpoints elastic: when
    every old partition has scanned the same chunk prefix [0, cur) — which
    the uniform schedules incremental sessions require — the scanned set
    maps to the *prefix* [0, cur·k) (merge) or [0, cur/k) (split, k | cur)
    of every new stream, so a resumed scan continues exactly where the
    paused one stopped, with slice bounds re-derived for the new
    partitioning.  Merge and split with the same factor are mutual
    inverses, so repartitioning back recovers the original layout
    bit-for-bit (tests/test_elastic.py).

    Slices are gathered on the host, so the view is a streaming source
    (``resident`` False) even over a resident inner — elastic resume runs
    the incremental discipline by definition.
    """

    def __init__(self, inner: ChunkSource, partitions: int):
        if not isinstance(inner, ChunkSource):
            raise TypeError("RepartitionedSource wraps a ChunkSource; use "
                            "repartition() for plain shards dicts")
        P, C, L = inner.spec.P, inner.spec.C, inner.spec.L
        P_new = int(partitions)
        if P_new <= 0:
            raise ValueError(f"partitions must be positive, got {partitions}")
        if P_new <= P:
            if P % P_new:
                raise ValueError(
                    f"cannot repartition {P} -> {P_new}: the new partition "
                    "count must divide the old one (merge) or be a multiple "
                    "of it (split)")
            k = P // P_new
            C_new = C * k
        else:
            if P_new % P:
                raise ValueError(
                    f"cannot repartition {P} -> {P_new}: the new partition "
                    "count must divide the old one (merge) or be a multiple "
                    "of it (split)")
            k = P_new // P
            if C % k:
                raise ValueError(
                    f"cannot split {P} -> {P_new}: the factor {k} must "
                    f"divide the per-partition chunk count C={C}")
            C_new = C // k
        self.inner = inner
        self._factor = k
        self._is_merge = P_new <= P
        self.spec = ChunkSpec(P_new, C_new, L, inner.spec.columns)
        # physical layout is a property of the data, not the partitioning:
        # the view serves the inner source's encoded bytes unchanged
        self.encodings = inner.encodings

    def _index_maps(self, lo: int, hi: int):
        """Old (partition, chunk-within-block) index grids for new chunks
        [lo, hi) of every new partition, plus the covering old range."""
        k = self._factor
        j = np.arange(lo, hi)
        i = np.arange(self.spec.P)
        if self._is_merge:
            olo, ohi = lo // k, (hi - 1) // k + 1
            rows = i[:, None] * k + (j % k)[None, :]
            cols = np.broadcast_to((j // k)[None, :] - olo, rows.shape)
        else:
            olo, ohi = lo * k, hi * k
            rows = np.broadcast_to((i // k)[:, None], (i.size, j.size))
            cols = (j[None, :] - lo) * k + (i % k)[:, None]
        return rows, cols, olo, ohi

    def slice_cols(self, lo: int, hi: int):
        rows, cols, olo, ohi = self._index_maps(lo, hi)
        block = self.inner.slice_cols(olo, ohi)
        return {name: np.asarray(v)[rows, cols] for name, v in block.items()}

    def mask_chunk_sums(self) -> np.ndarray:
        # pure index remap of the inner counts — no data read
        if getattr(self, "_mask_sums", None) is None:
            rows, cols, _, _ = self._index_maps(0, self.spec.C)
            self._mask_sums = self.inner.mask_chunk_sums()[rows, cols]
        return self._mask_sums


def as_source(data) -> ChunkSource:
    """Normalize the engine's data argument: a ChunkSource passes through,
    a plain [P, C, L] shards dict wraps into an :class:`InMemorySource`."""
    if isinstance(data, ChunkSource):
        return data
    if isinstance(data, dict):
        return InMemorySource(data)
    raise TypeError(
        f"expected a ChunkSource or a [P, C, L] shards dict, got "
        f"{type(data).__name__}")


def repartition(data, partitions: int) -> ChunkSource:
    """P'-way :class:`RepartitionedSource` view of ``data`` — pass-through
    when the partition count already matches."""
    src = as_source(data)
    if int(partitions) == src.spec.P:
        return src
    return RepartitionedSource(src, int(partitions))
