"""Compressed column encodings decoded *inside* the scan kernel.

Streamed q6-class scans are bandwidth-bound (BENCH_streaming): the bytes
that cross the host→device boundary per round-slice are the cost.  These
encodings shrink those bytes while keeping the *decoded* values exactly
equal to the plain column, so every aggregate stays bitwise-identical to
the plain-source run (DESIGN.md §12):

``DictEncoding``
    Low-cardinality columns (TPC-H ``discount``: 11 values, ``quantity``:
    50, ``tax``: 9) stored as small-int codes into a per-column value
    table.  Decode is a gather — ``values[code]`` — which reproduces the
    original float bit pattern exactly (the table holds the original
    values; no arithmetic is performed).  f32 → int8 is a 4x byte cut.

``BitPackedEncoding``
    Bounded non-negative ints (``shipdate`` < 2526 fits 12 bits, ``rfls``
    < 4 fits 2) packed little-endian into int32 words along the chunk
    axis.  Decode is shift-and-mask — exact integer ops, bit-for-bit.
    L must be divisible by the per-word lane count (32 // bits); chunk
    lengths here are powers of two, so any bits in {1,2,4,8,16} divides.

Both decoders are pure ``jnp`` expressions on the trailing axis, so the
same helper runs in three contexts with identical results: inside the
fused Pallas kernel body (``repro.kernels.fused_agg``), in the generic
scan/legacy-kernel fallback (``decode_cols``), and under ``eval_shape``
for checkpoint payload templates.  Encodings are hashable NamedTuples —
they ride through jit static args unchanged.

Encode (host, NumPy) lives here too so ``source.EncodedSource`` and the
benchmarks share one implementation.  ``encode_array`` → physical array,
``decode_block`` → logical array; round-trip is asserted exact in
tests/test_encodings.py (hypothesis, both encodings).
"""
from __future__ import annotations

from typing import NamedTuple, Tuple

import jax.numpy as jnp
import numpy as np


class DictEncoding(NamedTuple):
    """Dictionary code column: physical small-int codes, logical = values[code].

    ``values`` is the sorted tuple of distinct logical values (Python
    floats/ints — hashable, so the encoding is a valid jit static).
    ``code_dtype`` is the physical dtype name; ``logical_dtype`` the
    decoded dtype name (must match the plain column's dtype).
    """

    values: Tuple[float, ...]
    code_dtype: str = "int8"
    logical_dtype: str = "float32"

    @property
    def lanes(self) -> int:
        return 1  # one code per logical element

    def physical_dtype(self) -> str:
        return self.code_dtype

    def table(self):
        return jnp.asarray(np.asarray(self.values, dtype=self.logical_dtype))


class BitPackedEncoding(NamedTuple):
    """``bits``-wide non-negative ints packed into int32 words (little-endian
    within the word) along the trailing axis.  lanes = 32 // bits values per
    word; the logical trailing length L must be a multiple of lanes.
    """

    bits: int
    logical_dtype: str = "int32"

    @property
    def lanes(self) -> int:
        return 32 // self.bits

    def physical_dtype(self) -> str:
        return "int32"


Encoding = DictEncoding | BitPackedEncoding


# ---------------------------------------------------------------------------
# host-side encode (NumPy)
# ---------------------------------------------------------------------------

def dict_encoding_for(arr) -> DictEncoding:
    """Build a DictEncoding from the distinct values of ``arr`` (host)."""
    a = np.asarray(arr)
    values = np.unique(a)
    if values.size > np.iinfo(np.int16).max:
        raise ValueError(f"dictionary too large: {values.size} distinct values")
    code_dtype = "int8" if values.size <= np.iinfo(np.int8).max + 1 else "int16"
    return DictEncoding(values=tuple(values.tolist()), code_dtype=code_dtype,
                        logical_dtype=a.dtype.name)


def encode_array(arr, enc: Encoding):
    """Host encode: logical array -> physical array (last axis packed for
    bit-packing).  Raises if the data does not fit the encoding exactly."""
    a = np.asarray(arr)
    if isinstance(enc, DictEncoding):
        table = np.asarray(enc.values, dtype=enc.logical_dtype)
        codes = np.searchsorted(table, a)
        codes = np.clip(codes, 0, table.size - 1)
        if not np.array_equal(table[codes], a):
            raise ValueError("dict encoding: values outside the dictionary")
        return codes.astype(enc.code_dtype)
    bits, lanes = enc.bits, enc.lanes
    if a.dtype.kind not in "iu":
        raise ValueError(f"bit-packing needs an integer column, got {a.dtype}")
    if a.min() < 0 or a.max() >= (1 << bits):
        raise ValueError(f"bit-packing {bits} bits: values outside [0, 2^{bits})")
    if a.shape[-1] % lanes:
        raise ValueError(
            f"bit-packing {bits} bits: trailing length {a.shape[-1]} not a "
            f"multiple of {lanes} lanes")
    words = a.astype(np.int64).reshape(*a.shape[:-1], a.shape[-1] // lanes, lanes)
    shifts = (bits * np.arange(lanes)).astype(np.int64)
    return (words << shifts).sum(axis=-1).astype(np.int32)


# ---------------------------------------------------------------------------
# device-side decode (pure jnp: valid in jit, eval_shape, and Pallas bodies)
# ---------------------------------------------------------------------------

def decode_block(x, enc: Encoding | None):
    """Decode one physical block back to logical values on the trailing axis.

    Exactness contract: for DictEncoding the gather returns the original
    bit patterns; for BitPackedEncoding shift-and-mask recovers the exact
    ints.  Works on any leading shape; pure jnp so it traces identically
    inside Pallas kernel bodies and plain jitted programs.
    """
    if enc is None:
        return x
    if isinstance(enc, DictEncoding):
        return jnp.take(enc.table(), x.astype(jnp.int32), axis=0)
    bits, lanes = enc.bits, enc.lanes
    shifts = bits * jnp.arange(lanes, dtype=jnp.int32)
    vals = (x[..., None] >> shifts) & ((1 << bits) - 1)
    return vals.reshape(*x.shape[:-1], x.shape[-1] * lanes).astype(
        enc.logical_dtype)


def decode_cols(cols: dict, encodings) -> dict:
    """Decode every encoded column of a slice dict; plain columns pass
    through untouched.  ``encodings`` is a tuple of (name, Encoding)."""
    enc_map = dict(encodings)
    return {k: decode_block(v, enc_map.get(k)) for k, v in cols.items()}


def normalize_encodings(encodings) -> tuple:
    """Canonical hashable form: name-sorted tuple of (name, Encoding)."""
    return tuple(sorted(dict(encodings).items()))
