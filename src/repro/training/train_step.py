"""Training step: loss, grads (microbatched accumulation), optimizer update.

``make_train_step(cfg)`` returns a pure (params, opt_state, batch) ->
(params, opt_state, metrics) function suitable for jit with GSPMD shardings
(repro/launch/dryrun.py wires in_shardings/out_shardings).

Gradient accumulation over ``cfg.train_microbatches`` uses `lax.scan` so the
per-microbatch activation footprint is 1/M of the step's; grads accumulate
in f32.  Metrics include the ingredients the PF-OLA bridge consumes: per-step
loss sum/sumsq/count over microbatches feed the confidence-bounded
grad-accumulation estimator (repro/training/grad_estimator.py).
"""
from __future__ import annotations

from typing import Dict

import jax
import jax.numpy as jnp
from jax import lax

from repro.configs.base import ArchConfig
from repro.models import transformer as T
from repro.training import optimizer as O

AUX_LOSS_WEIGHT = 0.01


@jax.custom_vjp
def _grad_dtype_boundary(x):
    """Identity forward; casts the cotangent back to x's dtype.

    The cross-entropy tail runs in f32, so without this boundary the
    *entire* backward residual stream — including every TP activation
    all-reduce — is carried in f32.  Pinning cotangents to the activation
    dtype (bf16) halves backward activation traffic and collective bytes
    (EXPERIMENTS.md §Perf iteration q2); this matches Megatron's bf16
    gradient-communication convention.
    """
    return x


def _gdb_fwd(x):
    # residual: a zero-size array carrying the primal dtype (dtypes are not
    # JAX types, so smuggle it via an empty array)
    return x, jnp.zeros((0,), x.dtype)


def _gdb_bwd(res, g):
    return (g.astype(res.dtype),)


_grad_dtype_boundary.defvjp(_gdb_fwd, _gdb_bwd)


def shift_targets(cfg: ArchConfig, batch: Dict[str, jnp.ndarray], seq_total: int):
    """(targets, mask) aligned with the model's hidden-state positions.

    Hidden position j predicts the token at input position j+1.  For VLM
    inputs the first `vis_tokens` positions are patch embeddings; only text
    transitions are scored.
    """
    tokens = batch["tokens"]
    B, S_txt = tokens.shape
    P = seq_total - S_txt
    targets = jnp.zeros((B, seq_total), jnp.int32)
    targets = lax.dynamic_update_slice(
        targets, tokens[:, 1:], (0, P))                       # h_{P+i} -> tok_{i+1}
    mask = jnp.zeros((B, seq_total), jnp.float32)
    mask = lax.dynamic_update_slice(
        mask, jnp.ones((B, S_txt - 1), jnp.float32), (0, P))
    return targets, mask


def loss_fn(params, cfg: ArchConfig, batch):
    x, aux, _ = T.forward(params, cfg, batch)
    x = _grad_dtype_boundary(x)
    targets, mask = shift_targets(cfg, batch, x.shape[1])
    ce = T.xent_loss(params, cfg, x, targets, mask)
    return ce + AUX_LOSS_WEIGHT * aux, ce


def _split_micro(batch, m, batch_axes=None):
    """[B, ...] -> [M, B/M, ...]; re-pin the batch shard onto dim 1.

    Without the constraint GSPMD is free to shard the microbatch axis (M)
    instead of the batch axis — measured on qwen3 train_4k this replicated
    per-device batches 8× and inserted score-sized all-reduces in the
    attention backward (EXPERIMENTS.md §Perf iteration q1).
    """
    from jax.sharding import PartitionSpec as P

    def split(x):
        x = x.reshape((m, x.shape[0] // m, *x.shape[1:]))
        if batch_axes:
            spec = P(None, batch_axes, *([None] * (x.ndim - 2)))
            x = jax.lax.with_sharding_constraint(x, spec)
        return x

    return jax.tree.map(split, batch)


def make_train_step(cfg: ArchConfig, *, lr: float = 1e-4, clip: float = 1.0,
                    dp_size: int = 1, batch_axes=None):
    """Build the jittable train step for an architecture.

    ``dp_size``: data-parallel shard count of the global batch — microbatch
    count is capped so each microbatch still shards evenly over it.
    ``batch_axes``: mesh axes carrying the batch dim; when given, microbatch
    xs are sharding-constrained so the scan cannot reshard them.
    """

    grad_fn = jax.value_and_grad(loss_fn, has_aux=True)

    def train_step(params, opt_state, batch):
        B = batch["tokens"].shape[0]
        M = cfg.train_microbatches
        while M > 1 and (B % M or (B // M) % dp_size):
            M -= 1

        if M == 1:
            (_, ce), grads = grad_fn(params, cfg, batch)
            ce_sum, ce_sumsq, nmb = ce, ce * ce, jnp.ones((), jnp.float32)
        else:
            micro = _split_micro(batch, M, batch_axes)
            g0 = jax.tree.map(
                lambda p: jnp.zeros(p.shape, jnp.float32), params)
            z = jnp.zeros((), jnp.float32)

            def acc(carry, mb):
                g, s, sq = carry
                (_, ce), gi = grad_fn(params, cfg, mb)
                g = jax.tree.map(
                    lambda a, b: a + b.astype(jnp.float32) / M, g, gi)
                return (g, s + ce, sq + ce * ce), None

            (grads, ce_sum, ce_sumsq), _ = lax.scan(acc, (g0, z, z), micro)
            ce = ce_sum / M
            nmb = jnp.asarray(M, jnp.float32)

        gnorm = jnp.sqrt(sum(
            jnp.sum(jnp.square(g.astype(jnp.float32)))
            for g in jax.tree.leaves(grads)))
        if clip is not None:
            scale = jnp.minimum(1.0, clip / (gnorm + 1e-9))
            grads = jax.tree.map(
                lambda g: (g.astype(jnp.float32) * scale).astype(g.dtype),
                grads)
        new_params, new_opt = O.opt_update(
            grads, opt_state, params, cfg.optimizer, lr=lr)
        metrics = {
            "loss": ce if M == 1 else ce_sum / M,
            "loss_sum": ce_sum,
            "loss_sumsq": ce_sumsq,
            "num_micro": nmb,
            "grad_norm": gnorm,
        }
        return new_params, new_opt, metrics

    return train_step


def init_train_state(cfg: ArchConfig, key, dtype=jnp.bfloat16):
    """Materialized params + optimizer state (smoke scale only)."""
    from repro.models import spec as S
    params = S.init_params(T.param_specs(cfg, dtype=dtype), key)
    opt = O.opt_init(params, cfg.optimizer)
    return params, opt
