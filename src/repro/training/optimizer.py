"""Optimizers in pure JAX: AdamW (fp32 master + moments) and Adafactor
(factored second moment, no first moment, no master) for the ≥300B MoE archs
where AdamW state cannot fit 256×16 GB (DESIGN.md §5, accounting in
EXPERIMENTS.md §Dry-run).

State sharding: every state leaf mirrors its parameter's model-axis sharding
and additionally takes the `data` axis on its largest free divisible dim
(ZeRO; see repro/dist/sharding.py).  Under jit+GSPMD the gradient reshard
lowers to reduce-scatter and the updated-param fetch to all-gather.
"""
from __future__ import annotations

from typing import Any, NamedTuple

import jax
import jax.numpy as jnp


class AdamWState(NamedTuple):
    step: jnp.ndarray
    master: Any   # fp32 params
    mu: Any       # fp32 first moment
    nu: Any       # fp32 second moment


class AdafactorState(NamedTuple):
    step: jnp.ndarray
    vr: Any       # row stats (mean over last dim), fp32
    vc: Any       # col stats (mean over second-to-last dim), fp32


def adamw_init(params) -> AdamWState:
    def f32(t):
        return jax.tree.map(lambda x: x.astype(jnp.float32), t)

    def zeros(t):
        return jax.tree.map(lambda x: jnp.zeros(x.shape, jnp.float32), t)
    return AdamWState(jnp.zeros((), jnp.int32), f32(params), zeros(params),
                      zeros(params))


def adamw_update(grads, state: AdamWState, params, *, lr=1e-4, b1=0.9,
                 b2=0.95, eps=1e-8, wd=0.1):
    step = state.step + 1
    t = step.astype(jnp.float32)

    mu = jax.tree.map(lambda g, m: b1 * m + (1 - b1) * g.astype(jnp.float32),
                      grads, state.mu)
    nu = jax.tree.map(
        lambda g, v: b2 * v + (1 - b2) * jnp.square(g.astype(jnp.float32)),
        grads, state.nu)

    def new_master(m, v, ma):
        mhat = m / (1 - b1 ** t)
        vhat = v / (1 - b2 ** t)
        return ma - lr * (mhat / (jnp.sqrt(vhat) + eps) + wd * ma)

    master = jax.tree.map(new_master, mu, nu, state.master)
    new_params = jax.tree.map(lambda ma, p: ma.astype(p.dtype), master, params)
    return new_params, AdamWState(step, master, mu, nu)


def _factored_dims(shape):
    return len(shape) >= 2 and shape[-1] > 1 and shape[-2] > 1


def adafactor_init(params) -> AdafactorState:
    def vr(x):
        return (jnp.zeros(x.shape[:-1], jnp.float32) if _factored_dims(x.shape)
                else jnp.zeros(x.shape, jnp.float32))

    def vc(x):
        return (jnp.zeros(x.shape[:-2] + x.shape[-1:], jnp.float32)
                if _factored_dims(x.shape) else jnp.zeros((1,), jnp.float32))

    return AdafactorState(jnp.zeros((), jnp.int32),
                          jax.tree.map(vr, params), jax.tree.map(vc, params))


def adafactor_update(grads, state: AdafactorState, params, *, lr=1e-4,
                     decay=0.8, eps=1e-30, clip=1.0, wd=0.0):
    step = state.step + 1
    t = step.astype(jnp.float32)
    beta = 1.0 - t ** (-decay)

    def upd(g, p, vr, vc):
        g = g.astype(jnp.float32)
        g2 = g * g + eps
        if _factored_dims(g.shape):
            vr_n = beta * vr + (1 - beta) * jnp.mean(g2, axis=-1)
            vc_n = beta * vc + (1 - beta) * jnp.mean(g2, axis=-2)
            denom = jnp.mean(vr_n, axis=-1, keepdims=True)
            r = (vr_n / jnp.maximum(denom, eps))[..., None]
            u = g * jax.lax.rsqrt(jnp.maximum(r * vc_n[..., None, :], eps))
        else:
            vr_n = beta * vr + (1 - beta) * g2
            vc_n = vc
            u = g * jax.lax.rsqrt(jnp.maximum(vr_n, eps))
        # update clipping (RMS(u) <= clip)
        rms = jnp.sqrt(jnp.mean(u * u) + 1e-12)
        u = u / jnp.maximum(1.0, rms / clip)
        new = p.astype(jnp.float32) - lr * (u + wd * p.astype(jnp.float32))
        return new.astype(p.dtype), vr_n, vc_n

    out = jax.tree.map(upd, grads, params, state.vr, state.vc)
    new_params = jax.tree.map(lambda o: o[0], out,
                              is_leaf=lambda x: isinstance(x, tuple))
    vr = jax.tree.map(lambda o: o[1], out, is_leaf=lambda x: isinstance(x, tuple))
    vc = jax.tree.map(lambda o: o[2], out, is_leaf=lambda x: isinstance(x, tuple))
    return new_params, AdafactorState(step, vr, vc)


def opt_init(params, kind: str):
    return adamw_init(params) if kind == "adamw" else adafactor_init(params)


def opt_update(grads, state, params, kind: str, **kw):
    if kind == "adamw":
        return adamw_update(grads, state, params, **kw)
    return adafactor_update(grads, state, params, **kw)
