"""Confidence-bounded gradient accumulation — PF-OLA machinery applied to
the microbatch loop (beyond-paper feature, DESIGN.md §2).

A gradient over a global batch is an associative-decomposable aggregate of
per-microbatch contributions — a GLA.  Treating the microbatch stream as
the scan and the per-microbatch *loss* (or a random projection of the
gradient) as ``func(d)``, the paper's sampling estimator gives an anytime
confidence interval on the full-batch statistic.  When the relative CI
width drops below a target, the remaining microbatches carry little
information: the step can fire early (adaptive effective batch size).

Statistically this is the paper Eq. (2)/(4) estimator with D = the step's
microbatch population and S = those processed so far; microbatch order is
random because the data pipeline shuffles (global randomization §4.2).

``accumulate_until_confident`` is a host-side driver (each microbatch grad
is one jitted call) used by examples/adaptive_batch.py; the fully-jitted
variant embeds the width test in a `lax.while_loop`.
"""
from __future__ import annotations

from typing import Callable

import jax
import jax.numpy as jnp

from repro.core import estimators as E


def ci_relative_width(sum_, sumsq, n, n_total, confidence=0.95):
    """Relative CI width of the mean estimate after n of n_total microbatches."""
    est = E.horvitz_estimate(sum_, jnp.asarray(n, jnp.float32),
                             jnp.asarray(n_total, jnp.float32))
    var = E.variance_estimate(sum_, sumsq, jnp.asarray(n, jnp.float32),
                              jnp.asarray(n_total, jnp.float32))
    lo, hi = E.normal_bounds(est, var, confidence)
    return (hi - lo) / jnp.maximum(jnp.abs(est), 1e-9)


def accumulate_until_confident(
    grad_fn: Callable,            # (params, microbatch) -> (loss, grads)
    params,
    microbatches,                 # pytree with leading axis M
    *,
    target_rel_width: float = 0.05,
    min_micro: int = 2,
    confidence: float = 0.95,
):
    """Accumulate microbatch grads until the loss-mean CI is tight.

    Returns (grads_mean, n_used, history) — grads averaged over the n_used
    microbatches actually consumed.  The estimator state is the paper's
    (sum, sumSq, count); n_total = M (sampling without replacement from the
    step's population).
    """
    M = jax.tree.leaves(microbatches)[0].shape[0]
    g_acc = None
    s = sq = 0.0
    history = []
    n_used = M
    for i in range(M):
        mb = jax.tree.map(lambda x, i=i: x[i], microbatches)
        loss, g = grad_fn(params, mb)
        loss = float(loss)
        g_acc = g if g_acc is None else jax.tree.map(jnp.add, g_acc, g)
        s += loss
        sq += loss * loss
        if i + 1 >= min_micro:
            w = float(ci_relative_width(
                jnp.asarray(s), jnp.asarray(sq), i + 1, M, confidence))
        else:
            w = float("inf")
        history.append({"n": i + 1, "loss": loss, "rel_width": w})
        if w <= target_rel_width:
            n_used = i + 1
            break
    grads = jax.tree.map(lambda g: g / n_used, g_acc)
    return grads, n_used, history
