"""Compiled-program invariant auditor — the catalog behind DESIGN.md §10.

PF-OLA's "virtually no overhead" claim (paper §5) is not a wall-time
accident: it rests on structural invariants of the *compiled* program —
one pass over the chunk stream, an O(slice) device footprint per
incremental step, one kernel dispatch per round-slice, one merge
collective per round, no recompilation as the session advances.  Until
now those invariants were spot-asserted by private HLO greps buried in
three benchmarks; this module names them, makes each one a reusable
check over optimized HLO text (built on ``repro.analysis.hlo_cost``),
and certifies any plan pre-execution:

    from repro.core import engine
    report = engine.audit_plan(q, shards, rounds=8, emit="chunk")
    report.raise_for_failures()

or at session construction::

    Session(q, shards, rounds=8, audit=True)   # raises AuditError on fail

The catalog (check names accepted by ``checks=``):

  ``one_chunk_pass``            exactly one while loop over the chunk
                                stream, regardless of how many queries or
                                estimators ride the scan (from
                                benchmarks/multiquery.py).
  ``o_slice_footprint``         the incremental step program's ENTRY
                                parameters are one round-slice plus the
                                small carry/weights — never the dataset
                                (from benchmarks/streaming.py).
  ``single_kernel_dispatch``    kernel plans issue exactly one
                                ``ops.group_agg``/partials dispatch per
                                (partition, round-slice) (from
                                benchmarks/groupby.py; CPU interpret mode
                                shows dispatches as Pallas grid loops).
  ``fused_single_dispatch``     fused-kernel plans (DESIGN.md §12) issue
                                exactly ONE ``pl.pallas_call`` per
                                (partition, round-slice): predicate,
                                bucketing, in-kernel column decode and
                                accumulation all ride a single dispatch.
                                Counted structurally with
                                ``kernels.fused_agg.count_dispatches``
                                under ``jax.eval_shape``, so it holds on
                                any backend, not just interpret mode.
  ``bytes_moved``               encoded sources (``data/encodings.py``)
                                must stream measurably fewer physical
                                bytes per round-slice than the logical
                                columns they decode to — the
                                decode-in-kernel bandwidth win is
                                asserted, not assumed.
  ``one_collective_per_round``  a sharded session step lowers its single
                                ``lax.psum`` to at most one all-reduce
                                per merged-state leaf, and none of them
                                sits inside the chunk loop (collective
                                count is O(1) per round, not O(C)).
  ``dtype_discipline``          no estimator state or estimate leaf is
                                silently carried below float32.
  ``no_recompile_across_rounds``  driving a session through all its
                                rounds adds at most one jit cache entry
                                per distinct slice shape (plus the
                                kernel paths' first-round variant) —
                                the no-recompile-storm certificate.
                                Dynamic (executes the scan), so it is
                                NOT in the default check set; request it
                                explicitly or via ``ALL_CHECKS``.
  ``bounded_compiles_under_churn``  the serving extension of the same
                                certificate (:func:`audit_service`, not
                                part of the per-plan catalog): an
                                attach/detach churn workload against a
                                shared scan — including at least one
                                slot-capacity doubling and a
                                detach-then-reattach slot reuse — grows
                                the serving step's jit cache by at most
                                one entry per (bank, capacity) pair
                                stepped, never one per arrival
                                (repro/serving/service.py).

Checks report ``pass`` / ``fail`` / ``skip`` — skip means the invariant
does not apply to the plan (e.g. kernel dispatch counts on a scan plan,
collectives without a mesh) and carries the reason, so a CI lane can
assert "nothing failed" without lying about what it certified.
"""
from __future__ import annotations

import argparse
import sys
from dataclasses import dataclass, field
from typing import Any, Callable, Dict, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.analysis import hlo_cost
from repro.core import engine as EN
from repro.core import scan as SC
from repro.data import source as DSRC


class AuditError(RuntimeError):
    """Raised by :meth:`AuditReport.raise_for_failures` when any check failed."""


@dataclass(frozen=True)
class CheckResult:
    """Outcome of one named invariant check.

    ``status`` is ``"pass"``, ``"fail"`` or ``"skip"``; ``detail`` is a
    human-readable sentence (the skip reason, or what was measured);
    ``data`` carries the measured quantities (loop counts, byte totals,
    cache deltas) for benchmarks and tests to consume.
    """

    name: str
    status: str
    detail: str = ""
    data: Dict[str, Any] = field(default_factory=dict)

    @property
    def passed(self) -> bool:
        return self.status == "pass"

    @property
    def failed(self) -> bool:
        return self.status == "fail"

    @property
    def skipped(self) -> bool:
        return self.status == "skip"

    def __str__(self) -> str:
        return f"[{self.status:>4}] {self.name}: {self.detail}"


@dataclass(frozen=True)
class AuditReport:
    """Structured result of :func:`audit_plan` over one plan."""

    plan: Dict[str, Any]
    results: Tuple[CheckResult, ...]

    @property
    def ok(self) -> bool:
        """True when no check failed (skips do not count against a plan)."""
        return not self.failures

    @property
    def failures(self) -> Tuple[CheckResult, ...]:
        return tuple(r for r in self.results if r.failed)

    def result(self, name: str) -> CheckResult:
        for r in self.results:
            if r.name == name:
                return r
        raise KeyError(f"no check named {name!r} in this report "
                       f"(ran: {[r.name for r in self.results]})")

    def raise_for_failures(self) -> None:
        if self.failures:
            lines = [f"plan {self.plan} failed "
                     f"{len(self.failures)} invariant check(s):"]
            lines += [f"  {r}" for r in self.failures]
            raise AuditError("\n".join(lines))

    def summary(self) -> str:
        head = (f"audit {self.plan.get('gla')} [{self.plan.get('engine')}, "
                f"emit={self.plan.get('emit')}]: "
                f"{'OK' if self.ok else 'FAIL'}")
        return "\n".join([head, *(f"  {r}" for r in self.results)])


# ---------------------------------------------------------------------------
# the reusable checks: pure functions over optimized HLO text
# ---------------------------------------------------------------------------

def chunk_loop_count(hlo_text: str, trip: int) -> int:
    """Number of while loops with exactly ``trip`` iterations.

    The chunk-stream loop is identified by its trip count (chunks per
    round-slice, or C for whole-shard scans); per-query fix-up loops
    (scatter expansions, estimate assembly) have item-scale trips and are
    told apart by it — the multiquery benchmark's original discriminator.
    """
    return sum(t == trip for t in hlo_cost.while_trip_counts(hlo_text))


def check_one_chunk_pass(hlo_text: str, *, chunk_trip: int,
                         expected: int = 1, where: str = "") -> CheckResult:
    """ONE loop over the chunk stream, no matter how many queries ride it."""
    n = chunk_loop_count(hlo_text, chunk_trip)
    loc = f" ({where})" if where else ""
    if n == expected:
        return CheckResult(
            "one_chunk_pass", "pass",
            f"{n} loop(s) with trip {chunk_trip}{loc}",
            {"chunk_loops": n, "chunk_trip": chunk_trip})
    return CheckResult(
        "one_chunk_pass", "fail",
        f"expected {expected} chunk loop(s) with trip {chunk_trip}, found "
        f"{n}{loc} — the program re-scans (or never scans) the chunk stream",
        {"chunk_loops": n, "chunk_trip": chunk_trip,
         "trips": hlo_cost.while_trip_counts(hlo_text)})


def check_slice_footprint(hlo_text: str, *, slice_bytes: int,
                          floor_bytes: int, dataset_bytes: Optional[int] = None,
                          where: str = "") -> CheckResult:
    """ENTRY parameter bytes of the step program are O(slice), not O(data).

    ``floor_bytes`` (one live column of the slice) guards against the HLO
    text format drifting and ``entry_param_bytes`` degrading to ~0, which
    would make the upper bound vacuous.  The ceiling allows 1.5x the slice
    plus 1 MiB of carry/weights.  When ``dataset_bytes`` shows the plan is
    out-of-core by >= 8x, the step must also stay below dataset/8.
    """
    got = hlo_cost.entry_param_bytes(hlo_text)
    ceil = slice_bytes * 1.5 + (1 << 20)
    data = {"entry_param_bytes": got, "slice_bytes": slice_bytes,
            "floor_bytes": floor_bytes, "ceiling_bytes": ceil,
            "dataset_bytes": dataset_bytes}
    loc = f" ({where})" if where else ""
    if got < floor_bytes:
        return CheckResult(
            "o_slice_footprint", "fail",
            f"step ENTRY params {got:.0f}B below one live column "
            f"({floor_bytes}B){loc} — entry_param_bytes is no longer "
            "reading the compiled program", data)
    if got > ceil:
        return CheckResult(
            "o_slice_footprint", "fail",
            f"step transfers {got:.0f}B, expected O(slice) ~ "
            f"{slice_bytes}B{loc}", data)
    if (dataset_bytes is not None and dataset_bytes >= 8 * slice_bytes
            and got >= dataset_bytes / 8):
        return CheckResult(
            "o_slice_footprint", "fail",
            f"step transfers {got:.0f}B >= dataset/8 "
            f"({dataset_bytes}B total){loc} — the scan is not "
            "out-of-core", data)
    return CheckResult(
        "o_slice_footprint", "pass",
        f"step ENTRY params {got:.0f}B within "
        f"[{floor_bytes}, {ceil:.0f}]B{loc}", data)


def check_kernel_dispatch(hlo_text: str, *, dispatches: int,
                          backend: Optional[str] = None,
                          where: str = "") -> CheckResult:
    """Exactly ``dispatches`` Pallas launches — and NO leftover scan loops.

    In interpret mode (the CPU backend) every while op remaining in an
    optimized kernel-path program is a Pallas grid loop, so the total
    while count IS the dispatch count (benchmarks/groupby.py).  On other
    backends dispatches lower to custom-calls the text of which is not
    stable across versions, so the check is skipped rather than guessed.
    """
    backend = backend if backend is not None else jax.default_backend()
    if backend != "cpu":
        return CheckResult(
            "single_kernel_dispatch", "skip",
            f"dispatch structure is only countable in Pallas interpret "
            f"mode (backend is {backend!r})", {"backend": backend})
    n = int(hlo_cost.count_ops(hlo_text, "while", trip_scaled=False))
    loc = f" ({where})" if where else ""
    data = {"while_ops": n, "expected": dispatches, "backend": backend}
    if n == dispatches:
        return CheckResult(
            "single_kernel_dispatch", "pass",
            f"{n} grid loop(s) == one dispatch per (partition, "
            f"round-slice){loc}", data)
    return CheckResult(
        "single_kernel_dispatch", "fail",
        f"expected {dispatches} Pallas grid loops, found {n} while "
        f"op(s){loc} — extra scan loops or missing/duplicated dispatches",
        data)


def check_collectives(hlo_text: str, *, max_reductions: int,
                      where: str = "") -> CheckResult:
    """One psum per sharded step: <= one all-reduce per merged-state leaf,
    and none of them trip-scaled (i.e. inside the chunk loop).

    A single ``lax.psum`` of a k-leaf state lowers to at most k all-reduce
    ops (XLA may combine them further), so "one collective per round"
    compiles to ``1 <= n <= k``.  The trip-invariance clause is the real
    performance contract: the synchronized barrier's per-chunk psum shows
    up precisely as a trip-scaled count of O(C), not O(1).
    """
    flat = sum(int(hlo_cost.count_ops(hlo_text, op, trip_scaled=False))
               for op in ("all-reduce", "all-reduce-start"))
    scaled = sum(int(hlo_cost.count_ops(hlo_text, op, trip_scaled=True))
                 for op in ("all-reduce", "all-reduce-start"))
    loc = f" ({where})" if where else ""
    data = {"all_reduce_ops": flat, "trip_scaled": scaled,
            "max_reductions": max_reductions}
    if flat == 0:
        return CheckResult(
            "one_collective_per_round", "fail",
            f"no all-reduce in the sharded step{loc} — the merge "
            "collective was lost (states would stay per-device)", data)
    if flat > max_reductions:
        return CheckResult(
            "one_collective_per_round", "fail",
            f"{flat} all-reduce ops for a {max_reductions}-leaf merged "
            f"state{loc} — more than one collective round per step", data)
    if scaled != flat:
        return CheckResult(
            "one_collective_per_round", "fail",
            f"all-reduce count is trip-scaled ({flat} -> {scaled}){loc} — "
            "a collective sits inside the chunk loop (per-chunk barrier "
            "semantics leaked into the async step)", data)
    return CheckResult(
        "one_collective_per_round", "pass",
        f"{flat} all-reduce op(s) <= {max_reductions} state leaves, none "
        f"inside loops{loc}", data)


def check_dtype_discipline(shapes_by_role: Dict[str, Any]) -> CheckResult:
    """No floating leaf of the estimator state/estimate below float32.

    ``shapes_by_role`` maps a role name ("init", "states", "merged",
    "estimate", ...) to a pytree of ``jax.ShapeDtypeStruct`` (from
    ``jax.eval_shape`` — the check never touches real data).
    """
    narrow = []
    for role, tree in shapes_by_role.items():
        if tree is None:
            continue
        for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
            dt = np.dtype(leaf.dtype)
            if np.issubdtype(dt, np.floating) and dt.itemsize < 4:
                narrow.append(f"{role}{jax.tree_util.keystr(path)}: {dt}")
    if narrow:
        return CheckResult(
            "dtype_discipline", "fail",
            "estimator state carried below float32: " + ", ".join(narrow),
            {"narrow_leaves": narrow})
    n = sum(len(jax.tree_util.tree_leaves(t))
            for t in shapes_by_role.values() if t is not None)
    return CheckResult(
        "dtype_discipline", "pass",
        f"{n} state/estimate leaves all >= float32",
        {"leaves_checked": n})


# ---------------------------------------------------------------------------
# the plan driver
# ---------------------------------------------------------------------------

STATIC_CHECKS: Tuple[str, ...] = (
    "one_chunk_pass", "o_slice_footprint", "single_kernel_dispatch",
    "fused_single_dispatch", "bytes_moved",
    "one_collective_per_round", "dtype_discipline")
ALL_CHECKS: Tuple[str, ...] = (*STATIC_CHECKS, "no_recompile_across_rounds")


class _Plan:
    """Lowered-program cache + shared shape math for one audited plan."""

    def __init__(self, gla, source, sched: np.ndarray, *, emit: str,
                 mode: str, lanes: int, snapshots: bool, confidence: float,
                 mesh, axis_name: str):
        self.gla = gla
        self.source = source
        self.sched = sched
        self.emit, self.mode, self.lanes = emit, mode, lanes
        self.snapshots, self.confidence = snapshots, confidence
        self.mesh, self.axis_name = mesh, axis_name
        spec = source.spec
        self.P, self.C, self.L = spec.P, spec.C, spec.L
        self.R = sched.shape[1] - 1
        self.uniform = bool(np.all(sched == sched[0]))
        self.widths = sorted({int(sched[0, r + 1] - sched[0, r])
                              for r in range(self.R)}) if self.uniform else []
        self.steppable = mode == "async" and self.uniform
        self.encodings = tuple(getattr(source, "encodings", ()) or ())
        # mirrors Session's path selection exactly, fused preference
        # included — the audit certifies the program the session will run
        if emit == "kernel":
            if SC.fused_available(gla, spec.columns):
                self.path = "kernel_fused"
            else:
                self.path = ("kernel_bundle" if gla.members
                             else "kernel_group" if gla.kernel_num_groups
                             is not None else "kernel_scalar")
        else:
            self.path = "scan"
        self._step = None       # (hlo_text, eval_shape outputs)
        self._fused_hlo = None

    # -- shape math ----------------------------------------------------------

    def col_bytes(self, width: int) -> int:
        """Bytes of every column over [P, width, L] (+ trailing dims)."""
        total = 0
        for c in self.source.spec.columns:
            n = self.P * width * self.L
            for t in c.trailing:
                n *= t
            total += n * np.dtype(c.dtype).itemsize
        return total

    def states_like(self):
        base = (SC.stack_init(self.gla, self.lanes)
                if self.path == "scan" else self.gla.init())
        return jax.eval_shape(lambda: jax.tree.map(
            lambda x: jnp.broadcast_to(x, (self.P, *x.shape)), base))

    # -- lowered programs ----------------------------------------------------

    def step(self):
        """(optimized HLO text, eval_shape outputs) of the per-round step
        program — the same lowering the session's incremental driver jits.
        Returns None for plans that cannot step (sync mode, non-uniform
        schedule)."""
        if not self.steppable:
            return None
        if self._step is None:
            w = max(self.widths)
            # physical slice shapes: encoded sources ship packed columns
            args = (self.gla, self.states_like(),
                    self.source.step_slice_like(w),
                    jax.ShapeDtypeStruct((self.P,), jnp.float32),
                    jax.ShapeDtypeStruct((self.P,), jnp.float32),
                    jax.ShapeDtypeStruct((), jnp.float32))
            if self.mesh is None:
                from repro.core import session as SN
                fn = SN._step_vmapped
                kw = dict(path=self.path, lanes=self.lanes,
                          confidence=self.confidence, all_alive=True,
                          first=False, encodings=self.encodings)
            else:
                from repro.dist import shard_engine
                fn = shard_engine.session_step_sharded
                kw = dict(mesh=self.mesh, axis_name=self.axis_name,
                          path=self.path, lanes=self.lanes,
                          confidence=self.confidence, first=False,
                          encodings=self.encodings)
            hlo = fn.lower(*args, **kw).compile().as_text()
            self._step = (hlo, fn.eval_shape(*args, **kw))
        return self._step

    def fused(self) -> Optional[str]:
        """Optimized HLO text of the fused whole-scan program.  Lowered
        from shapes only (no data), but reported only for resident sources
        — a streaming plan never runs it."""
        if not self.source.resident:
            return None
        if self._fused_hlo is None:
            shards_like = self.source.spec.slice_like(self.C)
            sched_like = jax.ShapeDtypeStruct((self.P, self.R + 1), jnp.int32)
            if self.mesh is None:
                low = EN._run_vmapped.lower(
                    self.gla, shards_like, sched_like,
                    jax.ShapeDtypeStruct((self.P,), jnp.bool_),
                    mode=self.mode, emit=self.emit, lanes=self.lanes,
                    snapshots=self.snapshots, confidence=self.confidence,
                    all_alive=True)
            else:
                from repro.dist import shard_engine
                low = shard_engine._run_sharded_jit.lower(
                    self.gla, shards_like, sched_like,
                    jax.ShapeDtypeStruct((self.P, self.R), jnp.float32),
                    mesh=self.mesh, axis_name=self.axis_name, mode=self.mode,
                    emit=self.emit, lanes=self.lanes,
                    snapshots=self.snapshots, sync_cost_model=True)
            self._fused_hlo = low.compile().as_text()
        return self._fused_hlo


def _skip(name: str, reason: str) -> CheckResult:
    return CheckResult(name, "skip", reason)


def _merge_results(name: str, parts) -> CheckResult:
    """Combine per-program results for one check into a single verdict."""
    parts = [p for p in parts if p is not None]
    if not parts:
        return _skip(name, "no program to audit for this plan")
    fails = [p for p in parts if p.failed]
    if fails:
        return fails[0]
    passes = [p for p in parts if p.passed]
    if passes:
        detail = "; ".join(p.detail for p in passes)
        data = {}
        for p in passes:
            data.update(p.data)
        return CheckResult(name, "pass", detail, data)
    return CheckResult(name, "skip", "; ".join(p.detail for p in parts))


def _audit_one_chunk_pass(p: _Plan) -> CheckResult:
    if p.path != "scan":
        return _skip("one_chunk_pass",
                     "kernel plans have no chunk scan loop — dispatch "
                     "structure is certified by single_kernel_dispatch")
    if p.emit == "round_masked":
        return _skip("one_chunk_pass",
                     "emit='round_masked' re-scans all chunks per round — "
                     "O(R*C) by design (DESIGN.md §3)")
    if p.emit not in ("chunk", "round"):
        return _skip("one_chunk_pass", f"emit={p.emit!r} not audited")
    parts = []
    fused = p.fused()
    if fused is not None:
        trip = p.C if p.emit == "chunk" else p.C // p.R
        if p.emit == "round" and (p.C % p.R or trip == p.R):
            parts.append(_skip(
                "one_chunk_pass",
                f"fused round loop (trip {p.R}) indistinguishable from "
                f"the chunk loop (trip {trip}) at these sizes"))
        else:
            parts.append(check_one_chunk_pass(
                fused, chunk_trip=trip, where="fused program"))
    step = p.step()
    if step is not None:
        w = max(p.widths)
        parts.append(check_one_chunk_pass(
            step[0], chunk_trip=w, where="step program"))
    elif fused is None:
        parts.append(_skip("one_chunk_pass",
                           "plan is neither fused-executable nor "
                           "incrementally steppable"))
    return _merge_results("one_chunk_pass", parts)


def _audit_slice_footprint(p: _Plan) -> CheckResult:
    step = p.step()
    if step is None:
        return _skip("o_slice_footprint",
                     "plan cannot step incrementally — no per-round "
                     "transfer surface to certify")
    w = max(p.widths)
    # the sharded step's optimized HLO is the *per-device* module: its
    # ENTRY params hold 1/ndev of every partition-sharded operand
    ndev = 1 if p.mesh is None else int(p.mesh.devices.size)
    return check_slice_footprint(
        step[0], slice_bytes=p.col_bytes(w) // ndev,
        floor_bytes=p.P * w * p.L * 4 // ndev,
        dataset_bytes=p.col_bytes(p.C) // ndev, where="step program")


def _audit_kernel_dispatch(p: _Plan) -> CheckResult:
    if p.path == "scan":
        return _skip("single_kernel_dispatch",
                     "not a kernel plan (emit != 'kernel')")
    if p.path == "kernel_fused":
        # the fused body's in-kernel segment_sum lowers to scatter loops
        # under interpret mode, so a while-op census over the HLO cannot
        # isolate Pallas grid loops; fused_single_dispatch counts actual
        # pallas_call constructions at trace time instead
        return _skip("single_kernel_dispatch",
                     "fused kernel plan — certified by fused_single_dispatch")
    # scalar GLAs (legacy) run one whole-shard prefix dispatch;
    # group/bundle plans dispatch once per round-slice when snapshotting
    is_scalar = not p.gla.members and p.gla.kernel_num_groups is None
    per_shard = p.R if (not is_scalar and p.snapshots) else 1
    parts = []
    fused = p.fused()
    if fused is not None:
        trip = p.C // per_shard if p.C % per_shard == 0 else 0
        if trip < 2:
            parts.append(_skip(
                "single_kernel_dispatch",
                f"grid of {trip} step(s) per dispatch is unrolled in "
                "interpret mode — nothing to count"))
        else:
            expected = (p.P if p.mesh is None else 1) * per_shard
            parts.append(check_kernel_dispatch(
                fused, dispatches=expected, where="fused program"))
    step = p.step()
    if step is not None:
        w = max(p.widths)
        if w < 2:
            parts.append(_skip(
                "single_kernel_dispatch",
                "1-chunk round-slices are unrolled in interpret mode"))
        else:
            parts.append(check_kernel_dispatch(
                step[0], dispatches=p.P if p.mesh is None else 1,
                where="step program"))
    return _merge_results("single_kernel_dispatch", parts)


def _audit_fused_dispatch(p: _Plan) -> CheckResult:
    if p.path != "kernel_fused":
        return _skip("fused_single_dispatch",
                     "plan does not take the fused kernel path (no "
                     "FusedSpec, non-f32 state, or trailing-dim columns)")
    from repro.kernels import fused_agg as FK
    w = max(p.widths) if p.widths else p.C
    slice_like = p.source.step_slice_like(w)
    # one partition's round-slice, shapes only — the dispatch counter
    # fires during tracing, so eval_shape counts without executing
    one = {k: jax.ShapeDtypeStruct(tuple(v.shape[1:]), v.dtype)
           for k, v in slice_like.items()}
    st = jax.eval_shape(p.gla.init)
    with FK.count_dispatches() as box:
        jax.eval_shape(
            lambda s, sl: SC.fused_round_step(p.gla, s, sl, p.encodings),
            st, one)
    n = box[0]
    # join plans ship replicated probe tables as extra kernel operands —
    # report their VMEM residency against the kernel's budget
    pbytes = FK.probe_bytes(p.gla)
    data = {"dispatches": n, "expected": 1, "encoded_cols":
            [name for name, _ in p.encodings],
            "probe_bytes": pbytes,
            "probe_budget_bytes": FK.PROBE_VMEM_BUDGET_BYTES}
    if n == 1:
        k = len(getattr(p.gla, "members", ()) or ()) or 1
        probe = (f", {pbytes}B of join probe tables in-kernel"
                 if pbytes else "")
        return CheckResult(
            "fused_single_dispatch", "pass",
            f"one pallas_call per (partition, round-slice) covers "
            f"{k} member(s), predicate, bucketing and "
            f"{len(p.encodings)} in-kernel decode(s){probe}", data)
    return CheckResult(
        "fused_single_dispatch", "fail",
        f"fused round-slice step issued {n} Pallas dispatches, expected "
        "1 — selection/bucketing/decode/accumulation split across "
        "kernels", data)


def _audit_bytes_moved(p: _Plan) -> CheckResult:
    if not p.encodings:
        return _skip("bytes_moved",
                     "no encoded columns — the physical stream already "
                     "is the logical stream")
    w = max(p.widths) if p.widths else p.C

    def _bytes(tree) -> int:
        return sum(int(np.prod(v.shape)) * np.dtype(v.dtype).itemsize
                   for v in jax.tree.leaves(tree))

    phys = _bytes(p.source.step_slice_like(w))
    logical = _bytes(p.source.spec.slice_like(w))
    ratio = phys / logical
    data = {"physical_bytes": phys, "logical_bytes": logical,
            "ratio": ratio,
            "encoded_cols": [name for name, _ in p.encodings]}
    if ratio <= 0.95:
        return CheckResult(
            "bytes_moved", "pass",
            f"encoded round-slice streams {phys}B for {logical}B of "
            f"logical columns ({ratio:.2f}x)", data)
    return CheckResult(
        "bytes_moved", "fail",
        f"encoded round-slice streams {phys}B vs {logical}B logical "
        f"({ratio:.2f}x) — encodings are not shrinking the stream "
        "measurably (<= 0.95x required)", data)


def _audit_collectives(p: _Plan) -> CheckResult:
    if p.mesh is None:
        return _skip("one_collective_per_round",
                     "vmapped engine merges with a tensordot — no "
                     "collectives to count (pass mesh= for the sharded "
                     "engine)")
    if p.mesh.devices.size <= 1:
        return _skip("one_collective_per_round",
                     "1-device mesh — psum lowers to a no-op")
    step = p.step()
    if step is None:
        return _skip("one_collective_per_round",
                     "plan cannot step incrementally — per-round "
                     "collective structure undefined")
    merged_like = step[1][2]
    leaves = len(jax.tree_util.tree_leaves(merged_like))
    return check_collectives(step[0], max_reductions=leaves,
                             where="sharded step")


def _audit_dtype(p: _Plan) -> CheckResult:
    roles = {"init": p.states_like()}
    step = p.step()
    if step is not None:
        new_states, views, merged, est = step[1]
        roles.update({"states": new_states, "views": views,
                      "merged": merged, "estimate": est})
    return check_dtype_discipline(roles)


def _audit_no_recompile(p: _Plan) -> CheckResult:
    if not p.steppable:
        return _skip("no_recompile_across_rounds",
                     "plan cannot step incrementally — nothing recompiles")
    if p.mesh is None:
        from repro.core import session as SN
        fn = SN._step_vmapped
    else:
        from repro.dist import shard_engine
        fn = shard_engine.session_step_sharded
    cache_size = getattr(fn, "_cache_size", None)
    if cache_size is None:
        return _skip("no_recompile_across_rounds",
                     "jit cache introspection unavailable in this jax")
    from repro.core import session as SN
    from repro.core.spec import QuerySpec
    before = cache_size()
    sess = SN.Session(
        QuerySpec(p.gla, rounds=p.R, schedule=p.sched, emit=p.emit,
                  sync=p.mode == "sync", lanes=p.lanes,
                  snapshots=p.snapshots, confidence=p.confidence),
        p.source, mesh=p.mesh, axis_name=p.axis_name)
    while not sess.done:
        sess.step()
    jax.block_until_ready(sess.result().final)
    delta = cache_size() - before
    # one entry per distinct slice shape, plus at most one extra
    # steady-state variant: kernel paths trace a first=True round-0
    # program (running sum starts from the first delta instead of
    # zero + delta), and sharded sessions retrace once when round 0's
    # freshly-initialized (unsharded) states are replaced by the step's
    # own mesh-sharded outputs.  Both are one-time; a per-round miss is
    # the storm this check exists to catch.
    extra = 1 if p.R > 1 and (p.path != "scan" or p.mesh is not None) else 0
    budget = len(p.widths) + extra
    data = {"cache_miss_delta": delta, "budget": budget,
            "rounds": p.R, "distinct_widths": len(p.widths)}
    if delta <= budget:
        return CheckResult(
            "no_recompile_across_rounds", "pass",
            f"{p.R} rounds compiled {delta} step program(s) "
            f"(budget {budget})", data)
    return CheckResult(
        "no_recompile_across_rounds", "fail",
        f"{p.R} rounds triggered {delta} step compilations (budget "
        f"{budget}) — a recompile storm: some step input's shape/dtype "
        "or a static argument varies per round", data)


_CHECK_FNS: Dict[str, Callable[[_Plan], CheckResult]] = {
    "one_chunk_pass": _audit_one_chunk_pass,
    "o_slice_footprint": _audit_slice_footprint,
    "single_kernel_dispatch": _audit_kernel_dispatch,
    "fused_single_dispatch": _audit_fused_dispatch,
    "bytes_moved": _audit_bytes_moved,
    "one_collective_per_round": _audit_collectives,
    "dtype_discipline": _audit_dtype,
    "no_recompile_across_rounds": _audit_no_recompile,
}


def audit_plan(gla, data, *, rounds: int = 8,
               schedule: Optional[np.ndarray] = None, emit: str = "chunk",
               mode: str = "async", lanes: int = 1, snapshots: bool = True,
               confidence: float = 0.95, mesh=None, axis_name: str = "data",
               checks: Optional[Sequence[str]] = None,
               raise_on_failure: bool = False) -> AuditReport:
    """Certify a query plan against the invariant catalog, pre-execution.

    Args mirror :func:`repro.core.engine.run_query`; the plan is validated
    and normalized by the same ``engine.normalize_plan``, then its compiled
    programs (the fused whole-scan program for resident sources, the
    incremental step program for steppable configs) are lowered from
    *shapes only* and checked — no data is scanned.  The one exception is
    ``no_recompile_across_rounds``, which drives a throwaway session over
    the real data; it is excluded from the default ``checks``
    (:data:`STATIC_CHECKS`) and must be requested explicitly (or via
    :data:`ALL_CHECKS`).

    Returns an :class:`AuditReport`; with ``raise_on_failure`` the report
    raises :class:`AuditError` before returning.
    """
    source = DSRC.as_source(data)
    R, sched = EN.normalize_plan(gla, source, rounds, schedule, emit)
    plan = _Plan(gla, source, np.asarray(sched, np.int32), emit=emit,
                 mode=mode, lanes=lanes, snapshots=snapshots,
                 confidence=confidence, mesh=mesh, axis_name=axis_name)
    names = tuple(checks) if checks is not None else STATIC_CHECKS
    unknown = [n for n in names if n not in _CHECK_FNS]
    if unknown:
        raise ValueError(f"unknown audit check(s) {unknown}; catalog: "
                         f"{sorted(_CHECK_FNS)}")
    results = tuple(_CHECK_FNS[n](plan) for n in names)
    report = AuditReport(
        plan={"gla": gla.name,
              "engine": "sharded" if mesh is not None else "vmapped",
              "emit": emit, "mode": mode, "path": plan.path,
              "P": plan.P, "C": plan.C, "L": plan.L, "rounds": plan.R,
              "lanes": lanes, "backend": jax.default_backend()},
        results=results)
    if raise_on_failure:
        report.raise_for_failures()
    return report


# ---------------------------------------------------------------------------
# serving churn audit (repro/serving/service.py, DESIGN.md §11)
# ---------------------------------------------------------------------------

def audit_service(family, data, *, rounds: int = 4, confidence: float = 0.95,
                  mesh=None, axis_name: str = "data",
                  raise_on_failure: bool = False) -> AuditReport:
    """Certify the serving layer's recompile discipline under churn.

    Drives a throwaway :class:`repro.serving.service.SharedScan` through
    an adversarial membership workload — staggered attaches forcing at
    least one slot-capacity doubling, every group bank of the family,
    and a detach-then-reattach slot reuse — and asserts the serving
    step's jit cache grew by at most the scan's compile budget: one
    entry per (bank, capacity) pair actually stepped.  A per-arrival
    compile (the storm the padded-slot design exists to prevent) blows
    the budget immediately: the workload makes 3 + #groups + 2
    membership changes against a budget of ~2 + #groups.
    """
    from repro.core.gla import SlotQuery
    from repro.serving import service as SV

    scan = SV.SharedScan(family, data, rounds=rounds, confidence=confidence,
                         mesh=mesh, axis_name=axis_name)
    engine = "sharded" if mesh is not None else "vmapped"
    plan = {"gla": f"slot-family[{'+'.join(family.expr_names)}]",
            "engine": engine, "emit": "serve", "mode": "async",
            "P": scan.P, "C": scan.C, "rounds": scan.rounds,
            "backend": jax.default_backend()}

    def q(i: int) -> SlotQuery:
        return SlotQuery(family.expr_names[i % len(family.expr_names)])

    before = SV.serve_step_cache_sizes()[engine]
    if before is None:
        report = AuditReport(plan=plan, results=(
            _skip("bounded_compiles_under_churn",
                  "jit cache introspection unavailable in this jax"),))
        if raise_on_failure:
            report.raise_for_failures()
        return report

    recs = [scan.attach(q(0))]
    scan.step()                               # scalar K=1
    recs += [scan.attach(q(1)), scan.attach(q(2))]
    scan.step()                               # forces K=1 -> 2 -> 4
    scan.detach(recs.pop())
    reused = scan.attach(q(1))                # slot reuse: same capacity
    scan.step()
    for g in family.groups:                   # one slot per group bank
        recs.append(scan.attach(SlotQuery(family.expr_names[0], group=g)))
    scan.step()
    arrivals = 3 + len(family.groups) + 1     # membership changes made
    delta = SV.serve_step_cache_sizes()[engine] - before
    budget = scan.compile_budget()
    doublings = max(b.doublings for b in scan.banks.values())
    data_out = {"cache_miss_delta": delta, "budget": budget,
                "arrivals": arrivals, "doublings": doublings,
                "banks": sorted(scan.banks),
                "reused_slot": reused.slot,
                "stepped_capacities": {n: sorted(b.stepped_ks)
                                       for n, b in scan.banks.items()}}
    if doublings < 1:
        result = CheckResult(
            "bounded_compiles_under_churn", "fail",
            "churn workload never doubled a bank's capacity — the check "
            "is not exercising growth", data_out)
    elif delta <= budget:
        result = CheckResult(
            "bounded_compiles_under_churn", "pass",
            f"{arrivals} membership changes ({doublings} doubling(s), "
            f"{len(scan.banks)} bank(s)) compiled {delta} serving step(s) "
            f"(budget {budget})", data_out)
    else:
        result = CheckResult(
            "bounded_compiles_under_churn", "fail",
            f"{arrivals} membership changes compiled {delta} serving "
            f"step(s), budget {budget} — the step is recompiling per "
            "arrival (a static argument or shape varies with membership, "
            "not just with capacity)", data_out)
    report = AuditReport(plan=plan, results=(result,))
    if raise_on_failure:
        report.raise_for_failures()
    return report


# ---------------------------------------------------------------------------
# CLI: the CI audit-smoke lane (python -m repro.analysis.audit)
# ---------------------------------------------------------------------------

def _smoke_data(rows: int, parts: int, chunk: int, rounds: int):
    from repro.core import randomize
    from repro.data import tpch

    cols = tpch.generate_lineitem(rows, seed=7)
    cols["orderkey"] = tpch.generate_orders_fk(rows, seed=7)
    shards = randomize.randomize_global(
        {k: jnp.asarray(v) for k, v in cols.items()}, jax.random.key(7),
        parts)
    n_chunks = -(-rows // parts // chunk)
    # >= 2 chunks per round-slice so interpret-mode grid loops stay loops,
    # and chunks-per-round != rounds so the chunk loop is identifiable
    min_chunks = max(-(-n_chunks // rounds), 2) * rounds
    if min_chunks // rounds == rounds:
        min_chunks += rounds
    return randomize.pack_partitions(shards, chunk_len=chunk,
                                     min_chunks=min_chunks)


def _smoke_plans(rows: int):
    from repro.core import gla
    from repro.data import tpch

    d = float(rows)
    q6 = gla.make_sum_gla(tpch.q6_func, tpch.q6_cond(tpch.Q6_LOW_WINDOW),
                          d_total=d)
    q1 = gla.make_groupby_gla(tpch.q1_func, tpch.q1_cond,
                              tpch.q1_group_small, num_groups=4, d_total=d,
                              num_aggs=4)
    from repro.core.gla import GLABundle
    bundle = GLABundle([q1, q6])
    # two-table Q3-class join: the fused kernel must still be ONE dispatch
    # with the probe tables riding as kernel operands (DESIGN.md §13)
    segment, valid = tpch.orders_table(max(1, rows // 4), seed=14)
    q3 = gla.make_join_groupby_gla(
        tpch.q6_func, tpch.q1_cond, lambda c: c["orderkey"], segment, valid,
        num_groups=tpch.NUM_SEGMENTS, d_total=d)
    return [("q6", q6, "chunk"), ("q1", q1, "kernel"),
            ("bundle", bundle, "kernel"), ("q3-join", q3, "kernel")]


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        description="Certify the q1/q6/bundle smoke plans against the full "
                    "invariant catalog on both engines (CI audit-smoke).")
    ap.add_argument("--rows", type=int, default=20_000)
    ap.add_argument("--rounds", type=int, default=4)
    args = ap.parse_args(argv)

    failed = False
    meshes = [("vmapped", None, 4)]
    n_dev = jax.device_count()
    if n_dev > 1:
        from repro.launch.mesh import make_test_mesh
        mesh = make_test_mesh(n_dev)
        meshes.append(("sharded", mesh, mesh.devices.size))
    else:
        print("# single device: sharded-engine plans skipped "
              "(run under XLA_FLAGS=--xla_force_host_platform_device_count=8)")

    for engine_name, mesh, parts in meshes:
        shards = _smoke_data(args.rows, parts, 128, args.rounds)
        plans = _smoke_plans(args.rows)
        for name, q, emit in plans:
            report = audit_plan(q, shards, rounds=args.rounds, emit=emit,
                                mesh=mesh, checks=ALL_CHECKS)
            print(report.summary())
            if not report.ok:
                failed = True
        # encoded-source plan: certifies the in-kernel decode path —
        # fused_single_dispatch must still see ONE pallas_call, and
        # bytes_moved must see the physical stream shrink
        from repro.data import encodings as ENCS
        from repro.data.source import EncodedSource
        np_shards = {k: np.asarray(v) for k, v in shards.items()}
        esrc = EncodedSource.from_shards(np_shards, {
            "discount": ENCS.dict_encoding_for(np_shards["discount"]),
            "shipdate": ENCS.BitPackedEncoding(bits=16),
            "rfls": ENCS.BitPackedEncoding(bits=2)})
        bundle = dict((n, g) for n, g, _ in plans)["bundle"]
        report = audit_plan(bundle, esrc, rounds=args.rounds, emit="kernel",
                            mesh=mesh, checks=ALL_CHECKS)
        print(report.summary())
        if not report.ok:
            failed = True
        # serving churn certificate (DESIGN.md §11)
        from repro.core.gla import SlotFamily
        from repro.data import tpch
        fam = SlotFamily(
            exprs={"q6": tpch.q6_func, "qty": lambda c: c["quantity"]},
            pred_cols=("shipdate", "discount"),
            groups={"rfls": (tpch.q1_group_small, 4)})
        report = audit_service(fam, shards, rounds=args.rounds, mesh=mesh)
        print(report.summary())
        if not report.ok:
            failed = True
    print("audit-smoke:", "FAIL" if failed else "OK")
    return 1 if failed else 0


if __name__ == "__main__":
    sys.exit(main())
