"""Loop-aware cost analysis over optimized HLO text.

XLA's built-in ``compiled.cost_analysis()`` visits every while-loop body
exactly once, so any scanned computation (layer stacks, microbatch
accumulation, flash-attention KV blocks, recurrent time scans) is
undercounted by its trip count — for a 64-layer scanned model that is a 64×
error.  Fortunately the optimized HLO annotates
``backend_config={"known_trip_count":{"n":...}}`` on while ops, so an exact
loop-aware walk is possible:

    cost(computation) = Σ instruction costs
                        + Σ while(body) × trip + while(cond) × trip
                        + Σ fusion/call(called computations)

Per-instruction model:
  * flops: dot/dot-general = 2 · prod(output dims) · prod(contracting dims)
    (batch dims are part of the output); transcendental elementwise ops
    (exp/tanh/log/...) = 1 flop/element; everything else 0 — matmuls
    dominate every assigned cell.
  * bytes: counted at fusion boundaries (operands + outputs), matching
    XLA's bytes-accessed convention.  dynamic-(update-)slice inside a fusion
    replaces the sliced operand's traffic with the slice size (this is what
    makes decode-cache updates O(token) instead of O(cache)).
  * collectives: all-reduce / all-gather / reduce-scatter / all-to-all /
    collective-permute output bytes, bucketed by kind, trip-scaled like
    everything else.

Validated against XLA's cost_analysis at trip-count=1 in
tests/test_hlo_cost.py.
"""
from __future__ import annotations

import re
from dataclasses import dataclass, field
from typing import Dict, List, Optional

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "bf16": 2, "f16": 2, "f8e4m3": 1, "f8e5m2": 1,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "pred": 1, "c64": 8, "c128": 16,
}

COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
               "collective-permute")

_TRANSCENDENTAL = ("exponential", "tanh", "log", "rsqrt", "sqrt", "power",
                   "divide", "sine", "cosine", "logistic", "expm1", "log1p",
                   "atan2", "erf")

_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")
_INST_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%([^\s=]+)\s*=\s*(\([^)]*\)|[a-z0-9]+\[[0-9,]*\][^\s]*)\s*"
    r"([a-z0-9\-]+)\((.*)$")
_CALLS_RE = re.compile(r"(?:calls|to_apply)=%([^\s,)]+)")
_COND_BODY_RE = re.compile(r"condition=%([^\s,)]+),\s*body=%([^\s,)]+)")
_TRIP_RE = re.compile(r'"known_trip_count":\{"n":"(\d+)"\}')
_BRANCHES_RE = re.compile(r"branch_computations=\{([^}]*)\}")


_OPNAME_RE = re.compile(r'op_name="([^"]{0,120})')


def _opname(rest: str) -> str:
    m = _OPNAME_RE.search(rest)
    if not m:
        return ""
    name = m.group(1)
    # keep the trailing, most specific path segments
    return "  @" + "/".join(name.split("/")[-3:])


def _shape_bytes(type_str: str) -> int:
    """Total bytes of a (possibly tuple) HLO type string."""
    total = 0
    for m in _SHAPE_RE.finditer(type_str):
        dt, dims = m.groups()
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES.get(dt, 4)
    return total


def _shape_elems(type_str: str) -> int:
    m = _SHAPE_RE.search(type_str)
    if not m:
        return 0
    dims = m.group(2)
    n = 1
    if dims:
        for d in dims.split(","):
            n *= int(d)
    return n


@dataclass
class Cost:
    flops: float = 0.0
    bytes: float = 0.0
    collective_bytes: Dict[str, float] = field(default_factory=dict)

    def __iadd__(self, other: "Cost"):
        self.flops += other.flops
        self.bytes += other.bytes
        for k, v in other.collective_bytes.items():
            self.collective_bytes[k] = self.collective_bytes.get(k, 0.0) + v
        return self

    def scaled(self, t: float) -> "Cost":
        return Cost(self.flops * t, self.bytes * t,
                    {k: v * t for k, v in self.collective_bytes.items()})

    @property
    def total_collective(self) -> float:
        return sum(self.collective_bytes.values())


@dataclass
class Instruction:
    name: str
    type_str: str
    opcode: str
    rest: str  # operands + attributes tail of the line


def split_computations(hlo: str) -> Dict[str, List[Instruction]]:
    comps: Dict[str, List[Instruction]] = {}
    current: Optional[str] = None
    for line in hlo.splitlines():
        stripped = line.strip()
        if current is None:
            m = re.match(r"^(?:ENTRY\s+)?%([^\s(]+)\s*\(.*\)\s*->.*\{", line)
            if m:
                current = m.group(1)
                comps[current] = []
                if stripped.endswith("}"):
                    current = None
            continue
        if stripped == "}":
            current = None
            continue
        im = _INST_RE.match(line)
        if im:
            name, tstr, opcode, rest = im.groups()
            comps[current].append(Instruction(name, tstr, opcode, rest))
    return comps


def _dot_flops(inst: Instruction, shapes: Dict[str, str]) -> float:
    out_elems = _shape_elems(inst.type_str)
    k = 1
    m = re.search(r"lhs_contracting_dims=\{([0-9,]*)\}", inst.rest)
    if m and m.group(1):
        # lhs type: newer XLA prints operand types inline —
        # ``dot(f32[4,32,48]{2,1,0} %lhs, ...)`` — so the first shape in the
        # operand list IS the lhs; older text has bare ``%lhs`` and needs the
        # computation-wide shape table.
        head = inst.rest.split(")", 1)[0]
        sm = _SHAPE_RE.search(head)
        if sm is None:
            lhs = re.search(r"%([^\s,)]+)", head)
            sm = _SHAPE_RE.search(shapes.get(lhs.group(1), "")) if lhs else None
        if sm and sm.group(2):
            dims = [int(d) for d in sm.group(2).split(",")]
            for ci in m.group(1).split(","):
                idx = int(ci)
                if idx < len(dims):
                    k *= dims[idx]
    return 2.0 * out_elems * k


class HloCost:
    def __init__(self, hlo_text: str, *, count_copies: bool = False,
                 count_converts: bool = False):
        """count_copies/count_converts: whether `copy` / `convert` traffic
        is charged.  Both default OFF: on the CPU backend, while-loop carry
        copies and bf16→f32 staging converts are backend artifacts that do
        not exist in the TPU lowering (carries are updated in place; bf16 is
        native) — charging them would overstate the TPU memory term by an
        order of magnitude (measured on deepseek train_4k).
        """
        self.comps = split_computations(hlo_text)
        self.entry = self._find_entry(hlo_text)
        self.count_copies = count_copies
        self.count_converts = count_converts
        self._memo: Dict[str, Cost] = {}
        self.op_bytes: Dict[str, float] = {}   # breakdown (unscaled by loops)

    @staticmethod
    def _find_entry(hlo: str) -> str:
        m = re.search(r"^ENTRY\s+%([^\s(]+)", hlo, re.M)
        if m is None:
            raise ValueError(
                "no ENTRY computation in HLO text — not an optimized HLO "
                "dump (pass compiled.as_text(), not a lowered/StableHLO "
                "module)")
        return m.group(1)

    def _operand_names(self, inst: Instruction) -> List[str]:
        head = inst.rest.split(")", 1)[0]
        return re.findall(r"%([^\s,()]+)", head)

    def comp_cost(self, name: str, *, boundary_bytes=True) -> Cost:
        if name in self._memo:
            return self._memo[name]
        insts = self.comps.get(name, [])
        shapes = {i.name: i.type_str for i in insts}
        # parameters appear as instructions with opcode "parameter"
        total = Cost()
        has_ds = any(i.opcode == "dynamic-slice" for i in insts)
        has_dus = any(i.opcode == "dynamic-update-slice" for i in insts)
        ds_bytes = sum(_shape_bytes(i.type_str) for i in insts
                       if i.opcode in ("dynamic-slice", "dynamic-update-slice"))

        for inst in insts:
            op = inst.opcode
            if op in ("parameter", "constant", "get-tuple-element", "tuple",
                      "bitcast", "after-all"):
                continue
            if op == "copy" and not self.count_copies:
                continue
            if op == "convert" and not self.count_converts:
                continue
            if op == "while":
                cb = _COND_BODY_RE.search(inst.rest)
                trip = 1
                tm = _TRIP_RE.search(inst.rest)
                if tm:
                    trip = int(tm.group(1))
                if cb:
                    cond, body = cb.groups()
                    total += self.comp_cost(body).scaled(trip)
                    total += self.comp_cost(cond).scaled(trip)
                continue
            if op == "conditional":
                bm = _BRANCHES_RE.search(inst.rest)
                if bm:
                    branches = re.findall(r"%([^\s,]+)", bm.group(1))
                    sub = [self.comp_cost(b) for b in branches]
                    if sub:
                        total += Cost(
                            max(c.flops for c in sub),
                            max(c.bytes for c in sub),
                            max((c.collective_bytes for c in sub),
                                key=lambda d: sum(d.values())))
                continue
            if op in ("fusion", "call", "custom-call"):
                cm = _CALLS_RE.search(inst.rest)
                if cm:
                    inner = self.comp_cost(cm.group(1), boundary_bytes=False)
                    total += Cost(inner.flops, 0.0,
                                  dict(inner.collective_bytes))
                    # pure staging fusions (convert/copy wrappers) are CPU
                    # backend artifacts — no TPU traffic
                    inner_ops = {i.opcode for i in self.comps.get(cm.group(1), [])}
                    staging = inner_ops <= {"parameter", "convert", "copy",
                                            "bitcast", "tuple",
                                            "get-tuple-element", "constant"}
                    if staging and not self.count_converts:
                        continue
                    # boundary traffic; dynamic-slice fusions move only the
                    # slice, dus fusions update in place
                    called = self.comps.get(cm.group(1), [])
                    c_ds = [i for i in called if i.opcode in
                            ("dynamic-slice", "dynamic-update-slice",
                             "slice", "gather")]
                    out_b = _shape_bytes(inst.type_str)
                    opn_b = sum(_shape_bytes(shapes.get(o, ""))
                                for o in self._operand_names(inst))
                    if c_ds:
                        moved = sum(
                            self._update_bytes(i, called)
                            if i.opcode in ("dynamic-update-slice", "scatter")
                            else _shape_bytes(i.type_str)
                            for i in c_ds)
                        total += Cost(0.0, min(out_b + opn_b,
                                               out_b + 2.0 * moved + 1024))
                    else:
                        total += Cost(0.0, out_b + opn_b)
                continue
            # plain instruction
            c = Cost()
            if op in ("dot", "dot-general"):
                c.flops = _dot_flops(inst, shapes)
            elif op in _TRANSCENDENTAL:
                c.flops = float(_shape_elems(inst.type_str))
            if op in COLLECTIVES or op.rstrip("-start") in COLLECTIVES:
                kind = op.replace("-start", "")
                c.collective_bytes[kind] = float(_shape_bytes(inst.type_str))
            out_b = _shape_bytes(inst.type_str)
            opn_b = sum(_shape_bytes(shapes.get(o, ""))
                        for o in self._operand_names(inst))
            if op in ("dynamic-slice", "slice", "gather"):
                # reads only the window it produces (+ writes it)
                c.bytes = 2.0 * out_b
            elif op in ("dynamic-update-slice", "scatter"):
                upd = self._update_bytes(inst, insts)
                c.bytes = 2.0 * upd
            elif boundary_bytes or op in ("dot", "dot-general") or (
                    op in COLLECTIVES):
                c.bytes = out_b + opn_b
            total += c
        self._memo[name] = total
        return total

    def _update_bytes(self, inst: Instruction, insts) -> int:
        """bytes of the update operand (operand 1) of a dynamic-update-slice."""
        shapes = {i.name: i.type_str for i in insts}
        ops = self._operand_names(inst)
        if len(ops) >= 2:
            return _shape_bytes(shapes.get(ops[1], "")) or 1024
        return 1024

    def total(self) -> Cost:
        return self.comp_cost(self.entry)

    # -- diagnostics --------------------------------------------------------
    def bytes_breakdown(self, top: int = 20):
        """Trip-scaled bytes per (opcode, shape) — hillclimbing diagnostic."""
        acc: Dict[str, float] = {}

        def walk(name: str, mult: float, boundary: bool):
            for inst in self.comps.get(name, []):
                op = inst.opcode
                if op in ("parameter", "constant", "get-tuple-element",
                          "tuple", "bitcast", "after-all"):
                    continue
                if op == "copy" and not self.count_copies:
                    continue
                if op == "convert" and not self.count_converts:
                    continue
                if op == "while":
                    cb = _COND_BODY_RE.search(inst.rest)
                    tm = _TRIP_RE.search(inst.rest)
                    trip = int(tm.group(1)) if tm else 1
                    if cb:
                        walk(cb.group(2), mult * trip, True)
                    continue
                if op in ("fusion", "call", "custom-call"):
                    cm = _CALLS_RE.search(inst.rest)
                    if not cm:
                        continue
                    called = self.comps.get(cm.group(1), [])
                    inner_ops = {i.opcode for i in called}
                    staging = inner_ops <= {"parameter", "convert", "copy",
                                            "bitcast", "tuple",
                                            "get-tuple-element", "constant"}
                    if staging and not self.count_converts:
                        continue
                    shapes = {i.name: i.type_str for i in
                              self.comps.get(name, [])}
                    c_ds = [i for i in called if i.opcode in
                            ("dynamic-slice", "dynamic-update-slice",
                             "slice", "gather")]
                    out_b = _shape_bytes(inst.type_str)
                    opn_b = sum(_shape_bytes(shapes.get(o, ""))
                                for o in self._operand_names(inst))
                    if c_ds:
                        moved = sum(
                            self._update_bytes(i, called)
                            if i.opcode in ("dynamic-update-slice", "scatter")
                            else _shape_bytes(i.type_str) for i in c_ds)
                        b = min(out_b + opn_b, out_b + 2.0 * moved + 1024)
                    else:
                        b = out_b + opn_b
                    key = f"fusion:{inst.type_str.split('{')[0][:40]}"
                    key += _opname(inst.rest)
                    acc[key] = acc.get(key, 0.0) + b * mult
                    continue
                shapes = {i.name: i.type_str for i in self.comps.get(name, [])}
                out_b = _shape_bytes(inst.type_str)
                opn_b = sum(_shape_bytes(shapes.get(o, ""))
                            for o in self._operand_names(inst))
                if op in ("dynamic-slice", "slice", "gather"):
                    b = 2.0 * out_b
                elif op in ("dynamic-update-slice", "scatter"):
                    b = 2.0 * self._update_bytes(inst, self.comps.get(name, []))
                else:
                    b = out_b + opn_b
                key = f"{op}:{inst.type_str.split('{')[0][:40]}"
                key += _opname(inst.rest)
                acc[key] = acc.get(key, 0.0) + b * mult

        walk(self.entry, 1.0, True)
        return sorted(acc.items(), key=lambda kv: -kv[1])[:top]


def count_ops(hlo_text: str, opcode: str, *, trip_scaled: bool = True) -> float:
    """Count instructions with ``opcode`` reachable from the entry.

    Walks while bodies (multiplied by ``known_trip_count`` when
    ``trip_scaled``), fusion/call targets, and conditional branches —
    the same traversal as :class:`HloCost`.  Used by benchmarks/groupby.py
    to verify dispatch counts: the segment_sum path issues scatters once per
    chunk (trip-scaled through the scan loops), the Pallas path issues one
    grid loop (a ``while`` op, interpret mode) per dispatch.
    """
    hc = HloCost(hlo_text)
    total = 0.0
    seen_stack: List[str] = []

    def walk(name: str, mult: float):
        if name in seen_stack:  # defensive: HLO computations are acyclic
            return
        seen_stack.append(name)
        nonlocal total
        for inst in hc.comps.get(name, []):
            if inst.opcode == opcode:
                total += mult
            if inst.opcode == "while":
                cb = _COND_BODY_RE.search(inst.rest)
                tm = _TRIP_RE.search(inst.rest)
                trip = int(tm.group(1)) if (tm and trip_scaled) else 1
                if cb:
                    walk(cb.group(1), mult * trip)
                    walk(cb.group(2), mult * trip)
            elif inst.opcode in ("fusion", "call", "custom-call"):
                cm = _CALLS_RE.search(inst.rest)
                if cm:
                    walk(cm.group(1), mult)
            elif inst.opcode == "conditional":
                bm = _BRANCHES_RE.search(inst.rest)
                if bm:
                    for b in re.findall(r"%([^\s,]+)", bm.group(1)):
                        walk(b, mult)
        seen_stack.pop()

    walk(hc.entry, 1.0)
    return total


def entry_param_bytes(hlo_text: str) -> float:
    """Total bytes of the ENTRY computation's parameters.

    This is the program's per-invocation operand surface — everything a
    call must have resident on (or transferred to) the device.  Used by
    benchmarks/streaming.py to certify the incremental session step is
    O(slice): the step program's parameters are one round-slice of
    columns plus the (small) carry/weights, never the whole dataset.
    """
    hc = HloCost(hlo_text)
    return float(sum(_shape_bytes(i.type_str)
                     for i in hc.comps.get(hc.entry, [])
                     if i.opcode == "parameter"))


def while_trip_counts(hlo_text: str) -> List[int]:
    """Trip counts of every while op reachable from the entry (each counted
    once, nested or not; unknown trips report as 1).

    Lets callers identify *which* loops a program runs, not just how many:
    benchmarks/multiquery.py uses it to verify the shared multi-query scan
    keeps exactly ONE loop over the chunk axis regardless of how many
    queries ride it (the per-query scatter/estimate fix-up loops have
    item-scale trip counts and are told apart by trip).
    """
    hc = HloCost(hlo_text)
    trips: List[int] = []
    seen_stack: List[str] = []

    def walk(name: str):
        if name in seen_stack:  # defensive: HLO computations are acyclic
            return
        seen_stack.append(name)
        for inst in hc.comps.get(name, []):
            if inst.opcode == "while":
                tm = _TRIP_RE.search(inst.rest)
                trips.append(int(tm.group(1)) if tm else 1)
                cb = _COND_BODY_RE.search(inst.rest)
                if cb:
                    walk(cb.group(1))
                    walk(cb.group(2))
            elif inst.opcode in ("fusion", "call", "custom-call"):
                cm = _CALLS_RE.search(inst.rest)
                if cm:
                    walk(cm.group(1))
            elif inst.opcode == "conditional":
                bm = _BRANCHES_RE.search(inst.rest)
                if bm:
                    for b in re.findall(r"%([^\s,]+)", bm.group(1)):
                        walk(b)
        seen_stack.pop()

    walk(hc.entry)
    return trips


def analyze(hlo_text: str) -> dict:
    cost = HloCost(hlo_text).total()
    return {
        "flops": cost.flops,
        "bytes": cost.bytes,
        "collective_bytes": cost.collective_bytes,
        "collective_total": cost.total_collective,
    }
