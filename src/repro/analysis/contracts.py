"""Framework-contract linter — static AST checks on the repo's own source.

PF-OLA's composability argument (DESIGN.md §2) only holds while every GLA
honors the merge-monoid contract and every jitted region stays a pure
shape-stable function of its inputs.  This module enforces those
disciplines *statically*, with the stdlib ``ast`` module only (no jax
import — the CI ``contracts`` job runs it on a bare Python):

    python -m repro.analysis.contracts src tests benchmarks examples

Rules (DESIGN.md §10 documents each with rationale):

  C001  ``GLA(...)`` constructed with ``kernel_num_groups`` must also pass
        ``kernel_cols`` — the group kernel cannot gather its inputs
        otherwise (the constructor would fail only at dispatch time).
  C002  A ``GLA`` subclass that overrides one of a paired protocol must
        override both: (``kernel_cols``, ``kernel_num_groups``) and
        (``serialize``, ``deserialize``).  Half a pair is a latent
        dispatch/checkpoint bug.
  C003  No host concretization inside registered jit regions: ``float()``,
        ``int()``, ``bool()``, ``.item()``, ``np.asarray``/``np.array``,
        ``jax.device_get``, ``.tolist()``.  Each forces a device sync and
        breaks tracing.
  C004  No wall-clock or host RNG inside registered jit regions:
        ``time.time``/``perf_counter``/``monotonic``, ``datetime.now``,
        ``np.random.*``, ``random.*``.  They freeze a trace-time value
        into the compiled program.
  C005  Divisions in ``core/estimators.py`` must have statically-clamped
        denominators (a nonzero constant, or a value built from
        ``jnp.maximum``/``jnp.clip``).  This is the "no NaN reaches a
        QueryResult" invariant, checked before runtime.
  C006  ``variance_estimate`` must keep both guards: a ``jnp.maximum``
        clamp and the ``jnp.where`` small-sample gate.
  C007  The checkpoint envelope manifest: ``_CKPT_VERSION`` must equal the
        newest version recorded in :data:`ENVELOPE_HISTORY`, and the keys
        built by ``Session._meta`` must match that manifest exactly — any
        envelope change forces a version bump *and* a history entry here.
  C008  Suppression comments (``# contracts: allow(C0XX)``) are honored
        only for ``(path-suffix, rule)`` pairs recorded in
        :data:`ALLOWLIST`; an unlisted suppression is itself an error, so
        the allowlist in this file is the single audit point.
  C009  Framework code must not call ``run_query``/``run_queries``/
        ``Session`` with the deprecated loose plan kwargs
        (:data:`DEPRECATED_PLAN_KWARGS` — rounds/stop/emit/mode/...);
        plans are spelled as ``QuerySpec`` (repro/core/spec.py).  Applies
        to ``src``, ``benchmarks`` and ``examples``; ``tests`` are exempt
        — the compat shim itself is under test there.
  C010  Every ``PlanNode`` subclass (repro/core/spec.py plan trees) must
        declare its ``monoid`` and ``estimator`` class attributes — the
        merge-monoid / estimator pairing is the lowering contract
        (DESIGN.md §13): a node without them would lower to a GLA whose
        merge algebra is undocumented and unauditable.

Exit status: 0 when clean, 1 with one ``path:line: CODE message`` line per
violation on stdout.
"""
from __future__ import annotations

import argparse
import ast
import io
import re
import sys
import tokenize
from pathlib import Path
from typing import Dict, Iterable, List, Optional, Sequence, Set, Tuple

# ---------------------------------------------------------------------------
# Policy tables
# ---------------------------------------------------------------------------

# Files whose functions run under jax.jit, and how the jit regions are
# identified within them:
#   "all"       — every top-level function in the file is traced code
#                 (scan.py is the chunk-fold library; nothing in it may
#                 touch the host)
#   "decorated" — only functions carrying a jax.jit /
#                 functools.partial(jax.jit, ...) decorator, including
#                 every def nested inside them
JIT_REGION_FILES: Dict[str, str] = {
    "core/scan.py": "all",
    "core/session.py": "decorated",
    "core/engine.py": "decorated",
    "dist/shard_engine.py": "decorated",
    "serving/service.py": "decorated",
}

# The deprecated loose plan kwargs (C009).  Mirrors
# repro.core.spec.DEPRECATED_PLAN_KWARGS; duplicated literally because the
# contracts job runs on a bare interpreter that must not import repro
# (spec.py is import-light, but the single-source audit point for this
# linter is this file — tests/test_query_spec.py asserts the two stay in
# sync).
DEPRECATED_PLAN_KWARGS: frozenset = frozenset({
    "rounds", "schedule", "stop", "confidence", "mode", "emit", "lanes",
    "snapshots", "alive", "fault", "sync_cost_model", "estimator_merge",
})

# Entry points whose loose plan kwargs are deprecated (call-site leaf
# names).  Session.resume and audit_plan keep their own signatures.
_PLAN_ENTRY_POINTS = frozenset({"run_query", "run_queries", "Session"})

# Versioned manifest of the checkpoint envelope's meta keys.  Growing or
# renaming a key in Session._meta REQUIRES bumping _CKPT_VERSION and adding
# the new key set here — C007 fails otherwise.  History is append-only.
ENVELOPE_HISTORY: Dict[int, frozenset] = {
    2: frozenset({
        "version", "gla", "rounds", "steps", "emit", "mode", "lanes",
        "snapshots", "confidence", "path", "P", "C", "L", "schedule",
        "alive", "elapsed_s", "converged", "source", "fingerprint",
    }),
    3: frozenset({
        "version", "gla", "rounds", "steps", "emit", "mode", "lanes",
        "snapshots", "confidence", "path", "P", "C", "L", "schedule",
        "alive", "cursors", "fail_at", "fault_estimator", "elapsed_s",
        "converged", "source", "fingerprint",
    }),
}

# The only suppressions the linter honors: (path suffix, rule) pairs.
# Empty today — a new entry is a reviewed policy decision, not a local
# convenience (DESIGN.md §10).
ALLOWLIST: frozenset = frozenset()

_SUPPRESS_RE = re.compile(r"#\s*contracts:\s*allow\((C\d{3})\)")

_HOST_CASTS = {"float", "int", "bool"}
_HOST_NP_FNS = {"asarray", "array"}
_HOST_METHODS = {"item", "tolist"}
_CLOCK_TIME_FNS = {"time", "perf_counter", "monotonic", "process_time"}


class Violation:
    __slots__ = ("path", "line", "code", "message")

    def __init__(self, path: str, line: int, code: str, message: str):
        self.path, self.line = path, line
        self.code, self.message = code, message

    def __str__(self) -> str:
        return f"{self.path}:{self.line}: {self.code} {self.message}"


def _dotted(node: ast.AST) -> str:
    """'np.random.normal' for nested Attribute/Name chains, '' otherwise."""
    parts: List[str] = []
    while isinstance(node, ast.Attribute):
        parts.append(node.attr)
        node = node.value
    if isinstance(node, ast.Name):
        parts.append(node.id)
        return ".".join(reversed(parts))
    return ""


def _is_jit_decorator(dec: ast.AST) -> bool:
    d = _dotted(dec)
    if d in ("jax.jit", "jit"):
        return True
    if isinstance(dec, ast.Call):
        f = _dotted(dec.func)
        if f in ("jax.jit", "jit"):
            return True
        if f in ("functools.partial", "partial") and dec.args:
            return _dotted(dec.args[0]) in ("jax.jit", "jit")
    return False


# ---------------------------------------------------------------------------
# C001/C002 — GLA construction and subclass pairing
# ---------------------------------------------------------------------------

_PAIRS = (("kernel_cols", "kernel_num_groups"),
          ("serialize", "deserialize"))


def _check_gla(tree: ast.Module, path: str, out: List[Violation]) -> None:
    for node in ast.walk(tree):
        if isinstance(node, ast.Call) and _dotted(node.func).split(".")[-1] == "GLA":
            kw = {k.arg for k in node.keywords if k.arg}
            if "kernel_num_groups" in kw and "kernel_cols" not in kw:
                out.append(Violation(
                    path, node.lineno, "C001",
                    "GLA(..., kernel_num_groups=...) without kernel_cols=: "
                    "the group kernel has no input columns to gather"))
        if isinstance(node, ast.ClassDef):
            bases = {_dotted(b).split(".")[-1] for b in node.bases}
            if "GLA" not in bases:
                continue
            defined: Set[str] = set()
            for item in node.body:
                if isinstance(item, (ast.FunctionDef, ast.AsyncFunctionDef)):
                    defined.add(item.name)
                elif isinstance(item, ast.Assign):
                    for t in item.targets:
                        if isinstance(t, ast.Name):
                            defined.add(t.id)
                elif isinstance(item, ast.AnnAssign) and isinstance(
                        item.target, ast.Name):
                    defined.add(item.target.id)
            for a, b in _PAIRS:
                if (a in defined) != (b in defined):
                    have, miss = (a, b) if a in defined else (b, a)
                    out.append(Violation(
                        path, node.lineno, "C002",
                        f"GLA subclass {node.name} defines {have} without "
                        f"{miss}: the protocol is both-or-neither"))


# ---------------------------------------------------------------------------
# C003/C004 — host calls inside jit regions
# ---------------------------------------------------------------------------

def _jit_functions(tree: ast.Module, policy: str) -> Iterable[ast.AST]:
    if policy == "all":
        for node in tree.body:
            if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
                yield node
        return
    for node in ast.walk(tree):
        if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
            if any(_is_jit_decorator(d) for d in node.decorator_list):
                yield node


def _check_host_calls(fn: ast.AST, path: str, out: List[Violation]) -> None:
    fname = getattr(fn, "name", "<lambda>")
    where = f"in jit region {fname!r}"
    for node in ast.walk(fn):
        if not isinstance(node, ast.Call):
            continue
        d = _dotted(node.func)
        leaf = d.split(".")[-1] if d else ""
        if isinstance(node.func, ast.Name) and node.func.id in _HOST_CASTS:
            out.append(Violation(
                path, node.lineno, "C003",
                f"host concretization {node.func.id}(...) {where}: forces "
                "a device sync and breaks tracing"))
        elif d in (f"np.{f}" for f in _HOST_NP_FNS) or d in (
                f"numpy.{f}" for f in _HOST_NP_FNS):
            out.append(Violation(
                path, node.lineno, "C003",
                f"host concretization {d}(...) {where}"))
        elif d in ("jax.device_get", "device_get"):
            out.append(Violation(
                path, node.lineno, "C003",
                f"host concretization {d}(...) {where}"))
        elif isinstance(node.func, ast.Attribute) and not d and (
                node.func.attr in _HOST_METHODS):
            out.append(Violation(
                path, node.lineno, "C003",
                f"host concretization .{node.func.attr}() {where}"))
        elif leaf in _HOST_METHODS and d.count(".") >= 1 and not d.startswith(
                ("np.", "numpy.", "jnp.")):
            out.append(Violation(
                path, node.lineno, "C003",
                f"host concretization {d}(...) {where}"))
        elif d in (f"time.{f}" for f in _CLOCK_TIME_FNS):
            out.append(Violation(
                path, node.lineno, "C004",
                f"wall-clock {d}() {where}: freezes a trace-time value "
                "into the compiled program"))
        elif d in ("datetime.now", "datetime.datetime.now", "datetime.utcnow"):
            out.append(Violation(
                path, node.lineno, "C004", f"wall-clock {d}() {where}"))
        elif d.startswith(("np.random.", "numpy.random.")):
            out.append(Violation(
                path, node.lineno, "C004",
                f"host RNG {d}(...) {where}: not keyed, not traceable"))
        elif d.startswith("random."):
            out.append(Violation(
                path, node.lineno, "C004", f"host RNG {d}(...) {where}"))


# ---------------------------------------------------------------------------
# C005/C006 — estimator clamp discipline
# ---------------------------------------------------------------------------

_CLAMP_FNS = {"jnp.maximum", "jnp.clip", "jax.numpy.maximum",
              "jax.numpy.clip"}


def _collect_assignments(fn: ast.AST) -> Dict[str, ast.AST]:
    """name -> last assigned value expression, within one function body."""
    assigns: Dict[str, ast.AST] = {}
    for node in ast.walk(fn):
        if isinstance(node, ast.Assign) and len(node.targets) == 1 and (
                isinstance(node.targets[0], ast.Name)):
            assigns[node.targets[0].id] = node.value
        elif isinstance(node, ast.AnnAssign) and isinstance(
                node.target, ast.Name) and node.value is not None:
            assigns[node.target.id] = node.value
    return assigns


def _is_clamped(node: ast.AST, assigns: Dict[str, ast.AST],
                seen: Optional[Set[str]] = None) -> bool:
    """Statically nonzero: a nonzero constant, a clamp-call result, or an
    Add/Sub/Mult combination of clamped parts (Sub conservatively requires
    only one side — safe*(safe-1) with safe>=2 is the idiom)."""
    seen = seen or set()
    if isinstance(node, ast.Constant):
        return isinstance(node.value, (int, float)) and node.value != 0
    if isinstance(node, ast.Call) and _dotted(node.func) in _CLAMP_FNS:
        return True
    if isinstance(node, ast.Name):
        if node.id in seen or node.id not in assigns:
            return False
        return _is_clamped(assigns[node.id], assigns, seen | {node.id})
    if isinstance(node, ast.BinOp):
        if isinstance(node.op, ast.Mult):
            return (_is_clamped(node.left, assigns, seen)
                    and _is_clamped(node.right, assigns, seen))
        if isinstance(node.op, (ast.Add, ast.Sub)):
            return (_is_clamped(node.left, assigns, seen)
                    or _is_clamped(node.right, assigns, seen))
    if isinstance(node, ast.BoolOp):
        return all(_is_clamped(v, assigns, seen) for v in node.values)
    return False


def _check_estimators(tree: ast.Module, path: str,
                      out: List[Violation]) -> None:
    var_fn = None
    for fn in ast.walk(tree):
        if not isinstance(fn, (ast.FunctionDef, ast.AsyncFunctionDef)):
            continue
        if fn.name == "variance_estimate":
            var_fn = fn
        assigns = _collect_assignments(fn)
        for node in ast.walk(fn):
            if isinstance(node, ast.BinOp) and isinstance(node.op, ast.Div):
                if not _is_clamped(node.right, assigns):
                    out.append(Violation(
                        path, node.lineno, "C005",
                        f"division in {fn.name!r} with an unclamped "
                        "denominator — route it through jnp.maximum/clip "
                        "so no NaN reaches a QueryResult"))
    if var_fn is None:
        out.append(Violation(path, 1, "C006",
                             "variance_estimate is missing"))
        return
    src_calls = {_dotted(n.func) for n in ast.walk(var_fn)
                 if isinstance(n, ast.Call)}
    if not src_calls & {"jnp.maximum", "jax.numpy.maximum"}:
        out.append(Violation(
            path, var_fn.lineno, "C006",
            "variance_estimate lost its jnp.maximum clamp"))
    if not src_calls & {"jnp.where", "jax.numpy.where"}:
        out.append(Violation(
            path, var_fn.lineno, "C006",
            "variance_estimate lost its jnp.where small-sample gate"))


# ---------------------------------------------------------------------------
# C007 — checkpoint envelope manifest
# ---------------------------------------------------------------------------

def _check_envelope(tree: ast.Module, path: str,
                    out: List[Violation]) -> None:
    version: Optional[int] = None
    ver_line = 1
    meta_fn = None
    for node in ast.walk(tree):
        if isinstance(node, ast.Assign) and len(node.targets) == 1 and (
                isinstance(node.targets[0], ast.Name)
                and node.targets[0].id == "_CKPT_VERSION"
                and isinstance(node.value, ast.Constant)):
            version = node.value.value
            ver_line = node.lineno
        if isinstance(node, ast.FunctionDef) and node.name == "_meta":
            meta_fn = node
    if version is None or meta_fn is None:
        out.append(Violation(
            path, 1, "C007",
            "could not locate _CKPT_VERSION and Session._meta — the "
            "envelope manifest check has lost its anchor"))
        return
    newest = max(ENVELOPE_HISTORY)
    if version != newest:
        out.append(Violation(
            path, ver_line, "C007",
            f"_CKPT_VERSION is {version} but ENVELOPE_HISTORY's newest "
            f"manifest is v{newest} — bump the version and record the new "
            "key set in repro/analysis/contracts.py"))
        return
    ret_dict = None
    for node in ast.walk(meta_fn):
        if isinstance(node, ast.Return) and isinstance(node.value, ast.Dict):
            ret_dict = node.value
    if ret_dict is None:
        out.append(Violation(
            path, meta_fn.lineno, "C007",
            "_meta no longer returns a literal dict — the envelope "
            "manifest can no longer be audited statically"))
        return
    keys = set()
    for k in ret_dict.keys:
        if isinstance(k, ast.Constant) and isinstance(k.value, str):
            keys.add(k.value)
        else:
            out.append(Violation(
                path, getattr(k, "lineno", meta_fn.lineno), "C007",
                "_meta uses a non-literal key — envelope keys must be "
                "string literals so the manifest stays auditable"))
    manifest = ENVELOPE_HISTORY[newest]
    extra, missing = keys - manifest, manifest - keys
    if extra or missing:
        detail = []
        if extra:
            detail.append(f"unmanifested keys {sorted(extra)}")
        if missing:
            detail.append(f"missing manifest keys {sorted(missing)}")
        out.append(Violation(
            path, meta_fn.lineno, "C007",
            f"Session._meta drifted from the v{newest} envelope manifest "
            f"({'; '.join(detail)}) — changing the envelope requires a "
            "_CKPT_VERSION bump plus a new ENVELOPE_HISTORY entry"))


# ---------------------------------------------------------------------------
# C010 — PlanNode monoid/estimator declarations
# ---------------------------------------------------------------------------

def _check_plan_nodes(tree: ast.Module, path: str,
                      out: List[Violation]) -> None:
    """Every class deriving (transitively, within the file) from PlanNode
    must declare ``monoid`` and ``estimator`` class attributes.  The base
    class itself is exempt — it defines the defaults the rule demands
    subclasses override deliberately."""
    classes: Dict[str, ast.ClassDef] = {}
    for node in ast.walk(tree):
        if isinstance(node, ast.ClassDef):
            classes[node.name] = node

    def derives(node: ast.ClassDef, seen: frozenset = frozenset()) -> bool:
        for b in node.bases:
            leaf = _dotted(b).split(".")[-1]
            if leaf == "PlanNode":
                return True
            if leaf in classes and leaf not in seen and derives(
                    classes[leaf], seen | {leaf}):
                return True
        return False

    for name, node in classes.items():
        if name == "PlanNode" or not derives(node):
            continue
        defined: Set[str] = set()
        for item in node.body:
            if isinstance(item, ast.Assign):
                for t in item.targets:
                    if isinstance(t, ast.Name):
                        defined.add(t.id)
            elif isinstance(item, ast.AnnAssign) and isinstance(
                    item.target, ast.Name):
                defined.add(item.target.id)
        missing = [a for a in ("monoid", "estimator") if a not in defined]
        if missing:
            out.append(Violation(
                path, node.lineno, "C010",
                f"PlanNode subclass {name} does not declare "
                f"{' or '.join(missing)} — every plan node states its "
                "merge monoid and estimator pairing (DESIGN.md §13)"))


# ---------------------------------------------------------------------------
# C009 — deprecated loose plan kwargs in framework code
# ---------------------------------------------------------------------------

def _check_plan_kwargs(tree: ast.Module, path: str,
                       out: List[Violation]) -> None:
    """Flag ``run_query``/``run_queries``/``Session`` calls passing any
    deprecated plan kwarg.  Matching is by call-site leaf name, so both
    ``EN.run_query(...)`` and ``repro.run_query(...)`` are covered;
    ``Session.resume`` / ``cls(...)`` / ``audit_plan`` have different
    leaves and keep their own signatures."""
    for node in ast.walk(tree):
        if not isinstance(node, ast.Call):
            continue
        leaf = _dotted(node.func).split(".")[-1]
        if leaf not in _PLAN_ENTRY_POINTS:
            continue
        bad = sorted(k.arg for k in node.keywords
                     if k.arg in DEPRECATED_PLAN_KWARGS)
        if bad:
            out.append(Violation(
                path, node.lineno, "C009",
                f"{leaf}(...) called with deprecated loose plan kwarg(s) "
                f"{bad} — build a repro.QuerySpec instead (the kwarg shim "
                "is for end-user back-compat only)"))


def _c009_exempt(rel: str) -> bool:
    """tests/ may exercise the deprecated shim — it is under test there."""
    parts = rel.replace("\\", "/").split("/")
    return "tests" in parts


# ---------------------------------------------------------------------------
# Suppressions (C008) and the per-file driver
# ---------------------------------------------------------------------------

def _suppressions(src: str) -> Dict[int, str]:
    """line -> suppressed rule, from REAL comment tokens only.

    Tokenizing (rather than scanning raw lines) keeps suppression text
    inside string literals — lint fixtures, docs — from being honored."""
    sup: Dict[int, str] = {}
    try:
        tokens = tokenize.generate_tokens(io.StringIO(src).readline)
        for tok in tokens:
            if tok.type == tokenize.COMMENT:
                m = _SUPPRESS_RE.search(tok.string)
                if m:
                    sup[tok.start[0]] = m.group(1)
    except (tokenize.TokenizeError, IndentationError, SyntaxError):
        pass  # unparseable source already fails as C000
    return sup


def _rel(path: Path, root: Path) -> str:
    try:
        return str(path.relative_to(root))
    except ValueError:
        return str(path)


def lint_file(path: Path, root: Path) -> List[Violation]:
    rel = _rel(path, root)
    src = path.read_text()
    try:
        tree = ast.parse(src, filename=rel)
    except SyntaxError as e:
        return [Violation(rel, e.lineno or 1, "C000",
                          f"syntax error: {e.msg}")]
    out: List[Violation] = []
    _check_gla(tree, rel, out)
    _check_plan_nodes(tree, rel, out)
    for suffix, policy in JIT_REGION_FILES.items():
        if rel.replace("\\", "/").endswith(suffix):
            for fn in _jit_functions(tree, policy):
                _check_host_calls(fn, rel, out)
    if rel.replace("\\", "/").endswith("core/estimators.py"):
        _check_estimators(tree, rel, out)
    if rel.replace("\\", "/").endswith("core/session.py"):
        _check_envelope(tree, rel, out)
    if not _c009_exempt(rel):
        _check_plan_kwargs(tree, rel, out)

    sup = _suppressions(src)
    kept: List[Violation] = []
    consumed: Set[int] = set()
    for v in out:
        if sup.get(v.line) == v.code:
            consumed.add(v.line)
            key = next((s for s in (a for a, _ in ALLOWLIST)
                        if rel.endswith(s)), None)
            if (key, v.code) in ALLOWLIST:
                continue  # documented, allowlisted suppression
            kept.append(Violation(
                v.path, v.line, "C008",
                f"suppression of {v.code} not in the contracts ALLOWLIST "
                f"(suppressed: {v.message})"))
        else:
            kept.append(v)
    # a suppression that silenced nothing of its code is stale — also C008
    for line, code in sup.items():
        if line not in consumed:
            kept.append(Violation(
                rel, line, "C008",
                f"stale suppression: no {code} violation on this line"))
    return kept


def iter_py_files(targets: Sequence[str], root: Path) -> Iterable[Path]:
    for t in targets:
        p = (root / t) if not Path(t).is_absolute() else Path(t)
        if p.is_file() and p.suffix == ".py":
            yield p
        elif p.is_dir():
            for f in sorted(p.rglob("*.py")):
                if "out" in f.parts or "__pycache__" in f.parts:
                    continue
                yield f


def main(argv: Optional[Sequence[str]] = None) -> int:
    ap = argparse.ArgumentParser(
        description="PF-OLA framework-contract linter (rules C001-C010; "
                    "see DESIGN.md §10)")
    ap.add_argument("targets", nargs="*",
                    default=["src", "tests", "benchmarks", "examples"],
                    help="files or directories to lint (default: the four "
                         "first-party trees)")
    ap.add_argument("--root", default=".",
                    help="repo root for relative paths (default: cwd)")
    args = ap.parse_args(argv)
    root = Path(args.root).resolve()

    violations: List[Violation] = []
    n_files = 0
    for f in iter_py_files(args.targets, root):
        n_files += 1
        violations.extend(lint_file(f, root))
    for v in violations:
        print(v)
    tag = "FAIL" if violations else "OK"
    print(f"contracts: {tag} — {len(violations)} violation(s) across "
          f"{n_files} file(s)")
    return 1 if violations else 0


if __name__ == "__main__":
    sys.exit(main())
