"""On-line aggregation over a *model* computation: dataset-level eval loss
with anytime confidence bounds (paper query (1) with func = loss).

Trains a small LM for a few steps, then streams a 32K-example eval corpus
through the OLA engine; the mean loss estimate tightens every round and the
sweep can stop early at a target precision — the paper's interactive
exploration, applied to ML evaluation.

    PYTHONPATH=src python examples/online_eval.py
"""
import sys
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parents[1] / "src"))

import jax
import jax.numpy as jnp
import numpy as np

import repro
from repro.configs import get_config
from repro.core import metrics, randomize
from repro.models import transformer as T
from repro.training import train_step as TS

SEQ = 32
EVAL_EXAMPLES = 32_768
PARTS = 8
TARGET_REL_WIDTH = 0.01


def main():
    cfg = get_config("smollm_135m").smoke()
    key = jax.random.key(0)
    params, opt = TS.init_train_state(cfg, key, dtype=jnp.float32)
    step = jax.jit(TS.make_train_step(cfg, lr=3e-3))
    for i in range(5):
        batch = {"tokens": jax.random.randint(jax.random.key(100 + i),
                                              (8, SEQ), 0, cfg.vocab_size)}
        params, opt, m = step(params, opt, batch)
    print(f"trained 5 steps, loss {float(m['loss']):.3f}")

    # eval corpus as a columnar dataset: one row per example
    toks = jax.random.randint(jax.random.key(7), (EVAL_EXAMPLES, SEQ),
                              0, cfg.vocab_size)
    cols = {f"t{j}": toks[:, j] for j in range(SEQ)}

    def loss_per_example(chunk):
        tt = jnp.stack([chunk[f"t{j}"] for j in range(SEQ)], axis=1)
        x, _, _ = T.forward(params, cfg, {"tokens": tt})
        tgt = jnp.pad(tt[:, 1:], ((0, 0), (0, 1)))
        head = (params["embed"].T if cfg.tie_embeddings
                else params["lm_head"])
        logits = (x @ head).astype(jnp.float32)
        lse = jax.nn.logsumexp(logits, axis=-1)
        gold = jnp.take_along_axis(logits, tgt[..., None], axis=-1)[..., 0]
        nll = (lse - gold)[:, :-1]
        return jnp.mean(nll, axis=1)

    parts = randomize.randomize_global(cols, jax.random.key(1), PARTS)
    shards = randomize.pack_partitions(parts, chunk_len=256)
    g = metrics.make_loss_gla(loss_per_example, d_total=float(EVAL_EXAMPLES))
    res = repro.run_query(repro.QuerySpec(g, rounds=8), shards)
    mean, lo, hi = metrics.mean_with_bounds(res.estimates)
    print(f"{'scanned':>8s} {'mean loss':>10s} {'95% CI':>19s} {'rel.w':>7s}")
    for r in range(len(mean)):
        frac = float(np.asarray(res.snapshots.scanned)[r]) / EVAL_EXAMPLES
        w = (hi[r] - lo[r]) / max(abs(mean[r]), 1e-9)
        marker = "  <-- could stop here" if w <= TARGET_REL_WIDTH else ""
        print(f"{frac:7.0%} {mean[r]:10.4f} [{lo[r]:8.4f},{hi[r]:8.4f}] "
              f"{w:7.4f}{marker}")


if __name__ == "__main__":
    main()
