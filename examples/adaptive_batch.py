"""Confidence-bounded gradient accumulation: the paper's estimator applied
to the microbatch loop (beyond-paper feature).

Each training step accumulates microbatch gradients only until the
confidence interval on the step's mean loss is tight — late microbatches
carry little information once the estimate has converged, so the step
fires early (adaptive effective batch size).

    PYTHONPATH=src python examples/adaptive_batch.py
"""
import sys
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parents[1] / "src"))

import jax
import jax.numpy as jnp

from repro.configs import get_config
from repro.models import spec, transformer as T
from repro.training import grad_estimator as GE
from repro.training import optimizer as O
from repro.training.train_step import loss_fn

SEQ, MICRO, MB = 32, 16, 4


def main():
    cfg = get_config("smollm_135m").smoke()
    key = jax.random.key(0)
    params = spec.init_params(T.param_specs(cfg, dtype=jnp.float32), key)
    opt = O.opt_init(params, cfg.optimizer)

    @jax.jit
    def grad_fn(p, mb):
        (loss, _), g = jax.value_and_grad(loss_fn, has_aux=True)(p, cfg, mb)
        return loss, g

    for step in range(8):
        toks = jax.random.randint(jax.random.key(step), (MICRO * MB, SEQ),
                                  0, cfg.vocab_size)
        micro = {"tokens": toks.reshape(MICRO, MB, SEQ)}
        grads, n_used, hist = GE.accumulate_until_confident(
            grad_fn, params, micro, target_rel_width=0.08)
        params, opt = O.opt_update(grads, opt, params, cfg.optimizer,
                                   lr=3e-3)
        last = hist[-1]
        print(f"step {step}: used {n_used}/{MICRO} microbatches "
              f"(rel CI width {last['rel_width']:.3f}), "
              f"loss {last['loss']:.4f}")


if __name__ == "__main__":
    main()
