"""LLM serving demo: batched prefill + greedy decode.

Moved here from ``repro.launch.serve`` — that module is now the OLA
query service entry point (DESIGN.md §11); this demo drives the model
half of the serving stack (``repro.serving.serve_step``).

    PYTHONPATH=src python examples/llm_serve_demo.py --arch qwen3_32b \
        --smoke --batch 4 --prompt-len 16 --gen 24

On hardware the same prefill/decode steps run under the production mesh
with the flash-decoding cache sharding proven by the dry-run.
"""
from __future__ import annotations

import argparse
import sys
import time
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parents[1] / "src"))

import jax
import jax.numpy as jnp

from repro.configs import get_config
from repro.models import spec, transformer as T
from repro.serving import serve_step as SS


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="smollm_135m")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--gen", type=int, default=24)
    ap.add_argument("--smoke", action="store_true")
    args = ap.parse_args(argv)

    cfg = get_config(args.arch)
    if args.smoke:
        cfg = cfg.smoke()
    key = jax.random.key(0)
    params = spec.init_params(
        T.param_specs(cfg, dtype=jnp.float32 if args.smoke else jnp.bfloat16),
        key)
    batch = {"tokens": jax.random.randint(
        key, (args.batch, args.prompt_len), 0, cfg.vocab_size)}
    if cfg.frontend == "vision_stub":
        batch["patches"] = jax.random.normal(
            key, (args.batch, cfg.vis_tokens, cfg.d_model), jnp.float32)
    if cfg.is_encoder_decoder:
        batch["frames"] = jax.random.normal(
            key, (args.batch, cfg.encoder_seq, cfg.d_model), jnp.float32)

    total = args.prompt_len + (cfg.vis_tokens if cfg.frontend else 0)
    t0 = time.time()
    out = SS.greedy_generate(cfg, params, batch, steps=args.gen,
                             cache_len=total + args.gen + 1)
    dt = time.time() - t0
    print(f"arch={cfg.name} generated [{args.batch}, {args.gen}] tokens "
          f"in {dt:.2f}s ({args.batch * args.gen / dt:.1f} tok/s)")
    print("sample:", jax.device_get(out[0])[:16].tolist())


if __name__ == "__main__":
    main()
