"""Quickstart: on-line aggregation in 40 lines.

Runs TPC-H Q6 (low selectivity) over a synthetic 1M-row lineitem instance
with the paper's asynchronous single estimator and prints the anytime
estimate with 95% confidence bounds as the scan progresses — stop reading
whenever the bounds are tight enough for you.

    PYTHONPATH=src python examples/quickstart.py
"""
import sys
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parents[1] / "src"))

import jax
import jax.numpy as jnp
import numpy as np

import repro
from repro.core import randomize
from repro.data import tpch

ROWS = 1_000_000
PARTITIONS = 8

# 1. generate + globally randomize + chunk the data (paper §4.2 load path)
cols = tpch.generate_lineitem(ROWS)
parts = randomize.randomize_global(
    {k: jnp.asarray(v) for k, v in cols.items()}, jax.random.key(0),
    PARTITIONS)
shards = randomize.pack_partitions(parts, chunk_len=2048)

# 2. express the query as a GLA with the single-estimator model (Alg. 1)
query = repro.make_sum_gla(
    tpch.q6_func, tpch.q6_cond(tpch.Q6_LOW_WINDOW),
    d_total=float(ROWS), estimator="single")

# 3. run with on-line estimation (10 snapshot rounds)
res = repro.run_query(repro.QuerySpec(query, rounds=10), shards)

exact = tpch.exact_answer(cols, tpch.q6_func,
                          tpch.q6_cond(tpch.Q6_LOW_WINDOW))[0]
print(f"{'scanned':>9s} {'estimate':>12s} {'lower':>12s} {'upper':>12s} "
      f"{'rel.width':>9s}")
est = res.estimates
for r in range(10):
    e = float(np.asarray(est.estimate)[r])
    lo = float(np.asarray(est.lower)[r])
    hi = float(np.asarray(est.upper)[r])
    frac = float(np.asarray(res.snapshots.scanned)[r]) / ROWS
    print(f"{frac:8.0%} {e:12.2f} {lo:12.2f} {hi:12.2f} "
          f"{(hi - lo) / max(abs(e), 1e-9):9.4f}")
print(f"\nexact answer: {exact:.2f}   final: {float(res.final):.2f}")
assert abs(float(res.final) - exact) / abs(exact) < 1e-3
