"""End-to-end driver: the paper's full TPC-H evaluation workload.

All three tasks (aggregation, group-by, join group-by), each with the three
estimation models (single / multiple / synchronized-semantics), plus a
straggler simulation — the paper's §5 in one script, scaled to one CPU.

    PYTHONPATH=src python examples/tpch_ola.py [rows]
"""
import sys
import time
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parents[1] / "src"))

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import engine, gla, randomize
from repro.data import tpch

ROWS = int(sys.argv[1]) if len(sys.argv) > 1 else 500_000
PARTS = 8


def main():
    cols = tpch.generate_lineitem(ROWS, seed=5)
    parts = randomize.randomize_global(
        {k: jnp.asarray(v) for k, v in cols.items()}, jax.random.key(3),
        PARTS)
    # pad chunk count to a multiple of 8 so every run gets 8 snapshot rounds
    n_chunks = -(-ROWS // PARTS // 1024)
    shards = randomize.pack_partitions(parts, chunk_len=1024,
                                       min_chunks=-(-n_chunks // 8) * 8)
    supp, valid = tpch.supplier_nation_table()

    queries = {
        "Q6 agg (low sel)": lambda est: gla.make_sum_gla(
            tpch.q6_func, tpch.q6_cond(tpch.Q6_LOW_WINDOW),
            d_total=float(ROWS), estimator=est),
        "Q6 agg (high sel)": lambda est: gla.make_sum_gla(
            tpch.q6_func, tpch.q6_cond(tpch.Q6_HIGH_WINDOW),
            d_total=float(ROWS), estimator=est),
        "Q1 group-by small": lambda est: gla.make_groupby_gla(
            tpch.q1_func, tpch.q1_cond, tpch.q1_group_small, num_groups=4,
            d_total=float(ROWS), estimator=est, num_aggs=4),
        "join group-by": lambda est: gla.make_join_groupby_gla(
            tpch.q1_func, tpch.q6_cond(tpch.Q6_LOW_WINDOW),
            lambda c: c["suppkey"], supp, valid,
            num_groups=tpch.NUM_NATIONS, d_total=float(ROWS),
            estimator=est, num_aggs=4),
    }

    C = shards["_mask"].shape[1]
    rounds = 8
    while C % rounds:
        rounds -= 1

    for name, make in queries.items():
        print(f"\n=== {name} ===")
        for est_kind in ("single", "multiple"):
            g = make(est_kind)
            t0 = time.perf_counter()
            res = engine.run_query(g, shards, rounds=rounds, emit="round")
            jax.block_until_ready(res.final)
            dt = time.perf_counter() - t0
            est = res.estimates
            lo = np.asarray(est.lower, np.float64)
            hi = np.asarray(est.upper, np.float64)
            mid = np.asarray(est.estimate, np.float64)
            while mid.ndim > 1:           # group-by: report group 0, agg -1
                lo, hi, mid = lo[..., 0], hi[..., 0], mid[..., 0]
            w = (hi - lo) / np.maximum(np.abs(mid), 1e-12)
            print(f"  {est_kind:9s} {dt:6.2f}s  rel.width by round: "
                  + " ".join(f"{x:.3f}" for x in w))

        # straggler run: partitions at different speeds, async estimation
        sched = engine.straggler_schedule(PARTS, C, rounds,
                                          speeds=[1, 1, 1, 1, 2, 2, 3, 4])
        g = make("single")
        res = engine.run_query(g, shards, schedule=sched, mode="async")
        print(f"  async+stragglers final matches: "
              f"{np.allclose(np.asarray(res.final), np.asarray(engine.run_query(g, shards, rounds=rounds).final), rtol=1e-5)}")


if __name__ == "__main__":
    main()
